"""Make `pytest python/tests/` work from the repo root: the build-time
package (`compile`) lives in python/, not on the default sys.path."""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
