"""AOT lowering: JAX → HLO **text** artifacts for the Rust/PJRT runtime.

HLO text (not a serialized ``HloModuleProto``) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Artifacts:
    train_step.hlo.txt       fused fwd/bwd/Adam step     (flat interface)
    forward.hlo.txt          inference logits            (flat interface)
    repmatmul_strict.hlo.txt the Layer-1 strict kernel on a fixed shape,
                             for the Rust↔XLA cross-backend bitwise test
    repmatmul_mxu.hlo.txt    the MXU-tiled kernel, same shape
    manifest.json            config + flat-parameter name/shape table
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.repmatmul import repmatmul_mxu, repmatmul_strict, vmem_footprint_bytes
from .model import Config, flat_names, forward_flat, param_shapes, train_step_flat

# the canonical cross-backend test shape (divisible by the default tiles)
XSHAPE = (32, 48, 16)  # (M, K, N)


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(cfg: Config):
    """Lower the flat train step and forward functions."""
    names = flat_names(cfg)
    shapes = param_shapes(cfg)
    p_specs = [jax.ShapeDtypeStruct(shapes[k], jnp.float32) for k in names]
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)
    tgt = jax.ShapeDtypeStruct((cfg.batch * cfg.seq,), jnp.int32)
    step = jax.ShapeDtypeStruct((), jnp.float32)

    fwd = jax.jit(lambda *a: forward_flat(cfg, *a)).lower(*p_specs, tok)
    ts = jax.jit(lambda *a: train_step_flat(cfg, *a)).lower(
        *p_specs, *p_specs, *p_specs, tok, tgt, step
    )
    return to_hlo_text(fwd), to_hlo_text(ts)


def lower_kernels():
    m, k, n = XSHAPE
    x = jax.ShapeDtypeStruct((m, k), jnp.float32)
    y = jax.ShapeDtypeStruct((k, n), jnp.float32)
    strict = jax.jit(lambda a, b: (repmatmul_strict(a, b, bm=8, bn=16),)).lower(x, y)
    mxu = jax.jit(lambda a, b: (repmatmul_mxu(a, b, bm=8, bk=16, bn=16),)).lower(x, y)
    return to_hlo_text(strict), to_hlo_text(mxu)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--n-heads", type=int, default=4)
    ap.add_argument("--d-ff", type=int, default=128)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args()

    cfg = Config(
        vocab=args.vocab,
        d_model=args.d_model,
        n_layers=args.n_layers,
        n_heads=args.n_heads,
        d_ff=args.d_ff,
        seq=args.seq,
        batch=args.batch,
    )
    os.makedirs(args.out_dir, exist_ok=True)

    fwd_txt, ts_txt = lower_model(cfg)
    strict_txt, mxu_txt = lower_kernels()
    outputs = {
        "forward.hlo.txt": fwd_txt,
        "train_step.hlo.txt": ts_txt,
        "repmatmul_strict.hlo.txt": strict_txt,
        "repmatmul_mxu.hlo.txt": mxu_txt,
    }
    for fname, text in outputs.items():
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    m, k, n = XSHAPE
    manifest = {
        "config": cfg.to_dict(),
        "params": [[name, list(shape)] for name, shape in param_shapes(cfg).items()],
        "xmatmul_shape": [m, k, n],
        "vmem_strict_tile_bytes": vmem_footprint_bytes(m, k, n, 8, 16),
        "artifacts": list(outputs.keys()),
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")

    # line-based twin of the manifest for the (JSON-parser-free) Rust side:
    #   param <name> <d0> <d1> ...
    #   config <key> <value>
    tpath = os.path.join(args.out_dir, "manifest.txt")
    with open(tpath, "w") as f:
        for key in ("vocab", "d_model", "n_layers", "n_heads", "d_ff", "seq", "batch"):
            f.write(f"config {key} {getattr(cfg, key)}\n")
        f.write(f"config xm {m}\nconfig xk {k}\nconfig xn {n}\n")
        for name, shape in param_shapes(cfg).items():
            dims = " ".join(str(d) for d in shape)
            f.write(f"param {name} {dims}\n")
    print(f"wrote {tpath}")


if __name__ == "__main__":
    main()
