"""Layer 2 — the JAX training-step / inference graphs, lowered AOT to HLO.

A Llama-style causal LM (RMSNorm, RoPE, SiLU-gated MLP) whose dense
projections run through the Layer-1 RepOps Pallas kernels
(:mod:`compile.kernels.repmatmul`), so the reproducible-matmul operation
order lowers into the same HLO artifact the Rust runtime executes.

Everything here is build-time only: ``compile.aot`` lowers
:func:`train_step` and :func:`forward` once; the Rust coordinator loads the
HLO text via PJRT and Python never appears on the request path.
"""

from dataclasses import dataclass, field, asdict

import jax
import jax.numpy as jnp

from .kernels.repmatmul import repmatmul_mxu


@dataclass(frozen=True)
class Config:
    vocab: int = 256
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 128
    seq: int = 16
    batch: int = 2
    rope_base: float = 10_000.0
    # Adam
    lr: float = 1e-2
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    # kernel tiles (MXU-shaped on real TPU; clipped to shapes here)
    bm: int = 8
    bk: int = 64
    bn: int = 128

    def to_dict(self):
        return asdict(self)


def param_shapes(cfg: Config) -> dict:
    """Name → shape for every learnable parameter (sorted name order is the
    canonical flattening used by the AOT artifact manifest)."""
    shapes = {
        "embed.w": (cfg.vocab, cfg.d_model),
        "final_norm.gamma": (cfg.d_model,),
        "lm_head.w": (cfg.d_model, cfg.vocab),
    }
    for l in range(cfg.n_layers):
        p = f"blk{l}"
        shapes[f"{p}.attn_norm.gamma"] = (cfg.d_model,)
        for proj in ("q", "k", "v", "o"):
            shapes[f"{p}.attn.{proj}.w"] = (cfg.d_model, cfg.d_model)
        shapes[f"{p}.mlp_norm.gamma"] = (cfg.d_model,)
        shapes[f"{p}.mlp.gate.w"] = (cfg.d_model, cfg.d_ff)
        shapes[f"{p}.mlp.up.w"] = (cfg.d_model, cfg.d_ff)
        shapes[f"{p}.mlp.down.w"] = (cfg.d_ff, cfg.d_model)
    return dict(sorted(shapes.items()))


def init_params(cfg: Config, seed: int = 0) -> dict:
    """Deterministic 1/√fan_in init (gammas to 1)."""
    params = {}
    key = jax.random.PRNGKey(seed)
    for name, shape in param_shapes(cfg).items():
        if name.endswith(".gamma"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            key, sub = jax.random.split(key)
            scale = 1.0 / (shape[0] ** 0.5)
            params[name] = jax.random.uniform(
                sub, shape, jnp.float32, -scale, scale
            )
    return params


def _mm_impl(cfg: Config, x, w):
    """2-D projection through the Layer-1 reproducible kernel."""
    bm = cfg.bm if x.shape[0] % cfg.bm == 0 else x.shape[0]
    bk = cfg.bk if x.shape[1] % cfg.bk == 0 else x.shape[1]
    bn = cfg.bn if w.shape[1] % cfg.bn == 0 else w.shape[1]
    return repmatmul_mxu(x, w, bm=bm, bk=bk, bn=bn)


# pallas_call has no autodiff rule; give the projection the standard matmul
# VJP with BOTH backward contractions routed through the reproducible kernel
# (transposes are pure movement) — the same backward graph the Rust engine's
# autodiff emits (dA = dY·Bᵀ, dB = Aᵀ·dY).
import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _mm(cfg: Config, x, w):
    return _mm_impl(cfg, x, w)


def _mm_fwd(cfg: Config, x, w):
    return _mm_impl(cfg, x, w), (x, w)


def _mm_bwd(cfg: Config, res, g):
    x, w = res
    dx = _mm_impl(cfg, g, w.T)
    dw = _mm_impl(cfg, x.T, g)
    return dx, dw


_mm.defvjp(_mm_fwd, _mm_bwd)


def _rmsnorm(x, gamma, eps=1e-6):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gamma


def _rope_tables(cfg: Config):
    dh = cfg.d_model // cfg.n_heads
    half = dh // 2
    pos = jnp.arange(cfg.seq, dtype=jnp.float32)[:, None]
    freq = cfg.rope_base ** (-2.0 * jnp.arange(half, dtype=jnp.float32) / dh)
    theta = pos * freq[None, :]
    return jnp.sin(theta), jnp.cos(theta)  # each (seq, dh/2)


def _rope(x, sin, cos):
    """Interleaved-pair rotation; x: (..., seq, dh)."""
    x0 = x[..., 0::2]
    x1 = x[..., 1::2]
    r0 = x0 * cos - x1 * sin
    r1 = x0 * sin + x1 * cos
    out = jnp.stack([r0, r1], axis=-1)
    return out.reshape(x.shape)


def forward(cfg: Config, params: dict, tokens):
    """Causal-LM logits: tokens (batch, seq) int32 → (batch*seq, vocab)."""
    b, s = tokens.shape
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    x = params["embed.w"][tokens]  # (b, s, d)
    x = x.reshape(b * s, d)
    sin, cos = _rope_tables(cfg)
    mask = jnp.where(
        jnp.arange(s)[None, :] > jnp.arange(s)[:, None], -1e9, 0.0
    ).astype(jnp.float32)

    for l in range(cfg.n_layers):
        p = f"blk{l}"
        xn = _rmsnorm(x, params[f"{p}.attn_norm.gamma"])
        q = _mm(cfg, xn, params[f"{p}.attn.q.w"])
        k = _mm(cfg, xn, params[f"{p}.attn.k.w"])
        v = _mm(cfg, xn, params[f"{p}.attn.v.w"])

        def heads(t):
            return t.reshape(b, s, h, dh).transpose(0, 2, 1, 3)  # (b,h,s,dh)

        q, k, v = heads(q), heads(k), heads(v)
        q = _rope(q, sin, cos)
        k = _rope(k, sin, cos)
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / (dh**0.5)
        probs = jax.nn.softmax(scores + mask[None, None], axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b * s, d)
        x = x + _mm(cfg, ctx, params[f"{p}.attn.o.w"])

        xn = _rmsnorm(x, params[f"{p}.mlp_norm.gamma"])
        gate = jax.nn.silu(_mm(cfg, xn, params[f"{p}.mlp.gate.w"]))
        up = _mm(cfg, xn, params[f"{p}.mlp.up.w"])
        x = x + _mm(cfg, gate * up, params[f"{p}.mlp.down.w"])

    x = _rmsnorm(x, params["final_norm.gamma"])
    return _mm(cfg, x, params["lm_head.w"])  # (b*s, vocab)


def loss_fn(cfg: Config, params: dict, tokens, targets):
    """Mean next-token cross-entropy; targets (batch*seq,) int32."""
    logits = forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[:, None], axis=1)[:, 0]
    return jnp.mean(nll)


def train_step(cfg: Config, params, m, v, tokens, targets, step):
    """One fused fwd/bwd/Adam step.

    ``step`` is the 1-based step index (float32 scalar; bias correction).
    Returns (new_params, new_m, new_v, loss).
    """
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens, targets))(params)
    bc1 = 1.0 - cfg.beta1**step
    bc2 = 1.0 - cfg.beta2**step

    def upd(w, g, mi, vi):
        mi = cfg.beta1 * mi + (1.0 - cfg.beta1) * g
        vi = cfg.beta2 * vi + (1.0 - cfg.beta2) * g * g
        mhat = mi / bc1
        vhat = vi / bc2
        return w - cfg.lr * mhat / (jnp.sqrt(vhat) + cfg.eps), mi, vi

    out = jax.tree_util.tree_map(upd, params, grads, m, v)
    new_p = {k: t[0] for k, t in out.items()}
    new_m = {k: t[1] for k, t in out.items()}
    new_v = {k: t[2] for k, t in out.items()}
    return new_p, new_m, new_v, loss


# ---------------------------------------------------------------------------
# flat (positional) wrappers — the AOT artifact interface for the Rust side
# ---------------------------------------------------------------------------

def flat_names(cfg: Config):
    return list(param_shapes(cfg).keys())


def forward_flat(cfg: Config, *args):
    """`(p_0..p_{n-1}, tokens) -> (logits,)` with params in sorted order."""
    names = flat_names(cfg)
    params = dict(zip(names, args[: len(names)]))
    tokens = args[len(names)]
    return (forward(cfg, params, tokens),)


def train_step_flat(cfg: Config, *args):
    """`(p.., m.., v.., tokens, targets, step) -> (p'.., m'.., v'.., loss)`."""
    names = flat_names(cfg)
    n = len(names)
    params = dict(zip(names, args[0:n]))
    m = dict(zip(names, args[n : 2 * n]))
    v = dict(zip(names, args[2 * n : 3 * n]))
    tokens, targets, step = args[3 * n : 3 * n + 3]
    new_p, new_m, new_v, loss = train_step(cfg, params, m, v, tokens, targets, step)
    return (
        *[new_p[k] for k in names],
        *[new_m[k] for k in names],
        *[new_v[k] for k in names],
        loss,
    )
