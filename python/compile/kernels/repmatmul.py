"""Layer 1 — RepOps matmul as Pallas kernels (paper §3.2, adapted to TPU).

The paper's CUDA RepOps parallelizes the M/N loops across threadblocks and
serializes the K loop per output element. The TPU mapping (DESIGN.md
§Hardware-Adaptation):

* threadblock grid        → ``grid=(M/bm, N/bn)`` Pallas grid over output tiles
* shared-memory staging   → ``BlockSpec`` HBM→VMEM schedules
* serialized K loop       → ``jax.lax.fori_loop`` inside the kernel body —
  a reduction order fixed by the *program*, not the hardware

Two variants:

* :func:`repmatmul_strict` — scalar-K accumulation via rank-1 updates; its
  per-element FP operation sequence (separately-rounded mul then add,
  ascending k) is **identical to the Rust engine's** ``repops::matmul``, so
  cross-backend bitwise agreement is testable.
* :func:`repmatmul_mxu` — K-tile accumulation with a per-tile ``jnp.dot``
  (the MXU-shaped variant for real TPEs): the reduction tree is fixed by the
  tile shapes (bm, bk, bn), reproducible across devices that implement the
  same dot contraction, and much faster.

Kernels are lowered with ``interpret=True``: real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute; interpret mode lowers to
plain HLO, preserving the operation order.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _strict_kernel(x_ref, y_ref, o_ref):
    """One (bm, bn) output tile: ascending-k rank-1 accumulation."""
    x = x_ref[...]  # (bm, K)
    y = y_ref[...]  # (K, bn)
    k = x.shape[1]
    acc0 = jnp.zeros((x.shape[0], y.shape[1]), dtype=jnp.float32)

    def body(i, acc):
        # separately-rounded multiply and add, k ascending — the same
        # scalar sequence as rust repops::matmul_into
        return acc + x[:, i][:, None] * y[i, :][None, :]

    o_ref[...] = jax.lax.fori_loop(0, k, body, acc0)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def repmatmul_strict(x, y, bm: int = 8, bn: int = 128):
    """Bitwise-reproducible matmul with the Rust engine's FP order.

    ``x: (M, K), y: (K, N) -> (M, N)`` float32. M must divide by ``bm`` and
    N by ``bn`` (pad first if not).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims {x.shape} @ {y.shape}"
    bm = min(bm, m)
    bn = min(bn, n)
    assert m % bm == 0 and n % bn == 0, f"tile ({bm},{bn}) must divide ({m},{n})"
    return pl.pallas_call(
        _strict_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def _mxu_kernel(x_ref, y_ref, o_ref, *, bk: int):
    """One (bm, bn) output tile: ascending K-tile dot accumulation."""
    x = x_ref[...]  # (bm, K)
    y = y_ref[...]  # (K, bn)
    k = x.shape[1]
    nk = k // bk
    acc0 = jnp.zeros((x.shape[0], y.shape[1]), dtype=jnp.float32)

    def body(t, acc):
        xt = jax.lax.dynamic_slice(x, (0, t * bk), (x.shape[0], bk))
        yt = jax.lax.dynamic_slice(y, (t * bk, 0), (bk, y.shape[1]))
        # per-tile contraction on the MXU; tile-level accumulation order is
        # fixed by this loop
        return acc + jnp.dot(xt, yt, preferred_element_type=jnp.float32)

    return o_ref.__setitem__(..., jax.lax.fori_loop(0, nk, body, acc0))


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def repmatmul_mxu(x, y, bm: int = 128, bk: int = 128, bn: int = 128):
    """MXU-tiled reproducible matmul (TPU-shaped; fixed K-tile order)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2
    bm = min(bm, m)
    bk = min(bk, k)
    bn = min(bn, n)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"tiles ({bm},{bk},{bn}) must divide ({m},{k},{n})"
    )
    return pl.pallas_call(
        functools.partial(_mxu_kernel, bk=bk),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def _softmax_kernel(x_ref, o_ref):
    """Row-block softmax with fixed-order (ascending-j) sum via fori_loop."""
    x = x_ref[...]  # (bm, N)
    m = jnp.max(x, axis=1, keepdims=True)
    e = jnp.exp(x - m)
    n = x.shape[1]

    def body(j, acc):
        return acc + e[:, j]

    s = jax.lax.fori_loop(0, n, body, jnp.zeros((x.shape[0],), jnp.float32))
    o_ref[...] = e / s[:, None]


@functools.partial(jax.jit, static_argnames=("bm",))
def repsoftmax(x, bm: int = 8):
    """Reproducible row softmax (fixed-order row sums)."""
    m, n = x.shape
    bm = min(bm, m)
    assert m % bm == 0
    return pl.pallas_call(
        _softmax_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x)


def vmem_footprint_bytes(m: int, k: int, n: int, bm: int, bn: int) -> int:
    """Estimated VMEM bytes per grid cell for the strict kernel: the x-tile
    (bm, K), y-tile (K, bn), and accumulator (bm, bn), FP32.

    Used by DESIGN.md §Perf to check tiles fit the ~16 MiB VMEM budget —
    interpret mode gives no hardware occupancy numbers.
    """
    del m, n
    return 4 * (bm * k + k * bn + bm * bn)
