"""Pure-jnp / numpy oracles for the Pallas kernels.

Two levels of "correct":

* :func:`matmul_ref` — XLA's own matmul; kernels must match to float32
  tolerance (the *numerics* oracle).
* :func:`matmul_fixed_order` — a numpy loop executing the exact RepOps
  operation sequence (ascending-k, separately-rounded mul+add); the strict
  kernel must match it **bitwise** (the *reproducibility* oracle, and the
  same sequence the Rust engine implements).
"""

import jax.numpy as jnp
import numpy as np


def matmul_ref(x, y):
    """XLA matmul (numerics oracle)."""
    return jnp.matmul(x, y)


def matmul_fixed_order(x, y):
    """The paper's §3.2 pseudo-code executed literally in float32 numpy:
    for each (i, j), sum_k rounds after every mul and every add, ascending k.

    Vectorized over (i, j) — scalar FP ops on the same index are identical
    to the scalar loop — so it stays usable as a test oracle.
    """
    x = np.asarray(x, dtype=np.float32)
    y = np.asarray(y, dtype=np.float32)
    m, k = x.shape
    _, n = y.shape
    acc = np.zeros((m, n), dtype=np.float32)
    for kk in range(k):
        # one separately-rounded mul, one separately-rounded add, per element
        acc = (acc + x[:, kk][:, None] * y[kk, :][None, :]).astype(np.float32)
    return acc


def matmul_fixed_order_fma(x, y):
    """Ascending-k accumulation under the FMA contract: each term folds in
    with a SINGLE rounding, emulated exactly in float64 (a float32 product
    is exact in float64; the fused round-to-f32 is the final astype).

    This is what XLA CPU/GPU (and CUDA FFMA) actually emit for the strict
    kernel — the cross-backend contract implemented by the Rust engine's
    ``repops::matmul_fma``.
    """
    x64 = np.asarray(x, dtype=np.float64)
    y64 = np.asarray(y, dtype=np.float64)
    m, k = x64.shape
    _, n = y64.shape
    acc = np.zeros((m, n), dtype=np.float32)
    for kk in range(k):
        prod = x64[:, kk][:, None] * y64[kk, :][None, :]  # exact in f64
        acc = (acc.astype(np.float64) + prod).astype(np.float32)
    return acc


def softmax_ref(x):
    """Stable softmax oracle."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)
