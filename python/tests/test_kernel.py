"""Layer-1 kernel correctness: Pallas RepOps kernels vs the oracles.

* numerics — allclose against XLA matmul for swept shapes (hypothesis);
* reproducibility — the strict kernel matches the fixed-order numpy oracle
  BITWISE (the same FP sequence the Rust engine implements).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    matmul_fixed_order,
    matmul_fixed_order_fma,
    matmul_ref,
    softmax_ref,
)
from compile.kernels.repmatmul import (
    repmatmul_mxu,
    repmatmul_strict,
    repsoftmax,
    vmem_footprint_bytes,
)


def rand(shape, seed, scale=1.0):
    key = jax.random.PRNGKey(seed)
    return jax.random.uniform(key, shape, jnp.float32, -scale, scale)


# a wide-exponent distribution that exposes reduction-order differences
def adversarial(shape, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    mant = jax.random.uniform(k1, shape, jnp.float32, -1.0, 1.0)
    mag = jax.random.randint(k2, shape, -12, 12).astype(jnp.float32)
    return mant * (2.0**mag)


class TestStrictKernel:
    def test_matches_ref_allclose(self):
        x, y = rand((32, 48), 0), rand((48, 16), 1)
        got = repmatmul_strict(x, y, bm=8, bn=16)
        np.testing.assert_allclose(got, matmul_ref(x, y), rtol=1e-5, atol=1e-5)

    def test_bitwise_matches_fixed_order_fma_oracle(self):
        # THE reproducibility contract: ascending-k accumulation with one
        # rounding per term. XLA contracts `acc + a*b` to FMA, so the
        # kernel's pinned FP sequence is fma(a, b, acc) in ascending k —
        # matched bitwise by the float64-emulated oracle and by the Rust
        # engine's repops::matmul_fma (see rust/tests/cross_backend.rs).
        x, y = adversarial((16, 32), 2), adversarial((32, 8), 3)
        got = np.asarray(repmatmul_strict(x, y, bm=8, bn=8))
        want = matmul_fixed_order_fma(x, y)
        assert got.dtype == np.float32
        np.testing.assert_array_equal(
            got.view(np.uint32), want.view(np.uint32),
            err_msg="strict kernel must be bitwise fixed-order (FMA contract)",
        )
        # and the separate-rounding oracle agrees to a couple of ULPs
        sep = matmul_fixed_order(x, y)
        np.testing.assert_allclose(got, sep, rtol=1e-6)

    def test_tile_invariance_bitwise(self):
        # block shapes parallelize M/N only; bits must not depend on them
        x, y = adversarial((16, 64), 4), adversarial((64, 32), 5)
        a = np.asarray(repmatmul_strict(x, y, bm=16, bn=32))
        b = np.asarray(repmatmul_strict(x, y, bm=2, bn=8))
        np.testing.assert_array_equal(a.view(np.uint32), b.view(np.uint32))

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.sampled_from([4, 8, 16]),
        k=st.sampled_from([3, 16, 33, 64]),
        n=st.sampled_from([4, 8, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_shape_sweep_allclose(self, m, k, n, seed):
        x, y = rand((m, k), seed), rand((k, n), seed + 1)
        got = repmatmul_strict(x, y, bm=min(4, m), bn=min(4, n))
        np.testing.assert_allclose(got, matmul_ref(x, y), rtol=2e-5, atol=2e-5)


class TestMxuKernel:
    def test_matches_ref_allclose(self):
        x, y = rand((32, 48), 6), rand((48, 16), 7)
        got = repmatmul_mxu(x, y, bm=8, bk=16, bn=16)
        np.testing.assert_allclose(got, matmul_ref(x, y), rtol=1e-5, atol=1e-5)

    def test_same_tiles_same_bits(self):
        # For the MXU variant the ENTIRE tile tuple (bm, bk, bn) is part of
        # the reproducibility contract: XLA chooses the in-tile `dot`
        # reduction tree per shape, so changing any tile legally changes
        # bits — the §3.3 "hard-coded kernel parameters" trade-off. The
        # contract is: same program (same tiles) → same bits.
        x, y = adversarial((16, 64), 8), adversarial((64, 32), 9)
        a = np.asarray(repmatmul_mxu(x, y, bm=16, bk=16, bn=32))
        b = np.asarray(repmatmul_mxu(x, y, bm=16, bk=16, bn=32))
        np.testing.assert_array_equal(a.view(np.uint32), b.view(np.uint32))

    def test_k_tile_changes_reduction_tree(self):
        # sanity that the adversarial distribution detects order changes:
        # a different K tiling is a different reduction tree
        x, y = adversarial((16, 64), 8), adversarial((64, 32), 9)
        a = np.asarray(repmatmul_mxu(x, y, bm=16, bk=16, bn=32))
        c = np.asarray(repmatmul_mxu(x, y, bm=16, bk=64, bn=32))
        assert not np.array_equal(a.view(np.uint32), c.view(np.uint32))
        np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(
        mt=st.sampled_from([(8, 8), (16, 4)]),
        k=st.sampled_from([16, 32, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_shape_sweep_allclose(self, mt, k, seed):
        m, n = mt
        x, y = rand((m, k), seed), rand((k, n), seed + 1)
        got = repmatmul_mxu(x, y, bm=m, bk=min(16, k), bn=n)
        np.testing.assert_allclose(got, matmul_ref(x, y), rtol=2e-5, atol=2e-5)


class TestSoftmaxKernel:
    def test_matches_ref(self):
        x = rand((16, 33), 10, scale=6.0)
        got = repsoftmax(x, bm=8)
        np.testing.assert_allclose(got, softmax_ref(x), rtol=1e-5, atol=1e-6)

    def test_rows_sum_to_one(self):
        x = adversarial((8, 64), 11)
        # clamp the adversarial magnitudes: softmax saturates past exp range
        x = jnp.clip(x, -50.0, 50.0)
        got = np.asarray(repsoftmax(x, bm=4))
        np.testing.assert_allclose(got.sum(axis=1), np.ones(8), rtol=1e-5)


def test_vmem_footprint_model():
    # (bm, K) + (K, bn) + (bm, bn) fp32
    assert vmem_footprint_bytes(128, 512, 128, 8, 16) == 4 * (8 * 512 + 512 * 16 + 8 * 16)
    # MXU-shaped tiles on a big contraction stay inside a 16 MiB VMEM budget
    assert vmem_footprint_bytes(4096, 4096, 4096, 128, 128) < 16 << 20


@pytest.mark.parametrize("bad", [(7, 16), (8, 9)])
def test_tile_divisibility_asserted(bad):
    bm, bn = bad
    x, y = rand((16, 16), 12), rand((16, 32), 13)
    with pytest.raises(AssertionError):
        repmatmul_strict(x, y, bm=bm, bn=bn)
