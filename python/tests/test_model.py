"""Layer-2 model tests: shapes, learning signal, determinism, and the flat
AOT interface round-trip."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import (
    Config,
    flat_names,
    forward,
    forward_flat,
    init_params,
    loss_fn,
    param_shapes,
    train_step,
    train_step_flat,
)

CFG = Config(vocab=64, d_model=32, n_layers=1, n_heads=2, d_ff=64, seq=8, batch=2)


def batch_for(cfg, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (cfg.batch, cfg.seq), 0, cfg.vocab)
    targets = jax.random.randint(k2, (cfg.batch * cfg.seq,), 0, cfg.vocab)
    return tokens, targets


def zeros_like_params(cfg):
    return {k: jnp.zeros(s, jnp.float32) for k, s in param_shapes(cfg).items()}


def test_forward_shape_and_loss_near_uniform():
    params = init_params(CFG, 0)
    tokens, targets = batch_for(CFG, 1)
    logits = forward(CFG, params, tokens)
    assert logits.shape == (CFG.batch * CFG.seq, CFG.vocab)
    loss = loss_fn(CFG, params, tokens, targets)
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_train_step_decreases_loss_on_fixed_batch():
    params = init_params(CFG, 0)
    m = zeros_like_params(CFG)
    v = zeros_like_params(CFG)
    tokens, _ = batch_for(CFG, 2)
    # learnable rule: target = (token + 1) % vocab
    targets = ((tokens.reshape(-1) + 1) % CFG.vocab).astype(jnp.int32)
    first = None
    step_fn = jax.jit(lambda p, m, v, t: train_step(CFG, p, m, v, tokens, targets, t))
    last = None
    for t in range(1, 21):
        params, m, v, loss = step_fn(params, m, v, jnp.float32(t))
        first = first or float(loss)
        last = float(loss)
    assert last < first * 0.7, f"{first} -> {last}"


def test_training_is_deterministic():
    tokens, targets = batch_for(CFG, 3)

    def run():
        params = init_params(CFG, 7)
        m = zeros_like_params(CFG)
        v = zeros_like_params(CFG)
        for t in range(1, 4):
            params, m, v, loss = train_step(CFG, params, m, v, tokens, targets, jnp.float32(t))
        return params, loss

    p1, l1 = run()
    p2, l2 = run()
    assert float(l1) == float(l2)
    for k in p1:
        np.testing.assert_array_equal(
            np.asarray(p1[k]).view(np.uint32), np.asarray(p2[k]).view(np.uint32)
        )


def test_flat_wrappers_roundtrip():
    params = init_params(CFG, 0)
    names = flat_names(CFG)
    m = zeros_like_params(CFG)
    v = zeros_like_params(CFG)
    tokens, targets = batch_for(CFG, 4)

    flat_logits = forward_flat(CFG, *[params[k] for k in names], tokens)[0]
    np.testing.assert_array_equal(flat_logits, forward(CFG, params, tokens))

    flat_out = train_step_flat(
        CFG,
        *[params[k] for k in names],
        *[m[k] for k in names],
        *[v[k] for k in names],
        tokens,
        targets,
        jnp.float32(1.0),
    )
    n = len(names)
    assert len(flat_out) == 3 * n + 1
    ref_p, ref_m, ref_v, ref_loss = train_step(CFG, params, m, v, tokens, targets, jnp.float32(1.0))
    np.testing.assert_array_equal(flat_out[-1], ref_loss)
    for i, k in enumerate(names):
        np.testing.assert_array_equal(flat_out[i], ref_p[k])
        np.testing.assert_array_equal(flat_out[n + i], ref_m[k])
        np.testing.assert_array_equal(flat_out[2 * n + i], ref_v[k])


def test_param_shapes_sorted_and_complete():
    shapes = param_shapes(CFG)
    names = list(shapes.keys())
    assert names == sorted(names)
    assert "embed.w" in shapes and "lm_head.w" in shapes
    n_params = sum(int(np.prod(s)) for s in shapes.values())
    assert n_params > 2 * CFG.vocab * CFG.d_model  # embed + head + blocks
