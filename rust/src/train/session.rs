//! The per-job execution driver shared by trainers and (during disputes)
//! the referee's bookkeeping: builds the model + extended graph from a
//! [`JobSpec`], derives deterministic batches, and advances the state
//! machine one step at a time.

use std::collections::BTreeMap;

use crate::graph::autodiff::TrainStep;
use crate::graph::executor::{execute, execute_traced, ExecOpts, State, StepTrace, TamperFn};
use crate::graph::kernels::Backend;
use crate::hash::Hash;
use crate::tensor::Tensor;

use super::data::DataGen;
use super::JobSpec;

/// A fully-instantiated training program: everybody (client, trainers,
/// referee) constructs an identical `Session` from the same [`JobSpec`].
pub struct Session {
    pub spec: JobSpec,
    pub program: TrainStep,
    pub genesis: State,
    pub data: DataGen,
    /// Commitment to the whole job (graph structure + genesis + metadata).
    pub job_hash: Hash,
}

impl Session {
    pub fn new(spec: JobSpec) -> Session {
        let model = spec.preset.build(spec.batch, spec.seq);
        let program = model.train_step(&spec.optimizer);
        let mut genesis = model.init_state(spec.weight_seed, &spec.optimizer);
        genesis.step = 0;
        let data = DataGen::new(spec.preset, spec.batch, spec.seq, spec.data_seed);
        let job_hash = spec.commit(
            &program.graph.structure_hash(),
            &genesis.genesis_commitment().root(),
        );
        Session { spec, program, genesis, data, job_hash }
    }

    /// Deterministic batch for 1-based `step`.
    pub fn batch(&self, step: u64) -> BTreeMap<String, Tensor> {
        self.data.batch(step)
    }

    /// Advance one step WITHOUT tracing (the fast honest path).
    /// Returns the next state and the step loss.
    pub fn advance(&self, state: &State, backend: Backend) -> (State, f32) {
        let step = state.step + 1;
        let batch = self.batch(step);
        let e = execute(&self.program.graph, state, &batch, backend, step, &ExecOpts::default());
        let loss = e.values[self.program.loss.node][0].data()[0];
        (self.apply(state, step, &e.values), loss)
    }

    /// Advance one step WITH AugmentedCGNode tracing (checkpoint steps and
    /// dispute re-execution). `tamper` injects faults (dishonest trainers).
    pub fn advance_traced(
        &self,
        state: &State,
        backend: Backend,
        keep_values: bool,
        tamper: Option<TamperFn>,
    ) -> (State, f32, StepTrace) {
        let step = state.step + 1;
        let batch = self.batch(step);
        let (e, trace) =
            execute_traced(&self.program.graph, state, &batch, backend, step, keep_values, tamper);
        let loss = e.values[self.program.loss.node][0].data()[0];
        (self.apply(state, step, &e.values), loss, trace)
    }

    /// Build the next state from executed values: updated params/opt-state
    /// replace old entries; frozen params carry over.
    fn apply(&self, state: &State, step: u64, values: &[Vec<Tensor>]) -> State {
        let mut next = state.clone();
        next.step = step;
        for (name, slot) in &self.program.param_updates {
            next.params.insert(name.clone(), values[slot.node][slot.out_idx].clone());
        }
        for (name, slot) in &self.program.opt_updates {
            next.opt.insert(name.clone(), values[slot.node][slot.out_idx].clone());
        }
        next
    }

    /// The checkpoint hash at a state+trace boundary: genesis root for step
    /// 0, otherwise the Merkle root of the producing step's node hashes.
    pub fn genesis_root(&self) -> Hash {
        self.genesis.genesis_commitment().root()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Preset;

    #[test]
    fn two_sessions_agree_bitwise() {
        let spec = JobSpec::quick(Preset::Mlp, 6);
        let s1 = Session::new(spec);
        let s2 = Session::new(spec);
        assert_eq!(s1.job_hash, s2.job_hash);
        let mut a = s1.genesis.clone();
        let mut b = s2.genesis.clone();
        for _ in 0..6 {
            let (na, la) = s1.advance(&a, Backend::Rep);
            let (nb, lb) = s2.advance(&b, Backend::Rep);
            assert_eq!(la.to_bits(), lb.to_bits());
            a = na;
            b = nb;
        }
        for (k, t) in &a.params {
            assert!(t.bit_eq(&b.params[k]), "{k}");
        }
    }

    #[test]
    fn traced_and_untraced_states_match() {
        let spec = JobSpec::quick(Preset::LlamaTiny, 3);
        let s = Session::new(spec);
        let (plain, l1) = s.advance(&s.genesis, Backend::Rep);
        let (traced, l2, trace) = s.advance_traced(&s.genesis, Backend::Rep, false, None);
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(plain.step, traced.step);
        for (k, t) in &plain.params {
            assert!(t.bit_eq(&traced.params[k]), "{k}");
        }
        assert_eq!(trace.step, 1);
        assert!(trace.nodes.len() > 50, "extended graph has many nodes");
    }

    #[test]
    fn loss_decreases_over_llama_tiny_run() {
        let spec = JobSpec::quick(Preset::LlamaTiny, 30);
        let s = Session::new(spec);
        let mut st = s.genesis.clone();
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..30 {
            let (n, l) = s.advance(&st, Backend::Rep);
            first.get_or_insert(l);
            last = l;
            st = n;
        }
        assert!(last < first.unwrap(), "{:?} -> {last}", first.unwrap());
    }

    #[test]
    fn trace_root_is_stable_across_reexecution() {
        let spec = JobSpec::quick(Preset::Mlp, 4);
        let s = Session::new(spec);
        // run to step 2, then re-execute step 3 twice
        let mut st = s.genesis.clone();
        for _ in 0..2 {
            st = s.advance(&st, Backend::Rep).0;
        }
        let (_, _, t1) = s.advance_traced(&st, Backend::Rep, false, None);
        let (_, _, t2) = s.advance_traced(&st, Backend::Rep, true, None);
        assert_eq!(t1.root(), t2.root());
        assert!(t2.values.is_some());
    }
}
