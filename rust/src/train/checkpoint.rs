//! Multi-level checkpoint schedules and their cost model (paper §2.1).
//!
//! With `N` checkpoints per level, trainers store/log `N` evenly-spaced
//! checkpoints over `[0, n]`; each Phase 1 round narrows the dispute to one
//! interval and re-executes it with `N` finer checkpoints, until interval
//! length 1. Re-execution totals a `1/N + 1/N² + …` fraction of training —
//! the paper's "under 6% at N=20, under 1.1% at N=100".

/// The boundaries at which a segment `(start, end]` is checkpointed when
/// split `n_intervals` ways: strictly increasing step numbers ending at
/// `end`. Every party derives the identical schedule.
pub fn split_points(start: u64, end: u64, n_intervals: u64) -> Vec<u64> {
    assert!(end > start, "empty segment ({start}, {end}]");
    let len = end - start;
    let k = n_intervals.min(len).max(1);
    // even split: boundary i at start + ceil(len·i/k), deduplicated by
    // construction since len ≥ k
    (1..=k).map(|i| start + (len * i).div_ceil(k)).collect()
}

/// Steps at which a trainer logs checkpoints during the *initial* training
/// run (level-0 schedule plus the final step).
pub fn level0_schedule(steps: u64, n: u64) -> Vec<u64> {
    split_points(0, steps, n)
}

/// Number of levels Phase 1 needs to reach interval length 1.
pub fn levels_needed(steps: u64, n: u64) -> u32 {
    let mut len = steps;
    let mut levels = 0;
    while len > 1 {
        len = len.div_ceil(n.max(2));
        levels += 1;
    }
    levels.max(1)
}

/// Upper bound on the fraction of training re-executed during Phase 1
/// (geometric series `Σ_{ℓ≥1} N^{-ℓ}`; the paper's §2.1 cost analysis).
pub fn reexec_fraction_bound(n: u64) -> f64 {
    let n = n as f64;
    1.0 / (n - 1.0)
}

/// Storage cost model: bytes a trainer holds for level-0 checkpoints of a
/// state of `state_bytes` bytes.
pub fn storage_bytes(n: u64, state_bytes: u64) -> u64 {
    n * state_bytes
}

/// The paper's §2.1 worked examples, used by the `phase1_costs` bench to
/// print the paper-vs-ours table: (model, params, fp32 state bytes with
/// Adam m+v = 3×params×4).
pub const PAPER_MODELS: [(&str, u64); 3] = [
    ("DistilBERT-66M", 66_000_000),
    ("Llama-1B", 1_240_000_000),
    ("Llama-8B", 8_030_000_000),
];

/// FP32 bytes of weights + Adam state for a parameter count.
pub fn adam_state_bytes(params: u64) -> u64 {
    3 * params * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall, Gen};

    #[test]
    fn split_points_even_and_terminal() {
        assert_eq!(split_points(0, 100, 4), vec![25, 50, 75, 100]);
        assert_eq!(split_points(0, 10, 3), vec![4, 7, 10]);
        assert_eq!(split_points(5, 8, 10), vec![6, 7, 8]); // clamps to len
        assert_eq!(split_points(0, 1, 5), vec![1]);
    }

    #[test]
    fn prop_split_points_invariants() {
        forall("split points strictly increase and end at end", 64, |g: &mut Gen| {
            let start = g.usize_in(0, 1000) as u64;
            let len = g.usize_in(1, 500) as u64;
            let n = g.usize_in(1, 64) as u64;
            let pts = split_points(start, start + len, n);
            assert_eq!(*pts.last().unwrap(), start + len);
            assert!(pts[0] > start);
            for w in pts.windows(2) {
                assert!(w[0] < w[1], "{pts:?}");
            }
            assert!(pts.len() as u64 <= n.min(len));
        });
    }

    #[test]
    fn levels_match_log() {
        assert_eq!(levels_needed(1, 20), 1);
        assert_eq!(levels_needed(20, 20), 1);
        assert_eq!(levels_needed(400, 20), 2);
        assert_eq!(levels_needed(401, 20), 3);
        assert_eq!(levels_needed(8000, 20), 3);
    }

    #[test]
    fn paper_cost_numbers() {
        // "When N=20, this comes to under 6%."
        assert!(reexec_fraction_bound(20) < 0.06);
        // "With N=100, the amount of re-execution reduces to under 1.1%"
        assert!(reexec_fraction_bound(100) < 0.011);
        // "a few hundred gigabytes of storage" for Llama-8B weights at N=20:
        // the paper counts just the learnable parameters here (8B × 4B = 32GB,
        // ×20 = 640GB ≈ "a few hundred GB").
        let w = 8_030_000_000u64 * 4;
        let s20 = storage_bytes(20, w);
        assert!(s20 > 100 << 30 && s20 < 1000 << 30, "{s20}");
        // "With N=100 … storage requirements reaches a few terabytes."
        let s100 = storage_bytes(100, w);
        assert!(s100 > (1u64) << 40 && s100 < (10u64) << 40, "{s100}");
    }
}
