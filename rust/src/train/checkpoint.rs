//! Multi-level checkpoint schedules and their cost model (paper §2.1),
//! plus the **canonical checkpoint-state serialization** used by segment
//! state-transfer.
//!
//! With `N` checkpoints per level, trainers store/log `N` evenly-spaced
//! checkpoints over `[0, n]`; each Phase 1 round narrows the dispute to one
//! interval and re-executes it with `N` finer checkpoints, until interval
//! length 1. Re-execution totals a `1/N + 1/N² + …` fraction of training —
//! the paper's "under 6% at N=20, under 1.1% at N=100".
//!
//! [`encode_state`]/[`decode_state`] turn a training [`State`] into one
//! canonical byte string (`decode(encode(s)) == s` bit-exactly and
//! `encode(decode(b)) == b` for every accepted `b`), so the Merkle root
//! over the decoded state's leaves ([`State::state_root`]) is well-defined
//! for any accepted upload. The bytes cross the wire in
//! [`CHECKPOINT_CHUNK`](crate::verde::wire::CHECKPOINT_CHUNK)-sized chunks
//! ([`chunk_count`]/[`chunk_slice`]) carried by the
//! `FetchCheckpoint`/`Checkpoint`/`SeedCheckpoint` protocol messages.

use std::collections::BTreeMap;

use crate::graph::executor::State;
use crate::tensor::Tensor;
use crate::verde::wire::{self, Reader, WireError, CHECKPOINT_CHUNK};

/// The boundaries at which a segment `(start, end]` is checkpointed when
/// split `n_intervals` ways: strictly increasing step numbers ending at
/// `end`. Every party derives the identical schedule.
pub fn split_points(start: u64, end: u64, n_intervals: u64) -> Vec<u64> {
    assert!(end > start, "empty segment ({start}, {end}]");
    let len = end - start;
    let k = n_intervals.min(len).max(1);
    // even split: boundary i at start + ceil(len·i/k), deduplicated by
    // construction since len ≥ k
    (1..=k).map(|i| start + (len * i).div_ceil(k)).collect()
}

/// Steps at which a trainer logs checkpoints during the *initial* training
/// run (level-0 schedule plus the final step).
pub fn level0_schedule(steps: u64, n: u64) -> Vec<u64> {
    split_points(0, steps, n)
}

/// Number of levels Phase 1 needs to reach interval length 1.
pub fn levels_needed(steps: u64, n: u64) -> u32 {
    let mut len = steps;
    let mut levels = 0;
    while len > 1 {
        len = len.div_ceil(n.max(2));
        levels += 1;
    }
    levels.max(1)
}

/// Upper bound on the fraction of training re-executed during Phase 1
/// (geometric series `Σ_{ℓ≥1} N^{-ℓ}`; the paper's §2.1 cost analysis).
pub fn reexec_fraction_bound(n: u64) -> f64 {
    let n = n as f64;
    1.0 / (n - 1.0)
}

/// Storage cost model: bytes a trainer holds for level-0 checkpoints of a
/// state of `state_bytes` bytes.
pub fn storage_bytes(n: u64, state_bytes: u64) -> u64 {
    n * state_bytes
}

/// The paper's §2.1 worked examples, used by the `phase1_costs` bench to
/// print the paper-vs-ours table: (model, params, fp32 state bytes with
/// Adam m+v = 3×params×4).
pub const PAPER_MODELS: [(&str, u64); 3] = [
    ("DistilBERT-66M", 66_000_000),
    ("Llama-1B", 1_240_000_000),
    ("Llama-8B", 8_030_000_000),
];

/// FP32 bytes of weights + Adam state for a parameter count.
pub fn adam_state_bytes(params: u64) -> u64 {
    3 * params * 4
}

// ---------------------------------------------------------------------------
// canonical checkpoint-state serialization (segment state-transfer)
// ---------------------------------------------------------------------------

fn put_tensor_map(out: &mut Vec<u8>, map: &BTreeMap<String, Tensor>) {
    wire::put_u64(out, map.len() as u64);
    for (name, t) in map {
        wire::put_str(out, name);
        wire::put_tensor(out, t);
    }
}

fn read_tensor_map(
    r: &mut Reader<'_>,
    context: &'static str,
) -> Result<BTreeMap<String, Tensor>, WireError> {
    let n = r.usize(context)?;
    // Cheapest possible entry: 8-byte name length + 8-byte tensor rank.
    if n > r.remaining() / 16 {
        return Err(WireError::Truncated {
            context,
            need: n.saturating_mul(16),
            have: r.remaining(),
        });
    }
    let mut map = BTreeMap::new();
    let mut prev: Option<String> = None;
    for _ in 0..n {
        let name = r.str(context)?;
        // Canonicity: the encoder walks a BTreeMap, so names arrive in
        // strictly ascending order; anything else is a non-canonical (or
        // duplicate-key) encoding and is refused.
        if prev.as_deref().is_some_and(|p| p >= name.as_str()) {
            return Err(WireError::Malformed { context });
        }
        let t = wire::read_tensor(r)?;
        prev = Some(name.clone());
        map.insert(name, t);
    }
    Ok(map)
}

/// Canonical serialization of a checkpoint [`State`]: step, then the
/// params and optimizer-state maps (name-ascending, each tensor as
/// shape-prefixed little-endian FP32 bits).
pub fn encode_state(state: &State) -> Vec<u8> {
    let mut out = Vec::with_capacity(state_wire_len(state));
    wire::put_u64(&mut out, state.step);
    put_tensor_map(&mut out, &state.params);
    put_tensor_map(&mut out, &state.opt);
    debug_assert_eq!(out.len(), state_wire_len(state), "state_wire_len drifted");
    out
}

/// Exact encoded length of [`encode_state`]'s output.
pub fn state_wire_len(state: &State) -> usize {
    let map_len = |m: &BTreeMap<String, Tensor>| {
        8 + m
            .iter()
            .map(|(name, t)| 8 + name.len() + wire::tensor_wire_len(t))
            .sum::<usize>()
    };
    8 + map_len(&state.params) + map_len(&state.opt)
}

/// Decode a serialized checkpoint state. Total on hostile bytes: rejects
/// truncation, absurd counts/shapes, non-canonical map order, and
/// trailing bytes.
pub fn decode_state(bytes: &[u8]) -> Result<State, WireError> {
    let mut r = Reader::new(bytes);
    let step = r.u64("state.step")?;
    let params = read_tensor_map(&mut r, "state.params")?;
    let opt = read_tensor_map(&mut r, "state.opt")?;
    r.finish()?;
    Ok(State { step, params, opt })
}

/// Whether `bytes` is a canonical checkpoint-state encoding for exactly
/// `step` whose Merkle state root is `root` — the acceptance test every
/// verifier applies to a fetched checkpoint upload before trusting it
/// (state-transfer seeding, audit replays). Total on hostile bytes: a
/// malformed encoding is simply `false`, never a panic.
pub fn verify_encoded_state(bytes: &[u8], step: u64, root: &crate::hash::Hash) -> bool {
    match decode_state(bytes) {
        Ok(st) => st.step == step && st.state_root() == *root,
        Err(_) => false,
    }
}

/// Number of wire chunks a serialized state of `len` bytes needs (≥ 1).
pub fn chunk_count(len: usize) -> u64 {
    (len.div_ceil(CHECKPOINT_CHUNK)).max(1) as u64
}

/// The byte slice carried by chunk `chunk` of `bytes`.
///
/// # Panics
/// If `chunk` is out of range for `bytes` (`chunk >= chunk_count(len)`).
pub fn chunk_slice(bytes: &[u8], chunk: u64) -> &[u8] {
    let start = (chunk as usize) * CHECKPOINT_CHUNK;
    assert!(start < bytes.len().max(1), "chunk {chunk} out of range");
    &bytes[start..bytes.len().min(start + CHECKPOINT_CHUNK)]
}

/// The hash of every wire chunk of `bytes`, in chunk order — the body of a
/// `Response::Manifest`. A streaming receiver checks each arriving chunk
/// payload against its entry (`Hash::of_bytes(payload) == chunks[i]`)
/// instead of buffering the whole state, and [`manifest_root`] over this
/// list is the content address the checkpoint cache keys on.
pub fn chunk_hashes(bytes: &[u8]) -> Vec<crate::hash::Hash> {
    (0..chunk_count(bytes.len()))
        .map(|c| crate::hash::Hash::of_bytes(chunk_slice(bytes, c)))
        .collect()
}

/// Merkle root over a manifest's chunk-hash list: one digest binding the
/// exact chunk sequence, used when comparing manifests across replicas.
pub fn manifest_root(chunks: &[crate::hash::Hash]) -> crate::hash::Hash {
    crate::hash::merkle::merkle_root(chunks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall, Gen};

    fn sample_state(seed: u64) -> State {
        let mut st = State::default();
        st.step = seed;
        st.params.insert("layer.w".into(), Tensor::rand([3, 4], seed, 1.0));
        st.params.insert("layer.b".into(), Tensor::rand([4], seed ^ 1, 0.5));
        st.opt.insert("layer.w.m".into(), Tensor::rand([3, 4], seed ^ 2, 0.1));
        st.opt.insert("layer.w.v".into(), Tensor::rand([3, 4], seed ^ 3, 0.1));
        st
    }

    #[test]
    fn state_roundtrips_bit_exactly_and_size_exactly() {
        let st = sample_state(7);
        let bytes = encode_state(&st);
        assert_eq!(bytes.len(), state_wire_len(&st));
        let back = decode_state(&bytes).expect("decodes");
        assert_eq!(back.step, st.step);
        assert_eq!(back.params.len(), 2);
        for (k, t) in &st.params {
            assert!(back.params[k].bit_eq(t), "{k}");
        }
        for (k, t) in &st.opt {
            assert!(back.opt[k].bit_eq(t), "{k}");
        }
        // canonical: re-encoding reproduces the bytes, and the state root
        // survives the trip
        assert_eq!(encode_state(&back), bytes);
        assert_eq!(back.state_root(), st.state_root());
    }

    #[test]
    fn state_decode_is_total_on_hostile_bytes() {
        let bytes = encode_state(&sample_state(3));
        for cut in 0..bytes.len() {
            assert!(decode_state(&bytes[..cut]).is_err(), "prefix {cut} accepted");
        }
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(matches!(decode_state(&padded), Err(WireError::Trailing { extra: 1 })));
        // absurd map count must not allocate
        let mut evil = Vec::new();
        wire::put_u64(&mut evil, 0); // step
        wire::put_u64(&mut evil, u64::MAX); // param count
        assert!(matches!(decode_state(&evil), Err(WireError::Truncated { .. })));
        // single-byte corruption either errors or stays canonical
        for pos in 0..bytes.len() {
            let mut corrupt = bytes.clone();
            corrupt[pos] ^= 0x10;
            if let Ok(st) = decode_state(&corrupt) {
                assert_eq!(encode_state(&st), corrupt, "non-canonical state accepted");
            }
        }
    }

    #[test]
    fn state_decode_rejects_unsorted_names() {
        // Hand-build an encoding with params out of order: same entries a
        // canonical encoder would sort.
        let a = Tensor::rand([2], 1, 1.0);
        let b = Tensor::rand([2], 2, 1.0);
        let mut evil = Vec::new();
        wire::put_u64(&mut evil, 1); // step
        wire::put_u64(&mut evil, 2); // 2 params, wrong order
        wire::put_str(&mut evil, "zz");
        wire::put_tensor(&mut evil, &a);
        wire::put_str(&mut evil, "aa");
        wire::put_tensor(&mut evil, &b);
        wire::put_u64(&mut evil, 0); // no opt state
        assert!(matches!(
            decode_state(&evil),
            Err(WireError::Malformed { context: "state.params" })
        ));
    }

    #[test]
    fn verify_encoded_state_binds_step_and_root() {
        let st = sample_state(11);
        let bytes = encode_state(&st);
        let root = st.state_root();
        assert!(verify_encoded_state(&bytes, st.step, &root));
        assert!(!verify_encoded_state(&bytes, st.step + 1, &root), "wrong step accepted");
        let other = sample_state(12).state_root();
        assert!(!verify_encoded_state(&bytes, st.step, &other), "wrong root accepted");
        assert!(!verify_encoded_state(&bytes[..bytes.len() - 1], st.step, &root));
        assert!(!verify_encoded_state(&[], st.step, &root));
    }

    #[test]
    fn chunking_covers_the_bytes_exactly() {
        assert_eq!(chunk_count(0), 1);
        assert_eq!(chunk_count(1), 1);
        assert_eq!(chunk_count(CHECKPOINT_CHUNK), 1);
        assert_eq!(chunk_count(CHECKPOINT_CHUNK + 1), 2);
        let bytes: Vec<u8> = (0..(CHECKPOINT_CHUNK + 123)).map(|i| i as u8).collect();
        let total = chunk_count(bytes.len());
        assert_eq!(total, 2);
        let mut back = Vec::new();
        for c in 0..total {
            back.extend_from_slice(chunk_slice(&bytes, c));
        }
        assert_eq!(back, bytes, "chunks reassemble to the original bytes");
        assert_eq!(chunk_slice(&bytes, 1).len(), 123);
    }

    #[test]
    fn chunk_hashes_match_slices_and_bind_content() {
        let bytes: Vec<u8> = (0..(2 * CHECKPOINT_CHUNK + 17)).map(|i| (i * 7) as u8).collect();
        let hashes = chunk_hashes(&bytes);
        assert_eq!(hashes.len() as u64, chunk_count(bytes.len()));
        for (c, h) in hashes.iter().enumerate() {
            assert_eq!(*h, crate::hash::Hash::of_bytes(chunk_slice(&bytes, c as u64)), "{c}");
        }
        // Any single-byte change lands in exactly one chunk hash and moves
        // the manifest root.
        let root = manifest_root(&hashes);
        let mut tampered = bytes.clone();
        tampered[CHECKPOINT_CHUNK + 5] ^= 0x40;
        let tampered_hashes = chunk_hashes(&tampered);
        assert_eq!(hashes[0], tampered_hashes[0]);
        assert_ne!(hashes[1], tampered_hashes[1]);
        assert_eq!(hashes[2], tampered_hashes[2]);
        assert_ne!(root, manifest_root(&tampered_hashes));
        // Degenerate input still describes one (empty-payload) chunk.
        assert_eq!(chunk_hashes(&[]).len(), 1);
    }

    #[test]
    fn split_points_even_and_terminal() {
        assert_eq!(split_points(0, 100, 4), vec![25, 50, 75, 100]);
        assert_eq!(split_points(0, 10, 3), vec![4, 7, 10]);
        assert_eq!(split_points(5, 8, 10), vec![6, 7, 8]); // clamps to len
        assert_eq!(split_points(0, 1, 5), vec![1]);
    }

    #[test]
    fn prop_split_points_invariants() {
        forall("split points strictly increase and end at end", 64, |g: &mut Gen| {
            let start = g.usize_in(0, 1000) as u64;
            let len = g.usize_in(1, 500) as u64;
            let n = g.usize_in(1, 64) as u64;
            let pts = split_points(start, start + len, n);
            assert_eq!(*pts.last().unwrap(), start + len);
            assert!(pts[0] > start);
            for w in pts.windows(2) {
                assert!(w[0] < w[1], "{pts:?}");
            }
            assert!(pts.len() as u64 <= n.min(len));
        });
    }

    #[test]
    fn levels_match_log() {
        assert_eq!(levels_needed(1, 20), 1);
        assert_eq!(levels_needed(20, 20), 1);
        assert_eq!(levels_needed(400, 20), 2);
        assert_eq!(levels_needed(401, 20), 3);
        assert_eq!(levels_needed(8000, 20), 3);
    }

    #[test]
    fn paper_cost_numbers() {
        // "When N=20, this comes to under 6%."
        assert!(reexec_fraction_bound(20) < 0.06);
        // "With N=100, the amount of re-execution reduces to under 1.1%"
        assert!(reexec_fraction_bound(100) < 0.011);
        // "a few hundred gigabytes of storage" for Llama-8B weights at N=20:
        // the paper counts just the learnable parameters here (8B × 4B = 32GB,
        // ×20 = 640GB ≈ "a few hundred GB").
        let w = 8_030_000_000u64 * 4;
        let s20 = storage_bytes(20, w);
        assert!(s20 > 100 << 30 && s20 < 1000 << 30, "{s20}");
        // "With N=100 … storage requirements reaches a few terabytes."
        let s100 = storage_bytes(100, w);
        assert!(s100 > (1u64) << 40 && s100 < (10u64) << 40, "{s100}");
    }
}
