//! Training-program setup and execution: the "program setup" of paper §2
//! (client specifies model, initial weights, training data, optimizer,
//! batch size), the synthetic corpus ([`data`]), the multi-level checkpoint
//! schedule ([`checkpoint`]), and the step-by-step session driver
//! ([`session`]).

pub mod checkpoint;
pub mod data;
pub mod session;

use crate::graph::autodiff::Optimizer;
use crate::hash::{Hash, Hasher};
use crate::model::Preset;

/// Everything the client fixes up front. All parties (trainers, referee)
/// derive identical programs, initial states, and data streams from this —
/// the paper's "program setup" plus training metadata.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobSpec {
    pub preset: Preset,
    pub batch: usize,
    pub seq: usize,
    /// Total number of training steps `n`.
    pub steps: u64,
    pub optimizer: Optimizer,
    /// Seed for the initial parameters.
    pub weight_seed: u64,
    /// Seed for the synthetic data stream.
    pub data_seed: u64,
    /// Phase 1 checkpoint count per level (`N` in §2.1).
    pub checkpoint_n: u64,
}

impl JobSpec {
    pub fn quick(preset: Preset, steps: u64) -> JobSpec {
        JobSpec {
            preset,
            batch: 2,
            seq: 8,
            steps,
            optimizer: Optimizer::adam(1e-2),
            weight_seed: 0xA11CE,
            data_seed: 0xDA7A,
            checkpoint_n: 4,
        }
    }

    /// The job restricted to its first `steps` steps — the
    /// checkpoint-segment prefix the service layer delegates when a job is
    /// sharded. Segment ends come from the Phase-1
    /// [`checkpoint::split_points`] schedule, so every party derives the
    /// identical sub-job, and a prefix job's final commitment **is** the
    /// full job's checkpoint commitment at that boundary (training is
    /// deterministic from the spec).
    pub fn prefix(&self, steps: u64) -> JobSpec {
        debug_assert!(steps >= 1 && steps <= self.steps, "prefix {steps} of {}", self.steps);
        JobSpec { steps, ..*self }
    }

    /// Commitment to the job itself (model structure + seeds + metadata);
    /// disputes are scoped to a job hash.
    pub fn commit(&self, graph_structure: &Hash, genesis_root: &Hash) -> Hash {
        let mut h = Hasher::new("verde.job.v1");
        h.str(self.preset.name());
        h.u64(self.batch as u64);
        h.u64(self.seq as u64);
        h.u64(self.steps);
        h.u64(self.weight_seed);
        h.u64(self.data_seed);
        h.u64(self.checkpoint_n);
        h.hash(graph_structure);
        h.hash(genesis_root);
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::Hash;

    #[test]
    fn prefix_changes_only_steps() {
        let a = JobSpec::quick(Preset::Mlp, 16);
        let p = a.prefix(4);
        assert_eq!(p.steps, 4);
        assert_eq!(p.preset, a.preset);
        assert_eq!(p.data_seed, a.data_seed);
        assert_eq!(p.weight_seed, a.weight_seed);
        assert_eq!(p.checkpoint_n, a.checkpoint_n);
        assert_eq!(a.prefix(a.steps), a, "full-length prefix is the job itself");
    }

    #[test]
    fn job_commit_binds_fields() {
        let a = JobSpec::quick(Preset::Mlp, 16);
        let mut b = a;
        b.data_seed += 1;
        let g = Hash::of_bytes(b"g");
        let s = Hash::of_bytes(b"s");
        assert_ne!(a.commit(&g, &s), b.commit(&g, &s));
        assert_eq!(a.commit(&g, &s), a.commit(&g, &s));
    }
}
