//! Deterministic synthetic training data (DESIGN.md §4 substitution 4).
//!
//! The corpus is a Markov byte stream: a fixed random successor map over the
//! vocabulary, followed with probability ~0.8 — enough structure for the
//! LM loss to fall visibly within a few hundred steps, with entropy left
//! over so it never collapses. Every batch is a pure function of
//! `(data_seed, step)`, which is what lets the client commit to the whole
//! dataset up front and lets any party re-derive any batch.

use std::collections::BTreeMap;

use crate::hash::{hash_tensor, merkle::MerkleTree, Hash, Hasher};
use crate::model::Preset;
use crate::tensor::Tensor;
use crate::util::prng::{derive_seed, SplitMix64};

/// What kind of batch a model preset consumes.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Kind {
    /// `tokens [b, s]` + `targets [b*s]` (next-token LM).
    TokenLm { vocab: usize },
    /// `x [b, d]` + `targets [b]` (classification).
    Features { d_in: usize, classes: usize },
}

/// Deterministic per-step batch generator.
#[derive(Debug, Clone)]
pub struct DataGen {
    seed: u64,
    batch: usize,
    seq: usize,
    kind: Kind,
    /// Markov successor table (TokenLm only).
    successor: Vec<usize>,
}

impl DataGen {
    pub fn new(preset: Preset, batch: usize, seq: usize, seed: u64) -> DataGen {
        let kind = match preset {
            Preset::Mlp => Kind::Features { d_in: 16, classes: 8 },
            Preset::LlamaTiny | Preset::LlamaTinyLora | Preset::BertTiny => {
                Kind::TokenLm { vocab: 64 }
            }
            Preset::LlamaSmall | Preset::LlamaBase | Preset::BertSmall => {
                Kind::TokenLm { vocab: 256 }
            }
        };
        let successor = match kind {
            Kind::TokenLm { vocab } => {
                let mut rng = SplitMix64::new(derive_seed(seed, "successor", 0));
                (0..vocab).map(|_| rng.next_bounded(vocab as u64) as usize).collect()
            }
            Kind::Features { .. } => Vec::new(),
        };
        DataGen { seed, batch, seq, kind, successor }
    }

    /// The batch for 1-based training step `step`.
    pub fn batch(&self, step: u64) -> BTreeMap<String, Tensor> {
        let mut rng = SplitMix64::new(derive_seed(self.seed, "batch", step));
        let mut out = BTreeMap::new();
        match self.kind {
            Kind::TokenLm { vocab } => {
                let mut toks = Vec::with_capacity(self.batch * self.seq);
                let mut tgts = Vec::with_capacity(self.batch * self.seq);
                for _ in 0..self.batch {
                    let mut cur = rng.next_bounded(vocab as u64) as usize;
                    for _ in 0..self.seq {
                        toks.push(cur as f32);
                        // next token: Markov successor 80% of the time
                        let next = if rng.next_f32() < 0.8 {
                            self.successor[cur]
                        } else {
                            rng.next_bounded(vocab as u64) as usize
                        };
                        tgts.push(next as f32);
                        cur = next;
                    }
                }
                out.insert("tokens".into(), Tensor::new([self.batch, self.seq], toks));
                out.insert("targets".into(), Tensor::new([self.batch * self.seq], tgts));
            }
            Kind::Features { d_in, classes } => {
                let x = Tensor::rand([self.batch, d_in], derive_seed(self.seed, "x", step), 1.0);
                let t: Vec<f32> = (0..self.batch)
                    .map(|r| {
                        let row = &x.data()[r * d_in..r * d_in + classes];
                        row.iter()
                            .enumerate()
                            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                            .unwrap()
                            .0 as f32
                    })
                    .collect();
                out.insert("x".into(), x);
                out.insert("targets".into(), Tensor::new([self.batch], t));
            }
        }
        out
    }

    /// Leaf commitment to step `step`'s batch (name → tensor hash, ordered).
    pub fn batch_leaf(&self, step: u64) -> Hash {
        let batch = self.batch(step);
        let mut h = Hasher::new("verde.data-leaf.v1");
        h.u64(step);
        h.u64(batch.len() as u64);
        for (name, t) in &batch {
            h.str(name);
            let th = hash_tensor(t);
            h.hash(&th);
        }
        h.finish()
    }

    /// Merkle commitment to the entire `steps`-long dataset (the client's
    /// up-front data commitment).
    pub fn commitment(&self, steps: u64) -> MerkleTree {
        let leaves: Vec<Hash> = (1..=steps).map(|s| self.batch_leaf(s)).collect();
        MerkleTree::build(&leaves)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_are_step_deterministic() {
        let g = DataGen::new(Preset::LlamaTiny, 2, 8, 42);
        let a = g.batch(5);
        let b = g.batch(5);
        let c = g.batch(6);
        assert!(a["tokens"].bit_eq(&b["tokens"]));
        assert!(a["targets"].bit_eq(&b["targets"]));
        assert!(!a["tokens"].bit_eq(&c["tokens"]));
    }

    #[test]
    fn tokens_in_vocab_range() {
        let g = DataGen::new(Preset::LlamaTiny, 4, 16, 1);
        for step in 1..=10 {
            let b = g.batch(step);
            for &t in b["tokens"].data().iter().chain(b["targets"].data()) {
                assert!(t >= 0.0 && t < 64.0 && t.fract() == 0.0);
            }
        }
    }

    #[test]
    fn markov_structure_is_learnable() {
        // ≥60% of targets should follow the successor map (0.8 nominal)
        let g = DataGen::new(Preset::LlamaTiny, 8, 32, 3);
        let mut follow = 0;
        let mut total = 0;
        for step in 1..=20 {
            let b = g.batch(step);
            for (tok, tgt) in b["tokens"].data().iter().zip(b["targets"].data()) {
                total += 1;
                if g.successor[*tok as usize] == *tgt as usize {
                    follow += 1;
                }
            }
        }
        let frac = follow as f64 / total as f64;
        assert!(frac > 0.6, "successor-follow fraction {frac}");
    }

    #[test]
    fn mlp_batches_have_valid_labels() {
        let g = DataGen::new(Preset::Mlp, 8, 0, 2);
        let b = g.batch(1);
        assert_eq!(b["x"].shape(), &[8, 16]);
        for &t in b["targets"].data() {
            assert!(t >= 0.0 && t < 8.0);
        }
    }

    #[test]
    fn commitment_and_leaves_verify() {
        let g = DataGen::new(Preset::LlamaTiny, 2, 4, 9);
        let tree = g.commitment(8);
        assert_eq!(tree.leaf_count(), 8);
        for step in 1..=8u64 {
            let proof = tree.prove((step - 1) as usize);
            assert!(MerkleTree::verify(&tree.root(), &g.batch_leaf(step), &proof));
        }
        // a forged leaf fails
        let forged = g.batch_leaf(99);
        let proof = tree.prove(0);
        assert!(!MerkleTree::verify(&tree.root(), &forged, &proof));
    }
}
