//! The trainer party: runs the delegated training job (honestly or with an
//! injected [`Fault`]), logs multi-level checkpoints, and answers the
//! referee's dispute requests.
//!
//! A dishonest trainer here is a *consistent* cheater: whatever wrong
//! computation it committed to during training, it reproduces faithfully
//! during dispute re-execution. That is the strongest adversary the
//! protocol's hash comparisons must pin down.

use std::collections::{BTreeMap, HashMap};

use crate::graph::executor::{execute, execute_traced_swap, ExecOpts, State, StepTrace};
use crate::graph::kernels::Backend;
use crate::graph::{Graph, InitKind, NodeId, Op, Slot};
use crate::hash::Hash;
use crate::net::Endpoint;
use crate::tensor::Tensor;
use crate::train::checkpoint::{
    chunk_count, chunk_hashes, chunk_slice, encode_state, level0_schedule,
};
use crate::train::session::Session;
use crate::train::JobSpec;
use crate::util::metrics::Counters;

use super::faults::{mutate_op, Fault};
use super::protocol::{InputProvenance, Request, Response};

/// A trainer node (honest or faulty).
pub struct TrainerNode {
    pub name: String,
    pub session: Session,
    pub backend: Backend,
    pub fault: Fault,
    /// Checkpoint states stored during training + dispute (step → state
    /// AFTER that step; step 0 = genesis).
    stored: BTreeMap<u64, State>,
    /// Checkpoint roots (step → Merkle root of that step's trace; step 0 =
    /// genesis commitment root).
    roots: BTreeMap<u64, Hash>,
    /// Cached traces (hashes only) for steps we had to record.
    traces: HashMap<u64, StepTrace>,
    /// Full node-output values for the one step currently under dispute.
    value_cache: Option<(u64, Vec<Vec<Tensor>>)>,
    /// Lazily-built mutated graph for `WrongOperator`.
    wrong_graph: Option<Graph>,
    /// Boundary this trainer was seeded at (0 = trained from genesis). A
    /// seeded trainer holds no trajectory below this step and refuses
    /// dispute queries that would need one.
    seed_base: u64,
    /// Cached canonical serialization of one checkpoint state
    /// (`(step, state root, bytes)`), so chunked uploads of the same
    /// boundary don't re-encode per chunk.
    encoded_ckpt: Option<(u64, Hash, Vec<u8>)>,
    pub counters: Counters,
    /// Per-step training losses (logging/examples).
    pub losses: Vec<f32>,
}

impl TrainerNode {
    pub fn new(name: &str, spec: JobSpec, backend: Backend, fault: Fault) -> TrainerNode {
        Self::with_session(name, Session::new(spec), backend, fault)
    }

    /// Build from an already-constructed session (callers that needed the
    /// session to pick fault targets avoid a second graph/state build).
    pub fn with_session(name: &str, session: Session, backend: Backend, fault: Fault) -> TrainerNode {
        TrainerNode {
            name: name.to_string(),
            session,
            backend,
            fault,
            stored: BTreeMap::new(),
            roots: BTreeMap::new(),
            traces: HashMap::new(),
            value_cache: None,
            wrong_graph: None,
            seed_base: 0,
            encoded_ckpt: None,
            counters: Counters::new(),
            losses: Vec::new(),
        }
    }

    pub fn honest(name: &str, spec: JobSpec) -> TrainerNode {
        Self::new(name, spec, Backend::Rep, Fault::None)
    }

    /// Build a trainer seeded with a verified checkpoint state: `train()`
    /// starts from `seed` (its `step` must sit strictly inside the job)
    /// instead of the genesis state, so the job costs only
    /// `spec.steps − seed.step` training steps. `seed_root` is the state's
    /// Merkle root (already verified by the caller); it stands in as the
    /// checkpoint commitment at the seed boundary.
    ///
    /// # Panics
    /// If `seed.step` is outside `1..session.spec.steps`.
    pub fn with_seed(
        name: &str,
        session: Session,
        backend: Backend,
        fault: Fault,
        seed: State,
        seed_root: Hash,
    ) -> TrainerNode {
        assert!(
            seed.step >= 1 && seed.step < session.spec.steps,
            "seed step {} outside job of {} steps",
            seed.step,
            session.spec.steps
        );
        let mut t = Self::with_session(name, session, backend, fault);
        t.seed_base = seed.step;
        t.roots.insert(seed.step, seed_root);
        t.stored.insert(seed.step, seed);
        t
    }

    /// The boundary this trainer was seeded at (0 when trained from
    /// genesis).
    pub fn seed_base(&self) -> u64 {
        self.seed_base
    }

    // -----------------------------------------------------------------
    // training
    // -----------------------------------------------------------------

    /// Run the job — from genesis, or from the seeded checkpoint for a
    /// trainer built with [`TrainerNode::with_seed`] — logging level-0
    /// checkpoints, and return the final commitment the trainer reports to
    /// the client. A seeded trainer executes exactly
    /// `spec.steps − seed_base` steps.
    pub fn train(&mut self) -> Hash {
        let spec = self.session.spec;
        let schedule = level0_schedule(spec.steps, spec.checkpoint_n);
        if self.seed_base == 0 {
            self.stored.insert(0, self.session.genesis.clone());
            self.roots.insert(0, self.session.genesis_root());
        }

        // Process-wide totals; the per-trainer `counters` stay authoritative
        // for tests, these feed the live stats plane.
        let g = crate::obs::global();
        let g_steps = g.counter("trainer_steps");
        g.counter("trainer_runs").inc();

        let mut state = self.stored[&self.seed_base].clone();
        for step in self.seed_base + 1..=spec.steps {
            let record = schedule.contains(&step);
            let (next, loss) = self.exec_step(&state, record, false);
            self.losses.push(loss);
            self.counters.incr("steps_trained");
            g_steps.inc();
            if record {
                self.stored.insert(step, next.clone());
                self.counters.add("checkpoint_bytes_stored", next.byte_len() as u64);
            }
            state = next;
        }
        self.final_commit()
    }

    /// The trainer's claimed final commitment.
    pub fn final_commit(&mut self) -> Hash {
        self.root_at(self.effective_step(self.session.spec.steps))
    }

    pub fn final_state(&mut self) -> State {
        self.state_at(self.session.spec.steps)
    }

    // -----------------------------------------------------------------
    // faulty execution machinery
    // -----------------------------------------------------------------

    /// For `SkipSteps`, every step past the cutoff is answered with the
    /// stale step's artifacts.
    fn effective_step(&self, step: u64) -> u64 {
        match self.fault {
            Fault::SkipSteps { after } => step.min(after),
            _ => step,
        }
    }

    /// Graph used at `step` (the `WrongOperator` cheater runs — and commits
    /// to — a mutated program at its target step).
    fn graph_for(&mut self, step: u64) -> Graph {
        if let Fault::WrongOperator { step: s, node } = self.fault {
            if s == step {
                if self.wrong_graph.is_none() {
                    let mut g = self.session.program.graph.clone();
                    let op = mutate_op(&g.nodes[node].op).unwrap_or_else(|| {
                        panic!(
                            "WrongOperator target node {node} ({}) has no impostor",
                            g.nodes[node].op.mnemonic()
                        )
                    });
                    g.nodes[node].op = op;
                    self.wrong_graph = Some(g);
                }
                return self.wrong_graph.clone().unwrap();
            }
        }
        self.session.program.graph.clone()
    }

    /// Batch used at `step` (`WrongData` swaps in a far-future batch).
    fn batch_for(&self, step: u64) -> BTreeMap<String, Tensor> {
        match self.fault {
            Fault::WrongData { step: s } if s == step => self.session.batch(step + 7777),
            _ => self.session.batch(step),
        }
    }

    /// Execute the step after `state` under this trainer's fault model.
    /// Returns (next state, loss) and caches the trace/values as requested.
    fn exec_step(&mut self, state: &State, record: bool, keep_values: bool) -> (State, f32) {
        let step = state.step + 1;
        let graph = self.graph_for(step);
        let batch = self.batch_for(step);
        let fault = self.fault;
        // InconsistentCommit diverges the state at its target step (so a
        // dispute happens at all); the Phase 2 inconsistency is injected
        // when answering NodeHashSeq.
        let first_update_node =
            self.session.program.param_updates.values().map(|s| s.node).min().unwrap_or(0);
        let tamper = move |id: NodeId, ins: &[&Tensor], outs: &mut Vec<Tensor>| match fault {
            Fault::TamperOutput { step: s, node, delta } if s == step && id == node => {
                outs[0].data_mut()[0] += delta;
            }
            Fault::InconsistentCommit { step: s } if s == step && id == first_update_node => {
                outs[0].data_mut()[0] += 1e-2;
            }
            Fault::SkipOptimizer { step: s } if s == step => {
                // pass (w, m, v) through untouched on every update node
                if outs.len() == 3 && ins.len() == 4 {
                    outs[0] = ins[0].clone();
                    outs[1] = ins[2].clone();
                    outs[2] = ins[3].clone();
                }
            }
            _ => {}
        };
        // ForgedLineage: compute one node from an input its upstream never
        // produced — and commit to the hash of that forged input.
        let swap = move |id: NodeId, input_idx: usize, t: &Tensor| -> Option<Tensor> {
            match fault {
                Fault::ForgedLineage { step: s, node } if s == step && id == node && input_idx == 0 => {
                    let mut forged = t.clone();
                    forged.data_mut()[0] += 1.0;
                    Some(forged)
                }
                _ => None,
            }
        };
        let needs_tamper = fault.affects_step(step)
            && matches!(
                fault,
                Fault::TamperOutput { .. } | Fault::InconsistentCommit { .. } | Fault::SkipOptimizer { .. }
            );
        let needs_swap = matches!(fault, Fault::ForgedLineage { step: s, .. } if s == step);

        if !record && !keep_values {
            // fast honest-path execution: no per-node hashing
            let opts = ExecOpts {
                record_trace: false,
                keep_values: false,
                tamper: if needs_tamper { Some(&tamper) } else { None },
                input_swap: if needs_swap { Some(&swap) } else { None },
            };
            let exec = execute(&graph, state, &batch, self.backend, step, &opts);
            let loss = exec.values[self.session.program.loss.node][0].data()[0];
            let next = self.apply(state, step, &exec.values);
            return (next, loss);
        }

        let (exec, mut trace) = execute_traced_swap(
            &graph,
            state,
            &batch,
            self.backend,
            step,
            keep_values,
            if needs_tamper { Some(&tamper) } else { None },
            if needs_swap { Some(&swap) } else { None },
        );
        self.counters.incr("traces_recorded");
        self.counters.add("hash_bytes", (trace.nodes.len() * 32) as u64);
        self.roots.insert(step, trace.root());
        if keep_values {
            self.value_cache = Some((step, exec.values.clone()));
        }
        trace.values = None;
        self.traces.insert(step, trace);
        let loss = exec.values[self.session.program.loss.node][0].data()[0];
        let next = self.apply(state, step, &exec.values);
        (next, loss)
    }

    fn apply(&self, state: &State, step: u64, values: &[Vec<Tensor>]) -> State {
        let mut next = state.clone();
        next.step = step;
        for (name, slot) in &self.session.program.param_updates {
            next.params.insert(name.clone(), values[slot.node][slot.out_idx].clone());
        }
        for (name, slot) in &self.session.program.opt_updates {
            next.opt.insert(name.clone(), values[slot.node][slot.out_idx].clone());
        }
        next
    }

    // -----------------------------------------------------------------
    // dispute-side materialization
    // -----------------------------------------------------------------

    /// State after `step` (re-executing from the nearest stored checkpoint;
    /// re-executed steps are counted — they are the §2.1 cost).
    fn state_at(&mut self, step: u64) -> State {
        let step = self.effective_step(step);
        if let Some(s) = self.stored.get(&step) {
            return s.clone();
        }
        let (&from, base) = self
            .stored
            .range(..=step)
            .next_back()
            .expect("genesis always stored");
        let mut state = base.clone();
        for _ in from..step {
            let (next, _) = self.exec_step(&state, false, false);
            self.counters.incr("steps_reexecuted");
            state = next;
        }
        self.stored.insert(step, state.clone());
        state
    }

    /// Checkpoint root at `step` (0 = genesis).
    fn root_at(&mut self, step: u64) -> Hash {
        let step = self.effective_step(step);
        if let Some(r) = self.roots.get(&step) {
            return *r;
        }
        let prev = self.state_at(step - 1);
        let (next, _) = self.exec_step(&prev, true, false);
        self.counters.incr("steps_reexecuted");
        self.stored.insert(step, next);
        self.roots[&step]
    }

    /// Trace of `step` (recording it if missing).
    fn trace_at(&mut self, step: u64) -> StepTrace {
        let step = self.effective_step(step);
        if !self.traces.contains_key(&step) {
            let prev = self.state_at(step - 1);
            let (next, _) = self.exec_step(&prev, true, false);
            self.counters.incr("steps_reexecuted");
            self.stored.insert(step, next);
        }
        self.traces[&step].clone()
    }

    /// Node output values of `step` (re-executing with retained values).
    fn values_at(&mut self, step: u64) -> Vec<Vec<Tensor>> {
        let step = self.effective_step(step);
        if let Some((s, v)) = &self.value_cache {
            if *s == step {
                return v.clone();
            }
        }
        let prev = self.state_at(step - 1);
        let (_, _) = self.exec_step(&prev, true, true);
        self.counters.incr("steps_reexecuted");
        self.value_cache.as_ref().expect("just cached").1.clone()
    }

    /// Build the Case 2(a) provenance proof for a state tensor feeding the
    /// `Init` node `node_idx` of `step`.
    fn input_proof(&mut self, step: u64, node_idx: usize) -> Option<InputProvenance> {
        let graph = &self.session.program.graph;
        let (kind, name) = match &graph.nodes.get(node_idx)?.op {
            Op::Init { kind, name } => (kind.clone(), name.clone()),
            _ => return None,
        };
        if step <= 1 {
            // value comes from the genesis commitment
            let state = self.state_at(0);
            let idx = state.leaf_index(&kind, &name)?;
            let leaves = state.leaf_hashes();
            let tree = state.genesis_commitment();
            return Some(InputProvenance::Genesis { leaf: leaves[idx], proof: tree.prove(idx) });
        }
        // value was emitted by a node of the previous step: the update node
        // if the tensor is trainable, otherwise its own Init node
        // (carried-over frozen value).
        let slot: Slot = match kind {
            InitKind::Param => self
                .session
                .program
                .param_updates
                .get(&name)
                .copied()
                .unwrap_or(Slot::new(node_idx, 0)),
            InitKind::OptState => self
                .session
                .program
                .opt_updates
                .get(&name)
                .copied()
                .unwrap_or(Slot::new(node_idx, 0)),
            InitKind::Data => return None,
        };
        let prev_trace = self.trace_at(step - 1);
        let node = prev_trace.nodes[slot.node].clone();
        let proof = prev_trace.commit().prove(slot.node);
        Some(InputProvenance::PrevStep { node, out_idx: slot.out_idx, proof })
    }

    /// Serve one chunk of the canonical serialization of the checkpoint
    /// state after `step` — the upload half of segment state-transfer. The
    /// encoding is cached per boundary so a multi-chunk upload encodes
    /// once.
    fn checkpoint_chunk(&mut self, step: u64, chunk: u64) -> Response {
        if step < 1 || step < self.seed_base || step > self.session.spec.steps {
            return Response::Refuse(format!("{}: no checkpoint at step {step}", self.name));
        }
        if self.encoded_ckpt.as_ref().map(|(s, _, _)| *s) != Some(step) {
            let state = self.state_at(step);
            let root = state.state_root();
            let bytes = encode_state(&state);
            self.encoded_ckpt = Some((step, root, bytes));
        }
        let (root, total, payload) = {
            let (_, root, bytes) = self.encoded_ckpt.as_ref().expect("just cached");
            let total = chunk_count(bytes.len());
            if chunk >= total {
                return Response::Refuse(format!(
                    "{}: checkpoint at {step} has {total} chunks, no chunk {chunk}",
                    self.name
                ));
            }
            (*root, total, chunk_slice(bytes, chunk).to_vec())
        };
        self.counters.add("checkpoint_bytes_served", payload.len() as u64);
        Response::Checkpoint { step, root, total_chunks: total, chunk, payload }
    }

    /// Serve the shape of the checkpoint after `step` for streaming
    /// state-transfer: state root, encoded length, and the hash of every
    /// chunk in order. Shares the per-boundary encoding cache with chunk
    /// serving, so a manifest followed by its chunk fetches encodes the
    /// state exactly once.
    fn checkpoint_manifest(&mut self, step: u64) -> Response {
        if step < 1 || step < self.seed_base || step > self.session.spec.steps {
            return Response::Refuse(format!("{}: no checkpoint at step {step}", self.name));
        }
        if self.encoded_ckpt.as_ref().map(|(s, _, _)| *s) != Some(step) {
            let state = self.state_at(step);
            let root = state.state_root();
            let bytes = encode_state(&state);
            self.encoded_ckpt = Some((step, root, bytes));
        }
        let (_, root, bytes) = self.encoded_ckpt.as_ref().expect("just cached");
        Response::Manifest {
            step,
            root: *root,
            total_len: bytes.len() as u64,
            chunks: chunk_hashes(bytes),
        }
    }
}

impl Endpoint for TrainerNode {
    fn name(&self) -> &str {
        &self.name
    }

    fn call(&mut self, req: Request) -> Response {
        match req {
            Request::FinalCommit => Response::Commit(self.final_commit()),
            Request::CheckpointHashes { boundaries } => {
                // A seeded trainer holds no trajectory below its seed
                // boundary: it cannot (and must not pretend to) derive
                // those checkpoints.
                if self.seed_base > 0 && boundaries.iter().any(|&b| b < self.seed_base) {
                    return Response::Refuse(format!(
                        "{}: seeded at step {}, no earlier checkpoints",
                        self.name, self.seed_base
                    ));
                }
                let hashes = boundaries.iter().map(|&b| self.root_at(b)).collect();
                Response::Hashes(hashes)
            }
            Request::NodeHashSeq { step }
            | Request::OpenNode { step, .. }
            | Request::InputTensor { step, .. }
                if self.seed_base > 0 && step <= self.seed_base =>
            {
                Response::Refuse(format!(
                    "{}: seeded at step {}, no trace for step {step}",
                    self.name, self.seed_base
                ))
            }
            Request::InputProof { step, .. } if self.seed_base > 0 && step <= self.seed_base + 1 => {
                // Provenance for step seed_base+1 would need the seed
                // step's trace, which a seeded trainer never executed.
                Response::Refuse(format!(
                    "{}: seeded at step {}, no provenance for step {step}",
                    self.name, self.seed_base
                ))
            }
            Request::NodeHashSeq { step } => {
                let mut seq = self.trace_at(step).node_hashes;
                if let Fault::InconsistentCommit { step: s } = self.fault {
                    if s == step {
                        // lie in Phase 2: corrupt the last entry so the
                        // sequence no longer matches the Phase 1 root
                        if let Some(last) = seq.last_mut() {
                            last.0[0] ^= 0xAA;
                        }
                    }
                }
                Response::NodeSeq(seq)
            }
            Request::OpenNode { step, idx } => {
                let trace = self.trace_at(step);
                match trace.nodes.get(idx) {
                    Some(n) => Response::Node(n.clone()),
                    None => Response::Refuse(format!("no node {idx} at step {step}")),
                }
            }
            Request::InputProof { step, node_idx } => match self.input_proof(step, node_idx) {
                Some(p) => Response::Proof(p),
                None => Response::Refuse(format!("no provenance for node {node_idx}")),
            },
            Request::InputTensor { step, node_idx, input_idx } => {
                let graph = self.graph_for(step);
                let Some(node) = graph.nodes.get(node_idx) else {
                    return Response::Refuse(format!("no node {node_idx}"));
                };
                let Some(slot) = node.inputs.get(input_idx).copied() else {
                    return Response::Refuse(format!("no input {input_idx}"));
                };
                let values = self.values_at(step);
                Response::TensorPayload(values[slot.node][slot.out_idx].clone())
            }
            Request::Train { .. } | Request::SeedCheckpoint { .. } => {
                // A TrainerNode is bound to one job at construction; job
                // delegation and checkpoint seeding are handled by
                // `service::worker::WorkerHost`.
                Response::Refuse("trainer is bound to a single job".into())
            }
            Request::FetchCheckpoint { step, chunk } => self.checkpoint_chunk(step, chunk),
            Request::FetchManifest { step } => self.checkpoint_manifest(step),
            Request::CommitRoot { step } => {
                // Same range guard as checkpoint serving: hostile or stale
                // steps refuse instead of panicking, and a seeded trainer
                // holds no state below its seed boundary to commit to.
                if step < 1 || step < self.seed_base || step > self.session.spec.steps {
                    Response::Refuse(format!("{}: no checkpoint at step {step}", self.name))
                } else {
                    // The committed root is the state root the checkpoint
                    // upload serves, so an audit can bind the commitment
                    // to the bytes the worker actually ships.
                    Response::Commit(self.state_at(step).state_root())
                }
            }
            Request::Submit { .. } | Request::Status { .. } | Request::Cancel { .. } => {
                // Client-API messages address a coordinator frontend
                // (`service::client::DelegationFrontend`), never a trainer.
                Response::Refuse("trainer does not host the client API".into())
            }
            Request::Stats => {
                // Stats are served by hosts that own a registry (worker
                // host, coordinator frontend); a bare trainer has none.
                Response::Refuse("trainer serves no stats registry".into())
            }
            Request::Ping => Response::Pong,
            Request::Shutdown => Response::Bye,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Preset;

    fn spec() -> JobSpec {
        JobSpec::quick(Preset::Mlp, 8)
    }

    #[test]
    fn honest_trainers_agree() {
        let mut a = TrainerNode::honest("a", spec());
        let mut b = TrainerNode::honest("b", spec());
        assert_eq!(a.train(), b.train());
        assert_eq!(a.losses.len(), 8);
    }

    #[test]
    fn every_fault_changes_the_final_commit() {
        let honest = TrainerNode::honest("h", spec()).train();
        // forged-lineage target: first MatMul (a node with real inputs)
        let s = Session::new(spec());
        let mm = s
            .program
            .graph
            .nodes
            .iter()
            .position(|n| matches!(n.op, crate::graph::Op::MatMul))
            .unwrap();
        let faults = [
            Fault::TamperOutput { step: 3, node: 4, delta: 0.5 },
            Fault::WrongData { step: 2 },
            Fault::SkipOptimizer { step: 5 },
            Fault::SkipSteps { after: 4 },
            Fault::ForgedLineage { step: 3, node: mm },
            Fault::InconsistentCommit { step: 6 },
        ];
        for f in faults {
            let mut t = TrainerNode::new("f", spec(), Backend::Rep, f);
            assert_ne!(t.train(), honest, "{f:?} must diverge");
        }
        // WrongOperator on a mutable node
        let s = Session::new(spec());
        let node = super::super::faults::first_mutable_node(&s.program.graph).unwrap();
        let mut t = TrainerNode::new(
            "wo",
            spec(),
            Backend::Rep,
            Fault::WrongOperator { step: 2, node },
        );
        assert_ne!(t.train(), honest);
    }

    #[test]
    fn free_backend_diverges_from_rep() {
        use crate::tensor::profile::HardwareProfile;
        let honest = TrainerNode::honest("h", spec()).train();
        let mut t = TrainerNode::new(
            "hw",
            spec(),
            Backend::Free(HardwareProfile::T4_16G),
            Fault::NonRepHardware,
        );
        assert_ne!(t.train(), honest, "free-order kernels must diverge bitwise");
    }

    #[test]
    fn checkpoint_roots_are_reproducible_after_training() {
        let mut t = TrainerNode::honest("t", spec());
        let final1 = t.train();
        // roots can be re-derived for arbitrary steps (dispute path)
        let r3a = t.root_at(3);
        let r3b = t.root_at(3);
        assert_eq!(r3a, r3b);
        assert_eq!(t.final_commit(), final1);
        // reexecution happened only for uncached steps
        assert!(t.counters.get("steps_reexecuted") > 0);
    }

    #[test]
    fn skip_steps_replays_stale_roots() {
        let mut t = TrainerNode::new("lazy", spec(), Backend::Rep, Fault::SkipSteps { after: 3 });
        t.train();
        assert_eq!(t.root_at(3), t.root_at(5));
        assert_eq!(t.root_at(3), t.root_at(8));
        let mut h = TrainerNode::honest("h", spec());
        h.train();
        assert_eq!(h.root_at(3), t.root_at(3), "honest prefix agrees");
        assert_ne!(h.root_at(4), t.root_at(4));
    }

    #[test]
    fn endpoint_answers_protocol_requests() {
        let mut t = TrainerNode::honest("t", spec());
        t.train();
        match t.call(Request::FinalCommit) {
            Response::Commit(_) => {}
            other => panic!("{other:?}"),
        }
        match t.call(Request::CheckpointHashes { boundaries: vec![2, 4, 6, 8] }) {
            Response::Hashes(h) => assert_eq!(h.len(), 4),
            other => panic!("{other:?}"),
        }
        let seq = match t.call(Request::NodeHashSeq { step: 5 }) {
            Response::NodeSeq(s) => s,
            other => panic!("{other:?}"),
        };
        assert_eq!(seq.len(), t.session.program.graph.len());
        match t.call(Request::OpenNode { step: 5, idx: 3 }) {
            Response::Node(n) => {
                assert_eq!(n.id, 3);
                assert_eq!(n.commit(), seq[3]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn seeded_trainer_matches_full_training_with_delta_steps_only() {
        let spec = JobSpec::quick(Preset::Mlp, 8);
        let mut full = TrainerNode::honest("full", spec);
        let commit = full.train();
        let seed = full.state_at(5);
        let seed_root = seed.state_root();

        let mut seeded = TrainerNode::with_seed(
            "seeded",
            Session::new(spec),
            Backend::Rep,
            Fault::None,
            seed,
            seed_root,
        );
        assert_eq!(seeded.seed_base(), 5);
        let seeded_commit = seeded.train();
        assert_eq!(seeded_commit, commit, "seeded run reaches the identical commitment");
        assert_eq!(seeded.counters.get("steps_trained"), 3, "only the delta is trained");
        assert_eq!(seeded.losses.len(), 3);
        // later checkpoints are reachable, earlier ones are refused
        assert_eq!(seeded.root_at(7), full.root_at(7));
        match seeded.call(Request::CheckpointHashes { boundaries: vec![2, 8] }) {
            Response::Refuse(_) => {}
            other => panic!("{other:?}"),
        }
        match seeded.call(Request::NodeHashSeq { step: 4 }) {
            Response::Refuse(_) => {}
            other => panic!("{other:?}"),
        }
        match seeded.call(Request::InputProof { step: 6, node_idx: 0 }) {
            Response::Refuse(_) => {}
            other => panic!("{other:?}"),
        }
        // boundaries at/after the seed answer normally
        match seeded.call(Request::CheckpointHashes { boundaries: vec![6, 8] }) {
            Response::Hashes(h) => {
                assert_eq!(h, vec![full.root_at(6), full.root_at(8)]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn checkpoint_chunks_reassemble_and_verify() {
        use crate::train::checkpoint::decode_state;
        let spec = JobSpec::quick(Preset::Mlp, 6);
        let mut t = TrainerNode::honest("t", spec);
        t.train();
        let mut bytes = Vec::new();
        let mut chunk = 0u64;
        let mut root = Hash::ZERO;
        let mut total = 1u64;
        loop {
            match t.call(Request::FetchCheckpoint { step: 4, chunk }) {
                Response::Checkpoint { step, root: r, total_chunks, chunk: c, payload } => {
                    assert_eq!(step, 4);
                    assert_eq!(c, chunk);
                    bytes.extend_from_slice(&payload);
                    root = r;
                    total = total_chunks;
                }
                other => panic!("{other:?}"),
            }
            chunk += 1;
            if chunk >= total {
                break;
            }
        }
        let state = decode_state(&bytes).expect("upload decodes");
        assert_eq!(state.step, 4);
        assert_eq!(state.state_root(), root, "upload matches its committed root");
        assert!(state.params.keys().eq(t.session.genesis.params.keys()));
        // out-of-range requests are refused, not panics
        assert!(matches!(
            t.call(Request::FetchCheckpoint { step: 99, chunk: 0 }),
            Response::Refuse(_)
        ));
        assert!(matches!(
            t.call(Request::FetchCheckpoint { step: 4, chunk: 999 }),
            Response::Refuse(_)
        ));
    }

    #[test]
    fn input_tensor_matches_trace_hash() {
        let mut t = TrainerNode::honest("t", spec());
        t.train();
        let trace = t.trace_at(4);
        // find a node with at least one input
        let idx = t
            .session
            .program
            .graph
            .nodes
            .iter()
            .position(|n| !n.inputs.is_empty())
            .unwrap();
        match t.call(Request::InputTensor { step: 4, node_idx: idx, input_idx: 0 }) {
            Response::TensorPayload(tensor) => {
                assert_eq!(
                    crate::hash::hash_tensor(&tensor),
                    trace.nodes[idx].input_hashes[0]
                );
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn genesis_input_proof_verifies() {
        use crate::hash::merkle::MerkleTree;
        let mut t = TrainerNode::honest("t", spec());
        t.train();
        // find a Param init node
        let pid = t
            .session
            .program
            .graph
            .init_nodes(&InitKind::Param)
            .first()
            .unwrap()
            .0;
        match t.call(Request::InputProof { step: 1, node_idx: pid }) {
            Response::Proof(InputProvenance::Genesis { leaf, proof }) => {
                assert!(MerkleTree::verify(&t.session.genesis_root(), &leaf, &proof));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn prev_step_input_proof_verifies() {
        use crate::hash::merkle::MerkleTree;
        let mut t = TrainerNode::honest("t", spec());
        t.train();
        let pid = t
            .session
            .program
            .graph
            .init_nodes(&InitKind::Param)
            .first()
            .unwrap()
            .0;
        let prev_root = t.root_at(3);
        match t.call(Request::InputProof { step: 4, node_idx: pid }) {
            Response::Proof(InputProvenance::PrevStep { node, out_idx, proof }) => {
                assert!(MerkleTree::verify(&prev_root, &node.commit(), &proof));
                // the emitted output hash is the param value entering step 4
                let trace4 = t.trace_at(4);
                assert_eq!(node.output_hashes[out_idx], trace4.nodes[pid].output_hashes[0]);
            }
            other => panic!("{other:?}"),
        }
    }
}
