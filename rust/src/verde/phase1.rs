//! Phase 1 (paper Algorithm 1): identify the first diverging training step
//! by multi-level checkpoint-hash comparison.
//!
//! Per the paper's footnote 2, within a level the referee receives all N
//! checkpoint hashes in one round and scans linearly (N is small enough
//! that this beats binary search in round trips); *levels* provide the
//! logarithmic narrowing.

use crate::hash::Hash;
use crate::net::Endpoint;
use crate::train::checkpoint::split_points;

use super::protocol::{Request, Response};

/// Outcome of Phase 1.
#[derive(Debug, Clone)]
pub struct Phase1Result {
    /// The first training step the trainers diverged at (1-based).
    pub diverging_step: u64,
    /// The agreed checkpoint hash entering that step (`h_start`).
    pub h_start: Hash,
    /// The two disputed ending hashes (`h_end[i]` from trainer `i`).
    pub h_end: [Hash; 2],
    /// Interaction rounds used (levels walked).
    pub rounds: u32,
}

/// Errors that end the dispute during Phase 1 (before any decision).
#[derive(Debug, Clone, PartialEq)]
pub enum Phase1Error {
    /// Final commitments match — nothing to resolve.
    NoDispute,
    /// Trainer `i` refused or answered malformed — treated as dishonest.
    Misbehaved { trainer: usize, why: String },
    /// A trainer's reported hash for the final boundary contradicts its own
    /// final commitment (consistency check).
    CommitMismatch { trainer: usize },
}

/// Run Phase 1 between the referee and two trainer endpoints.
///
/// `genesis_root` is `C_0` (the referee derives it from the job spec);
/// `steps` is the total step count `n`; `n_per_level` is the checkpoint
/// count `N`.
pub fn run_phase1(
    trainers: &mut [&mut dyn Endpoint; 2],
    genesis_root: Hash,
    steps: u64,
    n_per_level: u64,
) -> Result<Phase1Result, Phase1Error> {
    // Algorithm 1 lines 4–7: final commitments.
    let mut finals = [Hash::ZERO; 2];
    for (i, t) in trainers.iter_mut().enumerate() {
        finals[i] = match t.call(Request::FinalCommit) {
            Response::Commit(h) => h,
            other => {
                return Err(Phase1Error::Misbehaved {
                    trainer: i,
                    why: format!("bad FinalCommit response: {other:?}"),
                })
            }
        };
    }
    if finals[0] == finals[1] {
        return Err(Phase1Error::NoDispute);
    }

    // interval (s0, s1] known to contain the first divergence
    let mut s0 = 0u64;
    let mut s1 = steps;
    let mut h_start = genesis_root;
    let mut h_end = finals;
    let mut rounds = 0u32;

    while s1 - s0 > 1 {
        rounds += 1;
        let boundaries = split_points(s0, s1, n_per_level);
        let mut reported: [Vec<Hash>; 2] = [Vec::new(), Vec::new()];
        for (i, t) in trainers.iter_mut().enumerate() {
            reported[i] = match t.call(Request::CheckpointHashes {
                boundaries: boundaries.clone(),
            }) {
                Response::Hashes(h) if h.len() == boundaries.len() => h,
                other => {
                    return Err(Phase1Error::Misbehaved {
                        trainer: i,
                        why: format!("bad CheckpointHashes response: {other:?}"),
                    })
                }
            };
        }
        // consistency: last boundary == s1, whose hashes must equal the
        // h_end each trainer already committed to
        for i in 0..2 {
            if *reported[i].last().unwrap() != h_end[i] {
                return Err(Phase1Error::CommitMismatch { trainer: i });
            }
        }
        // find the first diverging boundary (must exist: the last one does)
        let d = boundaries
            .iter()
            .zip(reported[0].iter().zip(reported[1].iter()))
            .position(|(_, (a, b))| a != b)
            .expect("h_end differs, so some boundary differs");
        // narrow: previous boundary (or s0) agrees
        if d > 0 {
            s0 = boundaries[d - 1];
            h_start = reported[0][d - 1]; // == reported[1][d-1]
        }
        s1 = boundaries[d];
        h_end = [reported[0][d], reported[1][d]];
    }

    Ok(Phase1Result { diverging_step: s1, h_start, h_end, rounds })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::kernels::Backend;
    use crate::model::Preset;
    use crate::net::Metered;
    use crate::train::JobSpec;
    use crate::verde::faults::Fault;
    use crate::verde::trainer::TrainerNode;

    fn run(fault: Fault, steps: u64, n: u64) -> Result<Phase1Result, Phase1Error> {
        let mut spec = JobSpec::quick(Preset::Mlp, steps);
        spec.checkpoint_n = n;
        let mut honest = TrainerNode::honest("honest", spec);
        let mut cheat = TrainerNode::new("cheat", spec, Backend::Rep, fault);
        honest.train();
        cheat.train();
        let genesis = honest.session.genesis_root();
        let mut a = Metered::new(honest);
        let mut b = Metered::new(cheat);
        run_phase1(&mut [&mut a, &mut b], genesis, steps, n)
    }

    #[test]
    fn no_dispute_when_honest() {
        let r = run(Fault::None, 8, 4);
        assert_eq!(r.unwrap_err(), Phase1Error::NoDispute);
    }

    #[test]
    fn finds_exact_diverging_step() {
        for target in [1u64, 5, 13, 16] {
            let r = run(Fault::TamperOutput { step: target, node: 4, delta: 0.25 }, 16, 4)
                .unwrap();
            assert_eq!(r.diverging_step, target, "fault at step {target}");
            assert_ne!(r.h_end[0], r.h_end[1]);
        }
    }

    #[test]
    fn finds_step_with_large_n_and_deep_levels() {
        let r = run(Fault::WrongData { step: 11 }, 27, 3).unwrap();
        assert_eq!(r.diverging_step, 11);
        assert!(r.rounds >= 2, "27 steps at N=3 needs ≥3 levels, got {}", r.rounds);
    }

    #[test]
    fn skip_steps_diverges_right_after_cutoff() {
        let r = run(Fault::SkipSteps { after: 9 }, 16, 4).unwrap();
        assert_eq!(r.diverging_step, 10);
    }

    #[test]
    fn communication_is_hashes_only() {
        let mut spec = JobSpec::quick(Preset::Mlp, 32);
        spec.checkpoint_n = 4;
        let mut honest = TrainerNode::honest("honest", spec);
        let mut cheat = TrainerNode::new(
            "cheat",
            spec,
            Backend::Rep,
            Fault::TamperOutput { step: 17, node: 4, delta: 0.5 },
        );
        honest.train();
        cheat.train();
        let genesis = honest.session.genesis_root();
        let mut a = Metered::new(honest);
        let mut b = Metered::new(cheat);
        let r = run_phase1(&mut [&mut a, &mut b], genesis, 32, 4).unwrap();
        assert_eq!(r.diverging_step, 17);
        // Phase 1 total traffic should be a few KiB of hashes, nowhere near
        // the model-state megabytes.
        let total = a.bytes_received() + a.bytes_sent() + b.bytes_received() + b.bytes_sent();
        assert!(total < 10_000, "phase 1 moved {total} bytes");
    }
}
