//! Phase 2 (paper Algorithm 2): inside the disputed step, identify the
//! first diverging node of the extended computational graph and obtain both
//! trainers' openings of it.

use crate::graph::executor::AugmentedCGNode;
use crate::hash::merkle::merkle_root;
use crate::net::Endpoint;

use super::phase1::Phase1Result;
use super::protocol::{Request, Response};
use super::referee::Verdict;

/// Outcome of Phase 2: the diverging node's index and both openings,
/// ready for the referee's decision algorithm.
#[derive(Debug, Clone)]
pub struct Phase2Result {
    pub step: u64,
    pub node_idx: usize,
    pub openings: [AugmentedCGNode; 2],
    /// Both committed node-hash sequences (consulted by the decision
    /// algorithm when verifying source-node openings in Case 2b).
    pub seqs: [Vec<crate::hash::Hash>; 2],
}

/// Run Phase 2. Returns either the diverging-node openings or an early
/// verdict (a trainer whose Phase 2 messages are inconsistent with its
/// Phase 1 commitments is convicted without any decision algorithm).
pub fn run_phase2(
    trainers: &mut [&mut dyn Endpoint; 2],
    p1: &Phase1Result,
    graph_len: usize,
) -> Result<Phase2Result, Verdict> {
    let step = p1.diverging_step;

    // lines 3–5: node-hash sequences
    let mut seqs: [Vec<crate::hash::Hash>; 2] = [Vec::new(), Vec::new()];
    for (i, t) in trainers.iter_mut().enumerate() {
        seqs[i] = match t.call(Request::NodeHashSeq { step }) {
            Response::NodeSeq(s) => s,
            other => {
                return Err(Verdict::misbehaved(i, format!("bad NodeHashSeq: {other:?}")))
            }
        };
        // structural sanity: the program has a fixed node count
        if seqs[i].len() != graph_len {
            return Err(Verdict::misbehaved(
                i,
                format!("sequence length {} != program length {graph_len}", seqs[i].len()),
            ));
        }
    }

    // line 7: the sequences must merkle-hash to the Phase 1 commitments
    for i in 0..2 {
        if merkle_root(&seqs[i]) != p1.h_end[i] {
            return Err(Verdict::commit_inconsistent(i));
        }
    }

    // lines 8–9: first diverging node index
    let d = match seqs[0].iter().zip(seqs[1].iter()).position(|(a, b)| a != b) {
        Some(d) => d,
        None => {
            // identical sequences would imply identical roots — the merkle
            // check above makes this unreachable for differing h_end
            unreachable!("h_end differ but node sequences agree");
        }
    };

    // line 10: openings, each verified against the trainer's own sequence
    let mut openings: Vec<AugmentedCGNode> = Vec::with_capacity(2);
    for (i, t) in trainers.iter_mut().enumerate() {
        let node = match t.call(Request::OpenNode { step, idx: d }) {
            Response::Node(n) => n,
            other => {
                return Err(Verdict::misbehaved(i, format!("bad OpenNode: {other:?}")))
            }
        };
        if node.commit() != seqs[i][d] {
            return Err(Verdict::misbehaved(
                i,
                format!("node opening does not hash to committed sequence entry {d}"),
            ));
        }
        if node.id != d {
            return Err(Verdict::misbehaved(i, format!("opened node id {} != {d}", node.id)));
        }
        openings.push(node);
    }

    Ok(Phase2Result {
        step,
        node_idx: d,
        openings: [openings[0].clone(), openings[1].clone()],
        seqs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::kernels::Backend;
    use crate::model::Preset;
    use crate::train::JobSpec;
    use crate::verde::faults::Fault;
    use crate::verde::phase1::run_phase1;
    use crate::verde::referee::DecisionCase;
    use crate::verde::trainer::TrainerNode;

    fn dispute_to_phase2(
        fault: Fault,
        steps: u64,
    ) -> (Result<Phase2Result, Verdict>, TrainerNode, TrainerNode) {
        let spec = JobSpec::quick(Preset::Mlp, steps);
        let mut honest = TrainerNode::honest("honest", spec);
        let mut cheat = TrainerNode::new("cheat", spec, Backend::Rep, fault);
        honest.train();
        cheat.train();
        let genesis = honest.session.genesis_root();
        let graph_len = honest.session.program.graph.len();
        let p1 = run_phase1(&mut [&mut honest, &mut cheat], genesis, steps, 4).unwrap();
        let r = run_phase2(&mut [&mut honest, &mut cheat], &p1, graph_len);
        (r, honest, cheat)
    }

    #[test]
    fn finds_the_tampered_node() {
        let (r, honest, _) = dispute_to_phase2(
            Fault::TamperOutput { step: 5, node: 7, delta: 0.5 },
            8,
        );
        let r = r.unwrap();
        assert_eq!(r.step, 5);
        assert_eq!(r.node_idx, 7, "first divergence is the tampered node");
        // inputs agree (first divergence), outputs differ — Case 3 shape
        assert_eq!(r.openings[0].input_hashes, r.openings[1].input_hashes);
        assert_ne!(r.openings[0].output_hashes, r.openings[1].output_hashes);
        drop(honest);
    }

    #[test]
    fn wrong_data_diverges_at_a_data_init_node() {
        let (r, honest, _) = dispute_to_phase2(Fault::WrongData { step: 3 }, 8);
        let r = r.unwrap();
        let node = &honest.session.program.graph.nodes[r.node_idx];
        assert!(
            matches!(
                node.op,
                crate::graph::Op::Init { kind: crate::graph::InitKind::Data, .. }
            ),
            "diverged at {:?}",
            node.op
        );
    }

    #[test]
    fn inconsistent_commit_convicted_at_line7() {
        let (r, _, _) = dispute_to_phase2(Fault::InconsistentCommit { step: 6 }, 8);
        match r.unwrap_err() {
            Verdict::Dishonest { trainer, case, .. } => {
                assert_eq!(trainer, 1);
                assert_eq!(case, DecisionCase::CommitInconsistent);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn skip_optimizer_diverges_at_update_node() {
        let (r, honest, _) = dispute_to_phase2(Fault::SkipOptimizer { step: 4 }, 8);
        let r = r.unwrap();
        let node = &honest.session.program.graph.nodes[r.node_idx];
        assert!(
            matches!(node.op, crate::graph::Op::AdamUpdate { .. }),
            "diverged at {:?}",
            node.op
        );
    }
}
