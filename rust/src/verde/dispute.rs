//! Full 2-trainer dispute orchestration: Phase 1 → Phase 2 → decision,
//! with communication and referee-work accounting.

use crate::net::{Endpoint, Metered};
use crate::train::JobSpec;
use crate::util::metrics::Counters;

use super::phase1::{run_phase1, Phase1Error};
use super::phase2::run_phase2;
use super::referee::{Referee, Verdict};

/// Everything a resolved dispute reports.
#[derive(Debug, Clone)]
pub struct DisputeReport {
    pub verdict: Verdict,
    /// First diverging training step (None if no dispute / early verdict).
    pub diverging_step: Option<u64>,
    /// First diverging node in the step's extended graph.
    pub diverging_node: Option<usize>,
    /// Phase 1 interaction rounds.
    pub phase1_rounds: u32,
    /// Total protocol bytes exchanged with each trainer.
    pub bytes: [u64; 2],
    /// Referee work counters (ops recomputed, lineage checks, input bytes).
    pub referee: Counters,
}

/// Run a complete dispute between two trainer endpoints.
///
/// The referee derives its own program/genesis/data view from `spec` (the
/// client's program setup) and ends up recomputing at most one operator.
pub fn run_dispute(
    spec: JobSpec,
    trainer0: impl Endpoint,
    trainer1: impl Endpoint,
) -> DisputeReport {
    let report = run_dispute_inner(spec, trainer0, trainer1);
    record_dispute(&report);
    report
}

/// Fold one finished dispute into the process-global stats plane
/// (`dispute_*` keys). The report itself stays the authoritative record;
/// these are monotonic totals for the live stats plane.
fn record_dispute(r: &DisputeReport) {
    let g = crate::obs::global();
    g.counter("dispute_runs").inc();
    g.counter("dispute_phase1_rounds").add(r.phase1_rounds as u64);
    g.counter("dispute_recomputed").add(r.referee.get("ops_recomputed"));
    g.counter("dispute_bytes").add(r.bytes[0] + r.bytes[1]);
    if r.verdict.convicted().is_some() {
        g.counter("dispute_convictions").inc();
    }
}

fn run_dispute_inner(
    spec: JobSpec,
    trainer0: impl Endpoint,
    trainer1: impl Endpoint,
) -> DisputeReport {
    let mut referee = Referee::new(spec);
    let mut t0 = Metered::new(trainer0);
    let mut t1 = Metered::new(trainer1);
    let genesis = referee.session.genesis_root();
    let graph_len = referee.session.program.graph.len();

    let p1 = match run_phase1(&mut [&mut t0, &mut t1], genesis, spec.steps, spec.checkpoint_n) {
        Ok(p1) => p1,
        Err(Phase1Error::NoDispute) => {
            return DisputeReport {
                verdict: Verdict::NoDispute,
                diverging_step: None,
                diverging_node: None,
                phase1_rounds: 0,
                bytes: [t0.bytes_sent() + t0.bytes_received(), t1.bytes_sent() + t1.bytes_received()],
                referee: referee.counters,
            }
        }
        Err(Phase1Error::Misbehaved { trainer, why }) => {
            return DisputeReport {
                verdict: Verdict::misbehaved(trainer, why),
                diverging_step: None,
                diverging_node: None,
                phase1_rounds: 0,
                bytes: [t0.bytes_sent() + t0.bytes_received(), t1.bytes_sent() + t1.bytes_received()],
                referee: referee.counters,
            }
        }
        Err(Phase1Error::CommitMismatch { trainer }) => {
            return DisputeReport {
                verdict: Verdict::commit_inconsistent(trainer),
                diverging_step: None,
                diverging_node: None,
                phase1_rounds: 0,
                bytes: [t0.bytes_sent() + t0.bytes_received(), t1.bytes_sent() + t1.bytes_received()],
                referee: referee.counters,
            }
        }
    };

    let (verdict, node_idx) = match run_phase2(&mut [&mut t0, &mut t1], &p1, graph_len) {
        Ok(p2) => {
            let v = referee.decide(&mut [&mut t0, &mut t1], &p1, &p2);
            (v, Some(p2.node_idx))
        }
        Err(early) => (early, None),
    };

    DisputeReport {
        verdict,
        diverging_step: Some(p1.diverging_step),
        diverging_node: node_idx,
        phase1_rounds: p1.rounds,
        bytes: [t0.bytes_sent() + t0.bytes_received(), t1.bytes_sent() + t1.bytes_received()],
        referee: referee.counters,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::kernels::Backend;
    use crate::model::Preset;
    use crate::verde::faults::Fault;
    use crate::verde::referee::DecisionCase;
    use crate::verde::trainer::TrainerNode;

    fn dispute(fault: Fault) -> DisputeReport {
        let spec = JobSpec::quick(Preset::Mlp, 8);
        let mut honest = TrainerNode::honest("honest", spec);
        let mut cheat = TrainerNode::new("cheat", spec, Backend::Rep, fault);
        honest.train();
        cheat.train();
        run_dispute(spec, honest, cheat)
    }

    #[test]
    fn honest_pair_no_dispute() {
        let spec = JobSpec::quick(Preset::Mlp, 8);
        let mut a = TrainerNode::honest("a", spec);
        let mut b = TrainerNode::honest("b", spec);
        a.train();
        b.train();
        let r = run_dispute(spec, a, b);
        assert_eq!(r.verdict, Verdict::NoDispute);
    }

    #[test]
    fn tamper_output_convicted_by_recompute() {
        let r = dispute(Fault::TamperOutput { step: 5, node: 7, delta: 0.5 });
        assert_eq!(r.verdict.convicted(), Some(1), "{:?}", r.verdict);
        assert_eq!(r.verdict.case(), Some(DecisionCase::OutputRecompute));
        assert_eq!(r.diverging_step, Some(5));
        assert_eq!(r.referee.get("ops_recomputed"), 1, "exactly one op recomputed");
    }

    #[test]
    fn wrong_data_convicted_by_data_check() {
        let r = dispute(Fault::WrongData { step: 3 });
        assert_eq!(r.verdict.convicted(), Some(1), "{:?}", r.verdict);
        assert_eq!(r.verdict.case(), Some(DecisionCase::DataCheck));
        assert_eq!(r.referee.get("ops_recomputed"), 0, "no recompute needed");
    }

    #[test]
    fn cheater_as_trainer0_also_convicted() {
        let spec = JobSpec::quick(Preset::Mlp, 8);
        let mut honest = TrainerNode::honest("honest", spec);
        let mut cheat = TrainerNode::new(
            "cheat",
            spec,
            Backend::Rep,
            Fault::TamperOutput { step: 2, node: 7, delta: -0.25 },
        );
        honest.train();
        cheat.train();
        // NOTE: cheater first this time
        let r = run_dispute(spec, cheat, honest);
        assert_eq!(r.verdict.convicted(), Some(0), "{:?}", r.verdict);
    }
}
