//! The referee's decision algorithm (paper §2.3): given both trainers'
//! openings of the first diverging AugmentedCGNode, determine the dishonest
//! party — recomputing AT MOST ONE operator.
//!
//! Case 1 — structure differs → compare against the client's program.
//! Case 2 — an input tensor hash differs →
//!   (a) input from the starting checkpoint/data → Merkle membership proof
//!       against the agreed commitment (or the referee's own data/genesis
//!       derivation);
//!   (b) input from another node of the step → source-node opening.
//! Case 3 — an output tensor hash differs → fetch the (agreed) input
//!   tensors and recompute the single operator with RepOps.

use crate::graph::executor::AugmentedCGNode;
use crate::graph::kernels::{run_op, Backend};
use crate::graph::{InitKind, Op, Slot};
use crate::hash::merkle::MerkleTree;
use crate::hash::{hash_tensor, Hash, Hasher};
use crate::net::Endpoint;
use crate::tensor::Tensor;
use crate::train::session::Session;
use crate::train::JobSpec;
use crate::util::metrics::Counters;

use super::phase1::Phase1Result;
use super::phase2::Phase2Result;
use super::protocol::{InputProvenance, Request, Response};

/// Which branch of the decision algorithm produced the verdict.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecisionCase {
    /// Case 1: graph structure mismatch vs the client's program.
    Structure,
    /// Case 2a: state-input lineage (Merkle membership) failure.
    StateLineage,
    /// Case 2a (data): data-init output contradicts the committed dataset.
    DataCheck,
    /// Constant node contradicts the program's baked constant.
    ConstCheck,
    /// Case 2b: input hash contradicts the (agreed) source node's output.
    InputLineage,
    /// Case 3: single-operator recomputation.
    OutputRecompute,
    /// Algorithm 2 line 7: Phase 2 messages inconsistent with Phase 1.
    CommitInconsistent,
    /// Refused/malformed protocol messages.
    Misbehaved,
}

/// The referee's ruling.
#[derive(Debug, Clone, PartialEq)]
pub enum Verdict {
    Dishonest { trainer: usize, case: DecisionCase, reason: String },
    /// Every participant proved dishonest (possible when no trainer is
    /// honest; the protocol still exposes them all, §2's limitation note).
    BothDishonest { case: DecisionCase, reason: String },
    NoDispute,
}

impl Verdict {
    pub fn misbehaved(trainer: usize, why: String) -> Verdict {
        Verdict::Dishonest { trainer, case: DecisionCase::Misbehaved, reason: why }
    }

    pub fn commit_inconsistent(trainer: usize) -> Verdict {
        Verdict::Dishonest {
            trainer,
            case: DecisionCase::CommitInconsistent,
            reason: "phase 2 node sequence does not merkle-hash to the phase 1 commitment".into(),
        }
    }

    /// The convicted trainer index, if a single one.
    pub fn convicted(&self) -> Option<usize> {
        match self {
            Verdict::Dishonest { trainer, .. } => Some(*trainer),
            _ => None,
        }
    }

    pub fn case(&self) -> Option<DecisionCase> {
        match self {
            Verdict::Dishonest { case, .. } | Verdict::BothDishonest { case, .. } => Some(*case),
            Verdict::NoDispute => None,
        }
    }
}

/// From a per-trainer boolean "is consistent with the truth", produce the
/// verdict.
fn convict(ok: [bool; 2], case: DecisionCase, reason: &str) -> Verdict {
    match ok {
        [true, false] => Verdict::Dishonest { trainer: 1, case, reason: reason.into() },
        [false, true] => Verdict::Dishonest { trainer: 0, case, reason: reason.into() },
        [false, false] => Verdict::BothDishonest { case, reason: reason.into() },
        [true, true] => unreachable!("diverging claims cannot both match the truth: {reason}"),
    }
}

/// The referee party. Holds its own [`Session`] (program, data stream,
/// genesis) derived from the client's job spec — but never trains.
pub struct Referee {
    pub session: Session,
    pub counters: Counters,
}

impl Referee {
    pub fn new(spec: JobSpec) -> Referee {
        Referee { session: Session::new(spec), counters: Counters::new() }
    }

    /// §2.3 decision algorithm over the Phase 2 openings.
    pub fn decide(
        &mut self,
        trainers: &mut [&mut dyn Endpoint; 2],
        p1: &Phase1Result,
        p2: &Phase2Result,
    ) -> Verdict {
        let graph = &self.session.program.graph;
        let d = p2.node_idx;
        let node = &graph.nodes[d];
        let expected_structure = graph.node_structure_hash(d);
        let [n0, n1] = &p2.openings;

        // ---- Case 1: structure --------------------------------------------
        let ok = [n0.structure == expected_structure, n1.structure == expected_structure];
        if !(ok[0] && ok[1]) {
            return convict(ok, DecisionCase::Structure, "node structure differs from the client's program");
        }

        // ---- leaf nodes: outputs are checked against ground truth ----------
        match &node.op {
            Op::Init { kind: InitKind::Data, name } => {
                // the referee has the training data (program setup): derive
                // the true batch tensor hash itself
                let batch = self.session.batch(p2.step);
                let truth = hash_tensor(&batch[name]);
                self.counters.incr("data_checks");
                let ok = [n0.output_hashes[0] == truth, n1.output_hashes[0] == truth];
                return convict(ok, DecisionCase::DataCheck, "data-init output contradicts the committed dataset");
            }
            Op::Const { value } => {
                let truth = hash_tensor(value);
                let ok = [n0.output_hashes[0] == truth, n1.output_hashes[0] == truth];
                return convict(ok, DecisionCase::ConstCheck, "constant contradicts the program");
            }
            Op::Init { kind, name } => {
                // Case 2a: state input — membership proofs
                return self.decide_state_lineage(trainers, p1, p2, kind.clone(), name.clone());
            }
            _ => {}
        }

        // ---- Case 2b: diverging input hash ---------------------------------
        if n0.input_hashes != n1.input_hashes {
            let j = n0
                .input_hashes
                .iter()
                .zip(&n1.input_hashes)
                .position(|(a, b)| a != b)
                .unwrap();
            let src = node.inputs[j];
            // both trainers committed the same hash for the source node
            // (it precedes the first divergence)
            debug_assert_eq!(p2.seqs[0][src.node], p2.seqs[1][src.node]);
            let agreed_src_hash = p2.seqs[0][src.node];
            // open the source from either trainer; accept the first opening
            // that matches the agreed commitment
            let mut src_open: Option<AugmentedCGNode> = None;
            for t in trainers.iter_mut() {
                if let Response::Node(n) = t.call(Request::OpenNode { step: p2.step, idx: src.node }) {
                    if n.commit() == agreed_src_hash {
                        src_open = Some(n);
                        break;
                    }
                }
            }
            let Some(src_open) = src_open else {
                return Verdict::BothDishonest {
                    case: DecisionCase::Misbehaved,
                    reason: "neither trainer opened the agreed source node".into(),
                };
            };
            let truth = src_open.output_hashes[src.out_idx];
            self.counters.incr("lineage_checks");
            let ok = [n0.input_hashes[j] == truth, n1.input_hashes[j] == truth];
            return convict(
                ok,
                DecisionCase::InputLineage,
                "claimed input hash was never emitted by its source node",
            );
        }

        // ---- Case 3: inputs agree, outputs differ → recompute one operator --
        debug_assert_ne!(n0.output_hashes, n1.output_hashes);
        let mut input_tensors: Vec<Tensor> = Vec::with_capacity(node.inputs.len());
        for (j, _) in node.inputs.iter().enumerate() {
            let want = n0.input_hashes[j];
            let mut got: Option<Tensor> = None;
            for t in trainers.iter_mut() {
                if let Response::TensorPayload(tensor) =
                    t.call(Request::InputTensor { step: p2.step, node_idx: d, input_idx: j })
                {
                    if hash_tensor(&tensor) == want {
                        got = Some(tensor);
                        break;
                    }
                }
            }
            match got {
                Some(t) => {
                    self.counters.add("recompute_input_bytes", t.byte_len() as u64);
                    input_tensors.push(t);
                }
                None => {
                    return Verdict::BothDishonest {
                        case: DecisionCase::Misbehaved,
                        reason: format!("no trainer produced input {j} matching the agreed hash"),
                    }
                }
            }
        }
        let refs: Vec<&Tensor> = input_tensors.iter().collect();
        let outs = run_op(&node.op, &refs, Backend::Rep, p2.step);
        self.counters.incr("ops_recomputed");
        let truth: Vec<Hash> = outs.iter().map(hash_tensor).collect();
        let ok = [n0.output_hashes == truth, n1.output_hashes == truth];
        convict(ok, DecisionCase::OutputRecompute, "operator output contradicts RepOps recomputation")
    }

    /// Case 2a: the diverging node is a Param/OptState init — ask both
    /// trainers to prove their claimed value's lineage against the agreed
    /// commitments (genesis for step 1, the previous checkpoint otherwise).
    fn decide_state_lineage(
        &mut self,
        trainers: &mut [&mut dyn Endpoint; 2],
        p1: &Phase1Result,
        p2: &Phase2Result,
        kind: InitKind,
        name: String,
    ) -> Verdict {
        let d = p2.node_idx;
        // expected producer of this tensor in the PREVIOUS step's trace
        let producer: Slot = match kind {
            InitKind::Param => {
                self.session.program.param_updates.get(&name).copied().unwrap_or(Slot::new(d, 0))
            }
            InitKind::OptState => {
                self.session.program.opt_updates.get(&name).copied().unwrap_or(Slot::new(d, 0))
            }
            InitKind::Data => unreachable!("data handled by decide()"),
        };
        let mut ok = [false, false];
        for (i, t) in trainers.iter_mut().enumerate() {
            let claimed = p2.openings[i].output_hashes[0];
            let resp = t.call(Request::InputProof { step: p2.step, node_idx: d });
            ok[i] = match resp {
                Response::Proof(InputProvenance::Genesis { leaf, proof }) => {
                    if p2.step != 1 {
                        false
                    } else {
                        // the leaf must bind this (kind, name, claimed hash)
                        let tag = match kind {
                            InitKind::Param => "verde.state-leaf.param.v1",
                            _ => "verde.state-leaf.opt.v1",
                        };
                        let mut h = Hasher::new(tag);
                        h.str(&name);
                        h.hash(&claimed);
                        let expect_leaf = h.finish();
                        leaf == expect_leaf
                            && MerkleTree::verify(&p1.h_start, &leaf, &proof)
                    }
                }
                Response::Proof(InputProvenance::PrevStep { node, out_idx, proof }) => {
                    p2.step > 1
                        && node.id == producer.node
                        && out_idx == producer.out_idx
                        && out_idx < node.output_hashes.len()
                        && node.output_hashes[out_idx] == claimed
                        && MerkleTree::verify(&p1.h_start, &node.commit(), &proof)
                }
                _ => false,
            };
            self.counters.incr("lineage_checks");
        }
        convict(
            ok,
            DecisionCase::StateLineage,
            "claimed state value has no valid lineage to the agreed checkpoint",
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn convict_logic() {
        let v = convict([true, false], DecisionCase::Structure, "x");
        assert_eq!(v.convicted(), Some(1));
        let v = convict([false, true], DecisionCase::Structure, "x");
        assert_eq!(v.convicted(), Some(0));
        let v = convict([false, false], DecisionCase::OutputRecompute, "x");
        assert!(matches!(v, Verdict::BothDishonest { .. }));
        assert_eq!(v.case(), Some(DecisionCase::OutputRecompute));
    }

    #[test]
    #[should_panic]
    fn convict_rejects_impossible_both_ok() {
        convict([true, true], DecisionCase::Structure, "impossible");
    }
}
