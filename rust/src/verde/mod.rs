//! Verde — the dispute-resolution protocol (paper §2).
//!
//! A client delegates the same training job ([`crate::train::JobSpec`]) to
//! `k` trainers ([`trainer::TrainerNode`]). If their final commitments
//! disagree, the referee runs:
//!
//! * **Phase 1** ([`phase1`]) — multi-level checkpoint bisection to the
//!   first diverging *training step* (Algorithm 1);
//! * **Phase 2** ([`phase2`]) — node-hash comparison inside that step to the
//!   first diverging *operator* (Algorithm 2);
//! * **Decision** ([`referee`]) — Cases 1–3 of §2.3 over the two opened
//!   `AugmentedCGNode`s, recomputing at most ONE operator.
//!
//! [`faults`] catalogues dishonest-trainer behaviours, [`dispute`]
//! orchestrates a full 2-trainer dispute, and [`tournament`] extends to
//! k > 2 trainers (paper footnote 1).

pub mod dispute;
pub mod faults;
pub mod phase1;
pub mod phase2;
pub mod protocol;
pub mod referee;
pub mod tournament;
pub mod trainer;
pub mod wire;

pub use dispute::{run_dispute, DisputeReport};
pub use faults::Fault;
pub use referee::{DecisionCase, Verdict};
pub use trainer::TrainerNode;
