//! Protocol messages between the referee/coordinator and trainers.
//!
//! Wire sizes are no longer modeled: [`Request::wire_size`] and
//! [`Response::wire_size`] are defined as the exact length of the canonical
//! encoding produced by [`super::wire`], so the paper's "only short hashes
//! are communicated" claim is measured against real bytes. Tests here and
//! the property suite in `rust/tests/wire_props.rs` pin
//! `wire_size() == encode().len()` permanently.

use std::time::Duration;

use crate::graph::executor::AugmentedCGNode;
use crate::graph::kernels::Backend;
use crate::hash::merkle::MerkleProof;
use crate::hash::Hash;
use crate::tensor::Tensor;
use crate::train::JobSpec;

use super::wire;

/// Which hardware a job may be delegated to (per-job policy).
///
/// Verification hinges on bit-reproducibility: only RepOps workers
/// ([`Backend::Rep`]) can take part in disputes without the cross-hardware
/// divergence escape hatch. A client that intends to audit its job demands
/// `ReproducibleOnly`; throughput-only work can accept `Any` hardware
/// profile.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendRequirement {
    /// Any worker, including free-order tuned kernels on some
    /// [`HardwareProfile`](crate::tensor::profile::HardwareProfile).
    Any,
    /// Only bit-reproducible (RepOps) workers.
    ReproducibleOnly,
}

impl BackendRequirement {
    /// Does a worker advertising `backend` satisfy this requirement?
    pub fn admits(self, backend: &Backend) -> bool {
        match self {
            BackendRequirement::Any => true,
            BackendRequirement::ReproducibleOnly => matches!(backend, Backend::Rep),
        }
    }
}

/// Per-job delegation policy, carried next to the [`JobSpec`] in
/// [`Request::Submit`] and by `service::client::JobRequest`. Every field
/// has an "inherit the service default" form so `JobPolicy::default()` is
/// always a valid submission.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobPolicy {
    /// Replication factor: workers leased per checkpoint segment.
    /// `0` inherits the service default. On the wire, `k` and `segments`
    /// clamp to [`POLICY_FIELD_MAX`](super::wire::POLICY_FIELD_MAX).
    pub k: usize,
    /// Per-dispatch deadline override (`None` inherits the service
    /// default). Millisecond granularity on the wire.
    pub deadline: Option<Duration>,
    /// Scheduling priority: higher schedules first; ties run in
    /// submission order.
    pub priority: i64,
    /// Which hardware the job's segments may be leased to.
    pub backend: BackendRequirement,
    /// Checkpoint-delimited segments to shard the job into (≥ 1; shard
    /// edges come from the Phase-1 `split_points` schedule).
    pub segments: u64,
    /// Re-queue budget override (`None` inherits the service default).
    pub max_requeues: Option<u32>,
    /// Verified checkpoint state-transfer between segments: segment `i` is
    /// seeded with segment `i−1`'s Merkle-verified checkpoint and trains
    /// only `b_i − b_{i−1}` steps, instead of re-training the whole prefix
    /// `[0, b_i]`. Segments then run as a pipeline (each needs its
    /// predecessor's state) rather than concurrently; any transfer failure
    /// falls back to prefix re-training for that segment. `false` (the
    /// default) keeps the prefix-re-training behavior unchanged.
    pub transfer: bool,
    /// Optimistic audit tier: `0.0` (the default) runs every segment
    /// k-replicated; a rate in `(0.0, 1.0]` instead leases **one** staked
    /// worker per segment, records its per-segment checkpoint-root
    /// commitment ([`Request::CommitRoot`]), and independently replays a
    /// deterministic sample of committed segments at this rate. A matching
    /// replay settles the segment; a divergent replay escalates it into
    /// the full dispute tournament and a conviction slashes the worker's
    /// stake. On the wire the rate is a little-endian `f32`; encoders
    /// clamp it into `[0.0, 1.0]` (`NaN` → `0.0`) and decoders reject
    /// anything outside that range, so one canonical encoding per value
    /// is preserved.
    pub audit_rate: f32,
}

impl Default for JobPolicy {
    fn default() -> JobPolicy {
        JobPolicy {
            k: 0,
            deadline: None,
            priority: 0,
            backend: BackendRequirement::Any,
            segments: 1,
            max_requeues: None,
            transfer: false,
            audit_rate: 0.0,
        }
    }
}

/// Progress of a submitted job as reported over the wire by the
/// coordinator frontend ([`Response::Status`]) — the remote mirror of the
/// in-process `service::client::JobStatus`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RemoteStatus {
    /// The frontend knows no job under that id.
    Unknown,
    /// Submitted, no segment leased yet.
    Queued,
    /// At least one segment leased; counts cover finished segments.
    Running { segments_done: u64, segments_total: u64 },
    /// All segments settled (or the job was cancelled).
    Done {
        /// The commitment the service vouches for (`None` when unresolved
        /// or cancelled).
        accepted: Option<Hash>,
        /// True when the job ended by [`Request::Cancel`].
        cancelled: bool,
        /// Pairwise disputes across all segments.
        disputes: u64,
        /// Workers convicted as dishonest across all segments.
        eliminated: u64,
    },
}

/// Referee/coordinator → trainer requests.
#[derive(Debug, Clone)]
pub enum Request {
    /// The trainer's commitment to its final checkpoint.
    FinalCommit,
    /// Checkpoint hashes at the given step boundaries (trainers re-execute
    /// segments as needed — Algorithm 1's per-level logging).
    CheckpointHashes { boundaries: Vec<u64> },
    /// The full node-hash sequence of one step (Algorithm 2 lines 4–5).
    NodeHashSeq { step: u64 },
    /// Open node `idx` of `step` (Algorithm 2 line 10).
    OpenNode { step: u64, idx: usize },
    /// Provenance proof for the value feeding `(step, node_idx)`'s state
    /// input — Case 2(a): Merkle membership vs the previous checkpoint (or
    /// genesis).
    InputProof { step: u64, node_idx: usize },
    /// A full input tensor of a disputed node (Case 3 recomputation).
    InputTensor { step: u64, node_idx: usize, input_idx: usize },
    /// Delegate a training job to a worker (service layer): run it to
    /// completion and answer with the final commitment. Subsequent dispute
    /// requests on the same connection address this job.
    Train { spec: JobSpec },
    /// Liveness probe (service layer): a healthy worker answers
    /// [`Response::Pong`] immediately without touching its active job. The
    /// coordinator revokes the lease of a worker that misses its ping
    /// deadline.
    Ping,
    /// Client → coordinator frontend: register a job with per-job policy.
    /// Answered with [`Response::Submitted`] carrying the job id every
    /// later `Status`/`Cancel` addresses.
    Submit { spec: JobSpec, policy: JobPolicy },
    /// Client → coordinator frontend: poll a submitted job's progress.
    /// Answered with [`Response::Status`].
    Status { job_id: u64 },
    /// Client → coordinator frontend: cancel a submitted job; its leases
    /// return to the pool mid-flight. Answered with
    /// [`Response::Cancelled`].
    Cancel { job_id: u64 },
    /// Coordinator → worker (state transfer): upload chunk `chunk` of the
    /// serialized checkpoint state after training step `step` of the
    /// active job. Answered with [`Response::Checkpoint`]; the coordinator
    /// verifies the reassembled state's Merkle root before seeding the
    /// next segment with it.
    FetchCheckpoint { step: u64, chunk: u64 },
    /// Coordinator → worker (streaming state transfer): describe the
    /// serialized checkpoint state after training step `step` of the
    /// active job without shipping any payload. Answered with
    /// [`Response::Manifest`] carrying the per-chunk hashes, which lets
    /// the coordinator verify each subsequently fetched chunk the moment
    /// it arrives instead of buffering the whole state first.
    FetchManifest { step: u64 },
    /// Coordinator → worker (state transfer): chunk `chunk` of
    /// `total_chunks` of a verified checkpoint state at boundary `start`
    /// of `spec`'s step range. Intermediate chunks are acknowledged with
    /// [`Response::Pong`]; the final chunk makes the worker reassemble the
    /// state, verify it against `root` (Merkle root over the state
    /// leaves), train the remaining `spec.steps − start` steps, and answer
    /// [`Response::Commit`] exactly as a full `Train` would — or
    /// [`Response::Refuse`] when the upload fails verification.
    SeedCheckpoint {
        spec: JobSpec,
        start: u64,
        root: Hash,
        total_chunks: u64,
        chunk: u64,
        payload: Vec<u8>,
    },
    /// Coordinator → worker (optimistic audit tier): commit to the Merkle
    /// root of the checkpoint state after training step `step` of the
    /// active job. Answered with [`Response::Commit`] carrying the state
    /// root — the binding commitment a sampled replay audit is checked
    /// against — or [`Response::Refuse`] when `step` is outside the active
    /// job's trained range (hostile or stale steps never panic a worker).
    CommitRoot { step: u64 },
    /// Ask any stats-serving peer (worker host, coordinator frontend) for
    /// a point-in-time [`Snapshot`](crate::obs::Snapshot) of its metrics
    /// registry. Answered with [`Response::Stats`]; peers without a
    /// registry refuse. Read-only and safe to poll — `verde stats` drives
    /// this.
    Stats,
    /// End the conversation (stream/threaded transports).
    Shutdown,
}

/// Where a disputed state input came from (Case 2a evidence).
#[derive(Debug, Clone)]
pub enum InputProvenance {
    /// The job's initial state: membership proof of the state leaf in the
    /// genesis commitment.
    Genesis { leaf: Hash, proof: MerkleProof },
    /// Produced by a node of the previous step: that node's opening plus a
    /// membership proof of its hash in the agreed previous checkpoint.
    PrevStep { node: AugmentedCGNode, out_idx: usize, proof: MerkleProof },
}

impl InputProvenance {
    /// Exact encoded size in bytes (discriminant included).
    pub fn wire_size(&self) -> usize {
        wire::provenance_wire_len(self)
    }
}

/// Trainer → referee/coordinator responses.
#[derive(Debug, Clone)]
pub enum Response {
    Commit(Hash),
    Hashes(Vec<Hash>),
    NodeSeq(Vec<Hash>),
    Node(AugmentedCGNode),
    Proof(InputProvenance),
    TensorPayload(Tensor),
    /// The trainer cannot or will not answer (counted as dishonest).
    Refuse(String),
    Bye,
    /// Liveness answer to [`Request::Ping`].
    Pong,
    /// [`Request::Submit`] accepted; the job is registered under this id.
    Submitted { job_id: u64 },
    /// Answer to [`Request::Status`].
    Status(RemoteStatus),
    /// Answer to [`Request::Cancel`]: whether the cancel took effect
    /// before the job finished.
    Cancelled(bool),
    /// Answer to [`Request::FetchCheckpoint`]: one chunk of the serialized
    /// checkpoint state after `step`, plus the Merkle root (over the state
    /// leaves) the full state commits to. Every chunk of one state repeats
    /// the same `root` and `total_chunks`.
    Checkpoint {
        step: u64,
        root: Hash,
        total_chunks: u64,
        chunk: u64,
        payload: Vec<u8>,
    },
    /// Answer to [`Request::FetchManifest`]: the shape of the serialized
    /// checkpoint state after `step` — its Merkle state root, total
    /// encoded length, and the hash of every `CHECKPOINT_CHUNK`-sized
    /// chunk in order. `chunks` is non-empty and consistent with
    /// `total_len` by construction; decoders enforce both. The
    /// coordinator certifies a manifest by unanimity across the winning
    /// group, then streams chunks and verifies each against its manifest
    /// hash on arrival.
    Manifest {
        step: u64,
        root: Hash,
        total_len: u64,
        chunks: Vec<Hash>,
    },
    /// Answer to [`Request::Stats`]: the peer's live metrics snapshot —
    /// versioned key set, zeros when nothing has happened yet.
    Stats(crate::obs::Snapshot),
}

impl Request {
    /// Exact wire size in bytes: `self.encode().len()` by definition.
    pub fn wire_size(&self) -> usize {
        wire::request_wire_len(self)
    }
}

impl Response {
    /// Exact wire size in bytes: `self.encode().len()` by definition.
    pub fn wire_size(&self) -> usize {
        wire::response_wire_len(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Preset;

    #[test]
    fn wire_sizes_scale_with_payload() {
        let small = Response::Hashes(vec![Hash::ZERO; 2]);
        let big = Response::Hashes(vec![Hash::ZERO; 20]);
        assert!(big.wire_size() > small.wire_size());
        // tag + u64 count + 20 digests
        assert_eq!(big.wire_size(), 1 + 8 + 640);

        let t = Tensor::zeros([16, 16]);
        let payload = Response::TensorPayload(t);
        assert!(payload.wire_size() > 1024);

        assert_eq!(Request::FinalCommit.wire_size(), 1);
        // tag + u64 count + 3 × u64 boundary
        assert_eq!(
            Request::CheckpointHashes { boundaries: vec![1, 2, 3] }.wire_size(),
            33
        );
    }

    #[test]
    fn wire_size_equals_encoded_length() {
        let reqs = [
            Request::FinalCommit,
            Request::CheckpointHashes { boundaries: vec![4, 8, 15, 16, 23, 42] },
            Request::NodeHashSeq { step: 3 },
            Request::OpenNode { step: 3, idx: 9 },
            Request::InputProof { step: 2, node_idx: 1 },
            Request::InputTensor { step: 2, node_idx: 1, input_idx: 0 },
            Request::Train { spec: JobSpec::quick(Preset::LlamaTiny, 64) },
            Request::Ping,
            Request::Submit {
                spec: JobSpec::quick(Preset::Mlp, 32),
                policy: JobPolicy {
                    k: 3,
                    deadline: Some(Duration::from_millis(1500)),
                    priority: -4,
                    backend: BackendRequirement::ReproducibleOnly,
                    segments: 4,
                    max_requeues: Some(2),
                    transfer: true,
                    audit_rate: 0.25,
                },
            },
            Request::Submit {
                spec: JobSpec::quick(Preset::Mlp, 8),
                policy: JobPolicy::default(),
            },
            Request::Status { job_id: 17 },
            Request::Cancel { job_id: u64::MAX },
            Request::FetchCheckpoint { step: 9, chunk: 2 },
            Request::FetchManifest { step: 9 },
            Request::CommitRoot { step: 12 },
            Request::Stats,
            Request::SeedCheckpoint {
                spec: JobSpec::quick(Preset::Mlp, 10),
                start: 5,
                root: Hash::ZERO,
                total_chunks: 2,
                chunk: 0,
                payload: vec![3; 40],
            },
            Request::Shutdown,
        ];
        for r in reqs {
            assert_eq!(r.wire_size(), r.encode().len(), "{r:?}");
        }
        let resps = [
            Response::Commit(Hash::ZERO),
            Response::Hashes(vec![Hash::ZERO; 7]),
            Response::TensorPayload(Tensor::rand([4, 5], 1, 1.0)),
            Response::Refuse("why".into()),
            Response::Bye,
            Response::Pong,
            Response::Submitted { job_id: 9 },
            Response::Status(RemoteStatus::Unknown),
            Response::Status(RemoteStatus::Queued),
            Response::Status(RemoteStatus::Running { segments_done: 1, segments_total: 4 }),
            Response::Status(RemoteStatus::Done {
                accepted: Some(Hash::ZERO),
                cancelled: false,
                disputes: 2,
                eliminated: 1,
            }),
            Response::Status(RemoteStatus::Done {
                accepted: None,
                cancelled: true,
                disputes: 0,
                eliminated: 0,
            }),
            Response::Cancelled(true),
            Response::Cancelled(false),
            Response::Checkpoint {
                step: 5,
                root: Hash::ZERO,
                total_chunks: 3,
                chunk: 2,
                payload: vec![9; 64],
            },
            Response::Manifest {
                step: 5,
                root: Hash::ZERO,
                total_len: 64,
                chunks: vec![Hash::ZERO],
            },
            Response::Stats(crate::obs::Snapshot::empty()),
            Response::Stats({
                let reg = crate::obs::Registry::new();
                reg.counter("coord_jobs_submitted").add(4);
                reg.gauge("coord_queue_depth").set(1);
                reg.histogram("coord_tick_us", &[10, 100]).observe(55);
                reg.snapshot()
            }),
        ];
        for r in resps {
            assert_eq!(r.wire_size(), r.encode().len(), "{r:?}");
        }
    }

    #[test]
    fn backend_requirement_admits_matches_reproducibility() {
        use crate::graph::kernels::Backend;
        use crate::tensor::profile::HardwareProfile;
        assert!(BackendRequirement::Any.admits(&Backend::Rep));
        assert!(BackendRequirement::Any.admits(&Backend::Free(HardwareProfile::T4_16G)));
        assert!(BackendRequirement::ReproducibleOnly.admits(&Backend::Rep));
        assert!(!BackendRequirement::ReproducibleOnly
            .admits(&Backend::Free(HardwareProfile::A100_40G)));
    }
}
