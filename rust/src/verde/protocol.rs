//! Protocol messages between the referee/coordinator and trainers.
//!
//! Wire sizes are no longer modeled: [`Request::wire_size`] and
//! [`Response::wire_size`] are defined as the exact length of the canonical
//! encoding produced by [`super::wire`], so the paper's "only short hashes
//! are communicated" claim is measured against real bytes. Tests here and
//! the property suite in `rust/tests/wire_props.rs` pin
//! `wire_size() == encode().len()` permanently.

use crate::graph::executor::AugmentedCGNode;
use crate::hash::merkle::MerkleProof;
use crate::hash::Hash;
use crate::tensor::Tensor;
use crate::train::JobSpec;

use super::wire;

/// Referee/coordinator → trainer requests.
#[derive(Debug, Clone)]
pub enum Request {
    /// The trainer's commitment to its final checkpoint.
    FinalCommit,
    /// Checkpoint hashes at the given step boundaries (trainers re-execute
    /// segments as needed — Algorithm 1's per-level logging).
    CheckpointHashes { boundaries: Vec<u64> },
    /// The full node-hash sequence of one step (Algorithm 2 lines 4–5).
    NodeHashSeq { step: u64 },
    /// Open node `idx` of `step` (Algorithm 2 line 10).
    OpenNode { step: u64, idx: usize },
    /// Provenance proof for the value feeding `(step, node_idx)`'s state
    /// input — Case 2(a): Merkle membership vs the previous checkpoint (or
    /// genesis).
    InputProof { step: u64, node_idx: usize },
    /// A full input tensor of a disputed node (Case 3 recomputation).
    InputTensor { step: u64, node_idx: usize, input_idx: usize },
    /// Delegate a training job to a worker (service layer): run it to
    /// completion and answer with the final commitment. Subsequent dispute
    /// requests on the same connection address this job.
    Train { spec: JobSpec },
    /// Liveness probe (service layer): a healthy worker answers
    /// [`Response::Pong`] immediately without touching its active job. The
    /// coordinator revokes the lease of a worker that misses its ping
    /// deadline.
    Ping,
    /// End the conversation (stream/threaded transports).
    Shutdown,
}

/// Where a disputed state input came from (Case 2a evidence).
#[derive(Debug, Clone)]
pub enum InputProvenance {
    /// The job's initial state: membership proof of the state leaf in the
    /// genesis commitment.
    Genesis { leaf: Hash, proof: MerkleProof },
    /// Produced by a node of the previous step: that node's opening plus a
    /// membership proof of its hash in the agreed previous checkpoint.
    PrevStep { node: AugmentedCGNode, out_idx: usize, proof: MerkleProof },
}

impl InputProvenance {
    /// Exact encoded size in bytes (discriminant included).
    pub fn wire_size(&self) -> usize {
        wire::provenance_wire_len(self)
    }
}

/// Trainer → referee/coordinator responses.
#[derive(Debug, Clone)]
pub enum Response {
    Commit(Hash),
    Hashes(Vec<Hash>),
    NodeSeq(Vec<Hash>),
    Node(AugmentedCGNode),
    Proof(InputProvenance),
    TensorPayload(Tensor),
    /// The trainer cannot or will not answer (counted as dishonest).
    Refuse(String),
    Bye,
    /// Liveness answer to [`Request::Ping`].
    Pong,
}

impl Request {
    /// Exact wire size in bytes: `self.encode().len()` by definition.
    pub fn wire_size(&self) -> usize {
        wire::request_wire_len(self)
    }
}

impl Response {
    /// Exact wire size in bytes: `self.encode().len()` by definition.
    pub fn wire_size(&self) -> usize {
        wire::response_wire_len(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Preset;

    #[test]
    fn wire_sizes_scale_with_payload() {
        let small = Response::Hashes(vec![Hash::ZERO; 2]);
        let big = Response::Hashes(vec![Hash::ZERO; 20]);
        assert!(big.wire_size() > small.wire_size());
        // tag + u64 count + 20 digests
        assert_eq!(big.wire_size(), 1 + 8 + 640);

        let t = Tensor::zeros([16, 16]);
        let payload = Response::TensorPayload(t);
        assert!(payload.wire_size() > 1024);

        assert_eq!(Request::FinalCommit.wire_size(), 1);
        // tag + u64 count + 3 × u64 boundary
        assert_eq!(
            Request::CheckpointHashes { boundaries: vec![1, 2, 3] }.wire_size(),
            33
        );
    }

    #[test]
    fn wire_size_equals_encoded_length() {
        let reqs = [
            Request::FinalCommit,
            Request::CheckpointHashes { boundaries: vec![4, 8, 15, 16, 23, 42] },
            Request::NodeHashSeq { step: 3 },
            Request::OpenNode { step: 3, idx: 9 },
            Request::InputProof { step: 2, node_idx: 1 },
            Request::InputTensor { step: 2, node_idx: 1, input_idx: 0 },
            Request::Train { spec: JobSpec::quick(Preset::LlamaTiny, 64) },
            Request::Ping,
            Request::Shutdown,
        ];
        for r in reqs {
            assert_eq!(r.wire_size(), r.encode().len(), "{r:?}");
        }
        let resps = [
            Response::Commit(Hash::ZERO),
            Response::Hashes(vec![Hash::ZERO; 7]),
            Response::TensorPayload(Tensor::rand([4, 5], 1, 1.0)),
            Response::Refuse("why".into()),
            Response::Bye,
            Response::Pong,
        ];
        for r in resps {
            assert_eq!(r.wire_size(), r.encode().len(), "{r:?}");
        }
    }
}
