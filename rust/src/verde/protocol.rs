//! Protocol messages between the referee and trainers, with wire-size
//! models for communication accounting (the paper's "only short hashes are
//! communicated" claim is measured, not assumed).

use crate::graph::executor::AugmentedCGNode;
use crate::hash::merkle::MerkleProof;
use crate::hash::Hash;
use crate::tensor::Tensor;

/// Referee → trainer requests.
#[derive(Debug, Clone)]
pub enum Request {
    /// The trainer's commitment to its final checkpoint.
    FinalCommit,
    /// Checkpoint hashes at the given step boundaries (trainers re-execute
    /// segments as needed — Algorithm 1's per-level logging).
    CheckpointHashes { boundaries: Vec<u64> },
    /// The full node-hash sequence of one step (Algorithm 2 lines 4–5).
    NodeHashSeq { step: u64 },
    /// Open node `idx` of `step` (Algorithm 2 line 10).
    OpenNode { step: u64, idx: usize },
    /// Provenance proof for the value feeding `(step, node_idx)`'s state
    /// input — Case 2(a): Merkle membership vs the previous checkpoint (or
    /// genesis).
    InputProof { step: u64, node_idx: usize },
    /// A full input tensor of a disputed node (Case 3 recomputation).
    InputTensor { step: u64, node_idx: usize, input_idx: usize },
    /// End the conversation (threaded transport).
    Shutdown,
}

/// Where a disputed state input came from (Case 2a evidence).
#[derive(Debug, Clone)]
pub enum InputProvenance {
    /// The job's initial state: membership proof of the state leaf in the
    /// genesis commitment.
    Genesis { leaf: Hash, proof: MerkleProof },
    /// Produced by a node of the previous step: that node's opening plus a
    /// membership proof of its hash in the agreed previous checkpoint.
    PrevStep { node: AugmentedCGNode, out_idx: usize, proof: MerkleProof },
}

impl InputProvenance {
    pub fn wire_size(&self) -> usize {
        match self {
            InputProvenance::Genesis { proof, .. } => 32 + proof.byte_len(),
            InputProvenance::PrevStep { node, proof, .. } => {
                node.byte_len() + 8 + proof.byte_len()
            }
        }
    }
}

/// Trainer → referee responses.
#[derive(Debug, Clone)]
pub enum Response {
    Commit(Hash),
    Hashes(Vec<Hash>),
    NodeSeq(Vec<Hash>),
    Node(AugmentedCGNode),
    Proof(InputProvenance),
    TensorPayload(Tensor),
    /// The trainer cannot or will not answer (counted as dishonest).
    Refuse(String),
    Bye,
}

impl Request {
    /// Modeled wire size in bytes (tag + payload).
    pub fn wire_size(&self) -> usize {
        1 + match self {
            Request::FinalCommit | Request::Shutdown => 0,
            Request::CheckpointHashes { boundaries } => 8 * boundaries.len(),
            Request::NodeHashSeq { .. } => 8,
            Request::OpenNode { .. } => 16,
            Request::InputProof { .. } => 16,
            Request::InputTensor { .. } => 24,
        }
    }
}

impl Response {
    pub fn wire_size(&self) -> usize {
        1 + match self {
            Response::Commit(_) => 32,
            Response::Hashes(h) => 32 * h.len(),
            Response::NodeSeq(h) => 32 * h.len(),
            Response::Node(n) => n.byte_len(),
            Response::Proof(p) => p.wire_size(),
            Response::TensorPayload(t) => 8 + 8 * t.rank() + t.byte_len(),
            Response::Refuse(s) => s.len(),
            Response::Bye => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_sizes_scale_with_payload() {
        let small = Response::Hashes(vec![Hash::ZERO; 2]);
        let big = Response::Hashes(vec![Hash::ZERO; 20]);
        assert!(big.wire_size() > small.wire_size());
        assert_eq!(big.wire_size(), 1 + 640);

        let t = Tensor::zeros([16, 16]);
        let payload = Response::TensorPayload(t);
        assert!(payload.wire_size() > 1024);

        assert_eq!(Request::FinalCommit.wire_size(), 1);
        assert_eq!(
            Request::CheckpointHashes { boundaries: vec![1, 2, 3] }.wire_size(),
            25
        );
    }
}
