//! The fault model: every dishonest-trainer behaviour the protocol must
//! catch (DESIGN.md §1 maps each to the referee case that convicts it).
//!
//! Faults are *consistent* lies: the cheating trainer commits to the same
//! wrong computation during training and during dispute re-execution —
//! the hard case. (Inconsistent lying is caught immediately by the Merkle
//! checks; [`Fault::InconsistentCommit`] covers that path explicitly.)

use crate::graph::{NodeId, Op};

/// Dishonest-trainer strategies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fault {
    /// Honest execution on RepOps.
    None,
    /// Perturb one operator's output tensor at one step (bit flip / lazy
    /// approximation / backdoor insertion all look like this on the wire).
    TamperOutput { step: u64, node: NodeId, delta: f32 },
    /// Run a structurally different operator at one node (wrong graph —
    /// referee Case 1).
    WrongOperator { step: u64, node: NodeId },
    /// Train on a substituted data batch at one step (data poisoning).
    WrongData { step: u64 },
    /// Skip the optimizer update at one step (lazy trainer; weights pass
    /// through unchanged).
    SkipOptimizer { step: u64 },
    /// Stop computing after `after` steps and replay the stale checkpoint
    /// for the rest of the run (the paper's "lazy server" example).
    SkipSteps { after: u64 },
    /// Lie about one input hash in the committed trace (forged lineage —
    /// referee Case 2).
    ForgedLineage { step: u64, node: NodeId },
    /// Send a Phase 2 node sequence inconsistent with the Phase 1
    /// commitment (caught by Algorithm 2 line 7).
    InconsistentCommit { step: u64 },
    /// Honest intent, but executing on non-reproducible (free-order)
    /// kernels — the hardware-nondeterminism hazard RepOps removes (§3).
    NonRepHardware,
}

impl Fault {
    /// Does this fault alter the execution of step `step`?
    pub fn affects_step(&self, step: u64) -> bool {
        match self {
            Fault::None => false,
            Fault::TamperOutput { step: s, .. }
            | Fault::WrongOperator { step: s, .. }
            | Fault::WrongData { step: s }
            | Fault::SkipOptimizer { step: s }
            | Fault::ForgedLineage { step: s, .. }
            | Fault::InconsistentCommit { step: s } => *s == step,
            Fault::SkipSteps { after } => step > *after,
            Fault::NonRepHardware => true,
        }
    }

    /// The first training step whose checkpoint diverges from honest
    /// execution, if statically known (tests use this to validate Phase 1).
    pub fn first_divergent_step(&self) -> Option<u64> {
        match self {
            Fault::None => None,
            Fault::TamperOutput { step, .. }
            | Fault::WrongOperator { step, .. }
            | Fault::WrongData { step }
            | Fault::SkipOptimizer { step }
            | Fault::ForgedLineage { step, .. }
            | Fault::InconsistentCommit { step } => Some(*step),
            Fault::SkipSteps { after } => Some(after + 1),
            Fault::NonRepHardware => Some(1),
        }
    }

    pub fn describe(&self) -> String {
        format!("{self:?}")
    }
}

/// A structure-changing mutation for [`Fault::WrongOperator`]: swap the
/// operator for a shape-compatible impostor. Returns `None` when the node's
/// op has no safe impostor (callers pick a different node).
pub fn mutate_op(op: &Op) -> Option<Op> {
    match op {
        Op::Gelu => Some(Op::Relu),
        Op::Silu => Some(Op::Relu),
        Op::Relu => Some(Op::Tanh),
        Op::Tanh => Some(Op::Relu),
        Op::Scale { c } => Some(Op::Scale { c: c * 1.25 }),
        Op::RmsNorm { eps } => Some(Op::RmsNorm { eps: eps * 10.0 }),
        Op::LayerNorm { eps } => Some(Op::LayerNorm { eps: eps * 10.0 }),
        Op::AdamUpdate { lr, beta1, beta2, eps } => Some(Op::AdamUpdate {
            lr: lr * 0.5, // trains with half the promised learning rate
            beta1: *beta1,
            beta2: *beta2,
            eps: *eps,
        }),
        _ => None,
    }
}

/// First node in `graph` whose op has an impostor — a convenient target for
/// `WrongOperator` tests and CLI demos.
pub fn first_mutable_node(graph: &crate::graph::Graph) -> Option<NodeId> {
    graph.nodes.iter().position(|n| mutate_op(&n.op).is_some())
}

/// The first (lowest-id) parameter-update node of a training program — the
/// canonical `TamperOutput` target: perturbing an update output is
/// guaranteed to diverge the committed state (an activation tamper can be
/// swallowed by a ReLU). Shared by the CLI, the service's fault plans, and
/// tests so they can never drift apart.
pub fn first_update_node(program: &crate::graph::autodiff::TrainStep) -> Option<NodeId> {
    program.param_updates.values().map(|s| s.node).min()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn affects_step_logic() {
        let f = Fault::TamperOutput { step: 5, node: 3, delta: 1.0 };
        assert!(f.affects_step(5));
        assert!(!f.affects_step(4));
        let s = Fault::SkipSteps { after: 10 };
        assert!(!s.affects_step(10));
        assert!(s.affects_step(11));
        assert!(s.affects_step(99));
        assert!(!Fault::None.affects_step(1));
        assert!(Fault::NonRepHardware.affects_step(1));
    }

    #[test]
    fn first_divergence_matches_affects() {
        for f in [
            Fault::TamperOutput { step: 3, node: 0, delta: 0.1 },
            Fault::WrongData { step: 7 },
            Fault::SkipSteps { after: 4 },
        ] {
            let d = f.first_divergent_step().unwrap();
            assert!(f.affects_step(d));
            assert!(!f.affects_step(d - 1) || matches!(f, Fault::NonRepHardware));
        }
    }

    #[test]
    fn mutate_op_changes_attr_hash() {
        let g = Op::Gelu;
        let m = mutate_op(&g).unwrap();
        assert_ne!(g.attr_hash(), m.attr_hash());
        let s = Op::Scale { c: 2.0 };
        let ms = mutate_op(&s).unwrap();
        assert_ne!(s.attr_hash(), ms.attr_hash());
        assert!(mutate_op(&Op::MatMul).is_none());
    }
}
