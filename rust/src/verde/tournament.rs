//! k > 2 trainers (paper §2 footnote 1): trainers claiming identical
//! outputs merge; distinct claims are resolved pairwise, the survivor
//! carrying forward. An honest participant can never be eliminated, so the
//! surviving claim is correct whenever at least one trainer is honest.
//!
//! The tournament is generic over [`Endpoint`], so the same knockout runs
//! against in-process [`TrainerNode`](crate::verde::trainer::TrainerNode)s,
//! thread actors ([`crate::net::threaded::Remote`]), or remote worker
//! processes over TCP ([`crate::net::tcp::TcpEndpoint`]) — the service
//! layer's deployment shape.

use std::collections::BTreeMap;

use crate::hash::Hash;
use crate::net::Endpoint;
use crate::train::JobSpec;
use crate::verde::dispute::run_dispute;
use crate::verde::protocol::{Request, Response};
use crate::verde::referee::Verdict;

/// Outcome of a k-trainer tournament.
#[derive(Debug)]
pub struct TournamentReport {
    /// Index (into the input vector) of the trainer whose output is accepted.
    pub winner: usize,
    /// The accepted final commitment.
    pub accepted: Hash,
    /// Trainers proven dishonest, with the dispute verdicts that convicted
    /// them (merged trainers share their representative's fate only for
    /// accounting — identical claims are indistinguishable). Trainers that
    /// refuse to produce a final commitment at all are eliminated up front
    /// with a `Misbehaved` verdict.
    pub eliminated: Vec<(usize, Verdict)>,
    /// Number of pairwise disputes run (≤ distinct-claims − 1).
    pub disputes: usize,
}

/// Run the tournament over any endpoints. Final commitments are collected
/// via [`Request::FinalCommit`]; each dispute requires the participants to
/// serve re-execution queries, and survivors go on to later rounds with
/// their caches warm.
///
/// # Panics
/// If `trainers` is empty, if every trainer refuses to commit, or if a
/// dispute between distinct claims ends without a conviction (impossible
/// under the protocol's assumptions).
pub fn run_tournament<E: Endpoint>(spec: JobSpec, trainers: &mut [E]) -> TournamentReport {
    assert!(!trainers.is_empty());
    // collect claims; refusal to commit is an immediate elimination
    let mut eliminated: Vec<(usize, Verdict)> = Vec::new();
    let mut claims: Vec<Option<Hash>> = Vec::with_capacity(trainers.len());
    for (i, t) in trainers.iter_mut().enumerate() {
        match t.call(Request::FinalCommit) {
            Response::Commit(h) => claims.push(Some(h)),
            other => {
                claims.push(None);
                eliminated.push((i, Verdict::misbehaved(i, format!("no final commitment: {other:?}"))));
            }
        }
    }

    // merge identical claims: keep the first trainer per distinct claim
    let mut groups: BTreeMap<Hash, Vec<usize>> = BTreeMap::new();
    for (i, c) in claims.iter().enumerate() {
        if let Some(c) = c {
            groups.entry(*c).or_default().push(i);
        }
    }
    assert!(!groups.is_empty(), "every trainer refused to commit");
    if groups.len() == 1 {
        let winner = groups.values().next().unwrap()[0];
        return TournamentReport {
            winner,
            accepted: claims[winner].unwrap(),
            eliminated,
            disputes: 0,
        };
    }

    // representatives, in input order for determinism
    let mut reps: Vec<usize> = groups.values().map(|g| g[0]).collect();
    reps.sort();

    let mut disputes = 0;
    // pairwise knockout: champion vs next challenger
    let mut champion = reps[0];
    for &challenger in &reps[1..] {
        if champion == usize::MAX {
            // every prior claim was proven dishonest; adopt the challenger
            champion = challenger;
            continue;
        }
        let (lo, hi) = (champion.min(challenger), champion.max(challenger));
        let (left, right) = trainers.split_at_mut(hi);
        let (t_lo, t_hi) = (&mut left[lo], &mut right[0]);
        let (t0_idx, t1_idx) = (lo, hi);
        let report = run_dispute(spec, t_lo, t_hi);
        disputes += 1;
        match &report.verdict {
            Verdict::Dishonest { trainer, .. } => {
                let loser_idx = if *trainer == 0 { t0_idx } else { t1_idx };
                let winner_idx = if *trainer == 0 { t1_idx } else { t0_idx };
                eliminated.push((loser_idx, report.verdict.clone()));
                champion = winner_idx;
            }
            Verdict::BothDishonest { .. } => {
                eliminated.push((t0_idx, report.verdict.clone()));
                eliminated.push((t1_idx, report.verdict.clone()));
                champion = usize::MAX; // next challenger takes over
            }
            other => panic!("dispute between distinct claims ended with {other:?}"),
        }
    }
    if champion == usize::MAX {
        // everyone was proven dishonest; accept the last eliminated claim
        // holder by convention and report it as such (paper's limitation:
        // with zero honest trainers the accepted output may be wrong, but
        // k−1 parties are still exposed).
        champion = eliminated.last().map(|(i, _)| *i).unwrap_or(0);
    }

    let accepted = claims[champion]
        .or_else(|| claims.iter().flatten().next().copied())
        .expect("some claim exists");
    TournamentReport { winner: champion, accepted, eliminated, disputes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::kernels::Backend;
    use crate::model::Preset;
    use crate::verde::faults::Fault;
    use crate::verde::trainer::TrainerNode;

    fn mk(spec: JobSpec, fault: Fault, name: &str) -> TrainerNode {
        let mut t = TrainerNode::new(name, spec, Backend::Rep, fault);
        t.train();
        t
    }

    #[test]
    fn all_honest_merge_without_disputes() {
        let spec = JobSpec::quick(Preset::Mlp, 6);
        let mut ts = vec![
            mk(spec, Fault::None, "a"),
            mk(spec, Fault::None, "b"),
            mk(spec, Fault::None, "c"),
        ];
        let r = run_tournament(spec, &mut ts);
        assert_eq!(r.disputes, 0);
        assert!(r.eliminated.is_empty());
    }

    #[test]
    fn single_honest_survives_two_cheaters() {
        let spec = JobSpec::quick(Preset::Mlp, 6);
        let honest_commit = {
            let mut t = mk(spec, Fault::None, "h");
            t.final_commit()
        };
        let mut ts = vec![
            mk(spec, Fault::TamperOutput { step: 2, node: 7, delta: 0.5 }, "c1"),
            mk(spec, Fault::None, "h"),
            mk(spec, Fault::WrongData { step: 4 }, "c2"),
        ];
        let r = run_tournament(spec, &mut ts);
        assert_eq!(r.accepted, honest_commit, "honest claim must win");
        assert_eq!(r.disputes, 2);
        assert_eq!(r.eliminated.len(), 2);
        let eliminated: Vec<usize> = r.eliminated.iter().map(|(i, _)| *i).collect();
        assert!(eliminated.contains(&0));
        assert!(eliminated.contains(&2));
    }

    #[test]
    fn duplicate_cheater_claims_merge() {
        let spec = JobSpec::quick(Preset::Mlp, 6);
        let honest_commit = {
            let mut t = mk(spec, Fault::None, "h");
            t.final_commit()
        };
        // Tamper an optimizer-update output: guaranteed to diverge the
        // state (an activation tamper can be swallowed by a ReLU).
        let upd = {
            let s = crate::train::session::Session::new(spec);
            *s.program.param_updates.values().map(|sl| &sl.node).min().unwrap()
        };
        // two cheaters with the SAME fault produce the same (wrong) claim
        let mut ts = vec![
            mk(spec, Fault::TamperOutput { step: 3, node: upd, delta: 0.5 }, "c1"),
            mk(spec, Fault::TamperOutput { step: 3, node: upd, delta: 0.5 }, "c2"),
            mk(spec, Fault::None, "h"),
        ];
        let r = run_tournament(spec, &mut ts);
        assert_eq!(r.accepted, honest_commit);
        assert_eq!(r.disputes, 1, "identical claims merged into one dispute");
    }

    /// A party that refuses even to commit is eliminated without a dispute.
    struct Refusenik;

    impl Endpoint for Refusenik {
        fn name(&self) -> &str {
            "refusenik"
        }
        fn call(&mut self, _req: Request) -> Response {
            Response::Refuse("not playing".into())
        }
    }

    #[test]
    fn refusing_endpoint_is_eliminated_without_dispute() {
        enum Party {
            Node(TrainerNode),
            Refuse(Refusenik),
        }
        impl Endpoint for Party {
            fn name(&self) -> &str {
                match self {
                    Party::Node(t) => t.name(),
                    Party::Refuse(r) => r.name(),
                }
            }
            fn call(&mut self, req: Request) -> Response {
                match self {
                    Party::Node(t) => t.call(req),
                    Party::Refuse(r) => r.call(req),
                }
            }
        }
        let spec = JobSpec::quick(Preset::Mlp, 5);
        let honest_commit = mk(spec, Fault::None, "h").final_commit();
        let mut parties = vec![
            Party::Refuse(Refusenik),
            Party::Node(mk(spec, Fault::None, "h")),
        ];
        let r = run_tournament(spec, &mut parties);
        assert_eq!(r.accepted, honest_commit);
        assert_eq!(r.winner, 1);
        assert_eq!(r.disputes, 0, "one real claim, nothing to dispute");
        assert_eq!(r.eliminated.len(), 1);
        assert_eq!(r.eliminated[0].0, 0);
    }
}
