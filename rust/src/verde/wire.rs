//! The protocol's real wire format: a canonical length-prefixed binary
//! codec for every message the referee, coordinator, and trainers exchange,
//! plus frame I/O for stream transports ([`crate::net::tcp`]).
//!
//! Design rules:
//!
//! * **Canonical** — one valid encoding per value; `decode(encode(x))`
//!   reproduces `x` bit-exactly and `encode(decode(b)) == b` for any
//!   accepted `b`. Communication accounting can therefore use
//!   [`Request::wire_size`] / [`Response::wire_size`], which are defined to
//!   equal `encode().len()` exactly (enforced by tests here and by the
//!   property suite in `rust/tests/wire_props.rs`).
//! * **Total** — decoding never panics on hostile bytes: truncated,
//!   corrupted, or oversized input returns a [`WireError`]. Trainers are
//!   untrusted; the referee parses their bytes with this codec.
//! * **Simple** — fixed-width little-endian integers, 32-byte raw digests,
//!   `u64` element counts before every variable-length sequence. No
//!   varints, no compression, no reflection.
//!
//! Frame format on stream transports: `u32 LE payload length ‖ u64 LE
//! correlation tag ‖ payload`, with payloads capped at [`MAX_FRAME`] bytes.
//! The tag is chosen by the requester and echoed verbatim on the response
//! frame, so many requests can be in flight per connection and completions
//! are matched by tag, not arrival order ([`crate::net::mux`]).

use std::fmt;
use std::io::{self, Read, Write};

use crate::graph::autodiff::Optimizer;
use crate::graph::executor::AugmentedCGNode;
use crate::hash::merkle::MerkleProof;
use crate::hash::Hash;
use crate::model::Preset;
use crate::tensor::Tensor;
use crate::train::JobSpec;

use crate::obs::{HistogramSnapshot, Snapshot};

use super::protocol::{
    BackendRequirement, InputProvenance, JobPolicy, RemoteStatus, Request, Response,
};

/// Maximum frame payload a peer may send (256 MiB) — bounds allocation on
/// hostile length prefixes while leaving room for full-tensor payloads.
pub const MAX_FRAME: usize = 1 << 28;

/// Bytes of framing overhead per message on stream transports: a `u32 LE`
/// payload length followed by a `u64 LE` correlation tag.
pub const FRAME_HEADER_LEN: usize = 12;

/// Maximum tensor elements accepted by the decoder (payload ≤ [`MAX_FRAME`]).
const MAX_TENSOR_ELEMS: usize = MAX_FRAME / 4;

/// Maximum tensor rank accepted by the decoder.
const MAX_RANK: usize = 8;

/// Checkpoint-payload chunk size for state-transfer messages (1 MiB):
/// serialized checkpoint states larger than this cross the wire as a
/// sequence of `FetchCheckpoint`/`Checkpoint` (or `SeedCheckpoint`)
/// exchanges, keeping every frame small enough to interleave with other
/// multiplexed traffic.
pub const CHECKPOINT_CHUNK: usize = 1 << 20;

/// Maximum chunk count a checkpoint-transfer message may declare. This is
/// an anti-DoS ceiling on what the codec will even parse
/// (`MAX_CHECKPOINT_CHUNKS × CHECKPOINT_CHUNK` = 64 GiB), **not** the
/// operational size limit: receivers enforce their own configured byte
/// budgets (`ServiceConfig::max_checkpoint_bytes` coordinator-side, the
/// worker host's seed budget worker-side) and answer oversize transfers
/// with a reported `Refuse` instead of a wire tear.
pub const MAX_CHECKPOINT_CHUNKS: u64 = 1 << 16;

// Message tags. Requests and responses share one tag space so a stray
// response can never parse as a request (and vice versa).
const REQ_FINAL_COMMIT: u8 = 0x01;
const REQ_CHECKPOINT_HASHES: u8 = 0x02;
const REQ_NODE_HASH_SEQ: u8 = 0x03;
const REQ_OPEN_NODE: u8 = 0x04;
const REQ_INPUT_PROOF: u8 = 0x05;
const REQ_INPUT_TENSOR: u8 = 0x06;
const REQ_SHUTDOWN: u8 = 0x07;
const REQ_TRAIN: u8 = 0x08;
const REQ_PING: u8 = 0x09;
const REQ_SUBMIT: u8 = 0x0A;
const REQ_STATUS: u8 = 0x0B;
const REQ_CANCEL: u8 = 0x0C;
const REQ_FETCH_CHECKPOINT: u8 = 0x0D;
const REQ_SEED_CHECKPOINT: u8 = 0x0E;
const REQ_STATS: u8 = 0x0F;
const REQ_COMMIT_ROOT: u8 = 0x10;
const REQ_FETCH_MANIFEST: u8 = 0x11;

const RESP_COMMIT: u8 = 0x81;
const RESP_HASHES: u8 = 0x82;
const RESP_NODE_SEQ: u8 = 0x83;
const RESP_NODE: u8 = 0x84;
const RESP_PROOF: u8 = 0x85;
const RESP_TENSOR: u8 = 0x86;
const RESP_REFUSE: u8 = 0x87;
const RESP_BYE: u8 = 0x88;
const RESP_PONG: u8 = 0x89;
const RESP_SUBMITTED: u8 = 0x8A;
const RESP_STATUS: u8 = 0x8B;
const RESP_CANCELLED: u8 = 0x8C;
const RESP_CHECKPOINT: u8 = 0x8D;
const RESP_STATS: u8 = 0x8E;
const RESP_MANIFEST: u8 = 0x8F;

const PROV_GENESIS: u8 = 0x01;
const PROV_PREV_STEP: u8 = 0x02;

const OPT_ADAM: u8 = 0x01;
const OPT_SGD: u8 = 0x02;

const BACKEND_ANY: u8 = 0x01;
const BACKEND_REP_ONLY: u8 = 0x02;

const STATUS_UNKNOWN: u8 = 0x01;
const STATUS_QUEUED: u8 = 0x02;
const STATUS_RUNNING: u8 = 0x03;
const STATUS_DONE: u8 = 0x04;

/// Everything that can go wrong decoding hostile bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// The buffer ended before the structure did.
    Truncated { context: &'static str, need: usize, have: usize },
    /// An unknown discriminant byte.
    BadTag { context: &'static str, tag: u8 },
    /// The structure ended before the buffer did (non-canonical encoding).
    Trailing { extra: usize },
    /// A field value violates an invariant (bad UTF-8, unknown preset,
    /// absurd rank/length, ...).
    Malformed { context: &'static str },
    /// A frame length prefix exceeded [`MAX_FRAME`].
    FrameTooLarge { len: usize },
    /// Underlying transport failure while framing.
    Io(String),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated { context, need, have } => {
                write!(f, "truncated at {context}: need {need} bytes, have {have}")
            }
            WireError::BadTag { context, tag } => write!(f, "bad tag {tag:#04x} at {context}"),
            WireError::Trailing { extra } => write!(f, "{extra} trailing bytes after message"),
            WireError::Malformed { context } => write!(f, "malformed field: {context}"),
            WireError::FrameTooLarge { len } => {
                write!(f, "frame of {len} bytes exceeds cap {MAX_FRAME}")
            }
            WireError::Io(e) => write!(f, "transport: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

// ---------------------------------------------------------------------------
// primitive writers
// ---------------------------------------------------------------------------

pub(crate) fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_hash(out: &mut Vec<u8>, h: &Hash) {
    out.extend_from_slice(&h.0);
}

fn put_hashes(out: &mut Vec<u8>, hs: &[Hash]) {
    put_u64(out, hs.len() as u64);
    for h in hs {
        put_hash(out, h);
    }
}

pub(crate) fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u64(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

// ---------------------------------------------------------------------------
// primitive reader
// ---------------------------------------------------------------------------

/// Cursor over an untrusted byte buffer; every accessor is total.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, context: &'static str) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated { context, need: n, have: self.remaining() });
        }
        let slice = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(slice)
    }

    pub fn u8(&mut self, context: &'static str) -> Result<u8, WireError> {
        Ok(self.take(1, context)?[0])
    }

    pub fn u64(&mut self, context: &'static str) -> Result<u64, WireError> {
        let b = self.take(8, context)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8 bytes")))
    }

    pub fn f32(&mut self, context: &'static str) -> Result<f32, WireError> {
        let b = self.take(4, context)?;
        Ok(f32::from_le_bytes(b.try_into().expect("4 bytes")))
    }

    pub fn usize(&mut self, context: &'static str) -> Result<usize, WireError> {
        let v = self.u64(context)?;
        usize::try_from(v).map_err(|_| WireError::Malformed { context })
    }

    pub fn hash(&mut self, context: &'static str) -> Result<Hash, WireError> {
        let b = self.take(32, context)?;
        Ok(Hash(b.try_into().expect("32 bytes")))
    }

    pub fn hashes(&mut self, context: &'static str) -> Result<Vec<Hash>, WireError> {
        let n = self.usize(context)?;
        if n > self.remaining() / 32 {
            return Err(WireError::Truncated {
                context,
                need: n.saturating_mul(32),
                have: self.remaining(),
            });
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.hash(context)?);
        }
        Ok(out)
    }

    pub fn str(&mut self, context: &'static str) -> Result<String, WireError> {
        let n = self.usize(context)?;
        let bytes = self.take(n, context)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| WireError::Malformed { context })
    }

    /// Assert full consumption — rejects non-canonical padded encodings.
    pub fn finish(self) -> Result<(), WireError> {
        if self.remaining() != 0 {
            return Err(WireError::Trailing { extra: self.remaining() });
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// composite codecs
// ---------------------------------------------------------------------------

pub(crate) fn put_tensor(out: &mut Vec<u8>, t: &Tensor) {
    put_u64(out, t.rank() as u64);
    for &d in t.shape() {
        put_u64(out, d as u64);
    }
    out.extend_from_slice(&t.to_le_bytes());
}

pub(crate) fn read_tensor(r: &mut Reader<'_>) -> Result<Tensor, WireError> {
    let rank = r.usize("tensor.rank")?;
    if rank > MAX_RANK {
        return Err(WireError::Malformed { context: "tensor.rank" });
    }
    let mut shape = Vec::with_capacity(rank);
    let mut numel: usize = 1;
    for _ in 0..rank {
        let d = r.usize("tensor.dim")?;
        numel = numel
            .checked_mul(d)
            .filter(|&n| n <= MAX_TENSOR_ELEMS)
            .ok_or(WireError::Malformed { context: "tensor.numel" })?;
        shape.push(d);
    }
    let bytes = r.take(numel * 4, "tensor.data")?;
    Ok(Tensor::from_le_bytes(shape, bytes))
}

pub fn tensor_wire_len(t: &Tensor) -> usize {
    8 + 8 * t.rank() + t.byte_len()
}

fn put_proof(out: &mut Vec<u8>, p: &MerkleProof) {
    put_u64(out, p.index as u64);
    put_hashes(out, &p.siblings);
}

fn read_proof(r: &mut Reader<'_>) -> Result<MerkleProof, WireError> {
    let index = r.usize("proof.index")?;
    let siblings = r.hashes("proof.siblings")?;
    Ok(MerkleProof { index, siblings })
}

fn put_node(out: &mut Vec<u8>, n: &AugmentedCGNode) {
    put_u64(out, n.id as u64);
    put_hash(out, &n.structure);
    put_hashes(out, &n.input_hashes);
    put_hashes(out, &n.output_hashes);
}

fn read_node(r: &mut Reader<'_>) -> Result<AugmentedCGNode, WireError> {
    let id = r.usize("node.id")?;
    let structure = r.hash("node.structure")?;
    let input_hashes = r.hashes("node.inputs")?;
    let output_hashes = r.hashes("node.outputs")?;
    Ok(AugmentedCGNode { id, structure, input_hashes, output_hashes })
}

fn put_provenance(out: &mut Vec<u8>, p: &InputProvenance) {
    match p {
        InputProvenance::Genesis { leaf, proof } => {
            out.push(PROV_GENESIS);
            put_hash(out, leaf);
            put_proof(out, proof);
        }
        InputProvenance::PrevStep { node, out_idx, proof } => {
            out.push(PROV_PREV_STEP);
            put_node(out, node);
            put_u64(out, *out_idx as u64);
            put_proof(out, proof);
        }
    }
}

fn read_provenance(r: &mut Reader<'_>) -> Result<InputProvenance, WireError> {
    match r.u8("provenance.tag")? {
        PROV_GENESIS => {
            let leaf = r.hash("provenance.leaf")?;
            let proof = read_proof(r)?;
            Ok(InputProvenance::Genesis { leaf, proof })
        }
        PROV_PREV_STEP => {
            let node = read_node(r)?;
            let out_idx = r.usize("provenance.out_idx")?;
            let proof = read_proof(r)?;
            Ok(InputProvenance::PrevStep { node, out_idx, proof })
        }
        tag => Err(WireError::BadTag { context: "provenance", tag }),
    }
}

/// Encoded size of a provenance value including its discriminant byte.
pub fn provenance_wire_len(p: &InputProvenance) -> usize {
    match p {
        InputProvenance::Genesis { proof, .. } => 1 + 32 + proof.byte_len(),
        InputProvenance::PrevStep { node, proof, .. } => 1 + node.byte_len() + 8 + proof.byte_len(),
    }
}

fn put_optimizer(out: &mut Vec<u8>, o: &Optimizer) {
    match o {
        Optimizer::Adam { lr, beta1, beta2, eps } => {
            out.push(OPT_ADAM);
            put_f32(out, *lr);
            put_f32(out, *beta1);
            put_f32(out, *beta2);
            put_f32(out, *eps);
        }
        Optimizer::Sgd { lr } => {
            out.push(OPT_SGD);
            put_f32(out, *lr);
        }
    }
}

fn read_optimizer(r: &mut Reader<'_>) -> Result<Optimizer, WireError> {
    match r.u8("optimizer.tag")? {
        OPT_ADAM => Ok(Optimizer::Adam {
            lr: r.f32("optimizer.lr")?,
            beta1: r.f32("optimizer.beta1")?,
            beta2: r.f32("optimizer.beta2")?,
            eps: r.f32("optimizer.eps")?,
        }),
        OPT_SGD => Ok(Optimizer::Sgd { lr: r.f32("optimizer.lr")? }),
        tag => Err(WireError::BadTag { context: "optimizer", tag }),
    }
}

fn optimizer_wire_len(o: &Optimizer) -> usize {
    match o {
        Optimizer::Adam { .. } => 1 + 16,
        Optimizer::Sgd { .. } => 1 + 4,
    }
}

pub(crate) fn put_spec(out: &mut Vec<u8>, s: &JobSpec) {
    put_str(out, s.preset.name());
    put_u64(out, s.batch as u64);
    put_u64(out, s.seq as u64);
    put_u64(out, s.steps);
    put_optimizer(out, &s.optimizer);
    put_u64(out, s.weight_seed);
    put_u64(out, s.data_seed);
    put_u64(out, s.checkpoint_n);
}

pub(crate) fn read_spec(r: &mut Reader<'_>) -> Result<JobSpec, WireError> {
    let name = r.str("spec.preset")?;
    let preset = Preset::parse(&name).ok_or(WireError::Malformed { context: "spec.preset" })?;
    let batch = r.usize("spec.batch")?;
    let seq = r.usize("spec.seq")?;
    if batch == 0 || batch > 1 << 20 || seq == 0 || seq > 1 << 20 {
        return Err(WireError::Malformed { context: "spec.shape" });
    }
    let steps = r.u64("spec.steps")?;
    if steps == 0 {
        // A zero-step job would panic the checkpoint scheduler — reject at
        // the trust boundary, not inside the worker.
        return Err(WireError::Malformed { context: "spec.steps" });
    }
    let optimizer = read_optimizer(r)?;
    let weight_seed = r.u64("spec.weight_seed")?;
    let data_seed = r.u64("spec.data_seed")?;
    let checkpoint_n = r.u64("spec.checkpoint_n")?;
    Ok(JobSpec { preset, batch, seq, steps, optimizer, weight_seed, data_seed, checkpoint_n })
}

pub(crate) fn spec_wire_len(s: &JobSpec) -> usize {
    (8 + s.preset.name().len()) + 8 * 3 + optimizer_wire_len(&s.optimizer) + 8 * 3
}

/// Presence byte for optional fields: constrained to `{0, 1}` so every
/// optional keeps a single canonical encoding.
pub(crate) fn read_presence(r: &mut Reader<'_>, context: &'static str) -> Result<bool, WireError> {
    match r.u8(context)? {
        0 => Ok(false),
        1 => Ok(true),
        tag => Err(WireError::BadTag { context, tag }),
    }
}

/// Wire bound on `policy.k` and `policy.segments`. The encoder clamps to
/// it (a locally oversized policy must never produce an undecodable
/// message — it would tear the connection down instead of degrading) and
/// the decoder rejects anything beyond it from untrusted peers.
pub const POLICY_FIELD_MAX: u64 = 1 << 20;

pub(crate) fn put_policy(out: &mut Vec<u8>, p: &JobPolicy) {
    put_u64(out, (p.k as u64).min(POLICY_FIELD_MAX));
    match p.deadline {
        None => out.push(0),
        Some(d) => {
            out.push(1);
            put_u64(out, d.as_millis() as u64);
        }
    }
    put_u64(out, p.priority as u64);
    out.push(match p.backend {
        BackendRequirement::Any => BACKEND_ANY,
        BackendRequirement::ReproducibleOnly => BACKEND_REP_ONLY,
    });
    put_u64(out, p.segments.clamp(1, POLICY_FIELD_MAX));
    match p.max_requeues {
        None => out.push(0),
        Some(n) => {
            out.push(1);
            put_u64(out, u64::from(n));
        }
    }
    out.push(u8::from(p.transfer));
    // NaN compares false against everything, so `clamp` would pass it
    // through — map it to 0.0 (audits off) explicitly, then clamp. The
    // decoder's range check makes any other bit pattern non-canonical.
    let rate = if p.audit_rate.is_nan() { 0.0 } else { p.audit_rate.clamp(0.0, 1.0) };
    put_f32(out, rate);
}

pub(crate) fn read_policy(r: &mut Reader<'_>) -> Result<JobPolicy, WireError> {
    let k = r.usize("policy.k")?;
    if k as u64 > POLICY_FIELD_MAX {
        return Err(WireError::Malformed { context: "policy.k" });
    }
    let deadline = if read_presence(r, "policy.deadline")? {
        Some(std::time::Duration::from_millis(r.u64("policy.deadline_ms")?))
    } else {
        None
    };
    let priority = r.u64("policy.priority")? as i64;
    let backend = match r.u8("policy.backend")? {
        BACKEND_ANY => BackendRequirement::Any,
        BACKEND_REP_ONLY => BackendRequirement::ReproducibleOnly,
        tag => return Err(WireError::BadTag { context: "policy.backend", tag }),
    };
    let segments = r.u64("policy.segments")?;
    if segments == 0 || segments > POLICY_FIELD_MAX {
        // Zero segments is meaningless and absurd counts would let a
        // hostile client inflate the scheduler's queue for free.
        return Err(WireError::Malformed { context: "policy.segments" });
    }
    let max_requeues = if read_presence(r, "policy.max_requeues")? {
        let v = r.u64("policy.max_requeues")?;
        Some(u32::try_from(v).map_err(|_| WireError::Malformed { context: "policy.max_requeues" })?)
    } else {
        None
    };
    let transfer = read_presence(r, "policy.transfer")?;
    let audit_rate = r.f32("policy.audit_rate")?;
    // Rejects NaN too: NaN fails every range comparison.
    if !(0.0..=1.0).contains(&audit_rate) {
        return Err(WireError::Malformed { context: "policy.audit_rate" });
    }
    Ok(JobPolicy { k, deadline, priority, backend, segments, max_requeues, transfer, audit_rate })
}

pub(crate) fn policy_wire_len(p: &JobPolicy) -> usize {
    8 + (1 + if p.deadline.is_some() { 8 } else { 0 })
        + 8
        + 1
        + 8
        + (1 + if p.max_requeues.is_some() { 8 } else { 0 })
        + 1
        + 4
}

/// Write the shared `(total_chunks, chunk, payload)` tail of a
/// checkpoint-transfer message.
fn put_chunk(out: &mut Vec<u8>, total_chunks: u64, chunk: u64, payload: &[u8]) {
    put_u64(out, total_chunks);
    put_u64(out, chunk);
    put_u64(out, payload.len() as u64);
    out.extend_from_slice(payload);
}

/// Read and validate a checkpoint-transfer chunk tail: chunk counts are
/// clamped to [`MAX_CHECKPOINT_CHUNKS`] (bounding hostile reassembly
/// buffers) and payloads to `1..=CHECKPOINT_CHUNK` bytes.
fn read_chunk(r: &mut Reader<'_>) -> Result<(u64, u64, Vec<u8>), WireError> {
    let total_chunks = r.u64("chunk.total")?;
    if total_chunks == 0 || total_chunks > MAX_CHECKPOINT_CHUNKS {
        return Err(WireError::Malformed { context: "chunk.total" });
    }
    let chunk = r.u64("chunk.index")?;
    if chunk >= total_chunks {
        return Err(WireError::Malformed { context: "chunk.index" });
    }
    let len = r.usize("chunk.len")?;
    if len == 0 || len > CHECKPOINT_CHUNK {
        return Err(WireError::Malformed { context: "chunk.len" });
    }
    let payload = r.take(len, "chunk.payload")?.to_vec();
    Ok((total_chunks, chunk, payload))
}

fn chunk_wire_len(payload: &[u8]) -> usize {
    8 + 8 + 8 + payload.len()
}

fn put_status(out: &mut Vec<u8>, s: &RemoteStatus) {
    match s {
        RemoteStatus::Unknown => out.push(STATUS_UNKNOWN),
        RemoteStatus::Queued => out.push(STATUS_QUEUED),
        RemoteStatus::Running { segments_done, segments_total } => {
            out.push(STATUS_RUNNING);
            put_u64(out, *segments_done);
            put_u64(out, *segments_total);
        }
        RemoteStatus::Done { accepted, cancelled, disputes, eliminated } => {
            out.push(STATUS_DONE);
            match accepted {
                None => out.push(0),
                Some(h) => {
                    out.push(1);
                    put_hash(out, h);
                }
            }
            out.push(u8::from(*cancelled));
            put_u64(out, *disputes);
            put_u64(out, *eliminated);
        }
    }
}

fn read_status(r: &mut Reader<'_>) -> Result<RemoteStatus, WireError> {
    match r.u8("status.tag")? {
        STATUS_UNKNOWN => Ok(RemoteStatus::Unknown),
        STATUS_QUEUED => Ok(RemoteStatus::Queued),
        STATUS_RUNNING => Ok(RemoteStatus::Running {
            segments_done: r.u64("status.segments_done")?,
            segments_total: r.u64("status.segments_total")?,
        }),
        STATUS_DONE => {
            let accepted = if read_presence(r, "status.accepted")? {
                Some(r.hash("status.accepted")?)
            } else {
                None
            };
            let cancelled = read_presence(r, "status.cancelled")?;
            let disputes = r.u64("status.disputes")?;
            let eliminated = r.u64("status.eliminated")?;
            Ok(RemoteStatus::Done { accepted, cancelled, disputes, eliminated })
        }
        tag => Err(WireError::BadTag { context: "status", tag }),
    }
}

/// Encoded size of a status value including its discriminant byte.
pub fn status_wire_len(s: &RemoteStatus) -> usize {
    1 + match s {
        RemoteStatus::Unknown | RemoteStatus::Queued => 0,
        RemoteStatus::Running { .. } => 16,
        RemoteStatus::Done { accepted, .. } => {
            (1 + if accepted.is_some() { 32 } else { 0 }) + 1 + 8 + 8
        }
    }
}

/// Maximum histogram bucket-bound count a stats snapshot may declare per
/// histogram. The in-tree catalogs top out at a dozen buckets; anything
/// past this is a hostile or corrupt snapshot, not telemetry.
pub const MAX_HISTOGRAM_BOUNDS: usize = 1 << 16;

fn put_stat_pairs(out: &mut Vec<u8>, pairs: &[(String, u64)]) {
    put_u64(out, pairs.len() as u64);
    for (name, value) in pairs {
        put_str(out, name);
        put_u64(out, *value);
    }
}

/// Read a `(name, value)` section of a stats snapshot. Each entry costs at
/// least 16 bytes on the wire, which bounds the allocation a hostile count
/// can force before the buffer runs dry.
fn read_stat_pairs(
    r: &mut Reader<'_>,
    context: &'static str,
) -> Result<Vec<(String, u64)>, WireError> {
    let n = r.usize(context)?;
    if n > r.remaining() / 16 {
        return Err(WireError::Truncated {
            context,
            need: n.saturating_mul(16),
            have: r.remaining(),
        });
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let name = r.str(context)?;
        let value = r.u64(context)?;
        out.push((name, value));
    }
    Ok(out)
}

fn put_snapshot(out: &mut Vec<u8>, s: &Snapshot) {
    put_u64(out, s.version);
    put_stat_pairs(out, &s.counters);
    put_stat_pairs(out, &s.gauges);
    put_u64(out, s.histograms.len() as u64);
    for (name, h) in &s.histograms {
        put_str(out, name);
        put_u64(out, h.bounds.len() as u64);
        for &b in &h.bounds {
            put_u64(out, b);
        }
        // Exactly bounds+1 buckets go on the wire regardless of the local
        // vector's length, so every snapshot value has one decodable
        // encoding (registry-produced snapshots always match already).
        for i in 0..=h.bounds.len() {
            put_u64(out, h.buckets.get(i).copied().unwrap_or(0));
        }
        put_u64(out, h.sum);
        put_u64(out, h.count);
    }
}

fn read_snapshot(r: &mut Reader<'_>) -> Result<Snapshot, WireError> {
    let version = r.u64("stats.version")?;
    let counters = read_stat_pairs(r, "stats.counters")?;
    let gauges = read_stat_pairs(r, "stats.gauges")?;
    let n_hist = r.usize("stats.histograms")?;
    // Every histogram entry costs ≥ 8 (name len) + 8 (bound count) +
    // 8 (overflow bucket) + 16 (sum, count) = 40 bytes.
    if n_hist > r.remaining() / 40 {
        return Err(WireError::Truncated {
            context: "stats.histograms",
            need: n_hist.saturating_mul(40),
            have: r.remaining(),
        });
    }
    let mut histograms = Vec::with_capacity(n_hist);
    for _ in 0..n_hist {
        let name = r.str("stats.histogram.name")?;
        let n_bounds = r.usize("stats.histogram.bounds")?;
        if n_bounds > MAX_HISTOGRAM_BOUNDS || n_bounds > r.remaining() / 8 {
            return Err(WireError::Malformed { context: "stats.histogram.bounds" });
        }
        let mut bounds = Vec::with_capacity(n_bounds);
        for _ in 0..n_bounds {
            bounds.push(r.u64("stats.histogram.bound")?);
        }
        if n_bounds + 1 > r.remaining() / 8 {
            return Err(WireError::Truncated {
                context: "stats.histogram.buckets",
                need: (n_bounds + 1).saturating_mul(8),
                have: r.remaining(),
            });
        }
        let mut buckets = Vec::with_capacity(n_bounds + 1);
        for _ in 0..=n_bounds {
            buckets.push(r.u64("stats.histogram.bucket")?);
        }
        let sum = r.u64("stats.histogram.sum")?;
        let count = r.u64("stats.histogram.count")?;
        histograms.push((name, HistogramSnapshot { bounds, buckets, sum, count }));
    }
    Ok(Snapshot { version, counters, gauges, histograms })
}

/// Exact encoded size of a stats snapshot.
pub fn snapshot_wire_len(s: &Snapshot) -> usize {
    let pairs = |ps: &[(String, u64)]| {
        8 + ps.iter().map(|(k, _)| 8 + k.len() + 8).sum::<usize>()
    };
    let hists: usize = s
        .histograms
        .iter()
        .map(|(k, h)| 8 + k.len() + 8 + 8 * h.bounds.len() + 8 * (h.bounds.len() + 1) + 16)
        .sum();
    8 + pairs(&s.counters) + pairs(&s.gauges) + 8 + hists
}

// ---------------------------------------------------------------------------
// messages
// ---------------------------------------------------------------------------

impl Request {
    /// Canonical wire encoding (tag ‖ payload, no frame prefix).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_size());
        match self {
            Request::FinalCommit => out.push(REQ_FINAL_COMMIT),
            Request::CheckpointHashes { boundaries } => {
                out.push(REQ_CHECKPOINT_HASHES);
                put_u64(&mut out, boundaries.len() as u64);
                for &b in boundaries {
                    put_u64(&mut out, b);
                }
            }
            Request::NodeHashSeq { step } => {
                out.push(REQ_NODE_HASH_SEQ);
                put_u64(&mut out, *step);
            }
            Request::OpenNode { step, idx } => {
                out.push(REQ_OPEN_NODE);
                put_u64(&mut out, *step);
                put_u64(&mut out, *idx as u64);
            }
            Request::InputProof { step, node_idx } => {
                out.push(REQ_INPUT_PROOF);
                put_u64(&mut out, *step);
                put_u64(&mut out, *node_idx as u64);
            }
            Request::InputTensor { step, node_idx, input_idx } => {
                out.push(REQ_INPUT_TENSOR);
                put_u64(&mut out, *step);
                put_u64(&mut out, *node_idx as u64);
                put_u64(&mut out, *input_idx as u64);
            }
            Request::Shutdown => out.push(REQ_SHUTDOWN),
            Request::Train { spec } => {
                out.push(REQ_TRAIN);
                put_spec(&mut out, spec);
            }
            Request::Ping => out.push(REQ_PING),
            Request::Submit { spec, policy } => {
                out.push(REQ_SUBMIT);
                put_spec(&mut out, spec);
                put_policy(&mut out, policy);
            }
            Request::Status { job_id } => {
                out.push(REQ_STATUS);
                put_u64(&mut out, *job_id);
            }
            Request::Cancel { job_id } => {
                out.push(REQ_CANCEL);
                put_u64(&mut out, *job_id);
            }
            Request::FetchCheckpoint { step, chunk } => {
                out.push(REQ_FETCH_CHECKPOINT);
                put_u64(&mut out, *step);
                put_u64(&mut out, *chunk);
            }
            Request::SeedCheckpoint { spec, start, root, total_chunks, chunk, payload } => {
                out.push(REQ_SEED_CHECKPOINT);
                put_spec(&mut out, spec);
                put_u64(&mut out, *start);
                put_hash(&mut out, root);
                put_chunk(&mut out, *total_chunks, *chunk, payload);
            }
            Request::CommitRoot { step } => {
                out.push(REQ_COMMIT_ROOT);
                put_u64(&mut out, *step);
            }
            Request::FetchManifest { step } => {
                out.push(REQ_FETCH_MANIFEST);
                put_u64(&mut out, *step);
            }
            Request::Stats => out.push(REQ_STATS),
        }
        debug_assert_eq!(out.len(), self.wire_size(), "wire_size drifted from encoder");
        out
    }

    /// Decode a full message; rejects trailing bytes.
    pub fn decode(buf: &[u8]) -> Result<Request, WireError> {
        let mut r = Reader::new(buf);
        let req = match r.u8("request.tag")? {
            REQ_FINAL_COMMIT => Request::FinalCommit,
            REQ_CHECKPOINT_HASHES => {
                let n = r.usize("request.boundaries")?;
                if n > r.remaining() / 8 {
                    return Err(WireError::Truncated {
                        context: "request.boundaries",
                        need: n.saturating_mul(8),
                        have: r.remaining(),
                    });
                }
                let mut boundaries = Vec::with_capacity(n);
                for _ in 0..n {
                    boundaries.push(r.u64("request.boundary")?);
                }
                Request::CheckpointHashes { boundaries }
            }
            REQ_NODE_HASH_SEQ => Request::NodeHashSeq { step: r.u64("request.step")? },
            REQ_OPEN_NODE => Request::OpenNode {
                step: r.u64("request.step")?,
                idx: r.usize("request.idx")?,
            },
            REQ_INPUT_PROOF => Request::InputProof {
                step: r.u64("request.step")?,
                node_idx: r.usize("request.node_idx")?,
            },
            REQ_INPUT_TENSOR => Request::InputTensor {
                step: r.u64("request.step")?,
                node_idx: r.usize("request.node_idx")?,
                input_idx: r.usize("request.input_idx")?,
            },
            REQ_SHUTDOWN => Request::Shutdown,
            REQ_TRAIN => Request::Train { spec: read_spec(&mut r)? },
            REQ_PING => Request::Ping,
            REQ_SUBMIT => Request::Submit {
                spec: read_spec(&mut r)?,
                policy: read_policy(&mut r)?,
            },
            REQ_STATUS => Request::Status { job_id: r.u64("request.job_id")? },
            REQ_CANCEL => Request::Cancel { job_id: r.u64("request.job_id")? },
            REQ_FETCH_CHECKPOINT => {
                let step = r.u64("request.step")?;
                let chunk = r.u64("request.chunk")?;
                if chunk >= MAX_CHECKPOINT_CHUNKS {
                    return Err(WireError::Malformed { context: "request.chunk" });
                }
                Request::FetchCheckpoint { step, chunk }
            }
            REQ_SEED_CHECKPOINT => {
                let spec = read_spec(&mut r)?;
                let start = r.u64("seed.start")?;
                if start == 0 || start >= spec.steps {
                    // The seed boundary must sit strictly inside the job:
                    // start == 0 is just a fresh job and start >= steps
                    // leaves nothing to train.
                    return Err(WireError::Malformed { context: "seed.start" });
                }
                let root = r.hash("seed.root")?;
                let (total_chunks, chunk, payload) = read_chunk(&mut r)?;
                Request::SeedCheckpoint { spec, start, root, total_chunks, chunk, payload }
            }
            REQ_COMMIT_ROOT => Request::CommitRoot { step: r.u64("request.step")? },
            REQ_FETCH_MANIFEST => Request::FetchManifest { step: r.u64("request.step")? },
            REQ_STATS => Request::Stats,
            tag => return Err(WireError::BadTag { context: "request", tag }),
        };
        r.finish()?;
        Ok(req)
    }
}

/// Exact encoded length of a request — the single source of truth for
/// [`Request::wire_size`].
pub fn request_wire_len(req: &Request) -> usize {
    1 + match req {
        Request::FinalCommit | Request::Shutdown | Request::Ping | Request::Stats => 0,
        Request::CheckpointHashes { boundaries } => 8 + 8 * boundaries.len(),
        Request::NodeHashSeq { .. } | Request::CommitRoot { .. } | Request::FetchManifest { .. } => {
            8
        }
        Request::OpenNode { .. } | Request::InputProof { .. } => 16,
        Request::InputTensor { .. } => 24,
        Request::Train { spec } => spec_wire_len(spec),
        Request::Submit { spec, policy } => spec_wire_len(spec) + policy_wire_len(policy),
        Request::Status { .. } | Request::Cancel { .. } => 8,
        Request::FetchCheckpoint { .. } => 16,
        Request::SeedCheckpoint { spec, payload, .. } => {
            spec_wire_len(spec) + 8 + 32 + chunk_wire_len(payload)
        }
    }
}

impl Response {
    /// Canonical wire encoding (tag ‖ payload, no frame prefix).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_size());
        match self {
            Response::Commit(h) => {
                out.push(RESP_COMMIT);
                put_hash(&mut out, h);
            }
            Response::Hashes(hs) => {
                out.push(RESP_HASHES);
                put_hashes(&mut out, hs);
            }
            Response::NodeSeq(hs) => {
                out.push(RESP_NODE_SEQ);
                put_hashes(&mut out, hs);
            }
            Response::Node(n) => {
                out.push(RESP_NODE);
                put_node(&mut out, n);
            }
            Response::Proof(p) => {
                out.push(RESP_PROOF);
                put_provenance(&mut out, p);
            }
            Response::TensorPayload(t) => {
                out.push(RESP_TENSOR);
                put_tensor(&mut out, t);
            }
            Response::Refuse(s) => {
                out.push(RESP_REFUSE);
                put_str(&mut out, s);
            }
            Response::Bye => out.push(RESP_BYE),
            Response::Pong => out.push(RESP_PONG),
            Response::Submitted { job_id } => {
                out.push(RESP_SUBMITTED);
                put_u64(&mut out, *job_id);
            }
            Response::Status(s) => {
                out.push(RESP_STATUS);
                put_status(&mut out, s);
            }
            Response::Cancelled(ok) => {
                out.push(RESP_CANCELLED);
                out.push(u8::from(*ok));
            }
            Response::Checkpoint { step, root, total_chunks, chunk, payload } => {
                out.push(RESP_CHECKPOINT);
                put_u64(&mut out, *step);
                put_hash(&mut out, root);
                put_chunk(&mut out, *total_chunks, *chunk, payload);
            }
            Response::Manifest { step, root, total_len, chunks } => {
                out.push(RESP_MANIFEST);
                put_u64(&mut out, *step);
                put_hash(&mut out, root);
                put_u64(&mut out, *total_len);
                put_hashes(&mut out, chunks);
            }
            Response::Stats(s) => {
                out.push(RESP_STATS);
                put_snapshot(&mut out, s);
            }
        }
        debug_assert_eq!(out.len(), self.wire_size(), "wire_size drifted from encoder");
        out
    }

    /// Decode a full message; rejects trailing bytes.
    pub fn decode(buf: &[u8]) -> Result<Response, WireError> {
        let mut r = Reader::new(buf);
        let resp = match r.u8("response.tag")? {
            RESP_COMMIT => Response::Commit(r.hash("response.commit")?),
            RESP_HASHES => Response::Hashes(r.hashes("response.hashes")?),
            RESP_NODE_SEQ => Response::NodeSeq(r.hashes("response.node_seq")?),
            RESP_NODE => Response::Node(read_node(&mut r)?),
            RESP_PROOF => Response::Proof(read_provenance(&mut r)?),
            RESP_TENSOR => Response::TensorPayload(read_tensor(&mut r)?),
            RESP_REFUSE => Response::Refuse(r.str("response.refuse")?),
            RESP_BYE => Response::Bye,
            RESP_PONG => Response::Pong,
            RESP_SUBMITTED => Response::Submitted { job_id: r.u64("response.job_id")? },
            RESP_STATUS => Response::Status(read_status(&mut r)?),
            RESP_CANCELLED => Response::Cancelled(read_presence(&mut r, "response.cancelled")?),
            RESP_CHECKPOINT => {
                let step = r.u64("checkpoint.step")?;
                let root = r.hash("checkpoint.root")?;
                let (total_chunks, chunk, payload) = read_chunk(&mut r)?;
                Response::Checkpoint { step, root, total_chunks, chunk, payload }
            }
            RESP_MANIFEST => {
                let step = r.u64("manifest.step")?;
                let root = r.hash("manifest.root")?;
                let total_len = r.u64("manifest.total_len")?;
                let chunks = r.hashes("manifest.chunks")?;
                if chunks.is_empty() || chunks.len() as u64 > MAX_CHECKPOINT_CHUNKS {
                    return Err(WireError::Malformed { context: "manifest.chunks" });
                }
                // The chunk list must describe exactly `total_len` bytes of
                // `CHECKPOINT_CHUNK`-sized chunks (short final chunk allowed).
                if total_len == 0
                    || total_len.div_ceil(CHECKPOINT_CHUNK as u64) != chunks.len() as u64
                {
                    return Err(WireError::Malformed { context: "manifest.total_len" });
                }
                Response::Manifest { step, root, total_len, chunks }
            }
            RESP_STATS => Response::Stats(read_snapshot(&mut r)?),
            tag => return Err(WireError::BadTag { context: "response", tag }),
        };
        r.finish()?;
        Ok(resp)
    }
}

/// Exact encoded length of a response — the single source of truth for
/// [`Response::wire_size`].
pub fn response_wire_len(resp: &Response) -> usize {
    1 + match resp {
        Response::Commit(_) => 32,
        Response::Hashes(hs) | Response::NodeSeq(hs) => 8 + 32 * hs.len(),
        Response::Node(n) => n.byte_len(),
        Response::Proof(p) => provenance_wire_len(p),
        Response::TensorPayload(t) => tensor_wire_len(t),
        Response::Refuse(s) => 8 + s.len(),
        Response::Bye | Response::Pong => 0,
        Response::Submitted { .. } => 8,
        Response::Status(s) => status_wire_len(s),
        Response::Cancelled(_) => 1,
        Response::Checkpoint { payload, .. } => 8 + 32 + chunk_wire_len(payload),
        Response::Manifest { chunks, .. } => 8 + 32 + 8 + 8 + 32 * chunks.len(),
        Response::Stats(s) => snapshot_wire_len(s),
    }
}

// ---------------------------------------------------------------------------
// frame I/O
// ---------------------------------------------------------------------------

/// Write one `u32 LE length ‖ u64 LE tag ‖ payload` frame and flush. The
/// tag correlates this frame with its eventual answer: requesters pick a
/// per-connection-unique tag, responders echo it back verbatim.
pub fn write_frame(w: &mut impl Write, tag: u64, payload: &[u8]) -> io::Result<()> {
    assert!(payload.len() <= MAX_FRAME, "outgoing frame exceeds MAX_FRAME");
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&tag.to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Serialize a full `(tag, payload)` frame into a buffer — the form the
/// non-blocking multiplexer queues for readiness-driven writes.
pub fn frame_bytes(tag: u64, payload: &[u8]) -> Vec<u8> {
    assert!(payload.len() <= MAX_FRAME, "outgoing frame exceeds MAX_FRAME");
    let mut out = Vec::with_capacity(FRAME_HEADER_LEN + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&tag.to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Read one `(tag, payload)` frame. `Ok(None)` on clean EOF at a frame
/// boundary; EOF inside a frame is [`WireError::Truncated`].
pub fn read_frame(r: &mut impl Read) -> Result<Option<(u64, Vec<u8>)>, WireError> {
    let mut header = [0u8; FRAME_HEADER_LEN];
    let mut got = 0;
    while got < FRAME_HEADER_LEN {
        match r.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(WireError::Truncated {
                    context: "frame.header",
                    need: FRAME_HEADER_LEN,
                    have: got,
                })
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e.to_string())),
        }
    }
    let len = u32::from_le_bytes(header[0..4].try_into().expect("4 bytes")) as usize;
    if len > MAX_FRAME {
        return Err(WireError::FrameTooLarge { len });
    }
    let tag = u64::from_le_bytes(header[4..12].try_into().expect("8 bytes"));
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            WireError::Truncated { context: "frame.payload", need: len, have: 0 }
        } else {
            WireError::Io(e.to_string())
        }
    })?;
    Ok(Some((tag, payload)))
}

/// Incremental frame parser for non-blocking transports: if `buf` starts
/// with a complete frame, return `(tag, payload, bytes_consumed)`;
/// `Ok(None)` means more bytes are needed. Never consumes a partial frame.
pub fn split_frame(buf: &[u8]) -> Result<Option<(u64, Vec<u8>, usize)>, WireError> {
    if buf.len() < FRAME_HEADER_LEN {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[0..4].try_into().expect("4 bytes")) as usize;
    if len > MAX_FRAME {
        return Err(WireError::FrameTooLarge { len });
    }
    let total = FRAME_HEADER_LEN + len;
    if buf.len() < total {
        return Ok(None);
    }
    let tag = u64::from_le_bytes(buf[4..12].try_into().expect("8 bytes"));
    Ok(Some((tag, buf[FRAME_HEADER_LEN..total].to_vec(), total)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn sample_node() -> AugmentedCGNode {
        AugmentedCGNode {
            id: 17,
            structure: Hash::of_bytes(b"structure"),
            input_hashes: vec![Hash::of_bytes(b"i0"), Hash::of_bytes(b"i1")],
            output_hashes: vec![Hash::of_bytes(b"o0")],
        }
    }

    fn sample_proof(depth: usize) -> MerkleProof {
        MerkleProof {
            index: 5,
            siblings: (0..depth).map(|i| Hash::of_bytes(&[i as u8])).collect(),
        }
    }

    fn sample_requests() -> Vec<Request> {
        vec![
            Request::FinalCommit,
            Request::CheckpointHashes { boundaries: vec![1, 2, 3, 99] },
            Request::CheckpointHashes { boundaries: vec![] },
            Request::NodeHashSeq { step: 7 },
            Request::OpenNode { step: 3, idx: 41 },
            Request::InputProof { step: 9, node_idx: 2 },
            Request::InputTensor { step: 1, node_idx: 0, input_idx: 3 },
            Request::Shutdown,
            Request::Ping,
            Request::Train {
                spec: crate::train::JobSpec::quick(crate::model::Preset::Mlp, 12),
            },
            Request::Submit {
                spec: crate::train::JobSpec::quick(crate::model::Preset::Mlp, 24),
                policy: JobPolicy::default(),
            },
            Request::Submit {
                spec: crate::train::JobSpec::quick(crate::model::Preset::LlamaTiny, 64),
                policy: JobPolicy {
                    k: 4,
                    deadline: Some(std::time::Duration::from_millis(30_000)),
                    priority: -9,
                    backend: BackendRequirement::ReproducibleOnly,
                    segments: 8,
                    max_requeues: Some(1),
                    transfer: true,
                    audit_rate: 0.125,
                },
            },
            Request::Submit {
                spec: crate::train::JobSpec::quick(crate::model::Preset::Mlp, 16),
                policy: JobPolicy { audit_rate: 1.0, segments: 4, ..JobPolicy::default() },
            },
            Request::Status { job_id: 0 },
            Request::Status { job_id: u64::MAX },
            Request::Cancel { job_id: 3 },
            Request::FetchCheckpoint { step: 12, chunk: 0 },
            Request::FetchCheckpoint { step: u64::MAX, chunk: MAX_CHECKPOINT_CHUNKS - 1 },
            Request::SeedCheckpoint {
                spec: crate::train::JobSpec::quick(crate::model::Preset::Mlp, 16),
                start: 8,
                root: Hash::of_bytes(b"seed-root"),
                total_chunks: 3,
                chunk: 1,
                payload: vec![0xAB; 77],
            },
            Request::CommitRoot { step: 0 },
            Request::CommitRoot { step: u64::MAX },
            Request::FetchManifest { step: 0 },
            Request::FetchManifest { step: u64::MAX },
            Request::Stats,
        ]
    }

    fn sample_snapshot() -> Snapshot {
        Snapshot {
            version: crate::obs::STATS_VERSION,
            counters: vec![
                ("coord_jobs_submitted".to_string(), 12),
                ("net_tcp_bytes_in".to_string(), u64::MAX),
            ],
            gauges: vec![("coord_queue_depth".to_string(), 3)],
            histograms: vec![(
                "coord_tick_us".to_string(),
                HistogramSnapshot {
                    bounds: vec![10, 100, 1_000],
                    buckets: vec![4, 2, 1, 0],
                    sum: 777,
                    count: 7,
                },
            )],
        }
    }

    fn sample_responses() -> Vec<Response> {
        vec![
            Response::Commit(Hash::of_bytes(b"c")),
            Response::Hashes(vec![Hash::of_bytes(b"a"); 5]),
            Response::Hashes(vec![]),
            Response::NodeSeq(vec![Hash::of_bytes(b"n"); 3]),
            Response::Node(sample_node()),
            Response::Proof(InputProvenance::Genesis {
                leaf: Hash::of_bytes(b"leaf"),
                proof: sample_proof(6),
            }),
            Response::Proof(InputProvenance::PrevStep {
                node: sample_node(),
                out_idx: 1,
                proof: sample_proof(12),
            }),
            Response::TensorPayload(Tensor::rand([3, 4, 2], 7, 1.0)),
            Response::TensorPayload(Tensor::scalar(2.5)),
            Response::Refuse("nope — not answering".into()),
            Response::Bye,
            Response::Pong,
            Response::Submitted { job_id: 41 },
            Response::Status(RemoteStatus::Unknown),
            Response::Status(RemoteStatus::Queued),
            Response::Status(RemoteStatus::Running { segments_done: 2, segments_total: 5 }),
            Response::Status(RemoteStatus::Done {
                accepted: Some(Hash::of_bytes(b"done")),
                cancelled: false,
                disputes: 3,
                eliminated: 2,
            }),
            Response::Status(RemoteStatus::Done {
                accepted: None,
                cancelled: true,
                disputes: 0,
                eliminated: 0,
            }),
            Response::Cancelled(true),
            Response::Cancelled(false),
            Response::Checkpoint {
                step: 6,
                root: Hash::of_bytes(b"state-root"),
                total_chunks: 2,
                chunk: 0,
                payload: vec![0x5A; 128],
            },
            Response::Checkpoint {
                step: 1,
                root: Hash::ZERO,
                total_chunks: 1,
                chunk: 0,
                payload: vec![1],
            },
            Response::Manifest {
                step: 6,
                root: Hash::of_bytes(b"state-root"),
                total_len: CHECKPOINT_CHUNK as u64 + 128,
                chunks: vec![Hash::of_bytes(b"c0"), Hash::of_bytes(b"c1")],
            },
            Response::Manifest {
                step: 1,
                root: Hash::ZERO,
                total_len: 1,
                chunks: vec![Hash::of_bytes(b"only")],
            },
            Response::Stats(Snapshot::empty()),
            Response::Stats(sample_snapshot()),
        ]
    }

    #[test]
    fn requests_roundtrip_canonically() {
        for req in sample_requests() {
            let bytes = req.encode();
            assert_eq!(bytes.len(), req.wire_size(), "{req:?}");
            let back = Request::decode(&bytes).unwrap_or_else(|e| panic!("{req:?}: {e}"));
            assert_eq!(back.encode(), bytes, "{req:?} not canonical");
        }
    }

    #[test]
    fn responses_roundtrip_canonically() {
        for resp in sample_responses() {
            let bytes = resp.encode();
            assert_eq!(bytes.len(), resp.wire_size(), "{resp:?}");
            let back = Response::decode(&bytes).unwrap_or_else(|e| panic!("{resp:?}: {e}"));
            assert_eq!(back.encode(), bytes, "{resp:?} not canonical");
        }
    }

    #[test]
    fn tensor_payload_survives_bit_exactly() {
        let t = Tensor::rand([2, 3, 4], 42, 3.0);
        let bytes = Response::TensorPayload(t.clone()).encode();
        match Response::decode(&bytes).unwrap() {
            Response::TensorPayload(back) => assert!(back.bit_eq(&t)),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn job_spec_roundtrips_for_all_presets_and_optimizers() {
        use crate::graph::autodiff::Optimizer;
        use crate::model::Preset;
        for preset in ["mlp", "llama-tiny", "llama-tiny-lora", "llama-small", "bert-tiny"] {
            for opt in [Optimizer::adam(3e-3), Optimizer::Sgd { lr: 0.5 }] {
                let mut spec = JobSpec::quick(Preset::parse(preset).unwrap(), 17);
                spec.optimizer = opt;
                spec.weight_seed = 0xDEAD_BEEF;
                let bytes = Request::Train { spec }.encode();
                match Request::decode(&bytes).unwrap() {
                    Request::Train { spec: back } => {
                        assert_eq!(back.preset, spec.preset);
                        assert_eq!(back.optimizer, spec.optimizer);
                        assert_eq!(back.steps, spec.steps);
                        assert_eq!(back.weight_seed, spec.weight_seed);
                        assert_eq!(back.data_seed, spec.data_seed);
                        assert_eq!(back.batch, spec.batch);
                        assert_eq!(back.seq, spec.seq);
                        assert_eq!(back.checkpoint_n, spec.checkpoint_n);
                    }
                    other => panic!("{other:?}"),
                }
            }
        }
    }

    #[test]
    fn truncation_and_trailing_are_errors_not_panics() {
        let bytes = Response::Proof(InputProvenance::PrevStep {
            node: sample_node(),
            out_idx: 0,
            proof: sample_proof(9),
        })
        .encode();
        for cut in 0..bytes.len() {
            assert!(Response::decode(&bytes[..cut]).is_err(), "prefix {cut} accepted");
        }
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(matches!(Response::decode(&padded), Err(WireError::Trailing { extra: 1 })));
    }

    #[test]
    fn unknown_tags_rejected() {
        assert!(matches!(
            Request::decode(&[0x7f]),
            Err(WireError::BadTag { context: "request", .. })
        ));
        assert!(matches!(
            Response::decode(&[0x01]),
            Err(WireError::BadTag { context: "response", .. })
        ));
        assert!(Request::decode(&[]).is_err());
    }

    #[test]
    fn hostile_counts_do_not_allocate() {
        // Hashes response claiming u64::MAX entries in a 20-byte buffer.
        let mut evil = vec![RESP_HASHES];
        evil.extend_from_slice(&u64::MAX.to_le_bytes());
        evil.extend_from_slice(&[0u8; 11]);
        assert!(matches!(Response::decode(&evil), Err(WireError::Truncated { .. })));
        // Tensor with absurd dims.
        let mut evil = vec![RESP_TENSOR];
        evil.extend_from_slice(&2u64.to_le_bytes());
        evil.extend_from_slice(&(1u64 << 40).to_le_bytes());
        evil.extend_from_slice(&(1u64 << 40).to_le_bytes());
        assert!(matches!(Response::decode(&evil), Err(WireError::Malformed { .. })));
    }

    #[test]
    fn zero_step_job_delegation_rejected() {
        let spec = crate::train::JobSpec::quick(crate::model::Preset::Mlp, 0);
        let bytes = Request::Train { spec }.encode();
        assert!(matches!(
            Request::decode(&bytes),
            Err(WireError::Malformed { context: "spec.steps" })
        ));
    }

    #[test]
    fn hostile_policy_and_status_bytes_rejected() {
        // A presence byte outside {0,1} breaks canonicity and is refused.
        let spec = crate::train::JobSpec::quick(crate::model::Preset::Mlp, 4);
        let good = Request::Submit { spec, policy: JobPolicy::default() }.encode();
        // policy.deadline presence byte sits right after the spec + k.
        let pos = 1 + spec_wire_len(&spec) + 8;
        let mut evil = good.clone();
        assert_eq!(evil[pos], 0, "deadline presence byte located");
        evil[pos] = 2;
        assert!(matches!(
            Request::decode(&evil),
            Err(WireError::BadTag { context: "policy.deadline", .. })
        ));
        // Zero segments would divide the job into nothing.
        let mut zero_seg = Request::Submit {
            spec,
            policy: JobPolicy { segments: 1, ..JobPolicy::default() },
        }
        .encode();
        let seg_pos = good.len() - policy_wire_len(&JobPolicy::default()) + 8 + 1 + 8 + 1;
        assert_eq!(zero_seg[seg_pos], 1, "segments field located");
        zero_seg[seg_pos] = 0;
        assert!(matches!(
            Request::decode(&zero_seg),
            Err(WireError::Malformed { context: "policy.segments" })
        ));
        // Cancelled payload must be exactly 0 or 1.
        assert!(matches!(
            Response::decode(&[RESP_CANCELLED, 7]),
            Err(WireError::BadTag { context: "response.cancelled", .. })
        ));
        // Unknown status discriminant.
        assert!(matches!(
            Response::decode(&[RESP_STATUS, 0x7E]),
            Err(WireError::BadTag { context: "status", .. })
        ));
    }

    #[test]
    fn oversized_policy_fields_clamp_to_the_wire_bound() {
        // A locally absurd policy must still produce a decodable message:
        // k and segments clamp to POLICY_FIELD_MAX (and segments to >= 1)
        // rather than encoding bytes the receiving decoder would reject.
        let spec = crate::train::JobSpec::quick(crate::model::Preset::Mlp, 4);
        let policy = JobPolicy {
            k: usize::MAX,
            segments: u64::MAX,
            ..JobPolicy::default()
        };
        let bytes = Request::Submit { spec, policy }.encode();
        match Request::decode(&bytes).expect("clamped policy decodes") {
            Request::Submit { policy: back, .. } => {
                assert_eq!(back.k as u64, POLICY_FIELD_MAX);
                assert_eq!(back.segments, POLICY_FIELD_MAX);
            }
            other => panic!("{other:?}"),
        }
        let zero_segments = JobPolicy { segments: 0, ..JobPolicy::default() };
        let bytes = Request::Submit { spec, policy: zero_segments }.encode();
        match Request::decode(&bytes).expect("zero segments clamps to 1") {
            Request::Submit { policy: back, .. } => assert_eq!(back.segments, 1),
            other => panic!("{other:?}"),
        }
        // Out-of-range and NaN audit rates clamp on encode (NaN → 0.0,
        // audits off) so the message stays decodable.
        for (rate, expect) in [(7.5f32, 1.0f32), (-3.0, 0.0), (f32::NAN, 0.0)] {
            let policy = JobPolicy { audit_rate: rate, ..JobPolicy::default() };
            let bytes = Request::Submit { spec, policy }.encode();
            match Request::decode(&bytes).expect("clamped audit_rate decodes") {
                Request::Submit { policy: back, .. } => {
                    assert_eq!(back.audit_rate.to_bits(), expect.to_bits(), "rate {rate}");
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn hostile_audit_rate_and_commit_root_rejected() {
        // The audit rate is the last 4 bytes of a Submit policy; anything
        // outside [0.0, 1.0] — including NaN bit patterns — must be
        // rejected, never accepted as a second encoding of "no audits".
        let spec = crate::train::JobSpec::quick(crate::model::Preset::Mlp, 4);
        let good = Request::Submit { spec, policy: JobPolicy::default() }.encode();
        let pos = good.len() - 4;
        assert_eq!(
            f32::from_le_bytes(good[pos..].try_into().unwrap()).to_bits(),
            0.0f32.to_bits(),
            "audit_rate field located"
        );
        for evil_rate in [1.5f32, -0.25, f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut evil = good.clone();
            evil[pos..].copy_from_slice(&evil_rate.to_le_bytes());
            assert!(
                matches!(
                    Request::decode(&evil),
                    Err(WireError::Malformed { context: "policy.audit_rate" })
                ),
                "audit_rate {evil_rate} accepted"
            );
        }
        // CommitRoot: every strict prefix is Truncated, a junk tail is
        // Trailing — the same total-decoding battery as its siblings.
        let good = Request::CommitRoot { step: 42 }.encode();
        assert_eq!(good.len(), Request::CommitRoot { step: 42 }.wire_size());
        for cut in 0..good.len() {
            assert!(Request::decode(&good[..cut]).is_err(), "prefix {cut} accepted");
        }
        let mut padded = good.clone();
        padded.push(0);
        assert!(matches!(Request::decode(&padded), Err(WireError::Trailing { extra: 1 })));
    }

    #[test]
    fn hostile_checkpoint_chunks_rejected() {
        let good = Response::Checkpoint {
            step: 4,
            root: Hash::of_bytes(b"r"),
            total_chunks: 2,
            chunk: 1,
            payload: vec![7; 16],
        }
        .encode();
        // chunk tail sits after tag + step + root
        let tail = 1 + 8 + 32;
        // total_chunks == 0
        let mut evil = good.clone();
        evil[tail..tail + 8].copy_from_slice(&0u64.to_le_bytes());
        assert!(matches!(
            Response::decode(&evil),
            Err(WireError::Malformed { context: "chunk.total" })
        ));
        // total_chunks beyond the clamp
        let mut evil = good.clone();
        evil[tail..tail + 8].copy_from_slice(&(MAX_CHECKPOINT_CHUNKS + 1).to_le_bytes());
        assert!(matches!(
            Response::decode(&evil),
            Err(WireError::Malformed { context: "chunk.total" })
        ));
        // chunk index >= total_chunks
        let mut evil = good.clone();
        evil[tail + 8..tail + 16].copy_from_slice(&2u64.to_le_bytes());
        assert!(matches!(
            Response::decode(&evil),
            Err(WireError::Malformed { context: "chunk.index" })
        ));
        // payload length beyond CHECKPOINT_CHUNK must not allocate
        let mut evil = good.clone();
        evil[tail + 16..tail + 24].copy_from_slice(&((CHECKPOINT_CHUNK as u64) + 1).to_le_bytes());
        assert!(matches!(
            Response::decode(&evil),
            Err(WireError::Malformed { context: "chunk.len" })
        ));
        // truncation anywhere is an error, junk tail is Trailing
        for cut in 0..good.len() {
            assert!(Response::decode(&good[..cut]).is_err(), "prefix {cut} accepted");
        }
        let mut padded = good.clone();
        padded.push(0);
        assert!(matches!(Response::decode(&padded), Err(WireError::Trailing { extra: 1 })));

        // A seed whose boundary is outside the job is refused at decode.
        let spec = crate::train::JobSpec::quick(crate::model::Preset::Mlp, 8);
        let seed = Request::SeedCheckpoint {
            spec,
            start: 4,
            root: Hash::ZERO,
            total_chunks: 1,
            chunk: 0,
            payload: vec![1, 2, 3],
        };
        let bytes = seed.encode();
        assert_eq!(bytes.len(), seed.wire_size());
        // start sits right after tag + spec
        let pos = 1 + spec_wire_len(&spec);
        let mut evil = bytes.clone();
        evil[pos..pos + 8].copy_from_slice(&8u64.to_le_bytes()); // start == steps
        assert!(matches!(
            Request::decode(&evil),
            Err(WireError::Malformed { context: "seed.start" })
        ));
        let mut evil = bytes;
        evil[pos..pos + 8].copy_from_slice(&0u64.to_le_bytes()); // start == 0
        assert!(matches!(
            Request::decode(&evil),
            Err(WireError::Malformed { context: "seed.start" })
        ));
    }

    #[test]
    fn hostile_manifests_rejected() {
        let good = Response::Manifest {
            step: 4,
            root: Hash::of_bytes(b"r"),
            total_len: CHECKPOINT_CHUNK as u64 + 1,
            chunks: vec![Hash::of_bytes(b"c0"), Hash::of_bytes(b"c1")],
        }
        .encode();
        // layout: tag + step + root + total_len + count + hashes
        let len_pos = 1 + 8 + 32;
        let count_pos = len_pos + 8;
        // total_len == 0
        let mut evil = good.clone();
        evil[len_pos..len_pos + 8].copy_from_slice(&0u64.to_le_bytes());
        assert!(matches!(
            Response::decode(&evil),
            Err(WireError::Malformed { context: "manifest.total_len" })
        ));
        // total_len inconsistent with the chunk count (fits in one chunk
        // but two are listed)
        let mut evil = good.clone();
        evil[len_pos..len_pos + 8].copy_from_slice(&8u64.to_le_bytes());
        assert!(matches!(
            Response::decode(&evil),
            Err(WireError::Malformed { context: "manifest.total_len" })
        ));
        // an empty chunk list never describes a checkpoint
        let mut evil = good[..count_pos].to_vec();
        put_u64(&mut evil, 0);
        assert!(matches!(
            Response::decode(&evil),
            Err(WireError::Malformed { context: "manifest.chunks" })
        ));
        // a hostile count cannot force allocation past the buffer
        let mut evil = good[..count_pos].to_vec();
        put_u64(&mut evil, u64::MAX);
        assert!(matches!(Response::decode(&evil), Err(WireError::Truncated { .. })));
        // truncation anywhere is an error, junk tail is Trailing
        for cut in 0..good.len() {
            assert!(Response::decode(&good[..cut]).is_err(), "prefix {cut} accepted");
        }
        let mut padded = good.clone();
        padded.push(0);
        assert!(matches!(Response::decode(&padded), Err(WireError::Trailing { extra: 1 })));

        // FetchManifest: the same total-decoding battery as its siblings.
        let good = Request::FetchManifest { step: 42 }.encode();
        assert_eq!(good.len(), Request::FetchManifest { step: 42 }.wire_size());
        for cut in 0..good.len() {
            assert!(Request::decode(&good[..cut]).is_err(), "prefix {cut} accepted");
        }
        let mut padded = good.clone();
        padded.push(0);
        assert!(matches!(Request::decode(&padded), Err(WireError::Trailing { extra: 1 })));
    }

    #[test]
    fn stats_snapshot_roundtrips_and_rejects_hostile_counts() {
        // Full roundtrip with value equality, not just canonical bytes.
        let snap = sample_snapshot();
        let bytes = Response::Stats(snap.clone()).encode();
        match Response::decode(&bytes).expect("snapshot decodes") {
            Response::Stats(back) => assert_eq!(back, snap),
            other => panic!("{other:?}"),
        }

        // A counter section claiming u64::MAX entries in a short buffer
        // must fail before allocating.
        let mut evil = vec![RESP_STATS];
        put_u64(&mut evil, 1); // version
        evil.extend_from_slice(&u64::MAX.to_le_bytes()); // counter count
        assert!(matches!(Response::decode(&evil), Err(WireError::Truncated { .. })));

        // A histogram declaring an absurd bound count is malformed.
        let mut evil = vec![RESP_STATS];
        put_u64(&mut evil, 1); // version
        put_u64(&mut evil, 0); // counters
        put_u64(&mut evil, 0); // gauges
        put_u64(&mut evil, 1); // one histogram
        put_str(&mut evil, "h");
        put_u64(&mut evil, (MAX_HISTOGRAM_BOUNDS as u64) + 1);
        evil.resize(evil.len() + (1 << 20), 0); // plenty of real bytes behind it
        assert!(matches!(
            Response::decode(&evil),
            Err(WireError::Malformed { context: "stats.histogram.bounds" })
        ));

        // Truncation at every prefix is an error, never a panic; a padded
        // tail breaks canonicity.
        for cut in 0..bytes.len() {
            assert!(Response::decode(&bytes[..cut]).is_err(), "prefix {cut} accepted");
        }
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(matches!(Response::decode(&padded), Err(WireError::Trailing { extra: 1 })));

        // The Stats request is a bare tag.
        assert_eq!(Request::Stats.encode(), vec![REQ_STATS]);
        assert_eq!(Request::Stats.wire_size(), 1);
    }

    #[test]
    fn frames_roundtrip_and_reject_oversize() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 7, b"hello").unwrap();
        write_frame(&mut buf, u64::MAX, b"").unwrap();
        let mut cur = Cursor::new(buf);
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), (7, b"hello".to_vec()));
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), (u64::MAX, Vec::new()));
        assert!(read_frame(&mut cur).unwrap().is_none(), "clean EOF");

        let mut evil = Vec::new();
        evil.extend_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        evil.extend_from_slice(&0u64.to_le_bytes());
        assert!(matches!(
            read_frame(&mut Cursor::new(evil)),
            Err(WireError::FrameTooLarge { .. })
        ));

        // EOF mid-frame is truncation, not a clean close.
        let mut cut = Vec::new();
        write_frame(&mut cut, 3, b"abcdef").unwrap();
        cut.truncate(FRAME_HEADER_LEN + 3);
        assert!(matches!(
            read_frame(&mut Cursor::new(cut)),
            Err(WireError::Truncated { .. })
        ));
    }

    #[test]
    fn split_frame_parses_incrementally_and_echoes_tags() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 0xAB, b"first").unwrap();
        write_frame(&mut buf, 0xCD, b"second!").unwrap();

        // Feed the stream byte by byte: split_frame must return None until
        // a whole frame is buffered, then consume exactly that frame.
        let mut fed = Vec::new();
        let mut seen = Vec::new();
        for &b in &buf {
            fed.push(b);
            while let Some((tag, payload, consumed)) = split_frame(&fed).unwrap() {
                seen.push((tag, payload));
                fed.drain(..consumed);
            }
        }
        assert!(fed.is_empty(), "all bytes consumed at frame boundaries");
        assert_eq!(
            seen,
            vec![(0xAB, b"first".to_vec()), (0xCD, b"second!".to_vec())]
        );

        // frame_bytes agrees with write_frame byte-for-byte
        let mut via_writer = Vec::new();
        write_frame(&mut via_writer, 42, b"xyz").unwrap();
        assert_eq!(frame_bytes(42, b"xyz"), via_writer);

        // hostile length prefix is an error, not an allocation
        let mut evil = Vec::new();
        evil.extend_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        evil.extend_from_slice(&0u64.to_le_bytes());
        assert!(matches!(split_frame(&evil), Err(WireError::FrameTooLarge { .. })));
    }
}
