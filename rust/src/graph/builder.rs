//! Ergonomic forward-graph construction with static shape tracking.
//!
//! Model definitions ([`crate::model`]) build their forward pass through a
//! [`GraphBuilder`], which checks shapes at build time (our stand-in for
//! ONNX shape inference) and records the metadata [`super::autodiff`] needs
//! to derive the extended training-step graph.

use crate::tensor::Tensor;
use std::collections::BTreeMap;

use super::{Graph, InitKind, NodeId, Op, Slot};

/// Forward-graph builder with per-slot static shapes.
#[derive(Debug, Default)]
pub struct GraphBuilder {
    pub graph: Graph,
    /// `shapes[node][out_idx]` — static shape of every produced tensor.
    pub shapes: Vec<Vec<Vec<usize>>>,
    /// Declared parameter shapes, in declaration order.
    pub param_shapes: Vec<(String, Vec<usize>)>,
    /// Declared data-input shapes.
    pub data_shapes: BTreeMap<String, Vec<usize>>,
}

impl GraphBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn shape(&self, s: Slot) -> &[usize] {
        &self.shapes[s.node][s.out_idx]
    }

    fn push(&mut self, label: impl Into<String>, op: Op, inputs: Vec<Slot>, out_shapes: Vec<Vec<usize>>) -> NodeId {
        let id = self.graph.push(label, op, inputs);
        debug_assert_eq!(id, self.shapes.len());
        self.shapes.push(out_shapes);
        id
    }

    // ---- leaves -----------------------------------------------------------

    /// Declare a training-data input.
    pub fn data(&mut self, name: &str, shape: impl Into<Vec<usize>>) -> Slot {
        let shape = shape.into();
        self.data_shapes.insert(name.to_string(), shape.clone());
        let id = self.push(
            name,
            Op::Init { kind: InitKind::Data, name: name.to_string() },
            vec![],
            vec![shape],
        );
        Slot::new(id, 0)
    }

    /// Declare a learnable parameter.
    pub fn param(&mut self, name: &str, shape: impl Into<Vec<usize>>) -> Slot {
        let shape = shape.into();
        assert!(
            !self.param_shapes.iter().any(|(n, _)| n == name),
            "duplicate param '{name}'"
        );
        self.param_shapes.push((name.to_string(), shape.clone()));
        let id = self.push(
            name,
            Op::Init { kind: InitKind::Param, name: name.to_string() },
            vec![],
            vec![shape],
        );
        Slot::new(id, 0)
    }

    /// Bake a constant tensor into the program.
    pub fn constant(&mut self, label: &str, value: Tensor) -> Slot {
        let shape = value.shape().to_vec();
        let id = self.push(label, Op::Const { value }, vec![], vec![shape]);
        Slot::new(id, 0)
    }

    // ---- ops ---------------------------------------------------------------

    pub fn matmul(&mut self, label: &str, a: Slot, b: Slot) -> Slot {
        let (sa, sb) = (self.shape(a).to_vec(), self.shape(b).to_vec());
        assert_eq!(sa.len(), 2, "{label}: matmul lhs {sa:?}");
        assert_eq!(sb.len(), 2, "{label}: matmul rhs {sb:?}");
        assert_eq!(sa[1], sb[0], "{label}: matmul {sa:?} x {sb:?}");
        let id = self.push(label, Op::MatMul, vec![a, b], vec![vec![sa[0], sb[1]]]);
        Slot::new(id, 0)
    }

    pub fn bmm(&mut self, label: &str, a: Slot, b: Slot) -> Slot {
        let (sa, sb) = (self.shape(a).to_vec(), self.shape(b).to_vec());
        assert_eq!(sa.len(), 3, "{label}: bmm lhs {sa:?}");
        assert_eq!(sb.len(), 3, "{label}: bmm rhs {sb:?}");
        assert_eq!(sa[0], sb[0], "{label}: bmm batch {sa:?} x {sb:?}");
        assert_eq!(sa[2], sb[1], "{label}: bmm inner {sa:?} x {sb:?}");
        let id = self.push(label, Op::BatchMatMul, vec![a, b], vec![vec![sa[0], sa[1], sb[2]]]);
        Slot::new(id, 0)
    }

    pub fn transpose2d(&mut self, label: &str, x: Slot) -> Slot {
        let s = self.shape(x).to_vec();
        assert_eq!(s.len(), 2);
        let id = self.push(label, Op::Transpose2D, vec![x], vec![vec![s[1], s[0]]]);
        Slot::new(id, 0)
    }

    pub fn transpose_last2(&mut self, label: &str, x: Slot) -> Slot {
        let s = self.shape(x).to_vec();
        assert_eq!(s.len(), 3);
        let id = self.push(label, Op::TransposeLast2, vec![x], vec![vec![s[0], s[2], s[1]]]);
        Slot::new(id, 0)
    }

    pub fn perm0213(&mut self, label: &str, x: Slot) -> Slot {
        let s = self.shape(x).to_vec();
        assert_eq!(s.len(), 4);
        let id = self.push(label, Op::Perm0213, vec![x], vec![vec![s[0], s[2], s[1], s[3]]]);
        Slot::new(id, 0)
    }

    pub fn reshape(&mut self, label: &str, x: Slot, shape: impl Into<Vec<usize>>) -> Slot {
        let shape = shape.into();
        let from: usize = self.shape(x).iter().product();
        let to: usize = shape.iter().product();
        assert_eq!(from, to, "{label}: reshape {:?} -> {shape:?}", self.shape(x));
        let id = self.push(label, Op::Reshape { shape: shape.clone() }, vec![x], vec![shape]);
        Slot::new(id, 0)
    }

    fn binary_same(&mut self, label: &str, op: Op, a: Slot, b: Slot) -> Slot {
        assert_eq!(self.shape(a), self.shape(b), "{label}: {op:?} shape mismatch");
        let s = self.shape(a).to_vec();
        let id = self.push(label, op, vec![a, b], vec![s]);
        Slot::new(id, 0)
    }

    pub fn add(&mut self, label: &str, a: Slot, b: Slot) -> Slot {
        self.binary_same(label, Op::Add, a, b)
    }

    pub fn sub(&mut self, label: &str, a: Slot, b: Slot) -> Slot {
        self.binary_same(label, Op::Sub, a, b)
    }

    pub fn mul(&mut self, label: &str, a: Slot, b: Slot) -> Slot {
        self.binary_same(label, Op::Mul, a, b)
    }

    /// `a + b`, `b`'s shape a suffix of `a`'s (bias / mask add).
    pub fn add_bcast(&mut self, label: &str, a: Slot, b: Slot) -> Slot {
        let (sa, sb) = (self.shape(a).to_vec(), self.shape(b).to_vec());
        assert!(sb.len() <= sa.len() && sa[sa.len() - sb.len()..] == sb[..],
            "{label}: add_bcast {sa:?} + {sb:?}");
        let id = self.push(label, Op::AddBcast, vec![a, b], vec![sa]);
        Slot::new(id, 0)
    }

    pub fn scale(&mut self, label: &str, x: Slot, c: f32) -> Slot {
        let s = self.shape(x).to_vec();
        let id = self.push(label, Op::Scale { c }, vec![x], vec![s]);
        Slot::new(id, 0)
    }

    fn unary(&mut self, label: &str, op: Op, x: Slot) -> Slot {
        let s = self.shape(x).to_vec();
        let id = self.push(label, op, vec![x], vec![s]);
        Slot::new(id, 0)
    }

    pub fn gelu(&mut self, label: &str, x: Slot) -> Slot {
        self.unary(label, Op::Gelu, x)
    }

    pub fn silu(&mut self, label: &str, x: Slot) -> Slot {
        self.unary(label, Op::Silu, x)
    }

    pub fn relu(&mut self, label: &str, x: Slot) -> Slot {
        self.unary(label, Op::Relu, x)
    }

    pub fn tanh(&mut self, label: &str, x: Slot) -> Slot {
        self.unary(label, Op::Tanh, x)
    }

    pub fn softmax(&mut self, label: &str, x: Slot) -> Slot {
        self.unary(label, Op::Softmax, x)
    }

    pub fn layernorm(&mut self, label: &str, x: Slot, gamma: Slot, beta: Slot, eps: f32) -> Slot {
        let n = *self.shape(x).last().unwrap();
        assert_eq!(self.shape(gamma), [n], "{label}: gamma");
        assert_eq!(self.shape(beta), [n], "{label}: beta");
        let s = self.shape(x).to_vec();
        let id = self.push(label, Op::LayerNorm { eps }, vec![x, gamma, beta], vec![s]);
        Slot::new(id, 0)
    }

    pub fn rmsnorm(&mut self, label: &str, x: Slot, gamma: Slot, eps: f32) -> Slot {
        let n = *self.shape(x).last().unwrap();
        assert_eq!(self.shape(gamma), [n], "{label}: gamma");
        let s = self.shape(x).to_vec();
        let id = self.push(label, Op::RmsNorm { eps }, vec![x, gamma], vec![s]);
        Slot::new(id, 0)
    }

    pub fn rope(&mut self, label: &str, x: Slot, sin: Slot, cos: Slot) -> Slot {
        let s = self.shape(x).to_vec();
        assert_eq!(s.len(), 3, "{label}: rope wants [n,s,d]");
        assert_eq!(self.shape(sin), [s[1], s[2] / 2], "{label}: sin table");
        assert_eq!(self.shape(cos), [s[1], s[2] / 2], "{label}: cos table");
        let id = self.push(label, Op::Rope, vec![x, sin, cos], vec![s]);
        Slot::new(id, 0)
    }

    pub fn embedding(&mut self, label: &str, table: Slot, ids: Slot) -> Slot {
        let ts = self.shape(table).to_vec();
        assert_eq!(ts.len(), 2, "{label}: embedding table {ts:?}");
        let mut out = self.shape(ids).to_vec();
        out.push(ts[1]);
        let id = self.push(label, Op::Embedding, vec![table, ids], vec![out]);
        Slot::new(id, 0)
    }

    /// Mean cross-entropy: logits `[r, v]`, integer targets `[r]` → scalar.
    pub fn ce_loss(&mut self, label: &str, logits: Slot, targets: Slot) -> Slot {
        let ls = self.shape(logits).to_vec();
        assert_eq!(ls.len(), 2, "{label}: logits {ls:?}");
        assert_eq!(self.shape(targets), [ls[0]], "{label}: targets");
        let id = self.push(label, Op::CeLoss, vec![logits, targets], vec![vec![]]);
        Slot::new(id, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_tracks_shapes() {
        let mut b = GraphBuilder::new();
        let x = b.data("x", [2, 8]);
        let w = b.param("w", [8, 4]);
        let h = b.matmul("mm", x, w);
        assert_eq!(b.shape(h), &[2, 4]);
        let g = b.gelu("act", h);
        assert_eq!(b.shape(g), &[2, 4]);
        let r = b.reshape("r", g, [8]);
        assert_eq!(b.shape(r), &[8]);
        b.graph.validate().unwrap();
        assert_eq!(b.param_shapes.len(), 1);
        assert_eq!(b.data_shapes["x"], vec![2, 8]);
    }

    #[test]
    #[should_panic(expected = "matmul")]
    fn rejects_shape_mismatch() {
        let mut b = GraphBuilder::new();
        let x = b.data("x", [2, 8]);
        let w = b.param("w", [4, 4]);
        b.matmul("mm", x, w);
    }

    #[test]
    #[should_panic(expected = "duplicate param")]
    fn rejects_duplicate_param() {
        let mut b = GraphBuilder::new();
        b.param("w", [2, 2]);
        b.param("w", [2, 2]);
    }

    #[test]
    fn attention_shape_pipeline() {
        // the shape gymnastics attention needs, end to end
        let (bs, s, h, dh) = (2usize, 4usize, 2usize, 6usize);
        let d = h * dh;
        let mut b = GraphBuilder::new();
        let x = b.data("x", [bs * s, d]);
        let wq = b.param("wq", [d, d]);
        let q = b.matmul("q", x, wq);
        let q4 = b.reshape("q4", q, [bs, s, h, dh]);
        let qh = b.perm0213("qh", q4);
        assert_eq!(b.shape(qh), &[bs, h, s, dh]);
        let q3 = b.reshape("q3", qh, [bs * h, s, dh]);
        let kt = b.transpose_last2("kt", q3);
        assert_eq!(b.shape(kt), &[bs * h, dh, s]);
        let scores = b.bmm("scores", q3, kt);
        assert_eq!(b.shape(scores), &[bs * h, s, s]);
    }
}
