//! The computational-graph IR Verde arbitrates over (paper §2.2).
//!
//! A neural-network *program* is a topologically-sorted DAG of operator
//! nodes, ONNX-style. Model builders ([`crate::model`]) construct the
//! **forward** graph; [`autodiff`] extends it with backward and
//! optimizer-update nodes into the *extended computational graph* of paper
//! Figure 1; [`executor`] runs it node by node, producing the
//! `AugmentedCGNode` records (operator + input/output tensor hashes) that the
//! dispute-resolution protocol commits to.
//!
//! The node order of a [`Graph`] IS its canonical topological order — the
//! builder can only reference already-inserted nodes, and [`Graph::validate`]
//! re-checks the invariant. "We topologically sort the graph to ensure a
//! common order for all parties" (§2.2).

pub mod autodiff;
pub mod builder;
pub mod executor;
pub mod kernels;

use crate::hash::{Hash, Hasher};
use crate::tensor::Tensor;

/// Index of a node within its graph (== position in `Graph::nodes`).
pub type NodeId = usize;

/// A reference to the `out_idx`-th output tensor of node `node`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Slot {
    pub node: NodeId,
    pub out_idx: usize,
}

impl Slot {
    pub fn new(node: NodeId, out_idx: usize) -> Slot {
        Slot { node, out_idx }
    }
}

/// Where an initialization node's value comes from at execution time.
/// These are the "yellow" nodes of paper Figure 1.
#[derive(Debug, Clone, PartialEq)]
pub enum InitKind {
    /// A learnable parameter, read from the checkpoint state by name.
    Param,
    /// Optimizer state (Adam first/second moment), read from the checkpoint.
    OptState,
    /// A training-data tensor (token ids, targets), read from the batch.
    Data,
}

/// Operators. Forward ("blue"), backward ("red"), and update nodes all draw
/// from this one enum; the extended graph is just a graph that contains the
/// latter two kinds (Figure 1).
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    // ---- initialization ----------------------------------------------------
    /// Value injected from checkpoint/batch at execution time.
    Init { kind: InitKind, name: String },
    /// A constant baked into the program (masks, RoPE tables). The tensor is
    /// part of the graph structure and thus of the program commitment.
    Const { value: Tensor },

    // ---- structure / movement ----------------------------------------------
    Reshape { shape: Vec<usize> },
    Transpose2D,
    /// `[b, m, n] -> [b, n, m]`.
    TransposeLast2,
    /// `[a, b, c, d] -> [a, c, b, d]` (head split/merge for attention).
    Perm0213,
    /// Gather rows of input 0 (table `[v, d]`) by integer ids (input 1).
    Embedding,
    /// Scatter-add of gradients (input 1, `[..., d]`) by ids (input 0) into a
    /// zero `[vocab, d]` table — backward of `Embedding`.
    EmbeddingGrad { vocab: usize },

    // ---- elementwise -------------------------------------------------------
    Add,
    Sub,
    Mul,
    /// `a + b` where `b`'s shape is a suffix of `a`'s (bias add, mask add).
    AddBcast,
    Scale { c: f32 },
    Gelu,
    Silu,
    Relu,
    Tanh,

    // ---- contractions ------------------------------------------------------
    MatMul,
    BatchMatMul,

    // ---- normalization / softmax / loss ------------------------------------
    Softmax,
    LayerNorm { eps: f32 },
    RmsNorm { eps: f32 },
    /// Rotary position embedding. Inputs: `x [n, s, d]`, `sin [s, d/2]`,
    /// `cos [s, d/2]`.
    Rope,
    /// Mean cross-entropy over rows. Inputs: logits `[r, v]`, integer
    /// targets `[r]`; output: scalar loss.
    CeLoss,

    // ---- backward-only operators -------------------------------------------
    /// Inputs `(x, dy)` → `dy * gelu'(x)`.
    GeluGrad,
    /// Inputs `(x, dy)` → `dy * silu'(x)`.
    SiluGrad,
    /// Inputs `(x, dy)` → `dy * 1[x>0]`.
    ReluGrad,
    /// Inputs `(y, dy)` → `dy * (1 - y²)` (uses the saved output).
    TanhGrad,
    /// Inputs `(y, dy)` where `y = softmax(x)` → `y ⊙ (dy - Σ_j dy_j y_j)`.
    SoftmaxGrad,
    /// Inputs `(x, gamma, dy)` → `(dx, dgamma, dbeta)`.
    LayerNormGrad { eps: f32 },
    /// Inputs `(x, gamma, dy)` → `(dx, dgamma)`.
    RmsNormGrad { eps: f32 },
    /// Inputs `(dy, sin, cos)` → rotation by `-θ` (inverse of `Rope`).
    RopeGrad,
    /// Inputs `(logits, targets, dloss)` → dlogits `(softmax - onehot)·dloss/r`.
    CeGrad,
    /// Sum over leading dims until only the trailing `suffix_rank` dims
    /// remain — backward of `AddBcast`'s broadcast input.
    SumLeading { suffix_rank: usize },

    // ---- optimizer update nodes ---------------------------------------------
    /// Adam. Inputs `(w, g, m, v)` → `(w', m', v')`. Bias correction uses the
    /// executing step's 1-based index `t` (supplied by the executor; part of
    /// the step identity the protocol already pins down).
    AdamUpdate { lr: f32, beta1: f32, beta2: f32, eps: f32 },
    /// Plain SGD. Inputs `(w, g)` → `w'`.
    SgdUpdate { lr: f32 },
}

impl Op {
    /// Number of output tensors this operator produces.
    pub fn n_outputs(&self) -> usize {
        match self {
            Op::LayerNormGrad { .. } | Op::AdamUpdate { .. } => 3,
            Op::RmsNormGrad { .. } => 2,
            _ => 1,
        }
    }

    /// Number of input slots this operator consumes.
    pub fn n_inputs(&self) -> usize {
        match self {
            Op::Init { .. } | Op::Const { .. } => 0,
            Op::Reshape { .. }
            | Op::Transpose2D
            | Op::TransposeLast2
            | Op::Perm0213
            | Op::Scale { .. }
            | Op::Gelu
            | Op::Silu
            | Op::Relu
            | Op::Tanh
            | Op::Softmax
            | Op::SumLeading { .. } => 1,
            Op::Embedding
            | Op::EmbeddingGrad { .. }
            | Op::Add
            | Op::Sub
            | Op::Mul
            | Op::AddBcast
            | Op::MatMul
            | Op::BatchMatMul
            | Op::RmsNorm { .. }
            | Op::CeLoss
            | Op::GeluGrad
            | Op::SiluGrad
            | Op::ReluGrad
            | Op::TanhGrad
            | Op::SoftmaxGrad
            | Op::SgdUpdate { .. } => 2,
            Op::LayerNorm { .. }
            | Op::Rope
            | Op::LayerNormGrad { .. }
            | Op::RmsNormGrad { .. }
            | Op::RopeGrad
            | Op::CeGrad => 3,
            Op::AdamUpdate { .. } => 4,
        }
    }

    /// A short stable mnemonic, part of the node commitment.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            Op::Init { kind: InitKind::Param, .. } => "init.param",
            Op::Init { kind: InitKind::OptState, .. } => "init.opt",
            Op::Init { kind: InitKind::Data, .. } => "init.data",
            Op::Const { .. } => "const",
            Op::Reshape { .. } => "reshape",
            Op::Transpose2D => "transpose2d",
            Op::TransposeLast2 => "transpose_last2",
            Op::Perm0213 => "perm0213",
            Op::Embedding => "embedding",
            Op::EmbeddingGrad { .. } => "embedding_grad",
            Op::Add => "add",
            Op::Sub => "sub",
            Op::Mul => "mul",
            Op::AddBcast => "add_bcast",
            Op::Scale { .. } => "scale",
            Op::Gelu => "gelu",
            Op::Silu => "silu",
            Op::Relu => "relu",
            Op::Tanh => "tanh",
            Op::MatMul => "matmul",
            Op::BatchMatMul => "bmm",
            Op::Softmax => "softmax",
            Op::LayerNorm { .. } => "layernorm",
            Op::RmsNorm { .. } => "rmsnorm",
            Op::Rope => "rope",
            Op::CeLoss => "ce_loss",
            Op::GeluGrad => "gelu_grad",
            Op::SiluGrad => "silu_grad",
            Op::ReluGrad => "relu_grad",
            Op::TanhGrad => "tanh_grad",
            Op::SoftmaxGrad => "softmax_grad",
            Op::LayerNormGrad { .. } => "layernorm_grad",
            Op::RmsNormGrad { .. } => "rmsnorm_grad",
            Op::RopeGrad => "rope_grad",
            Op::CeGrad => "ce_grad",
            Op::SumLeading { .. } => "sum_leading",
            Op::AdamUpdate { .. } => "adam_update",
            Op::SgdUpdate { .. } => "sgd_update",
        }
    }

    /// Commit the operator *and its attributes* (paper: "operation (operator
    /// and attribute details)" is part of the AugmentedCGNode).
    pub fn attr_hash(&self) -> Hash {
        let mut h = Hasher::new("verde.op.v1");
        h.str(self.mnemonic());
        match self {
            Op::Init { name, .. } => {
                h.str(name);
            }
            Op::Const { value } => {
                let th = crate::hash::hash_tensor(value);
                h.hash(&th);
            }
            Op::Reshape { shape } => {
                h.u64(shape.len() as u64);
                for &d in shape {
                    h.u64(d as u64);
                }
            }
            Op::EmbeddingGrad { vocab } => {
                h.u64(*vocab as u64);
            }
            Op::Scale { c } => {
                h.u64(c.to_bits() as u64);
            }
            Op::LayerNorm { eps }
            | Op::RmsNorm { eps }
            | Op::LayerNormGrad { eps }
            | Op::RmsNormGrad { eps } => {
                h.u64(eps.to_bits() as u64);
            }
            Op::SumLeading { suffix_rank } => {
                h.u64(*suffix_rank as u64);
            }
            Op::AdamUpdate { lr, beta1, beta2, eps } => {
                h.u64(lr.to_bits() as u64);
                h.u64(beta1.to_bits() as u64);
                h.u64(beta2.to_bits() as u64);
                h.u64(eps.to_bits() as u64);
            }
            Op::SgdUpdate { lr } => {
                h.u64(lr.to_bits() as u64);
            }
            _ => {}
        }
        h.finish()
    }
}

/// One vertex of the computational graph.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub id: NodeId,
    /// Human-readable label (e.g. `"blk0.attn.q_proj"`); not committed —
    /// structure and attributes are what the protocol hashes.
    pub label: String,
    pub op: Op,
    pub inputs: Vec<Slot>,
}

/// A topologically-ordered operator DAG.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
}

impl Graph {
    pub fn new() -> Graph {
        Graph::default()
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Append a node; inputs must reference existing nodes (this is what
    /// keeps `nodes` topologically sorted by construction).
    pub fn push(&mut self, label: impl Into<String>, op: Op, inputs: Vec<Slot>) -> NodeId {
        let id = self.nodes.len();
        assert_eq!(
            inputs.len(),
            op.n_inputs(),
            "op {} wants {} inputs, got {}",
            op.mnemonic(),
            op.n_inputs(),
            inputs.len()
        );
        for s in &inputs {
            assert!(s.node < id, "node {id} references future node {}", s.node);
            assert!(
                s.out_idx < self.nodes[s.node].op.n_outputs(),
                "node {id} references output {} of node {} which has {}",
                s.out_idx,
                s.node,
                self.nodes[s.node].op.n_outputs()
            );
        }
        self.nodes.push(Node { id, label: label.into(), op, inputs });
        id
    }

    /// Check the topological invariant and id consistency.
    pub fn validate(&self) -> Result<(), String> {
        for (i, n) in self.nodes.iter().enumerate() {
            if n.id != i {
                return Err(format!("node at position {i} has id {}", n.id));
            }
            if n.inputs.len() != n.op.n_inputs() {
                return Err(format!("node {i} input arity mismatch"));
            }
            for s in &n.inputs {
                if s.node >= i {
                    return Err(format!("node {i} references non-past node {}", s.node));
                }
                if s.out_idx >= self.nodes[s.node].op.n_outputs() {
                    return Err(format!("node {i} references invalid output of {}", s.node));
                }
            }
        }
        Ok(())
    }

    /// Structural commitment to the whole program: op attributes + wiring.
    /// This is what the client hands the referee as "the model specification"
    /// and what Case 1 of the decision algorithm compares against.
    pub fn structure_hash(&self) -> Hash {
        let mut h = Hasher::new("verde.graph.v1");
        h.u64(self.nodes.len() as u64);
        for n in &self.nodes {
            let ah = n.op.attr_hash();
            h.hash(&ah);
            h.u64(n.inputs.len() as u64);
            for s in &n.inputs {
                h.u64(s.node as u64);
                h.u64(s.out_idx as u64);
            }
        }
        h.finish()
    }

    /// Structural commitment to a single node (op attrs + input wiring) —
    /// the "graph structure" part of an AugmentedCGNode, used by Case 1.
    pub fn node_structure_hash(&self, id: NodeId) -> Hash {
        let n = &self.nodes[id];
        let mut h = Hasher::new("verde.node-structure.v1");
        h.u64(n.id as u64);
        let ah = n.op.attr_hash();
        h.hash(&ah);
        h.u64(n.inputs.len() as u64);
        for s in &n.inputs {
            h.u64(s.node as u64);
            h.u64(s.out_idx as u64);
        }
        h.finish()
    }

    /// All `Init` nodes of a given kind, in topological order.
    pub fn init_nodes(&self, kind: &InitKind) -> Vec<(NodeId, String)> {
        self.nodes
            .iter()
            .filter_map(|n| match &n.op {
                Op::Init { kind: k, name } if k == kind => Some((n.id, name.clone())),
                _ => None,
            })
            .collect()
    }

    /// Consumers of each node (adjacency, for autodiff).
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for s in &n.inputs {
                out[s.node].push(n.id);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> Graph {
        let mut g = Graph::new();
        let x = g.push("x", Op::Init { kind: InitKind::Data, name: "x".into() }, vec![]);
        let w = g.push("w", Op::Init { kind: InitKind::Param, name: "w".into() }, vec![]);
        let mm = g.push("mm", Op::MatMul, vec![Slot::new(x, 0), Slot::new(w, 0)]);
        g.push("act", Op::Gelu, vec![Slot::new(mm, 0)]);
        g
    }

    #[test]
    fn push_keeps_topo_order_and_validates() {
        let g = tiny_graph();
        assert_eq!(g.len(), 4);
        g.validate().unwrap();
    }

    #[test]
    #[should_panic]
    fn push_rejects_wrong_arity() {
        let mut g = Graph::new();
        g.push("bad", Op::MatMul, vec![]);
    }

    #[test]
    fn structure_hash_sensitive_to_wiring_and_attrs() {
        let g1 = tiny_graph();
        let mut g2 = tiny_graph();
        // change an attribute: swap Gelu for Relu
        g2.nodes[3].op = Op::Relu;
        assert_ne!(g1.structure_hash(), g2.structure_hash());

        let mut g3 = tiny_graph();
        // rewire: act consumes w instead of mm
        g3.nodes[3].inputs[0] = Slot::new(1, 0);
        assert_ne!(g1.structure_hash(), g3.structure_hash());

        // labels are NOT committed
        let mut g4 = tiny_graph();
        g4.nodes[3].label = "renamed".into();
        assert_eq!(g1.structure_hash(), g4.structure_hash());
    }

    #[test]
    fn scale_attr_in_hash() {
        let mut g1 = Graph::new();
        let x = g1.push("x", Op::Init { kind: InitKind::Data, name: "x".into() }, vec![]);
        g1.push("s", Op::Scale { c: 2.0 }, vec![Slot::new(x, 0)]);
        let mut g2 = Graph::new();
        let x2 = g2.push("x", Op::Init { kind: InitKind::Data, name: "x".into() }, vec![]);
        g2.push("s", Op::Scale { c: 3.0 }, vec![Slot::new(x2, 0)]);
        assert_ne!(g1.structure_hash(), g2.structure_hash());
    }

    #[test]
    fn init_nodes_filtered_by_kind() {
        let g = tiny_graph();
        assert_eq!(g.init_nodes(&InitKind::Data).len(), 1);
        assert_eq!(g.init_nodes(&InitKind::Param).len(), 1);
        assert_eq!(g.init_nodes(&InitKind::OptState).len(), 0);
    }

    #[test]
    fn consumers_adjacency() {
        let g = tiny_graph();
        let c = g.consumers();
        assert_eq!(c[0], vec![2]); // x feeds mm
        assert_eq!(c[2], vec![3]); // mm feeds act
        assert!(c[3].is_empty());
    }

    #[test]
    fn validate_catches_future_reference() {
        let mut g = tiny_graph();
        g.nodes[2].inputs[0] = Slot::new(3, 0);
        assert!(g.validate().is_err());
    }
}
