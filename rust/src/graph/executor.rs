//! Node-by-node graph execution with AugmentedCGNode recording.
//!
//! This is the trainer's engine: it materializes `Init` nodes from the
//! checkpoint state / data batch, runs every operator through
//! [`run_op`](super::kernels::run_op), and (when asked) records the
//! per-node commitment
//! objects — the `AugmentedCGNode`s of paper §2.2 — whose hash sequence
//! forms the step checkpoint (Figure 2).

use std::collections::BTreeMap;

use crate::hash::{hash_tensor, merkle::MerkleTree, Hash, Hasher};
use crate::tensor::Tensor;

use super::kernels::{run_op, Backend};
use super::{Graph, InitKind, NodeId, Op};

// ---------------------------------------------------------------------------
// state
// ---------------------------------------------------------------------------

/// The training-program state machine's state (paper §2.1): learnable
/// parameters plus optimizer state, after `step` completed steps.
/// `BTreeMap` gives every party the same canonical ordering.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct State {
    pub step: u64,
    pub params: BTreeMap<String, Tensor>,
    pub opt: BTreeMap<String, Tensor>,
}

impl State {
    /// Canonical leaf list: `(domain-separated name, tensor hash)` for every
    /// state tensor, params first then optimizer state, name-ascending.
    pub fn leaf_hashes(&self) -> Vec<Hash> {
        let mut out = Vec::with_capacity(self.params.len() + self.opt.len());
        for (name, t) in &self.params {
            let mut h = Hasher::new("verde.state-leaf.param.v1");
            h.str(name);
            let th = hash_tensor(t);
            h.hash(&th);
            out.push(h.finish());
        }
        for (name, t) in &self.opt {
            let mut h = Hasher::new("verde.state-leaf.opt.v1");
            h.str(name);
            let th = hash_tensor(t);
            h.hash(&th);
            out.push(h.finish());
        }
        out
    }

    /// Index of a state tensor's leaf within [`State::leaf_hashes`].
    pub fn leaf_index(&self, kind: &InitKind, name: &str) -> Option<usize> {
        match kind {
            InitKind::Param => self.params.keys().position(|k| k == name),
            InitKind::OptState => {
                self.opt.keys().position(|k| k == name).map(|i| i + self.params.len())
            }
            InitKind::Data => None,
        }
    }

    /// The initial checkpoint commitment `C_0`: a Merkle tree over the state
    /// leaves (there is no producing step yet). Per-step checkpoints are
    /// instead committed via their node-hash trees ([`StepTrace::commit`]).
    pub fn genesis_commitment(&self) -> MerkleTree {
        MerkleTree::build(&self.leaf_hashes())
    }

    /// The Merkle root over this state's leaves — the commitment a
    /// checkpoint upload is verified against during segment state-transfer
    /// (same tree as [`State::genesis_commitment`], usable at any step).
    pub fn state_root(&self) -> Hash {
        self.genesis_commitment().root()
    }

    /// Total FP32 payload size (storage accounting for §2.1 cost analysis).
    pub fn byte_len(&self) -> usize {
        self.params.values().map(Tensor::byte_len).sum::<usize>()
            + self.opt.values().map(Tensor::byte_len).sum::<usize>()
    }
}

// ---------------------------------------------------------------------------
// AugmentedCGNode
// ---------------------------------------------------------------------------

/// The paper's per-node commitment object (§2.2): graph structure (wiring +
/// operator + attributes, folded into `structure`) plus the hashes of every
/// tensor flowing in and out.
#[derive(Debug, Clone, PartialEq)]
pub struct AugmentedCGNode {
    pub id: NodeId,
    /// `Graph::node_structure_hash(id)` — commits inputs wiring, operator,
    /// attributes.
    pub structure: Hash,
    pub input_hashes: Vec<Hash>,
    pub output_hashes: Vec<Hash>,
}

impl AugmentedCGNode {
    /// The node hash exchanged in Phase 2 (Algorithm 2 lines 4–5).
    pub fn commit(&self) -> Hash {
        let mut h = Hasher::new("verde.augnode.v1");
        h.u64(self.id as u64);
        h.hash(&self.structure);
        h.u64(self.input_hashes.len() as u64);
        for ih in &self.input_hashes {
            h.hash(ih);
        }
        h.u64(self.output_hashes.len() as u64);
        for oh in &self.output_hashes {
            h.hash(oh);
        }
        h.finish()
    }

    /// Wire size (communication accounting).
    pub fn byte_len(&self) -> usize {
        8 + 32 + 32 * (self.input_hashes.len() + self.output_hashes.len()) + 16
    }
}

// ---------------------------------------------------------------------------
// trace
// ---------------------------------------------------------------------------

/// Everything a trainer records about one executed training step.
#[derive(Debug, Clone)]
pub struct StepTrace {
    /// 1-based index of the step this trace executed.
    pub step: u64,
    pub nodes: Vec<AugmentedCGNode>,
    /// `nodes[i].commit()`, cached.
    pub node_hashes: Vec<Hash>,
    /// Full output tensors per node — kept only during dispute re-execution
    /// (`ExecOpts::keep_values`), not during normal training.
    pub values: Option<Vec<Vec<Tensor>>>,
}

impl StepTrace {
    /// The checkpoint commitment after this step: Merkle tree whose leaves
    /// are the step's node hashes (paper Figure 2). Verified against the
    /// Phase 2 hash sequence in Algorithm 2 line 7.
    pub fn commit(&self) -> MerkleTree {
        MerkleTree::build(&self.node_hashes)
    }

    pub fn root(&self) -> Hash {
        self.commit().root()
    }
}

// ---------------------------------------------------------------------------
// execution
// ---------------------------------------------------------------------------

/// A mutation applied to a node's freshly-computed outputs — the fault
/// injection hook dishonest trainers use ([`crate::verde::faults`]).
/// Receives `(node id, node inputs, outputs-to-mutate)`.
pub type TamperFn<'a> = &'a dyn Fn(NodeId, &[&Tensor], &mut Vec<Tensor>);

/// A substitution applied to a node's *input* tensor before compute and
/// hashing — models a trainer that feeds an operator a value its upstream
/// never produced (the forged-lineage fault, referee Case 2b).
/// Receives `(consumer node id, input index, true tensor)`.
pub type InputSwapFn<'a> = &'a dyn Fn(NodeId, usize, &Tensor) -> Option<Tensor>;

/// Execution options.
#[derive(Default)]
pub struct ExecOpts<'a> {
    /// Record AugmentedCGNodes (hashing every edge tensor). Off on the fast
    /// honest path except at checkpoint steps; on during dispute.
    pub record_trace: bool,
    /// Retain all node output tensors in the trace (dispute re-execution).
    pub keep_values: bool,
    /// Fault injection (dishonest trainers only).
    pub tamper: Option<TamperFn<'a>>,
    /// Input substitution (dishonest trainers only).
    pub input_swap: Option<InputSwapFn<'a>>,
}

/// Result of executing a graph.
pub struct Execution {
    /// Output tensors per node (always present during execution; pruned to
    /// requested outputs unless `keep_values`).
    pub values: Vec<Vec<Tensor>>,
    pub trace: Option<Vec<AugmentedCGNode>>,
}

/// Per-op-mnemonic wall-time accumulator (enabled by `VERDE_PROFILE=1`) —
/// the whole-stack profiling hook of the §Perf pass.
#[derive(Debug, Default)]
pub struct OpProfile {
    pub by_op: std::collections::BTreeMap<&'static str, (u64, std::time::Duration)>,
}

impl OpProfile {
    pub fn report(&self) -> String {
        let mut rows: Vec<_> = self.by_op.iter().collect();
        rows.sort_by_key(|(_, (_, d))| std::cmp::Reverse(*d));
        let total: std::time::Duration = self.by_op.values().map(|(_, d)| *d).sum();
        let mut s = format!("total {total:?}\n");
        for (op, (n, d)) in rows.into_iter().take(12) {
            s.push_str(&format!(
                "  {:<16} {:>8} calls {:>12?} ({:>4.1}%)\n",
                op,
                n,
                d,
                100.0 * d.as_secs_f64() / total.as_secs_f64()
            ));
        }
        s
    }
}

static PROFILE_ENABLED: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
static PROFILE: std::sync::Mutex<Option<OpProfile>> = std::sync::Mutex::new(None);

fn profile_enabled() -> bool {
    *PROFILE_ENABLED.get_or_init(|| std::env::var_os("VERDE_PROFILE").is_some())
}

/// Take and reset the global op profile (used with `VERDE_PROFILE=1`).
pub fn take_profile() -> Option<OpProfile> {
    PROFILE.lock().unwrap().take()
}

/// Execute `graph` with `Init` nodes fed from `state` (params/opt) and
/// `batch` (data tensors by name). `step_t` is the 1-based step index.
pub fn execute(
    graph: &Graph,
    state: &State,
    batch: &BTreeMap<String, Tensor>,
    backend: Backend,
    step_t: u64,
    opts: &ExecOpts,
) -> Execution {
    let mut values: Vec<Vec<Tensor>> = Vec::with_capacity(graph.len());
    let mut trace = if opts.record_trace { Some(Vec::with_capacity(graph.len())) } else { None };

    for node in &graph.nodes {
        // 1. materialize inputs (possibly substituted by a dishonest swap)
        let swapped: Vec<Option<Tensor>> = node
            .inputs
            .iter()
            .enumerate()
            .map(|(j, s)| {
                opts.input_swap
                    .and_then(|f| f(node.id, j, &values[s.node][s.out_idx]))
            })
            .collect();
        let inputs: Vec<&Tensor> = node
            .inputs
            .iter()
            .zip(&swapped)
            .map(|(s, sw)| sw.as_ref().unwrap_or(&values[s.node][s.out_idx]))
            .collect();

        // 2. compute
        let op_t0 = if profile_enabled() { Some(std::time::Instant::now()) } else { None };
        let mut outs: Vec<Tensor> = match &node.op {
            Op::Init { kind, name } => {
                let t = match kind {
                    InitKind::Param => state.params.get(name).unwrap_or_else(|| {
                        panic!("param '{name}' missing from state")
                    }),
                    InitKind::OptState => state.opt.get(name).unwrap_or_else(|| {
                        panic!("optimizer state '{name}' missing from state")
                    }),
                    InitKind::Data => batch.get(name).unwrap_or_else(|| {
                        panic!("data tensor '{name}' missing from batch")
                    }),
                };
                vec![t.clone()]
            }
            op => run_op(op, &inputs, backend, step_t),
        };
        debug_assert_eq!(outs.len(), node.op.n_outputs());
        if let Some(t0) = op_t0 {
            let mut guard = PROFILE.lock().unwrap();
            let prof = guard.get_or_insert_with(OpProfile::default);
            let e = prof.by_op.entry(node.op.mnemonic()).or_insert((0, std::time::Duration::ZERO));
            e.0 += 1;
            e.1 += t0.elapsed();
        }

        // 3. fault injection
        if let Some(tamper) = opts.tamper {
            tamper(node.id, &inputs, &mut outs);
        }

        // 4. record the AugmentedCGNode — the cheater hashes the inputs it
        //    actually used, so its lie is internally consistent
        if let Some(tr) = trace.as_mut() {
            let input_hashes = inputs.iter().map(|t| hash_tensor(t)).collect();
            let output_hashes = outs.iter().map(hash_tensor).collect();
            tr.push(AugmentedCGNode {
                id: node.id,
                structure: graph.node_structure_hash(node.id),
                input_hashes,
                output_hashes,
            });
        }

        values.push(outs);
    }

    Execution { values, trace }
}

/// Convenience: execute and build the [`StepTrace`] (dispute path).
pub fn execute_traced(
    graph: &Graph,
    state: &State,
    batch: &BTreeMap<String, Tensor>,
    backend: Backend,
    step_t: u64,
    keep_values: bool,
    tamper: Option<TamperFn>,
) -> (Execution, StepTrace) {
    execute_traced_swap(graph, state, batch, backend, step_t, keep_values, tamper, None)
}

/// [`execute_traced`] with an optional dishonest input substitution.
#[allow(clippy::too_many_arguments)]
pub fn execute_traced_swap(
    graph: &Graph,
    state: &State,
    batch: &BTreeMap<String, Tensor>,
    backend: Backend,
    step_t: u64,
    keep_values: bool,
    tamper: Option<TamperFn>,
    input_swap: Option<InputSwapFn>,
) -> (Execution, StepTrace) {
    let opts = ExecOpts { record_trace: true, keep_values, tamper, input_swap };
    let exec = execute(graph, state, batch, backend, step_t, &opts);
    let nodes = exec.trace.clone().expect("trace requested");
    let node_hashes = nodes.iter().map(AugmentedCGNode::commit).collect();
    let values = if keep_values { Some(exec.values.clone()) } else { None };
    let trace = StepTrace { step: step_t, nodes, node_hashes, values };
    (exec, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Op, Slot};
    use crate::tensor::profile::HardwareProfile;

    /// y = gelu(x @ w); loss-free toy graph.
    fn toy() -> (Graph, State, BTreeMap<String, Tensor>) {
        let mut g = Graph::new();
        let x = g.push("x", Op::Init { kind: InitKind::Data, name: "x".into() }, vec![]);
        let w = g.push("w", Op::Init { kind: InitKind::Param, name: "w".into() }, vec![]);
        let mm = g.push("mm", Op::MatMul, vec![Slot::new(x, 0), Slot::new(w, 0)]);
        g.push("act", Op::Gelu, vec![Slot::new(mm, 0)]);
        let mut state = State::default();
        state.params.insert("w".into(), Tensor::rand([4, 3], 1, 1.0));
        let mut batch = BTreeMap::new();
        batch.insert("x".into(), Tensor::rand([2, 4], 2, 1.0));
        (g, state, batch)
    }

    #[test]
    fn execute_produces_expected_values() {
        let (g, state, batch) = toy();
        let e = execute(&g, &state, &batch, Backend::Rep, 1, &ExecOpts::default());
        assert_eq!(e.values.len(), 4);
        let want = crate::tensor::repops::gelu(&crate::tensor::repops::matmul(
            &batch["x"],
            &state.params["w"],
        ));
        assert!(e.values[3][0].bit_eq(&want));
        assert!(e.trace.is_none());
    }

    #[test]
    fn trace_hashes_match_recomputation() {
        let (g, state, batch) = toy();
        let (_, t1) = execute_traced(&g, &state, &batch, Backend::Rep, 1, false, None);
        let (_, t2) = execute_traced(&g, &state, &batch, Backend::Rep, 1, false, None);
        assert_eq!(t1.node_hashes, t2.node_hashes, "deterministic trace");
        assert_eq!(t1.root(), t2.root());
        assert_eq!(t1.nodes.len(), 4);
        // input hashes of mm node reference x and w payloads
        assert_eq!(t1.nodes[2].input_hashes[0], hash_tensor(&batch["x"]));
        assert_eq!(t1.nodes[2].input_hashes[1], hash_tensor(&state.params["w"]));
    }

    #[test]
    fn tamper_changes_exactly_downstream_hashes() {
        let (g, state, batch) = toy();
        let (_, honest) = execute_traced(&g, &state, &batch, Backend::Rep, 1, false, None);
        let tamper = |id: NodeId, _ins: &[&Tensor], outs: &mut Vec<Tensor>| {
            if id == 2 {
                outs[0].data_mut()[0] += 1.0;
            }
        };
        let (_, bad) = execute_traced(&g, &state, &batch, Backend::Rep, 1, false, Some(&tamper));
        assert_eq!(honest.node_hashes[0], bad.node_hashes[0]);
        assert_eq!(honest.node_hashes[1], bad.node_hashes[1]);
        assert_ne!(honest.node_hashes[2], bad.node_hashes[2], "tampered node");
        assert_ne!(honest.node_hashes[3], bad.node_hashes[3], "downstream");
        assert_ne!(honest.root(), bad.root());
        // and the first divergence is exactly node 2
        let d = honest
            .node_hashes
            .iter()
            .zip(&bad.node_hashes)
            .position(|(a, b)| a != b);
        assert_eq!(d, Some(2));
    }

    #[test]
    fn backends_diverge_on_trace_but_are_self_consistent() {
        let (g, state, batch) = toy();
        let (_, rep) = execute_traced(&g, &state, &batch, Backend::Rep, 1, false, None);
        let (_, t4) = execute_traced(
            &g,
            &state,
            &batch,
            Backend::Free(HardwareProfile::T4_16G),
            1,
            false,
            None,
        );
        let (_, t4b) = execute_traced(
            &g,
            &state,
            &batch,
            Backend::Free(HardwareProfile::T4_16G),
            1,
            false,
            None,
        );
        assert_eq!(t4.node_hashes, t4b.node_hashes);
        // Init nodes agree between backends; compute nodes may differ.
        assert_eq!(rep.node_hashes[0], t4.node_hashes[0]);
        assert_eq!(rep.node_hashes[1], t4.node_hashes[1]);
    }

    #[test]
    fn state_leaf_index_and_genesis() {
        let mut state = State::default();
        state.params.insert("b".into(), Tensor::zeros([2]));
        state.params.insert("a".into(), Tensor::zeros([2]));
        state.opt.insert("a.m".into(), Tensor::zeros([2]));
        let leaves = state.leaf_hashes();
        assert_eq!(leaves.len(), 3);
        assert_eq!(state.leaf_index(&InitKind::Param, "a"), Some(0));
        assert_eq!(state.leaf_index(&InitKind::Param, "b"), Some(1));
        assert_eq!(state.leaf_index(&InitKind::OptState, "a.m"), Some(2));
        assert_eq!(state.leaf_index(&InitKind::Param, "zz"), None);
        let tree = state.genesis_commitment();
        assert_eq!(tree.leaf_count(), 3);
        // membership proof of param "a" verifies
        let p = tree.prove(0);
        assert!(MerkleTree::verify(&tree.root(), &leaves[0], &p));
    }

    #[test]
    #[should_panic(expected = "missing from state")]
    fn missing_param_panics() {
        let (g, _, batch) = toy();
        let state = State::default();
        execute(&g, &state, &batch, Backend::Rep, 1, &ExecOpts::default());
    }
}
