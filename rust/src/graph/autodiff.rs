//! Extended-graph construction (paper Figure 1): forward graph → forward +
//! backward + optimizer-update nodes.
//!
//! "This extended graph can be implicitly derived from the computational
//! graph representing the forward pass of the model … and an automatic
//! differentiation library like autograd" (§2.2). This module is that
//! autograd: reverse-mode VJP emission over the forward [`Graph`], followed
//! by one optimizer-update node per learnable parameter. The "saved tensor"
//! context edges of Figure 1 appear naturally: backward nodes consume the
//! forward nodes' output slots directly.
//!
//! Gradient accumulation for fan-out is emitted as a fixed ascending-id
//! chain of `Add` nodes, so the extended graph itself — not just its
//! execution — is canonical across parties.

use std::collections::{BTreeMap, HashMap};

use super::builder::GraphBuilder;
use super::{Graph, InitKind, Op, Slot};
use crate::tensor::Tensor;

/// Optimizer choice for the update nodes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Optimizer {
    Adam { lr: f32, beta1: f32, beta2: f32, eps: f32 },
    Sgd { lr: f32 },
}

impl Optimizer {
    pub fn adam(lr: f32) -> Optimizer {
        Optimizer::Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }

    /// Names of the per-parameter optimizer-state tensors.
    pub fn state_suffixes(&self) -> &'static [&'static str] {
        match self {
            Optimizer::Adam { .. } => &[".m", ".v"],
            Optimizer::Sgd { .. } => &[],
        }
    }
}

/// A complete training-step program: the extended computational graph plus
/// the slots where the next state is read from after execution.
#[derive(Debug, Clone)]
pub struct TrainStep {
    pub graph: Graph,
    /// Loss slot (scalar), for logging.
    pub loss: Slot,
    /// Parameter name → slot holding its updated value. Parameters absent
    /// here are frozen (e.g. base weights under LoRA) and carry over.
    pub param_updates: BTreeMap<String, Slot>,
    /// Optimizer-state name (`"<param>.m"` / `"<param>.v"`) → updated slot.
    pub opt_updates: BTreeMap<String, Slot>,
    /// Gradient slot per trainable parameter (exposed for tests/inspection).
    pub grads: BTreeMap<String, Slot>,
    /// Number of nodes in the original forward prefix.
    pub forward_len: usize,
}

/// Derive the extended training-step graph from a built forward pass.
///
/// * `builder` — the forward graph with static shapes.
/// * `loss` — scalar forward slot to differentiate.
/// * `opt` — optimizer applied to every parameter reached by gradients.
/// * `freeze` — parameter names to exclude from updates (LoRA base weights).
pub fn build_train_step(
    builder: &GraphBuilder,
    loss: Slot,
    opt: &Optimizer,
    freeze: &[&str],
) -> TrainStep {
    let mut g = builder.graph.clone();
    let forward_len = g.len();
    assert!(
        builder.shape(loss).is_empty(),
        "loss must be scalar, got {:?}",
        builder.shape(loss)
    );

    // ---- which slots need gradients ------------------------------------
    let mut requires = vec![false; forward_len];
    for n in &builder.graph.nodes {
        requires[n.id] = match &n.op {
            Op::Init { kind: InitKind::Param, .. } => true,
            Op::Init { .. } | Op::Const { .. } => false,
            _ => n.inputs.iter().any(|s| requires[s.node]),
        };
    }

    // ---- seed: d(loss)/d(loss) = 1 --------------------------------------
    let one = g.push("grad.seed", Op::Const { value: Tensor::scalar(1.0) }, vec![]);

    // pending[slot] = list of gradient contributions, ascending producer id
    let mut pending: HashMap<Slot, Vec<Slot>> = HashMap::new();
    pending.insert(loss, vec![Slot::new(one, 0)]);

    // Combine contributions with a fixed-order Add chain.
    fn combined(g: &mut Graph, pending: &mut HashMap<Slot, Vec<Slot>>, s: Slot) -> Option<Slot> {
        let mut list = pending.remove(&s)?;
        list.sort_by_key(|c| (c.node, c.out_idx));
        let mut acc = list[0];
        for c in &list[1..] {
            let id = g.push("grad.acc", Op::Add, vec![acc, *c]);
            acc = Slot::new(id, 0);
        }
        Some(acc)
    }

    let mut add = |pending: &mut HashMap<Slot, Vec<Slot>>, s: Slot, grad: Slot| {
        pending.entry(s).or_default().push(grad);
    };

    // grads of parameter init nodes, discovered as we sweep
    let mut param_grads: BTreeMap<String, Slot> = BTreeMap::new();

    // ---- reverse sweep ----------------------------------------------------
    for id in (0..forward_len).rev() {
        let node = builder.graph.nodes[id].clone();
        if !requires[id] {
            continue;
        }
        // Only single-output forward ops are differentiable (grad/update ops
        // never appear in a forward graph).
        let dy = match combined(&mut g, &mut pending, Slot::new(id, 0)) {
            Some(s) => s,
            None => continue, // no path to the loss
        };
        let lbl = |suffix: &str| format!("d.{}.{}", node.label, suffix);
        let ins = &node.inputs;
        match &node.op {
            Op::Init { kind: InitKind::Param, name } => {
                param_grads.insert(name.clone(), dy);
            }
            Op::Init { .. } | Op::Const { .. } => {}

            Op::Reshape { .. } => {
                let orig = builder.shape(ins[0]).to_vec();
                let r = g.push(lbl("reshape"), Op::Reshape { shape: orig }, vec![dy]);
                add(&mut pending, ins[0], Slot::new(r, 0));
            }
            Op::Transpose2D => {
                let r = g.push(lbl("t"), Op::Transpose2D, vec![dy]);
                add(&mut pending, ins[0], Slot::new(r, 0));
            }
            Op::TransposeLast2 => {
                let r = g.push(lbl("t"), Op::TransposeLast2, vec![dy]);
                add(&mut pending, ins[0], Slot::new(r, 0));
            }
            Op::Perm0213 => {
                let r = g.push(lbl("perm"), Op::Perm0213, vec![dy]);
                add(&mut pending, ins[0], Slot::new(r, 0));
            }
            Op::Embedding => {
                // inputs: (table, ids); ids get no grad
                if requires[ins[0].node] {
                    let vocab = builder.shape(ins[0])[0];
                    let r = g.push(lbl("table"), Op::EmbeddingGrad { vocab }, vec![ins[1], dy]);
                    add(&mut pending, ins[0], Slot::new(r, 0));
                }
            }
            Op::Add => {
                if requires[ins[0].node] {
                    add(&mut pending, ins[0], dy);
                }
                if requires[ins[1].node] {
                    add(&mut pending, ins[1], dy);
                }
            }
            Op::Sub => {
                if requires[ins[0].node] {
                    add(&mut pending, ins[0], dy);
                }
                if requires[ins[1].node] {
                    let r = g.push(lbl("neg"), Op::Scale { c: -1.0 }, vec![dy]);
                    add(&mut pending, ins[1], Slot::new(r, 0));
                }
            }
            Op::Mul => {
                if requires[ins[0].node] {
                    let r = g.push(lbl("a"), Op::Mul, vec![dy, ins[1]]);
                    add(&mut pending, ins[0], Slot::new(r, 0));
                }
                if requires[ins[1].node] {
                    let r = g.push(lbl("b"), Op::Mul, vec![dy, ins[0]]);
                    add(&mut pending, ins[1], Slot::new(r, 0));
                }
            }
            Op::AddBcast => {
                if requires[ins[0].node] {
                    add(&mut pending, ins[0], dy);
                }
                if requires[ins[1].node] {
                    let suffix_rank = builder.shape(ins[1]).len();
                    let r = g.push(lbl("b"), Op::SumLeading { suffix_rank }, vec![dy]);
                    add(&mut pending, ins[1], Slot::new(r, 0));
                }
            }
            Op::Scale { c } => {
                let r = g.push(lbl("s"), Op::Scale { c: *c }, vec![dy]);
                add(&mut pending, ins[0], Slot::new(r, 0));
            }
            Op::Gelu => {
                let r = g.push(lbl("gelu"), Op::GeluGrad, vec![ins[0], dy]);
                add(&mut pending, ins[0], Slot::new(r, 0));
            }
            Op::Silu => {
                let r = g.push(lbl("silu"), Op::SiluGrad, vec![ins[0], dy]);
                add(&mut pending, ins[0], Slot::new(r, 0));
            }
            Op::Relu => {
                let r = g.push(lbl("relu"), Op::ReluGrad, vec![ins[0], dy]);
                add(&mut pending, ins[0], Slot::new(r, 0));
            }
            Op::Tanh => {
                // saved tensor: the forward output y
                let r = g.push(lbl("tanh"), Op::TanhGrad, vec![Slot::new(id, 0), dy]);
                add(&mut pending, ins[0], Slot::new(r, 0));
            }
            Op::MatMul => {
                // da = dy @ bᵀ ; db = aᵀ @ dy
                if requires[ins[0].node] {
                    let bt = g.push(lbl("bt"), Op::Transpose2D, vec![ins[1]]);
                    let r = g.push(lbl("a"), Op::MatMul, vec![dy, Slot::new(bt, 0)]);
                    add(&mut pending, ins[0], Slot::new(r, 0));
                }
                if requires[ins[1].node] {
                    let at = g.push(lbl("at"), Op::Transpose2D, vec![ins[0]]);
                    let r = g.push(lbl("b"), Op::MatMul, vec![Slot::new(at, 0), dy]);
                    add(&mut pending, ins[1], Slot::new(r, 0));
                }
            }
            Op::BatchMatMul => {
                if requires[ins[0].node] {
                    let bt = g.push(lbl("bt"), Op::TransposeLast2, vec![ins[1]]);
                    let r = g.push(lbl("a"), Op::BatchMatMul, vec![dy, Slot::new(bt, 0)]);
                    add(&mut pending, ins[0], Slot::new(r, 0));
                }
                if requires[ins[1].node] {
                    let at = g.push(lbl("at"), Op::TransposeLast2, vec![ins[0]]);
                    let r = g.push(lbl("b"), Op::BatchMatMul, vec![Slot::new(at, 0), dy]);
                    add(&mut pending, ins[1], Slot::new(r, 0));
                }
            }
            Op::Softmax => {
                let r = g.push(lbl("softmax"), Op::SoftmaxGrad, vec![Slot::new(id, 0), dy]);
                add(&mut pending, ins[0], Slot::new(r, 0));
            }
            Op::LayerNorm { eps } => {
                let r = g.push(
                    lbl("ln"),
                    Op::LayerNormGrad { eps: *eps },
                    vec![ins[0], ins[1], dy],
                );
                if requires[ins[0].node] {
                    add(&mut pending, ins[0], Slot::new(r, 0));
                }
                if requires[ins[1].node] {
                    add(&mut pending, ins[1], Slot::new(r, 1));
                }
                if requires[ins[2].node] {
                    add(&mut pending, ins[2], Slot::new(r, 2));
                }
            }
            Op::RmsNorm { eps } => {
                let r = g.push(
                    lbl("rms"),
                    Op::RmsNormGrad { eps: *eps },
                    vec![ins[0], ins[1], dy],
                );
                if requires[ins[0].node] {
                    add(&mut pending, ins[0], Slot::new(r, 0));
                }
                if requires[ins[1].node] {
                    add(&mut pending, ins[1], Slot::new(r, 1));
                }
            }
            Op::Rope => {
                let r = g.push(lbl("rope"), Op::RopeGrad, vec![dy, ins[1], ins[2]]);
                add(&mut pending, ins[0], Slot::new(r, 0));
            }
            Op::CeLoss => {
                let r = g.push(lbl("ce"), Op::CeGrad, vec![ins[0], ins[1], dy]);
                add(&mut pending, ins[0], Slot::new(r, 0));
            }
            other => panic!(
                "op {} cannot appear in a forward graph",
                other.mnemonic()
            ),
        }
    }

    // ---- optimizer update nodes -----------------------------------------
    // One update node per trainable parameter, in forward declaration order
    // (canonical across parties).
    let mut param_updates = BTreeMap::new();
    let mut opt_updates = BTreeMap::new();
    for (pid, pname) in builder.graph.init_nodes(&InitKind::Param) {
        if freeze.contains(&pname.as_str()) {
            continue;
        }
        let grad = match param_grads.get(&pname) {
            Some(s) => *s,
            None => continue, // unreachable from loss → frozen implicitly
        };
        let w = Slot::new(pid, 0);
        match opt {
            Optimizer::Adam { lr, beta1, beta2, eps } => {
                let m = g.push(
                    format!("{pname}.m"),
                    Op::Init { kind: InitKind::OptState, name: format!("{pname}.m") },
                    vec![],
                );
                let v = g.push(
                    format!("{pname}.v"),
                    Op::Init { kind: InitKind::OptState, name: format!("{pname}.v") },
                    vec![],
                );
                let u = g.push(
                    format!("update.{pname}"),
                    Op::AdamUpdate { lr: *lr, beta1: *beta1, beta2: *beta2, eps: *eps },
                    vec![w, grad, Slot::new(m, 0), Slot::new(v, 0)],
                );
                param_updates.insert(pname.clone(), Slot::new(u, 0));
                opt_updates.insert(format!("{pname}.m"), Slot::new(u, 1));
                opt_updates.insert(format!("{pname}.v"), Slot::new(u, 2));
            }
            Optimizer::Sgd { lr } => {
                let u = g.push(
                    format!("update.{pname}"),
                    Op::SgdUpdate { lr: *lr },
                    vec![w, grad],
                );
                param_updates.insert(pname.clone(), Slot::new(u, 0));
            }
        }
    }

    g.validate().expect("extended graph invalid");
    TrainStep { graph: g, loss, param_updates, opt_updates, grads: param_grads, forward_len }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::executor::{execute, ExecOpts, State};
    use crate::graph::kernels::Backend;
    use crate::tensor::repops;
    use std::collections::BTreeMap;

    /// loss = CE(gelu(x@w1 + b) @ w2, targets)
    fn mlp_builder() -> (GraphBuilder, Slot) {
        let mut b = GraphBuilder::new();
        let x = b.data("x", [4, 8]);
        let t = b.data("t", [4]);
        let w1 = b.param("w1", [8, 16]);
        let b1 = b.param("b1", [16]);
        let w2 = b.param("w2", [16, 10]);
        let h = b.matmul("fc1", x, w1);
        let hb = b.add_bcast("bias1", h, b1);
        let a = b.gelu("act", hb);
        let logits = b.matmul("fc2", a, w2);
        let loss = b.ce_loss("loss", logits, t);
        (b, loss)
    }

    fn mlp_state(seed: u64) -> (State, BTreeMap<String, Tensor>) {
        let mut st = State::default();
        st.params.insert("w1".into(), Tensor::rand([8, 16], seed, 0.5));
        st.params.insert("b1".into(), Tensor::rand([16], seed + 1, 0.1));
        st.params.insert("w2".into(), Tensor::rand([16, 10], seed + 2, 0.5));
        let mut batch = BTreeMap::new();
        batch.insert(
            "x".into(),
            Tensor::rand([4, 8], seed + 3, 1.0),
        );
        batch.insert("t".into(), Tensor::new([4], vec![1.0, 3.0, 5.0, 9.0]));
        (st, batch)
    }

    fn init_opt_state(st: &mut State, ts: &TrainStep) {
        for name in ts.opt_updates.keys() {
            let pname = name.rsplit_once('.').unwrap().0;
            let shape = st.params[pname].shape().to_vec();
            st.opt.insert(name.clone(), Tensor::zeros(shape));
        }
    }

    #[test]
    fn extended_graph_structure() {
        let (b, loss) = mlp_builder();
        let ts = build_train_step(&b, loss, &Optimizer::adam(1e-3), &[]);
        assert_eq!(ts.param_updates.len(), 3);
        assert_eq!(ts.opt_updates.len(), 6);
        assert_eq!(ts.grads.len(), 3);
        assert!(ts.graph.len() > b.graph.len());
        ts.graph.validate().unwrap();
    }

    #[test]
    fn param_grads_match_finite_difference() {
        let (b, loss) = mlp_builder();
        let ts = build_train_step(&b, loss, &Optimizer::Sgd { lr: 0.1 }, &[]);
        let (mut st, batch) = mlp_state(7);
        init_opt_state(&mut st, &ts);
        let e = execute(&ts.graph, &st, &batch, Backend::Rep, 1, &ExecOpts::default());
        let loss_at = |st: &State| {
            let e = execute(&ts.graph, st, &batch, Backend::Rep, 1, &ExecOpts::default());
            e.values[ts.loss.node][0].data()[0]
        };
        for (pname, gslot) in &ts.grads {
            let g = &e.values[gslot.node][gslot.out_idx];
            // probe a few indices with central differences
            for idx in [0, g.numel() / 2, g.numel() - 1] {
                let h = 1e-2f32;
                let mut stp = st.clone();
                stp.params.get_mut(pname).unwrap().data_mut()[idx] += h;
                let mut stm = st.clone();
                stm.params.get_mut(pname).unwrap().data_mut()[idx] -= h;
                let fd = (loss_at(&stp) - loss_at(&stm)) / (2.0 * h);
                let got = g.data()[idx];
                assert!(
                    (got - fd).abs() < 2e-2_f32.max(fd.abs() * 0.1),
                    "{pname}[{idx}]: analytic {got} vs fd {fd}"
                );
            }
        }
    }

    #[test]
    fn sgd_step_decreases_loss() {
        let (b, loss) = mlp_builder();
        let ts = build_train_step(&b, loss, &Optimizer::Sgd { lr: 0.05 }, &[]);
        let (mut st, batch) = mlp_state(11);
        init_opt_state(&mut st, &ts);
        let mut losses = Vec::new();
        for step in 1..=20u64 {
            let e = execute(&ts.graph, &st, &batch, Backend::Rep, step, &ExecOpts::default());
            losses.push(e.values[ts.loss.node][0].data()[0]);
            let mut next = st.clone();
            for (name, slot) in &ts.param_updates {
                next.params.insert(name.clone(), e.values[slot.node][slot.out_idx].clone());
            }
            next.step += 1;
            st = next;
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.8),
            "loss should drop: {losses:?}"
        );
    }

    #[test]
    fn adam_step_decreases_loss_and_updates_moments() {
        let (b, loss) = mlp_builder();
        let ts = build_train_step(&b, loss, &Optimizer::adam(0.01), &[]);
        let (mut st, batch) = mlp_state(13);
        init_opt_state(&mut st, &ts);
        let mut first = None;
        let mut last = 0.0;
        for step in 1..=25u64 {
            let e = execute(&ts.graph, &st, &batch, Backend::Rep, step, &ExecOpts::default());
            last = e.values[ts.loss.node][0].data()[0];
            first.get_or_insert(last);
            let mut next = st.clone();
            for (name, slot) in &ts.param_updates {
                next.params.insert(name.clone(), e.values[slot.node][slot.out_idx].clone());
            }
            for (name, slot) in &ts.opt_updates {
                next.opt.insert(name.clone(), e.values[slot.node][slot.out_idx].clone());
            }
            next.step += 1;
            st = next;
        }
        assert!(last < first.unwrap() * 0.7, "adam: {first:?} -> {last}");
        assert!(st.opt["w1.m"].data().iter().any(|&x| x != 0.0));
        assert!(st.opt["w1.v"].data().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn freeze_excludes_params() {
        let (b, loss) = mlp_builder();
        let ts = build_train_step(&b, loss, &Optimizer::adam(0.01), &["w1", "b1"]);
        assert!(!ts.param_updates.contains_key("w1"));
        assert!(!ts.param_updates.contains_key("b1"));
        assert!(ts.param_updates.contains_key("w2"));
        // frozen params need no optimizer state
        assert!(!ts.opt_updates.contains_key("w1.m"));
        assert_eq!(ts.opt_updates.len(), 2);
    }

    #[test]
    fn fanout_grads_accumulate() {
        // y = sum-ish over (w used twice): loss = CE((x@w) + (x@w), t)
        let mut b = GraphBuilder::new();
        let x = b.data("x", [2, 4]);
        let t = b.data("t", [2]);
        let w = b.param("w", [4, 6]);
        let h1 = b.matmul("h1", x, w);
        let h2 = b.matmul("h2", x, w);
        let s = b.add("s", h1, h2);
        let loss = b.ce_loss("loss", s, t);
        let ts = build_train_step(&b, loss, &Optimizer::Sgd { lr: 0.1 }, &[]);
        let mut st = State::default();
        st.params.insert("w".into(), Tensor::rand([4, 6], 1, 0.5));
        let mut batch = BTreeMap::new();
        batch.insert("x".into(), Tensor::rand([2, 4], 2, 1.0));
        batch.insert("t".into(), Tensor::new([2], vec![0.0, 3.0]));
        let e = execute(&ts.graph, &st, &batch, Backend::Rep, 1, &ExecOpts::default());
        let g = &e.values[ts.grads["w"].node][ts.grads["w"].out_idx];
        // finite difference on one index
        let loss_at = |st: &State| {
            execute(&ts.graph, st, &batch, Backend::Rep, 1, &ExecOpts::default()).values
                [ts.loss.node][0]
                .data()[0]
        };
        let h = 1e-2f32;
        let mut stp = st.clone();
        stp.params.get_mut("w").unwrap().data_mut()[5] += h;
        let mut stm = st.clone();
        stm.params.get_mut("w").unwrap().data_mut()[5] -= h;
        let fd = (loss_at(&stp) - loss_at(&stm)) / (2.0 * h);
        assert!((g.data()[5] - fd).abs() < 2e-2, "{} vs {fd}", g.data()[5]);
    }

    #[test]
    fn extended_graph_is_canonical() {
        let (b1, l1) = mlp_builder();
        let (b2, l2) = mlp_builder();
        let t1 = build_train_step(&b1, l1, &Optimizer::adam(1e-3), &[]);
        let t2 = build_train_step(&b2, l2, &Optimizer::adam(1e-3), &[]);
        assert_eq!(t1.graph.structure_hash(), t2.graph.structure_hash());
    }
}
