//! Operator execution: maps each [`Op`] onto the RepOps or baseline tensor
//! kernels, under a chosen [`Backend`].
//!
//! `Backend::Rep` is the paper's RepOps path — bitwise identical on every
//! host. `Backend::Free(profile)` is the "ordinary tuned library" path whose
//! reduction order follows the simulated hardware profile; running the same
//! program under two different profiles is how the test-suite (and the
//! `NonRepHardware` fault) reproduces cross-hardware divergence.

use crate::tensor::baseline;
use crate::tensor::math;
use crate::tensor::profile::{HardwareProfile, KernelTimer};
use crate::tensor::repops;
use crate::tensor::Tensor;
use crate::util::parallel;

use super::{InitKind, Op};

/// Which operator family executes the graph.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Backend {
    /// RepOps: fixed FP order, hardware-independent bits (paper §3).
    Rep,
    /// Free-order tuned kernels on the given simulated device.
    Free(HardwareProfile),
}

impl Backend {
    pub fn describe(&self) -> String {
        match self {
            Backend::Rep => "repops".to_string(),
            Backend::Free(hw) => format!("free[{}]", hw.name),
        }
    }

    /// The scalar sum this backend uses for order-sensitive row reductions.
    #[inline]
    fn sum(&self, xs: &[f32]) -> f32 {
        match self {
            Backend::Rep => repops::sum_slice(xs),
            Backend::Free(hw) => baseline::sum_slice(xs, hw),
        }
    }

    #[inline]
    fn exp(&self, x: f32) -> f32 {
        match self {
            Backend::Rep => math::rep_exp(x),
            Backend::Free(_) => x.exp(),
        }
    }
}

/// Fixed-order integer power (RepOps never calls `powf`).
fn pow_fixed(base: f32, exp: u64) -> f32 {
    let mut r = 1.0f32;
    for _ in 0..exp {
        r *= base;
    }
    r
}

/// Execute one operator. `step_t` is the 1-based training-step index (used
/// by Adam bias correction). `Init` nodes are materialized by the executor,
/// not here.
///
/// # Panics
/// On shape mismatches (the executor converts these into protocol-visible
/// execution failures) and on `Init` ops.
pub fn run_op(op: &Op, inputs: &[&Tensor], backend: Backend, step_t: u64) -> Vec<Tensor> {
    let timer = KernelTimer::start();
    let out = match op {
        Op::Init { .. } => panic!("Init nodes are materialized by the executor"),
        Op::Const { value } => vec![value.clone()],

        // ---- movement -------------------------------------------------
        Op::Reshape { shape } => vec![inputs[0].reshape(shape.clone())],
        Op::Transpose2D => vec![repops::transpose2d(inputs[0])],
        Op::TransposeLast2 => vec![repops::transpose_last2(inputs[0])],
        Op::Perm0213 => vec![perm0213(inputs[0])],
        Op::Embedding => vec![repops::embedding(inputs[0], inputs[1])],
        Op::EmbeddingGrad { vocab } => {
            vec![repops::embedding_grad(*vocab, inputs[0], inputs[1])]
        }

        // ---- elementwise ----------------------------------------------
        Op::Add => vec![repops::add(inputs[0], inputs[1])],
        Op::Sub => vec![repops::sub(inputs[0], inputs[1])],
        Op::Mul => vec![repops::mul(inputs[0], inputs[1])],
        Op::AddBcast => vec![add_bcast(inputs[0], inputs[1])],
        Op::Scale { c } => vec![repops::scale(inputs[0], *c)],
        Op::Gelu => vec![match backend {
            Backend::Rep => repops::gelu(inputs[0]),
            Backend::Free(_) => baseline::gelu(inputs[0]),
        }],
        Op::Silu => vec![match backend {
            Backend::Rep => repops::silu(inputs[0]),
            Backend::Free(_) => baseline::silu(inputs[0]),
        }],
        Op::Relu => vec![repops::relu(inputs[0])],
        Op::Tanh => vec![match backend {
            Backend::Rep => repops::tanh(inputs[0]),
            Backend::Free(_) => repops::map(inputs[0], |x| x.tanh()),
        }],

        // ---- contractions ----------------------------------------------
        Op::MatMul => vec![match backend {
            Backend::Rep => repops::matmul(inputs[0], inputs[1]),
            Backend::Free(hw) => baseline::matmul(inputs[0], inputs[1], &hw),
        }],
        Op::BatchMatMul => vec![match backend {
            Backend::Rep => repops::bmm(inputs[0], inputs[1]),
            Backend::Free(hw) => baseline::bmm(inputs[0], inputs[1], &hw),
        }],

        // ---- normalization / softmax / loss -----------------------------
        Op::Softmax => vec![match backend {
            Backend::Rep => repops::softmax_lastdim(inputs[0]),
            Backend::Free(hw) => baseline::softmax_lastdim(inputs[0], &hw),
        }],
        Op::LayerNorm { eps } => vec![match backend {
            Backend::Rep => repops::layernorm(inputs[0], inputs[1], inputs[2], *eps),
            Backend::Free(hw) => baseline::layernorm(inputs[0], inputs[1], inputs[2], *eps, &hw),
        }],
        Op::RmsNorm { eps } => vec![match backend {
            Backend::Rep => repops::rmsnorm(inputs[0], inputs[1], *eps),
            Backend::Free(hw) => baseline::rmsnorm(inputs[0], inputs[1], *eps, &hw),
        }],
        Op::Rope => vec![rope_fwd(inputs[0], inputs[1], inputs[2])],
        Op::CeLoss => vec![ce_loss(inputs[0], inputs[1], backend)],

        // ---- backward ----------------------------------------------------
        Op::GeluGrad => vec![gelu_grad(inputs[0], inputs[1], backend)],
        Op::SiluGrad => vec![silu_grad(inputs[0], inputs[1], backend)],
        Op::ReluGrad => vec![repops::zipmap(inputs[0], inputs[1], |x, dy| {
            if x > 0.0 {
                dy
            } else {
                0.0
            }
        })],
        Op::TanhGrad => vec![repops::zipmap(inputs[0], inputs[1], |y, dy| dy * (1.0 - y * y))],
        Op::SoftmaxGrad => vec![softmax_grad(inputs[0], inputs[1], backend)],
        Op::LayerNormGrad { eps } => layernorm_grad(inputs[0], inputs[1], inputs[2], *eps, backend),
        Op::RmsNormGrad { eps } => rmsnorm_grad(inputs[0], inputs[1], inputs[2], *eps, backend),
        Op::RopeGrad => vec![rope_bwd(inputs[0], inputs[1], inputs[2])],
        Op::CeGrad => vec![ce_grad(inputs[0], inputs[1], inputs[2], backend)],
        Op::SumLeading { suffix_rank } => vec![sum_leading(inputs[0], *suffix_rank)],

        // ---- optimizer -----------------------------------------------------
        Op::AdamUpdate { lr, beta1, beta2, eps } => {
            adam_update(inputs[0], inputs[1], inputs[2], inputs[3], *lr, *beta1, *beta2, *eps, step_t)
        }
        Op::SgdUpdate { lr } => {
            vec![repops::zipmap(inputs[0], inputs[1], |w, g| w - *lr * g)]
        }
    };
    timer.stop(op_key(op));
    out
}

/// Coarse operator-family key for kernel-timing histograms. Static keys
/// keep the snapshot key set bounded regardless of program shape.
fn op_key(op: &Op) -> &'static str {
    match op {
        Op::MatMul | Op::BatchMatMul => "repops_matmul_us",
        Op::Softmax | Op::SoftmaxGrad => "repops_softmax_us",
        Op::LayerNorm { .. }
        | Op::LayerNormGrad { .. }
        | Op::RmsNorm { .. }
        | Op::RmsNormGrad { .. } => "repops_norm_us",
        Op::CeLoss | Op::CeGrad => "repops_loss_us",
        Op::AdamUpdate { .. } | Op::SgdUpdate { .. } => "repops_optim_us",
        _ => "repops_elementwise_us",
    }
}

/// `[a,b,c,d] -> [a,c,b,d]`. Pure data movement — every output row of `d`
/// floats is one independent copy, so output-row ranges fan out to the pool.
fn perm0213(x: &Tensor) -> Tensor {
    assert_eq!(x.rank(), 4, "perm0213 wants rank-4, got {:?}", x.shape());
    let (a, b, c, d) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let mut out = vec![0.0f32; x.numel()];
    let src = x.data();
    let min_rows = (parallel::EW_GRAIN / d.max(1)).max(1);
    parallel::for_each_row_chunk(&mut out, d, min_rows, |first, dst| {
        for (drow, ro) in dst.chunks_exact_mut(d).zip(first..) {
            // output row ro = ((ia*c + ic)*b + ib)
            let ib = ro % b;
            let ic = (ro / b) % c;
            let ia = ro / (b * c);
            drow.copy_from_slice(&src[(((ia * b) + ib) * c + ic) * d..][..d]);
        }
    });
    Tensor::new([a, c, b, d], out)
}

/// `a + b` where `b.shape` is a suffix of `a.shape`.
fn add_bcast(a: &Tensor, b: &Tensor) -> Tensor {
    let ar = a.rank();
    let br = b.rank();
    assert!(br <= ar, "add_bcast: {:?} + {:?}", a.shape(), b.shape());
    assert_eq!(
        &a.shape()[ar - br..],
        b.shape(),
        "add_bcast: rhs shape must be a suffix: {:?} + {:?}",
        a.shape(),
        b.shape()
    );
    let bn = b.numel().max(1);
    let mut out = a.data().to_vec();
    let bd = b.data();
    // each bn-float row adds the same broadcast operand: rows fan out
    let min_rows = (parallel::EW_GRAIN / bn).max(1);
    parallel::for_each_row_chunk(&mut out, bn, min_rows, |_, dst| {
        for orow in dst.chunks_exact_mut(bn) {
            for (o, &x) in orow.iter_mut().zip(bd) {
                *o += x;
            }
        }
    });
    Tensor::new(a.shape().to_vec(), out)
}

/// Backward of `add_bcast`'s broadcast operand: fold leading dims by
/// ascending-index summation into the trailing `suffix_rank` shape.
///
/// The leading (folded) dimension is order-critical, so the split is over
/// *output elements*: each one still accumulates its `i % sn == j` terms
/// in ascending flat order — exactly the serial per-element order (the
/// serial loop merely interleaves independent elements).
fn sum_leading(dy: &Tensor, suffix_rank: usize) -> Tensor {
    let r = dy.rank();
    assert!(suffix_rank <= r);
    let suffix: Vec<usize> = dy.shape()[r - suffix_rank..].to_vec();
    let sn: usize = suffix.iter().product::<usize>().max(1);
    let lead = dy.numel() / sn;
    let mut out = vec![0.0f32; sn];
    let dyd = dy.data();
    let min_cols = (parallel::EW_GRAIN / lead.max(1)).max(1);
    parallel::for_each_row_chunk(&mut out, 1, min_cols, |first, dst| {
        for l in 0..lead {
            let row = &dyd[l * sn + first..l * sn + first + dst.len()];
            for (o, &v) in dst.iter_mut().zip(row) {
                *o += v;
            }
        }
    });
    Tensor::new(suffix, out)
}

/// Interleaved-pair RoPE: pairs `(x_{2i}, x_{2i+1})` rotate by `θ_{s,i}`.
/// `x [n, s, d]`, `sin`/`cos` `[s, d/2]`.
fn rope_fwd(x: &Tensor, sin: &Tensor, cos: &Tensor) -> Tensor {
    rope_apply(x, sin, cos, false)
}

/// Inverse rotation (backward pass): rotate by `-θ`.
fn rope_bwd(dy: &Tensor, sin: &Tensor, cos: &Tensor) -> Tensor {
    rope_apply(dy, sin, cos, true)
}

fn rope_apply(x: &Tensor, sin: &Tensor, cos: &Tensor, inverse: bool) -> Tensor {
    assert_eq!(x.rank(), 3, "rope wants [n, s, d], got {:?}", x.shape());
    let (_n, s, d) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    assert_eq!(d % 2, 0, "rope head dim must be even");
    assert_eq!(sin.shape(), [s, d / 2], "rope sin table {:?}", sin.shape());
    assert_eq!(cos.shape(), [s, d / 2]);
    let mut out = vec![0.0f32; x.numel()];
    let (xd, sd, cd) = (x.data(), sin.data(), cos.data());
    // every (b, t) row rotates independently: rows fan out to the pool
    let min_rows = (parallel::EW_GRAIN / d.max(1)).max(1);
    parallel::for_each_row_chunk(&mut out, d, min_rows, |first, dst| {
        for (orow, rt) in dst.chunks_exact_mut(d).zip(first..) {
            let t = rt % s;
            let row = &xd[rt * d..][..d];
            let srow = &sd[t * (d / 2)..][..d / 2];
            let crow = &cd[t * (d / 2)..][..d / 2];
            for i in 0..d / 2 {
                let (x0, x1) = (row[2 * i], row[2 * i + 1]);
                let (sn, cs) = if inverse { (-srow[i], crow[i]) } else { (srow[i], crow[i]) };
                orow[2 * i] = x0 * cs - x1 * sn;
                orow[2 * i + 1] = x0 * sn + x1 * cs;
            }
        }
    });
    Tensor::new(x.shape().to_vec(), out)
}

/// Mean cross-entropy over rows (fixed ascending-row accumulation).
///
/// The scalar accumulation over rows is order-critical, so it stays a
/// single ascending loop (the log-softmax it consumes is parallel).
fn ce_loss(logits: &Tensor, targets: &Tensor, backend: Backend) -> Tensor {
    assert_eq!(logits.rank(), 2, "ce_loss wants [r, v] logits");
    let (r, v) = (logits.shape()[0], logits.shape()[1]);
    assert_eq!(targets.numel(), r, "ce_loss targets {:?}", targets.shape());
    let logp = match backend {
        Backend::Rep => repops::log_softmax_lastdim(logits),
        Backend::Free(hw) => baseline::log_softmax_lastdim(logits, &hw),
    };
    let mut acc = 0.0f32;
    for row in 0..r {
        let t = targets.data()[row] as usize;
        assert!(t < v, "target {t} out of vocab {v}");
        acc += -logp.data()[row * v + t];
    }
    Tensor::scalar(acc / r as f32)
}

/// `(softmax(logits) - onehot) * dloss / r`.
fn ce_grad(logits: &Tensor, targets: &Tensor, dloss: &Tensor, backend: Backend) -> Tensor {
    let (r, v) = (logits.shape()[0], logits.shape()[1]);
    let dl = dloss.data()[0];
    let mut p = match backend {
        Backend::Rep => repops::softmax_lastdim(logits),
        Backend::Free(hw) => baseline::softmax_lastdim(logits, &hw),
    };
    let scale = dl / r as f32;
    let td = targets.data().to_vec();
    // rows are independent elementwise updates: fan out to the pool
    let min_rows = (parallel::EW_GRAIN / v.max(1)).max(1);
    parallel::for_each_row_chunk(p.data_mut(), v, min_rows, |first, dst| {
        for (prow, row) in dst.chunks_exact_mut(v).zip(first..) {
            let t = td[row] as usize;
            for x in prow.iter_mut() {
                *x *= scale;
            }
            prow[t] -= scale;
        }
    });
    p
}

fn gelu_grad(x: &Tensor, dy: &Tensor, backend: Backend) -> Tensor {
    // gelu'(x) = Φ(x) + x·φ(x),  Φ = 0.5(1+erf(x/√2)), φ = N(0,1) pdf
    const INV_SQRT2: f32 = 0.707_106_781_186_547_6;
    const INV_SQRT_2PI: f32 = 0.398_942_280_401_432_7;
    repops::zipmap(x, dy, |x, dy| {
        let cdf = match backend {
            Backend::Rep => 0.5 * (1.0 + math::rep_erf(x * INV_SQRT2)),
            Backend::Free(_) => 0.5 * (1.0 + math::rep_erf(x * INV_SQRT2)),
        };
        let pdf = INV_SQRT_2PI * backend.exp(-0.5 * x * x);
        dy * (cdf + x * pdf)
    })
}

fn silu_grad(x: &Tensor, dy: &Tensor, backend: Backend) -> Tensor {
    repops::zipmap(x, dy, |x, dy| {
        let s = match backend {
            Backend::Rep => math::rep_sigmoid(x),
            Backend::Free(_) => 1.0 / (1.0 + (-x).exp()),
        };
        dy * (s + x * s * (1.0 - s))
    })
}

/// `dx = y ⊙ (dy - Σ_j dy_j·y_j)` per row; the dot is order-sensitive
/// *within* a row, so rows fan out to the pool (one scratch `prod` buffer
/// per chunk) while each row's ascending-j dot stays intact.
fn softmax_grad(y: &Tensor, dy: &Tensor, backend: Backend) -> Tensor {
    assert_eq!(y.shape(), dy.shape());
    let n = *y.shape().last().unwrap();
    let mut out = vec![0.0f32; y.numel()];
    let (yd, dyd) = (y.data(), dy.data());
    let min_rows = (parallel::EW_GRAIN / n.max(1)).max(1);
    parallel::for_each_row_chunk(&mut out, n, min_rows, |first, dst| {
        let mut prod = vec![0.0f32; n];
        for (orow, r) in dst.chunks_exact_mut(n).zip(first..) {
            let yr = &yd[r * n..(r + 1) * n];
            let dyr = &dyd[r * n..(r + 1) * n];
            for j in 0..n {
                prod[j] = dyr[j] * yr[j];
            }
            let dot = backend.sum(&prod);
            for j in 0..n {
                orow[j] = yr[j] * (dyr[j] - dot);
            }
        }
    });
    Tensor::new(y.shape().to_vec(), out)
}

/// LayerNorm backward → `(dx, dgamma, dbeta)`.
///
/// Deliberately serial: `dgamma`/`dbeta` accumulate *across rows* in
/// ascending row order, which makes the row dimension order-critical here
/// (unlike the forward pass). Splitting rows across threads would need
/// per-thread partials plus a reduction — a different, non-reproducible
/// summation tree — so the whole backward stays one fixed-order loop.
fn layernorm_grad(x: &Tensor, gamma: &Tensor, dy: &Tensor, eps: f32, backend: Backend) -> Vec<Tensor> {
    let n = *x.shape().last().unwrap();
    let rows = x.numel() / n;
    assert_eq!(gamma.shape(), [n]);
    assert_eq!(dy.shape(), x.shape());
    let inv_n = 1.0 / n as f32;
    let mut dx = vec![0.0f32; x.numel()];
    let mut dgamma = vec![0.0f32; n];
    let mut dbeta = vec![0.0f32; n];
    let mut xhat = vec![0.0f32; n];
    let mut g = vec![0.0f32; n];
    let mut gx = vec![0.0f32; n];
    let mut sq = vec![0.0f32; n];
    for r in 0..rows {
        let xr = &x.data()[r * n..(r + 1) * n];
        let dyr = &dy.data()[r * n..(r + 1) * n];
        let mean = backend.sum(xr) * inv_n;
        for j in 0..n {
            let d = xr[j] - mean;
            sq[j] = d * d;
        }
        let var = backend.sum(&sq) * inv_n;
        let inv_std = match backend {
            Backend::Rep => math::rep_rsqrt(var + eps),
            Backend::Free(_) => 1.0 / (var + eps).sqrt(),
        };
        for j in 0..n {
            xhat[j] = (xr[j] - mean) * inv_std;
            g[j] = dyr[j] * gamma.data()[j];
            gx[j] = g[j] * xhat[j];
        }
        let mg = backend.sum(&g) * inv_n;
        let mgx = backend.sum(&gx) * inv_n;
        let dxr = &mut dx[r * n..(r + 1) * n];
        for j in 0..n {
            dxr[j] = (g[j] - mg - xhat[j] * mgx) * inv_std;
            // rows ascending: fixed accumulation order for the param grads
            dgamma[j] += dyr[j] * xhat[j];
            dbeta[j] += dyr[j];
        }
    }
    vec![
        Tensor::new(x.shape().to_vec(), dx),
        Tensor::new([n], dgamma),
        Tensor::new([n], dbeta),
    ]
}

/// RMSNorm backward → `(dx, dgamma)`.
///
/// Serial for the same reason as [`layernorm_grad`]: `dgamma` sums over
/// rows in ascending order, making rows order-critical.
fn rmsnorm_grad(x: &Tensor, gamma: &Tensor, dy: &Tensor, eps: f32, backend: Backend) -> Vec<Tensor> {
    let n = *x.shape().last().unwrap();
    let rows = x.numel() / n;
    assert_eq!(gamma.shape(), [n]);
    assert_eq!(dy.shape(), x.shape());
    let inv_n = 1.0 / n as f32;
    let mut dx = vec![0.0f32; x.numel()];
    let mut dgamma = vec![0.0f32; n];
    let mut g = vec![0.0f32; n];
    let mut gx = vec![0.0f32; n];
    let mut sq = vec![0.0f32; n];
    for r in 0..rows {
        let xr = &x.data()[r * n..(r + 1) * n];
        let dyr = &dy.data()[r * n..(r + 1) * n];
        for j in 0..n {
            sq[j] = xr[j] * xr[j];
        }
        let ms = backend.sum(&sq) * inv_n + eps;
        let inv_rms = match backend {
            Backend::Rep => math::rep_rsqrt(ms),
            Backend::Free(_) => 1.0 / ms.sqrt(),
        };
        for j in 0..n {
            g[j] = dyr[j] * gamma.data()[j];
            gx[j] = g[j] * xr[j];
        }
        let sgx = backend.sum(&gx);
        let dxr = &mut dx[r * n..(r + 1) * n];
        let inv_rms3 = inv_rms * inv_rms * inv_rms;
        for j in 0..n {
            dxr[j] = g[j] * inv_rms - xr[j] * sgx * inv_rms3 * inv_n;
            dgamma[j] += dyr[j] * xr[j] * inv_rms;
        }
    }
    vec![Tensor::new(x.shape().to_vec(), dx), Tensor::new([n], dgamma)]
}

/// Adam with bias correction at step `t` (1-based). All elementwise.
#[allow(clippy::too_many_arguments)]
fn adam_update(
    w: &Tensor,
    g: &Tensor,
    m: &Tensor,
    v: &Tensor,
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    t: u64,
) -> Vec<Tensor> {
    assert_eq!(w.shape(), g.shape());
    assert_eq!(w.shape(), m.shape());
    assert_eq!(w.shape(), v.shape());
    assert!(t >= 1, "Adam step index is 1-based");
    let bc1 = 1.0 - pow_fixed(beta1, t);
    let bc2 = 1.0 - pow_fixed(beta2, t);
    let mut nw = vec![0.0f32; w.numel()];
    let mut nm = vec![0.0f32; w.numel()];
    let mut nv = vec![0.0f32; w.numel()];
    let (wd, gd, md, vd) = (w.data(), g.data(), m.data(), v.data());
    // purely elementwise: index ranges fan out, writing disjoint slices of
    // all three outputs (SendPtr carries the two extra output bases)
    let nmp = parallel::SendPtr::new(nm.as_mut_ptr());
    let nvp = parallel::SendPtr::new(nv.as_mut_ptr());
    parallel::for_each_row_chunk(&mut nw, 1, parallel::EW_GRAIN, |first, dst| {
        for (o, i) in dst.iter_mut().zip(first..) {
            let gi = gd[i];
            let mi = beta1 * md[i] + (1.0 - beta1) * gi;
            let vi = beta2 * vd[i] + (1.0 - beta2) * (gi * gi);
            let mhat = mi / bc1;
            let vhat = vi / bc2;
            *o = wd[i] - lr * mhat / (vhat.sqrt() + eps);
            // SAFETY: index i lies in this chunk's exclusive range; chunks
            // of the three parallel outputs are disjoint the same way.
            unsafe {
                *nmp.get().add(i) = mi;
                *nvp.get().add(i) = vi;
            }
        }
    });
    vec![
        Tensor::new(w.shape().to_vec(), nw),
        Tensor::new(w.shape().to_vec(), nm),
        Tensor::new(w.shape().to_vec(), nv),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Op;

    fn t(shape: &[usize], seed: u64) -> Tensor {
        Tensor::rand(shape.to_vec(), seed, 1.0)
    }

    /// Central-difference check of a scalar function's gradient.
    fn finite_diff(
        f: &dyn Fn(&Tensor) -> f32,
        x: &Tensor,
        idx: usize,
        h: f32,
    ) -> f32 {
        let mut xp = x.clone();
        xp.data_mut()[idx] += h;
        let mut xm = x.clone();
        xm.data_mut()[idx] -= h;
        (f(&xp) - f(&xm)) / (2.0 * h)
    }

    #[test]
    fn perm0213_roundtrip_and_layout() {
        let x = t(&[2, 3, 4, 5], 1);
        let y = perm0213(&x);
        assert_eq!(y.shape(), &[2, 4, 3, 5]);
        let z = perm0213(&y);
        assert!(z.bit_eq(&x), "perm0213 is an involution on dims 1,2");
        // spot-check an element: x[1,2,3,4] == y[1,3,2,4]
        let xi = ((1 * 3 + 2) * 4 + 3) * 5 + 4;
        let yi = ((1 * 4 + 3) * 3 + 2) * 5 + 4;
        assert_eq!(x.data()[xi], y.data()[yi]);
    }

    #[test]
    fn add_bcast_row_and_matrix() {
        let a = t(&[2, 3, 4], 2);
        let row = t(&[4], 3);
        let r = add_bcast(&a, &row);
        assert_eq!(r.data()[5], a.data()[5] + row.data()[1]);
        let mat = t(&[3, 4], 4);
        let r2 = add_bcast(&a, &mat);
        assert_eq!(r2.data()[13], a.data()[13] + mat.data()[1]);
    }

    #[test]
    fn sum_leading_inverts_bcast_shape() {
        let dy = Tensor::full([2, 3, 4], 1.0);
        let s = sum_leading(&dy, 1);
        assert_eq!(s.shape(), &[4]);
        assert!(s.data().iter().all(|&x| x == 6.0));
        let s2 = sum_leading(&dy, 2);
        assert_eq!(s2.shape(), &[3, 4]);
        assert!(s2.data().iter().all(|&x| x == 2.0));
    }

    #[test]
    fn rope_inverse_recovers_input() {
        let x = t(&[2, 5, 8], 5);
        let mut sin = Tensor::zeros([5, 4]);
        let mut cos = Tensor::zeros([5, 4]);
        for s in 0..5 {
            for i in 0..4 {
                let theta = s as f32 / (10_000f32).powf(2.0 * i as f32 / 8.0);
                sin.data_mut()[s * 4 + i] = math::rep_sin(theta);
                cos.data_mut()[s * 4 + i] = math::rep_cos(theta);
            }
        }
        let y = rope_fwd(&x, &sin, &cos);
        let back = rope_bwd(&y, &sin, &cos);
        assert!(back.max_abs_diff(&x) < 1e-5, "rope inverse");
        // norm preservation (rotations)
        let nx: f32 = x.data().iter().map(|v| v * v).sum();
        let ny: f32 = y.data().iter().map(|v| v * v).sum();
        assert!((nx - ny).abs() / nx < 1e-5);
    }

    #[test]
    fn ce_loss_uniform_logits_is_log_vocab() {
        let logits = Tensor::zeros([4, 16]);
        let targets = Tensor::new([4], vec![0.0, 5.0, 10.0, 15.0]);
        let l = ce_loss(&logits, &targets, Backend::Rep);
        assert!((l.data()[0] - (16f32).ln()).abs() < 1e-4);
    }

    #[test]
    fn ce_grad_matches_finite_difference() {
        let logits = t(&[3, 7], 6);
        let targets = Tensor::new([3], vec![1.0, 4.0, 6.0]);
        let dl = Tensor::scalar(1.0);
        let grad = ce_grad(&logits, &targets, &dl, Backend::Rep);
        let f = |l: &Tensor| ce_loss(l, &targets, Backend::Rep).data()[0];
        for idx in [0, 5, 10, 20] {
            let fd = finite_diff(&f, &logits, idx, 1e-2);
            assert!(
                (grad.data()[idx] - fd).abs() < 1e-3,
                "idx {idx}: analytic {} vs fd {fd}",
                grad.data()[idx]
            );
        }
    }

    #[test]
    fn activation_grads_match_finite_difference() {
        let x = t(&[32], 7);
        let dy = Tensor::full([32], 1.0);
        let cases: Vec<(Op, Box<dyn Fn(&Tensor) -> Tensor>)> = vec![
            (Op::GeluGrad, Box::new(|x: &Tensor| repops::gelu(x))),
            (Op::SiluGrad, Box::new(|x: &Tensor| repops::silu(x))),
            (Op::ReluGrad, Box::new(|x: &Tensor| repops::relu(x))),
        ];
        for (gop, f) in cases {
            let g = run_op(&gop, &[&x, &dy], Backend::Rep, 1);
            for idx in [0, 7, 31] {
                let fd = finite_diff(
                    &|xx: &Tensor| repops::sum_all(&f(xx)),
                    &x,
                    idx,
                    1e-3,
                );
                let got = g[0].data()[idx];
                assert!(
                    (got - fd).abs() < 1e-2,
                    "{}: idx {idx} analytic {got} vs fd {fd}",
                    gop.mnemonic()
                );
            }
        }
    }

    #[test]
    fn tanh_grad_uses_output() {
        let x = t(&[16], 8);
        let y = repops::tanh(&x);
        let dy = Tensor::full([16], 1.0);
        let g = run_op(&Op::TanhGrad, &[&y, &dy], Backend::Rep, 1);
        for idx in [0, 9, 15] {
            let fd = finite_diff(&|xx: &Tensor| repops::sum_all(&repops::tanh(xx)), &x, idx, 1e-3);
            assert!((g[0].data()[idx] - fd).abs() < 1e-3);
        }
    }

    #[test]
    fn softmax_grad_matches_finite_difference() {
        let x = t(&[2, 5], 9);
        let dy = t(&[2, 5], 10);
        let y = repops::softmax_lastdim(&x);
        let g = softmax_grad(&y, &dy, Backend::Rep);
        let f = |xx: &Tensor| {
            let yy = repops::softmax_lastdim(xx);
            repops::sum_all(&repops::mul(&yy, &dy))
        };
        for idx in 0..10 {
            let fd = finite_diff(&f, &x, idx, 1e-3);
            assert!(
                (g.data()[idx] - fd).abs() < 1e-3,
                "idx {idx}: {} vs {fd}",
                g.data()[idx]
            );
        }
    }

    #[test]
    fn layernorm_grad_matches_finite_difference() {
        let x = t(&[3, 8], 11);
        let gamma = t(&[8], 12);
        let beta = t(&[8], 13);
        let dy = t(&[3, 8], 14);
        let eps = 1e-5;
        let grads = layernorm_grad(&x, &gamma, &dy, eps, Backend::Rep);
        let f_x = |xx: &Tensor| {
            repops::sum_all(&repops::mul(&repops::layernorm(xx, &gamma, &beta, eps), &dy))
        };
        for idx in [0, 10, 23] {
            let fd = finite_diff(&f_x, &x, idx, 1e-3);
            assert!(
                (grads[0].data()[idx] - fd).abs() < 2e-2,
                "dx[{idx}]: {} vs {fd}",
                grads[0].data()[idx]
            );
        }
        let f_g = |gg: &Tensor| {
            repops::sum_all(&repops::mul(&repops::layernorm(&x, gg, &beta, eps), &dy))
        };
        for idx in [0, 4, 7] {
            let fd = finite_diff(&f_g, &gamma, idx, 1e-3);
            assert!((grads[1].data()[idx] - fd).abs() < 1e-2, "dgamma[{idx}]");
        }
        let f_b = |bb: &Tensor| {
            repops::sum_all(&repops::mul(&repops::layernorm(&x, &gamma, bb, eps), &dy))
        };
        for idx in [0, 7] {
            let fd = finite_diff(&f_b, &beta, idx, 1e-3);
            assert!((grads[2].data()[idx] - fd).abs() < 1e-2, "dbeta[{idx}]");
        }
    }

    #[test]
    fn rmsnorm_grad_matches_finite_difference() {
        let x = t(&[3, 8], 15);
        let gamma = t(&[8], 16);
        let dy = t(&[3, 8], 17);
        let eps = 1e-6;
        let grads = rmsnorm_grad(&x, &gamma, &dy, eps, Backend::Rep);
        let f_x = |xx: &Tensor| {
            repops::sum_all(&repops::mul(&repops::rmsnorm(xx, &gamma, eps), &dy))
        };
        for idx in [0, 11, 23] {
            let fd = finite_diff(&f_x, &x, idx, 1e-3);
            assert!(
                (grads[0].data()[idx] - fd).abs() < 2e-2,
                "dx[{idx}]: {} vs {fd}",
                grads[0].data()[idx]
            );
        }
        let f_g = |gg: &Tensor| {
            repops::sum_all(&repops::mul(&repops::rmsnorm(&x, gg, eps), &dy))
        };
        for idx in [0, 5] {
            let fd = finite_diff(&f_g, &gamma, idx, 1e-3);
            assert!((grads[1].data()[idx] - fd).abs() < 1e-2, "dgamma[{idx}]");
        }
    }

    #[test]
    fn adam_first_step_moves_against_gradient() {
        let w = Tensor::zeros([4]);
        let g = Tensor::new([4], vec![1.0, -1.0, 2.0, 0.0]);
        let m = Tensor::zeros([4]);
        let v = Tensor::zeros([4]);
        let out = adam_update(&w, &g, &m, &v, 0.1, 0.9, 0.999, 1e-8, 1);
        // with zero m/v and bias correction, |Δw| ≈ lr for any g≠0
        assert!((out[0].data()[0] + 0.1).abs() < 1e-3);
        assert!((out[0].data()[1] - 0.1).abs() < 1e-3);
        assert!((out[0].data()[2] + 0.1).abs() < 1e-3);
        assert_eq!(out[0].data()[3], 0.0);
        // moments updated
        assert!((out[1].data()[0] - 0.1).abs() < 1e-6);
        assert!((out[2].data()[0] - 0.001).abs() < 1e-7);
    }

    #[test]
    fn adam_is_step_dependent() {
        let w = t(&[8], 18);
        let g = t(&[8], 19);
        let m = t(&[8], 20);
        let v = repops::map(&t(&[8], 21), |x| x * x + 0.01);
        let s1 = adam_update(&w, &g, &m, &v, 0.01, 0.9, 0.999, 1e-8, 1);
        let s9 = adam_update(&w, &g, &m, &v, 0.01, 0.9, 0.999, 1e-8, 9);
        assert!(!s1[0].bit_eq(&s9[0]), "bias correction must depend on t");
    }

    #[test]
    fn pow_fixed_matches_powi() {
        for t in 0..30u64 {
            let want = 0.9f64.powi(t as i32) as f32;
            assert!((pow_fixed(0.9, t) - want).abs() < 1e-5);
        }
    }

    #[test]
    fn free_backend_runs_all_op_kinds() {
        // smoke: every op executes under Free backend too
        let hw = HardwareProfile::T4_16G;
        let x = t(&[4, 6], 22);
        let w = t(&[6, 3], 23);
        for (op, ins) in [
            (Op::MatMul, vec![&x, &w]),
            (Op::Gelu, vec![&x]),
            (Op::Softmax, vec![&x]),
        ] {
            let r = run_op(&op, &ins, Backend::Free(hw), 1);
            let r2 = run_op(&op, &ins, Backend::Free(hw), 1);
            assert!(r[0].bit_eq(&r2[0]), "{} deterministic per profile", op.mnemonic());
        }
    }
}
