//! Llama-family causal transformer LM: RMSNorm, RoPE, SiLU-gated MLP,
//! untied LM head — the operator inventory of the paper's Llama-3.1 rows,
//! optionally with LoRA adapters on the attention projections (Table 2).

use crate::graph::builder::GraphBuilder;
use crate::graph::Slot;
use crate::tensor::math::{rep_cos, rep_sin};
use crate::tensor::Tensor;

use super::BuiltModel;

/// Configuration for [`build_llama`].
#[derive(Debug, Clone)]
pub struct LlamaConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub batch: usize,
    /// `Some(r)` adds LoRA adapters (rank `r`) to q/v projections and
    /// freezes every base weight — the paper's Table 2 fine-tuning setup.
    pub lora_rank: Option<usize>,
    pub rope_base: f32,
}

/// Deterministic RoPE tables `[seq, d_head/2]`, built with RepOps math so
/// the program constants are bit-identical everywhere.
pub fn rope_tables(seq: usize, d_head: usize, base: f32) -> (Tensor, Tensor) {
    let half = d_head / 2;
    let mut sin = vec![0.0f32; seq * half];
    let mut cos = vec![0.0f32; seq * half];
    for s in 0..seq {
        for i in 0..half {
            // theta = s * base^(-2i/d)
            let exponent = -2.0 * i as f32 / d_head as f32;
            // base^e = exp(e·ln base) via repops math
            let freq = crate::tensor::math::rep_exp(exponent * crate::tensor::math::rep_ln(base));
            let theta = s as f32 * freq;
            sin[s * half + i] = rep_sin(theta);
            cos[s * half + i] = rep_cos(theta);
        }
    }
    (Tensor::new([seq, half], sin), Tensor::new([seq, half], cos))
}

/// Causal attention mask `[seq, seq]`: 0 on/below the diagonal, -1e9 above.
pub fn causal_mask(seq: usize) -> Tensor {
    let mut m = vec![0.0f32; seq * seq];
    for i in 0..seq {
        for j in (i + 1)..seq {
            m[i * seq + j] = -1e9;
        }
    }
    Tensor::new([seq, seq], m)
}

/// A linear projection, optionally LoRA-adapted:
/// `y = x @ W (+ (x @ A) @ B · 1/r)`.
/// Returns the output slot; pushes `A`/`B` params when `rank` is set.
fn linear(
    b: &mut GraphBuilder,
    name: &str,
    x: Slot,
    d_in: usize,
    d_out: usize,
    lora: Option<usize>,
    frozen: &mut Vec<String>,
) -> Slot {
    let w = b.param(&format!("{name}.w"), [d_in, d_out]);
    let base = b.matmul(&format!("{name}.mm"), x, w);
    match lora {
        None => base,
        Some(r) => {
            frozen.push(format!("{name}.w"));
            let a = b.param(&format!("{name}.lora_a"), [d_in, r]);
            let bb = b.param(&format!("{name}.lora_b"), [r, d_out]);
            let xa = b.matmul(&format!("{name}.xa"), x, a);
            let xab = b.matmul(&format!("{name}.xab"), xa, bb);
            let scaled = b.scale(&format!("{name}.lora_scale"), xab, 1.0 / r as f32);
            b.add(&format!("{name}.lora_add"), base, scaled)
        }
    }
}

/// Build the forward graph of a Llama-style causal LM.
///
/// Data inputs: `tokens [batch, seq]` (integer-valued), `targets
/// [batch*seq]`. Output: mean next-token cross-entropy.
pub fn build_llama(cfg: &LlamaConfig) -> BuiltModel {
    let LlamaConfig { vocab, d_model: d, n_layers, n_heads: h, d_ff, seq: s, batch: bs, lora_rank, rope_base } = *cfg;
    assert_eq!(d % h, 0, "d_model must divide n_heads");
    let dh = d / h;
    assert_eq!(dh % 2, 0, "head dim must be even for RoPE");
    let mut b = GraphBuilder::new();
    let mut frozen = Vec::new();

    let tokens = b.data("tokens", [bs, s]);
    let targets = b.data("targets", [bs * s]);

    let embed = b.param("embed.w", [vocab, d]);
    if lora_rank.is_some() {
        frozen.push("embed.w".to_string());
    }
    let x0 = b.embedding("embed", embed, tokens);
    let mut x = b.reshape("embed.flat", x0, [bs * s, d]); // [B*S, D]

    let (sin_t, cos_t) = rope_tables(s, dh, rope_base);
    let sin = b.constant("rope.sin", sin_t);
    let cos = b.constant("rope.cos", cos_t);
    let mask = b.constant("mask.causal", causal_mask(s));

    for l in 0..n_layers {
        let p = |part: &str| format!("blk{l}.{part}");

        // ---- attention ----------------------------------------------------
        let g1 = b.param(&p("attn_norm.gamma"), [d]);
        if lora_rank.is_some() {
            frozen.push(p("attn_norm.gamma"));
        }
        let xn = b.rmsnorm(&p("attn_norm"), x, g1, 1e-6);

        let q = linear(&mut b, &p("attn.q"), xn, d, d, lora_rank, &mut frozen);
        let k = linear(&mut b, &p("attn.k"), xn, d, d, None, &mut frozen);
        let v = linear(&mut b, &p("attn.v"), xn, d, d, lora_rank, &mut frozen);
        if lora_rank.is_some() {
            frozen.push(p("attn.k.w"));
        }

        // heads: [B*S, D] -> [B, S, H, Dh] -> [B, H, S, Dh] -> [B*H, S, Dh]
        let split = |b: &mut GraphBuilder, t: Slot, tag: &str| {
            let r4 = b.reshape(&p(&format!("attn.{tag}.r4")), t, [bs, s, h, dh]);
            let pm = b.perm0213(&p(&format!("attn.{tag}.perm")), r4);
            b.reshape(&p(&format!("attn.{tag}.r3")), pm, [bs * h, s, dh])
        };
        let q3 = split(&mut b, q, "q");
        let k3 = split(&mut b, k, "k");
        let v3 = split(&mut b, v, "v");

        let qr = b.rope(&p("attn.q.rope"), q3, sin, cos);
        let kr = b.rope(&p("attn.k.rope"), k3, sin, cos);

        let kt = b.transpose_last2(&p("attn.kt"), kr);
        let scores = b.bmm(&p("attn.scores"), qr, kt);
        let scaled = b.scale(&p("attn.scale"), scores, 1.0 / (dh as f32).sqrt());
        let masked = b.add_bcast(&p("attn.mask"), scaled, mask);
        let probs = b.softmax(&p("attn.softmax"), masked);
        let ctx = b.bmm(&p("attn.ctx"), probs, v3);

        // merge heads: [B*H, S, Dh] -> [B, H, S, Dh] -> [B, S, H, Dh] -> [B*S, D]
        let c4 = b.reshape(&p("attn.merge.r4"), ctx, [bs, h, s, dh]);
        let cp = b.perm0213(&p("attn.merge.perm"), c4);
        let cm = b.reshape(&p("attn.merge.r2"), cp, [bs * s, d]);

        let o = linear(&mut b, &p("attn.o"), cm, d, d, None, &mut frozen);
        if lora_rank.is_some() {
            frozen.push(p("attn.o.w"));
        }
        x = b.add(&p("attn.residual"), x, o);

        // ---- SiLU-gated MLP -------------------------------------------------
        let g2 = b.param(&p("mlp_norm.gamma"), [d]);
        if lora_rank.is_some() {
            frozen.push(p("mlp_norm.gamma"));
        }
        let xn2 = b.rmsnorm(&p("mlp_norm"), x, g2, 1e-6);
        let gate_w = b.param(&p("mlp.gate.w"), [d, d_ff]);
        let up_w = b.param(&p("mlp.up.w"), [d, d_ff]);
        let down_w = b.param(&p("mlp.down.w"), [d_ff, d]);
        if lora_rank.is_some() {
            frozen.push(p("mlp.gate.w"));
            frozen.push(p("mlp.up.w"));
            frozen.push(p("mlp.down.w"));
        }
        let gate = b.matmul(&p("mlp.gate"), xn2, gate_w);
        let gact = b.silu(&p("mlp.silu"), gate);
        let up = b.matmul(&p("mlp.up"), xn2, up_w);
        let prod = b.mul(&p("mlp.gateup"), gact, up);
        let down = b.matmul(&p("mlp.down"), prod, down_w);
        x = b.add(&p("mlp.residual"), x, down);
    }

    let gf = b.param("final_norm.gamma", [d]);
    if lora_rank.is_some() {
        frozen.push("final_norm.gamma".to_string());
    }
    let xf = b.rmsnorm("final_norm", x, gf, 1e-6);
    let head = b.param("lm_head.w", [d, vocab]);
    if lora_rank.is_some() {
        frozen.push("lm_head.w".to_string());
    }
    let logits = b.matmul("lm_head", xf, head);
    let loss = b.ce_loss("loss", logits, targets);

    BuiltModel { builder: b, logits, loss, frozen }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::autodiff::Optimizer;
    use crate::graph::executor::{execute, ExecOpts};
    use crate::graph::kernels::Backend;
    use std::collections::BTreeMap;

    fn tiny() -> LlamaConfig {
        LlamaConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            seq: 6,
            batch: 2,
            lora_rank: None,
            rope_base: 10_000.0,
        }
    }

    fn batch_for(cfg: &LlamaConfig, seed: u64) -> BTreeMap<String, Tensor> {
        let mut rng = crate::util::prng::SplitMix64::new(seed);
        let toks: Vec<f32> = (0..cfg.batch * cfg.seq)
            .map(|_| rng.next_bounded(cfg.vocab as u64) as f32)
            .collect();
        let tgts: Vec<f32> = (0..cfg.batch * cfg.seq)
            .map(|_| rng.next_bounded(cfg.vocab as u64) as f32)
            .collect();
        let mut m = BTreeMap::new();
        m.insert("tokens".into(), Tensor::new([cfg.batch, cfg.seq], toks));
        m.insert("targets".into(), Tensor::new([cfg.batch * cfg.seq], tgts));
        m
    }

    #[test]
    fn forward_runs_and_loss_near_uniform() {
        let cfg = tiny();
        let m = build_llama(&cfg);
        let st = m.init_state(3, &Optimizer::adam(1e-3));
        let batch = batch_for(&cfg, 5);
        let e = execute(&m.builder.graph, &st, &batch, Backend::Rep, 1, &ExecOpts::default());
        let loss = e.values[m.loss.node][0].data()[0];
        let uniform = (cfg.vocab as f32).ln();
        assert!(
            (loss - uniform).abs() < 0.5,
            "random-init loss {loss} should be near ln V = {uniform}"
        );
    }

    #[test]
    fn causal_mask_blocks_future() {
        let m = causal_mask(4);
        assert_eq!(m.at2(0, 0), 0.0);
        assert_eq!(m.at2(2, 1), 0.0);
        assert_eq!(m.at2(1, 2), -1e9);
        assert_eq!(m.at2(0, 3), -1e9);
    }

    #[test]
    fn causality_future_tokens_dont_affect_past_logits() {
        let cfg = tiny();
        let m = build_llama(&cfg);
        let st = m.init_state(3, &Optimizer::adam(1e-3));
        let mut b1 = batch_for(&cfg, 7);
        let mut b2 = b1.clone();
        // change the LAST token of sequence 0
        let last = cfg.seq - 1;
        b2.get_mut("tokens").unwrap().data_mut()[last] =
            (b1["tokens"].data()[last] as usize as f32 + 1.0) % cfg.vocab as f32;
        let e1 = execute(&m.builder.graph, &st, &b1, Backend::Rep, 1, &ExecOpts::default());
        let e2 = execute(&m.builder.graph, &st, &b2, Backend::Rep, 1, &ExecOpts::default());
        let l1 = &e1.values[m.logits.node][0];
        let l2 = &e2.values[m.logits.node][0];
        let v = cfg.vocab;
        // logits at positions < last of sequence 0 must be bit-identical
        for pos in 0..last {
            for j in 0..v {
                assert_eq!(
                    l1.data()[pos * v + j].to_bits(),
                    l2.data()[pos * v + j].to_bits(),
                    "position {pos} leaked future info"
                );
            }
        }
        // ...and the last position must differ
        let differs = (0..v).any(|j| l1.data()[last * v + j] != l2.data()[last * v + j]);
        assert!(differs);
        let _ = &mut b1;
    }

    #[test]
    fn rope_tables_bounded_and_first_row_identity() {
        let (sin, cos) = rope_tables(8, 8, 10_000.0);
        // position 0 ⇒ zero rotation
        for i in 0..4 {
            assert_eq!(sin.at2(0, i), 0.0);
            assert_eq!(cos.at2(0, i), 1.0);
        }
        for v in sin.data().iter().chain(cos.data()) {
            assert!(v.abs() <= 1.0 + 1e-6);
        }
    }

    #[test]
    fn lora_freezes_base_trains_adapters() {
        let mut cfg = tiny();
        cfg.lora_rank = Some(4);
        let m = build_llama(&cfg);
        let ts = m.train_step(&Optimizer::adam(1e-3));
        // all updated params are LoRA adapters
        for name in ts.param_updates.keys() {
            assert!(
                name.contains("lora_"),
                "only adapters should train, got {name}"
            );
        }
        assert!(!ts.param_updates.is_empty());
        // base weights exist but are frozen
        assert!(m.frozen.iter().any(|f| f == "lm_head.w"));
        // trainable fraction is small (the point of LoRA)
        let total: usize = m.n_params();
        let trainable: usize = m
            .builder
            .param_shapes
            .iter()
            .filter(|(n, _)| ts.param_updates.contains_key(n))
            .map(|(_, s)| s.iter().product::<usize>())
            .sum();
        assert!(
            (trainable as f64) < 0.3 * total as f64,
            "trainable {trainable} of {total}"
        );
    }

    #[test]
    fn training_reduces_loss_on_learnable_data() {
        // learnable task: next token = (token + 1) mod V
        let cfg = LlamaConfig { n_layers: 1, seq: 8, ..tiny() };
        let m = build_llama(&cfg);
        let ts = m.train_step(&Optimizer::adam(0.01));
        let mut st = m.init_state(1, &Optimizer::adam(0.01));
        let mut rng = crate::util::prng::SplitMix64::new(9);
        let mut first = None;
        let mut last = 0.0;
        for step in 1..=30u64 {
            let mut toks = Vec::new();
            for _ in 0..cfg.batch {
                let start = rng.next_bounded(cfg.vocab as u64) as usize;
                for i in 0..cfg.seq {
                    toks.push(((start + i) % cfg.vocab) as f32);
                }
            }
            let tgts: Vec<f32> = toks.iter().map(|&t| ((t as usize + 1) % cfg.vocab) as f32).collect();
            let mut batch = BTreeMap::new();
            batch.insert("tokens".into(), Tensor::new([cfg.batch, cfg.seq], toks));
            batch.insert("targets".into(), Tensor::new([cfg.batch * cfg.seq], tgts));
            let e = execute(&ts.graph, &st, &batch, Backend::Rep, step, &ExecOpts::default());
            last = e.values[ts.loss.node][0].data()[0];
            first.get_or_insert(last);
            let mut next = st.clone();
            for (name, slot) in &ts.param_updates {
                next.params.insert(name.clone(), e.values[slot.node][slot.out_idx].clone());
            }
            for (name, slot) in &ts.opt_updates {
                next.opt.insert(name.clone(), e.values[slot.node][slot.out_idx].clone());
            }
            next.step += 1;
            st = next;
        }
        assert!(
            last < first.unwrap() * 0.7,
            "loss {} -> {last} should drop on deterministic data",
            first.unwrap()
        );
    }
}
