//! Model zoo: graph-builder definitions of the paper's evaluation models,
//! scaled to this testbed (DESIGN.md §4 substitution 3).
//!
//! Two transformer families cover the paper's operator inventories:
//!
//! * **Llama family** ([`transformer`]) — RMSNorm, SiLU-gated MLP, RoPE,
//!   untied LM head (paper's Llama-3.1-1B / 8B rows);
//! * **BERT family** ([`bert`]) — LayerNorm, exact-erf GeLU, learned
//!   positional embeddings (paper's DistilBERT rows).
//!
//! Plus [`mlp`] (a small classifier for fast protocol tests) and [`lora`]
//! (low-rank adapters for the paper's Table 2 fine-tuning row).

pub mod bert;
pub mod lora;
pub mod mlp;
pub mod transformer;

use crate::graph::autodiff::{build_train_step, Optimizer, TrainStep};
use crate::graph::builder::GraphBuilder;
use crate::graph::executor::State;
use crate::graph::Slot;
use crate::tensor::Tensor;
use crate::util::prng::derive_seed;

/// A built forward pass, ready for [`build_train_step`] or inference.
pub struct BuiltModel {
    pub builder: GraphBuilder,
    /// `[batch*seq, vocab]` logits.
    pub logits: Slot,
    /// Scalar mean cross-entropy over all positions.
    pub loss: Slot,
    /// Names of parameters a LoRA run freezes (empty without LoRA).
    pub frozen: Vec<String>,
}

impl BuiltModel {
    /// Derive the extended training-step program.
    pub fn train_step(&self, opt: &Optimizer) -> TrainStep {
        let freeze: Vec<&str> = self.frozen.iter().map(String::as_str).collect();
        build_train_step(&self.builder, self.loss, opt, &freeze)
    }

    /// Deterministic initial state: params from seeded uniform init scaled by
    /// 1/√fan_in, optimizer state zeroed per `opt`.
    pub fn init_state(&self, seed: u64, opt: &Optimizer) -> State {
        let mut st = State::default();
        for (name, shape) in &self.builder.param_shapes {
            let fan_in = if shape.len() >= 2 { shape[0] } else { shape[0].max(1) };
            let scale = if shape.len() == 1 {
                // norm gains init to 1, biases to 0 — match convention by name
                0.0
            } else {
                1.0 / (fan_in as f32).sqrt()
            };
            let t = if shape.len() == 1 {
                if name.ends_with(".gamma") || name.ends_with(".gain") {
                    Tensor::full(shape.clone(), 1.0)
                } else {
                    Tensor::zeros(shape.clone())
                }
            } else {
                let _ = scale;
                Tensor::rand(shape.clone(), derive_seed(seed, "param", param_index(name)), 1.0 / (fan_in as f32).sqrt())
            };
            st.params.insert(name.clone(), t);
        }
        // optimizer state: zeros matching each trainable param
        let ts = self.train_step(opt);
        for name in ts.opt_updates.keys() {
            let pname = name.rsplit_once('.').unwrap().0;
            st.opt.insert(name.clone(), Tensor::zeros(st.params[pname].shape().to_vec()));
        }
        st
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.builder
            .param_shapes
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }
}

/// Stable per-name stream index for parameter init.
fn param_index(name: &str) -> u64 {
    // FNV over the name; collisions only mean shared streams, harmless.
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Named model presets used by the CLI, tests and benches.
/// `(family)-(size)` mirror the paper's evaluation models at testbed scale.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preset {
    /// 2-layer byte-vocab Llama — protocol tests & disputes (~110k params).
    LlamaTiny,
    /// `llama-tiny` with rank-4 LoRA adapters, base weights frozen — the
    /// Table 2 fine-tuning shape at protocol-test scale.
    LlamaTinyLora,
    /// 4-layer Llama — the Table 1 "Llama-1B" stand-in (~3M params).
    LlamaSmall,
    /// 6-layer Llama — the Table 2 "Llama-8B" stand-in (~6M params).
    LlamaBase,
    /// 2-layer BERT — protocol tests.
    BertTiny,
    /// 4-layer BERT — the Table 1 "DistilBERT" stand-in (~1M params).
    BertSmall,
    /// Tiny MLP classifier — fastest dispute demos.
    Mlp,
}

impl Preset {
    pub fn parse(s: &str) -> Option<Preset> {
        Some(match s {
            "llama-tiny" => Preset::LlamaTiny,
            "llama-tiny-lora" => Preset::LlamaTinyLora,
            "llama-small" => Preset::LlamaSmall,
            "llama-base" => Preset::LlamaBase,
            "bert-tiny" => Preset::BertTiny,
            "bert-small" => Preset::BertSmall,
            "mlp" => Preset::Mlp,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Preset::LlamaTiny => "llama-tiny",
            Preset::LlamaTinyLora => "llama-tiny-lora",
            Preset::LlamaSmall => "llama-small",
            Preset::LlamaBase => "llama-base",
            Preset::BertTiny => "bert-tiny",
            Preset::BertSmall => "bert-small",
            Preset::Mlp => "mlp",
        }
    }

    /// Build the forward graph with the preset's default batch/seq.
    pub fn build(&self, batch: usize, seq: usize) -> BuiltModel {
        match self {
            Preset::LlamaTinyLora => lora::llama_tiny_lora(4, batch, seq),
            Preset::LlamaTiny => transformer::build_llama(&transformer::LlamaConfig {
                vocab: 64,
                d_model: 32,
                n_layers: 2,
                n_heads: 2,
                d_ff: 64,
                seq,
                batch,
                lora_rank: None,
                rope_base: 10_000.0,
            }),
            Preset::LlamaSmall => transformer::build_llama(&transformer::LlamaConfig {
                vocab: 256,
                d_model: 128,
                n_layers: 4,
                n_heads: 4,
                d_ff: 256,
                seq,
                batch,
                lora_rank: None,
                rope_base: 10_000.0,
            }),
            Preset::LlamaBase => transformer::build_llama(&transformer::LlamaConfig {
                vocab: 256,
                d_model: 192,
                n_layers: 6,
                n_heads: 6,
                d_ff: 384,
                seq,
                batch,
                lora_rank: None,
                rope_base: 10_000.0,
            }),
            Preset::BertTiny => bert::build_bert(&bert::BertConfig {
                vocab: 64,
                d_model: 32,
                n_layers: 2,
                n_heads: 2,
                d_ff: 64,
                seq,
                batch,
            }),
            Preset::BertSmall => bert::build_bert(&bert::BertConfig {
                vocab: 256,
                d_model: 96,
                n_layers: 4,
                n_heads: 4,
                d_ff: 192,
                seq,
                batch,
            }),
            Preset::Mlp => mlp::build_mlp(&mlp::MlpConfig {
                d_in: 16,
                d_hidden: 32,
                classes: 8,
                batch,
            }),
        }
    }

    pub const ALL: [Preset; 7] = [
        Preset::LlamaTiny,
        Preset::LlamaTinyLora,
        Preset::LlamaSmall,
        Preset::LlamaBase,
        Preset::BertTiny,
        Preset::BertSmall,
        Preset::Mlp,
    ];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_build_and_validate() {
        for p in Preset::ALL {
            let m = p.build(2, 8);
            m.builder.graph.validate().unwrap();
            assert!(m.n_params() > 0, "{}", p.name());
            assert!(m.builder.shape(m.loss).is_empty(), "loss is scalar");
        }
    }

    #[test]
    fn preset_names_roundtrip() {
        for p in Preset::ALL {
            assert_eq!(Preset::parse(p.name()), Some(p));
        }
        assert_eq!(Preset::parse("nope"), None);
    }

    #[test]
    fn init_state_is_seed_deterministic() {
        let m = Preset::LlamaTiny.build(2, 8);
        let opt = Optimizer::adam(1e-3);
        let a = m.init_state(7, &opt);
        let b = m.init_state(7, &opt);
        let c = m.init_state(8, &opt);
        assert_eq!(a.params.len(), b.params.len());
        for (k, t) in &a.params {
            assert!(t.bit_eq(&b.params[k]), "{k}");
        }
        assert!(a.params.iter().any(|(k, t)| !t.bit_eq(&c.params[k])));
        // every trainable param has m and v
        assert_eq!(a.opt.len(), 2 * a.params.len());
    }

    #[test]
    fn norm_gains_init_to_one() {
        let m = Preset::LlamaTiny.build(1, 4);
        let st = m.init_state(1, &Optimizer::adam(1e-3));
        let gamma = st.params.iter().find(|(k, _)| k.ends_with(".gamma")).unwrap();
        assert!(gamma.1.data().iter().all(|&x| x == 1.0));
    }
}
