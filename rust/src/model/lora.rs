//! LoRA helpers: presets for the paper's Table 2 fine-tuning benchmark
//! (Llama-8B + LoRA → our `llama-base` + rank-8 adapters).

use super::transformer::{build_llama, LlamaConfig};
use super::BuiltModel;

/// `llama-base` with rank-`r` adapters on the attention q/v projections and
/// all base weights frozen — the Table 2 configuration at testbed scale.
pub fn llama_base_lora(r: usize, batch: usize, seq: usize) -> BuiltModel {
    build_llama(&LlamaConfig {
        vocab: 256,
        d_model: 192,
        n_layers: 6,
        n_heads: 6,
        d_ff: 384,
        seq,
        batch,
        lora_rank: Some(r),
        rope_base: 10_000.0,
    })
}

/// Tiny LoRA model for protocol tests.
pub fn llama_tiny_lora(r: usize, batch: usize, seq: usize) -> BuiltModel {
    build_llama(&LlamaConfig {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        seq,
        batch,
        lora_rank: Some(r),
        rope_base: 10_000.0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::autodiff::Optimizer;

    #[test]
    fn lora_update_set_is_adapters_only() {
        let m = llama_tiny_lora(4, 1, 4);
        let ts = m.train_step(&Optimizer::adam(1e-3));
        assert!(!ts.param_updates.is_empty());
        for k in ts.param_updates.keys() {
            assert!(k.contains("lora_"), "{k}");
        }
        // 2 layers × (q, v) × (a, b) = 8 adapters
        assert_eq!(ts.param_updates.len(), 8);
    }

    #[test]
    fn frozen_params_carry_over_in_state() {
        let m = llama_tiny_lora(2, 1, 4);
        let opt = Optimizer::adam(1e-3);
        let st = m.init_state(5, &opt);
        // optimizer state exists only for adapters
        for k in st.opt.keys() {
            assert!(k.contains("lora_"), "{k}");
        }
        assert_eq!(st.opt.len(), 2 * 8);
    }
}
