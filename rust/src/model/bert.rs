//! BERT/DistilBERT-family encoder: LayerNorm, exact-erf GeLU, learned
//! positional embeddings, post-norm residuals — the operator inventory of
//! the paper's DistilBERT rows (the ops "not present in Llama" that
//! Observation 2 calls out: LayerNorm, GeLU, ERF).
//!
//! Trained here as a causal LM (mask included) so the same synthetic corpus
//! and loss pipeline serve both families; the paper's overhead benches
//! measure operator cost, which is mask-independent.

use crate::graph::builder::GraphBuilder;

use super::transformer::causal_mask;
use super::BuiltModel;

/// Configuration for [`build_bert`].
#[derive(Debug, Clone)]
pub struct BertConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub batch: usize,
}

/// Build the forward graph of a BERT-style encoder LM.
///
/// Data inputs: `tokens [batch, seq]`, `targets [batch*seq]`.
pub fn build_bert(cfg: &BertConfig) -> BuiltModel {
    let BertConfig { vocab, d_model: d, n_layers, n_heads: h, d_ff, seq: s, batch: bs } = *cfg;
    assert_eq!(d % h, 0);
    let dh = d / h;
    let mut b = GraphBuilder::new();

    let tokens = b.data("tokens", [bs, s]);
    let targets = b.data("targets", [bs * s]);

    // token + learned positional embeddings
    let embed = b.param("embed.w", [vocab, d]);
    let pos = b.param("pos.w", [s, d]);
    let x0 = b.embedding("embed", embed, tokens); // [B, S, D]
    let xp = b.add_bcast("pos.add", x0, pos); // + [S, D]
    let mut x = b.reshape("embed.flat", xp, [bs * s, d]);

    // embedding LayerNorm (BERT convention)
    let eg = b.param("embed_norm.gamma", [d]);
    let eb = b.param("embed_norm.beta", [d]);
    x = b.layernorm("embed_norm", x, eg, eb, 1e-12);

    let mask = b.constant("mask.causal", causal_mask(s));

    for l in 0..n_layers {
        let p = |part: &str| format!("blk{l}.{part}");

        // ---- attention (post-norm, BERT style) ------------------------------
        let wq = b.param(&p("attn.q.w"), [d, d]);
        let bq = b.param(&p("attn.q.b"), [d]);
        let wk = b.param(&p("attn.k.w"), [d, d]);
        let bk = b.param(&p("attn.k.b"), [d]);
        let wv = b.param(&p("attn.v.w"), [d, d]);
        let bv = b.param(&p("attn.v.b"), [d]);

        let q0 = b.matmul(&p("attn.q"), x, wq);
        let q = b.add_bcast(&p("attn.q.bias"), q0, bq);
        let k0 = b.matmul(&p("attn.k"), x, wk);
        let k = b.add_bcast(&p("attn.k.bias"), k0, bk);
        let v0 = b.matmul(&p("attn.v"), x, wv);
        let v = b.add_bcast(&p("attn.v.bias"), v0, bv);

        let split = |b: &mut GraphBuilder, t, tag: &str| {
            let r4 = b.reshape(&p(&format!("attn.{tag}.r4")), t, [bs, s, h, dh]);
            let pm = b.perm0213(&p(&format!("attn.{tag}.perm")), r4);
            b.reshape(&p(&format!("attn.{tag}.r3")), pm, [bs * h, s, dh])
        };
        let q3 = split(&mut b, q, "q");
        let k3 = split(&mut b, k, "k");
        let v3 = split(&mut b, v, "v");

        let kt = b.transpose_last2(&p("attn.kt"), k3);
        let scores = b.bmm(&p("attn.scores"), q3, kt);
        let scaled = b.scale(&p("attn.scale"), scores, 1.0 / (dh as f32).sqrt());
        let masked = b.add_bcast(&p("attn.mask"), scaled, mask);
        let probs = b.softmax(&p("attn.softmax"), masked);
        let ctx = b.bmm(&p("attn.ctx"), probs, v3);

        let c4 = b.reshape(&p("attn.merge.r4"), ctx, [bs, h, s, dh]);
        let cp = b.perm0213(&p("attn.merge.perm"), c4);
        let cm = b.reshape(&p("attn.merge.r2"), cp, [bs * s, d]);

        let wo = b.param(&p("attn.o.w"), [d, d]);
        let bo = b.param(&p("attn.o.b"), [d]);
        let o0 = b.matmul(&p("attn.o"), cm, wo);
        let o = b.add_bcast(&p("attn.o.bias"), o0, bo);

        let res1 = b.add(&p("attn.residual"), x, o);
        let g1 = b.param(&p("attn_norm.gamma"), [d]);
        let bt1 = b.param(&p("attn_norm.beta"), [d]);
        x = b.layernorm(&p("attn_norm"), res1, g1, bt1, 1e-12);

        // ---- GeLU MLP --------------------------------------------------------
        let w1 = b.param(&p("mlp.fc1.w"), [d, d_ff]);
        let b1 = b.param(&p("mlp.fc1.b"), [d_ff]);
        let w2 = b.param(&p("mlp.fc2.w"), [d_ff, d]);
        let b2 = b.param(&p("mlp.fc2.b"), [d]);
        let h1 = b.matmul(&p("mlp.fc1"), x, w1);
        let h1b = b.add_bcast(&p("mlp.fc1.bias"), h1, b1);
        let a = b.gelu(&p("mlp.gelu"), h1b);
        let h2 = b.matmul(&p("mlp.fc2"), a, w2);
        let h2b = b.add_bcast(&p("mlp.fc2.bias"), h2, b2);

        let res2 = b.add(&p("mlp.residual"), x, h2b);
        let g2 = b.param(&p("mlp_norm.gamma"), [d]);
        let bt2 = b.param(&p("mlp_norm.beta"), [d]);
        x = b.layernorm(&p("mlp_norm"), res2, g2, bt2, 1e-12);
    }

    let head = b.param("lm_head.w", [d, vocab]);
    let logits = b.matmul("lm_head", x, head);
    let loss = b.ce_loss("loss", logits, targets);

    BuiltModel { builder: b, logits, loss, frozen: Vec::new() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::autodiff::Optimizer;
    use crate::graph::executor::{execute, ExecOpts};
    use crate::graph::kernels::Backend;
    use crate::graph::Op;
    use crate::tensor::Tensor;
    use std::collections::BTreeMap;

    fn tiny() -> BertConfig {
        BertConfig { vocab: 32, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32, seq: 6, batch: 2 }
    }

    #[test]
    fn forward_runs_loss_near_uniform() {
        let cfg = tiny();
        let m = build_bert(&cfg);
        let st = m.init_state(4, &Optimizer::adam(1e-3));
        let mut batch = BTreeMap::new();
        let mut rng = crate::util::prng::SplitMix64::new(1);
        let toks: Vec<f32> =
            (0..cfg.batch * cfg.seq).map(|_| rng.next_bounded(32) as f32).collect();
        batch.insert("tokens".into(), Tensor::new([cfg.batch, cfg.seq], toks.clone()));
        batch.insert("targets".into(), Tensor::new([cfg.batch * cfg.seq], toks));
        let e = execute(&m.builder.graph, &st, &batch, Backend::Rep, 1, &ExecOpts::default());
        let loss = e.values[m.loss.node][0].data()[0];
        assert!((loss - (32f32).ln()).abs() < 0.6, "loss {loss}");
    }

    #[test]
    fn uses_bert_operator_inventory() {
        let m = build_bert(&tiny());
        let has = |f: &dyn Fn(&Op) -> bool| m.builder.graph.nodes.iter().any(|n| f(&n.op));
        assert!(has(&|op| matches!(op, Op::LayerNorm { .. })), "LayerNorm");
        assert!(has(&|op| matches!(op, Op::Gelu)), "GeLU");
        assert!(!has(&|op| matches!(op, Op::RmsNorm { .. })), "no RMSNorm in BERT");
        assert!(!has(&|op| matches!(op, Op::Rope)), "no RoPE in BERT");
        // learned positions exist
        assert!(m.builder.param_shapes.iter().any(|(n, _)| n == "pos.w"));
    }

    #[test]
    fn trains_on_learnable_data() {
        let cfg = BertConfig { n_layers: 1, ..tiny() };
        let m = build_bert(&cfg);
        let ts = m.train_step(&Optimizer::adam(0.02));
        let mut st = m.init_state(2, &Optimizer::adam(0.02));
        let mut first = None;
        let mut last = 0.0;
        for step in 1..=25u64 {
            // fixed mapping task: next = token reversed bitwise-ish (t*7+1 mod V)
            let mut rng = crate::util::prng::SplitMix64::new(step);
            let toks: Vec<f32> =
                (0..cfg.batch * cfg.seq).map(|_| rng.next_bounded(32) as f32).collect();
            let tgts: Vec<f32> = toks.iter().map(|&t| ((t as usize * 7 + 1) % 32) as f32).collect();
            let mut batch = BTreeMap::new();
            batch.insert("tokens".into(), Tensor::new([cfg.batch, cfg.seq], toks));
            batch.insert("targets".into(), Tensor::new([cfg.batch * cfg.seq], tgts));
            let e = execute(&ts.graph, &st, &batch, Backend::Rep, step, &ExecOpts::default());
            last = e.values[ts.loss.node][0].data()[0];
            first.get_or_insert(last);
            let mut next = st.clone();
            for (name, slot) in &ts.param_updates {
                next.params.insert(name.clone(), e.values[slot.node][slot.out_idx].clone());
            }
            for (name, slot) in &ts.opt_updates {
                next.opt.insert(name.clone(), e.values[slot.node][slot.out_idx].clone());
            }
            next.step += 1;
            st = next;
        }
        assert!(last < first.unwrap() * 0.8, "{:?} -> {last}", first.unwrap());
    }
}
