//! A small MLP classifier — the fastest program for exercising the full
//! dispute pipeline in tests and the quickstart example.

use crate::graph::builder::GraphBuilder;

use super::BuiltModel;

/// Configuration for [`build_mlp`].
#[derive(Debug, Clone)]
pub struct MlpConfig {
    pub d_in: usize,
    pub d_hidden: usize,
    pub classes: usize,
    pub batch: usize,
}

/// `loss = CE(relu(x@w1+b1)@w2 + b2, targets)`.
///
/// Data inputs: `x [batch, d_in]`, `targets [batch]`.
pub fn build_mlp(cfg: &MlpConfig) -> BuiltModel {
    let MlpConfig { d_in, d_hidden, classes, batch } = *cfg;
    let mut b = GraphBuilder::new();
    let x = b.data("x", [batch, d_in]);
    let targets = b.data("targets", [batch]);
    let w1 = b.param("fc1.w", [d_in, d_hidden]);
    let b1 = b.param("fc1.b", [d_hidden]);
    let w2 = b.param("fc2.w", [d_hidden, classes]);
    let b2 = b.param("fc2.b", [classes]);
    let h = b.matmul("fc1", x, w1);
    let hb = b.add_bcast("fc1.bias", h, b1);
    let a = b.relu("relu", hb);
    let l0 = b.matmul("fc2", a, w2);
    let logits = b.add_bcast("fc2.bias", l0, b2);
    let loss = b.ce_loss("loss", logits, targets);
    BuiltModel { builder: b, logits, loss, frozen: Vec::new() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::autodiff::Optimizer;
    use crate::graph::executor::{execute, ExecOpts};
    use crate::graph::kernels::Backend;
    use crate::tensor::Tensor;
    use std::collections::BTreeMap;

    #[test]
    fn mlp_learns_a_linear_rule() {
        let cfg = MlpConfig { d_in: 8, d_hidden: 16, classes: 4, batch: 16 };
        let m = build_mlp(&cfg);
        let ts = m.train_step(&Optimizer::adam(0.05));
        let mut st = m.init_state(3, &Optimizer::adam(0.05));
        let mut first = None;
        let mut last = 0.0;
        for step in 1..=40u64 {
            let x = Tensor::rand([cfg.batch, cfg.d_in], step, 1.0);
            // label = argmax of first 4 features
            let t: Vec<f32> = (0..cfg.batch)
                .map(|r| {
                    let row = &x.data()[r * cfg.d_in..r * cfg.d_in + 4];
                    row.iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0 as f32
                })
                .collect();
            let mut batch = BTreeMap::new();
            batch.insert("x".into(), x);
            batch.insert("targets".into(), Tensor::new([cfg.batch], t));
            let e = execute(&ts.graph, &st, &batch, Backend::Rep, step, &ExecOpts::default());
            last = e.values[ts.loss.node][0].data()[0];
            first.get_or_insert(last);
            let mut next = st.clone();
            for (name, slot) in &ts.param_updates {
                next.params.insert(name.clone(), e.values[slot.node][slot.out_idx].clone());
            }
            for (name, slot) in &ts.opt_updates {
                next.opt.insert(name.clone(), e.values[slot.node][slot.out_idx].clone());
            }
            next.step += 1;
            st = next;
        }
        assert!(last < first.unwrap() * 0.75, "{:?} -> {last}", first.unwrap());
    }
}
