//! # Verde: Verification via Refereed Delegation for Machine Learning Programs
//!
//! A reproduction of the Verde paper (Arun et al., 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the dispute-resolution coordinator: trainers,
//!   referee, the two-phase bisection protocol, Merkle commitments, and the
//!   deterministic (RepOps) execution substrate it arbitrates over.
//! * **Layer 2** — a JAX training-step / inference graph (`python/compile/model.py`)
//!   lowered AOT to HLO text and executed from Rust via PJRT (`runtime`).
//! * **Layer 1** — Pallas kernels implementing reproducible (fixed
//!   floating-point-order) operators (`python/compile/kernels/`).
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured results.

pub mod tensor;
pub mod hash;
pub mod graph;
pub mod model;
pub mod train;
pub mod verde;
pub mod net;
pub mod obs;
pub mod service;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod util;
