//! Binary Merkle trees over protocol commitments (paper Figure 2).
//!
//! The checkpoint after a training step is the Merkle root over the hashes
//! of all `AugmentedCGNode`s of that step; Case 2a of the referee's decision
//! algorithm verifies *membership proofs* against committed roots. Interior
//! nodes are domain-separated from leaves so a leaf can never be
//! reinterpreted as an interior node (second-preimage hardening).

use super::Hash;

const LEAF_TAG: u8 = 0x00;
const NODE_TAG: u8 = 0x01;

/// A Merkle tree retaining all levels (so proofs can be generated).
#[derive(Debug, Clone)]
pub struct MerkleTree {
    /// `levels[0]` = hashed leaves, `levels.last()` = `[root]`.
    levels: Vec<Vec<Hash>>,
}

/// A membership proof: sibling hashes bottom-up plus the leaf index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MerkleProof {
    pub index: usize,
    pub siblings: Vec<Hash>,
}

impl MerkleProof {
    /// Exact wire size in bytes (leaf index + sibling count + siblings),
    /// matching the encoding in [`crate::verde::wire`].
    pub fn byte_len(&self) -> usize {
        16 + 32 * self.siblings.len()
    }
}

/// Leaf commitment: domain-separated hash of the raw leaf hash.
fn leaf_hash(h: &Hash) -> Hash {
    Hash::combine(LEAF_TAG, h, &Hash::ZERO)
}

impl MerkleTree {
    /// Build from pre-hashed leaves. An odd node at any level is promoted by
    /// pairing with itself (standard duplicate-last construction).
    ///
    /// # Panics
    /// On zero leaves — the protocol never commits to an empty step.
    pub fn build(leaves: &[Hash]) -> MerkleTree {
        assert!(!leaves.is_empty(), "cannot build a Merkle tree over 0 leaves");
        let mut levels = vec![leaves.iter().map(leaf_hash).collect::<Vec<_>>()];
        while levels.last().unwrap().len() > 1 {
            let prev = levels.last().unwrap();
            let mut next = Vec::with_capacity(prev.len().div_ceil(2));
            for pair in prev.chunks(2) {
                let l = &pair[0];
                let r = pair.get(1).unwrap_or(l);
                next.push(Hash::combine(NODE_TAG, l, r));
            }
            levels.push(next);
        }
        MerkleTree { levels }
    }

    pub fn root(&self) -> Hash {
        self.levels.last().unwrap()[0]
    }

    pub fn leaf_count(&self) -> usize {
        self.levels[0].len()
    }

    /// Membership proof for leaf `index`.
    pub fn prove(&self, index: usize) -> MerkleProof {
        assert!(index < self.leaf_count(), "leaf {index} out of range");
        let mut siblings = Vec::new();
        let mut i = index;
        for level in &self.levels[..self.levels.len() - 1] {
            let sib = if i % 2 == 0 {
                // right sibling, or self-duplicate at the edge
                *level.get(i + 1).unwrap_or(&level[i])
            } else {
                level[i - 1]
            };
            siblings.push(sib);
            i /= 2;
        }
        MerkleProof { index, siblings }
    }

    /// Verify `proof` that `leaf` (raw hash, pre-domain-separation) is the
    /// `proof.index`-th leaf of the tree with root `root`.
    pub fn verify(root: &Hash, leaf: &Hash, proof: &MerkleProof) -> bool {
        let mut acc = leaf_hash(leaf);
        let mut i = proof.index;
        for sib in &proof.siblings {
            acc = if i % 2 == 0 {
                Hash::combine(NODE_TAG, &acc, sib)
            } else {
                Hash::combine(NODE_TAG, sib, &acc)
            };
            i /= 2;
        }
        acc == *root
    }
}

/// Convenience: Merkle root of a hash sequence (the `MerkleHash(seq)` of
/// Algorithm 2 line 7).
pub fn merkle_root(leaves: &[Hash]) -> Hash {
    MerkleTree::build(leaves).root()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall, Gen};

    fn leaves(n: usize, seed: u64) -> Vec<Hash> {
        (0..n)
            .map(|i| Hash::of_bytes(format!("leaf-{seed}-{i}").as_bytes()))
            .collect()
    }

    #[test]
    fn single_leaf_tree() {
        let ls = leaves(1, 0);
        let t = MerkleTree::build(&ls);
        let p = t.prove(0);
        assert!(p.siblings.is_empty());
        assert!(MerkleTree::verify(&t.root(), &ls[0], &p));
    }

    #[test]
    fn all_proofs_verify_various_sizes() {
        for n in [1, 2, 3, 4, 5, 7, 8, 15, 16, 17, 33] {
            let ls = leaves(n, n as u64);
            let t = MerkleTree::build(&ls);
            for i in 0..n {
                let p = t.prove(i);
                assert!(MerkleTree::verify(&t.root(), &ls[i], &p), "n={n} i={i}");
            }
        }
    }

    #[test]
    fn tampered_proofs_fail() {
        let ls = leaves(9, 1);
        let t = MerkleTree::build(&ls);
        let root = t.root();
        let p = t.prove(4);
        // wrong leaf
        assert!(!MerkleTree::verify(&root, &ls[5], &p));
        // wrong index
        let mut p2 = p.clone();
        p2.index = 5;
        assert!(!MerkleTree::verify(&root, &ls[4], &p2));
        // corrupted sibling
        let mut p3 = p.clone();
        p3.siblings[0] = Hash::of_bytes(b"evil");
        assert!(!MerkleTree::verify(&root, &ls[4], &p3));
        // wrong root
        assert!(!MerkleTree::verify(&Hash::of_bytes(b"no"), &ls[4], &p));
    }

    #[test]
    fn root_sensitive_to_any_leaf_and_to_order() {
        let ls = leaves(8, 2);
        let r = merkle_root(&ls);
        for i in 0..8 {
            let mut tampered = ls.clone();
            tampered[i] = Hash::of_bytes(b"swap");
            assert_ne!(merkle_root(&tampered), r, "leaf {i}");
        }
        let mut swapped = ls.clone();
        swapped.swap(2, 3);
        assert_ne!(merkle_root(&swapped), r);
    }

    #[test]
    fn leaf_interior_domain_separation() {
        // A 2-leaf root must not equal any single-leaf construction over the
        // concatenated children (classic CVE-2012-2459-style ambiguity).
        let ls = leaves(2, 3);
        let t = MerkleTree::build(&ls);
        let fake = Hash::combine(NODE_TAG, &ls[0], &ls[1]);
        assert_ne!(t.root(), fake);
    }

    #[test]
    fn prop_proofs_roundtrip_and_cross_fail() {
        forall("merkle proofs verify; cross-leaf proofs fail", 48, |g: &mut Gen| {
            let n = g.usize_in(1, 40);
            let ls: Vec<Hash> =
                (0..n).map(|i| Hash::of_bytes(&[(g.u64() & 0xff) as u8, i as u8])).collect();
            let t = MerkleTree::build(&ls);
            let i = g.usize_in(0, n - 1);
            let p = t.prove(i);
            assert!(MerkleTree::verify(&t.root(), &ls[i], &p));
            let j = g.usize_in(0, n - 1);
            if ls[j] != ls[i] {
                assert!(!MerkleTree::verify(&t.root(), &ls[j], &p));
            }
        });
    }
}
