//! Cryptographic commitments: SHA-256 hashing of tensors and protocol
//! objects, plus Merkle trees ([`merkle`]) for the checkpoint format of
//! paper §2.2 / Figure 2.

pub mod merkle;
pub mod sha256;

use std::fmt;

use self::sha256::Sha256;

use crate::tensor::Tensor;

/// A 32-byte SHA-256 digest. The protocol's only commitment primitive
/// (the paper assumes "a standard collision-resistant hash function like
/// SHA-256", §2).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Hash(pub [u8; 32]);

impl Hash {
    pub const ZERO: Hash = Hash([0u8; 32]);

    pub fn of_bytes(bytes: &[u8]) -> Hash {
        let mut h = Sha256::new();
        h.update(bytes);
        Hash(h.finalize().into())
    }

    /// Domain-separated two-input hash (Merkle interior nodes etc.).
    pub fn combine(tag: u8, left: &Hash, right: &Hash) -> Hash {
        let mut h = Sha256::new();
        h.update([tag]);
        h.update(left.0);
        h.update(right.0);
        Hash(h.finalize().into())
    }

    pub fn to_hex(&self) -> String {
        let mut s = String::with_capacity(64);
        for b in self.0 {
            s.push_str(&format!("{b:02x}"));
        }
        s
    }

    /// Short prefix for log lines.
    pub fn short(&self) -> String {
        self.to_hex()[..8].to_string()
    }
}

impl fmt::Debug for Hash {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "#{}", self.short())
    }
}

impl fmt::Display for Hash {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.to_hex())
    }
}

/// An incremental SHA-256 hasher with domain separation, used to build
/// structured commitments (tensor payloads, protocol nodes).
pub struct Hasher {
    inner: Sha256,
}

impl Hasher {
    /// Start a hasher domain-separated by `tag` (prevents cross-protocol
    /// collisions between e.g. tensor hashes and node hashes).
    pub fn new(tag: &str) -> Hasher {
        let mut inner = Sha256::new();
        inner.update((tag.len() as u64).to_le_bytes());
        inner.update(tag.as_bytes());
        Hasher { inner }
    }

    pub fn bytes(&mut self, bytes: &[u8]) -> &mut Self {
        self.inner.update((bytes.len() as u64).to_le_bytes());
        self.inner.update(bytes);
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.inner.update(v.to_le_bytes());
        self
    }

    pub fn str(&mut self, s: &str) -> &mut Self {
        self.bytes(s.as_bytes())
    }

    pub fn hash(&mut self, h: &Hash) -> &mut Self {
        self.inner.update(h.0);
        self
    }

    pub fn finish(self) -> Hash {
        Hash(self.inner.finalize().into())
    }
}

/// Commit to a tensor: shape (rank-prefixed, u64 LE dims) then the raw
/// little-endian FP32 bit patterns. Bitwise equality of tensors ⟺ equal
/// hashes (modulo SHA-256 collisions).
pub fn hash_tensor(t: &Tensor) -> Hash {
    let mut h = Hasher::new("verde.tensor.v1");
    h.u64(t.rank() as u64);
    for &d in t.shape() {
        h.u64(d as u64);
    }
    // Hash payload in one update; 4-byte LE per element.
    h.bytes(&t.to_le_bytes());
    h.finish()
}

/// Hash a labelled list of tensors (e.g. a parameter set) — order matters.
pub fn hash_tensor_list(items: &[(&str, &Tensor)]) -> Hash {
    let mut h = Hasher::new("verde.tensorlist.v1");
    h.u64(items.len() as u64);
    for (name, t) in items {
        h.str(name);
        let th = hash_tensor(t);
        h.hash(&th);
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sha256_known_answer() {
        // SHA-256("abc")
        let h = Hash::of_bytes(b"abc");
        assert_eq!(
            h.to_hex(),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn tensor_hash_sensitive_to_bits_and_shape() {
        let a = Tensor::new([2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new([2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(hash_tensor(&a), hash_tensor(&b));

        let reshaped = a.reshape([4]);
        assert_ne!(hash_tensor(&a), hash_tensor(&reshaped), "shape is committed");

        let mut c = a.clone();
        c.data_mut()[3] = 4.0 + f32::EPSILON * 4.0;
        assert_ne!(hash_tensor(&a), hash_tensor(&c), "one-ulp flip changes hash");

        let zero = Tensor::new([1], vec![0.0]);
        let negzero = Tensor::new([1], vec![-0.0]);
        assert_ne!(hash_tensor(&zero), hash_tensor(&negzero), "raw bits, not values");
    }

    #[test]
    fn domain_separation() {
        let t = Tensor::new([1], vec![1.0]);
        let th = hash_tensor(&t);
        let raw = Hash::of_bytes(&t.to_le_bytes());
        assert_ne!(th, raw);
    }

    #[test]
    fn tensor_list_order_matters() {
        let a = Tensor::new([1], vec![1.0]);
        let b = Tensor::new([1], vec![2.0]);
        let h1 = hash_tensor_list(&[("a", &a), ("b", &b)]);
        let h2 = hash_tensor_list(&[("b", &b), ("a", &a)]);
        assert_ne!(h1, h2);
    }

    #[test]
    fn hasher_length_prefixing_prevents_ambiguity() {
        // ("ab","c") must differ from ("a","bc")
        let mut h1 = Hasher::new("t");
        h1.str("ab").str("c");
        let mut h2 = Hasher::new("t");
        h2.str("a").str("bc");
        assert_ne!(h1.finish(), h2.finish());
    }
}
