//! `verde` — CLI for the refereed-delegation training system.
//!
//! Subcommands:
//!   train        run a training job honestly and print the loss curve + commitment
//!   dispute      delegate to 2 trainers (one faulty) and resolve the dispute
//!   tournament   k trainers with a mix of faults; run the knockout
//!   info         print a model preset's graph statistics
//!   worker       serve a worker process over TCP (`--listen`, `--fault`)
//!   coordinator  delegate jobs to a TCP worker pool, k workers per segment
//!                (multiplexed event-driven core; `--blocking` for the
//!                legacy scheduler; `--deadline-ms`, `--health-ms`,
//!                `--requeues`, `--resolvers`, `--readmit-ms` for the
//!                failure policy; `--segments` shards each job at its
//!                checkpoint boundaries and `--transfer` seeds each
//!                segment with its predecessor's Merkle-verified
//!                checkpoint so it trains only the delta; `--audit-rate R`
//!                runs jobs on the optimistic staked tier — one worker per
//!                job, segments spot-checked by sampled replay at rate R,
//!                divergence escalated to a tournament and convictions
//!                slashed (`--audit-seed`, `--stake` tune the sampler key
//!                and the per-worker deposit); `--serve ADDR`
//!                exposes the Submit/Status/Cancel client API over TCP —
//!                `--serve-conns N` accepts N concurrent clients — instead
//!                of submitting `--jobs` itself; `--journal PATH` makes the
//!                coordinator durable: state transitions are written-ahead
//!                to PATH and a restart with the same path recovers —
//!                settled jobs re-serve their logged verdict, in-flight
//!                jobs re-train only unsettled segments)
//!   client       drive a serving coordinator remotely: submit `--jobs`
//!                jobs over the wire (optionally `--segments`/`--transfer`
//!                sharded), poll status, optionally `--cancel N` one of
//!                them mid-flight
//!   stats        fetch the live stats snapshot from a serving coordinator
//!                or worker (`--from host:port`); Prometheus text by
//!                default, `--json` for the JSON rendering
//!
//! Every subcommand accepts `--threads N` (default: `VERDE_THREADS`, else
//! all cores): the RepOps kernel thread count. Results are bitwise
//! identical at any setting — only wall-clock changes.
//!
//! Examples:
//!   verde train --model llama-tiny --steps 32 --batch 2 --seq 8
//!   verde dispute --model mlp --steps 16 --fault tamper --fault-step 9
//!   verde tournament --model mlp --steps 8 --k 4
//!   verde info --model llama-small
//!   verde worker --listen 127.0.0.1:7000
//!   verde worker --listen 127.0.0.1:7001 --fault tamper@3
//!   verde coordinator --workers 127.0.0.1:7000,127.0.0.1:7001 --jobs 8 --k 2 --segments 4
//!   verde coordinator --workers 127.0.0.1:7000,127.0.0.1:7001 --jobs 8 --segments 4 --audit-rate 0.25
//!   verde coordinator --workers 127.0.0.1:7000,127.0.0.1:7001 --serve 127.0.0.1:9000
//!   verde coordinator --workers 127.0.0.1:7000,127.0.0.1:7001 --jobs 8 --journal /var/lib/verde/coord.wal
//!   verde client --coordinator 127.0.0.1:9000 --jobs 4 --segments 4 --cancel 1
//!   verde stats --from 127.0.0.1:9000 --json

use std::net::TcpListener;

use verde::graph::kernels::Backend;
use verde::model::Preset;
use verde::net::mux::Mux;
use verde::net::tcp::{serve_connection, spawn_server_threaded, TcpEndpoint};
use verde::net::Endpoint as _;
use verde::service::{
    run_service_blocking, Delegation, DelegationFrontend, FaultPlan, JobPolicy, JobRequest,
    JobStatus, PooledWorker, RemoteStatus, ServiceConfig, ServiceReport, WorkerHost, WorkerPool,
};
use verde::tensor::profile::HardwareProfile;
use verde::train::session::Session;
use verde::train::JobSpec;
use verde::util::cli::Args;
use verde::util::metrics::human_bytes;
use verde::verde::faults::{first_mutable_node, first_update_node, Fault};
use verde::verde::protocol::{Request, Response};
use verde::verde::tournament::run_tournament;
use verde::verde::trainer::TrainerNode;
use verde::verde::run_dispute;

fn spec_from(args: &Args) -> JobSpec {
    let preset = Preset::parse(args.get_or("model", "mlp"))
        .unwrap_or_else(|| panic!("unknown --model (try: mlp, llama-tiny, llama-small, llama-base, bert-tiny, bert-small)"));
    let mut spec = JobSpec::quick(preset, args.get_u64("steps", 16));
    spec.batch = args.get_usize("batch", 2);
    spec.seq = args.get_usize("seq", 8);
    spec.checkpoint_n = args.get_u64("checkpoint-n", 4);
    spec.weight_seed = args.get_u64("weight-seed", 0xA11CE);
    spec.data_seed = args.get_u64("data-seed", 0xDA7A);
    spec
}

fn fault_from(args: &Args, spec: JobSpec) -> Fault {
    let step = args.get_u64("fault-step", spec.steps / 2 + 1);
    let session = Session::new(spec);
    let upd = first_update_node(&session.program).expect("no trainable params");
    match args.get_or("fault", "tamper") {
        "tamper" => Fault::TamperOutput {
            step,
            node: args.get_usize("fault-node", upd),
            delta: args.get_f32("fault-delta", 0.05),
        },
        "wrong-op" => Fault::WrongOperator {
            step,
            node: args.get_usize(
                "fault-node",
                first_mutable_node(&session.program.graph).expect("no mutable op"),
            ),
        },
        "wrong-data" => Fault::WrongData { step },
        "skip-opt" => Fault::SkipOptimizer { step },
        "skip-steps" => Fault::SkipSteps { after: step.saturating_sub(1).max(1) },
        "forged-lineage" => {
            let mm = session
                .program
                .graph
                .nodes
                .iter()
                .position(|n| matches!(n.op, verde::graph::Op::MatMul))
                .expect("no matmul");
            Fault::ForgedLineage { step, node: args.get_usize("fault-node", mm) }
        }
        "inconsistent" => Fault::InconsistentCommit { step },
        "non-rep" => Fault::NonRepHardware,
        other => panic!("unknown --fault '{other}' (tamper, wrong-op, wrong-data, skip-opt, skip-steps, forged-lineage, inconsistent, non-rep)"),
    }
}

fn cmd_train(args: &Args) {
    let spec = spec_from(args);
    println!("training {} for {} steps (batch={}, seq={})", spec.preset.name(), spec.steps, spec.batch, spec.seq);
    let mut t = TrainerNode::honest("trainer", spec);
    let t0 = std::time::Instant::now();
    let commit = t.train();
    let dt = t0.elapsed();
    for (i, l) in t.losses.iter().enumerate() {
        if i == 0 || (i + 1) % 10 == 0 || i + 1 == t.losses.len() {
            println!("  step {:>5}  loss {:.4}", i + 1, l);
        }
    }
    println!("final commitment: {}", commit.to_hex());
    println!(
        "wall {dt:?}  ({:.1} steps/s)  checkpoint storage {}",
        spec.steps as f64 / dt.as_secs_f64(),
        human_bytes(t.counters.get("checkpoint_bytes_stored"))
    );
}

fn cmd_dispute(args: &Args) {
    let spec = spec_from(args);
    let fault = fault_from(args, spec);
    println!("job: {} x{} steps; cheater fault: {fault:?}", spec.preset.name(), spec.steps);
    let backend = if matches!(fault, Fault::NonRepHardware) {
        Backend::Free(HardwareProfile::T4_16G)
    } else {
        Backend::Rep
    };
    let mut honest = TrainerNode::honest("honest", spec);
    let mut cheat = TrainerNode::new("cheat", spec, backend, fault);
    print!("training honest trainer... ");
    honest.train();
    print!("done. training cheater... ");
    cheat.train();
    println!("done.");
    let r = run_dispute(spec, honest, cheat);
    println!("--- dispute report ---");
    println!("verdict:        {:?}", r.verdict);
    println!("diverging step: {:?}", r.diverging_step);
    println!("diverging node: {:?}", r.diverging_node);
    println!("phase-1 rounds: {}", r.phase1_rounds);
    println!(
        "bytes moved:    trainer0 {} / trainer1 {}",
        human_bytes(r.bytes[0]),
        human_bytes(r.bytes[1])
    );
    println!("referee work:   {}", r.referee.to_json());
}

fn cmd_tournament(args: &Args) {
    let spec = spec_from(args);
    let k = args.get_usize("k", 4);
    println!("tournament: {k} trainers, {} x{} steps", spec.preset.name(), spec.steps);
    let session = Session::new(spec);
    let upd = first_update_node(&session.program).expect("no trainable params");
    let mut trainers: Vec<TrainerNode> = (0..k)
        .map(|i| {
            // trainer 0 honest; others get a spread of faults
            let fault = match i % 4 {
                0 => Fault::None,
                1 => Fault::TamperOutput { step: 2, node: upd, delta: 0.03 },
                2 => Fault::WrongData { step: 3 },
                _ => Fault::SkipSteps { after: spec.steps / 2 },
            };
            let mut t = TrainerNode::new(&format!("t{i}"), spec, Backend::Rep, fault);
            print!("training t{i} ({:?})... ", fault);
            t.train();
            println!("done");
            t
        })
        .collect();
    let r = run_tournament(spec, &mut trainers);
    println!("--- tournament report ---");
    println!("winner:    t{} (commitment {})", r.winner, r.accepted.short());
    println!("disputes:  {}", r.disputes);
    for (i, v) in &r.eliminated {
        println!("eliminated t{i}: {v:?}");
    }
}

fn cmd_info(args: &Args) {
    let spec = spec_from(args);
    let session = Session::new(spec);
    let m = spec.preset.build(spec.batch, spec.seq);
    println!("model {}", spec.preset.name());
    println!("  parameters:        {}", m.n_params());
    println!("  forward nodes:     {}", m.builder.graph.len());
    println!("  extended nodes:    {}", session.program.graph.len());
    println!("  trainable tensors: {}", session.program.param_updates.len());
    println!("  state size:        {}", human_bytes(session.genesis.byte_len() as u64));
    println!("  graph commitment:  {}", session.program.graph.structure_hash().to_hex());
    println!("  job commitment:    {}", session.job_hash.to_hex());
}

fn cmd_worker(args: &Args) {
    let listen = args.get_or("listen", "127.0.0.1:7000");
    let plan = FaultPlan::parse(args.get_or("fault", "none")).unwrap_or_else(|| {
        panic!("unknown --fault (none, tamper[@S], wrong-op[@S], wrong-data[@S], skip-opt[@S], skip-steps[@S], forged-lineage[@S], inconsistent[@S], stall[@N], nap[@N])")
    });
    let max_conns = args.get("max-conns").map(|v| {
        v.parse::<usize>().unwrap_or_else(|_| panic!("--max-conns wants an integer, got '{v}'"))
    });
    let listener = TcpListener::bind(listen)
        .unwrap_or_else(|e| panic!("cannot bind {listen}: {e}"));
    let addr = listener.local_addr().expect("local addr");
    println!("worker listening on {addr} (plan: {plan})");
    let mut host = WorkerHost::new(&format!("worker@{addr}"), plan);
    let mut served = 0usize;
    for conn in listener.incoming() {
        let stream = match conn {
            Ok(s) => s,
            Err(e) => {
                eprintln!("accept failed: {e}");
                continue;
            }
        };
        let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
        match serve_connection(stream, &mut host) {
            Ok(stats) => println!(
                "connection from {peer}: {} requests, {} in / {} out",
                stats.requests,
                human_bytes(stats.bytes_in),
                human_bytes(stats.bytes_out)
            ),
            Err(e) => eprintln!("connection from {peer} failed: {e}"),
        }
        served += 1;
        if max_conns.is_some_and(|m| served >= m) {
            break;
        }
    }
    println!("worker exiting after {served} connections ({})", host.counters.to_json());
}

fn print_report(report: &ServiceReport) {
    println!("--- service report ---");
    for o in &report.outcomes {
        println!(
            "job {:>3}: winner {:<24} disputes {}  eliminated {}  requeues {}  {}  {:?}{}",
            o.job_id,
            if o.cancelled {
                "<cancelled>"
            } else {
                o.winner.as_deref().unwrap_or("<unresolved>")
            },
            o.disputes,
            o.eliminated,
            o.requeues,
            human_bytes(o.bytes),
            o.wall,
            if o.segments.len() > 1 {
                format!("  ({} segments)", o.segments.len())
            } else {
                String::new()
            }
        );
    }
    if !report.revoked.is_empty() {
        println!("revoked/suspended workers: {}", report.revoked.join(", "));
    }
    if report.total_seeded_segments() > 0 || report.total_uploads_rejected() > 0 {
        println!(
            "state transfer: {} seeded segments, {} moved, {} uploads rejected, {} worker-steps trained",
            report.total_seeded_segments(),
            human_bytes(report.total_transfer_bytes()),
            report.total_uploads_rejected(),
            report.total_steps_trained(),
        );
    }
    if report.total_audit_sampled() > 0 || report.total_slashed() > 0 {
        println!(
            "audits: {} sampled, {} passed, {} escalated, {} replay steps, {} stake slashed",
            report.total_audit_sampled(),
            report.total_audit_passed(),
            report.total_audit_escalated(),
            report.total_audit_steps(),
            report.total_slashed(),
        );
        for s in &report.stakes {
            println!(
                "  stake {:<24} deposited {:>6}  locked {:>6}  slashed {:>6}",
                s.worker, s.deposited, s.locked, s.slashed
            );
        }
    }
    println!(
        "{} jobs in {:?}  ({:.2} jobs/s, {} total, {} / job, {} coordinator threads)",
        report.outcomes.len(),
        report.wall,
        report.jobs_per_sec(),
        human_bytes(report.total_bytes()),
        human_bytes(report.bytes_per_job() as u64),
        report.threads
    );
    println!("JSON {}", report.to_json());
}

fn cmd_coordinator(args: &Args) {
    let addrs = args.get_list("workers");
    assert!(!addrs.is_empty(), "--workers host:port[,host:port...] is required");
    let k = args.get_usize("k", addrs.len().min(4));
    let n_jobs = args.get_usize("jobs", 8) as u64;
    let blocking = args.flag("blocking");
    let base = spec_from(args);

    // Event-driven path: one multiplexed connection per worker, zero
    // coordinator threads per worker. `--blocking` keeps the legacy
    // thread-per-dispatch scheduler over blocking TCP endpoints.
    let mux = if blocking { None } else { Some(Mux::new()) };
    let workers: Vec<PooledWorker> = addrs
        .iter()
        .map(|addr| {
            let worker = match &mux {
                Some(mux) => {
                    let conn = mux
                        .connect(addr, addr)
                        .unwrap_or_else(|e| panic!("cannot connect to worker {addr}: {e}"));
                    PooledWorker::mux(addr, conn)
                }
                None => {
                    let ep = TcpEndpoint::connect(addr, addr)
                        .unwrap_or_else(|e| panic!("cannot connect to worker {addr}: {e}"));
                    PooledWorker::new(addr, ep)
                }
            };
            println!("connected to worker {addr}");
            worker
        })
        .collect();
    let pool = WorkerPool::new(workers);

    if blocking {
        let jobs: Vec<JobSpec> = (0..n_jobs)
            .map(|i| {
                let mut spec = base;
                spec.data_seed = base.data_seed.wrapping_add(i.wrapping_mul(0x9E37_79B9));
                spec
            })
            .collect();
        println!(
            "delegating {n_jobs} jobs ({} x{} steps) to {} workers, k={k} (blocking scheduler)",
            base.preset.name(),
            base.steps,
            pool.size(),
        );
        let report = run_service_blocking(jobs, &pool, k);
        print_report(&report);
        for mut w in pool.into_workers() {
            let _ = w.call(Request::Shutdown);
        }
        return;
    }

    let mut cfg = ServiceConfig::new(k);
    cfg.dispatch_deadline = std::time::Duration::from_millis(args.get_u64("deadline-ms", 600_000));
    cfg.call_deadline = std::time::Duration::from_millis(args.get_u64("call-deadline-ms", 60_000));
    cfg.max_requeues = args.get_u64("requeues", 3) as u32;
    cfg.resolvers = args.get_usize("resolvers", 4);
    cfg.health_check = args
        .get("health-ms")
        .map(|v| std::time::Duration::from_millis(v.parse().expect("--health-ms integer")));
    // Re-admission with exponential backoff is on by default in the CLI;
    // `--readmit-ms 0` restores permanent expulsion.
    let readmit_ms = args.get_u64("readmit-ms", 1000);
    cfg.readmit_backoff =
        (readmit_ms > 0).then(|| std::time::Duration::from_millis(readmit_ms));
    cfg.max_strikes = args.get_u64("max-strikes", 3) as u32;
    cfg.audit_seed = args.get_u64("audit-seed", 0);
    cfg.worker_stake = args.get_u64("stake", 1000);
    let segments = args.get_u64("segments", 1).max(1);
    let transfer = args.flag("transfer");
    // Optimistic tier: 0.0 keeps k-replication, anything in (0,1] leases a
    // single staked worker and spot-checks its commitments at that rate.
    let audit_rate = args.get_f32("audit-rate", 0.0);

    // `--journal PATH` makes the coordinator durable: every state
    // transition is journaled, and restarting with the same path recovers
    // — settled jobs re-serve their logged outcome, in-flight jobs re-train
    // only their unsettled segments.
    let (delegation, recovered) = match args.get("journal") {
        Some(path) => {
            let (d, handles) = Delegation::recover(&pool, cfg, path)
                .unwrap_or_else(|e| panic!("cannot recover journal {path}: {e}"));
            if !handles.is_empty() {
                let done = handles
                    .iter()
                    .filter(|h| matches!(h.try_status(), JobStatus::Done(_)))
                    .count();
                println!(
                    "recovered {} job(s) from {path} ({done} settled, {} re-queued)",
                    handles.len(),
                    handles.len() - done,
                );
            }
            (d, handles)
        }
        None => (Delegation::start(&pool, cfg), Vec::new()),
    };

    if let Some(listen) = args.get("serve") {
        // Serve the Submit/Status/Cancel client API over TCP: remote
        // `verde client` processes drive this delegation, concurrently —
        // each accepted connection runs on its own thread against a clone
        // of the frontend (shared handle registry).
        let conns = args.get_usize("serve-conns", 1);
        let listener =
            TcpListener::bind(listen).unwrap_or_else(|e| panic!("cannot bind {listen}: {e}"));
        let addr = listener.local_addr().expect("local addr");
        println!(
            "coordinator serving the client API on {addr} ({} workers, k={k}, up to {conns} concurrent connection(s))",
            pool.size()
        );
        let frontend = DelegationFrontend::new("coordinator", delegation.client())
            .with_stats(delegation.registry().clone());
        // Re-attach: pre-crash job ids answer Status/Cancel on this server.
        frontend.adopt(recovered);
        let server = spawn_server_threaded(listener, frontend.clone(), Some(conns));
        let frontend = server.join().expect("frontend accept thread");
        // Drain every remotely submitted job before reporting.
        let handles = frontend.handles();
        println!("all {} client connection(s) closed; draining {} jobs", conns, handles.len());
        for h in handles {
            h.wait();
        }
    } else {
        println!(
            "delegating {n_jobs} jobs ({} x{} steps, {segments} segment(s){}{}) to {} workers, k={k} (event-driven core)",
            base.preset.name(),
            base.steps,
            if transfer { ", state transfer" } else { "" },
            if audit_rate > 0.0 {
                format!(", optimistic audit_rate={audit_rate}")
            } else {
                String::new()
            },
            pool.size(),
        );
        let handles: Vec<_> = (0..n_jobs)
            .map(|i| {
                let mut spec = base;
                spec.data_seed = base.data_seed.wrapping_add(i.wrapping_mul(0x9E37_79B9));
                let mut req = JobRequest::new(spec).with_segments(segments);
                if transfer {
                    req = req.with_state_transfer();
                }
                if audit_rate > 0.0 {
                    req = req.with_audit(audit_rate);
                }
                delegation.submit(req)
            })
            .collect();
        for h in recovered.iter().chain(&handles) {
            h.wait();
        }
    }

    let report = delegation.finish();
    print_report(&report);

    // orderly shutdown (revoked workers are gone already)
    for mut w in pool.into_workers() {
        let _ = w.call(Request::Shutdown);
    }
}

fn cmd_client(args: &Args) {
    let addr = args.get("coordinator").expect("--coordinator host:port is required");
    let n_jobs = args.get_u64("jobs", 4);
    let segments = args.get_u64("segments", 1).max(1);
    let transfer = args.flag("transfer");
    let k = args.get_usize("k", 0);
    // Priorities are signed (higher schedules first, negatives demote).
    let priority = args
        .get("priority")
        .map(|v| {
            v.parse::<i64>()
                .unwrap_or_else(|_| panic!("--priority wants an integer, got '{v}'"))
        })
        .unwrap_or(0);
    let cancel_idx =
        args.get("cancel").map(|v| v.parse::<usize>().expect("--cancel wants a job index"));
    let base = spec_from(args);

    let mut ep = TcpEndpoint::connect("coordinator", addr)
        .unwrap_or_else(|e| panic!("cannot connect to coordinator {addr}: {e}"));
    let audit_rate = args.get_f32("audit-rate", 0.0).clamp(0.0, 1.0);
    let policy =
        JobPolicy { k, segments, priority, transfer, audit_rate, ..JobPolicy::default() };
    let mut ids: Vec<u64> = Vec::new();
    for i in 0..n_jobs {
        let mut spec = base;
        spec.data_seed = base.data_seed.wrapping_add(i.wrapping_mul(0x9E37_79B9));
        match ep.call(Request::Submit { spec, policy }) {
            Response::Submitted { job_id } => {
                println!("submitted job {job_id} ({} x{} steps)", spec.preset.name(), spec.steps);
                ids.push(job_id);
            }
            other => panic!("submit refused: {other:?}"),
        }
    }

    if let Some(idx) = cancel_idx {
        let job_id = *ids.get(idx).unwrap_or_else(|| {
            panic!("--cancel {idx} is out of range: only {} jobs were submitted", ids.len())
        });
        match ep.call(Request::Cancel { job_id }) {
            Response::Cancelled(ok) => println!(
                "cancel job {job_id}: {}",
                if ok { "accepted, leases released" } else { "too late (already finished)" }
            ),
            other => panic!("cancel failed: {other:?}"),
        }
    }

    let mut settled = vec![false; ids.len()];
    loop {
        for (i, &job_id) in ids.iter().enumerate() {
            if settled[i] {
                continue;
            }
            match ep.call(Request::Status { job_id }) {
                Response::Status(RemoteStatus::Done {
                    accepted,
                    cancelled,
                    disputes,
                    eliminated,
                }) => {
                    settled[i] = true;
                    let what = if cancelled {
                        "cancelled".to_string()
                    } else {
                        match accepted {
                            Some(h) => format!(
                                "accepted {} ({disputes} disputes, {eliminated} eliminated)",
                                h.short()
                            ),
                            None => "unresolved".to_string(),
                        }
                    };
                    println!("job {job_id}: {what}");
                }
                Response::Status(_) => {}
                other => panic!("status failed: {other:?}"),
            }
        }
        if settled.iter().all(|&s| s) {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
    println!("all {} jobs settled", ids.len());
}

fn cmd_stats(args: &Args) {
    let addr = args
        .get("from")
        .or_else(|| args.get("coordinator"))
        .expect("--from host:port is required (a serving coordinator or a worker)");
    let mut ep = TcpEndpoint::connect("stats", addr)
        .unwrap_or_else(|e| panic!("cannot connect to {addr}: {e}"));
    match ep.call(Request::Stats) {
        Response::Stats(snap) => {
            if args.flag("json") {
                println!("{}", snap.to_json());
            } else {
                print!("{}", snap.to_prometheus());
            }
        }
        Response::Refuse(why) => {
            eprintln!("{addr} refused the stats request: {why}");
            std::process::exit(1);
        }
        other => panic!("unexpected stats response: {other:?}"),
    }
}

fn main() {
    let args = Args::parse();
    // Global RepOps thread knob, honored by every subcommand (kernels are
    // bitwise identical at any thread count; this only changes wall-clock).
    // Falls back to VERDE_THREADS, then to the machine's core count.
    if let Some(t) = args.get("threads") {
        let t: usize =
            t.parse().unwrap_or_else(|_| panic!("--threads wants a positive integer, got '{t}'"));
        verde::util::parallel::set_threads(t);
    }
    match args.positional.first().map(String::as_str) {
        Some("train") => cmd_train(&args),
        Some("dispute") => cmd_dispute(&args),
        Some("tournament") => cmd_tournament(&args),
        Some("info") => cmd_info(&args),
        Some("worker") => cmd_worker(&args),
        Some("coordinator") => cmd_coordinator(&args),
        Some("client") => cmd_client(&args),
        Some("stats") => cmd_stats(&args),
        _ => {
            eprintln!(
                "usage: verde <train|dispute|tournament|info|worker|coordinator|client|stats> [--model M] [--steps N] [--threads T] ..."
            );
            eprintln!("see rust/src/main.rs header for examples");
            std::process::exit(2);
        }
    }
}
