//! Optimistic audit tier: deterministic spot-check sampling and stake
//! bookkeeping.
//!
//! Full k-replication buys the refereed-delegation guarantee by paying
//! `k ×` the training cost on every job. The audit tier buys the same
//! guarantee statistically (SPEX, arxiv 2503.18899; Optimistic Verifiable
//! Training, arxiv 2403.09603): a job with
//! [`JobPolicy::audit_rate`](crate::verde::protocol::JobPolicy::audit_rate)
//! `> 0` leases **one** staked worker that trains every segment and
//! commits each segment's checkpoint state root
//! ([`Request::CommitRoot`](crate::verde::protocol::Request::CommitRoot)).
//! The coordinator samples committed segments with the deterministic
//! [`AuditSampler`] and replays each sampled segment on an independent
//! worker seeded from the claimed predecessor checkpoint — one segment of
//! re-training, no prefix. A matching replay settles the segment; a
//! divergent replay escalates it into the full dispute tournament, and a
//! conviction slashes the worker's stake in the [`StakeLedger`].
//!
//! Expected honest cost per job: `(1 + audit_rate) × steps` worker-steps
//! instead of `k × steps` — the `1 + ε` economics the service needs at
//! fleet scale.

use std::collections::BTreeMap;

/// SplitMix64 finalizer: a bijective avalanche over `u64`. Public so tests
/// (and the bench) can reproduce the coordinator's sampling decisions.
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Deterministic per-segment audit coin.
///
/// Sampling must be unpredictable to the worker (it cannot know which
/// segments will be audited when it commits) yet reproducible by the
/// coordinator and its tests — so the coin is a keyed hash of
/// `(seed, job_id, seg_idx)`, not an ambient RNG. The same seed, job and
/// segment always land the same decision.
#[derive(Debug, Clone, Copy)]
pub struct AuditSampler {
    seed: u64,
}

impl AuditSampler {
    pub fn new(seed: u64) -> AuditSampler {
        AuditSampler { seed }
    }

    /// Should segment `seg_idx` of job `job_id` be replay-audited at
    /// `rate`? `rate <= 0` never samples, `rate >= 1` always samples, and
    /// in between the keyed hash's top 53 bits form a uniform draw from
    /// `[0, 1)`.
    pub fn sample(&self, job_id: u64, seg_idx: u64, rate: f32) -> bool {
        if rate <= 0.0 {
            return false;
        }
        if rate >= 1.0 {
            return true;
        }
        let x = splitmix64(
            self.seed
                ^ splitmix64(job_id)
                ^ splitmix64(seg_idx.wrapping_mul(0xD6E8_FEB8_6659_FD93)),
        );
        let draw = (x >> 11) as f64 / (1u64 << 53) as f64;
        draw < f64::from(rate)
    }
}

/// One worker's stake account.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StakeEntry {
    /// Worker name (the pool's stable identity).
    pub worker: String,
    /// Total ever deposited.
    pub deposited: u64,
    /// Portion locked behind an in-flight audit or escalation.
    pub locked: u64,
    /// Portion confiscated by convictions.
    pub slashed: u64,
}

impl StakeEntry {
    /// Stake neither locked nor slashed — what a new optimistic lease can
    /// bind.
    pub fn available(&self) -> u64 {
        self.deposited - self.locked - self.slashed
    }
}

/// Deposit / lock / slash / release bookkeeping for the optimistic tier.
///
/// Workers are enrolled lazily with a uniform deposit at their first
/// optimistic lease ([`StakeLedger::enroll`]). While a sampled segment's
/// replay (or its escalation tournament) is in flight the worker's
/// available stake is locked; a conviction moves the locked portion to
/// `slashed`, an acquittal releases it. A worker whose stake is fully
/// slashed is no longer [`eligible`](StakeLedger::eligible) for optimistic
/// leases — it can still serve replicated work, where honesty is enforced
/// by replication rather than collateral.
#[derive(Debug, Clone)]
pub struct StakeLedger {
    default_deposit: u64,
    accounts: BTreeMap<String, StakeEntry>,
}

impl StakeLedger {
    pub fn new(default_deposit: u64) -> StakeLedger {
        StakeLedger { default_deposit, accounts: BTreeMap::new() }
    }

    /// Register `worker` with the default deposit if unseen; no-op
    /// otherwise.
    pub fn enroll(&mut self, worker: &str) {
        if !self.accounts.contains_key(worker) {
            self.accounts.insert(
                worker.to_string(),
                StakeEntry {
                    worker: worker.to_string(),
                    deposited: self.default_deposit,
                    locked: 0,
                    slashed: 0,
                },
            );
        }
    }

    /// Stake `worker` could bind right now (unseen workers report the
    /// deposit enrollment would grant them).
    pub fn available(&self, worker: &str) -> u64 {
        match self.accounts.get(worker) {
            Some(e) => e.available(),
            None => self.default_deposit,
        }
    }

    /// May `worker` take an optimistic lease? Requires positive available
    /// stake: a slashed-out worker has nothing left to forfeit, so its
    /// commitments are worthless.
    pub fn eligible(&self, worker: &str) -> bool {
        self.available(worker) > 0
    }

    /// Lock `worker`'s full available stake behind an in-flight audit.
    /// Returns the amount locked.
    pub fn lock(&mut self, worker: &str) -> u64 {
        self.enroll(worker);
        let e = self.accounts.get_mut(worker).expect("just enrolled");
        let amount = e.available();
        e.locked += amount;
        amount
    }

    /// Release `worker`'s locked stake back to available (audit passed,
    /// or escalation settled without convicting it).
    pub fn release(&mut self, worker: &str) {
        if let Some(e) = self.accounts.get_mut(worker) {
            e.locked = 0;
        }
    }

    /// Confiscate `worker`'s locked stake (conviction). Returns the amount
    /// slashed — zero when nothing was locked.
    pub fn slash(&mut self, worker: &str) -> u64 {
        self.enroll(worker);
        let e = self.accounts.get_mut(worker).expect("just enrolled");
        let amount = e.locked;
        e.locked = 0;
        e.slashed += amount;
        amount
    }

    /// Reinstate an account with exact `deposited`/`slashed` amounts and
    /// nothing locked — the recovery path's primitive. Journal replay folds
    /// lock/release/slash entries into per-worker totals and then calls
    /// this once per account; any stake still locked at the crash is
    /// deliberately *not* restored as locked (the audit it backed died with
    /// the process and its segment is re-queued), so it returns to
    /// available rather than leaking.
    pub fn restore(&mut self, worker: &str, deposited: u64, slashed: u64) {
        self.accounts.insert(
            worker.to_string(),
            StakeEntry {
                worker: worker.to_string(),
                deposited,
                locked: 0,
                slashed: slashed.min(deposited),
            },
        );
    }

    /// Total stake currently locked across all accounts.
    pub fn total_locked(&self) -> u64 {
        self.accounts.values().map(|e| e.locked).sum()
    }

    /// Total stake ever slashed across all accounts.
    pub fn total_slashed(&self) -> u64 {
        self.accounts.values().map(|e| e.slashed).sum()
    }

    /// Point-in-time copy of every account, sorted by worker name.
    pub fn snapshot(&self) -> Vec<StakeEntry> {
        self.accounts.values().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampler_is_deterministic_and_respects_bounds() {
        let s = AuditSampler::new(0xA0D1_7);
        for job in 0..8u64 {
            for seg in 0..8u64 {
                assert!(!s.sample(job, seg, 0.0), "rate 0 sampled {job}/{seg}");
                assert!(s.sample(job, seg, 1.0), "rate 1 skipped {job}/{seg}");
                assert_eq!(
                    s.sample(job, seg, 0.5),
                    s.sample(job, seg, 0.5),
                    "non-deterministic at {job}/{seg}"
                );
            }
        }
        // A different seed flips at least one decision over a modest grid —
        // the coin is keyed, not constant.
        let t = AuditSampler::new(0xBEEF);
        let flipped = (0..64u64)
            .flat_map(|j| (0..4u64).map(move |g| (j, g)))
            .any(|(j, g)| s.sample(j, g, 0.5) != t.sample(j, g, 0.5));
        assert!(flipped);
    }

    #[test]
    fn sampler_frequency_tracks_rate() {
        let s = AuditSampler::new(7);
        let n = 10_000u64;
        for rate in [0.1f32, 0.5, 0.9] {
            let hits = (0..n).filter(|&j| s.sample(j, 0, rate)).count() as f64;
            let freq = hits / n as f64;
            assert!(
                (freq - f64::from(rate)).abs() < 0.03,
                "rate {rate}: observed {freq}"
            );
        }
    }

    #[test]
    fn ledger_lifecycle_deposit_lock_slash_release() {
        let mut l = StakeLedger::new(1000);
        assert!(l.eligible("w0"));
        assert_eq!(l.available("w0"), 1000);

        // Lock binds the full available stake.
        assert_eq!(l.lock("w0"), 1000);
        assert_eq!(l.available("w0"), 0);
        assert_eq!(l.total_locked(), 1000);

        // Release restores it.
        l.release("w0");
        assert_eq!(l.available("w0"), 1000);
        assert_eq!(l.total_locked(), 0);

        // Slash confiscates exactly the locked portion, permanently.
        assert_eq!(l.lock("w0"), 1000);
        assert_eq!(l.slash("w0"), 1000);
        assert_eq!(l.available("w0"), 0);
        assert_eq!(l.total_slashed(), 1000);
        assert!(!l.eligible("w0"), "slashed-out worker stays ineligible");
        // Nothing left to lock or slash.
        assert_eq!(l.lock("w0"), 0);
        assert_eq!(l.slash("w0"), 0);

        // Other workers are unaffected.
        assert!(l.eligible("w1"));
        let snap = l.snapshot();
        assert_eq!(snap.len(), 1, "only enrolled workers appear: {snap:?}");
        assert_eq!(snap[0].worker, "w0");
        assert_eq!(snap[0].slashed, 1000);
    }

    #[test]
    fn slash_without_lock_confiscates_nothing() {
        let mut l = StakeLedger::new(500);
        assert_eq!(l.slash("w"), 0);
        assert_eq!(l.available("w"), 500, "unlocked stake survives a stray slash");
    }
}
