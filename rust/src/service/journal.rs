//! Write-ahead job journal: the coordinator's durable memory.
//!
//! The referee's guarantee (correct result if one leased worker is honest)
//! is only as strong as the referee's memory. Without a journal the
//! coordinator is an in-memory single point of failure: a restart strands
//! every submitted handle, forgets every lease and verdict, and silently
//! voids the audit tier's slashing threat. This module makes the event
//! loop's decisions durable so [`Delegation::recover`] can resume a crashed
//! coordinator with recovery cost proportional to work *lost*, not work
//! done.
//!
//! # Format
//!
//! The journal is an append-only file of length-prefixed entries:
//!
//! ```text
//! u32 LE payload length ‖ payload        (repeated)
//! payload = u8 tag ‖ body                (canonical wire codec)
//! ```
//!
//! Entries reuse the canonical codec rules from [`crate::verde::wire`]:
//! one valid encoding per value, [`JournalEntry::wire_size`] `==`
//! `encode().len()` exactly, and total decoding — hostile or corrupt bytes
//! return a [`WireError`], never panic. Payloads are capped at
//! [`MAX_JOURNAL_ENTRY`] so a corrupt length prefix cannot force an absurd
//! allocation.
//!
//! # Fsync policy
//!
//! Appends accumulate in a process-local buffer; [`Journal::sync`] flushes
//! the buffer with one `write(2)` and `fdatasync`s the file. The event
//! loop syncs at the boundaries where durability is load-bearing — job
//! submit (the client was told "submitted"), segment settle (a verdict
//! or certified root was accepted), and job settle/cancel (a handle was
//! released) — and leaves cheap high-frequency records (lease grants,
//! audit commitments) riding on the next boundary sync. A crash therefore
//! loses at most the work since the last settled boundary, which is
//! exactly the work recovery re-queues anyway.
//!
//! # Torn tails
//!
//! A crash mid-append can leave a partial frame at the end of the file.
//! [`replay`] tolerates exactly that: an *incomplete* final frame (too few
//! bytes for its length prefix, or fewer payload bytes than the prefix
//! declares) terminates replay cleanly and is reported as
//! [`Replay::torn_bytes`]; recovery truncates it by re-appending after the
//! last whole entry. A *complete but malformed* entry is different — that
//! is corruption, not a torn write — and fails replay with the decoder's
//! [`WireError`].
//!
//! # Recovery fold
//!
//! [`recover`] folds a replayed entry sequence into [`Recovery`]: finished
//! [`JobOutcome`]s to re-serve, in-flight jobs with their settled segments
//! (trusted from the log — only unsettled segments are re-trained),
//! folded stake accounts (anything locked behind an in-flight audit at the
//! crash is released rather than leaked — the audit it backed died with
//! the process and its segment is re-queued), permanently revoked workers,
//! and the next job id. The fold is keyed (last entry per job/segment
//! wins), so replaying a journal that spans several crash generations is
//! idempotent.
//!
//! [`Delegation::recover`]: crate::service::Delegation::recover

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::hash::Hash;
use crate::train::JobSpec;
use crate::verde::protocol::JobPolicy;
use crate::verde::wire::{
    policy_wire_len, put_hash, put_policy, put_spec, put_str, put_u64, read_policy,
    read_presence, read_spec, spec_wire_len, Reader, WireError,
};

use super::coordinator::{JobOutcome, SegmentOutcome};

/// Maximum journal entry payload (16 MiB): far above any real entry (the
/// largest is a `JobSettled` with hundreds of segments) while bounding the
/// allocation a corrupt length prefix can demand.
pub const MAX_JOURNAL_ENTRY: usize = 1 << 24;

// Entry tags. One shared space; 0x00 is reserved as always-invalid so an
// all-zero torn region can never decode as an entry.
const ENT_SUBMIT: u8 = 0x01;
const ENT_LEASE: u8 = 0x02;
const ENT_REVOKE: u8 = 0x03;
const ENT_SEGMENT_SETTLED: u8 = 0x04;
const ENT_AUDIT_COMMIT: u8 = 0x05;
const ENT_AUDIT_OUTCOME: u8 = 0x06;
const ENT_STAKE_LOCK: u8 = 0x07;
const ENT_STAKE_RELEASE: u8 = 0x08;
const ENT_STAKE_SLASH: u8 = 0x09;
const ENT_JOB_SETTLED: u8 = 0x0A;

/// One durable coordinator decision.
#[derive(Debug, Clone, PartialEq)]
pub enum JournalEntry {
    /// A job was accepted: the full request (spec + policy) so recovery
    /// can rebuild the run without the client.
    Submit { job_id: u64, spec: JobSpec, policy: JobPolicy },
    /// A segment lease was granted to `workers` (informational: leases are
    /// not re-armed by recovery, their segments re-queue).
    Lease { job_id: u64, seg_idx: u64, lease_seq: u64, workers: Vec<String> },
    /// A worker's lease was permanently revoked (expelled from the pool).
    Revoke { worker: String },
    /// A segment settled: the verdict, certified root, and full accounting
    /// are trusted from the log on recovery — the segment is never
    /// re-trained.
    SegmentSettled { job_id: u64, outcome: SegmentOutcome },
    /// An optimistic worker committed a segment state root.
    AuditCommit { job_id: u64, seg_idx: u64, worker: String, root: Hash },
    /// A sampled replay audit concluded (`passed` false = escalated).
    AuditOutcome { job_id: u64, seg_idx: u64, passed: bool },
    /// `amount` of `worker`'s stake was locked behind an in-flight audit.
    StakeLock { worker: String, amount: u64 },
    /// `worker`'s locked stake returned to available.
    StakeRelease { worker: String },
    /// `amount` of `worker`'s locked stake was confiscated by a
    /// conviction.
    StakeSlash { worker: String, amount: u64 },
    /// A job reached a terminal outcome (settled or cancelled); its handle
    /// can be re-served from this record alone.
    JobSettled { outcome: JobOutcome },
}

// ---------------------------------------------------------------------------
// outcome codecs
// ---------------------------------------------------------------------------

fn dur_nanos(d: Duration) -> u64 {
    u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
}

fn put_opt_hash(out: &mut Vec<u8>, h: &Option<Hash>) {
    match h {
        Some(h) => {
            out.push(1);
            put_hash(out, h);
        }
        None => out.push(0),
    }
}

fn read_opt_hash(r: &mut Reader<'_>, context: &'static str) -> Result<Option<Hash>, WireError> {
    Ok(if read_presence(r, context)? { Some(r.hash(context)?) } else { None })
}

fn put_opt_str(out: &mut Vec<u8>, s: &Option<String>) {
    match s {
        Some(s) => {
            out.push(1);
            put_str(out, s);
        }
        None => out.push(0),
    }
}

fn read_opt_str(r: &mut Reader<'_>, context: &'static str) -> Result<Option<String>, WireError> {
    Ok(if read_presence(r, context)? { Some(r.str(context)?) } else { None })
}

fn put_opt_u64(out: &mut Vec<u8>, v: &Option<u64>) {
    match v {
        Some(v) => {
            out.push(1);
            put_u64(out, *v);
        }
        None => out.push(0),
    }
}

fn read_opt_u64(r: &mut Reader<'_>, context: &'static str) -> Result<Option<u64>, WireError> {
    Ok(if read_presence(r, context)? { Some(r.u64(context)?) } else { None })
}

fn put_strs(out: &mut Vec<u8>, ss: &[String]) {
    put_u64(out, ss.len() as u64);
    for s in ss {
        put_str(out, s);
    }
}

fn read_strs(r: &mut Reader<'_>, context: &'static str) -> Result<Vec<String>, WireError> {
    let n = r.usize(context)?;
    // Every string costs at least its 8-byte length prefix.
    if n > r.remaining() / 8 {
        return Err(WireError::Truncated {
            context,
            need: n.saturating_mul(8),
            have: r.remaining(),
        });
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(r.str(context)?);
    }
    Ok(out)
}

fn read_u32_field(r: &mut Reader<'_>, context: &'static str) -> Result<u32, WireError> {
    u32::try_from(r.u64(context)?).map_err(|_| WireError::Malformed { context })
}

fn opt_hash_len(h: &Option<Hash>) -> usize {
    1 + if h.is_some() { 32 } else { 0 }
}

fn opt_str_len(s: &Option<String>) -> usize {
    1 + s.as_ref().map_or(0, |s| 8 + s.len())
}

fn opt_u64_len(v: &Option<u64>) -> usize {
    1 + if v.is_some() { 8 } else { 0 }
}

fn strs_len(ss: &[String]) -> usize {
    8 + ss.iter().map(|s| 8 + s.len()).sum::<usize>()
}

fn put_segment_outcome(out: &mut Vec<u8>, o: &SegmentOutcome) {
    put_u64(out, o.seg as u64);
    put_u64(out, o.start);
    put_u64(out, o.end);
    put_opt_hash(out, &o.accepted);
    put_opt_str(out, &o.winner);
    put_strs(out, &o.workers);
    put_u64(out, o.disputes as u64);
    put_u64(out, o.eliminated as u64);
    put_u64(out, u64::from(o.requeues));
    put_u64(out, o.revoked as u64);
    put_u64(out, dur_nanos(o.wall));
    put_u64(out, o.bytes);
    put_u64(out, o.requests);
    put_u64(out, o.leased_seq);
    put_opt_u64(out, &o.seeded_from);
    put_u64(out, o.steps_trained);
    put_u64(out, o.transfer_bytes);
    put_u64(out, u64::from(o.uploads_rejected));
    out.push(u8::from(o.audit_sampled));
    out.push(u8::from(o.audit_passed));
    out.push(u8::from(o.audit_escalated));
    put_u64(out, o.audit_steps);
    put_u64(out, o.slashed);
}

fn read_segment_outcome(r: &mut Reader<'_>) -> Result<SegmentOutcome, WireError> {
    const C: &str = "journal segment outcome";
    Ok(SegmentOutcome {
        seg: r.usize(C)?,
        start: r.u64(C)?,
        end: r.u64(C)?,
        accepted: read_opt_hash(r, C)?,
        winner: read_opt_str(r, C)?,
        workers: read_strs(r, C)?,
        disputes: r.usize(C)?,
        eliminated: r.usize(C)?,
        requeues: read_u32_field(r, C)?,
        revoked: r.usize(C)?,
        wall: Duration::from_nanos(r.u64(C)?),
        bytes: r.u64(C)?,
        requests: r.u64(C)?,
        leased_seq: r.u64(C)?,
        seeded_from: read_opt_u64(r, C)?,
        steps_trained: r.u64(C)?,
        transfer_bytes: r.u64(C)?,
        uploads_rejected: read_u32_field(r, C)?,
        audit_sampled: read_presence(r, C)?,
        audit_passed: read_presence(r, C)?,
        audit_escalated: read_presence(r, C)?,
        audit_steps: r.u64(C)?,
        slashed: r.u64(C)?,
    })
}

fn segment_outcome_len(o: &SegmentOutcome) -> usize {
    8 * 3
        + opt_hash_len(&o.accepted)
        + opt_str_len(&o.winner)
        + strs_len(&o.workers)
        + 8 * 4
        + 8 * 4
        + opt_u64_len(&o.seeded_from)
        + 8 * 3
        + 3
        + 8 * 2
}

/// Smallest possible encoded [`SegmentOutcome`] — guards the segment-count
/// prefix of a [`JobOutcome`] against hostile allocation requests.
const MIN_SEGMENT_OUTCOME: usize = 8 * 3 + 1 + 1 + 8 + 8 * 4 + 8 * 4 + 1 + 8 * 3 + 3 + 8 * 2;

fn put_job_outcome(out: &mut Vec<u8>, o: &JobOutcome) {
    put_u64(out, o.job_id);
    put_opt_hash(out, &o.accepted);
    put_opt_str(out, &o.winner);
    out.push(u8::from(o.cancelled));
    put_u64(out, o.disputes as u64);
    put_u64(out, o.eliminated as u64);
    put_u64(out, u64::from(o.requeues));
    put_u64(out, o.revoked as u64);
    put_u64(out, dur_nanos(o.wall));
    put_u64(out, o.bytes);
    put_u64(out, o.requests);
    put_u64(out, o.segments.len() as u64);
    for s in &o.segments {
        put_segment_outcome(out, s);
    }
}

fn read_job_outcome(r: &mut Reader<'_>) -> Result<JobOutcome, WireError> {
    const C: &str = "journal job outcome";
    let job_id = r.u64(C)?;
    let accepted = read_opt_hash(r, C)?;
    let winner = read_opt_str(r, C)?;
    let cancelled = read_presence(r, C)?;
    let disputes = r.usize(C)?;
    let eliminated = r.usize(C)?;
    let requeues = read_u32_field(r, C)?;
    let revoked = r.usize(C)?;
    let wall = Duration::from_nanos(r.u64(C)?);
    let bytes = r.u64(C)?;
    let requests = r.u64(C)?;
    let n = r.usize(C)?;
    if n > r.remaining() / MIN_SEGMENT_OUTCOME {
        return Err(WireError::Truncated {
            context: C,
            need: n.saturating_mul(MIN_SEGMENT_OUTCOME),
            have: r.remaining(),
        });
    }
    let mut segments = Vec::with_capacity(n);
    for _ in 0..n {
        segments.push(read_segment_outcome(r)?);
    }
    Ok(JobOutcome {
        job_id,
        accepted,
        winner,
        cancelled,
        disputes,
        eliminated,
        requeues,
        revoked,
        wall,
        bytes,
        requests,
        segments,
    })
}

fn job_outcome_len(o: &JobOutcome) -> usize {
    8 + opt_hash_len(&o.accepted)
        + opt_str_len(&o.winner)
        + 1
        + 8 * 7
        + 8
        + o.segments.iter().map(segment_outcome_len).sum::<usize>()
}

// ---------------------------------------------------------------------------
// entry codec
// ---------------------------------------------------------------------------

impl JournalEntry {
    /// Exact encoded payload size; defined to equal `encode().len()`
    /// (pinned by the property suite in `rust/tests/wire_props.rs`).
    pub fn wire_size(&self) -> usize {
        1 + match self {
            JournalEntry::Submit { spec, policy, .. } => {
                8 + spec_wire_len(spec) + policy_wire_len(policy)
            }
            JournalEntry::Lease { workers, .. } => 8 * 3 + strs_len(workers),
            JournalEntry::Revoke { worker } => 8 + worker.len(),
            JournalEntry::SegmentSettled { outcome, .. } => 8 + segment_outcome_len(outcome),
            JournalEntry::AuditCommit { worker, .. } => 8 * 2 + 8 + worker.len() + 32,
            JournalEntry::AuditOutcome { .. } => 8 * 2 + 1,
            JournalEntry::StakeLock { worker, .. } | JournalEntry::StakeSlash { worker, .. } => {
                8 + worker.len() + 8
            }
            JournalEntry::StakeRelease { worker } => 8 + worker.len(),
            JournalEntry::JobSettled { outcome } => job_outcome_len(outcome),
        }
    }

    /// Canonical payload bytes (tag + body, no length prefix).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_size());
        match self {
            JournalEntry::Submit { job_id, spec, policy } => {
                out.push(ENT_SUBMIT);
                put_u64(&mut out, *job_id);
                put_spec(&mut out, spec);
                put_policy(&mut out, policy);
            }
            JournalEntry::Lease { job_id, seg_idx, lease_seq, workers } => {
                out.push(ENT_LEASE);
                put_u64(&mut out, *job_id);
                put_u64(&mut out, *seg_idx);
                put_u64(&mut out, *lease_seq);
                put_strs(&mut out, workers);
            }
            JournalEntry::Revoke { worker } => {
                out.push(ENT_REVOKE);
                put_str(&mut out, worker);
            }
            JournalEntry::SegmentSettled { job_id, outcome } => {
                out.push(ENT_SEGMENT_SETTLED);
                put_u64(&mut out, *job_id);
                put_segment_outcome(&mut out, outcome);
            }
            JournalEntry::AuditCommit { job_id, seg_idx, worker, root } => {
                out.push(ENT_AUDIT_COMMIT);
                put_u64(&mut out, *job_id);
                put_u64(&mut out, *seg_idx);
                put_str(&mut out, worker);
                put_hash(&mut out, root);
            }
            JournalEntry::AuditOutcome { job_id, seg_idx, passed } => {
                out.push(ENT_AUDIT_OUTCOME);
                put_u64(&mut out, *job_id);
                put_u64(&mut out, *seg_idx);
                out.push(u8::from(*passed));
            }
            JournalEntry::StakeLock { worker, amount } => {
                out.push(ENT_STAKE_LOCK);
                put_str(&mut out, worker);
                put_u64(&mut out, *amount);
            }
            JournalEntry::StakeRelease { worker } => {
                out.push(ENT_STAKE_RELEASE);
                put_str(&mut out, worker);
            }
            JournalEntry::StakeSlash { worker, amount } => {
                out.push(ENT_STAKE_SLASH);
                put_str(&mut out, worker);
                put_u64(&mut out, *amount);
            }
            JournalEntry::JobSettled { outcome } => {
                out.push(ENT_JOB_SETTLED);
                put_job_outcome(&mut out, outcome);
            }
        }
        debug_assert_eq!(out.len(), self.wire_size(), "wire_size drifted from encode");
        out
    }

    /// Total decode of one payload. Rejects trailing bytes — the length
    /// prefix must frame exactly one entry.
    pub fn decode(buf: &[u8]) -> Result<JournalEntry, WireError> {
        let mut r = Reader::new(buf);
        let tag = r.u8("journal entry tag")?;
        let entry = match tag {
            ENT_SUBMIT => JournalEntry::Submit {
                job_id: r.u64("journal submit")?,
                spec: read_spec(&mut r)?,
                policy: read_policy(&mut r)?,
            },
            ENT_LEASE => JournalEntry::Lease {
                job_id: r.u64("journal lease")?,
                seg_idx: r.u64("journal lease")?,
                lease_seq: r.u64("journal lease")?,
                workers: read_strs(&mut r, "journal lease workers")?,
            },
            ENT_REVOKE => JournalEntry::Revoke { worker: r.str("journal revoke")? },
            ENT_SEGMENT_SETTLED => JournalEntry::SegmentSettled {
                job_id: r.u64("journal segment settled")?,
                outcome: read_segment_outcome(&mut r)?,
            },
            ENT_AUDIT_COMMIT => JournalEntry::AuditCommit {
                job_id: r.u64("journal audit commit")?,
                seg_idx: r.u64("journal audit commit")?,
                worker: r.str("journal audit commit")?,
                root: r.hash("journal audit commit")?,
            },
            ENT_AUDIT_OUTCOME => JournalEntry::AuditOutcome {
                job_id: r.u64("journal audit outcome")?,
                seg_idx: r.u64("journal audit outcome")?,
                passed: read_presence(&mut r, "journal audit outcome")?,
            },
            ENT_STAKE_LOCK => JournalEntry::StakeLock {
                worker: r.str("journal stake lock")?,
                amount: r.u64("journal stake lock")?,
            },
            ENT_STAKE_RELEASE => {
                JournalEntry::StakeRelease { worker: r.str("journal stake release")? }
            }
            ENT_STAKE_SLASH => JournalEntry::StakeSlash {
                worker: r.str("journal stake slash")?,
                amount: r.u64("journal stake slash")?,
            },
            ENT_JOB_SETTLED => JournalEntry::JobSettled { outcome: read_job_outcome(&mut r)? },
            t => return Err(WireError::BadTag { context: "journal entry", tag: t }),
        };
        r.finish()?;
        Ok(entry)
    }

    /// Append this entry's frame (`u32 LE` payload length ‖ payload) to
    /// `out`.
    fn frame_into(&self, out: &mut Vec<u8>) {
        let payload = self.encode();
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
    }
}

// ---------------------------------------------------------------------------
// replay
// ---------------------------------------------------------------------------

/// Result of scanning a journal file's bytes.
#[derive(Debug)]
pub struct Replay {
    /// Every whole entry, in append order.
    pub entries: Vec<JournalEntry>,
    /// Bytes of incomplete final frame discarded as a torn write (0 for a
    /// cleanly closed journal).
    pub torn_bytes: usize,
}

/// Scan raw journal bytes into entries. An incomplete final frame is a
/// tolerated torn tail; a complete frame that fails to decode is
/// corruption and fails the whole replay.
pub fn replay(buf: &[u8]) -> Result<Replay, WireError> {
    let mut entries = Vec::new();
    let mut pos = 0usize;
    while pos < buf.len() {
        let rem = buf.len() - pos;
        if rem < 4 {
            return Ok(Replay { entries, torn_bytes: rem });
        }
        let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().expect("4 bytes")) as usize;
        if len > MAX_JOURNAL_ENTRY {
            return Err(WireError::FrameTooLarge { len });
        }
        if rem - 4 < len {
            return Ok(Replay { entries, torn_bytes: rem });
        }
        entries.push(JournalEntry::decode(&buf[pos + 4..pos + 4 + len])?);
        pos += 4 + len;
    }
    Ok(Replay { entries, torn_bytes: 0 })
}

// ---------------------------------------------------------------------------
// recovery fold
// ---------------------------------------------------------------------------

/// An unsettled job reconstructed from the journal: re-submit it with its
/// settled segments pre-filled so only the remainder re-trains.
#[derive(Debug, Clone)]
pub struct RecoveredJob {
    pub job_id: u64,
    pub spec: JobSpec,
    pub policy: JobPolicy,
    /// Settled segment verdicts trusted from the log, in segment order.
    pub settled: Vec<SegmentOutcome>,
}

/// One worker's folded stake history.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RecoveredStake {
    pub worker: String,
    /// Total ever confiscated by convictions.
    pub slashed: u64,
    /// Amount locked behind an audit that was still in flight at the
    /// crash. Recovery releases it (the segment re-queues) — surfaced so
    /// the release itself can be journaled.
    pub locked_at_crash: u64,
}

/// Everything [`Delegation::recover`] needs, folded from a replay.
///
/// [`Delegation::recover`]: crate::service::Delegation::recover
#[derive(Debug)]
pub struct Recovery {
    /// Terminal outcomes in job-id order — re-served as finished handles.
    pub finished: Vec<JobOutcome>,
    /// Unsettled jobs in job-id order — re-queued for the remainder.
    pub jobs: Vec<RecoveredJob>,
    /// Folded stake accounts in worker order.
    pub stakes: Vec<RecoveredStake>,
    /// Workers permanently revoked before the crash (never re-lease).
    pub revoked: Vec<String>,
    /// First unused job id (`max journaled id + 1`, or 0 for an empty
    /// journal) — seeds the client's id counter.
    pub next_job_id: u64,
    /// Whole entries replayed.
    pub entries: u64,
    /// Torn-tail bytes discarded.
    pub torn_bytes: usize,
}

/// Fold a replay into recovery state. Keyed per job / segment / worker, so
/// duplicate or superseded entries (journals spanning several crash
/// generations) resolve to the last write.
pub fn recover(replay: Replay) -> Recovery {
    struct OpenJob {
        spec: JobSpec,
        policy: JobPolicy,
        settled: BTreeMap<usize, SegmentOutcome>,
    }
    let mut open: BTreeMap<u64, OpenJob> = BTreeMap::new();
    let mut finished: BTreeMap<u64, JobOutcome> = BTreeMap::new();
    let mut stakes: BTreeMap<String, (u64, u64)> = BTreeMap::new(); // slashed, locked
    let mut revoked: Vec<String> = Vec::new();
    let mut next_job_id = 0u64;

    let entries = replay.entries.len() as u64;
    for e in replay.entries {
        match e {
            JournalEntry::Submit { job_id, spec, policy } => {
                next_job_id = next_job_id.max(job_id.saturating_add(1));
                open.insert(job_id, OpenJob { spec, policy, settled: BTreeMap::new() });
            }
            JournalEntry::SegmentSettled { job_id, outcome } => {
                if let Some(j) = open.get_mut(&job_id) {
                    j.settled.insert(outcome.seg, outcome);
                }
            }
            JournalEntry::JobSettled { outcome } => {
                next_job_id = next_job_id.max(outcome.job_id.saturating_add(1));
                open.remove(&outcome.job_id);
                finished.insert(outcome.job_id, outcome);
            }
            JournalEntry::StakeLock { worker, amount } => {
                stakes.entry(worker).or_insert((0, 0)).1 = amount;
            }
            JournalEntry::StakeRelease { worker } => {
                stakes.entry(worker).or_insert((0, 0)).1 = 0;
            }
            JournalEntry::StakeSlash { worker, amount } => {
                let s = stakes.entry(worker).or_insert((0, 0));
                s.0 = s.0.saturating_add(amount);
                s.1 = 0;
            }
            JournalEntry::Revoke { worker } => {
                if !revoked.contains(&worker) {
                    revoked.push(worker);
                }
            }
            // Leases and audit records are audit-trail only: a lease or
            // in-flight audit from a dead process cannot be re-armed (the
            // worker connection is gone), so its segment re-queues.
            JournalEntry::Lease { .. }
            | JournalEntry::AuditCommit { .. }
            | JournalEntry::AuditOutcome { .. } => {}
        }
    }

    Recovery {
        finished: finished.into_values().collect(),
        jobs: open
            .into_iter()
            .map(|(job_id, j)| RecoveredJob {
                job_id,
                spec: j.spec,
                policy: j.policy,
                settled: j.settled.into_values().collect(),
            })
            .collect(),
        stakes: stakes
            .into_iter()
            .map(|(worker, (slashed, locked))| RecoveredStake {
                worker,
                slashed,
                locked_at_crash: locked,
            })
            .collect(),
        revoked,
        next_job_id,
        entries,
        torn_bytes: replay.torn_bytes,
    }
}

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

/// Buffered append-only journal writer.
///
/// Appends land in a process-local buffer; [`Journal::sync`] writes the
/// buffer and `fdatasync`s. A journal that cannot write panics rather than
/// acknowledging work it cannot remember — a silent WAL is worse than
/// none.
pub struct Journal {
    file: File,
    path: PathBuf,
    buf: Vec<u8>,
    entries: u64,
    bytes: u64,
    syncs: u64,
}

impl Journal {
    /// Start a fresh journal at `path`, truncating any existing file.
    pub fn create(path: &Path) -> std::io::Result<Journal> {
        let file = OpenOptions::new().create(true).write(true).truncate(true).open(path)?;
        Ok(Journal {
            file,
            path: path.to_path_buf(),
            buf: Vec::new(),
            entries: 0,
            bytes: 0,
            syncs: 0,
        })
    }

    /// Re-open an existing journal for appending after `recovered_bytes`
    /// of whole entries (a torn tail past that point is truncated away —
    /// replay already discarded it).
    pub fn resume(path: &Path, recovered_bytes: u64) -> std::io::Result<Journal> {
        let file = OpenOptions::new().create(true).write(true).open(path)?;
        file.set_len(recovered_bytes)?;
        let mut j = Journal {
            file,
            path: path.to_path_buf(),
            buf: Vec::new(),
            entries: 0,
            bytes: recovered_bytes,
            syncs: 0,
        };
        use std::io::Seek;
        j.file.seek(std::io::SeekFrom::End(0)).map(|_| j)
    }

    /// Buffer one entry. Durable only after the next [`Journal::sync`].
    pub fn append(&mut self, entry: &JournalEntry) {
        let before = self.buf.len();
        entry.frame_into(&mut self.buf);
        self.entries += 1;
        self.bytes += (self.buf.len() - before) as u64;
    }

    /// Flush buffered entries and `fdatasync` the file. Returns whether
    /// anything was flushed (false = nothing buffered since the last
    /// sync).
    pub fn sync(&mut self) -> bool {
        if self.buf.is_empty() {
            return false;
        }
        self.file
            .write_all(&self.buf)
            .and_then(|()| self.file.sync_data())
            .unwrap_or_else(|e| panic!("journal {}: write failed: {e}", self.path.display()));
        self.buf.clear();
        self.syncs += 1;
        true
    }

    /// Entries appended this process lifetime.
    pub fn entries(&self) -> u64 {
        self.entries
    }

    /// Total file bytes after the buffered tail flushes.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Completed sync barriers.
    pub fn syncs(&self) -> u64 {
        self.syncs
    }
}

impl Drop for Journal {
    fn drop(&mut self) {
        // Best-effort final flush; a panic mid-drop would abort.
        if !self.buf.is_empty() {
            let _ = self.file.write_all(&self.buf).and_then(|()| self.file.sync_data());
            self.buf.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Preset;
    use crate::verde::protocol::BackendRequirement;

    fn spec() -> JobSpec {
        JobSpec::quick(Preset::LlamaTiny, 8)
    }

    fn policy() -> JobPolicy {
        JobPolicy {
            k: 2,
            deadline: None,
            priority: 1,
            backend: BackendRequirement::Any,
            segments: 4,
            max_requeues: Some(2),
            transfer: true,
            audit_rate: 0.25,
        }
    }

    fn seg_outcome() -> SegmentOutcome {
        SegmentOutcome {
            seg: 1,
            start: 4,
            end: 8,
            accepted: Some(Hash::of_bytes(b"root")),
            winner: Some("w1".to_string()),
            workers: vec!["w1".to_string(), "w2".to_string()],
            disputes: 1,
            eliminated: 1,
            requeues: 2,
            revoked: 1,
            wall: Duration::from_micros(1234),
            bytes: 4096,
            requests: 17,
            leased_seq: 42,
            seeded_from: Some(4),
            steps_trained: 4,
            transfer_bytes: 512,
            uploads_rejected: 1,
            audit_sampled: true,
            audit_passed: false,
            audit_escalated: true,
            audit_steps: 4,
            slashed: 1000,
        }
    }

    fn entries() -> Vec<JournalEntry> {
        vec![
            JournalEntry::Submit { job_id: 7, spec: spec(), policy: policy() },
            JournalEntry::Lease {
                job_id: 7,
                seg_idx: 0,
                lease_seq: 3,
                workers: vec!["a".to_string(), "b".to_string()],
            },
            JournalEntry::Revoke { worker: "b".to_string() },
            JournalEntry::SegmentSettled { job_id: 7, outcome: seg_outcome() },
            JournalEntry::AuditCommit {
                job_id: 7,
                seg_idx: 1,
                worker: "a".to_string(),
                root: Hash::of_bytes(b"commit"),
            },
            JournalEntry::AuditOutcome { job_id: 7, seg_idx: 1, passed: true },
            JournalEntry::StakeLock { worker: "a".to_string(), amount: 900 },
            JournalEntry::StakeRelease { worker: "a".to_string() },
            JournalEntry::StakeSlash { worker: "a".to_string(), amount: 900 },
            JournalEntry::JobSettled {
                outcome: JobOutcome {
                    job_id: 7,
                    accepted: Some(Hash::of_bytes(b"final")),
                    winner: Some("a".to_string()),
                    cancelled: false,
                    disputes: 1,
                    eliminated: 1,
                    requeues: 2,
                    revoked: 1,
                    wall: Duration::from_millis(9),
                    bytes: 1 << 16,
                    requests: 120,
                    segments: vec![seg_outcome()],
                },
            },
        ]
    }

    #[test]
    fn wire_size_matches_encode_for_every_kind() {
        for e in entries() {
            assert_eq!(e.wire_size(), e.encode().len(), "{e:?}");
        }
    }

    #[test]
    fn round_trip_every_kind() {
        for e in entries() {
            let b = e.encode();
            let d = JournalEntry::decode(&b).expect("decode");
            assert_eq!(d, e);
            assert_eq!(d.encode(), b, "re-encode is canonical");
        }
    }

    #[test]
    fn truncated_payload_is_rejected_at_every_length() {
        for e in entries() {
            let b = e.encode();
            for cut in 0..b.len() {
                assert!(
                    JournalEntry::decode(&b[..cut]).is_err(),
                    "{e:?} decoded from {cut}/{} bytes",
                    b.len()
                );
            }
        }
    }

    #[test]
    fn replay_tolerates_torn_tail_but_not_corruption() {
        let mut buf = Vec::new();
        for e in entries() {
            e.frame_into(&mut buf);
        }
        let whole = replay(&buf).expect("clean replay");
        assert_eq!(whole.entries.len(), entries().len());
        assert_eq!(whole.torn_bytes, 0);

        // Any truncation inside the final frame is a torn tail: replay
        // returns every earlier entry and reports the discarded bytes.
        let last_frame = 4 + entries().last().unwrap().wire_size();
        for cut in (buf.len() - last_frame + 1)..buf.len() {
            let r = replay(&buf[..cut]).expect("torn tail tolerated");
            assert_eq!(r.entries.len(), entries().len() - 1, "cut {cut}");
            assert_eq!(r.torn_bytes, cut - (buf.len() - last_frame), "cut {cut}");
        }

        // Flipping the tag of a *whole* interior entry is corruption.
        let mut corrupt = buf.clone();
        corrupt[4] = 0xEE;
        assert!(replay(&corrupt).is_err());

        // An absurd length prefix is corruption, not a torn tail.
        let mut absurd = buf.clone();
        absurd[0..4].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(matches!(replay(&absurd), Err(WireError::FrameTooLarge { .. })));
    }

    #[test]
    fn recovery_fold_partitions_jobs_and_balances_stakes() {
        let mut es = entries();
        // A second job that never settles: submit + one settled segment.
        es.push(JournalEntry::Submit { job_id: 9, spec: spec(), policy: policy() });
        es.push(JournalEntry::SegmentSettled { job_id: 9, outcome: seg_outcome() });
        // A lock still outstanding at the crash.
        es.push(JournalEntry::StakeLock { worker: "c".to_string(), amount: 1000 });

        let rec = recover(Replay { entries: es, torn_bytes: 3 });
        assert_eq!(rec.finished.len(), 1);
        assert_eq!(rec.finished[0].job_id, 7);
        assert_eq!(rec.jobs.len(), 1);
        assert_eq!(rec.jobs[0].job_id, 9);
        assert_eq!(rec.jobs[0].settled.len(), 1);
        assert_eq!(rec.jobs[0].settled[0].seg, 1);
        assert_eq!(rec.next_job_id, 10);
        assert_eq!(rec.revoked, vec!["b".to_string()]);
        assert_eq!(rec.torn_bytes, 3);

        let a = rec.stakes.iter().find(|s| s.worker == "a").expect("a folded");
        assert_eq!(a.slashed, 900);
        assert_eq!(a.locked_at_crash, 0, "slash clears the lock");
        let c = rec.stakes.iter().find(|s| s.worker == "c").expect("c folded");
        assert_eq!(c.locked_at_crash, 1000, "outstanding lock surfaced for release");
    }

    #[test]
    fn journal_file_round_trip_with_resume() {
        let dir = std::env::temp_dir().join(format!("verde-journal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("unit.wal");

        let es = entries();
        let mut j = Journal::create(&path).unwrap();
        j.append(&es[0]);
        j.append(&es[1]);
        j.sync();
        assert_eq!(j.entries(), 2);
        assert_eq!(j.syncs(), 1);
        drop(j);

        let replayed = replay(&std::fs::read(&path).unwrap()).unwrap();
        assert_eq!(replayed.entries.len(), 2);
        let whole_bytes = std::fs::metadata(&path).unwrap().len();

        // Simulate a torn tail, then resume: the tail is truncated away and
        // new appends continue from the last whole entry.
        {
            use std::io::Write as _;
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(&[0x55, 0xAA, 0x01]).unwrap();
        }
        let mut j2 = Journal::resume(&path, whole_bytes).unwrap();
        j2.append(&es[2]);
        j2.sync();
        drop(j2);

        let replayed = replay(&std::fs::read(&path).unwrap()).unwrap();
        assert_eq!(replayed.entries.len(), 3);
        assert_eq!(replayed.torn_bytes, 0);
        assert_eq!(replayed.entries[2], es[2]);

        std::fs::remove_file(&path).ok();
    }
}
