//! A blocking pool of worker endpoints. Scheduler lanes acquire `k`
//! workers **atomically** (all-or-nothing under one lock), which keeps the
//! acquire path deadlock-free: a lane either gets its full complement or
//! sleeps without holding anything.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use crate::net::Endpoint;

/// A worker endpoint owned by the pool, addressable by name in reports.
pub struct PooledWorker {
    pub name: String,
    pub endpoint: Box<dyn Endpoint + Send>,
}

impl PooledWorker {
    pub fn new(name: &str, endpoint: impl Endpoint + Send + 'static) -> PooledWorker {
        PooledWorker { name: name.to_string(), endpoint: Box::new(endpoint) }
    }
}

/// Free-list of idle workers plus a condvar for lanes waiting on capacity.
pub struct WorkerPool {
    size: usize,
    free: Mutex<VecDeque<PooledWorker>>,
    available: Condvar,
}

impl WorkerPool {
    /// # Panics
    /// On an empty worker set.
    pub fn new(workers: Vec<PooledWorker>) -> WorkerPool {
        assert!(!workers.is_empty(), "a pool needs at least one worker");
        WorkerPool {
            size: workers.len(),
            free: Mutex::new(workers.into()),
            available: Condvar::new(),
        }
    }

    /// Total workers owned by the pool (idle + leased).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Idle workers right now (diagnostic; racy by nature).
    pub fn idle(&self) -> usize {
        self.free.lock().unwrap().len()
    }

    /// Block until `k` workers are free, then take them all at once.
    ///
    /// # Panics
    /// If `k` exceeds the pool size (would deadlock) or `k == 0`.
    pub fn acquire(&self, k: usize) -> Vec<PooledWorker> {
        assert!(k >= 1, "acquire(0) is meaningless");
        assert!(k <= self.size, "acquire({k}) from a pool of {}", self.size);
        let mut free = self.free.lock().unwrap();
        while free.len() < k {
            free = self.available.wait(free).unwrap();
        }
        free.drain(..k).collect()
    }

    /// Return leased workers and wake waiting lanes.
    pub fn release(&self, workers: Vec<PooledWorker>) {
        let mut free = self.free.lock().unwrap();
        free.extend(workers);
        drop(free);
        self.available.notify_all();
    }

    /// Tear the pool down, handing every idle worker back (used for
    /// orderly shutdown: callers typically send `Request::Shutdown` to
    /// each endpoint). Leased workers must be released first.
    pub fn into_workers(self) -> Vec<PooledWorker> {
        self.free.into_inner().unwrap().into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::verde::protocol::{Request, Response};

    struct Nop;

    impl Endpoint for Nop {
        fn name(&self) -> &str {
            "nop"
        }
        fn call(&mut self, _req: Request) -> Response {
            Response::Bye
        }
    }

    #[test]
    fn acquire_release_roundtrip() {
        let pool = WorkerPool::new((0..4).map(|i| PooledWorker::new(&format!("w{i}"), Nop)).collect());
        assert_eq!(pool.size(), 4);
        let lease = pool.acquire(3);
        assert_eq!(lease.len(), 3);
        assert_eq!(pool.idle(), 1);
        pool.release(lease);
        assert_eq!(pool.idle(), 4);
        assert_eq!(pool.into_workers().len(), 4);
    }

    #[test]
    fn blocked_acquire_wakes_on_release() {
        use std::sync::Arc;
        let pool = Arc::new(WorkerPool::new(
            (0..2).map(|i| PooledWorker::new(&format!("w{i}"), Nop)).collect(),
        ));
        let lease = pool.acquire(2);
        let p2 = Arc::clone(&pool);
        let waiter = std::thread::spawn(move || p2.acquire(2).len());
        std::thread::sleep(std::time::Duration::from_millis(20));
        pool.release(lease);
        assert_eq!(waiter.join().unwrap(), 2);
    }

    #[test]
    #[should_panic(expected = "acquire(3) from a pool of 2")]
    fn oversubscription_panics_rather_than_deadlocks() {
        let pool = WorkerPool::new((0..2).map(|i| PooledWorker::new(&format!("w{i}"), Nop)).collect());
        pool.acquire(3);
    }
}
