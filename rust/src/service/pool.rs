//! The worker pool: a free-list of leasable worker endpoints with
//! **lease revocation**. Scheduler state machines acquire `k` workers
//! **atomically** (all-or-nothing under one lock) which keeps the acquire
//! path deadlock-free, and hand back each worker either by releasing it
//! (healthy) or revoking it (missed a dispatch deadline or health-check
//! ping). A revoked worker leaves the pool permanently: it never re-enters
//! the free list and [`WorkerPool::size`] shrinks.
//!
//! Workers are held as [`PooledWorker`]s, which unify three transports
//! behind one dispatch surface:
//!
//! * **Blocking** — any [`Endpoint`] (in-process [`WorkerHost`]
//!   (crate::service::worker::WorkerHost), threaded remote, blocking TCP).
//! * **Actor** — the same endpoint activated onto its own mailbox thread so
//!   the event-driven coordinator can dispatch without blocking; the
//!   endpoint is recovered when the actor is deactivated.
//! * **Mux** — a [`MuxConn`] on the non-blocking multiplexer: no
//!   coordinator-side thread at all, deadlines enforced by the mux driver.
//!
//! All three offer the non-blocking [`PooledWorker::dispatch`] (completions
//! arrive on a channel) and the blocking [`Endpoint`] adapter used by
//! dispute tournaments.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::net::mux::{Completion, CompletionKind, MuxConn};
use crate::net::Endpoint;
use crate::verde::protocol::{Request, Response};

/// Message into a worker actor's mailbox.
enum ActorMsg {
    Dispatch { token: u64, req: Request, reply: Sender<Completion> },
    Stop,
}

/// A blocking endpoint running on its own mailbox thread, so dispatches
/// return immediately and the caller collects the answer as a
/// [`Completion`]. Deadlines for actor-backed workers are enforced by the
/// coordinator's timer (the actor itself cannot be interrupted — a stalled
/// endpoint strands its thread, which is exactly the failure the service
/// layer revokes leases over).
struct ActorHandle {
    tx: Sender<ActorMsg>,
    join: JoinHandle<Box<dyn Endpoint + Send>>,
    reply_tx: Sender<Completion>,
    reply_rx: Receiver<Completion>,
    next_call_tag: u64,
}

fn spawn_actor(name: &str, mut endpoint: Box<dyn Endpoint + Send>) -> ActorHandle {
    let (tx, rx) = channel::<ActorMsg>();
    let join = std::thread::Builder::new()
        .name(format!("verde-actor-{name}"))
        .spawn(move || {
            while let Ok(msg) = rx.recv() {
                match msg {
                    ActorMsg::Dispatch { token, req, reply } => {
                        let resp = endpoint.call(req);
                        let _ = reply.send(Completion {
                            token,
                            kind: CompletionKind::Answered,
                            resp,
                        });
                    }
                    ActorMsg::Stop => break,
                }
            }
            endpoint
        })
        .expect("spawn worker actor");
    let (reply_tx, reply_rx) = channel();
    ActorHandle {
        tx,
        join,
        reply_tx,
        reply_rx,
        // Blocking calls tag from the top half of the space, mirroring the
        // mux convention: dispatch tokens stay below 2^63.
        next_call_tag: 1 << 63,
    }
}

/// The transport behind one pooled worker.
enum Link {
    Blocking(Box<dyn Endpoint + Send>),
    Actor(ActorHandle),
    Mux(MuxConn),
    /// The worker was lost (actor thread panicked / link torn down).
    Dead(String),
}

/// A worker endpoint owned by the pool, addressable by name in reports.
pub struct PooledWorker {
    pub name: String,
    link: Link,
    /// Deadline applied to blocking calls routed through an actor link.
    call_deadline: Duration,
    /// Latched when a blocking call through this worker went unanswered;
    /// the coordinator revokes the lease of a faulted worker at job end.
    faulted: bool,
}

impl PooledWorker {
    /// Wrap any blocking endpoint (in-process host, threaded remote,
    /// blocking TCP endpoint).
    pub fn new(name: &str, endpoint: impl Endpoint + Send + 'static) -> PooledWorker {
        PooledWorker {
            name: name.to_string(),
            link: Link::Blocking(Box::new(endpoint)),
            call_deadline: Duration::from_secs(60),
            faulted: false,
        }
    }

    /// Wrap a multiplexed connection — the zero-thread-per-worker shape.
    pub fn mux(name: &str, conn: MuxConn) -> PooledWorker {
        PooledWorker {
            name: name.to_string(),
            link: Link::Mux(conn),
            call_deadline: Duration::from_secs(60),
            faulted: false,
        }
    }

    /// Deadline for blocking calls (dispute/tournament traffic). Applies
    /// to actor and mux links; a plain blocking link runs unbounded, which
    /// is the pre-event-core behavior tests rely on.
    pub fn set_call_deadline(&mut self, d: Duration) {
        self.call_deadline = d;
        if let Link::Mux(_) = self.link {
            // Rebuild the mux handle's deadline in place.
            let link = std::mem::replace(&mut self.link, Link::Dead(String::new()));
            if let Link::Mux(conn) = link {
                self.link = Link::Mux(conn.with_call_deadline(d));
            }
        }
    }

    /// Move a blocking endpoint onto its own actor thread so dispatches
    /// don't block the event loop. Idempotent; no-op for mux links.
    /// Returns `true` when a thread was actually spawned, so callers can
    /// account coordinator-side threads honestly.
    pub fn activate(&mut self) -> bool {
        if matches!(self.link, Link::Blocking(_)) {
            let link = std::mem::replace(&mut self.link, Link::Dead(String::new()));
            if let Link::Blocking(endpoint) = link {
                self.link = Link::Actor(spawn_actor(&self.name, endpoint));
                return true;
            }
        }
        false
    }

    /// Stop the actor thread and recover the blocking endpoint. Only safe
    /// for responsive workers (a stalled actor never drains its mailbox);
    /// the coordinator revokes unresponsive workers instead of
    /// deactivating them.
    pub fn deactivate(&mut self) {
        if matches!(self.link, Link::Actor(_)) {
            let link = std::mem::replace(&mut self.link, Link::Dead(String::new()));
            if let Link::Actor(actor) = link {
                let _ = actor.tx.send(ActorMsg::Stop);
                match actor.join.join() {
                    Ok(endpoint) => self.link = Link::Blocking(endpoint),
                    Err(_) => self.link = Link::Dead("worker actor panicked".into()),
                }
            }
        }
    }

    /// Non-blocking dispatch: enqueue `req` under `token`; the answer (or
    /// a synthesized refusal) arrives on `reply`. For mux links the
    /// deadline is enforced by the mux driver; for actor links the
    /// coordinator's timer enforces it (the actor cannot be interrupted).
    pub fn dispatch(
        &mut self,
        token: u64,
        req: Request,
        deadline: Option<Instant>,
        reply: &Sender<Completion>,
    ) {
        let _ = self.activate();
        match &mut self.link {
            Link::Mux(conn) => conn.submit(token, &req, deadline, reply),
            Link::Actor(actor) => {
                let msg = ActorMsg::Dispatch { token, req, reply: reply.clone() };
                if actor.tx.send(msg).is_err() {
                    let _ = reply.send(Completion {
                        token,
                        kind: CompletionKind::Transport,
                        resp: Response::Refuse(format!("{}: worker actor gone", self.name)),
                    });
                }
            }
            Link::Blocking(_) => unreachable!("activate() precedes dispatch"),
            Link::Dead(why) => {
                let _ = reply.send(Completion {
                    token,
                    kind: CompletionKind::Transport,
                    resp: Response::Refuse(format!("{}: {why}", self.name)),
                });
            }
        }
    }

    /// True once any request through this worker went unanswered (blocking
    /// call deadline, mux deadline, or dead transport).
    pub fn faulted(&self) -> bool {
        if self.faulted {
            return true;
        }
        match &self.link {
            Link::Mux(conn) => conn.faulted(),
            Link::Dead(_) => true,
            _ => false,
        }
    }

    /// Clear the fault latch at the start of a fresh lease.
    pub fn reset_fault(&mut self) {
        self.faulted = false;
        if let Link::Mux(conn) = &mut self.link {
            conn.reset_fault();
        }
    }
}

impl Endpoint for PooledWorker {
    fn name(&self) -> &str {
        &self.name
    }

    /// Blocking adapter over whichever link backs this worker — disputes
    /// and tournaments run over it unchanged.
    fn call(&mut self, req: Request) -> Response {
        match &mut self.link {
            Link::Blocking(endpoint) => endpoint.call(req),
            Link::Mux(conn) => conn.call(req),
            Link::Actor(actor) => {
                let tag = actor.next_call_tag;
                actor.next_call_tag += 1;
                let msg = ActorMsg::Dispatch { token: tag, req, reply: actor.reply_tx.clone() };
                if actor.tx.send(msg).is_err() {
                    self.faulted = true;
                    return Response::Refuse(format!("{}: worker actor gone", self.name));
                }
                loop {
                    match actor.reply_rx.recv_timeout(self.call_deadline) {
                        Ok(c) if c.token == tag => return c.resp,
                        // Stale answer from an earlier abandoned call.
                        Ok(_) => continue,
                        Err(_) => {
                            self.faulted = true;
                            return Response::Refuse(format!(
                                "{}: deadline expired before the worker answered",
                                self.name
                            ));
                        }
                    }
                }
            }
            Link::Dead(why) => Response::Refuse(format!("{}: {why}", self.name)),
        }
    }
}

struct PoolState {
    free: VecDeque<PooledWorker>,
    /// Live workers (idle + leased); shrinks on revocation.
    size: usize,
    /// Names of revoked workers, in revocation order.
    revoked: Vec<String>,
}

/// Free-list of idle workers plus a condvar for callers waiting on
/// capacity, with permanent lease revocation.
pub struct WorkerPool {
    state: Mutex<PoolState>,
    available: Condvar,
}

impl WorkerPool {
    /// # Panics
    /// On an empty worker set.
    pub fn new(workers: Vec<PooledWorker>) -> WorkerPool {
        assert!(!workers.is_empty(), "a pool needs at least one worker");
        WorkerPool {
            state: Mutex::new(PoolState {
                size: workers.len(),
                free: workers.into(),
                revoked: Vec::new(),
            }),
            available: Condvar::new(),
        }
    }

    /// Live workers owned by the pool (idle + leased, revoked excluded).
    pub fn size(&self) -> usize {
        self.state.lock().unwrap().size
    }

    /// Idle workers right now (diagnostic; racy by nature).
    pub fn idle(&self) -> usize {
        self.state.lock().unwrap().free.len()
    }

    /// Names of workers whose leases were revoked, in revocation order.
    pub fn revoked(&self) -> Vec<String> {
        self.state.lock().unwrap().revoked.clone()
    }

    /// Block until `k` workers are free, then take them all at once.
    ///
    /// # Panics
    /// If `k == 0`, or if `k` exceeds the pool's live size (at entry or
    /// after revocations shrink the pool below `k` while waiting — the
    /// panic is the deadlock-free alternative to waiting forever).
    pub fn acquire(&self, k: usize) -> Vec<PooledWorker> {
        assert!(k >= 1, "acquire(0) is meaningless");
        let mut st = self.state.lock().unwrap();
        loop {
            assert!(k <= st.size, "acquire({k}) from a pool of {}", st.size);
            if st.free.len() >= k {
                return st.free.drain(..k).collect();
            }
            st = self.available.wait(st).unwrap();
        }
    }

    /// Take `k` workers if they are free right now, else `None` — the
    /// event-driven coordinator's non-blocking acquire.
    pub fn try_acquire(&self, k: usize) -> Option<Vec<PooledWorker>> {
        if k == 0 {
            return Some(Vec::new());
        }
        let mut st = self.state.lock().unwrap();
        if st.free.len() >= k {
            Some(st.free.drain(..k).collect())
        } else {
            None
        }
    }

    /// Take every currently idle worker (health-check sweeps, teardown).
    pub fn drain_idle(&self) -> Vec<PooledWorker> {
        let mut st = self.state.lock().unwrap();
        st.free.drain(..).collect()
    }

    /// Return leased workers and wake waiting acquirers.
    pub fn release(&self, workers: Vec<PooledWorker>) {
        let mut st = self.state.lock().unwrap();
        st.free.extend(workers);
        drop(st);
        self.available.notify_all();
    }

    /// Permanently expel a leased worker: it never re-enters the free list
    /// and the pool's size shrinks. Waiting acquirers are woken so an
    /// acquire that can no longer be satisfied panics instead of sleeping
    /// forever.
    pub fn revoke(&self, worker: PooledWorker) {
        let mut st = self.state.lock().unwrap();
        st.size -= 1;
        st.revoked.push(worker.name.clone());
        drop(st);
        drop(worker);
        self.available.notify_all();
    }

    /// Tear the pool down, handing every idle worker back (used for
    /// orderly shutdown: callers typically send `Request::Shutdown` to
    /// each endpoint). Leased workers must be released first.
    pub fn into_workers(self) -> Vec<PooledWorker> {
        self.state.into_inner().unwrap().free.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Nop;

    impl Endpoint for Nop {
        fn name(&self) -> &str {
            "nop"
        }
        fn call(&mut self, _req: Request) -> Response {
            Response::Bye
        }
    }

    fn pool_of(n: usize) -> WorkerPool {
        WorkerPool::new((0..n).map(|i| PooledWorker::new(&format!("w{i}"), Nop)).collect())
    }

    #[test]
    fn acquire_release_roundtrip() {
        let pool = pool_of(4);
        assert_eq!(pool.size(), 4);
        let lease = pool.acquire(3);
        assert_eq!(lease.len(), 3);
        assert_eq!(pool.idle(), 1);
        pool.release(lease);
        assert_eq!(pool.idle(), 4);
        assert_eq!(pool.into_workers().len(), 4);
    }

    #[test]
    fn blocked_acquire_wakes_on_release() {
        use std::sync::Arc;
        let pool = Arc::new(pool_of(2));
        let lease = pool.acquire(2);
        let p2 = Arc::clone(&pool);
        let waiter = std::thread::spawn(move || p2.acquire(2).len());
        std::thread::sleep(std::time::Duration::from_millis(20));
        pool.release(lease);
        assert_eq!(waiter.join().unwrap(), 2);
    }

    #[test]
    #[should_panic(expected = "acquire(3) from a pool of 2")]
    fn oversubscription_panics_rather_than_deadlocks() {
        let pool = pool_of(2);
        pool.acquire(3);
    }

    #[test]
    fn revoked_worker_never_returns_and_size_shrinks() {
        let pool = pool_of(3);
        let mut lease = pool.acquire(2);
        let victim = lease.pop().unwrap();
        let victim_name = victim.name.clone();
        pool.revoke(victim);
        assert_eq!(pool.size(), 2, "revocation shrinks the pool");
        assert_eq!(pool.revoked(), vec![victim_name.clone()]);
        pool.release(lease);
        assert_eq!(pool.idle(), 2);
        // the revoked name is not among the survivors
        let names: Vec<String> =
            pool.into_workers().into_iter().map(|w| w.name).collect();
        assert_eq!(names.len(), 2);
        assert!(!names.contains(&victim_name), "{names:?}");
    }

    #[test]
    fn try_acquire_never_blocks() {
        let pool = pool_of(2);
        let lease = pool.try_acquire(2).expect("both free");
        assert!(pool.try_acquire(1).is_none(), "everything is leased");
        pool.release(lease);
        assert!(pool.try_acquire(1).is_some());
    }

    #[test]
    fn actor_roundtrip_activate_dispatch_deactivate() {
        let mut w = PooledWorker::new("w0", Nop);
        assert!(w.activate(), "first activation spawns the actor");
        assert!(!w.activate(), "activation is idempotent");
        let (tx, rx) = channel();
        w.dispatch(7, Request::FinalCommit, None, &tx);
        let c = rx.recv_timeout(Duration::from_secs(5)).expect("completion");
        assert_eq!(c.token, 7);
        assert_eq!(c.kind, CompletionKind::Answered);
        assert!(matches!(c.resp, Response::Bye));
        // blocking adapter works through the actor too
        assert!(matches!(w.call(Request::FinalCommit), Response::Bye));
        // deactivation hands the endpoint back; blocking calls keep working
        w.deactivate();
        assert!(matches!(w.call(Request::FinalCommit), Response::Bye));
        assert!(!w.faulted());
    }

    /// An endpoint that never answers its second request — the actor-link
    /// equivalent of a worker process hanging mid-protocol.
    struct StallSecond {
        seen: u64,
    }

    impl Endpoint for StallSecond {
        fn name(&self) -> &str {
            "stall2"
        }
        fn call(&mut self, _req: Request) -> Response {
            self.seen += 1;
            if self.seen >= 2 {
                loop {
                    std::thread::sleep(Duration::from_secs(3600));
                }
            }
            Response::Bye
        }
    }

    #[test]
    fn blocking_call_deadline_latches_fault_on_stalled_actor() {
        let mut w = PooledWorker::new("w0", StallSecond { seen: 0 });
        w.set_call_deadline(Duration::from_millis(100));
        w.activate();
        assert!(matches!(w.call(Request::FinalCommit), Response::Bye));
        assert!(!w.faulted());
        let t0 = Instant::now();
        let resp = w.call(Request::FinalCommit);
        assert!(matches!(resp, Response::Refuse(_)), "{resp:?}");
        assert!(w.faulted(), "missed deadline latches the fault");
        assert!(t0.elapsed() < Duration::from_secs(5));
        // do NOT deactivate: the actor is stranded. Dropping w detaches it.
    }
}
