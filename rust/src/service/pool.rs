//! The worker pool: a free-list of leasable worker endpoints with **lease
//! revocation and suspension**. Scheduler state machines acquire `k`
//! workers **atomically** (all-or-nothing under one lock) which keeps the
//! acquire path deadlock-free, and hand back each worker by releasing it
//! (healthy), suspending it (missed a deadline — it may return after a
//! probation backoff), or revoking it (permanently expelled). Suspended
//! and revoked workers leave the live count: [`WorkerPool::size`] shrinks.
//!
//! Suspension is the mechanism behind the coordinator's **re-admission
//! with exponential backoff**: a worker that misses a dispatch deadline or
//! ping is [`WorkerPool::suspend`]ed until a backoff instant; once due it
//! is handed out via [`WorkerPool::parole_due`] for a probe ping, and
//! either [`WorkerPool::readmit`]ted (answered — rejoins the free list,
//! `size` grows back), [`WorkerPool::resuspend`]ed (still silent — backoff
//! doubles), or [`WorkerPool::expel`]led (struck out). The pool is a
//! cheaply clonable handle (`Arc` inside) so a long-lived
//! [`Delegation`](crate::service::client::Delegation) can own a reference
//! while callers keep theirs.
//!
//! Each worker carries the [`Backend`] it advertises
//! ([`PooledWorker::with_backend`]); [`WorkerPool::try_acquire_where`]
//! leases against a predicate so jobs with a
//! [`BackendRequirement`](crate::verde::protocol::BackendRequirement) are
//! routed to admissible hardware only.
//!
//! Workers are held as [`PooledWorker`]s, which unify three transports
//! behind one dispatch surface:
//!
//! * **Blocking** — any [`Endpoint`] (in-process
//!   [`WorkerHost`](crate::service::worker::WorkerHost), threaded remote,
//!   blocking TCP).
//! * **Actor** — the same endpoint activated onto its own mailbox thread so
//!   the event-driven coordinator can dispatch without blocking; the
//!   endpoint is recovered when the actor is deactivated.
//! * **Mux** — a [`MuxConn`] on the non-blocking multiplexer: no
//!   coordinator-side thread at all, deadlines enforced by the mux driver.
//!
//! All three offer the non-blocking [`PooledWorker::dispatch`] (completions
//! arrive on a channel) and the blocking [`Endpoint`] adapter used by
//! dispute tournaments.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::graph::kernels::Backend;
use crate::net::mux::{Completion, CompletionKind, MuxConn};
use crate::net::Endpoint;
use crate::verde::protocol::{BackendRequirement, Request, Response};

/// Message into a worker actor's mailbox.
enum ActorMsg {
    Dispatch { token: u64, req: Request, reply: Sender<Completion> },
    Stop,
}

/// A blocking endpoint running on its own mailbox thread, so dispatches
/// return immediately and the caller collects the answer as a
/// [`Completion`]. Deadlines for actor-backed workers are enforced by the
/// coordinator's timer (the actor itself cannot be interrupted — a stalled
/// endpoint strands its thread, which is exactly the failure the service
/// layer revokes leases over).
struct ActorHandle {
    tx: Sender<ActorMsg>,
    join: JoinHandle<Box<dyn Endpoint + Send>>,
    reply_tx: Sender<Completion>,
    reply_rx: Receiver<Completion>,
    next_call_tag: u64,
}

fn spawn_actor(name: &str, mut endpoint: Box<dyn Endpoint + Send>) -> ActorHandle {
    let (tx, rx) = channel::<ActorMsg>();
    let join = std::thread::Builder::new()
        .name(format!("verde-actor-{name}"))
        .spawn(move || {
            while let Ok(msg) = rx.recv() {
                match msg {
                    ActorMsg::Dispatch { token, req, reply } => {
                        let resp = endpoint.call(req);
                        let _ = reply.send(Completion {
                            token,
                            kind: CompletionKind::Answered,
                            resp,
                        });
                    }
                    ActorMsg::Stop => break,
                }
            }
            endpoint
        })
        .expect("spawn worker actor");
    let (reply_tx, reply_rx) = channel();
    ActorHandle {
        tx,
        join,
        reply_tx,
        reply_rx,
        // Blocking calls tag from the top half of the space, mirroring the
        // mux convention: dispatch tokens stay below 2^63.
        next_call_tag: 1 << 63,
    }
}

/// The transport behind one pooled worker.
enum Link {
    Blocking(Box<dyn Endpoint + Send>),
    Actor(ActorHandle),
    Mux(MuxConn),
    /// The worker was lost (actor thread panicked / link torn down).
    Dead(String),
}

/// A worker endpoint owned by the pool, addressable by name in reports.
pub struct PooledWorker {
    pub name: String,
    link: Link,
    /// The hardware class this worker advertises; jobs with a
    /// reproducible-only requirement are never leased to `Free` workers.
    backend: Backend,
    /// Deadlines missed so far — drives the re-admission backoff doubling.
    strikes: u32,
    /// Deadline applied to blocking calls routed through an actor link.
    call_deadline: Duration,
    /// Latched when a blocking call through this worker went unanswered;
    /// the coordinator revokes the lease of a faulted worker at job end.
    faulted: bool,
}

impl PooledWorker {
    /// Wrap any blocking endpoint (in-process host, threaded remote,
    /// blocking TCP endpoint).
    pub fn new(name: &str, endpoint: impl Endpoint + Send + 'static) -> PooledWorker {
        PooledWorker {
            name: name.to_string(),
            link: Link::Blocking(Box::new(endpoint)),
            backend: Backend::Rep,
            strikes: 0,
            call_deadline: Duration::from_secs(60),
            faulted: false,
        }
    }

    /// Wrap a multiplexed connection — the zero-thread-per-worker shape.
    pub fn mux(name: &str, conn: MuxConn) -> PooledWorker {
        PooledWorker {
            name: name.to_string(),
            link: Link::Mux(conn),
            backend: Backend::Rep,
            strikes: 0,
            call_deadline: Duration::from_secs(60),
            faulted: false,
        }
    }

    /// Declare the hardware class this worker runs on (default
    /// [`Backend::Rep`]). This is advertised capability used for routing;
    /// lying about it is caught the usual way — by losing disputes.
    pub fn with_backend(mut self, backend: Backend) -> PooledWorker {
        self.backend = backend;
        self
    }

    /// The hardware class this worker advertises.
    pub fn backend(&self) -> &Backend {
        &self.backend
    }

    /// Deadlines this worker has missed (drives suspension backoff).
    pub fn strikes(&self) -> u32 {
        self.strikes
    }

    /// Record one more missed deadline.
    pub fn add_strike(&mut self) {
        self.strikes = self.strikes.saturating_add(1);
    }

    /// Deadline for blocking calls (dispute/tournament traffic). Applies
    /// to actor and mux links; a plain blocking link runs unbounded, which
    /// is the pre-event-core behavior tests rely on.
    pub fn set_call_deadline(&mut self, d: Duration) {
        self.call_deadline = d;
        if let Link::Mux(_) = self.link {
            // Rebuild the mux handle's deadline in place.
            let link = std::mem::replace(&mut self.link, Link::Dead(String::new()));
            if let Link::Mux(conn) = link {
                self.link = Link::Mux(conn.with_call_deadline(d));
            }
        }
    }

    /// Move a blocking endpoint onto its own actor thread so dispatches
    /// don't block the event loop. Idempotent; no-op for mux links.
    /// Returns `true` when a thread was actually spawned, so callers can
    /// account coordinator-side threads honestly.
    pub fn activate(&mut self) -> bool {
        if matches!(self.link, Link::Blocking(_)) {
            let link = std::mem::replace(&mut self.link, Link::Dead(String::new()));
            if let Link::Blocking(endpoint) = link {
                self.link = Link::Actor(spawn_actor(&self.name, endpoint));
                return true;
            }
        }
        false
    }

    /// Stop the actor thread and recover the blocking endpoint. Only safe
    /// for responsive workers (a stalled actor never drains its mailbox);
    /// the coordinator revokes unresponsive workers instead of
    /// deactivating them.
    pub fn deactivate(&mut self) {
        if matches!(self.link, Link::Actor(_)) {
            let link = std::mem::replace(&mut self.link, Link::Dead(String::new()));
            if let Link::Actor(actor) = link {
                let _ = actor.tx.send(ActorMsg::Stop);
                match actor.join.join() {
                    Ok(endpoint) => self.link = Link::Blocking(endpoint),
                    Err(_) => self.link = Link::Dead("worker actor panicked".into()),
                }
            }
        }
    }

    /// Non-blocking dispatch: enqueue `req` under `token`; the answer (or
    /// a synthesized refusal) arrives on `reply`. For mux links the
    /// deadline is enforced by the mux driver; for actor links the
    /// coordinator's timer enforces it (the actor cannot be interrupted).
    pub fn dispatch(
        &mut self,
        token: u64,
        req: Request,
        deadline: Option<Instant>,
        reply: &Sender<Completion>,
    ) {
        let _ = self.activate();
        match &mut self.link {
            Link::Mux(conn) => conn.submit(token, &req, deadline, reply),
            Link::Actor(actor) => {
                let msg = ActorMsg::Dispatch { token, req, reply: reply.clone() };
                if actor.tx.send(msg).is_err() {
                    let _ = reply.send(Completion {
                        token,
                        kind: CompletionKind::Transport,
                        resp: Response::Refuse(format!("{}: worker actor gone", self.name)),
                    });
                }
            }
            Link::Blocking(_) => unreachable!("activate() precedes dispatch"),
            Link::Dead(why) => {
                let _ = reply.send(Completion {
                    token,
                    kind: CompletionKind::Transport,
                    resp: Response::Refuse(format!("{}: {why}", self.name)),
                });
            }
        }
    }

    /// True once any request through this worker went unanswered (blocking
    /// call deadline, mux deadline, or dead transport).
    pub fn faulted(&self) -> bool {
        if self.faulted {
            return true;
        }
        match &self.link {
            Link::Mux(conn) => conn.faulted(),
            Link::Dead(_) => true,
            _ => false,
        }
    }

    /// Clear the fault latch at the start of a fresh lease.
    pub fn reset_fault(&mut self) {
        self.faulted = false;
        if let Link::Mux(conn) = &mut self.link {
            conn.reset_fault();
        }
    }
}

impl Endpoint for PooledWorker {
    fn name(&self) -> &str {
        &self.name
    }

    /// Blocking adapter over whichever link backs this worker — disputes
    /// and tournaments run over it unchanged.
    fn call(&mut self, req: Request) -> Response {
        match &mut self.link {
            Link::Blocking(endpoint) => endpoint.call(req),
            Link::Mux(conn) => conn.call(req),
            Link::Actor(actor) => {
                let tag = actor.next_call_tag;
                actor.next_call_tag += 1;
                let msg = ActorMsg::Dispatch { token: tag, req, reply: actor.reply_tx.clone() };
                if actor.tx.send(msg).is_err() {
                    self.faulted = true;
                    return Response::Refuse(format!("{}: worker actor gone", self.name));
                }
                loop {
                    match actor.reply_rx.recv_timeout(self.call_deadline) {
                        Ok(c) if c.token == tag => return c.resp,
                        // Stale answer from an earlier abandoned call.
                        Ok(_) => continue,
                        Err(_) => {
                            self.faulted = true;
                            return Response::Refuse(format!(
                                "{}: deadline expired before the worker answered",
                                self.name
                            ));
                        }
                    }
                }
            }
            Link::Dead(why) => Response::Refuse(format!("{}: {why}", self.name)),
        }
    }
}

/// A suspended worker serving its probation backoff.
struct Suspended {
    worker: PooledWorker,
    until: Instant,
}

struct PoolState {
    free: VecDeque<PooledWorker>,
    /// Live workers (idle + leased); shrinks on suspension/revocation.
    size: usize,
    /// Suspended workers waiting out their backoff.
    suspended: Vec<Suspended>,
    /// Workers handed out via [`WorkerPool::parole_due`] and not yet
    /// readmitted / resuspended / expelled.
    on_parole: usize,
    /// Reproducible ([`Backend::Rep`]) workers that may ever serve again
    /// (free + leased + suspended + paroled); shrinks only on permanent
    /// expulsion. Drives [`WorkerPool::any_eligible`] for
    /// reproducible-only jobs even while individual workers are leased
    /// out and uninspectable.
    rep_total: usize,
    /// Names of workers whose leases were revoked or suspended, in event
    /// order (a re-admitted worker's name stays on the record).
    revoked: Vec<String>,
}

struct PoolInner {
    state: Mutex<PoolState>,
    available: Condvar,
}

/// Free-list of idle workers plus a condvar for callers waiting on
/// capacity, with lease suspension (probation + re-admission) and
/// permanent revocation. Cloning the pool clones a handle to the same
/// shared state.
#[derive(Clone)]
pub struct WorkerPool {
    inner: Arc<PoolInner>,
}

impl WorkerPool {
    /// # Panics
    /// On an empty worker set.
    pub fn new(workers: Vec<PooledWorker>) -> WorkerPool {
        assert!(!workers.is_empty(), "a pool needs at least one worker");
        WorkerPool {
            inner: Arc::new(PoolInner {
                state: Mutex::new(PoolState {
                    size: workers.len(),
                    rep_total: workers
                        .iter()
                        .filter(|w| matches!(w.backend, Backend::Rep))
                        .count(),
                    free: workers.into(),
                    suspended: Vec::new(),
                    on_parole: 0,
                    revoked: Vec::new(),
                }),
                available: Condvar::new(),
            }),
        }
    }

    fn state(&self) -> std::sync::MutexGuard<'_, PoolState> {
        self.inner.state.lock().unwrap()
    }

    /// Live workers owned by the pool (idle + leased; suspended and
    /// revoked excluded).
    pub fn size(&self) -> usize {
        self.state().size
    }

    /// Idle workers right now (diagnostic; racy by nature).
    pub fn idle(&self) -> usize {
        self.state().free.len()
    }

    /// Workers currently out of the live pool but eligible to return:
    /// suspended plus out on a parole probe.
    pub fn suspended(&self) -> usize {
        let st = self.state();
        st.suspended.len() + st.on_parole
    }

    /// Names of workers whose leases were revoked or suspended, in event
    /// order.
    pub fn revoked(&self) -> Vec<String> {
        self.state().revoked.clone()
    }

    /// Export the pool's occupancy into stats gauges under a single lock
    /// acquisition — the event loop calls this once per tick, so one
    /// lock round-trip instead of three.
    pub fn observe_gauges(
        &self,
        idle: &crate::obs::Gauge,
        suspended: &crate::obs::Gauge,
        size: &crate::obs::Gauge,
    ) {
        let st = self.state();
        idle.set(st.free.len() as u64);
        suspended.set((st.suspended.len() + st.on_parole) as u64);
        size.set(st.size as u64);
    }

    /// Could a worker satisfying `req` ever be leased again? Counts free,
    /// leased, suspended, and paroled workers — everything short of
    /// permanent expulsion. Leased workers are not inspectable, so the
    /// reproducible case is answered from a maintained counter rather
    /// than a scan; a `false` here is final and lets the scheduler fail a
    /// segment instead of deferring it forever.
    pub fn any_eligible(&self, req: BackendRequirement) -> bool {
        let st = self.state();
        match req {
            BackendRequirement::Any => st.size + st.suspended.len() + st.on_parole > 0,
            BackendRequirement::ReproducibleOnly => st.rep_total > 0,
        }
    }

    /// Block until `k` workers are free, then take them all at once.
    ///
    /// # Panics
    /// If `k == 0`, or if `k` exceeds the pool's live size (at entry or
    /// after revocations shrink the pool below `k` while waiting — the
    /// panic is the deadlock-free alternative to waiting forever).
    pub fn acquire(&self, k: usize) -> Vec<PooledWorker> {
        assert!(k >= 1, "acquire(0) is meaningless");
        let mut st = self.state();
        loop {
            assert!(k <= st.size, "acquire({k}) from a pool of {}", st.size);
            if st.free.len() >= k {
                return st.free.drain(..k).collect();
            }
            st = self.inner.available.wait(st).unwrap();
        }
    }

    /// Take `k` workers if they are free right now, else `None` — the
    /// event-driven coordinator's non-blocking acquire.
    pub fn try_acquire(&self, k: usize) -> Option<Vec<PooledWorker>> {
        self.try_acquire_where(k, |_| true)
    }

    /// Take `k` workers satisfying `pred` if that many are free right
    /// now, else `None` (free workers failing the predicate stay in
    /// place, in order) — backend-requirement routing.
    pub fn try_acquire_where(
        &self,
        k: usize,
        pred: impl Fn(&PooledWorker) -> bool,
    ) -> Option<Vec<PooledWorker>> {
        if k == 0 {
            return Some(Vec::new());
        }
        let mut st = self.state();
        if st.free.iter().filter(|w| pred(w)).count() < k {
            return None;
        }
        let mut taken = Vec::with_capacity(k);
        let mut rest = VecDeque::with_capacity(st.free.len());
        while let Some(w) = st.free.pop_front() {
            if taken.len() < k && pred(&w) {
                taken.push(w);
            } else {
                rest.push_back(w);
            }
        }
        st.free = rest;
        Some(taken)
    }

    /// Take the idle worker named `name`, leaving everyone else in place
    /// (`None` when no idle worker bears that name — it may be leased,
    /// suspended, or gone). The audit tier uses this to pin an optimistic
    /// job to its staked worker across segments, and to re-lease an
    /// accused worker into its own escalation tournament.
    pub fn try_take_named(&self, name: &str) -> Option<PooledWorker> {
        let mut st = self.state();
        let idx = st.free.iter().position(|w| w.name == name)?;
        st.free.remove(idx)
    }

    /// Take every currently idle worker (health-check sweeps, teardown).
    pub fn drain_idle(&self) -> Vec<PooledWorker> {
        let mut st = self.state();
        st.free.drain(..).collect()
    }

    /// Return leased workers and wake waiting acquirers.
    pub fn release(&self, workers: Vec<PooledWorker>) {
        let mut st = self.state();
        st.free.extend(workers);
        drop(st);
        self.inner.available.notify_all();
    }

    /// Permanently expel a leased worker: it never re-enters the free list
    /// and the pool's size shrinks. Waiting acquirers are woken so an
    /// acquire that can no longer be satisfied panics instead of sleeping
    /// forever.
    pub fn revoke(&self, worker: PooledWorker) {
        let mut st = self.state();
        st.size -= 1;
        if matches!(worker.backend, Backend::Rep) {
            st.rep_total -= 1;
        }
        st.revoked.push(worker.name.clone());
        drop(st);
        drop(worker);
        self.inner.available.notify_all();
    }

    /// Suspend a leased worker until `until`: it leaves the live pool
    /// (size shrinks, like a revocation — the name is logged) but stays
    /// eligible for parole once the backoff elapses.
    pub fn suspend(&self, worker: PooledWorker, until: Instant) {
        let mut st = self.state();
        st.size -= 1;
        st.revoked.push(worker.name.clone());
        st.suspended.push(Suspended { worker, until });
        drop(st);
        self.inner.available.notify_all();
    }

    /// Earliest instant a suspended worker becomes due for parole.
    pub fn next_parole(&self) -> Option<Instant> {
        self.state().suspended.iter().map(|s| s.until).min()
    }

    /// Take every suspended worker whose backoff has elapsed, for a probe
    /// ping. Each must come back via [`WorkerPool::readmit`],
    /// [`WorkerPool::resuspend`], or [`WorkerPool::expel`].
    pub fn parole_due(&self, now: Instant) -> Vec<PooledWorker> {
        let mut st = self.state();
        let mut due = Vec::new();
        let mut keep = Vec::with_capacity(st.suspended.len());
        for s in st.suspended.drain(..) {
            if s.until <= now {
                due.push(s.worker);
            } else {
                keep.push(s);
            }
        }
        st.suspended = keep;
        st.on_parole += due.len();
        due
    }

    /// A paroled worker answered its probe: re-enter the free list, live
    /// size grows back.
    pub fn readmit(&self, worker: PooledWorker) {
        let mut st = self.state();
        st.on_parole -= 1;
        st.size += 1;
        st.free.push_back(worker);
        drop(st);
        self.inner.available.notify_all();
    }

    /// A paroled worker missed its probe: back to suspension with a new
    /// (longer) backoff.
    pub fn resuspend(&self, worker: PooledWorker, until: Instant) {
        let mut st = self.state();
        st.on_parole -= 1;
        st.suspended.push(Suspended { worker, until });
    }

    /// A paroled worker struck out: permanently expelled.
    pub fn expel(&self, worker: PooledWorker) {
        let mut st = self.state();
        st.on_parole -= 1;
        if matches!(worker.backend, Backend::Rep) {
            st.rep_total -= 1;
        }
        drop(st);
        drop(worker);
        self.inner.available.notify_all();
    }

    /// Tear the pool down, handing every idle worker back (used for
    /// orderly shutdown: callers typically send `Request::Shutdown` to
    /// each endpoint). Leased workers must be released first; suspended
    /// workers are dropped — by definition they stopped answering, so no
    /// goodbye is owed.
    pub fn into_workers(self) -> Vec<PooledWorker> {
        let mut st = self.state();
        st.size = 0;
        st.rep_total = 0;
        st.suspended.clear();
        st.free.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Nop;

    impl Endpoint for Nop {
        fn name(&self) -> &str {
            "nop"
        }
        fn call(&mut self, _req: Request) -> Response {
            Response::Bye
        }
    }

    fn pool_of(n: usize) -> WorkerPool {
        WorkerPool::new((0..n).map(|i| PooledWorker::new(&format!("w{i}"), Nop)).collect())
    }

    #[test]
    fn acquire_release_roundtrip() {
        let pool = pool_of(4);
        assert_eq!(pool.size(), 4);
        let lease = pool.acquire(3);
        assert_eq!(lease.len(), 3);
        assert_eq!(pool.idle(), 1);
        pool.release(lease);
        assert_eq!(pool.idle(), 4);
        assert_eq!(pool.into_workers().len(), 4);
    }

    #[test]
    fn blocked_acquire_wakes_on_release() {
        use std::sync::Arc;
        let pool = Arc::new(pool_of(2));
        let lease = pool.acquire(2);
        let p2 = Arc::clone(&pool);
        let waiter = std::thread::spawn(move || p2.acquire(2).len());
        std::thread::sleep(std::time::Duration::from_millis(20));
        pool.release(lease);
        assert_eq!(waiter.join().unwrap(), 2);
    }

    #[test]
    #[should_panic(expected = "acquire(3) from a pool of 2")]
    fn oversubscription_panics_rather_than_deadlocks() {
        let pool = pool_of(2);
        pool.acquire(3);
    }

    #[test]
    fn revoked_worker_never_returns_and_size_shrinks() {
        let pool = pool_of(3);
        let mut lease = pool.acquire(2);
        let victim = lease.pop().unwrap();
        let victim_name = victim.name.clone();
        pool.revoke(victim);
        assert_eq!(pool.size(), 2, "revocation shrinks the pool");
        assert_eq!(pool.revoked(), vec![victim_name.clone()]);
        pool.release(lease);
        assert_eq!(pool.idle(), 2);
        // the revoked name is not among the survivors
        let names: Vec<String> =
            pool.into_workers().into_iter().map(|w| w.name).collect();
        assert_eq!(names.len(), 2);
        assert!(!names.contains(&victim_name), "{names:?}");
    }

    #[test]
    fn suspended_worker_paroles_and_readmits() {
        let pool = pool_of(3);
        let mut lease = pool.acquire(2);
        let mut victim = lease.pop().unwrap();
        victim.add_strike();
        assert_eq!(victim.strikes(), 1);
        let until = Instant::now() + Duration::from_millis(30);
        pool.suspend(victim, until);
        assert_eq!(pool.size(), 2, "suspension leaves the live pool");
        assert_eq!(pool.suspended(), 1);
        assert_eq!(pool.revoked().len(), 1, "suspension is logged");
        assert!(pool.next_parole().is_some());
        assert!(pool.parole_due(Instant::now()).is_empty(), "backoff not yet served");
        std::thread::sleep(Duration::from_millis(40));
        let due = pool.parole_due(Instant::now());
        assert_eq!(due.len(), 1);
        assert_eq!(pool.suspended(), 1, "paroled workers still count as out");
        let w = due.into_iter().next().unwrap();
        pool.readmit(w);
        assert_eq!(pool.size(), 3, "re-admission restores the live size");
        assert_eq!(pool.suspended(), 0);
        pool.release(lease);
        assert_eq!(pool.idle(), 3);
    }

    #[test]
    fn resuspend_and_expel_account_parole_correctly() {
        let pool = pool_of(2);
        let mut lease = pool.acquire(2);
        pool.suspend(lease.pop().unwrap(), Instant::now());
        pool.suspend(lease.pop().unwrap(), Instant::now());
        assert_eq!(pool.size(), 0);
        let due = pool.parole_due(Instant::now());
        assert_eq!(due.len(), 2);
        let mut it = due.into_iter();
        pool.resuspend(it.next().unwrap(), Instant::now() + Duration::from_secs(60));
        pool.expel(it.next().unwrap());
        assert_eq!(pool.suspended(), 1, "one back in suspension, one gone");
        assert_eq!(pool.size(), 0);
        assert!(
            pool.any_eligible(BackendRequirement::Any),
            "the resuspended worker keeps hope alive"
        );
        assert!(
            pool.any_eligible(BackendRequirement::ReproducibleOnly),
            "the resuspended worker is reproducible"
        );
    }

    #[test]
    fn try_acquire_where_routes_by_backend() {
        use crate::tensor::profile::HardwareProfile;
        let free_hw = Backend::Free(HardwareProfile::T4_16G);
        let pool = WorkerPool::new(vec![
            PooledWorker::new("gpu0", Nop).with_backend(free_hw),
            PooledWorker::new("rep0", Nop),
            PooledWorker::new("gpu1", Nop).with_backend(free_hw),
            PooledWorker::new("rep1", Nop),
        ]);
        let rep_only = |w: &PooledWorker| matches!(w.backend(), Backend::Rep);
        let lease = pool.try_acquire_where(2, rep_only).expect("two rep workers free");
        let names: Vec<&str> = lease.iter().map(|w| w.name.as_str()).collect();
        assert_eq!(names, vec!["rep0", "rep1"]);
        assert!(pool.try_acquire_where(1, rep_only).is_none(), "no rep worker left");
        assert_eq!(pool.idle(), 2, "free-order workers stay in place");
        assert!(
            pool.any_eligible(BackendRequirement::ReproducibleOnly),
            "leased rep workers still count as eligible"
        );
        // Permanently expelling both rep workers extinguishes eligibility
        // even though free-order workers remain.
        let mut lease = lease;
        pool.revoke(lease.pop().unwrap());
        pool.revoke(lease.pop().unwrap());
        assert!(!pool.any_eligible(BackendRequirement::ReproducibleOnly));
        assert!(pool.any_eligible(BackendRequirement::Any));
    }

    #[test]
    fn try_acquire_never_blocks() {
        let pool = pool_of(2);
        let lease = pool.try_acquire(2).expect("both free");
        assert!(pool.try_acquire(1).is_none(), "everything is leased");
        pool.release(lease);
        assert!(pool.try_acquire(1).is_some());
    }

    #[test]
    fn actor_roundtrip_activate_dispatch_deactivate() {
        let mut w = PooledWorker::new("w0", Nop);
        assert!(w.activate(), "first activation spawns the actor");
        assert!(!w.activate(), "activation is idempotent");
        let (tx, rx) = channel();
        w.dispatch(7, Request::FinalCommit, None, &tx);
        let c = rx.recv_timeout(Duration::from_secs(5)).expect("completion");
        assert_eq!(c.token, 7);
        assert_eq!(c.kind, CompletionKind::Answered);
        assert!(matches!(c.resp, Response::Bye));
        // blocking adapter works through the actor too
        assert!(matches!(w.call(Request::FinalCommit), Response::Bye));
        // deactivation hands the endpoint back; blocking calls keep working
        w.deactivate();
        assert!(matches!(w.call(Request::FinalCommit), Response::Bye));
        assert!(!w.faulted());
    }

    /// An endpoint that never answers its second request — the actor-link
    /// equivalent of a worker process hanging mid-protocol.
    struct StallSecond {
        seen: u64,
    }

    impl Endpoint for StallSecond {
        fn name(&self) -> &str {
            "stall2"
        }
        fn call(&mut self, _req: Request) -> Response {
            self.seen += 1;
            if self.seen >= 2 {
                loop {
                    std::thread::sleep(Duration::from_secs(3600));
                }
            }
            Response::Bye
        }
    }

    #[test]
    fn blocking_call_deadline_latches_fault_on_stalled_actor() {
        let mut w = PooledWorker::new("w0", StallSecond { seen: 0 });
        w.set_call_deadline(Duration::from_millis(100));
        w.activate();
        assert!(matches!(w.call(Request::FinalCommit), Response::Bye));
        assert!(!w.faulted());
        let t0 = Instant::now();
        let resp = w.call(Request::FinalCommit);
        assert!(matches!(resp, Response::Refuse(_)), "{resp:?}");
        assert!(w.faulted(), "missed deadline latches the fault");
        assert!(t0.elapsed() < Duration::from_secs(5));
        // do NOT deactivate: the actor is stranded. Dropping w detaches it.
    }
}
