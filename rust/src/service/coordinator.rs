//! The delegation coordinator, rebuilt as an **event-driven core**: one
//! event-loop thread drives per-job state machines off a completion queue,
//! so the number of coordinator threads is fixed (`1` event loop + a small
//! tournament-resolver pool) no matter how many workers are in flight —
//! thousands of multiplexed TCP workers fit in a handful of threads.
//!
//! Job lifecycle:
//!
//! ```text
//!   Queued ──lease k workers──▶ Dispatching ──all slots answered──▶ Resolving ──▶ Done
//!     ▲                            │                                  (tournament on a
//!     │       deadline expired /   │                                   resolver thread)
//!     └── job re-queued ◀── lease revoked for the silent worker
//! ```
//!
//! * **Dispatching** — `Request::Train` is submitted to every leased worker
//!   with a per-request deadline ([`ServiceConfig::dispatch_deadline`]).
//!   Completions (answers, deadline expiries, transport failures) arrive on
//!   one channel; the deadline for actor-backed workers is enforced by the
//!   loop's timer heap, for mux-backed workers by the mux driver — both
//!   paths synthesize `Response::Refuse`, deduplicated by token.
//! * **Revocation & re-queue** — a worker that misses its deadline (or a
//!   health-check ping) has its lease revoked: it never re-enters the pool
//!   and [`WorkerPool::size`] shrinks. Its job releases the surviving
//!   workers and re-queues (bounded by [`ServiceConfig::max_requeues`]),
//!   completing on whoever remains.
//! * **Resolving** — collected claims go to a resolver thread, which runs
//!   the unchanged blocking [`run_tournament`] over the workers' blocking
//!   [`Endpoint`] adapters (dispute traffic is deadline-bounded too; a
//!   worker that goes silent mid-dispute is convicted by the referee and
//!   revoked afterwards).
//!
//! The pre-event-core scheduler survives as [`run_service_blocking`] — the
//! thread-per-dispatch baseline the benches compare against.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::fmt::Write as _;
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::hash::Hash;
use crate::net::mux::{Completion, CompletionKind};
use crate::net::{Endpoint, Metered};
use crate::train::JobSpec;
use crate::verde::protocol::{Request, Response};
use crate::verde::tournament::run_tournament;

use super::pool::{PooledWorker, WorkerPool};

/// Tuning knobs for the event-driven service core.
#[derive(Debug, Clone, Copy)]
pub struct ServiceConfig {
    /// Workers leased per job.
    pub k: usize,
    /// Deadline for each `Train` dispatch; expiry revokes the silent
    /// worker's lease and re-queues the job.
    pub dispatch_deadline: Duration,
    /// Deadline for each blocking dispute/tournament request.
    pub call_deadline: Duration,
    /// How many times a job may be re-queued after lease revocations
    /// before it is reported unresolved.
    pub max_requeues: u32,
    /// Tournament resolver threads. Coordinator threads total
    /// `1 + resolvers` (plus the global mux driver when multiplexed
    /// transport is used).
    pub resolvers: usize,
    /// Ping idle workers this often; a missed ping revokes the lease.
    /// `None` disables health checks.
    pub health_check: Option<Duration>,
    /// Deadline for health-check pings.
    pub ping_deadline: Duration,
}

impl ServiceConfig {
    pub fn new(k: usize) -> ServiceConfig {
        ServiceConfig {
            k,
            dispatch_deadline: Duration::from_secs(600),
            call_deadline: Duration::from_secs(60),
            max_requeues: 3,
            resolvers: 4,
            health_check: None,
            ping_deadline: Duration::from_secs(5),
        }
    }
}

/// Per-job result plus its cost accounting.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub job_id: u64,
    /// The commitment the service vouches for (`None` when no worker even
    /// produced a claim — all assignments failed or were revoked).
    pub accepted: Option<Hash>,
    /// Name of the worker whose claim was accepted.
    pub winner: Option<String>,
    /// Pairwise disputes the job needed (0 when all claims agree).
    pub disputes: usize,
    /// Workers eliminated as dishonest by the tournament.
    pub eliminated: usize,
    /// Times this job was re-queued after a lease revocation.
    pub requeues: u32,
    /// Worker leases revoked across this job's attempts (deadline misses
    /// and transport deaths).
    pub revoked: usize,
    /// Wall-clock latency: first lease → verdict.
    pub wall: Duration,
    /// Protocol bytes exchanged with this job's workers (both directions,
    /// exact `wire_size` accounting, all attempts included).
    pub bytes: u64,
    /// Protocol requests issued to this job's workers.
    pub requests: u64,
}

/// Aggregate service run report.
#[derive(Debug)]
pub struct ServiceReport {
    /// Outcomes sorted by job id.
    pub outcomes: Vec<JobOutcome>,
    /// Wall time for the whole batch.
    pub wall: Duration,
    /// Workers assigned per job.
    pub k: usize,
    /// Pool size the batch started with.
    pub workers: usize,
    /// Names of workers whose leases were revoked during the run.
    pub revoked: Vec<String>,
    /// Coordinator-side threads the run used. Event core: event loop +
    /// resolvers + one actor thread per blocking-linked worker it had to
    /// activate (mux-linked workers need none — that is the scaling
    /// argument). Blocking baseline: lanes × (1 + k) at peak.
    pub threads: usize,
}

impl ServiceReport {
    pub fn jobs_per_sec(&self) -> f64 {
        self.outcomes.len() as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    pub fn total_bytes(&self) -> u64 {
        self.outcomes.iter().map(|o| o.bytes).sum()
    }

    pub fn total_disputes(&self) -> usize {
        self.outcomes.iter().map(|o| o.disputes).sum()
    }

    /// Workers eliminated as dishonest across all tournaments.
    pub fn total_eliminated(&self) -> usize {
        self.outcomes.iter().map(|o| o.eliminated).sum()
    }

    /// Job re-queues forced by lease revocations.
    pub fn total_requeued(&self) -> u64 {
        self.outcomes.iter().map(|o| u64::from(o.requeues)).sum()
    }

    /// Mean protocol bytes per job.
    pub fn bytes_per_job(&self) -> f64 {
        if self.outcomes.is_empty() {
            0.0
        } else {
            self.total_bytes() as f64 / self.outcomes.len() as f64
        }
    }

    /// Mean job latency (first lease → verdict).
    pub fn mean_latency(&self) -> Duration {
        if self.outcomes.is_empty() {
            Duration::ZERO
        } else {
            self.outcomes.iter().map(|o| o.wall).sum::<Duration>() / self.outcomes.len() as u32
        }
    }

    /// One machine-readable JSON object (stable key order).
    pub fn to_json(&self) -> String {
        let resolved = self.outcomes.iter().filter(|o| o.accepted.is_some()).count();
        let mut s = String::from("{");
        let _ = write!(
            s,
            "\"jobs\":{},\"resolved\":{},\"k\":{},\"workers\":{},\"wall_s\":{:.6},\
             \"jobs_per_sec\":{:.3},\"mean_latency_s\":{:.6},\"total_bytes\":{},\
             \"bytes_per_job\":{:.1},\"disputes\":{},\"eliminated\":{},\"requeued\":{},\
             \"revoked\":{},\"threads\":{}",
            self.outcomes.len(),
            resolved,
            self.k,
            self.workers,
            self.wall.as_secs_f64(),
            self.jobs_per_sec(),
            self.mean_latency().as_secs_f64(),
            self.total_bytes(),
            self.bytes_per_job(),
            self.total_disputes(),
            self.total_eliminated(),
            self.total_requeued(),
            self.revoked.len(),
            self.threads,
        );
        s.push('}');
        s
    }
}

// ---------------------------------------------------------------------------
// event-driven core
// ---------------------------------------------------------------------------

/// Wake-only completion token (resolver → event loop nudge).
const WAKE_TOKEN: u64 = u64::MAX;

/// A job waiting for a lease.
struct QueuedJob {
    job_id: u64,
    spec: JobSpec,
    requeues: u32,
    revoked: usize,
    bytes: u64,
    requests: u64,
    /// First-lease instant, kept across re-queues so `wall` measures
    /// first lease → verdict.
    t0: Option<Instant>,
}

enum SlotState {
    Waiting,
    Done(Response),
    /// Deadline expired or transport died — the worker gets revoked.
    Failed,
}

/// A job whose `Train` dispatches are in flight.
struct ActiveJob {
    spec: JobSpec,
    t0: Instant,
    requeues: u32,
    revoked: usize,
    bytes: u64,
    requests: u64,
    workers: Vec<PooledWorker>,
    slots: Vec<SlotState>,
    outstanding: usize,
}

/// What a completion token addresses.
enum Target {
    Job { job_id: u64, slot: usize },
    Probe,
}

/// Work order for a resolver thread.
struct ResolveTask {
    job_id: u64,
    spec: JobSpec,
    t0: Instant,
    requeues: u32,
    revoked: usize,
    bytes: u64,
    requests: u64,
    workers: Vec<PooledWorker>,
}

struct Resolved {
    outcome: JobOutcome,
    workers: Vec<PooledWorker>,
}

/// Run the tournament for one job on a resolver thread. The workers'
/// blocking [`Endpoint`] adapters carry the dispute traffic; unanswered
/// requests surface as `Refuse` (convicting the silent worker) and latch
/// the worker's fault flag for revocation by the event loop.
fn resolve(task: ResolveTask) -> Resolved {
    let ResolveTask { job_id, spec, t0, requeues, revoked, mut bytes, mut requests, mut workers } =
        task;
    let names: Vec<String> = workers.iter().map(|w| w.name.clone()).collect();
    let mut metered: Vec<Metered<&mut PooledWorker>> =
        workers.iter_mut().map(Metered::new).collect();
    let report = run_tournament(spec, &mut metered);
    bytes += metered.iter().map(|m| m.bytes_sent() + m.bytes_received()).sum::<u64>();
    requests += metered.iter().map(|m| m.counters.get("requests")).sum::<u64>();
    drop(metered);
    let outcome = JobOutcome {
        job_id,
        accepted: Some(report.accepted),
        winner: Some(names[report.winner].clone()),
        disputes: report.disputes,
        eliminated: report.eliminated.len(),
        requeues,
        revoked,
        wall: t0.elapsed(),
        bytes,
        requests,
    };
    Resolved { outcome, workers }
}

/// Pop every expired deadline and synthesize a `DeadlineExpired` refusal
/// for tokens still outstanding. Answered tokens were already removed from
/// the map — which is also what dedups this timer against mux-enforced
/// deadlines racing it.
fn fire_expired_deadlines(
    deadlines: &mut BinaryHeap<Reverse<(Instant, u64)>>,
    tokens: &HashMap<u64, Target>,
    events: &mut Vec<Completion>,
) {
    let now = Instant::now();
    while deadlines.peek().is_some_and(|Reverse((d, _))| *d <= now) {
        let Reverse((_, token)) = deadlines.pop().expect("peeked");
        if tokens.contains_key(&token) {
            events.push(Completion {
                token,
                kind: CompletionKind::DeadlineExpired,
                resp: Response::Refuse("deadline expired before the worker answered".into()),
            });
        }
    }
}

/// Resolve a health probe: an unanswered ping (or a latched fault) revokes
/// the lease; a healthy worker returns to the free list.
fn settle_probe(w: PooledWorker, kind: CompletionKind, pool: &WorkerPool) {
    if kind.unresponsive() || w.faulted() {
        pool.revoke(w);
    } else {
        pool.release(vec![w]);
    }
}

/// Run a batch of jobs against the pool with the event-driven core and
/// default tuning: `k` workers per job, per-dispatch deadlines, lease
/// revocation + re-queue, tournaments on a small resolver pool.
///
/// # Panics
/// If `k == 0` or `k > pool.size()`.
pub fn run_service(jobs: Vec<JobSpec>, pool: &WorkerPool, k: usize) -> ServiceReport {
    run_service_with(jobs, pool, ServiceConfig::new(k))
}

/// [`run_service`] with explicit tuning.
///
/// # Panics
/// If `cfg.k == 0` or `cfg.k > pool.size()`.
pub fn run_service_with(
    jobs: Vec<JobSpec>,
    pool: &WorkerPool,
    cfg: ServiceConfig,
) -> ServiceReport {
    let start_size = pool.size();
    assert!(cfg.k >= 1 && cfg.k <= start_size, "k={} vs pool of {start_size}", cfg.k);
    let resolvers = cfg.resolvers.max(1);
    let n_jobs = jobs.len();
    let t_start = Instant::now();

    let mut queue: VecDeque<QueuedJob> = jobs
        .into_iter()
        .enumerate()
        .map(|(i, spec)| QueuedJob {
            job_id: i as u64,
            spec,
            requeues: 0,
            revoked: 0,
            bytes: 0,
            requests: 0,
            t0: None,
        })
        .collect();
    let mut outcomes: Vec<JobOutcome> = Vec::with_capacity(n_jobs);
    // Actor threads spawned for blocking-linked workers (0 for mux pools).
    let mut actor_threads: usize = 0;

    let (comp_tx, comp_rx) = channel::<Completion>();
    let (task_tx, task_rx) = channel::<ResolveTask>();
    let (resolved_tx, resolved_rx) = channel::<Resolved>();
    let task_rx = Arc::new(Mutex::new(task_rx));

    std::thread::scope(|scope| {
        for _ in 0..resolvers {
            let task_rx = Arc::clone(&task_rx);
            let resolved_tx = resolved_tx.clone();
            let comp_tx = comp_tx.clone();
            scope.spawn(move || loop {
                let task = task_rx.lock().unwrap().recv();
                let Ok(task) = task else { break };
                let resolved = resolve(task);
                if resolved_tx.send(resolved).is_err() {
                    break;
                }
                // Nudge the event loop: resolved jobs ride a side channel.
                let _ = comp_tx.send(Completion {
                    token: WAKE_TOKEN,
                    kind: CompletionKind::Answered,
                    resp: Response::Pong,
                });
            });
        }

        // --- event loop state ---
        let mut tokens: HashMap<u64, Target> = HashMap::new();
        let mut active: HashMap<u64, ActiveJob> = HashMap::new();
        let mut probing: HashMap<u64, PooledWorker> = HashMap::new();
        let mut deadlines: BinaryHeap<Reverse<(Instant, u64)>> = BinaryHeap::new();
        let mut next_token: u64 = 1;
        // First sweep fires immediately so even a short run probes its
        // idle workers at least once.
        let mut next_health = cfg.health_check.map(|_| Instant::now());
        let mut events: Vec<Completion> = Vec::new();

        while outcomes.len() < n_jobs {
            // 1. Lease workers for queued jobs while capacity allows.
            while let Some(job) = queue.pop_front() {
                let live = pool.size();
                if live == 0 {
                    outcomes.push(JobOutcome {
                        job_id: job.job_id,
                        accepted: None,
                        winner: None,
                        disputes: 0,
                        eliminated: 0,
                        requeues: job.requeues,
                        revoked: job.revoked,
                        wall: job.t0.map(|t| t.elapsed()).unwrap_or(Duration::ZERO),
                        bytes: job.bytes,
                        requests: job.requests,
                    });
                    continue;
                }
                let k = cfg.k.min(live);
                let Some(mut workers) = pool.try_acquire(k) else {
                    queue.push_front(job);
                    break;
                };
                let t0 = job.t0.unwrap_or_else(Instant::now);
                let deadline = Instant::now() + cfg.dispatch_deadline;
                let mut aj = ActiveJob {
                    spec: job.spec,
                    t0,
                    requeues: job.requeues,
                    revoked: job.revoked,
                    bytes: job.bytes,
                    requests: job.requests,
                    workers: Vec::new(),
                    slots: Vec::new(),
                    outstanding: 0,
                };
                for (slot, w) in workers.iter_mut().enumerate() {
                    actor_threads += usize::from(w.activate());
                    w.reset_fault();
                    w.set_call_deadline(cfg.call_deadline);
                    let token = next_token;
                    next_token += 1;
                    tokens.insert(token, Target::Job { job_id: job.job_id, slot });
                    deadlines.push(Reverse((deadline, token)));
                    let req = Request::Train { spec: job.spec };
                    aj.bytes += req.wire_size() as u64;
                    aj.requests += 1;
                    w.dispatch(token, req, Some(deadline), &comp_tx);
                    aj.slots.push(SlotState::Waiting);
                    aj.outstanding += 1;
                }
                aj.workers = workers;
                active.insert(job.job_id, aj);
            }

            if outcomes.len() >= n_jobs {
                break;
            }

            // 2. Sleep until the next completion, deadline, or health tick.
            let now = Instant::now();
            let mut timeout = Duration::from_millis(50);
            if let Some(Reverse((d, _))) = deadlines.peek() {
                timeout = timeout.min(d.saturating_duration_since(now));
            }
            if let Some(h) = next_health {
                timeout = timeout.min(h.saturating_duration_since(now));
            }
            match comp_rx.recv_timeout(timeout.max(Duration::from_micros(100))) {
                Ok(c) => events.push(c),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break,
            }
            while let Ok(c) = comp_rx.try_recv() {
                events.push(c);
            }

            // 3. Fire expired deadlines for tokens still outstanding.
            fire_expired_deadlines(&mut deadlines, &tokens, &mut events);

            // 4. Advance per-job state machines.
            for c in events.drain(..) {
                if c.token == WAKE_TOKEN {
                    continue;
                }
                let Some(target) = tokens.remove(&c.token) else {
                    continue; // stale: deadline already handled, or late duplicate
                };
                match target {
                    Target::Probe => {
                        let Some(w) = probing.remove(&c.token) else { continue };
                        settle_probe(w, c.kind, pool);
                    }
                    Target::Job { job_id, slot } => {
                        let Some(job) = active.get_mut(&job_id) else { continue };
                        job.slots[slot] = if c.kind.unresponsive() {
                            // Synthesized refusal: nothing crossed the wire.
                            SlotState::Failed
                        } else {
                            job.bytes += c.resp.wire_size() as u64;
                            SlotState::Done(c.resp)
                        };
                        job.outstanding -= 1;
                        if job.outstanding == 0 {
                            let job = active.remove(&job_id).expect("just seen");
                            finish_dispatch(
                                job_id,
                                job,
                                pool,
                                &cfg,
                                &mut queue,
                                &mut outcomes,
                                &task_tx,
                            );
                        }
                    }
                }
            }

            // 5. Collect resolved tournaments; revoke workers that went
            //    silent mid-dispute, release the rest.
            while let Ok(Resolved { mut outcome, workers }) = resolved_rx.try_recv() {
                let mut keep = Vec::new();
                for w in workers {
                    if w.faulted() {
                        outcome.revoked += 1;
                        pool.revoke(w);
                    } else {
                        keep.push(w);
                    }
                }
                pool.release(keep);
                outcomes.push(outcome);
            }

            // 6. Health-check sweep: ping every idle worker.
            let now = Instant::now();
            if next_health.is_some_and(|h| h <= now) {
                for mut w in pool.drain_idle() {
                    actor_threads += usize::from(w.activate());
                    let token = next_token;
                    next_token += 1;
                    let deadline = now + cfg.ping_deadline;
                    w.reset_fault();
                    tokens.insert(token, Target::Probe);
                    deadlines.push(Reverse((deadline, token)));
                    w.dispatch(token, Request::Ping, Some(deadline), &comp_tx);
                    probing.insert(token, w);
                }
                next_health = cfg.health_check.map(|p| now + p);
            }
        }

        // Drain outstanding health probes so every live worker is back in
        // the pool (deterministically) before the report is returned.
        while !probing.is_empty() {
            let now = Instant::now();
            let timeout = deadlines
                .peek()
                .map(|Reverse((d, _))| d.saturating_duration_since(now))
                .unwrap_or(Duration::from_millis(10));
            if let Ok(c) = comp_rx.recv_timeout(timeout.max(Duration::from_millis(1))) {
                events.push(c);
            }
            fire_expired_deadlines(&mut deadlines, &tokens, &mut events);
            for c in events.drain(..) {
                if let Some(Target::Probe) = tokens.remove(&c.token) {
                    if let Some(w) = probing.remove(&c.token) {
                        settle_probe(w, c.kind, pool);
                    }
                }
            }
        }

        drop(task_tx); // resolvers exit once the queue is empty
    });

    // Hand actors their endpoints back so the pool can be torn down with
    // plain blocking calls (`into_workers` + `Shutdown`).
    let mut idle = pool.drain_idle();
    for w in &mut idle {
        w.deactivate();
    }
    if !idle.is_empty() {
        pool.release(idle);
    }

    let mut outcomes = outcomes;
    outcomes.sort_by_key(|o| o.job_id);
    ServiceReport {
        outcomes,
        wall: t_start.elapsed(),
        k: cfg.k,
        workers: start_size,
        revoked: pool.revoked(),
        threads: 1 + resolvers + actor_threads,
    }
}

/// All of a job's dispatches answered (or expired): revoke silent workers
/// and re-queue, hand the claims to a resolver, or report failure.
#[allow(clippy::too_many_arguments)]
fn finish_dispatch(
    job_id: u64,
    job: ActiveJob,
    pool: &WorkerPool,
    cfg: &ServiceConfig,
    queue: &mut VecDeque<QueuedJob>,
    outcomes: &mut Vec<JobOutcome>,
    task_tx: &Sender<ResolveTask>,
) {
    let ActiveJob { spec, t0, requeues, mut revoked, bytes, requests, workers, slots, .. } = job;
    let mut keep: Vec<PooledWorker> = Vec::new();
    let mut any_failed = false;
    let mut commits = 0usize;
    for (w, slot) in workers.into_iter().zip(slots) {
        match slot {
            SlotState::Failed => {
                any_failed = true;
                revoked += 1;
                pool.revoke(w);
            }
            SlotState::Done(resp) => {
                if matches!(resp, Response::Commit(_)) {
                    commits += 1;
                }
                keep.push(w);
            }
            SlotState::Waiting => unreachable!("outstanding == 0"),
        }
    }

    if any_failed {
        // A silent worker compromised this assignment: release the
        // survivors and re-delegate the whole job to a fresh lease.
        pool.release(keep);
        if requeues < cfg.max_requeues && pool.size() > 0 {
            queue.push_back(QueuedJob {
                job_id,
                spec,
                requeues: requeues + 1,
                revoked,
                bytes,
                requests,
                t0: Some(t0),
            });
        } else {
            outcomes.push(JobOutcome {
                job_id,
                accepted: None,
                winner: None,
                disputes: 0,
                eliminated: 0,
                requeues,
                revoked,
                wall: t0.elapsed(),
                bytes,
                requests,
            });
        }
    } else if commits == 0 {
        // Everyone answered, nobody produced a claim: unresolvable.
        let eliminated = keep.len();
        pool.release(keep);
        outcomes.push(JobOutcome {
            job_id,
            accepted: None,
            winner: None,
            disputes: 0,
            eliminated,
            requeues,
            revoked,
            wall: t0.elapsed(),
            bytes,
            requests,
        });
    } else {
        let task =
            ResolveTask { job_id, spec, t0, requeues, revoked, bytes, requests, workers: keep };
        task_tx.send(task).expect("resolver pool alive while jobs outstanding");
    }
}

// ---------------------------------------------------------------------------
// blocking baseline (pre-event-core scheduler, kept for comparison)
// ---------------------------------------------------------------------------

/// Dispatch one job to its leased workers with thread-per-dispatch and
/// resolve it inline — the blocking baseline.
fn run_job_blocking(job_id: u64, spec: JobSpec, workers: &mut [PooledWorker]) -> JobOutcome {
    let t0 = Instant::now();
    let names: Vec<String> = workers.iter().map(|w| w.name.clone()).collect();
    let mut metered: Vec<Metered<&mut PooledWorker>> =
        workers.iter_mut().map(Metered::new).collect();

    // One OS thread per Train dispatch — the cost the event core removes.
    let trained: Vec<bool> = std::thread::scope(|scope| {
        let handles: Vec<_> = metered
            .iter_mut()
            .map(|m| {
                scope.spawn(move || matches!(m.call(Request::Train { spec }), Response::Commit(_)))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap_or(false)).collect()
    });

    if !trained.iter().any(|&ok| ok) {
        let bytes = metered.iter().map(|m| m.bytes_sent() + m.bytes_received()).sum();
        let requests = metered.iter().map(|m| m.counters.get("requests")).sum();
        return JobOutcome {
            job_id,
            accepted: None,
            winner: None,
            disputes: 0,
            eliminated: names.len(),
            requeues: 0,
            revoked: 0,
            wall: t0.elapsed(),
            bytes,
            requests,
        };
    }

    let report = run_tournament(spec, &mut metered);
    let bytes = metered.iter().map(|m| m.bytes_sent() + m.bytes_received()).sum();
    let requests = metered.iter().map(|m| m.counters.get("requests")).sum();
    JobOutcome {
        job_id,
        accepted: Some(report.accepted),
        winner: Some(names[report.winner].clone()),
        disputes: report.disputes,
        eliminated: report.eliminated.len(),
        requeues: 0,
        revoked: 0,
        wall: t0.elapsed(),
        bytes,
        requests,
    }
}

/// The pre-event-core scheduler: `pool.size() / k` lanes drain the queue,
/// each lane blocking on its lease and spawning one thread per Train
/// dispatch. No deadlines, no revocation — a hung worker stalls its lane
/// forever. Kept as the baseline the benches compare the event core
/// against (and as a worked example of the blocking `Endpoint` path).
pub fn run_service_blocking(jobs: Vec<JobSpec>, pool: &WorkerPool, k: usize) -> ServiceReport {
    assert!(k >= 1 && k <= pool.size(), "k={k} vs pool of {}", pool.size());
    let start_size = pool.size();
    let n_jobs = jobs.len();
    let queue: Mutex<VecDeque<(u64, JobSpec)>> =
        Mutex::new(jobs.into_iter().enumerate().map(|(i, s)| (i as u64, s)).collect());
    let outcomes: Mutex<Vec<JobOutcome>> = Mutex::new(Vec::with_capacity(n_jobs));
    let lanes = (start_size / k).clamp(1, n_jobs.max(1));

    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..lanes {
            scope.spawn(|| loop {
                let next = queue.lock().unwrap().pop_front();
                let Some((job_id, spec)) = next else { break };
                let mut lease = pool.acquire(k);
                let outcome = run_job_blocking(job_id, spec, &mut lease);
                pool.release(lease);
                outcomes.lock().unwrap().push(outcome);
            });
        }
    });
    let mut outcomes = outcomes.into_inner().unwrap();
    outcomes.sort_by_key(|o| o.job_id);
    ServiceReport {
        outcomes,
        wall: t0.elapsed(),
        k,
        workers: start_size,
        revoked: pool.revoked(),
        threads: lanes * (1 + k),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Preset;
    use crate::service::worker::{FaultPlan, WorkerHost};
    use crate::verde::trainer::TrainerNode;

    fn jobs(n: u64, steps: u64) -> Vec<JobSpec> {
        (0..n)
            .map(|i| {
                let mut spec = JobSpec::quick(Preset::Mlp, steps);
                spec.data_seed = spec.data_seed.wrapping_add(i * 1047);
                spec
            })
            .collect()
    }

    fn in_process_pool(plans: &[FaultPlan]) -> WorkerPool {
        WorkerPool::new(
            plans
                .iter()
                .enumerate()
                .map(|(i, &plan)| {
                    PooledWorker::new(&format!("w{i}"), WorkerHost::new(&format!("w{i}"), plan))
                })
                .collect(),
        )
    }

    #[test]
    fn all_honest_jobs_resolve_without_disputes() {
        let pool = in_process_pool(&[FaultPlan::Honest, FaultPlan::Honest]);
        let report = run_service(jobs(4, 4), &pool, 2);
        assert_eq!(report.outcomes.len(), 4);
        for o in &report.outcomes {
            assert!(o.accepted.is_some());
            assert_eq!(o.disputes, 0);
            assert_eq!(o.eliminated, 0);
            assert_eq!(o.requeues, 0);
            assert_eq!(o.revoked, 0);
            assert!(o.bytes > 0);
        }
        assert_eq!(report.total_disputes(), 0);
        assert!(report.revoked.is_empty());
        assert!(report.jobs_per_sec() > 0.0);
    }

    #[test]
    fn faulty_worker_is_beaten_on_every_job() {
        let pool = in_process_pool(&[
            FaultPlan::Honest,
            FaultPlan::Tamper { step: Some(2), delta: 0.05 },
        ]);
        let js = jobs(3, 5);
        let expected: Vec<Hash> =
            js.iter().map(|s| TrainerNode::honest("ref", *s).train()).collect();
        let report = run_service(js, &pool, 2);
        for (o, want) in report.outcomes.iter().zip(&expected) {
            assert_eq!(o.accepted, Some(*want), "job {}", o.job_id);
            assert_eq!(o.winner.as_deref(), Some("w0"));
            assert_eq!(o.disputes, 1);
            assert_eq!(o.eliminated, 1);
        }
    }

    #[test]
    fn lanes_run_jobs_concurrently_from_one_queue() {
        // 4 workers, k=2: several jobs in flight at once off one queue; 6
        // jobs must all resolve exactly once and every lease must return.
        let pool = in_process_pool(&[FaultPlan::Honest; 4]);
        let report = run_service(jobs(6, 3), &pool, 2);
        assert_eq!(report.outcomes.len(), 6);
        let ids: Vec<u64> = report.outcomes.iter().map(|o| o.job_id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(pool.idle(), 4, "all leases returned");
        let json = report.to_json();
        assert!(json.contains("\"jobs\":6"), "{json}");
        assert!(json.contains("\"resolved\":6"), "{json}");
        assert!(json.contains("\"requeued\":0"), "{json}");
        assert!(json.contains("\"eliminated\":0"), "{json}");
    }

    #[test]
    fn blocking_baseline_still_resolves_the_batch() {
        let pool = in_process_pool(&[
            FaultPlan::Honest,
            FaultPlan::Honest,
            FaultPlan::WrongData { step: Some(2) },
        ]);
        let report = run_service_blocking(jobs(4, 4), &pool, 3);
        assert_eq!(report.outcomes.len(), 4);
        for o in &report.outcomes {
            assert!(o.accepted.is_some());
            assert_eq!(o.eliminated, 1, "the poisoner is convicted each job");
        }
        assert!(report.threads >= 4, "thread-per-dispatch baseline");
    }

    #[test]
    fn stalled_worker_is_revoked_and_job_requeues() {
        // w2 stalls on its very first request (the Train dispatch): its
        // deadline fires, its lease is revoked, the job re-queues and
        // completes on the two honest survivors.
        let pool = in_process_pool(&[
            FaultPlan::Honest,
            FaultPlan::Honest,
            FaultPlan::Stall { at_request: 1 },
        ]);
        let js = jobs(3, 3);
        let expected: Vec<Hash> =
            js.iter().map(|s| TrainerNode::honest("ref", *s).train()).collect();
        let mut cfg = ServiceConfig::new(2);
        cfg.dispatch_deadline = Duration::from_millis(800);
        let report = run_service_with(js, &pool, cfg);

        assert_eq!(report.outcomes.len(), 3);
        for o in &report.outcomes {
            assert_eq!(o.accepted, Some(expected[o.job_id as usize]), "job {}", o.job_id);
        }
        assert_eq!(report.revoked, vec!["w2".to_string()]);
        assert_eq!(pool.size(), 2, "pool shrank by the revoked worker");
        assert_eq!(pool.idle(), 2, "surviving leases all returned");
        assert_eq!(report.total_requeued(), 1, "exactly one job paid a re-queue");
        let victim: Vec<&JobOutcome> =
            report.outcomes.iter().filter(|o| o.requeues > 0).collect();
        assert_eq!(victim.len(), 1);
        assert_eq!(victim[0].revoked, 1);
        let json = report.to_json();
        assert!(json.contains("\"requeued\":1"), "{json}");
        assert!(json.contains("\"revoked\":1"), "{json}");
    }

    #[test]
    fn health_check_ping_revokes_stalled_idle_worker() {
        // w1 never answers anything. A long dispatch deadline keeps the
        // dispatch path from catching it; the health-check ping must. The
        // single job runs on w0 while w1 idles, gets pinged, misses the
        // ping deadline, and is revoked.
        let pool = in_process_pool(&[
            FaultPlan::Honest,
            FaultPlan::Stall { at_request: 1 },
        ]);
        let mut cfg = ServiceConfig::new(1);
        cfg.dispatch_deadline = Duration::from_secs(60);
        cfg.health_check = Some(Duration::from_millis(1));
        cfg.ping_deadline = Duration::from_millis(120);
        let report = run_service_with(jobs(1, 8), &pool, cfg);

        assert_eq!(report.outcomes.len(), 1);
        assert!(report.outcomes[0].accepted.is_some());
        assert_eq!(report.revoked, vec!["w1".to_string()]);
        assert_eq!(pool.size(), 1);
        assert_eq!(pool.idle(), 1);
    }

    #[test]
    fn exhausted_requeues_report_unresolved_not_hang() {
        // Every worker stalls: each attempt revokes the whole lease, and
        // once the pool is empty the job must be reported unresolved
        // rather than hanging the coordinator.
        let pool = in_process_pool(&[
            FaultPlan::Stall { at_request: 1 },
            FaultPlan::Stall { at_request: 1 },
        ]);
        let mut cfg = ServiceConfig::new(2);
        cfg.dispatch_deadline = Duration::from_millis(200);
        cfg.max_requeues = 4;
        let report = run_service_with(jobs(1, 3), &pool, cfg);
        assert_eq!(report.outcomes.len(), 1);
        assert!(report.outcomes[0].accepted.is_none());
        assert_eq!(report.outcomes[0].revoked, 2, "both stallers revoked");
        assert_eq!(pool.size(), 0, "nobody left");
        assert_eq!(report.revoked.len(), 2);
    }
}
