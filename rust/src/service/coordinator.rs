//! The delegation coordinator: a job queue drained by scheduler lanes,
//! each lane leasing `k` workers from the pool, dispatching the job to all
//! of them concurrently, and resolving disagreements with a dispute
//! tournament — many jobs in flight at once, with per-job and aggregate
//! throughput/latency/byte metrics.

use std::collections::VecDeque;
use std::fmt::Write as _;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::hash::Hash;
use crate::net::{Endpoint, Metered};
use crate::train::JobSpec;
use crate::verde::protocol::{Request, Response};
use crate::verde::tournament::run_tournament;

use super::pool::{PooledWorker, WorkerPool};

/// Per-job result plus its cost accounting.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub job_id: u64,
    /// The commitment the service vouches for (`None` when no worker even
    /// produced a claim — all assignments failed).
    pub accepted: Option<Hash>,
    /// Name of the worker whose claim was accepted.
    pub winner: Option<String>,
    /// Pairwise disputes the job needed (0 when all claims agree).
    pub disputes: usize,
    /// Workers eliminated as dishonest (or unresponsive).
    pub eliminated: usize,
    /// Wall-clock latency: lease → verdict.
    pub wall: Duration,
    /// Protocol bytes exchanged with this job's workers (both directions,
    /// exact `wire_size` accounting).
    pub bytes: u64,
    /// Protocol requests issued to this job's workers.
    pub requests: u64,
}

/// Aggregate service run report.
#[derive(Debug)]
pub struct ServiceReport {
    /// Outcomes sorted by job id.
    pub outcomes: Vec<JobOutcome>,
    /// Wall time for the whole batch.
    pub wall: Duration,
    /// Workers assigned per job.
    pub k: usize,
    /// Pool size the batch ran against.
    pub workers: usize,
}

impl ServiceReport {
    pub fn jobs_per_sec(&self) -> f64 {
        self.outcomes.len() as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    pub fn total_bytes(&self) -> u64 {
        self.outcomes.iter().map(|o| o.bytes).sum()
    }

    pub fn total_disputes(&self) -> usize {
        self.outcomes.iter().map(|o| o.disputes).sum()
    }

    /// Mean protocol bytes per job.
    pub fn bytes_per_job(&self) -> f64 {
        if self.outcomes.is_empty() {
            0.0
        } else {
            self.total_bytes() as f64 / self.outcomes.len() as f64
        }
    }

    /// Mean job latency (lease → verdict).
    pub fn mean_latency(&self) -> Duration {
        if self.outcomes.is_empty() {
            Duration::ZERO
        } else {
            self.outcomes.iter().map(|o| o.wall).sum::<Duration>() / self.outcomes.len() as u32
        }
    }

    /// One machine-readable JSON object (stable key order).
    pub fn to_json(&self) -> String {
        let resolved = self.outcomes.iter().filter(|o| o.accepted.is_some()).count();
        let mut s = String::from("{");
        let _ = write!(
            s,
            "\"jobs\":{},\"resolved\":{},\"k\":{},\"workers\":{},\"wall_s\":{:.6},\
             \"jobs_per_sec\":{:.3},\"mean_latency_s\":{:.6},\"total_bytes\":{},\
             \"bytes_per_job\":{:.1},\"disputes\":{}",
            self.outcomes.len(),
            resolved,
            self.k,
            self.workers,
            self.wall.as_secs_f64(),
            self.jobs_per_sec(),
            self.mean_latency().as_secs_f64(),
            self.total_bytes(),
            self.bytes_per_job(),
            self.total_disputes(),
        );
        s.push('}');
        s
    }
}

/// Dispatch one job to its leased workers and resolve it.
fn run_job(job_id: u64, spec: JobSpec, workers: &mut [PooledWorker]) -> JobOutcome {
    let t0 = Instant::now();
    // names up front: `metered` mutably borrows every endpoint below
    let names: Vec<String> = workers.iter().map(|w| w.name.clone()).collect();
    let mut metered: Vec<Metered<&mut (dyn Endpoint + Send)>> =
        workers.iter_mut().map(|w| Metered::new(w.endpoint.as_mut())).collect();

    // Assign the job to every worker concurrently — training dominates the
    // job's latency, so serializing here would forfeit the whole point of
    // a k-worker pool.
    let trained: Vec<bool> = std::thread::scope(|scope| {
        let handles: Vec<_> = metered
            .iter_mut()
            .map(|m| scope.spawn(move || matches!(m.call(Request::Train { spec }), Response::Commit(_))))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap_or(false)).collect()
    });

    if !trained.iter().any(|&ok| ok) {
        let bytes = metered.iter().map(|m| m.bytes_sent() + m.bytes_received()).sum();
        let requests = metered.iter().map(|m| m.counters.get("requests")).sum();
        return JobOutcome {
            job_id,
            accepted: None,
            winner: None,
            disputes: 0,
            eliminated: names.len(),
            wall: t0.elapsed(),
            bytes,
            requests,
        };
    }

    // Tournament over the same metered endpoints: workers that failed to
    // train refuse `FinalCommit` and are eliminated up front.
    let report = run_tournament(spec, &mut metered);
    let bytes = metered.iter().map(|m| m.bytes_sent() + m.bytes_received()).sum();
    let requests = metered.iter().map(|m| m.counters.get("requests")).sum();
    JobOutcome {
        job_id,
        accepted: Some(report.accepted),
        winner: Some(names[report.winner].clone()),
        disputes: report.disputes,
        eliminated: report.eliminated.len(),
        wall: t0.elapsed(),
        bytes,
        requests,
    }
}

/// Run a batch of jobs against the pool, `k` workers per job, with
/// `pool.size() / k` scheduler lanes draining the queue concurrently.
///
/// # Panics
/// If `k == 0` or `k > pool.size()`.
pub fn run_service(jobs: Vec<JobSpec>, pool: &WorkerPool, k: usize) -> ServiceReport {
    assert!(k >= 1 && k <= pool.size(), "k={k} vs pool of {}", pool.size());
    let n_jobs = jobs.len();
    let queue: Mutex<VecDeque<(u64, JobSpec)>> = Mutex::new(
        jobs.into_iter().enumerate().map(|(i, s)| (i as u64, s)).collect(),
    );
    let outcomes: Mutex<Vec<JobOutcome>> = Mutex::new(Vec::with_capacity(n_jobs));
    let lanes = (pool.size() / k).clamp(1, n_jobs.max(1));

    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..lanes {
            scope.spawn(|| loop {
                let next = queue.lock().unwrap().pop_front();
                let Some((job_id, spec)) = next else { break };
                let mut lease = pool.acquire(k);
                let outcome = run_job(job_id, spec, &mut lease);
                pool.release(lease);
                outcomes.lock().unwrap().push(outcome);
            });
        }
    });
    let mut outcomes = outcomes.into_inner().unwrap();
    outcomes.sort_by_key(|o| o.job_id);
    ServiceReport { outcomes, wall: t0.elapsed(), k, workers: pool.size() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Preset;
    use crate::service::worker::{FaultPlan, WorkerHost};
    use crate::verde::trainer::TrainerNode;

    fn jobs(n: u64, steps: u64) -> Vec<JobSpec> {
        (0..n)
            .map(|i| {
                let mut spec = JobSpec::quick(Preset::Mlp, steps);
                spec.data_seed = spec.data_seed.wrapping_add(i * 1047);
                spec
            })
            .collect()
    }

    fn in_process_pool(plans: &[FaultPlan]) -> WorkerPool {
        WorkerPool::new(
            plans
                .iter()
                .enumerate()
                .map(|(i, &plan)| {
                    PooledWorker::new(&format!("w{i}"), WorkerHost::new(&format!("w{i}"), plan))
                })
                .collect(),
        )
    }

    #[test]
    fn all_honest_jobs_resolve_without_disputes() {
        let pool = in_process_pool(&[FaultPlan::Honest, FaultPlan::Honest]);
        let report = run_service(jobs(4, 4), &pool, 2);
        assert_eq!(report.outcomes.len(), 4);
        for o in &report.outcomes {
            assert!(o.accepted.is_some());
            assert_eq!(o.disputes, 0);
            assert_eq!(o.eliminated, 0);
            assert!(o.bytes > 0);
        }
        assert_eq!(report.total_disputes(), 0);
        assert!(report.jobs_per_sec() > 0.0);
    }

    #[test]
    fn faulty_worker_is_beaten_on_every_job() {
        let pool = in_process_pool(&[
            FaultPlan::Honest,
            FaultPlan::Tamper { step: Some(2), delta: 0.05 },
        ]);
        let js = jobs(3, 5);
        let expected: Vec<Hash> =
            js.iter().map(|s| TrainerNode::honest("ref", *s).train()).collect();
        let report = run_service(js, &pool, 2);
        for (o, want) in report.outcomes.iter().zip(&expected) {
            assert_eq!(o.accepted, Some(*want), "job {}", o.job_id);
            assert_eq!(o.winner.as_deref(), Some("w0"));
            assert_eq!(o.disputes, 1);
            assert_eq!(o.eliminated, 1);
        }
    }

    #[test]
    fn lanes_run_jobs_concurrently_from_one_queue() {
        // 4 workers, k=2 → 2 lanes; 6 jobs must all resolve exactly once.
        let pool = in_process_pool(&[FaultPlan::Honest; 4]);
        let report = run_service(jobs(6, 3), &pool, 2);
        assert_eq!(report.outcomes.len(), 6);
        let ids: Vec<u64> = report.outcomes.iter().map(|o| o.job_id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(pool.idle(), 4, "all leases returned");
        let json = report.to_json();
        assert!(json.contains("\"jobs\":6"), "{json}");
        assert!(json.contains("\"resolved\":6"), "{json}");
    }
}
