//! The delegation coordinator: a **persistent event-driven core** that
//! per-job client handles ([`crate::service::client`]) submit into. One
//! event-loop thread drives per-*segment* state machines off a completion
//! queue, so the number of coordinator threads is fixed (`1` event loop +
//! a small tournament-resolver pool) no matter how many workers are in
//! flight — thousands of multiplexed TCP workers fit in a handful of
//! threads.
//!
//! Jobs are sharded into **checkpoint-delimited segments** (shard edges
//! from the Phase-1 [`split_points`] schedule, carried by
//! [`JobPolicy::segments`]): segment `i` is the prefix job
//! `spec.prefix(boundary_i)`, so its honest verdict is the full job's
//! checkpoint commitment at that boundary, and the final segment's verdict
//! is exactly the unsharded job's commitment. Segments schedule
//! independently — different worker subsets, concurrently when capacity
//! allows — and their verdicts roll up into one [`JobOutcome`].
//!
//! Segment lifecycle:
//!
//! ```text
//!   Queued ──lease k workers──▶ Dispatching ──all slots answered──▶ Resolving ──▶ Done
//!     ▲                            │                                  (tournament on a
//!     │     deadline expired /     │                                   resolver thread)
//!     └── segment re-queued ◀── lease suspended/revoked
//! ```
//!
//! * **Scheduling** — queued segments order by [`JobPolicy::priority`]
//!   (higher first, FIFO among equals) and lease only workers admitted by
//!   the job's [`BackendRequirement`](crate::verde::protocol::BackendRequirement).
//! * **Suspension & re-admission** — a worker that misses its deadline is
//!   *suspended* with exponential backoff ([`ServiceConfig::readmit_backoff`]):
//!   once the backoff elapses it is probed with a ping and re-admitted if
//!   it answers, or suspended again (doubled backoff) until
//!   [`ServiceConfig::max_strikes`] expels it permanently. With
//!   `readmit_backoff: None` every miss is a permanent revocation.
//! * **Cancellation** — [`JobHandle::cancel`](crate::service::client::JobHandle::cancel)
//!   drops queued segments and finalizes the handle immediately;
//!   in-flight leases *drain* back to the pool as their dispatches settle
//!   (deadline-bounded), so the next lease never lands on a worker still
//!   crunching cancelled work, and the cancelled job's late answers are
//!   discarded.
//!
//! The batch entry points survive as thin compatibility wrappers:
//! [`run_service`] / [`run_service_with`] start a [`Delegation`], submit
//! every job, wait, and return the final [`ServiceReport`]. The
//! pre-event-core scheduler is still [`run_service_blocking`] — the
//! thread-per-dispatch baseline the benches compare against.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet, VecDeque};
use std::fmt::Write as _;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::hash::Hash;
use crate::net::mux::{Completion, CompletionKind};
use crate::net::{Endpoint, Metered};
use crate::obs::{Counter, Gauge, Histogram, Registry, Stage, COUNT_BOUNDS, LATENCY_US_BOUNDS};
use crate::train::checkpoint::{chunk_count, chunk_slice, split_points, verify_encoded_state};
use crate::train::JobSpec;
use crate::verde::protocol::{JobPolicy, Request, Response};
use crate::verde::tournament::run_tournament;
use crate::verde::wire::MAX_CHECKPOINT_CHUNKS;

use super::audit::{AuditSampler, StakeEntry, StakeLedger};
use super::client::{Delegation, JobCell, JobRequest};
use super::journal::{Journal, JournalEntry, RecoveredStake};
use super::pool::{PooledWorker, WorkerPool};
use super::transfer::{CheckpointCache, ChunkManifest, ChunkStream, Pop, SeedPayload};

/// Tuning knobs for the event-driven service core.
#[derive(Debug, Clone, Copy)]
pub struct ServiceConfig {
    /// Workers leased per segment (per-job [`JobPolicy::k`] overrides).
    pub k: usize,
    /// Deadline for each `Train` dispatch; expiry suspends/revokes the
    /// silent worker's lease and re-queues the segment
    /// ([`JobPolicy::deadline`] overrides).
    pub dispatch_deadline: Duration,
    /// Deadline for each blocking dispute/tournament request.
    pub call_deadline: Duration,
    /// How many times a segment may be re-queued after lease revocations
    /// before it is reported unresolved ([`JobPolicy::max_requeues`]
    /// overrides).
    pub max_requeues: u32,
    /// Tournament resolver threads. Coordinator threads total
    /// `1 + resolvers` (plus the global mux driver when multiplexed
    /// transport is used).
    pub resolvers: usize,
    /// Ping idle workers this often; a missed ping suspends/revokes the
    /// lease. `None` disables health checks.
    pub health_check: Option<Duration>,
    /// Deadline for health-check and parole pings.
    pub ping_deadline: Duration,
    /// Base backoff for re-admitting workers that missed a deadline: the
    /// n-th strike suspends for `readmit_backoff × 2^(n−1)`, and a parole
    /// ping afterwards decides between re-admission and another round.
    /// `None` (the default) keeps the legacy behavior: every miss is a
    /// permanent revocation.
    pub readmit_backoff: Option<Duration>,
    /// Missed deadlines (dispatch, ping, or parole) after which a worker
    /// is permanently expelled instead of suspended again.
    pub max_strikes: u32,
    /// Seed of the deterministic audit sampler: which committed segments
    /// of an optimistic job get replay-audited is a keyed hash of
    /// `(audit_seed, job_id, seg_idx)`, so tests (and post-mortems) can
    /// reproduce every sampling decision exactly.
    pub audit_seed: u64,
    /// Stake deposited for each worker at its first optimistic lease.
    /// Locked while a sampled audit (or its escalation) is in flight and
    /// slashed on conviction; a slashed-out worker loses optimistic
    /// eligibility.
    pub worker_stake: u64,
    /// Upper bound on the encoded size of any state the coordinator will
    /// relay between segments. A winning group whose certified manifest
    /// advertises more than this is treated as refusing state transfer:
    /// the successor falls back to an unseeded prefix run and the refusal
    /// is visible in the segment outcome (no silent truncation).
    pub max_checkpoint_bytes: u64,
    /// Byte budget of the content-addressed checkpoint cache keyed by
    /// certified state root. Repeat seeds for the same `(root, boundary)`
    /// are served from memory instead of re-fetched; `0` disables caching.
    pub ckpt_cache_bytes: u64,
    /// Streaming seed window: how many verified chunks may sit between
    /// the fetch producer and the slowest consumer worker before the
    /// pipeline applies backpressure. Peak coordinator memory for a
    /// relay is `~window × 1 MiB` instead of the whole checkpoint.
    pub stream_window: usize,
}

impl ServiceConfig {
    pub fn new(k: usize) -> ServiceConfig {
        ServiceConfig {
            k,
            dispatch_deadline: Duration::from_secs(600),
            call_deadline: Duration::from_secs(60),
            max_requeues: 3,
            resolvers: 4,
            health_check: None,
            ping_deadline: Duration::from_secs(5),
            readmit_backoff: None,
            max_strikes: 3,
            audit_seed: 0,
            worker_stake: 1_000,
            max_checkpoint_bytes: 1 << 30,
            ckpt_cache_bytes: 64 << 20,
            stream_window: 4,
        }
    }
}

/// Verdict and accounting for one checkpoint segment of a job.
#[derive(Debug, Clone, PartialEq)]
pub struct SegmentOutcome {
    /// Segment index within its job (0-based).
    pub seg: usize,
    /// Step range `(start, end]` this segment certifies; `end` is a
    /// Phase-1 `split_points` boundary and the accepted hash is the job's
    /// checkpoint commitment there.
    pub start: u64,
    pub end: u64,
    /// The commitment accepted for this boundary (`None` when unresolved).
    pub accepted: Option<Hash>,
    /// Name of the worker whose claim was accepted.
    pub winner: Option<String>,
    /// Names of the workers in the final (resolving) lease.
    pub workers: Vec<String>,
    /// Pairwise disputes this segment needed.
    pub disputes: usize,
    /// Workers eliminated as dishonest by the segment's tournament.
    pub eliminated: usize,
    /// Times this segment was re-queued after lease revocations.
    pub requeues: u32,
    /// Worker leases suspended/revoked across this segment's attempts.
    pub revoked: usize,
    /// Wall-clock latency: segment's first lease → verdict.
    pub wall: Duration,
    /// Protocol bytes exchanged with this segment's workers.
    pub bytes: u64,
    /// Protocol requests issued to this segment's workers.
    pub requests: u64,
    /// Global lease sequence number of the segment's first lease — a
    /// deterministic record of scheduling order (priority tests and
    /// post-mortems read this instead of racing wall clocks).
    pub leased_seq: u64,
    /// Boundary this segment's final lease was seeded from (`None` when it
    /// re-trained the whole prefix `[0, end]`).
    pub seeded_from: Option<u64>,
    /// Training steps each worker in the final lease executed for this
    /// segment: `end − seeded_from` when seeded, `end` when prefix — the
    /// observable speedup of verified state-transfer.
    pub steps_trained: u64,
    /// Checkpoint-transfer bytes moved while fetching this segment's
    /// verified state for its successor (0 when no fetch ran).
    pub transfer_bytes: u64,
    /// Checkpoint uploads from this segment's winners that failed Merkle
    /// verification against the agreed state root (each cost the uploader
    /// its lease; the fetch moved on to a survivor).
    pub uploads_rejected: u32,
    /// Optimistic tier: this segment's commitment was sampled for a replay
    /// audit.
    pub audit_sampled: bool,
    /// The sampled replay reproduced the commitment (segment settled
    /// without escalation).
    pub audit_passed: bool,
    /// The sampled replay diverged (or could not run) and the segment was
    /// escalated into a k-replicated dispute tournament.
    pub audit_escalated: bool,
    /// Extra training steps the audit tier spent on this segment beyond
    /// the settling lease: the optimistic attempt (when escalated) plus
    /// every completed replay.
    pub audit_steps: u64,
    /// Stake confiscated from the committed worker when the escalation
    /// tournament certified a different verdict than it committed to.
    pub slashed: u64,
}

impl SegmentOutcome {
    /// A settled-unresolved verdict (no claim accepted, all accounting
    /// zeroed); call sites fill in the counters they have via struct
    /// update. `start` is patched by the recording step from the job's
    /// boundary table.
    fn unresolved(seg: usize, end: u64) -> SegmentOutcome {
        SegmentOutcome {
            seg,
            start: 0,
            end,
            accepted: None,
            winner: None,
            workers: Vec::new(),
            disputes: 0,
            eliminated: 0,
            requeues: 0,
            revoked: 0,
            wall: Duration::ZERO,
            bytes: 0,
            requests: 0,
            leased_seq: 0,
            seeded_from: None,
            steps_trained: 0,
            transfer_bytes: 0,
            uploads_rejected: 0,
            audit_sampled: false,
            audit_passed: false,
            audit_escalated: false,
            audit_steps: 0,
            slashed: 0,
        }
    }
}

/// Per-job result plus its cost accounting, rolled up over the job's
/// checkpoint segments.
#[derive(Debug, Clone, PartialEq)]
pub struct JobOutcome {
    pub job_id: u64,
    /// The commitment the service vouches for: the final segment's
    /// verdict, provided *every* segment resolved (`None` otherwise, and
    /// always `None` for cancelled jobs).
    pub accepted: Option<Hash>,
    /// Name of the worker whose final-segment claim was accepted.
    pub winner: Option<String>,
    /// True when the job was ended by `JobHandle::cancel`.
    pub cancelled: bool,
    /// Pairwise disputes across all segments (0 when all claims agree).
    pub disputes: usize,
    /// Workers eliminated as dishonest across all segments.
    pub eliminated: usize,
    /// Segment re-queues after lease revocations, summed.
    pub requeues: u32,
    /// Worker leases suspended/revoked across all attempts (deadline
    /// misses and transport deaths).
    pub revoked: usize,
    /// Wall-clock latency: first lease of any segment → verdict.
    pub wall: Duration,
    /// Protocol bytes exchanged with this job's workers (both directions,
    /// exact `wire_size` accounting, all attempts included).
    pub bytes: u64,
    /// Protocol requests issued to this job's workers.
    pub requests: u64,
    /// Per-segment verdicts in segment order (settled segments only for
    /// cancelled jobs).
    pub segments: Vec<SegmentOutcome>,
}

impl JobOutcome {
    /// A terminal outcome for a job that never produced any verdict
    /// (cancelled before finishing, or submitted to a dead service).
    pub(crate) fn cancelled_stub(job_id: u64) -> JobOutcome {
        JobOutcome {
            job_id,
            accepted: None,
            winner: None,
            cancelled: true,
            disputes: 0,
            eliminated: 0,
            requeues: 0,
            revoked: 0,
            wall: Duration::ZERO,
            bytes: 0,
            requests: 0,
            segments: Vec::new(),
        }
    }
}

/// Aggregate service run report.
#[derive(Debug)]
pub struct ServiceReport {
    /// Outcomes sorted by job id.
    pub outcomes: Vec<JobOutcome>,
    /// Wall time for the whole run (delegation start → finish).
    pub wall: Duration,
    /// Default workers assigned per segment.
    pub k: usize,
    /// Pool size the run started with.
    pub workers: usize,
    /// Names of workers whose leases were suspended or revoked during the
    /// run, in event order.
    pub revoked: Vec<String>,
    /// Coordinator-side threads the run used. Event core: event loop +
    /// resolvers + one actor thread per blocking-linked worker it had to
    /// activate (mux-linked workers need none — that is the scaling
    /// argument). Blocking baseline: lanes × (1 + k) at peak.
    pub threads: usize,
    /// Final stake ledger: one entry per worker that ever took an
    /// optimistic lease (empty when no job used the audit tier).
    pub stakes: Vec<StakeEntry>,
    /// Dispatches the mux refused because a connection's bounded write
    /// buffer was full (slow-consumer stalls surfaced instead of letting
    /// one laggard worker grow coordinator memory without bound).
    pub overloads: u64,
    /// Seeds served from the content-addressed checkpoint cache instead
    /// of re-fetched from a winning group.
    pub ckpt_cache_hits: u64,
    /// Seed lookups that missed the checkpoint cache and paid a fetch.
    pub ckpt_cache_misses: u64,
}

impl ServiceReport {
    /// Jobs per wall-clock second; `0.0` for an empty report (a
    /// just-started or idle service must never report NaN).
    pub fn jobs_per_sec(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        self.outcomes.len() as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    pub fn total_bytes(&self) -> u64 {
        self.outcomes.iter().map(|o| o.bytes).sum()
    }

    pub fn total_disputes(&self) -> usize {
        self.outcomes.iter().map(|o| o.disputes).sum()
    }

    /// Workers eliminated as dishonest across all tournaments.
    pub fn total_eliminated(&self) -> usize {
        self.outcomes.iter().map(|o| o.eliminated).sum()
    }

    /// Segment re-queues forced by lease revocations.
    pub fn total_requeued(&self) -> u64 {
        self.outcomes.iter().map(|o| u64::from(o.requeues)).sum()
    }

    /// Jobs ended by cancellation.
    pub fn total_cancelled(&self) -> usize {
        self.outcomes.iter().filter(|o| o.cancelled).count()
    }

    /// Checkpoint-transfer bytes moved across all segment fetch+verify
    /// phases.
    pub fn total_transfer_bytes(&self) -> u64 {
        self.outcomes
            .iter()
            .flat_map(|o| &o.segments)
            .map(|s| s.transfer_bytes)
            .sum()
    }

    /// Segments whose final lease was seeded with a verified checkpoint
    /// (they trained `end − start` steps instead of the whole prefix).
    pub fn total_seeded_segments(&self) -> usize {
        self.outcomes
            .iter()
            .flat_map(|o| &o.segments)
            .filter(|s| s.seeded_from.is_some())
            .count()
    }

    /// Checkpoint uploads rejected by Merkle verification across the run.
    pub fn total_uploads_rejected(&self) -> u64 {
        self.outcomes
            .iter()
            .flat_map(|o| &o.segments)
            .map(|s| u64::from(s.uploads_rejected))
            .sum()
    }

    /// Training steps actually executed per worker lease, summed over all
    /// settled segments (`k` workers per segment each train
    /// `steps_trained`). With state transfer this is `k × steps` per job;
    /// prefix re-training pays `k × Σ b_i`.
    pub fn total_steps_trained(&self) -> u64 {
        self.outcomes
            .iter()
            .flat_map(|o| &o.segments)
            .map(|s| s.steps_trained * s.workers.len().max(1) as u64)
            .sum()
    }

    /// Segment commitments sampled for a replay audit.
    pub fn total_audit_sampled(&self) -> usize {
        self.outcomes
            .iter()
            .flat_map(|o| &o.segments)
            .filter(|s| s.audit_sampled)
            .count()
    }

    /// Sampled audits whose replay reproduced the commitment.
    pub fn total_audit_passed(&self) -> usize {
        self.outcomes
            .iter()
            .flat_map(|o| &o.segments)
            .filter(|s| s.audit_passed)
            .count()
    }

    /// Sampled audits that escalated into a dispute tournament.
    pub fn total_audit_escalated(&self) -> usize {
        self.outcomes
            .iter()
            .flat_map(|o| &o.segments)
            .filter(|s| s.audit_escalated)
            .count()
    }

    /// Extra training steps the audit tier spent (replays plus escalated
    /// optimistic attempts) on top of [`total_steps_trained`](Self::total_steps_trained).
    pub fn total_audit_steps(&self) -> u64 {
        self.outcomes.iter().flat_map(|o| &o.segments).map(|s| s.audit_steps).sum()
    }

    /// Stake confiscated by convictions across the run. Equals the sum of
    /// `slashed` over the final [`stakes`](Self::stakes) ledger: every
    /// slash is attributed to exactly one settling segment.
    pub fn total_slashed(&self) -> u64 {
        self.outcomes.iter().flat_map(|o| &o.segments).map(|s| s.slashed).sum()
    }

    /// Mean protocol bytes per job; `0.0` for an empty report.
    pub fn bytes_per_job(&self) -> f64 {
        if self.outcomes.is_empty() {
            0.0
        } else {
            self.total_bytes() as f64 / self.outcomes.len() as f64
        }
    }

    /// Mean job latency (first lease → verdict); zero for an empty report.
    pub fn mean_latency(&self) -> Duration {
        if self.outcomes.is_empty() {
            Duration::ZERO
        } else {
            self.outcomes.iter().map(|o| o.wall).sum::<Duration>() / self.outcomes.len() as u32
        }
    }

    /// One machine-readable JSON object (stable key order).
    pub fn to_json(&self) -> String {
        let resolved = self.outcomes.iter().filter(|o| o.accepted.is_some()).count();
        let mut s = String::from("{");
        let _ = write!(
            s,
            "\"jobs\":{},\"resolved\":{},\"cancelled\":{},\"k\":{},\"workers\":{},\
             \"wall_s\":{:.6},\"jobs_per_sec\":{:.3},\"mean_latency_s\":{:.6},\
             \"total_bytes\":{},\"bytes_per_job\":{:.1},\"disputes\":{},\"eliminated\":{},\
             \"requeued\":{},\"revoked\":{},\"threads\":{},\"steps_trained\":{},\
             \"seeded_segments\":{},\"transfer_bytes\":{},\"uploads_rejected\":{},\
             \"audit_sampled\":{},\"audit_passed\":{},\"audit_escalated\":{},\
             \"audit_steps\":{},\"stake_slashed\":{},\"overloads\":{},\
             \"ckpt_cache_hits\":{},\"ckpt_cache_misses\":{}",
            self.outcomes.len(),
            resolved,
            self.total_cancelled(),
            self.k,
            self.workers,
            self.wall.as_secs_f64(),
            self.jobs_per_sec(),
            self.mean_latency().as_secs_f64(),
            self.total_bytes(),
            self.bytes_per_job(),
            self.total_disputes(),
            self.total_eliminated(),
            self.total_requeued(),
            self.revoked.len(),
            self.threads,
            self.total_steps_trained(),
            self.total_seeded_segments(),
            self.total_transfer_bytes(),
            self.total_uploads_rejected(),
            self.total_audit_sampled(),
            self.total_audit_passed(),
            self.total_audit_escalated(),
            self.total_audit_steps(),
            self.total_slashed(),
            self.overloads,
            self.ckpt_cache_hits,
            self.ckpt_cache_misses,
        );
        s.push('}');
        s
    }
}

// ---------------------------------------------------------------------------
// event-driven core
// ---------------------------------------------------------------------------

/// Wake-only completion token (resolver/client → event loop nudge).
pub(crate) const WAKE_TOKEN: u64 = u64::MAX;

pub(crate) fn wake() -> Completion {
    Completion { token: WAKE_TOKEN, kind: CompletionKind::Answered, resp: Response::Pong }
}

/// Client → event loop commands (submissions ride a channel; a
/// [`wake`] completion follows each send so the loop reacts promptly).
pub(crate) enum Cmd {
    Submit { job_id: u64, spec: JobSpec, policy: JobPolicy, cell: Arc<JobCell> },
    /// A journal-recovered job: like `Submit`, but `settled` segments are
    /// trusted from the log (pre-filled, never re-trained) and the entry
    /// is *not* re-journaled — its `Submit` record from the previous
    /// process generation is already durable.
    Recover {
        job_id: u64,
        spec: JobSpec,
        policy: JobPolicy,
        cell: Arc<JobCell>,
        settled: Vec<SegmentOutcome>,
    },
    Cancel { job_id: u64, reply: Sender<bool> },
    Shutdown,
}

/// What the event loop hands back when it exits.
pub(crate) struct LoopReport {
    pub(crate) outcomes: Vec<JobOutcome>,
    pub(crate) actor_threads: usize,
    pub(crate) stakes: Vec<StakeEntry>,
    pub(crate) overloads: u64,
    pub(crate) ckpt_cache_hits: u64,
    pub(crate) ckpt_cache_misses: u64,
}

/// Where a segment's seed state comes from.
///
/// `Buffered` is the legacy shape (whole verified checkpoint in memory,
/// shared by `Arc`); `Stream` is the pipelined shape — the successor's
/// dispatch consumes verified chunks from a [`ChunkStream`] as the
/// resolver-side producer fetches them, so the coordinator never holds
/// more than the in-flight window of a large state.
#[derive(Clone)]
enum SeedSource {
    /// Prefix re-training: no state relayed.
    None,
    /// Whole checkpoint already in memory (cache hit, audit park, or a
    /// commitment-bound optimistic fetch).
    Buffered(Arc<SeedPayload>),
    /// Chunks arrive from the producer while this segment leases and
    /// dispatches; backpressure starts once the dispatch attaches.
    Stream(Arc<ChunkStream>),
}

impl SeedSource {
    fn is_none(&self) -> bool {
        matches!(self, SeedSource::None)
    }

    /// Boundary the seed starts the lease at (`None` = prefix run).
    fn seeded_from(&self) -> Option<u64> {
        match self {
            SeedSource::None => None,
            SeedSource::Buffered(p) => Some(p.start),
            SeedSource::Stream(s) => Some(s.manifest().step),
        }
    }

    /// Collapse to the buffered payload, aborting (and discarding) a
    /// stream — used on paths that can only make use of an in-memory
    /// seed (audit parking, fallback re-queues).
    fn into_buffered(self) -> Option<Arc<SeedPayload>> {
        match self {
            SeedSource::None => None,
            SeedSource::Buffered(p) => Some(p),
            SeedSource::Stream(s) => {
                s.abort();
                None
            }
        }
    }

    /// Tell a producer to stop without consuming the source.
    fn abort_if_stream(&self) {
        if let SeedSource::Stream(s) = self {
            s.abort();
        }
    }

    /// Seed for a re-queued lease: a buffered seed is still good (only
    /// the lease failed); a stream is single-shot — abort it and fall
    /// back to prefix re-training.
    fn for_requeue(self) -> SeedSource {
        match self {
            SeedSource::Stream(s) => {
                s.abort();
                SeedSource::None
            }
            other => other,
        }
    }
}

/// Per-segment state of a streaming seed dispatch: the event loop pumps
/// verified chunks out of `stream` to every live slot, keeping at most
/// `window` chunks ahead of the slowest slot's acknowledgements.
struct StreamPump {
    stream: Arc<ChunkStream>,
    /// Next chunk index to dispatch (same to every slot).
    next_chunk: u64,
    /// Per-slot count of acknowledged chunks.
    acked: Vec<u64>,
    /// Dispatch deadline shared by every chunk token of this lease.
    deadline: Instant,
    window: u64,
}

/// What a queued (or active) segment is for.
enum SegKind {
    /// Regular training work: k-replicated, or an optimistic job's
    /// single-staked-worker lease.
    Work,
    /// Sampled replay of an optimistic segment's commitment on one worker
    /// other than `accused`; its training commit is compared against
    /// `expect` when the dispatch settles.
    Audit { accused: String, expect: Hash },
}

/// Audit bookkeeping for one sampled segment of an optimistic job.
enum AuditState {
    /// The replay is queued or in flight; the optimistic attempt's outcome
    /// (and the verified successor seed it fetched) is parked here until
    /// the replay answers.
    Pending {
        outcome: Box<SegmentOutcome>,
        /// Verified end-state fetched alongside the optimistic attempt —
        /// released to seed the successor only once the audit passes.
        seed_next: Option<Arc<SeedPayload>>,
        /// The staked worker whose commitment is under audit.
        accused: String,
        /// Its committed hash for this boundary.
        expect: Hash,
    },
    /// The replay diverged (or could not run): the segment re-runs as a
    /// k-replicated prefix tournament. A certified verdict different from
    /// `expect` convicts `accused` (when the divergence was attributable)
    /// and slashes its stake at settlement.
    Escalated { accused: Option<String>, expect: Hash, audit_steps: u64 },
}

/// A segment waiting for a lease.
struct QueuedSeg {
    kind: SegKind,
    priority: i64,
    job_id: u64,
    seg_idx: usize,
    /// Prefix spec: `steps` is this segment's end boundary.
    spec: JobSpec,
    /// Seed state for the lease (`None` = prefix re-training). A buffered
    /// seed is kept across re-queues caused by worker failure; a stream
    /// is single-shot (its producer aborts on failure) and a seeded lease
    /// that *disagreed* falls back to prefix.
    seed: SeedSource,
    requeues: u32,
    revoked: usize,
    bytes: u64,
    requests: u64,
    /// First-lease instant of this segment, kept across re-queues.
    t0: Option<Instant>,
    leased_seq: u64,
}

impl PartialEq for QueuedSeg {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for QueuedSeg {}
impl PartialOrd for QueuedSeg {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QueuedSeg {
    /// Max-heap order: higher priority first, then FIFO by job id, then
    /// segment order.
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.priority
            .cmp(&other.priority)
            .then_with(|| other.job_id.cmp(&self.job_id))
            .then_with(|| other.seg_idx.cmp(&self.seg_idx))
    }
}

enum SlotState {
    Waiting,
    Done(Response),
    /// Deadline expired or transport died — the worker gets disciplined.
    Failed,
}

/// A segment whose `Train` (or chunked `SeedCheckpoint`) dispatches are in
/// flight.
struct ActiveSeg {
    kind: SegKind,
    spec: JobSpec,
    seed: SeedSource,
    t0: Instant,
    requeues: u32,
    revoked: usize,
    bytes: u64,
    requests: u64,
    workers: Vec<PooledWorker>,
    slots: Vec<SlotState>,
    tokens: Vec<u64>,
    outstanding: usize,
    leased_seq: u64,
    /// Present while a streaming seed is still being pumped to the slots.
    pump: Option<StreamPump>,
}

/// A settled audit dispatch, bundled for [`EventLoop::finish_audit`]
/// (the borrow of the active table is over by then; everything the
/// verdict logic needs travels by value).
struct AuditReturn {
    job_id: u64,
    seg_idx: usize,
    accused: String,
    expect: Hash,
    spec: JobSpec,
    seed: SeedSource,
    t0: Instant,
    requeues: u32,
    revoked: usize,
    bytes: u64,
    requests: u64,
    leased_seq: u64,
    workers: Vec<PooledWorker>,
    slots: Vec<SlotState>,
}

/// What a completion token addresses.
enum Target {
    Seg { job_id: u64, seg_idx: usize, slot: usize },
    /// Intermediate seed-chunk acknowledgement: accounted (and, for a
    /// streaming seed, advances the slot's pump window), never decides
    /// the slot (the final chunk's token does; a stalled worker misses
    /// that token's deadline).
    Ack { job_id: u64, seg_idx: usize, slot: usize },
    /// Health-check ping of an idle (live) worker.
    Probe,
    /// Parole ping of a suspended worker serving its backoff.
    Parole,
    /// In-flight dispatch of a cancelled job: the worker re-enters the
    /// pool (or is disciplined) when the dispatch settles.
    Drain,
}

/// How a resolver settles a segment.
pub(crate) enum ResolveMode {
    /// Prefix segment: full tournament (disputes available — every worker
    /// holds its whole trajectory).
    Tournament,
    /// Seeded segment whose commits all agreed: the event loop already
    /// established the verdict; the resolver only runs the state fetch.
    /// (Seeded segments that *disagree* never reach a resolver — they fall
    /// back to prefix re-training, where the dispute protocol applies.)
    Agreed { accepted: Hash, winner: usize },
    /// Optimistic single-worker segment: accept the lone claim
    /// provisionally and record it as the worker's commitment. The
    /// resolver additionally asks the worker for its explicit
    /// [`Request::CommitRoot`] state-root commitment and binds any fetched
    /// checkpoint to it; whether the claim gets replay-audited is decided
    /// by the event loop's sampler when the segment comes back.
    Commitment { claimed: Hash },
}

/// Work order for a resolver thread.
pub(crate) struct ResolveTask {
    job_id: u64,
    seg_idx: usize,
    start: u64,
    end: u64,
    spec: JobSpec,
    mode: ResolveMode,
    /// Fetch + verify this segment's end checkpoint for the next segment.
    want_state: bool,
    seeded_from: Option<u64>,
    t0: Instant,
    requeues: u32,
    revoked: usize,
    bytes: u64,
    requests: u64,
    leased_seq: u64,
    workers: Vec<PooledWorker>,
    /// The delegation's registry: resolvers trace fetch/verify span
    /// events through it (recording is a relaxed load when disabled).
    registry: Registry,
    /// Content-addressed checkpoint cache shared with the event loop.
    cache: Arc<CheckpointCache>,
    /// [`ServiceConfig::stream_window`] for any stream this task opens.
    stream_window: usize,
    /// [`ServiceConfig::max_checkpoint_bytes`] decode/relay clamp.
    max_checkpoint_bytes: u64,
}

pub(crate) struct Resolved {
    job_id: u64,
    outcome: SegmentOutcome,
    workers: Vec<PooledWorker>,
    /// Seed for the next segment: a [`SeedSource::Stream`] when a
    /// certified manifest opened a pipelined fetch (the stream-source
    /// workers stay with the producer and return via [`StreamDone`]),
    /// `Buffered` on a cache hit or commitment-bound fetch, `None` when
    /// no fetch was wanted or certification failed (the next segment
    /// then falls back to prefix re-training).
    seed: SeedSource,
    /// Indices into `workers` whose uploads failed Merkle verification —
    /// the event loop revokes their leases.
    rejected: Vec<usize>,
    /// Optimistic segment: `(worker, committed hash)` — the event loop
    /// records it and decides whether to sample a replay audit.
    commitment: Option<(String, Hash)>,
}

/// Producer half of a streaming state transfer, run on the resolver
/// thread after [`Resolved`] is sent: fetch chunks from the retained
/// winning-group sources, verify each against the certified manifest,
/// and push them into the stream the successor's dispatch consumes.
struct Production {
    job_id: u64,
    seg_idx: usize,
    /// Boundary being fetched (`manifest.step`, for cache insertion).
    end: u64,
    /// Settle instant of the producing segment — the parked outcome's
    /// wall clock keeps running until the transfer completes.
    t0: Instant,
    stream: Arc<ChunkStream>,
    /// Winning-group members that answered the manifest probe; they stay
    /// leased to the producer and travel back in [`StreamDone`].
    workers: Vec<PooledWorker>,
    cache: Arc<CheckpointCache>,
    /// Assemble the full state on the side for cache insertion (only
    /// when it fits the cache budget).
    assemble: bool,
}

/// Producer completion: releases the retained source workers and the
/// parked [`SegmentOutcome`] of the segment that streamed its state.
pub(crate) struct StreamDone {
    job_id: u64,
    seg_idx: usize,
    workers: Vec<PooledWorker>,
    /// Indices into `workers` whose chunks failed verification against
    /// the certified manifest — revoked like rejected uploads.
    rejected: Vec<usize>,
    bytes: u64,
    requests: u64,
    transfer_bytes: u64,
    /// High-water mark of bytes buffered in the stream window.
    peak: u64,
    /// Producing segment's total wall (settle + transfer overlap).
    wall: Duration,
}

/// Resolver → event loop messages (one channel carries both).
pub(crate) enum ResolverMsg {
    Resolved(Resolved),
    StreamDone(StreamDone),
}

/// Pull chunks `1..total` of the checkpoint at `step` from one worker,
/// appending to the chunk-0 `bytes` the unanimity probe already received.
/// Errors on refusals or chunk metadata inconsistent with the probe.
fn fetch_remaining_chunks(
    ep: &mut impl Endpoint,
    step: u64,
    root: Hash,
    total: u64,
    mut bytes: Vec<u8>,
) -> Result<Vec<u8>, String> {
    for chunk in 1..total {
        match ep.call(Request::FetchCheckpoint { step, chunk }) {
            Response::Checkpoint { step: s, root: r, total_chunks, chunk: c, payload }
                if s == step && r == root && total_chunks == total && c == chunk =>
            {
                bytes.extend_from_slice(&payload);
            }
            other => return Err(format!("checkpoint fetch failed: {other:?}")),
        }
    }
    Ok(bytes)
}

/// The fetch → verify half of state transfer, run against the workers
/// whose final claim equals the accepted hash (`group`). The state root is
/// certified by **unanimity** over the winning group: under the protocol's
/// standing assumption (≥ 1 honest worker per lease when the accepted
/// claim is honest), a unanimous root is the honest root; disagreement
/// yields no certified root and the caller falls back to prefix
/// re-training. Each member's upload is then Merkle-verified against the
/// certified root until one passes (resuming from the chunk 0 its probe
/// already delivered); members serving bad bytes land in `rejected`.
fn fetch_verified_state(
    metered: &mut [Metered<&mut PooledWorker>],
    group: &[usize],
    end: u64,
) -> (Option<SeedPayload>, Vec<usize>) {
    let mut rejected = Vec::new();
    // Unanimity probe: chunk 0 from every group member carries its claimed
    // root and chunk count. Declared counts are clamped even off-wire —
    // an in-process peer must not be able to drive an unbounded fetch.
    let mut probes: Vec<(usize, Hash, u64, Vec<u8>)> = Vec::new();
    for &i in group {
        match metered[i].call(Request::FetchCheckpoint { step: end, chunk: 0 }) {
            Response::Checkpoint { step, root, total_chunks, chunk: 0, payload }
                if step == end && (1..=MAX_CHECKPOINT_CHUNKS).contains(&total_chunks) =>
            {
                probes.push((i, root, total_chunks, payload));
            }
            _ => {} // refusals just drop the member from the fetch order
        }
    }
    let Some(&(_, root, _, _)) = probes.first() else {
        return (None, rejected);
    };
    if probes.iter().any(|&(_, r, _, _)| r != root) {
        // No certified root: someone in the winning group is lying about
        // the state commitment, but without a second claim to dispute we
        // cannot attribute it. The caller falls back to the safe path.
        return (None, rejected);
    }
    for (i, _, total, first) in probes {
        match fetch_remaining_chunks(&mut metered[i], end, root, total, first) {
            Ok(bytes) if verify_encoded_state(&bytes, end, &root) => {
                return (Some(SeedPayload { start: end, root, bytes }), rejected);
            }
            Ok(_) | Err(_) => rejected.push(i),
        }
    }
    (None, rejected)
}

/// Run the tournament (or accept a seeded segment's agreed verdict) for
/// one segment on a resolver thread, then optionally arrange its end
/// state for the next segment: a cache hit seeds buffered, a certified
/// manifest opens a [`ChunkStream`] whose producer ([`run_producer`],
/// returned as the second element) fetches and verifies chunk-by-chunk,
/// and only the optimistic commitment path still buffers the whole
/// checkpoint (it must bind to the worker's explicit `CommitRoot`). The
/// workers' blocking [`Endpoint`] adapters carry the dispute and
/// transfer traffic; unanswered requests surface as `Refuse` (convicting
/// the silent worker) and latch the worker's fault flag for discipline
/// by the event loop.
fn resolve(task: ResolveTask) -> (Resolved, Option<Production>) {
    let ResolveTask {
        job_id,
        seg_idx,
        start,
        end,
        spec,
        mode,
        want_state,
        seeded_from,
        t0,
        requeues,
        revoked,
        mut bytes,
        mut requests,
        leased_seq,
        mut workers,
        registry,
        cache,
        stream_window,
        max_checkpoint_bytes,
    } = task;
    let names: Vec<String> = workers.iter().map(|w| w.name.clone()).collect();
    let mut metered: Vec<Metered<&mut PooledWorker>> =
        workers.iter_mut().map(Metered::new).collect();
    let mut commitment: Option<(String, Hash)> = None;
    // `Some(answer)` in commitment mode: the worker's explicit CommitRoot
    // reply (`None` inside when it refused). Any fetched checkpoint must
    // verify against exactly this root or the seed is discarded.
    let mut bound_root: Option<Option<Hash>> = None;
    let (accepted, winner, disputes, eliminated) = match mode {
        ResolveMode::Tournament => {
            let report = run_tournament(spec, &mut metered);
            (report.accepted, report.winner, report.disputes, report.eliminated.len())
        }
        ResolveMode::Agreed { accepted, winner } => (accepted, winner, 0, 0),
        ResolveMode::Commitment { claimed } => {
            let root = match metered[0].call(Request::CommitRoot { step: end }) {
                Response::Commit(r) => Some(r),
                _ => None,
            };
            bound_root = Some(root);
            commitment = Some((names[0].clone(), claimed));
            (claimed, 0, 0, 0)
        }
    };

    let mut seed = SeedSource::None;
    let mut rejected = Vec::new();
    let mut transfer_bytes = 0u64;
    let mut opened: Option<(Arc<ChunkStream>, Vec<usize>, bool)> = None;
    if want_state {
        registry.spans().trace(job_id, Some(seg_idx as u64), Stage::Fetch, None);
        // The winning group: everyone whose (cached) final claim equals
        // the accepted hash, winner first so the fetch tries it first.
        let mut group: Vec<usize> = Vec::new();
        for i in (0..metered.len()).map(|o| (winner + o) % metered.len()) {
            if let Response::Commit(h) = metered[i].call(Request::FinalCommit) {
                if h == accepted {
                    group.push(i);
                }
            }
        }
        let before: u64 =
            metered.iter().map(|m| m.bytes_sent() + m.bytes_received()).sum();
        if bound_root.is_some() {
            // Optimistic commitment mode: the fetched state must bind to
            // the worker's explicit `CommitRoot` answer before anything
            // downstream sees it, so this path still buffers the whole
            // checkpoint. The cache short-circuits a repeat fetch of an
            // already-certified root.
            let mut fetched: Option<Arc<SeedPayload>> = None;
            if let Some(Some(r)) = &bound_root {
                fetched = cache.get(r, end);
            }
            if fetched.is_none() {
                let (s, r) = fetch_verified_state(&mut metered, &group, end);
                rejected = r;
                if let Some(p) = s {
                    let p = Arc::new(p);
                    cache.insert(Arc::clone(&p));
                    fetched = Some(p);
                }
            }
            if let (Some(p), Some(r)) = (&fetched, &bound_root) {
                if *r != Some(p.root) {
                    // The worker's explicit commitment refuses, or
                    // contradicts the root its served checkpoint verifies
                    // against: don't seed the successor from it. The
                    // training claim itself is still on the record and
                    // still replay-auditable.
                    fetched = None;
                }
            }
            if let Some(p) = fetched {
                registry.spans().trace(job_id, Some(seg_idx as u64), Stage::Verify, None);
                seed = SeedSource::Buffered(p);
            }
        } else {
            // Streaming path: certify a chunk manifest by unanimity over
            // the winning group, then either hit the cache (no transfer
            // at all) or open a stream — the responding sources stay with
            // the producer and the successor consumes verified chunks as
            // they arrive. A manifest advertising more than the relay
            // clamp is treated as a refusal, which the report surfaces as
            // an unseeded (prefix) successor.
            let mut manifests: Vec<(usize, Hash, u64, Vec<Hash>)> = Vec::new();
            for &i in &group {
                if let Response::Manifest { step, root, total_len, chunks } =
                    metered[i].call(Request::FetchManifest { step: end })
                {
                    if step == end
                        && total_len <= max_checkpoint_bytes
                        && chunks.len() as u64 == chunk_count(total_len as usize)
                        && chunks.len() as u64 <= MAX_CHECKPOINT_CHUNKS
                    {
                        manifests.push((i, root, total_len, chunks));
                    }
                }
            }
            if let Some((_, root, total_len, chunks)) = manifests.first().cloned() {
                let unanimous = manifests
                    .iter()
                    .all(|(_, r, t, c)| *r == root && *t == total_len && *c == chunks);
                if unanimous {
                    if let Some(hit) = cache.get(&root, end) {
                        registry.spans().trace(job_id, Some(seg_idx as u64), Stage::Verify, None);
                        seed = SeedSource::Buffered(hit);
                    } else {
                        // The certified manifest IS the verification
                        // contract: every chunk is checked against it as
                        // it arrives, so the Verify span lands here (one
                        // per certified fetch, exactly like the buffered
                        // path).
                        registry.spans().trace(job_id, Some(seg_idx as u64), Stage::Verify, None);
                        let sources: Vec<usize> = manifests.iter().map(|m| m.0).collect();
                        let assemble = total_len <= cache.budget();
                        let stream = Arc::new(ChunkStream::new(
                            ChunkManifest { step: end, root, total_len, chunks },
                            stream_window,
                        ));
                        seed = SeedSource::Stream(Arc::clone(&stream));
                        opened = Some((stream, sources, assemble));
                    }
                }
            }
        }
        let after: u64 = metered.iter().map(|m| m.bytes_sent() + m.bytes_received()).sum();
        transfer_bytes = after - before;
    }

    bytes += metered.iter().map(|m| m.bytes_sent() + m.bytes_received()).sum::<u64>();
    requests += metered.iter().map(|m| m.counters.get("requests")).sum::<u64>();
    drop(metered);
    // Split the lease: manifest-answering sources stay with the producer
    // (they return via `StreamDone`); everyone else goes home with the
    // verdict.
    let production = match opened {
        Some((stream, sources, assemble)) => {
            let mut slots: Vec<Option<PooledWorker>> = workers.into_iter().map(Some).collect();
            let retained: Vec<PooledWorker> =
                sources.iter().filter_map(|&i| slots[i].take()).collect();
            workers = slots.into_iter().flatten().collect();
            Some(Production {
                job_id,
                seg_idx,
                end,
                t0,
                stream,
                workers: retained,
                cache,
                assemble,
            })
        }
        None => None,
    };
    let outcome = SegmentOutcome {
        seg: seg_idx,
        start,
        end,
        accepted: Some(accepted),
        winner: Some(names[winner].clone()),
        workers: names,
        disputes,
        eliminated,
        requeues,
        revoked,
        wall: t0.elapsed(),
        bytes,
        requests,
        leased_seq,
        seeded_from,
        steps_trained: end - seeded_from.unwrap_or(0),
        transfer_bytes,
        uploads_rejected: rejected.len() as u32,
        audit_sampled: false,
        audit_passed: false,
        audit_escalated: false,
        audit_steps: 0,
        slashed: 0,
    };
    (Resolved { job_id, outcome, workers, seed, rejected, commitment }, production)
}

/// Stream the certified checkpoint at `p.end` chunk-by-chunk from the
/// retained sources into the consumer stream, verifying every chunk
/// against the certified manifest before forwarding it. Runs on the
/// resolver thread that settled the producing segment, *after* its
/// [`Resolved`] was sent — so the successor's lease acquisition overlaps
/// the fetch. A source serving a wrong chunk is marked rejected (its
/// lease is revoked at [`StreamDone`]) and the fetch rotates to the next
/// group member; only when every source has failed does the stream fail
/// (the consumer lease then falls back to prefix re-training).
fn run_producer(p: Production, comp_tx: &Sender<Completion>) -> StreamDone {
    let Production { job_id, seg_idx, end, t0, stream, mut workers, cache, assemble } = p;
    let manifest = stream.manifest().clone();
    let total = stream.total_chunks();
    let mut metered: Vec<Metered<&mut PooledWorker>> =
        workers.iter_mut().map(Metered::new).collect();
    let mut bad: Vec<bool> = vec![false; metered.len()];
    let mut buf: Vec<u8> = Vec::new();
    let mut delivered = true;
    let mut src = 0usize;
    let mut idx = 0u64;
    'fetch: while idx < total {
        // Invariant: at least one source is still good (all-bad breaks out
        // below before the next iteration).
        while bad[src] {
            src = (src + 1) % bad.len();
        }
        match metered[src].call(Request::FetchCheckpoint { step: end, chunk: idx }) {
            Response::Checkpoint { step, root, total_chunks, chunk, payload }
                if step == end
                    && root == manifest.root
                    && total_chunks == total
                    && chunk == idx
                    && Hash::of_bytes(&payload) == manifest.chunks[idx as usize] =>
            {
                if assemble {
                    buf.extend_from_slice(&payload);
                }
                if !stream.push(payload) {
                    // Consumer side aborted (cancellation or lease failure).
                    delivered = false;
                    break 'fetch;
                }
                let _ = comp_tx.send(wake());
                idx += 1;
            }
            _ => {
                bad[src] = true;
                if bad.iter().all(|&b| b) {
                    stream.fail();
                    let _ = comp_tx.send(wake());
                    delivered = false;
                    break 'fetch;
                }
            }
        }
    }
    if delivered {
        stream.close();
        let _ = comp_tx.send(wake());
        // The full state was assembled on the side purely for the cache:
        // a later segment (or job) at the same certified root seeds from
        // memory instead of re-fetching.
        if assemble
            && buf.len() as u64 == manifest.total_len
            && verify_encoded_state(&buf, end, &manifest.root)
        {
            cache.insert(Arc::new(SeedPayload { start: end, root: manifest.root, bytes: buf }));
        }
    }
    let bytes: u64 = metered.iter().map(|m| m.bytes_sent() + m.bytes_received()).sum();
    let requests: u64 = metered.iter().map(|m| m.counters.get("requests")).sum();
    drop(metered);
    let rejected: Vec<usize> =
        bad.iter().enumerate().filter_map(|(i, &b)| b.then_some(i)).collect();
    StreamDone {
        job_id,
        seg_idx,
        workers,
        rejected,
        bytes,
        requests,
        transfer_bytes: bytes,
        peak: stream.peak_buffered(),
        wall: t0.elapsed(),
    }
}

/// Cached handles for the delegation's `coord_*` instruments, registered
/// once at core start so the event loop records through relaxed atomics
/// only. The reconciliation counters (disputes, bytes, transfer, …) are
/// bumped in `record_segment` from the settling [`SegmentOutcome`], which
/// makes their totals equal the final [`ServiceReport`]'s by
/// construction — the e2e stats tests assert exact equality.
pub(crate) struct CoordMetrics {
    pub(crate) registry: Registry,
    jobs_submitted: Counter,
    jobs_resolved: Counter,
    jobs_cancelled: Counter,
    segments_settled: Counter,
    requeues: Counter,
    revoked: Counter,
    disciplined: Counter,
    disputes: Counter,
    eliminated: Counter,
    steps_trained: Counter,
    seeded_segments: Counter,
    transfer_bytes: Counter,
    uploads_rejected: Counter,
    audit_sampled: Counter,
    audit_passed: Counter,
    audit_escalated: Counter,
    audit_steps: Counter,
    stake_slashed: Counter,
    bytes: Counter,
    requests: Counter,
    journal_entries: Counter,
    journal_bytes: Counter,
    journal_syncs: Counter,
    journal_replayed_segments: Counter,
    journal_recovered_jobs: Counter,
    overloads: Counter,
    stream_peak_bytes: Gauge,
    stake_locked: Gauge,
    queue_depth: Gauge,
    active_segments: Gauge,
    resolving: Gauge,
    pool_idle: Gauge,
    pool_suspended: Gauge,
    pool_size: Gauge,
    tick_us: Histogram,
    completions_per_tick: Histogram,
}

impl CoordMetrics {
    fn new(registry: Registry) -> CoordMetrics {
        CoordMetrics {
            jobs_submitted: registry.counter("coord_jobs_submitted"),
            jobs_resolved: registry.counter("coord_jobs_resolved"),
            jobs_cancelled: registry.counter("coord_jobs_cancelled"),
            segments_settled: registry.counter("coord_segments_settled"),
            requeues: registry.counter("coord_requeues"),
            revoked: registry.counter("coord_revoked"),
            disciplined: registry.counter("coord_leases_disciplined"),
            disputes: registry.counter("coord_disputes"),
            eliminated: registry.counter("coord_eliminated"),
            steps_trained: registry.counter("coord_steps_trained"),
            seeded_segments: registry.counter("coord_seeded_segments"),
            transfer_bytes: registry.counter("coord_transfer_bytes"),
            uploads_rejected: registry.counter("coord_uploads_rejected"),
            audit_sampled: registry.counter("coord_audit_sampled"),
            audit_passed: registry.counter("coord_audit_passed"),
            audit_escalated: registry.counter("coord_audit_escalated"),
            audit_steps: registry.counter("coord_audit_steps"),
            stake_slashed: registry.counter("coord_stake_slashed"),
            bytes: registry.counter("coord_bytes"),
            requests: registry.counter("coord_requests"),
            journal_entries: registry.counter("coord_journal_entries"),
            journal_bytes: registry.counter("coord_journal_bytes"),
            journal_syncs: registry.counter("coord_journal_syncs"),
            journal_replayed_segments: registry.counter("coord_journal_replayed_segments"),
            journal_recovered_jobs: registry.counter("coord_journal_recovered_jobs"),
            overloads: registry.counter("coord_overloads"),
            stream_peak_bytes: registry.gauge("coord_stream_peak_bytes"),
            stake_locked: registry.gauge("coord_stake_locked"),
            queue_depth: registry.gauge("coord_queue_depth"),
            active_segments: registry.gauge("coord_active_segments"),
            resolving: registry.gauge("coord_resolving"),
            pool_idle: registry.gauge("coord_pool_idle"),
            pool_suspended: registry.gauge("coord_pool_suspended"),
            pool_size: registry.gauge("coord_pool_size"),
            tick_us: registry.histogram("coord_tick_us", &LATENCY_US_BOUNDS),
            completions_per_tick: registry.histogram("coord_completions_per_tick", &COUNT_BOUNDS),
            registry,
        }
    }

    /// Fold a settling segment's accounting into the reconciliation
    /// counters (called exactly once per settled segment).
    fn observe_settled(&self, outcome: &SegmentOutcome) {
        self.segments_settled.inc();
        self.disputes.add(outcome.disputes as u64);
        self.eliminated.add(outcome.eliminated as u64);
        self.requeues.add(u64::from(outcome.requeues));
        self.revoked.add(outcome.revoked as u64);
        self.steps_trained.add(outcome.steps_trained * outcome.workers.len().max(1) as u64);
        if outcome.seeded_from.is_some() {
            self.seeded_segments.inc();
        }
        self.transfer_bytes.add(outcome.transfer_bytes);
        self.uploads_rejected.add(u64::from(outcome.uploads_rejected));
        if outcome.audit_sampled {
            self.audit_sampled.inc();
        }
        if outcome.audit_passed {
            self.audit_passed.inc();
        }
        if outcome.audit_escalated {
            self.audit_escalated.inc();
        }
        self.audit_steps.add(outcome.audit_steps);
        self.stake_slashed.add(outcome.slashed);
        self.bytes.add(outcome.bytes);
        self.requests.add(outcome.requests);
    }
}

/// Append one entry to the write-ahead journal (no-op without one).
/// A free function over the two fields it needs, so call sites holding a
/// mutable borrow into another `EventLoop` field (`jobs`, typically) can
/// still journal.
fn wal(journal: &mut Option<Journal>, metrics: &CoordMetrics, entry: JournalEntry) {
    let Some(j) = journal.as_mut() else { return };
    let before = j.bytes();
    j.append(&entry);
    metrics.journal_entries.inc();
    metrics.journal_bytes.add(j.bytes() - before);
}

/// Flush and `fdatasync` the journal (no-op without one, or with nothing
/// buffered). Called at the durability boundaries: submit, segment settle,
/// job settle/cancel.
fn wal_sync(journal: &mut Option<Journal>, metrics: &CoordMetrics) {
    if let Some(j) = journal.as_mut() {
        if j.sync() {
            metrics.journal_syncs.inc();
        }
    }
}

/// The command channel plus its shutdown latch. Senders and the event
/// loop's final drain synchronize on the same mutex: a command sent while
/// the gate is open is guaranteed to be in the channel before the drain
/// runs, and once `closed` is set every later send fails — so a
/// [`Cmd::Submit`] can never slip through unprocessed and strand its
/// handle in `wait()`.
pub(crate) struct CmdGate {
    pub(crate) tx: Sender<Cmd>,
    pub(crate) closed: bool,
}

/// The spawned event core: gated command channel, the completion sender
/// (clients send [`wake`] nudges on it after each command), and the join
/// handles a [`Delegation`] collects at shutdown.
pub(crate) struct Core {
    pub(crate) gate: Arc<Mutex<CmdGate>>,
    pub(crate) comp_tx: Sender<Completion>,
    pub(crate) event_join: std::thread::JoinHandle<LoopReport>,
    pub(crate) resolver_joins: Vec<std::thread::JoinHandle<()>>,
    /// The delegation's private stats registry (`coord_*` keys); the
    /// event loop and resolvers record into clones of this handle.
    pub(crate) registry: Registry,
}

/// Pre-crash state a recovered event loop reinstates before its first
/// tick: folded stake accounts (restored against the *current* config's
/// deposit — recovery assumes the stake knob is stable across restarts)
/// and the permanently revoked worker set, which stays revoked forever.
pub(crate) struct CoreRestore {
    pub(crate) stakes: Vec<RecoveredStake>,
    pub(crate) revoked: Vec<String>,
}

/// Spawn the full event core: the event loop thread plus its resolver
/// pool. With `journal` set, every coordinator decision is write-ahead
/// logged through it; `restore` reinstates journal-recovered state.
pub(crate) fn start_core(
    pool: &WorkerPool,
    cfg: ServiceConfig,
    journal: Option<Journal>,
    restore: Option<CoreRestore>,
) -> Core {
    let (comp_tx, comp_rx) = channel::<Completion>();
    let (cmd_tx, cmd_rx) = channel::<Cmd>();
    let (task_tx, task_rx) = channel::<ResolveTask>();
    let (resolved_tx, resolved_rx) = channel::<ResolverMsg>();
    let gate = Arc::new(Mutex::new(CmdGate { tx: cmd_tx, closed: false }));
    let registry = Registry::new();
    let resolver_joins =
        spawn_resolvers(cfg.resolvers.max(1), task_rx, resolved_tx, comp_tx.clone());
    let event_loop = EventLoop::new(
        pool.clone(),
        cfg,
        comp_tx.clone(),
        task_tx,
        Arc::clone(&gate),
        registry.clone(),
        journal,
        restore,
    );
    let event_join = std::thread::Builder::new()
        .name("verde-event-loop".into())
        .spawn(move || event_loop.run(comp_rx, cmd_rx, resolved_rx))
        .expect("spawn event loop");
    Core { gate, comp_tx, event_join, resolver_joins, registry }
}

/// Spawn the resolver pool: each worker thread pulls [`ResolveTask`]s,
/// runs the tournament, and nudges the event loop.
fn spawn_resolvers(
    n: usize,
    task_rx: Receiver<ResolveTask>,
    resolved_tx: Sender<ResolverMsg>,
    comp_tx: Sender<Completion>,
) -> Vec<std::thread::JoinHandle<()>> {
    let task_rx = Arc::new(Mutex::new(task_rx));
    (0..n)
        .map(|i| {
            let task_rx = Arc::clone(&task_rx);
            let resolved_tx = resolved_tx.clone();
            let comp_tx = comp_tx.clone();
            std::thread::Builder::new()
                .name(format!("verde-resolver-{i}"))
                .spawn(move || loop {
                    let task = task_rx.lock().unwrap().recv();
                    let Ok(task) = task else { break };
                    let (resolved, production) = resolve(task);
                    if resolved_tx.send(ResolverMsg::Resolved(resolved)).is_err() {
                        break;
                    }
                    // Nudge the event loop: resolved segments ride a side
                    // channel. The successor can lease (and attach to the
                    // stream) while the producer below is still fetching.
                    let _ = comp_tx.send(wake());
                    if let Some(p) = production {
                        let done = run_producer(p, &comp_tx);
                        if resolved_tx.send(ResolverMsg::StreamDone(done)).is_err() {
                            break;
                        }
                        let _ = comp_tx.send(wake());
                    }
                })
                .expect("spawn resolver")
        })
        .collect()
}

/// One job's life inside the event loop.
struct JobRun {
    /// The full job spec (state-transfer jobs queue later segments only
    /// when their predecessor settles, so the prefix specs are derived
    /// lazily).
    spec: JobSpec,
    policy: JobPolicy,
    cell: Arc<JobCell>,
    /// Segment end boundaries (strictly increasing, last == `spec.steps`).
    boundaries: Vec<u64>,
    /// Settled segments, indexed by segment.
    done: Vec<Option<SegmentOutcome>>,
    finished: usize,
    /// Next segment index to queue. Non-transfer jobs queue everything at
    /// submit (`next_seg == boundaries.len()`); transfer jobs advance this
    /// one segment at a time as predecessors settle (pipeline).
    next_seg: usize,
    /// First lease of any segment (job wall-clock anchor). (There is no
    /// cancelled flag: `handle_cancel` removes the job from the map
    /// outright, so presence in `jobs` means live.)
    t0: Option<Instant>,
    /// An audit on this job escalated: the optimistic tier is off for the
    /// rest of the job and every remaining segment runs k-replicated.
    escalated: bool,
    /// Optimistic tier: the staked worker this job is pinned to — the same
    /// worker trains every segment (and carries its trainer cache across
    /// boundaries). Cleared when the worker leaves the pool or loses
    /// eligibility.
    pinned: Option<String>,
    /// Seed each optimistic segment was dispatched with, kept until the
    /// segment settles: a sampled replay must start from the same
    /// predecessor checkpoint the accused did. (Optimistic seeds are
    /// always buffered — the commitment fetch binds the whole payload.)
    seed_used: HashMap<usize, Arc<SeedPayload>>,
    /// In-flight audit state per sampled segment.
    audits: HashMap<usize, AuditState>,
}

impl JobRun {
    /// Is this job currently running on the optimistic single-worker tier?
    fn optimistic(&self) -> bool {
        self.policy.audit_rate > 0.0 && !self.escalated
    }

    /// Does this job advance one segment at a time (queueing segment `i+1`
    /// only once `i` settles)? True for state-transfer jobs and for the
    /// audit tier, whose sampling decision must land before the successor
    /// is seeded.
    fn pipelined(&self) -> bool {
        self.policy.transfer || self.policy.audit_rate > 0.0
    }

    /// Does segment `seg_idx`'s resolution need to fetch the boundary
    /// checkpoint (because the next segment is still waiting to be queued
    /// and the job moves state between segments)?
    fn wants_state(&self, seg_idx: usize) -> bool {
        self.pipelined()
            && self.next_seg == seg_idx + 1
            && self.next_seg < self.boundaries.len()
    }
}

/// Pop every expired deadline and synthesize a `DeadlineExpired` refusal
/// for tokens still outstanding. Answered tokens were already removed from
/// the map — which is also what dedups this timer against mux-enforced
/// deadlines racing it.
fn fire_expired_deadlines(
    deadlines: &mut BinaryHeap<Reverse<(Instant, u64)>>,
    tokens: &HashMap<u64, Target>,
    events: &mut Vec<Completion>,
) {
    let now = Instant::now();
    while deadlines.peek().is_some_and(|Reverse((d, _))| *d <= now) {
        let Reverse((_, token)) = deadlines.pop().expect("peeked");
        if tokens.contains_key(&token) {
            events.push(Completion {
                token,
                kind: CompletionKind::DeadlineExpired,
                resp: Response::Refuse("deadline expired before the worker answered".into()),
            });
        }
    }
}

/// The persistent event loop driving every segment state machine. Owned by
/// a [`Delegation`]'s event thread; exits once a [`Cmd::Shutdown`] arrived
/// and all work has drained.
pub(crate) struct EventLoop {
    pool: WorkerPool,
    cfg: ServiceConfig,
    comp_tx: Sender<Completion>,
    task_tx: Sender<ResolveTask>,
    gate: Arc<Mutex<CmdGate>>,
    queue: BinaryHeap<QueuedSeg>,
    jobs: HashMap<u64, JobRun>,
    active: HashMap<(u64, usize), ActiveSeg>,
    tokens: HashMap<u64, Target>,
    probing: HashMap<u64, PooledWorker>,
    paroling: HashMap<u64, PooledWorker>,
    /// Workers of cancelled jobs whose dispatch is still in flight.
    draining: HashMap<u64, PooledWorker>,
    deadlines: BinaryHeap<Reverse<(Instant, u64)>>,
    outcomes: Vec<JobOutcome>,
    next_token: u64,
    next_lease_seq: u64,
    next_health: Option<Instant>,
    actor_threads: usize,
    resolving_out: usize,
    shutting_down: bool,
    metrics: CoordMetrics,
    /// Deterministic audit coin (seeded by [`ServiceConfig::audit_seed`]).
    sampler: AuditSampler,
    /// Stake accounts backing the optimistic tier.
    ledger: StakeLedger,
    /// Workers permanently out of the pool (revoked or expelled): a pinned
    /// optimistic job re-leases immediately instead of waiting for them.
    gone: HashSet<String>,
    /// Write-ahead journal (`None` = volatile coordinator, the default).
    journal: Option<Journal>,
    /// Content-addressed checkpoint cache (keyed by certified state
    /// root), shared with the resolvers.
    cache: Arc<CheckpointCache>,
    /// Outcomes of segments whose state is still streaming to their
    /// successor: held here until the producer's [`StreamDone`] merges
    /// the transfer accounting and the segment records.
    parked: HashMap<(u64, usize), SegmentOutcome>,
    /// Producers whose [`StreamDone`] has not arrived yet (the loop must
    /// not exit while source workers are still out with a producer).
    streams_out: usize,
    /// Dispatches the mux refused on a full per-connection write buffer.
    overloads: u64,
    /// High-water mark over every stream's buffered window, in bytes.
    stream_peak: u64,
}

impl EventLoop {
    pub(crate) fn new(
        pool: WorkerPool,
        cfg: ServiceConfig,
        comp_tx: Sender<Completion>,
        task_tx: Sender<ResolveTask>,
        gate: Arc<Mutex<CmdGate>>,
        registry: Registry,
        journal: Option<Journal>,
        restore: Option<CoreRestore>,
    ) -> EventLoop {
        let mut ledger = StakeLedger::new(cfg.worker_stake);
        let mut gone = HashSet::new();
        if let Some(r) = restore {
            for s in r.stakes {
                // Anything locked at the crash was already released (and
                // journaled as released) by the recovery fold.
                ledger.restore(&s.worker, cfg.worker_stake.max(s.slashed), s.slashed);
            }
            gone.extend(r.revoked);
        }
        let cache = Arc::new(CheckpointCache::new(&registry, cfg.ckpt_cache_bytes));
        EventLoop {
            metrics: CoordMetrics::new(registry),
            pool,
            cfg,
            comp_tx,
            task_tx,
            gate,
            queue: BinaryHeap::new(),
            jobs: HashMap::new(),
            active: HashMap::new(),
            tokens: HashMap::new(),
            probing: HashMap::new(),
            paroling: HashMap::new(),
            draining: HashMap::new(),
            deadlines: BinaryHeap::new(),
            outcomes: Vec::new(),
            next_token: 1,
            next_lease_seq: 1,
            // First sweep fires immediately so even a short run probes its
            // idle workers at least once.
            next_health: cfg.health_check.map(|_| Instant::now()),
            actor_threads: 0,
            resolving_out: 0,
            shutting_down: false,
            sampler: AuditSampler::new(cfg.audit_seed),
            ledger,
            gone,
            journal,
            cache,
            parked: HashMap::new(),
            streams_out: 0,
            overloads: 0,
            stream_peak: 0,
        }
    }

    /// All work drained after a shutdown request?
    fn finished(&self) -> bool {
        self.shutting_down
            && self.jobs.is_empty()
            && self.queue.is_empty()
            && self.active.is_empty()
            && self.resolving_out == 0
            && self.streams_out == 0
            && self.probing.is_empty()
            && self.paroling.is_empty()
            && self.draining.is_empty()
    }

    pub(crate) fn run(
        mut self,
        comp_rx: Receiver<Completion>,
        cmd_rx: Receiver<Cmd>,
        resolved_rx: Receiver<ResolverMsg>,
    ) -> LoopReport {
        let mut events: Vec<Completion> = Vec::new();
        loop {
            let t_tick = Instant::now();
            // 1. Client commands (submissions, cancels, shutdown).
            while let Ok(cmd) = cmd_rx.try_recv() {
                self.handle_cmd(cmd);
            }

            // 2. Lease workers for queued segments while capacity allows.
            self.lease_pass();

            if self.finished() {
                break;
            }

            // 3. Sleep until the next completion, deadline, health tick,
            //    or parole instant. (The blocking wait is excluded from
            //    the tick-duration histogram: `coord_tick_us` measures
            //    work, not idleness.)
            let pre_wait = t_tick.elapsed();
            let now = Instant::now();
            let mut timeout = Duration::from_millis(50);
            if let Some(Reverse((d, _))) = self.deadlines.peek() {
                timeout = timeout.min(d.saturating_duration_since(now));
            }
            if let Some(h) = self.next_health {
                timeout = timeout.min(h.saturating_duration_since(now));
            }
            if let Some(p) = self.pool.next_parole() {
                timeout = timeout.min(p.saturating_duration_since(now));
            }
            match comp_rx.recv_timeout(timeout.max(Duration::from_micros(100))) {
                Ok(c) => events.push(c),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break,
            }
            while let Ok(c) = comp_rx.try_recv() {
                events.push(c);
            }
            let t_work = Instant::now();
            self.metrics.completions_per_tick.observe(events.len() as u64);

            // 4. Fire expired deadlines for tokens still outstanding.
            fire_expired_deadlines(&mut self.deadlines, &self.tokens, &mut events);

            // 5. Advance per-segment state machines.
            for c in events.drain(..) {
                self.handle_completion(c);
            }

            // 6. Collect resolved tournaments and finished stream
            //    producers; discipline workers that went silent
            //    mid-dispute, release the rest.
            while let Ok(msg) = resolved_rx.try_recv() {
                match msg {
                    ResolverMsg::Resolved(resolved) => self.handle_resolved(resolved),
                    ResolverMsg::StreamDone(done) => self.handle_stream_done(done),
                }
            }

            // 6b. Pump streaming seeds: forward any newly produced chunks
            //     to the consumer slots within each stream's window.
            self.pump_all();

            // 7. Health-check sweep: ping every idle worker.
            self.health_sweep();

            // 8. Parole sweep: probe suspended workers whose backoff is up.
            self.parole_sweep();

            self.metrics.tick_us.observe_micros(pre_wait + t_work.elapsed());
            self.metrics.stake_locked.set(self.ledger.total_locked());
            self.metrics.queue_depth.set(self.queue.len() as u64);
            self.metrics.active_segments.set(self.active.len() as u64);
            self.metrics.resolving.set(self.resolving_out as u64);
            self.pool.observe_gauges(
                &self.metrics.pool_idle,
                &self.metrics.pool_suspended,
                &self.metrics.pool_size,
            );
        }
        // Close the command gate, then settle stragglers: under the gate's
        // mutex, every command sent while the gate was open is already in
        // the channel, and every later send fails at the client (which
        // then stubs its own handle) — no submission can strand a waiter.
        self.gate.lock().unwrap().closed = true;
        while let Ok(cmd) = cmd_rx.try_recv() {
            match cmd {
                Cmd::Submit { job_id, cell, .. } | Cmd::Recover { job_id, cell, .. } => {
                    cell.finish(JobOutcome::cancelled_stub(job_id));
                }
                Cmd::Cancel { reply, .. } => {
                    let _ = reply.send(false);
                }
                Cmd::Shutdown => {}
            }
        }
        // Clean shutdown closes the journal at an entry boundary.
        wal_sync(&mut self.journal, &self.metrics);
        LoopReport {
            outcomes: self.outcomes,
            actor_threads: self.actor_threads,
            stakes: self.ledger.snapshot(),
            overloads: self.overloads,
            ckpt_cache_hits: self.cache.hits(),
            ckpt_cache_misses: self.cache.misses(),
        }
    }

    fn handle_cmd(&mut self, cmd: Cmd) {
        match cmd {
            Cmd::Submit { job_id, spec, policy, cell } => {
                if self.shutting_down {
                    // Late submission: the service is closing, the job
                    // never ran — terminal as cancelled, outside the
                    // report (the report covers the run being drained).
                    cell.finish(JobOutcome::cancelled_stub(job_id));
                    return;
                }
                self.metrics.jobs_submitted.inc();
                self.metrics.registry.spans().trace(job_id, None, Stage::Submit, None);
                // Write-ahead: the submission is durable before any lease
                // is taken, so a crash can never forget an accepted job.
                wal(
                    &mut self.journal,
                    &self.metrics,
                    JournalEntry::Submit { job_id, spec, policy },
                );
                if spec.steps == 0 {
                    // A zero-step job has no checkpoint schedule to shard
                    // or verify: settle it unresolved (not cancelled —
                    // nobody cancelled it) and keep it in the report like
                    // any other submission.
                    let outcome =
                        JobOutcome { cancelled: false, ..JobOutcome::cancelled_stub(job_id) };
                    self.outcomes.push(outcome.clone());
                    self.metrics.registry.spans().trace(job_id, None, Stage::Settle, None);
                    wal(
                        &mut self.journal,
                        &self.metrics,
                        JournalEntry::JobSettled { outcome: outcome.clone() },
                    );
                    wal_sync(&mut self.journal, &self.metrics);
                    cell.finish(outcome);
                    return;
                }
                wal_sync(&mut self.journal, &self.metrics);
                let boundaries = split_points(0, spec.steps, policy.segments.max(1));
                // With state transfer (or the audit tier) on, only the
                // first segment queues now: each later segment needs its
                // predecessor's verified checkpoint — and, for optimistic
                // jobs, its predecessor's sampling decision — so the
                // pipeline advances in `record_segment`.
                let queue_now = if policy.transfer || policy.audit_rate > 0.0 {
                    1
                } else {
                    boundaries.len()
                };
                for (seg_idx, &end) in boundaries.iter().enumerate().take(queue_now) {
                    self.metrics.registry.spans().trace(
                        job_id,
                        Some(seg_idx as u64),
                        Stage::Queue,
                        None,
                    );
                    self.queue.push(QueuedSeg {
                        kind: SegKind::Work,
                        priority: policy.priority,
                        job_id,
                        seg_idx,
                        spec: spec.prefix(end),
                        seed: SeedSource::None,
                        requeues: 0,
                        revoked: 0,
                        bytes: 0,
                        requests: 0,
                        t0: None,
                        leased_seq: 0,
                    });
                }
                let n = boundaries.len();
                self.jobs.insert(
                    job_id,
                    JobRun {
                        spec,
                        policy,
                        cell,
                        boundaries,
                        done: (0..n).map(|_| None).collect(),
                        finished: 0,
                        next_seg: queue_now,
                        t0: None,
                        escalated: false,
                        pinned: None,
                        seed_used: HashMap::new(),
                        audits: HashMap::new(),
                    },
                );
            }
            Cmd::Recover { job_id, spec, policy, cell, settled } => {
                if self.shutting_down {
                    cell.finish(JobOutcome::cancelled_stub(job_id));
                    return;
                }
                self.metrics.journal_recovered_jobs.inc();
                self.metrics.registry.spans().trace(job_id, None, Stage::Submit, None);
                if spec.steps == 0 {
                    // Degenerate recovered job (its JobSettled entry must
                    // have been lost to the torn tail): settle as a fresh
                    // zero-step submission would.
                    let outcome =
                        JobOutcome { cancelled: false, ..JobOutcome::cancelled_stub(job_id) };
                    self.outcomes.push(outcome.clone());
                    wal(
                        &mut self.journal,
                        &self.metrics,
                        JournalEntry::JobSettled { outcome: outcome.clone() },
                    );
                    wal_sync(&mut self.journal, &self.metrics);
                    cell.finish(outcome);
                    return;
                }
                let boundaries = split_points(0, spec.steps, policy.segments.max(1));
                let n = boundaries.len();
                // Settled verdicts are trusted from the log: pre-fill them
                // so only the remainder re-trains. They are counted by the
                // replay counter, NOT `observe_settled` — the live
                // registry's training totals then cover only work this
                // process actually performs (which is what the recovery
                // tests assert against).
                let mut done: Vec<Option<SegmentOutcome>> = (0..n).map(|_| None).collect();
                let mut finished = 0usize;
                for o in settled {
                    if o.seg < n && done[o.seg].is_none() {
                        finished += 1;
                        self.metrics.journal_replayed_segments.inc();
                        done[o.seg] = Some(o);
                    }
                }
                // Pipelined jobs (transfer or audit tier) advance one
                // segment at a time and their verified seeds died with the
                // old process, so the first unsettled segment re-queues as
                // a prefix re-train; independent segments all queue now.
                let pipelined = policy.transfer || policy.audit_rate > 0.0;
                let first_unsettled =
                    done.iter().position(|d| d.is_none()).unwrap_or(n);
                let queue_upto = if pipelined { (first_unsettled + 1).min(n) } else { n };
                for (seg_idx, &end) in boundaries.iter().enumerate().take(queue_upto) {
                    if done[seg_idx].is_some() {
                        continue;
                    }
                    self.metrics.registry.spans().trace(
                        job_id,
                        Some(seg_idx as u64),
                        Stage::Queue,
                        None,
                    );
                    self.queue.push(QueuedSeg {
                        kind: SegKind::Work,
                        priority: policy.priority,
                        job_id,
                        seg_idx,
                        spec: spec.prefix(end),
                        seed: SeedSource::None,
                        requeues: 0,
                        revoked: 0,
                        bytes: 0,
                        requests: 0,
                        t0: None,
                        leased_seq: 0,
                    });
                }
                cell.set_running(finished, n);
                self.jobs.insert(
                    job_id,
                    JobRun {
                        spec,
                        policy,
                        cell,
                        boundaries,
                        done,
                        finished,
                        next_seg: queue_upto,
                        t0: None,
                        escalated: false,
                        pinned: None,
                        seed_used: HashMap::new(),
                        audits: HashMap::new(),
                    },
                );
                if finished >= n {
                    // Every segment already settled before the crash; only
                    // the JobSettled record was lost. Re-finalize from the
                    // trusted verdicts.
                    self.finalize_job(job_id);
                }
            }
            Cmd::Cancel { job_id, reply } => {
                let ok = self.handle_cancel(job_id);
                let _ = reply.send(ok);
            }
            Cmd::Shutdown => self.shutting_down = true,
        }
    }

    /// Cancel a job: drop its queued segments, drain its in-flight leases
    /// back to the pool, and finalize the handle as cancelled. Returns
    /// false when the job already finished (or is unknown).
    fn handle_cancel(&mut self, job_id: u64) -> bool {
        if !self.jobs.contains_key(&job_id) {
            return false;
        }
        // Strip in-flight segments. A worker whose dispatch already
        // settled goes straight back (or gets disciplined, if it failed);
        // one whose Train is still executing is parked as *draining* — its
        // token and deadline stay armed and it re-enters the pool only
        // when the dispatch settles. Releasing it immediately would hand
        // the next job a link still crunching the cancelled Train, whose
        // deadline would then unjustly discipline an honest worker.
        let keys: Vec<(u64, usize)> =
            self.active.keys().filter(|(j, _)| *j == job_id).copied().collect();
        for key in keys {
            let mut aseg = self.active.remove(&key).expect("listed");
            // A streaming seed stops its producer: the abort unblocks a
            // push stuck on a full window and the producer returns its
            // sources via `StreamDone` (whose parked outcome is purged
            // below).
            if let Some(pump) = aseg.pump.take() {
                pump.stream.abort();
            }
            aseg.seed.abort_if_stream();
            let ActiveSeg { workers, slots, tokens, .. } = aseg;
            for ((w, slot), token) in workers.into_iter().zip(slots).zip(tokens) {
                match slot {
                    // A stream-fed slot that never got its final chunk has
                    // no armed token (the 0 sentinel): nothing to drain.
                    SlotState::Waiting if token != 0 => {
                        self.tokens.insert(token, Target::Drain);
                        self.draining.insert(token, w);
                    }
                    SlotState::Waiting => self.pool.release(vec![w]),
                    SlotState::Done(_) => self.pool.release(vec![w]),
                    SlotState::Failed => self.discipline(w, false),
                }
            }
        }
        // Queued segments are dropped lazily by the lease pass (their job
        // is gone from the map). Resolving segments finish on their
        // resolver thread; their leases return via `handle_resolved`.
        // Outcomes parked on an in-flight stream producer are discarded —
        // the producer's `StreamDone` still returns its workers.
        self.parked.retain(|(j, _), _| *j != job_id);
        let run = self.jobs.remove(&job_id).expect("checked");
        // Stakes locked behind this job's in-flight audits are released:
        // with the job gone no tournament can ever certify a conviction.
        for audit in run.audits.values() {
            let accused = match audit {
                AuditState::Pending { accused, .. } => accused,
                AuditState::Escalated { accused: Some(a), .. } => a,
                AuditState::Escalated { accused: None, .. } => continue,
            };
            self.ledger.release(accused);
            wal(
                &mut self.journal,
                &self.metrics,
                JournalEntry::StakeRelease { worker: accused.clone() },
            );
        }
        let segments: Vec<SegmentOutcome> = run.done.into_iter().flatten().collect();
        let outcome = JobOutcome {
            job_id,
            accepted: None,
            winner: None,
            cancelled: true,
            disputes: segments.iter().map(|s| s.disputes).sum(),
            eliminated: segments.iter().map(|s| s.eliminated).sum(),
            requeues: segments.iter().map(|s| s.requeues).sum(),
            revoked: segments.iter().map(|s| s.revoked).sum(),
            wall: run.t0.map(|t| t.elapsed()).unwrap_or(Duration::ZERO),
            bytes: segments.iter().map(|s| s.bytes).sum(),
            requests: segments.iter().map(|s| s.requests).sum(),
            segments,
        };
        self.metrics.jobs_cancelled.inc();
        self.metrics.registry.spans().trace(job_id, None, Stage::Settle, None);
        wal(
            &mut self.journal,
            &self.metrics,
            JournalEntry::JobSettled { outcome: outcome.clone() },
        );
        wal_sync(&mut self.journal, &self.metrics);
        self.outcomes.push(outcome.clone());
        run.cell.finish(outcome);
        true
    }

    /// Lease workers for queued segments. Segments whose requirement
    /// cannot be met *right now* are deferred (put back); segments whose
    /// requirement can never be met again fail immediately. The audit
    /// tier routes here too: optimistic work leases its single pinned
    /// staked worker, replay audits lease one worker other than the
    /// accused, and escalated segments prefer to include the accused so
    /// the tournament can convict it.
    fn lease_pass(&mut self) {
        if self.pool.idle() == 0 && self.pool.size() > 0 {
            // Every live worker is leased; they return via completions, so
            // there is nothing to decide yet. (With size == 0 the pass
            // still runs, to fail segments whose requirement can never be
            // met again.)
            return;
        }
        let mut deferred: Vec<QueuedSeg> = Vec::new();
        while let Some(seg) = self.queue.pop() {
            let (policy, optimistic, pinned, tournament_accused) =
                match self.jobs.get(&seg.job_id) {
                    // Cancelled and finalized: stale entry, drop it (and
                    // stop any producer still feeding its seed stream).
                    None => {
                        seg.seed.abort_if_stream();
                        continue;
                    }
                    Some(j) => (
                        j.policy,
                        j.optimistic(),
                        j.pinned.clone(),
                        match j.audits.get(&seg.seg_idx) {
                            Some(AuditState::Escalated { accused, .. }) => accused.clone(),
                            _ => None,
                        },
                    ),
                };
            if !self.pool.any_eligible(policy.backend) {
                // Nobody now, nobody ever: the segment is unresolvable.
                self.fail_segment(seg);
                continue;
            }
            let live = self.pool.size();
            if live == 0 {
                // Suspended workers may yet return; wait for parole.
                deferred.push(seg);
                continue;
            }
            if let SegKind::Audit { accused, .. } = &seg.kind {
                // A replay audit runs on one worker independent of the
                // accused committer.
                let accused = accused.clone();
                let pred = move |w: &PooledWorker| {
                    policy.backend.admits(w.backend()) && w.name != accused
                };
                match self.pool.try_acquire_where(1, pred) {
                    Some(ws) => self.dispatch_segment(seg, ws, policy),
                    None if self.pool.idle() == live && self.pool.suspended() == 0 => {
                        // Every live worker is idle and none qualifies (the
                        // accused is the whole pool): an independent
                        // auditor can never appear. Escalate instead of
                        // deferring forever.
                        self.escalate_audit_failure(seg);
                    }
                    None => deferred.push(seg),
                }
                continue;
            }
            if optimistic {
                // Optimistic tier: one staked worker, pinned to the job so
                // the same worker trains (and commits) every segment.
                if let Some(name) = &pinned {
                    if self.gone.contains(name) || !self.ledger.eligible(name) {
                        // The pinned worker left the pool or lost its
                        // stake: re-pin below.
                        if let Some(run) = self.jobs.get_mut(&seg.job_id) {
                            run.pinned = None;
                        }
                    } else if let Some(w) = self.pool.try_take_named(name) {
                        self.dispatch_segment(seg, vec![w], policy);
                        continue;
                    } else {
                        // Busy or suspended: the pin holds, wait for it.
                        deferred.push(seg);
                        continue;
                    }
                }
                let ledger = &self.ledger;
                let pred = |w: &PooledWorker| {
                    policy.backend.admits(w.backend()) && ledger.eligible(&w.name)
                };
                match self.pool.try_acquire_where(1, pred) {
                    Some(ws) => {
                        let name = ws[0].name.clone();
                        self.ledger.enroll(&name);
                        if let Some(run) = self.jobs.get_mut(&seg.job_id) {
                            run.pinned = Some(name);
                        }
                        self.dispatch_segment(seg, ws, policy);
                    }
                    None => deferred.push(seg),
                }
                continue;
            }
            // k-replicated work. An escalated segment leases at least two
            // workers and prefers to include the accused committer: the
            // tournament can then bisect against it and certify the
            // conviction (if the accused is unavailable the tournament
            // still re-establishes the honest verdict without it).
            let mut k = if policy.k == 0 { self.cfg.k } else { policy.k };
            if tournament_accused.is_some() {
                k = k.max(2);
            }
            let k = k.clamp(1, live);
            let mut ws: Vec<PooledWorker> = Vec::new();
            if let Some(name) = &tournament_accused {
                if let Some(w) = self.pool.try_take_named(name) {
                    if policy.backend.admits(w.backend()) {
                        ws.push(w);
                    } else {
                        self.pool.release(vec![w]);
                    }
                }
            }
            let taken = ws.first().map(|w| w.name.clone());
            let pred = move |w: &PooledWorker| {
                policy.backend.admits(w.backend()) && Some(&w.name) != taken.as_ref()
            };
            match self.pool.try_acquire_where(k - ws.len().min(k), pred) {
                Some(more) => {
                    ws.extend(more);
                    self.dispatch_segment(seg, ws, policy);
                }
                None => {
                    self.pool.release(ws);
                    deferred.push(seg);
                }
            }
        }
        for seg in deferred {
            self.queue.push(seg);
        }
    }

    /// Submit `Train` (or, for a seeded segment, the chunked
    /// `SeedCheckpoint` sequence whose final chunk triggers training) to
    /// every leased worker and park the segment in the active table.
    fn dispatch_segment(
        &mut self,
        seg: QueuedSeg,
        mut workers: Vec<PooledWorker>,
        policy: JobPolicy,
    ) {
        let t0 = seg.t0.unwrap_or_else(Instant::now);
        let lease_seq = self.next_lease_seq;
        self.next_lease_seq += 1;
        // The first lease stamps the scheduling order; re-queues keep it.
        let leased_seq = if seg.leased_seq == 0 { lease_seq } else { seg.leased_seq };
        let spans = self.metrics.registry.spans();
        spans.trace(seg.job_id, Some(seg.seg_idx as u64), Stage::Lease, None);
        if let SegKind::Audit { accused, .. } = &seg.kind {
            spans.trace(seg.job_id, Some(seg.seg_idx as u64), Stage::Audit, Some(accused));
        }
        if !seg.seed.is_none() {
            spans.trace(seg.job_id, Some(seg.seg_idx as u64), Stage::Seed, None);
        }
        for w in &workers {
            spans.trace(seg.job_id, Some(seg.seg_idx as u64), Stage::Dispatch, Some(&w.name));
        }
        // Lease grants ride the journal buffer (no fsync of their own):
        // losing one costs re-leasing work the crash loses anyway.
        wal(
            &mut self.journal,
            &self.metrics,
            JournalEntry::Lease {
                job_id: seg.job_id,
                seg_idx: seg.seg_idx as u64,
                lease_seq,
                workers: workers.iter().map(|w| w.name.clone()).collect(),
            },
        );
        let deadline = Instant::now() + policy.deadline.unwrap_or(self.cfg.dispatch_deadline);
        let mut aseg = ActiveSeg {
            kind: seg.kind,
            spec: seg.spec,
            seed: seg.seed.clone(),
            t0,
            requeues: seg.requeues,
            revoked: seg.revoked,
            bytes: seg.bytes,
            requests: seg.requests,
            workers: Vec::new(),
            slots: Vec::new(),
            tokens: Vec::new(),
            outstanding: 0,
            leased_seq,
            pump: None,
        };
        for (slot, w) in workers.iter_mut().enumerate() {
            self.actor_threads += usize::from(w.activate());
            w.reset_fault();
            w.set_call_deadline(self.cfg.call_deadline);
            // The request sequence for this slot: one Train, or the seed
            // chunks (the final chunk's answer is the training commit, so
            // only its token becomes the slot's deciding token — the
            // others are pipelined acks).
            let final_token;
            match &seg.seed {
                SeedSource::None => {
                    let token = self.next_token;
                    self.next_token += 1;
                    let req = Request::Train { spec: seg.spec };
                    aseg.bytes += req.wire_size() as u64;
                    aseg.requests += 1;
                    self.deadlines.push(Reverse((deadline, token)));
                    w.dispatch(token, req, Some(deadline), &self.comp_tx);
                    final_token = token;
                }
                SeedSource::Buffered(seed) => {
                    let total = chunk_count(seed.bytes.len());
                    let mut last = 0;
                    for chunk in 0..total {
                        let token = self.next_token;
                        self.next_token += 1;
                        if chunk + 1 < total {
                            self.tokens.insert(
                                token,
                                Target::Ack { job_id: seg.job_id, seg_idx: seg.seg_idx, slot },
                            );
                        }
                        let req = Request::SeedCheckpoint {
                            spec: seg.spec,
                            start: seed.start,
                            root: seed.root,
                            total_chunks: total,
                            chunk,
                            payload: chunk_slice(&seed.bytes, chunk).to_vec(),
                        };
                        aseg.bytes += req.wire_size() as u64;
                        aseg.requests += 1;
                        self.deadlines.push(Reverse((deadline, token)));
                        w.dispatch(token, req, Some(deadline), &self.comp_tx);
                        last = token;
                    }
                    final_token = last;
                }
                SeedSource::Stream(_) => {
                    // Chunks are pumped as the producer delivers them; the
                    // slot's deciding token is assigned when its final
                    // chunk dispatches (`0` is the not-yet sentinel — real
                    // tokens start at 1).
                    final_token = 0;
                }
            }
            if final_token != 0 {
                self.tokens.insert(
                    final_token,
                    Target::Seg { job_id: seg.job_id, seg_idx: seg.seg_idx, slot },
                );
            }
            aseg.slots.push(SlotState::Waiting);
            aseg.tokens.push(final_token);
            aseg.outstanding += 1;
        }
        aseg.workers = workers;
        if let SeedSource::Stream(stream) = &seg.seed {
            // From here on the consumer is live: the producer's window
            // cap applies (bounded coordinator memory), and any verified
            // chunks it already spilled are pumped out right below.
            stream.attach();
            aseg.pump = Some(StreamPump {
                stream: Arc::clone(stream),
                next_chunk: 0,
                acked: vec![0; aseg.slots.len()],
                deadline,
                window: self.cfg.stream_window.max(1) as u64,
            });
        }
        self.active.insert((seg.job_id, seg.seg_idx), aseg);
        // Anchor the job's wall clock and mark it running.
        if let Some(run) = self.jobs.get_mut(&seg.job_id) {
            if run.t0.is_none() {
                run.t0 = Some(t0);
            }
            run.cell.set_running(run.finished, run.boundaries.len());
        }
        self.pump_segment(seg.job_id, seg.seg_idx);
    }

    /// Pump every active streaming dispatch (cheap no-op for segments
    /// without a pump).
    fn pump_all(&mut self) {
        let keys: Vec<(u64, usize)> = self
            .active
            .iter()
            .filter(|(_, a)| a.pump.is_some())
            .map(|(k, _)| *k)
            .collect();
        for (job_id, seg_idx) in keys {
            self.pump_segment(job_id, seg_idx);
        }
    }

    /// Forward verified chunks from a streaming seed to the segment's
    /// waiting slots, staying within `window` chunks of the slowest
    /// slot's acknowledgements. The final chunk's dispatch arms each
    /// slot's deciding token (exactly like the buffered path); a failed
    /// stream aborts the whole dispatch.
    fn pump_segment(&mut self, job_id: u64, seg_idx: usize) {
        let key = (job_id, seg_idx);
        let mut stream_failed = false;
        {
            // Disjoint field borrows: the pump, slots, workers and token
            // plumbing all live on `self` and are advanced together.
            let EventLoop { active, tokens, deadlines, next_token, comp_tx, .. } = self;
            let Some(aseg) = active.get_mut(&key) else { return };
            let Some(pump) = aseg.pump.as_mut() else { return };
            let stream = Arc::clone(&pump.stream);
            let total = stream.total_chunks();
            let deadline = pump.deadline;
            let window = pump.window;
            let (start, root) = (stream.manifest().step, stream.manifest().root);
            while pump.next_chunk < total {
                // Backpressure: never run more than `window` chunks ahead
                // of the slowest still-waiting slot.
                let min_acked = aseg
                    .slots
                    .iter()
                    .zip(pump.acked.iter())
                    .filter(|(s, _)| matches!(s, SlotState::Waiting))
                    .map(|(_, a)| *a)
                    .min()
                    .unwrap_or(pump.next_chunk);
                if pump.next_chunk.saturating_sub(min_acked) >= window {
                    break;
                }
                match stream.try_pop() {
                    Pop::Pending => break,
                    Pop::Failed => {
                        stream_failed = true;
                        break;
                    }
                    Pop::Chunk(payload) => {
                        let idx = pump.next_chunk;
                        pump.next_chunk += 1;
                        let is_final = idx + 1 == total;
                        for (slot, w) in aseg.workers.iter_mut().enumerate() {
                            if !matches!(aseg.slots[slot], SlotState::Waiting) {
                                continue;
                            }
                            let token = *next_token;
                            *next_token += 1;
                            let req = Request::SeedCheckpoint {
                                spec: aseg.spec,
                                start,
                                root,
                                total_chunks: total,
                                chunk: idx,
                                payload: payload.clone(),
                            };
                            aseg.bytes += req.wire_size() as u64;
                            aseg.requests += 1;
                            deadlines.push(Reverse((deadline, token)));
                            if is_final {
                                tokens.insert(token, Target::Seg { job_id, seg_idx, slot });
                                aseg.tokens[slot] = token;
                            } else {
                                tokens.insert(token, Target::Ack { job_id, seg_idx, slot });
                            }
                            w.dispatch(token, req, Some(deadline), comp_tx);
                        }
                    }
                }
            }
            if !stream_failed && pump.next_chunk >= total {
                // Fully dispatched: the pump's work is done. Remaining
                // acks become plain accounting and each slot is decided
                // by its final token, exactly like a buffered seed.
                aseg.pump = None;
            }
        }
        if stream_failed {
            self.abort_stream_dispatch(job_id, seg_idx);
        }
    }

    /// A streaming dispatch died mid-seed (the producer failed, or every
    /// slot failed a chunk ack): tear the active segment down, discipline
    /// failed slots, release the rest, and re-queue as an unseeded prefix
    /// run (or settle unresolved when out of re-queues). Chunk tokens
    /// still armed for removed slots self-clean at their deadline — their
    /// completions find no active segment and are dropped.
    fn abort_stream_dispatch(&mut self, job_id: u64, seg_idx: usize) {
        let Some(mut aseg) = self.active.remove(&(job_id, seg_idx)) else { return };
        if let Some(pump) = aseg.pump.take() {
            pump.stream.abort();
        }
        aseg.seed.abort_if_stream();
        let ActiveSeg {
            spec, t0, requeues, mut revoked, bytes, requests, workers, slots, leased_seq, ..
        } = aseg;
        let mut keep: Vec<PooledWorker> = Vec::new();
        for (w, slot) in workers.into_iter().zip(slots) {
            match slot {
                SlotState::Failed => {
                    revoked += 1;
                    self.discipline(w, false);
                }
                _ => keep.push(w),
            }
        }
        self.pool.release(keep);
        let policy = self.jobs.get(&job_id).map(|j| j.policy).unwrap_or_default();
        let max_requeues = policy.max_requeues.unwrap_or(self.cfg.max_requeues);
        if requeues < max_requeues && (self.pool.size() > 0 || self.pool.suspended() > 0) {
            self.metrics.registry.spans().trace(job_id, Some(seg_idx as u64), Stage::Queue, None);
            self.queue.push(QueuedSeg {
                kind: SegKind::Work,
                priority: policy.priority,
                job_id,
                seg_idx,
                spec,
                seed: SeedSource::None,
                requeues: requeues + 1,
                revoked,
                bytes,
                requests,
                t0: Some(t0),
                leased_seq,
            });
        } else {
            self.record_segment(
                job_id,
                seg_idx,
                SegmentOutcome {
                    requeues,
                    revoked,
                    wall: t0.elapsed(),
                    bytes,
                    requests,
                    leased_seq,
                    ..SegmentOutcome::unresolved(seg_idx, spec.steps)
                },
                None,
            );
        }
    }

    /// A segment whose backend requirement can never again be satisfied
    /// (or that exhausted its re-queues) settles unresolved. A replay
    /// audit in that position escalates instead: the parked optimistic
    /// outcome must still settle one way or the other.
    fn fail_segment(&mut self, seg: QueuedSeg) {
        if matches!(seg.kind, SegKind::Audit { .. }) {
            self.escalate_audit_failure(seg);
            return;
        }
        seg.seed.abort_if_stream();
        let outcome = SegmentOutcome {
            requeues: seg.requeues,
            revoked: seg.revoked,
            wall: seg.t0.map(|t| t.elapsed()).unwrap_or(Duration::ZERO),
            bytes: seg.bytes,
            requests: seg.requests,
            leased_seq: seg.leased_seq,
            ..SegmentOutcome::unresolved(seg.seg_idx, seg.spec.steps)
        };
        self.record_segment(seg.job_id, seg.seg_idx, outcome, None);
    }

    /// Miss-deadline discipline: suspend with exponential backoff when
    /// re-admission is enabled and the worker has strikes left, expel
    /// permanently otherwise.
    fn discipline(&mut self, mut w: PooledWorker, from_parole: bool) {
        self.metrics.disciplined.inc();
        w.add_strike();
        match self.cfg.readmit_backoff {
            Some(base) if w.strikes() < self.cfg.max_strikes => {
                let factor = 1u32 << (w.strikes() - 1).min(16);
                let until = Instant::now() + base.saturating_mul(factor);
                if from_parole {
                    self.pool.resuspend(w, until);
                } else {
                    self.pool.suspend(w, until);
                }
            }
            _ => {
                self.gone.insert(w.name.clone());
                wal(
                    &mut self.journal,
                    &self.metrics,
                    JournalEntry::Revoke { worker: w.name.clone() },
                );
                if from_parole {
                    self.pool.expel(w);
                } else {
                    self.pool.revoke(w);
                }
            }
        }
    }

    fn handle_completion(&mut self, c: Completion) {
        if c.token == WAKE_TOKEN {
            return;
        }
        if matches!(c.kind, CompletionKind::Overloaded) {
            // The mux refused the dispatch on a full per-connection write
            // buffer: surfaced in the report so slow-consumer stalls are
            // visible, then handled like any other unresponsive slot.
            self.overloads += 1;
        }
        let Some(target) = self.tokens.remove(&c.token) else {
            return; // stale: deadline already handled, cancelled, or late duplicate
        };
        match target {
            Target::Ack { job_id, seg_idx, slot } => {
                // Intermediate seed-chunk acknowledgement: byte accounting
                // and, for a streaming seed, window advancement. A worker
                // that never acks also never answers the slot's deciding
                // token, whose deadline disciplines it — but a *failed*
                // ack on a streamed slot would leave that slot with no
                // armed token at all, so it fails here and the dispatch
                // aborts once every slot is decided.
                if !c.kind.unresponsive() {
                    let mut pump_now = false;
                    if let Some(aseg) = self.active.get_mut(&(job_id, seg_idx)) {
                        aseg.bytes += c.resp.wire_size() as u64;
                        if let Some(pump) = aseg.pump.as_mut() {
                            if let Some(a) = pump.acked.get_mut(slot) {
                                *a += 1;
                            }
                            pump_now = true;
                        }
                    }
                    if pump_now {
                        self.pump_segment(job_id, seg_idx);
                    }
                } else {
                    // While the pump is live no final token has been
                    // issued, so no slot can be `Done` yet: a failed ack
                    // decides its slot here, and once every slot has
                    // failed the whole streamed dispatch aborts. (With
                    // the pump finished — or on a buffered seed — failed
                    // acks stay advisory: the final token's deadline
                    // decides the slot, exactly as before.)
                    let mut all_failed = false;
                    if let Some(aseg) = self.active.get_mut(&(job_id, seg_idx)) {
                        if aseg.pump.is_some()
                            && matches!(aseg.slots.get(slot), Some(SlotState::Waiting))
                        {
                            aseg.slots[slot] = SlotState::Failed;
                            aseg.outstanding -= 1;
                            all_failed = aseg.outstanding == 0;
                        }
                    }
                    if all_failed {
                        self.abort_stream_dispatch(job_id, seg_idx);
                    }
                }
            }
            Target::Probe => {
                let Some(w) = self.probing.remove(&c.token) else { return };
                if c.kind.unresponsive() || w.faulted() {
                    self.discipline(w, false);
                } else {
                    self.pool.release(vec![w]);
                }
            }
            Target::Parole => {
                let Some(mut w) = self.paroling.remove(&c.token) else { return };
                if c.kind.unresponsive() || w.faulted() {
                    self.discipline(w, true);
                } else {
                    w.reset_fault();
                    self.pool.readmit(w);
                }
            }
            Target::Drain => {
                let Some(w) = self.draining.remove(&c.token) else { return };
                if c.kind.unresponsive() || w.faulted() {
                    // Even a cancelled job's stall is a stall.
                    self.discipline(w, false);
                } else {
                    self.pool.release(vec![w]);
                }
            }
            Target::Seg { job_id, seg_idx, slot } => {
                let Some(aseg) = self.active.get_mut(&(job_id, seg_idx)) else { return };
                if !matches!(aseg.slots.get(slot), Some(SlotState::Waiting)) {
                    // The slot was already decided (a streamed slot can
                    // fail via a chunk ack while its final token is still
                    // armed): never decide — or decrement — twice.
                    return;
                }
                aseg.slots[slot] = if c.kind.unresponsive() {
                    // Synthesized refusal: nothing crossed the wire.
                    SlotState::Failed
                } else {
                    aseg.bytes += c.resp.wire_size() as u64;
                    SlotState::Done(c.resp)
                };
                aseg.outstanding -= 1;
                if aseg.outstanding == 0 {
                    let aseg = self.active.remove(&(job_id, seg_idx)).expect("just seen");
                    self.finish_dispatch(job_id, seg_idx, aseg);
                }
            }
        }
    }

    /// All of a segment's dispatches answered (or expired): discipline
    /// silent workers and re-queue, hand the claims to a resolver, fall a
    /// disagreeing *seeded* lease back to prefix re-training, or settle
    /// the segment unresolved.
    fn finish_dispatch(&mut self, job_id: u64, seg_idx: usize, aseg: ActiveSeg) {
        let ActiveSeg {
            kind,
            spec,
            seed,
            t0,
            requeues,
            mut revoked,
            bytes,
            requests,
            workers,
            slots,
            leased_seq,
            ..
        } = aseg;
        if let SegKind::Audit { accused, expect } = kind {
            self.finish_audit(AuditReturn {
                job_id,
                seg_idx,
                accused,
                expect,
                spec,
                seed,
                t0,
                requeues,
                revoked,
                bytes,
                requests,
                leased_seq,
                workers,
                slots,
            });
            return;
        }
        let mut keep: Vec<PooledWorker> = Vec::new();
        let mut claims: Vec<Option<Hash>> = Vec::new();
        let mut any_failed = false;
        let mut commits = 0usize;
        for (w, slot) in workers.into_iter().zip(slots) {
            match slot {
                SlotState::Failed => {
                    any_failed = true;
                    revoked += 1;
                    self.discipline(w, false);
                }
                SlotState::Done(resp) => {
                    if let Response::Commit(h) = resp {
                        commits += 1;
                        claims.push(Some(h));
                    } else {
                        claims.push(None);
                    }
                    keep.push(w);
                }
                SlotState::Waiting => unreachable!("outstanding == 0"),
            }
        }

        let policy = self.jobs.get(&job_id).map(|j| j.policy).unwrap_or_default();
        let max_requeues = policy.max_requeues.unwrap_or(self.cfg.max_requeues);
        if any_failed {
            // A silent worker compromised this assignment: release the
            // survivors and re-delegate the segment to a fresh lease (a
            // buffered seed keeps its verified state — only the lease was
            // bad; a streamed seed is single-shot, so the re-queue falls
            // back to prefix re-training).
            self.pool.release(keep);
            if requeues < max_requeues && (self.pool.size() > 0 || self.pool.suspended() > 0) {
                self.metrics.registry.spans().trace(
                    job_id,
                    Some(seg_idx as u64),
                    Stage::Queue,
                    None,
                );
                self.queue.push(QueuedSeg {
                    kind: SegKind::Work,
                    priority: policy.priority,
                    job_id,
                    seg_idx,
                    spec,
                    seed: seed.for_requeue(),
                    requeues: requeues + 1,
                    revoked,
                    bytes,
                    requests,
                    t0: Some(t0),
                    leased_seq,
                });
            } else {
                self.record_segment(
                    job_id,
                    seg_idx,
                    SegmentOutcome {
                        requeues,
                        revoked,
                        wall: t0.elapsed(),
                        bytes,
                        requests,
                        leased_seq,
                        ..SegmentOutcome::unresolved(seg_idx, spec.steps)
                    },
                    None,
                );
            }
            return;
        }
        if commits == 0 {
            seed.abort_if_stream();
            if !seed.is_none() && requeues < max_requeues {
                // Every worker refused the seed wholesale. Blame is
                // unattributable (the seed itself could be at fault), so
                // nobody is disciplined — the segment falls back to prefix
                // re-training like any other seeded failure.
                self.pool.release(keep);
                self.metrics.registry.spans().trace(
                    job_id,
                    Some(seg_idx as u64),
                    Stage::Queue,
                    None,
                );
                self.queue.push(QueuedSeg {
                    kind: SegKind::Work,
                    priority: policy.priority,
                    job_id,
                    seg_idx,
                    spec,
                    seed: SeedSource::None,
                    requeues: requeues + 1,
                    revoked,
                    bytes,
                    requests,
                    t0: Some(t0),
                    leased_seq,
                });
                return;
            }
            // Everyone answered, nobody produced a claim: unresolvable.
            let eliminated = keep.len();
            let names = keep.iter().map(|w| w.name.clone()).collect();
            self.pool.release(keep);
            self.record_segment(
                job_id,
                seg_idx,
                SegmentOutcome {
                    workers: names,
                    eliminated,
                    requeues,
                    revoked,
                    wall: t0.elapsed(),
                    bytes,
                    requests,
                    leased_seq,
                    ..SegmentOutcome::unresolved(seg_idx, spec.steps)
                },
                None,
            );
            return;
        }

        let want_state = self.jobs.get(&job_id).is_some_and(|j| j.wants_state(seg_idx));
        let optimistic = self.jobs.get(&job_id).is_some_and(|j| j.optimistic());
        if optimistic {
            // Optimistic single-worker lease: the lone claim is accepted
            // provisionally as the worker's commitment; whether it gets
            // replay-audited is decided by the sampler when the resolver
            // hands the segment back. The dispatch seed is remembered so
            // a sampled replay starts from the same checkpoint the
            // committer did.
            let claimed = claims.iter().flatten().next().copied().expect("commits > 0");
            let seeded_from = seed.seeded_from();
            // Optimistic seeds are always buffered (the commitment fetch
            // binds the whole payload), and a sampled replay must start
            // from the exact checkpoint the committer did.
            let seed_buf = seed.into_buffered();
            if let Some(run) = self.jobs.get_mut(&job_id) {
                match &seed_buf {
                    Some(s) => {
                        run.seed_used.insert(seg_idx, Arc::clone(s));
                    }
                    None => {
                        run.seed_used.remove(&seg_idx);
                    }
                }
            }
            let start = self
                .jobs
                .get(&job_id)
                .map(|j| segment_start(&j.boundaries, seg_idx))
                .unwrap_or(0);
            let task = ResolveTask {
                job_id,
                seg_idx,
                start,
                end: spec.steps,
                spec,
                mode: ResolveMode::Commitment { claimed },
                want_state,
                seeded_from,
                t0,
                requeues,
                revoked,
                bytes,
                requests,
                leased_seq,
                workers: keep,
                registry: self.metrics.registry.clone(),
                cache: Arc::clone(&self.cache),
                stream_window: self.cfg.stream_window,
                max_checkpoint_bytes: self.cfg.max_checkpoint_bytes,
            };
            self.resolving_out += 1;
            self.task_tx.send(task).expect("resolver pool alive while segments outstanding");
            return;
        }
        let mode = match &seed {
            SeedSource::None => ResolveMode::Tournament,
            _ => {
                // Seeded lease: the optimistic fast path. All claims
                // agreeing certifies the boundary (the seed itself was
                // verified, and determinism makes every honest seeded run
                // commit identically). Any disagreement — or refusal —
                // falls back to prefix re-training, where the full dispute
                // protocol can assign blame; seeded trainers hold no
                // trajectory below their seed boundary, so bisection
                // cannot run against them.
                let first = claims.iter().flatten().next().copied();
                let agreed = claims.iter().all(|c| c.is_some() && *c == first);
                match (first, agreed) {
                    (Some(accepted), true) => {
                        let winner =
                            claims.iter().position(|c| c.is_some()).expect("commits > 0");
                        ResolveMode::Agreed { accepted, winner }
                    }
                    _ => {
                        seed.abort_if_stream();
                        self.pool.release(keep);
                        if requeues < max_requeues {
                            self.metrics.registry.spans().trace(
                                job_id,
                                Some(seg_idx as u64),
                                Stage::Queue,
                                None,
                            );
                            self.queue.push(QueuedSeg {
                                kind: SegKind::Work,
                                priority: policy.priority,
                                job_id,
                                seg_idx,
                                spec,
                                seed: SeedSource::None, // fall back to prefix re-training
                                requeues: requeues + 1,
                                revoked,
                                bytes,
                                requests,
                                t0: Some(t0),
                                leased_seq,
                            });
                        } else {
                            self.record_segment(
                                job_id,
                                seg_idx,
                                SegmentOutcome {
                                    requeues,
                                    revoked,
                                    wall: t0.elapsed(),
                                    bytes,
                                    requests,
                                    leased_seq,
                                    ..SegmentOutcome::unresolved(seg_idx, spec.steps)
                                },
                                None,
                            );
                        }
                        return;
                    }
                }
            }
        };

        let start = self
            .jobs
            .get(&job_id)
            .map(|j| segment_start(&j.boundaries, seg_idx))
            .unwrap_or(0);
        let task = ResolveTask {
            job_id,
            seg_idx,
            start,
            end: spec.steps,
            spec,
            mode,
            want_state,
            seeded_from: seed.seeded_from(),
            t0,
            requeues,
            revoked,
            bytes,
            requests,
            leased_seq,
            workers: keep,
            registry: self.metrics.registry.clone(),
            cache: Arc::clone(&self.cache),
            stream_window: self.cfg.stream_window,
            max_checkpoint_bytes: self.cfg.max_checkpoint_bytes,
        };
        self.resolving_out += 1;
        self.task_tx.send(task).expect("resolver pool alive while segments outstanding");
    }

    fn handle_resolved(&mut self, resolved: Resolved) {
        let Resolved { job_id, mut outcome, workers, seed, rejected, commitment } = resolved;
        self.resolving_out -= 1;
        let mut keep = Vec::new();
        for (i, w) in workers.into_iter().enumerate() {
            if rejected.contains(&i) {
                // The worker served a checkpoint upload contradicting the
                // certified state root: adversarial (or hopelessly
                // corrupt) — expel it outright, no parole.
                outcome.revoked += 1;
                self.gone.insert(w.name.clone());
                wal(
                    &mut self.journal,
                    &self.metrics,
                    JournalEntry::Revoke { worker: w.name.clone() },
                );
                self.pool.revoke(w);
            } else if w.faulted() {
                outcome.revoked += 1;
                self.discipline(w, false);
            } else {
                keep.push(w);
            }
        }
        self.pool.release(keep);
        let seg_idx = outcome.seg;
        if let SeedSource::Stream(stream) = seed {
            // The producer is (or will shortly be) fetching on the
            // resolver thread; its StreamDone must be awaited even if the
            // job is already gone.
            self.streams_out += 1;
            if !self.jobs.contains_key(&job_id) {
                stream.abort();
                return;
            }
            // Park this segment's outcome until the producer reports its
            // transfer accounting, and queue the successor NOW with the
            // stream as its seed — its lease acquisition (and the first
            // chunk dispatches) overlap the rest of the fetch.
            let run = self.jobs.get_mut(&job_id).expect("checked");
            if run.next_seg == seg_idx + 1 && run.next_seg < run.boundaries.len() {
                let next = run.next_seg;
                run.next_seg += 1;
                let end = run.boundaries[next];
                let spec = run.spec.prefix(end);
                let priority = run.policy.priority;
                self.parked.insert((job_id, seg_idx), outcome);
                self.metrics.registry.spans().trace(
                    job_id,
                    Some(next as u64),
                    Stage::Queue,
                    None,
                );
                self.queue.push(QueuedSeg {
                    kind: SegKind::Work,
                    priority,
                    job_id,
                    seg_idx: next,
                    spec,
                    seed: SeedSource::Stream(stream),
                    requeues: 0,
                    revoked: 0,
                    bytes: 0,
                    requests: 0,
                    t0: None,
                    leased_seq: 0,
                });
            } else {
                // No successor can consume it (it was queued by another
                // path in the meantime): discard the stream, park the
                // outcome for the producer's accounting all the same.
                stream.abort();
                self.parked.insert((job_id, seg_idx), outcome);
            }
            return;
        }
        if self.jobs.contains_key(&job_id) {
            let seed = seed.into_buffered();
            match commitment {
                Some((worker, commit)) => {
                    self.settle_optimistic(job_id, seg_idx, outcome, seed, worker, commit);
                }
                None => self.record_segment(job_id, seg_idx, outcome, seed),
            }
        }
        // else: the job was cancelled mid-resolve; leases returned, verdict
        // discarded.
    }

    /// A stream producer finished (or aborted): its source workers come
    /// home, and the producing segment's parked outcome — merged with the
    /// transfer accounting — finally records. Ordering is safe either
    /// way: `done[]` is indexed by segment, so the successor settling
    /// first cannot clash with this record.
    fn handle_stream_done(&mut self, done: StreamDone) {
        let StreamDone {
            job_id,
            seg_idx,
            workers,
            rejected,
            bytes,
            requests,
            transfer_bytes,
            peak,
            wall,
        } = done;
        self.streams_out -= 1;
        self.stream_peak = self.stream_peak.max(peak);
        self.metrics.stream_peak_bytes.set(self.stream_peak);
        let mut extra_revoked = 0usize;
        let mut keep = Vec::new();
        for (i, w) in workers.into_iter().enumerate() {
            if rejected.contains(&i) {
                // The source served chunks contradicting the certified
                // manifest: adversarial (or hopelessly corrupt) — expel
                // it outright, no parole.
                extra_revoked += 1;
                self.gone.insert(w.name.clone());
                wal(
                    &mut self.journal,
                    &self.metrics,
                    JournalEntry::Revoke { worker: w.name.clone() },
                );
                self.pool.revoke(w);
            } else if w.faulted() {
                extra_revoked += 1;
                self.discipline(w, false);
            } else {
                keep.push(w);
            }
        }
        self.pool.release(keep);
        let Some(mut outcome) = self.parked.remove(&(job_id, seg_idx)) else {
            // Cancelled (the cancel purged the parking spot): the workers
            // above still came home; nothing to record.
            return;
        };
        outcome.revoked += extra_revoked;
        outcome.uploads_rejected += rejected.len() as u32;
        outcome.bytes += bytes;
        outcome.requests += requests;
        outcome.transfer_bytes += transfer_bytes;
        outcome.wall = wall;
        if self.jobs.contains_key(&job_id) {
            self.record_segment(job_id, seg_idx, outcome, None);
        }
    }

    /// An optimistic segment came back from its resolver carrying the
    /// worker's commitment: flip the deterministic audit coin. Unsampled
    /// segments settle immediately; sampled segments lock the worker's
    /// stake, park the outcome, and queue a single-segment replay on an
    /// independent worker (seeded exactly as the committer was).
    fn settle_optimistic(
        &mut self,
        job_id: u64,
        seg_idx: usize,
        mut outcome: SegmentOutcome,
        seed: Option<Arc<SeedPayload>>,
        worker: String,
        commit: Hash,
    ) {
        let rate = self.jobs.get(&job_id).map(|j| j.policy.audit_rate).unwrap_or(0.0);
        if !self.sampler.sample(job_id, seg_idx as u64, rate) {
            self.record_segment(job_id, seg_idx, outcome, seed);
            return;
        }
        outcome.audit_sampled = true;
        let locked = self.ledger.lock(&worker);
        wal(
            &mut self.journal,
            &self.metrics,
            JournalEntry::StakeLock { worker: worker.clone(), amount: locked },
        );
        wal(
            &mut self.journal,
            &self.metrics,
            JournalEntry::AuditCommit {
                job_id,
                seg_idx: seg_idx as u64,
                worker: worker.clone(),
                root: commit,
            },
        );
        let Some(run) = self.jobs.get_mut(&job_id) else { return };
        let replay_seed = run.seed_used.get(&seg_idx).cloned();
        let spec = run.spec.prefix(run.boundaries[seg_idx]);
        let priority = run.policy.priority;
        run.audits.insert(
            seg_idx,
            AuditState::Pending {
                outcome: Box::new(outcome),
                seed_next: seed,
                accused: worker.clone(),
                expect: commit,
            },
        );
        self.metrics.registry.spans().trace(job_id, Some(seg_idx as u64), Stage::Queue, None);
        self.queue.push(QueuedSeg {
            kind: SegKind::Audit { accused: worker, expect: commit },
            priority,
            job_id,
            seg_idx,
            spec,
            seed: match replay_seed {
                Some(s) => SeedSource::Buffered(s),
                None => SeedSource::None,
            },
            requeues: 0,
            revoked: 0,
            bytes: 0,
            requests: 0,
            t0: None,
            leased_seq: 0,
        });
    }

    /// An audit replay's dispatch settled: compare the independent commit
    /// against the recorded commitment. A match settles the parked
    /// optimistic outcome; a divergence escalates the segment into a full
    /// tournament with the committer accused; a replay that failed to run
    /// retries on another worker or, out of retries, escalates unblamed.
    fn finish_audit(&mut self, ret: AuditReturn) {
        let AuditReturn {
            job_id,
            seg_idx,
            accused,
            expect,
            spec,
            seed,
            t0,
            requeues,
            mut revoked,
            bytes,
            requests,
            leased_seq,
            workers,
            slots,
        } = ret;
        let mut verdict: Option<Hash> = None;
        let mut failed = false;
        let mut keep: Vec<PooledWorker> = Vec::new();
        for (w, slot) in workers.into_iter().zip(slots) {
            match slot {
                SlotState::Failed => {
                    failed = true;
                    revoked += 1;
                    self.discipline(w, false);
                }
                SlotState::Done(resp) => {
                    // Byte accounting was folded in `handle_completion`.
                    if let Response::Commit(h) = resp {
                        verdict = Some(h);
                    }
                    keep.push(w);
                }
                SlotState::Waiting => unreachable!("outstanding == 0"),
            }
        }
        self.pool.release(keep);
        if !self.jobs.contains_key(&job_id) {
            // Cancelled mid-audit: the stake was released by
            // `handle_cancel` along with the parked outcome.
            return;
        }
        let policy = self.jobs.get(&job_id).map(|j| j.policy).unwrap_or_default();
        let max_requeues = policy.max_requeues.unwrap_or(self.cfg.max_requeues);
        // Steps the auditor actually re-trained (the whole prefix when the
        // committer also trained from scratch).
        let audit_steps = spec.steps - seed.seeded_from().unwrap_or(0);
        match verdict {
            Some(h) if h == expect => {
                // Independent replay reproduced the commitment: settle the
                // parked outcome and unlock the stake.
                self.ledger.release(&accused);
                wal(
                    &mut self.journal,
                    &self.metrics,
                    JournalEntry::StakeRelease { worker: accused.clone() },
                );
                wal(
                    &mut self.journal,
                    &self.metrics,
                    JournalEntry::AuditOutcome { job_id, seg_idx: seg_idx as u64, passed: true },
                );
                let Some(run) = self.jobs.get_mut(&job_id) else { return };
                let Some(AuditState::Pending { outcome, seed_next, .. }) =
                    run.audits.remove(&seg_idx)
                else {
                    return;
                };
                let mut outcome = *outcome;
                outcome.audit_passed = true;
                outcome.audit_steps += audit_steps;
                outcome.requeues += requeues;
                outcome.revoked += revoked;
                outcome.bytes += bytes;
                outcome.requests += requests;
                self.record_segment(job_id, seg_idx, outcome, seed_next);
            }
            Some(_) => {
                // The commitment and an independent replay disagree:
                // someone is lying. The full tournament — with the
                // committer re-leased into it — decides; a certified
                // verdict different from the commitment convicts and
                // slashes at settlement. The stake stays locked until
                // then.
                wal(
                    &mut self.journal,
                    &self.metrics,
                    JournalEntry::AuditOutcome { job_id, seg_idx: seg_idx as u64, passed: false },
                );
                self.escalate(
                    job_id,
                    seg_idx,
                    Some(accused),
                    audit_steps,
                    revoked,
                    bytes,
                    requests,
                    t0,
                    leased_seq,
                );
            }
            None if failed
                && requeues < max_requeues
                && (self.pool.size() > 0 || self.pool.suspended() > 0) =>
            {
                // The auditor went silent: retry the replay elsewhere.
                self.metrics.registry.spans().trace(
                    job_id,
                    Some(seg_idx as u64),
                    Stage::Queue,
                    None,
                );
                self.queue.push(QueuedSeg {
                    kind: SegKind::Audit { accused, expect },
                    priority: policy.priority,
                    job_id,
                    seg_idx,
                    spec,
                    seed,
                    requeues: requeues + 1,
                    revoked,
                    bytes,
                    requests,
                    t0: Some(t0),
                    leased_seq,
                });
            }
            None => {
                // The replay machinery failed (refusals or exhausted
                // retries), proving nothing about the committer: escalate
                // unblamed — replication instead of collateral.
                self.ledger.release(&accused);
                wal(
                    &mut self.journal,
                    &self.metrics,
                    JournalEntry::StakeRelease { worker: accused },
                );
                wal(
                    &mut self.journal,
                    &self.metrics,
                    JournalEntry::AuditOutcome { job_id, seg_idx: seg_idx as u64, passed: false },
                );
                self.escalate(
                    job_id, seg_idx, None, 0, revoked, bytes, requests, t0, leased_seq,
                );
            }
        }
    }

    /// A replay audit that can never run (no independent worker will ever
    /// be available) escalates unblamed.
    fn escalate_audit_failure(&mut self, seg: QueuedSeg) {
        let QueuedSeg { kind, job_id, seg_idx, revoked, bytes, requests, t0, leased_seq, .. } =
            seg;
        let SegKind::Audit { accused, .. } = kind else {
            unreachable!("only audit segments escalate from the lease pass");
        };
        self.ledger.release(&accused);
        wal(
            &mut self.journal,
            &self.metrics,
            JournalEntry::StakeRelease { worker: accused },
        );
        self.escalate(
            job_id,
            seg_idx,
            None,
            0,
            revoked,
            bytes,
            requests,
            t0.unwrap_or_else(Instant::now),
            leased_seq,
        );
    }

    /// Turn a sampled segment's parked `Pending` audit state into an
    /// `Escalated` one and re-queue the segment as a k-replicated prefix
    /// tournament. `convict` names the committer when a divergent replay
    /// proved the commitment wrong (the tournament verdict then decides
    /// the slash); `None` means the audit machinery itself failed and
    /// nobody is blamed. The whole optimistic tier is switched off for the
    /// rest of the job: later segments run k-replicated too.
    #[allow(clippy::too_many_arguments)]
    fn escalate(
        &mut self,
        job_id: u64,
        seg_idx: usize,
        convict: Option<String>,
        audit_steps: u64,
        revoked: usize,
        bytes: u64,
        requests: u64,
        t0: Instant,
        leased_seq: u64,
    ) {
        let Some(run) = self.jobs.get_mut(&job_id) else { return };
        run.escalated = true;
        run.pinned = None;
        let Some(AuditState::Pending { outcome: pending, expect, .. }) =
            run.audits.remove(&seg_idx)
        else {
            return;
        };
        run.audits.insert(
            seg_idx,
            AuditState::Escalated {
                accused: convict,
                expect,
                // The optimistic attempt's training is sunk cost now —
                // the tournament re-trains the prefix from scratch.
                audit_steps: pending.steps_trained + audit_steps,
            },
        );
        let spec = run.spec.prefix(run.boundaries[seg_idx]);
        let priority = run.policy.priority;
        let carried_revoked = pending.revoked + revoked;
        let carried_bytes = pending.bytes + bytes;
        let carried_requests = pending.requests + requests;
        let carried_seq = if pending.leased_seq != 0 { pending.leased_seq } else { leased_seq };
        self.metrics.registry.spans().trace(job_id, Some(seg_idx as u64), Stage::Queue, None);
        self.queue.push(QueuedSeg {
            kind: SegKind::Work,
            priority,
            job_id,
            seg_idx,
            // Prefix re-training: the seed chain above this boundary is
            // tainted by the disputed commitment.
            spec,
            seed: SeedSource::None,
            requeues: 0,
            revoked: carried_revoked,
            bytes: carried_bytes,
            requests: carried_requests,
            t0: Some(t0),
            leased_seq: carried_seq,
        });
    }

    /// Settle one segment, advance a state-transfer job's pipeline (queue
    /// the next segment — seeded when a verified checkpoint came back,
    /// prefix-fallback otherwise), and finalize the job once every segment
    /// settled.
    fn record_segment(
        &mut self,
        job_id: u64,
        seg_idx: usize,
        mut outcome: SegmentOutcome,
        seed: Option<Arc<SeedPayload>>,
    ) {
        let Some(run) = self.jobs.get_mut(&job_id) else { return };
        outcome.start = segment_start(&run.boundaries, seg_idx);
        run.seed_used.remove(&seg_idx);
        // A segment settling out of an escalated audit folds the audit
        // trail back in and decides the conviction: the tournament
        // certifying a verdict different from the recorded commitment
        // proves the committer lied — slash its locked stake. An
        // acquittal (same verdict) or an unattributed/unresolved ending
        // releases it.
        if let Some(AuditState::Escalated { accused, expect, audit_steps }) =
            run.audits.remove(&seg_idx)
        {
            outcome.audit_sampled = true;
            outcome.audit_escalated = true;
            outcome.audit_steps += audit_steps;
            if let Some(name) = accused {
                let convicted =
                    outcome.accepted.is_some() && outcome.accepted != Some(expect);
                if convicted {
                    outcome.slashed = self.ledger.slash(&name);
                    wal(
                        &mut self.journal,
                        &self.metrics,
                        JournalEntry::StakeSlash { worker: name, amount: outcome.slashed },
                    );
                } else {
                    self.ledger.release(&name);
                    wal(
                        &mut self.journal,
                        &self.metrics,
                        JournalEntry::StakeRelease { worker: name },
                    );
                }
            }
        }
        if run.done[seg_idx].is_none() {
            run.finished += 1;
            self.metrics.observe_settled(&outcome);
            let spans = self.metrics.registry.spans();
            if outcome.accepted.is_some() {
                let winner = outcome.winner.as_deref();
                spans.trace(job_id, Some(seg_idx as u64), Stage::Verdict, winner);
            }
            spans.trace(job_id, Some(seg_idx as u64), Stage::Settle, None);
            // A settled verdict (and its certified root) is a durability
            // boundary: journal and fsync before anything downstream acts
            // on it, so recovery can always trust it from the log.
            wal(
                &mut self.journal,
                &self.metrics,
                JournalEntry::SegmentSettled { job_id, outcome: outcome.clone() },
            );
            wal_sync(&mut self.journal, &self.metrics);
        }
        run.done[seg_idx] = Some(outcome);
        run.cell.set_running(run.finished, run.boundaries.len());
        let queue_next = (run.pipelined()
            && run.next_seg == seg_idx + 1
            && run.next_seg < run.boundaries.len())
        .then(|| {
            let next = run.next_seg;
            run.next_seg += 1;
            (next, run.boundaries[next], run.spec, run.policy.priority)
        });
        let job_done = run.finished >= run.boundaries.len();
        if let Some((next, end, spec, priority)) = queue_next {
            self.metrics.registry.spans().trace(job_id, Some(next as u64), Stage::Queue, None);
            self.queue.push(QueuedSeg {
                kind: SegKind::Work,
                priority,
                job_id,
                seg_idx: next,
                spec: spec.prefix(end),
                // No verified seed (failed fetch, unresolved predecessor,
                // non-unanimous roots) → the segment re-trains its prefix.
                seed: match seed {
                    Some(s) => SeedSource::Buffered(s),
                    None => SeedSource::None,
                },
                requeues: 0,
                revoked: 0,
                bytes: 0,
                requests: 0,
                t0: None,
                leased_seq: 0,
            });
        }
        if !job_done {
            return;
        }
        self.finalize_job(job_id);
    }

    /// Every segment settled: roll the job up, journal the settlement,
    /// and release the handle. (Also the re-finalization path for a
    /// recovered job whose segments had all settled before the crash.)
    fn finalize_job(&mut self, job_id: u64) {
        let run = self.jobs.remove(&job_id).expect("finalize of a live job");
        let segments: Vec<SegmentOutcome> =
            run.done.into_iter().map(|s| s.expect("all settled")).collect();
        let all_resolved = segments.iter().all(|s| s.accepted.is_some());
        let last = segments.last().expect("jobs have >= 1 segment");
        let outcome = JobOutcome {
            job_id,
            accepted: if all_resolved { last.accepted } else { None },
            winner: if all_resolved { last.winner.clone() } else { None },
            cancelled: false,
            disputes: segments.iter().map(|s| s.disputes).sum(),
            eliminated: segments.iter().map(|s| s.eliminated).sum(),
            requeues: segments.iter().map(|s| s.requeues).sum(),
            revoked: segments.iter().map(|s| s.revoked).sum(),
            wall: run.t0.map(|t| t.elapsed()).unwrap_or(Duration::ZERO),
            bytes: segments.iter().map(|s| s.bytes).sum(),
            requests: segments.iter().map(|s| s.requests).sum(),
            segments,
        };
        if outcome.accepted.is_some() {
            self.metrics.jobs_resolved.inc();
        }
        self.metrics.registry.spans().trace(job_id, None, Stage::Settle, None);
        wal(
            &mut self.journal,
            &self.metrics,
            JournalEntry::JobSettled { outcome: outcome.clone() },
        );
        wal_sync(&mut self.journal, &self.metrics);
        self.outcomes.push(outcome.clone());
        run.cell.finish(outcome);
    }

    /// Ping every idle worker when the health tick is due.
    fn health_sweep(&mut self) {
        if self.shutting_down {
            return;
        }
        let now = Instant::now();
        if !self.next_health.is_some_and(|h| h <= now) {
            return;
        }
        for mut w in self.pool.drain_idle() {
            self.actor_threads += usize::from(w.activate());
            let token = self.next_token;
            self.next_token += 1;
            let deadline = now + self.cfg.ping_deadline;
            w.reset_fault();
            self.tokens.insert(token, Target::Probe);
            self.deadlines.push(Reverse((deadline, token)));
            w.dispatch(token, Request::Ping, Some(deadline), &self.comp_tx);
            self.probing.insert(token, w);
        }
        self.next_health = self.cfg.health_check.map(|p| now + p);
    }

    /// Probe suspended workers whose backoff elapsed: answer → re-admit,
    /// silence → longer suspension or permanent expulsion.
    fn parole_sweep(&mut self) {
        if self.cfg.readmit_backoff.is_none() {
            return;
        }
        if self.shutting_down && self.jobs.is_empty() {
            return; // nothing left that could use a re-admitted worker
        }
        let now = Instant::now();
        for mut w in self.pool.parole_due(now) {
            self.actor_threads += usize::from(w.activate());
            w.reset_fault();
            let token = self.next_token;
            self.next_token += 1;
            let deadline = now + self.cfg.ping_deadline;
            self.tokens.insert(token, Target::Parole);
            self.deadlines.push(Reverse((deadline, token)));
            w.dispatch(token, Request::Ping, Some(deadline), &self.comp_tx);
            self.paroling.insert(token, w);
        }
    }
}

/// Start step (exclusive) of segment `seg_idx` given its job's boundaries.
fn segment_start(boundaries: &[u64], seg_idx: usize) -> u64 {
    if seg_idx == 0 {
        0
    } else {
        boundaries[seg_idx - 1]
    }
}

// ---------------------------------------------------------------------------
// batch compatibility wrappers
// ---------------------------------------------------------------------------

/// Run a batch of jobs against the pool with the event-driven core and
/// default tuning: `k` workers per job, per-dispatch deadlines, lease
/// revocation + re-queue, tournaments on a small resolver pool.
///
/// Compatibility wrapper: starts a [`Delegation`], submits every job,
/// waits, and returns the report — new code should hold the
/// [`Delegation`] and use [`Client::submit`](crate::service::client::Client::submit)
/// handles directly.
///
/// # Panics
/// If `k == 0` or `k > pool.size()`.
pub fn run_service(jobs: Vec<JobSpec>, pool: &WorkerPool, k: usize) -> ServiceReport {
    run_service_with(jobs, pool, ServiceConfig::new(k))
}

/// [`run_service`] with explicit tuning.
///
/// # Panics
/// If `cfg.k == 0` or `cfg.k > pool.size()`.
pub fn run_service_with(
    jobs: Vec<JobSpec>,
    pool: &WorkerPool,
    cfg: ServiceConfig,
) -> ServiceReport {
    let start_size = pool.size();
    assert!(cfg.k >= 1 && cfg.k <= start_size, "k={} vs pool of {start_size}", cfg.k);
    let delegation = Delegation::start(pool, cfg);
    let handles: Vec<_> =
        jobs.into_iter().map(|spec| delegation.submit(JobRequest::new(spec))).collect();
    for h in &handles {
        h.wait();
    }
    delegation.finish()
}

// ---------------------------------------------------------------------------
// blocking baseline (pre-event-core scheduler, kept for comparison)
// ---------------------------------------------------------------------------

/// Dispatch one job to its leased workers with thread-per-dispatch and
/// resolve it inline — the blocking baseline.
fn run_job_blocking(job_id: u64, spec: JobSpec, workers: &mut [PooledWorker]) -> JobOutcome {
    let t0 = Instant::now();
    let names: Vec<String> = workers.iter().map(|w| w.name.clone()).collect();
    let mut metered: Vec<Metered<&mut PooledWorker>> =
        workers.iter_mut().map(Metered::new).collect();

    // One OS thread per Train dispatch — the cost the event core removes.
    let trained: Vec<bool> = std::thread::scope(|scope| {
        let handles: Vec<_> = metered
            .iter_mut()
            .map(|m| {
                scope.spawn(move || matches!(m.call(Request::Train { spec }), Response::Commit(_)))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap_or(false)).collect()
    });

    if !trained.iter().any(|&ok| ok) {
        let bytes = metered.iter().map(|m| m.bytes_sent() + m.bytes_received()).sum();
        let requests = metered.iter().map(|m| m.counters.get("requests")).sum();
        return JobOutcome {
            job_id,
            accepted: None,
            winner: None,
            cancelled: false,
            disputes: 0,
            eliminated: names.len(),
            requeues: 0,
            revoked: 0,
            wall: t0.elapsed(),
            bytes,
            requests,
            segments: Vec::new(),
        };
    }

    let report = run_tournament(spec, &mut metered);
    let bytes = metered.iter().map(|m| m.bytes_sent() + m.bytes_received()).sum();
    let requests = metered.iter().map(|m| m.counters.get("requests")).sum();
    JobOutcome {
        job_id,
        accepted: Some(report.accepted),
        winner: Some(names[report.winner].clone()),
        cancelled: false,
        disputes: report.disputes,
        eliminated: report.eliminated.len(),
        requeues: 0,
        revoked: 0,
        wall: t0.elapsed(),
        bytes,
        requests,
        segments: Vec::new(),
    }
}

/// The pre-event-core scheduler: `pool.size() / k` lanes drain the queue,
/// each lane blocking on its lease and spawning one thread per Train
/// dispatch. No deadlines, no revocation, no sharding — a hung worker
/// stalls its lane forever. Kept as the baseline the benches compare the
/// event core against (and as a worked example of the blocking `Endpoint`
/// path).
pub fn run_service_blocking(jobs: Vec<JobSpec>, pool: &WorkerPool, k: usize) -> ServiceReport {
    assert!(k >= 1 && k <= pool.size(), "k={k} vs pool of {}", pool.size());
    let start_size = pool.size();
    let n_jobs = jobs.len();
    let queue: Mutex<VecDeque<(u64, JobSpec)>> =
        Mutex::new(jobs.into_iter().enumerate().map(|(i, s)| (i as u64, s)).collect());
    let outcomes: Mutex<Vec<JobOutcome>> = Mutex::new(Vec::with_capacity(n_jobs));
    let lanes = (start_size / k).clamp(1, n_jobs.max(1));

    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..lanes {
            scope.spawn(|| loop {
                let next = queue.lock().unwrap().pop_front();
                let Some((job_id, spec)) = next else { break };
                let mut lease = pool.acquire(k);
                let outcome = run_job_blocking(job_id, spec, &mut lease);
                pool.release(lease);
                outcomes.lock().unwrap().push(outcome);
            });
        }
    });
    let mut outcomes = outcomes.into_inner().unwrap();
    outcomes.sort_by_key(|o| o.job_id);
    ServiceReport {
        outcomes,
        wall: t0.elapsed(),
        k,
        workers: start_size,
        revoked: pool.revoked(),
        threads: lanes * (1 + k),
        stakes: Vec::new(),
        overloads: 0,
        ckpt_cache_hits: 0,
        ckpt_cache_misses: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Preset;
    use crate::service::worker::{FaultPlan, WorkerHost};
    use crate::verde::trainer::TrainerNode;

    fn jobs(n: u64, steps: u64) -> Vec<JobSpec> {
        (0..n)
            .map(|i| {
                let mut spec = JobSpec::quick(Preset::Mlp, steps);
                spec.data_seed = spec.data_seed.wrapping_add(i * 1047);
                spec
            })
            .collect()
    }

    fn in_process_pool(plans: &[FaultPlan]) -> WorkerPool {
        WorkerPool::new(
            plans
                .iter()
                .enumerate()
                .map(|(i, &plan)| {
                    PooledWorker::new(&format!("w{i}"), WorkerHost::new(&format!("w{i}"), plan))
                })
                .collect(),
        )
    }

    #[test]
    fn all_honest_jobs_resolve_without_disputes() {
        let pool = in_process_pool(&[FaultPlan::Honest, FaultPlan::Honest]);
        let report = run_service(jobs(4, 4), &pool, 2);
        assert_eq!(report.outcomes.len(), 4);
        for o in &report.outcomes {
            assert!(o.accepted.is_some());
            assert_eq!(o.disputes, 0);
            assert_eq!(o.eliminated, 0);
            assert_eq!(o.requeues, 0);
            assert_eq!(o.revoked, 0);
            assert!(!o.cancelled);
            assert!(o.bytes > 0);
            assert_eq!(o.segments.len(), 1, "default policy is unsharded");
            assert_eq!(o.segments[0].end, 4);
            assert_eq!(o.segments[0].accepted, o.accepted);
        }
        assert_eq!(report.total_disputes(), 0);
        assert!(report.revoked.is_empty());
        assert!(report.jobs_per_sec() > 0.0);
    }

    #[test]
    fn faulty_worker_is_beaten_on_every_job() {
        let pool = in_process_pool(&[
            FaultPlan::Honest,
            FaultPlan::Tamper { step: Some(2), delta: 0.05 },
        ]);
        let js = jobs(3, 5);
        let expected: Vec<Hash> =
            js.iter().map(|s| TrainerNode::honest("ref", *s).train()).collect();
        let report = run_service(js, &pool, 2);
        for (o, want) in report.outcomes.iter().zip(&expected) {
            assert_eq!(o.accepted, Some(*want), "job {}", o.job_id);
            assert_eq!(o.winner.as_deref(), Some("w0"));
            assert_eq!(o.disputes, 1);
            assert_eq!(o.eliminated, 1);
        }
    }

    #[test]
    fn lanes_run_jobs_concurrently_from_one_queue() {
        // 4 workers, k=2: several jobs in flight at once off one queue; 6
        // jobs must all resolve exactly once and every lease must return.
        let pool = in_process_pool(&[FaultPlan::Honest; 4]);
        let report = run_service(jobs(6, 3), &pool, 2);
        assert_eq!(report.outcomes.len(), 6);
        let ids: Vec<u64> = report.outcomes.iter().map(|o| o.job_id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(pool.idle(), 4, "all leases returned");
        let json = report.to_json();
        assert!(json.contains("\"jobs\":6"), "{json}");
        assert!(json.contains("\"resolved\":6"), "{json}");
        assert!(json.contains("\"cancelled\":0"), "{json}");
        assert!(json.contains("\"requeued\":0"), "{json}");
        assert!(json.contains("\"eliminated\":0"), "{json}");
    }

    #[test]
    fn blocking_baseline_still_resolves_the_batch() {
        let pool = in_process_pool(&[
            FaultPlan::Honest,
            FaultPlan::Honest,
            FaultPlan::WrongData { step: Some(2) },
        ]);
        let report = run_service_blocking(jobs(4, 4), &pool, 3);
        assert_eq!(report.outcomes.len(), 4);
        for o in &report.outcomes {
            assert!(o.accepted.is_some());
            assert_eq!(o.eliminated, 1, "the poisoner is convicted each job");
        }
        assert!(report.threads >= 4, "thread-per-dispatch baseline");
    }

    #[test]
    fn stalled_worker_is_revoked_and_job_requeues() {
        // w2 stalls on its very first request (the Train dispatch): its
        // deadline fires, its lease is revoked, the job re-queues and
        // completes on the two honest survivors.
        let pool = in_process_pool(&[
            FaultPlan::Honest,
            FaultPlan::Honest,
            FaultPlan::Stall { at_request: 1 },
        ]);
        let js = jobs(3, 3);
        let expected: Vec<Hash> =
            js.iter().map(|s| TrainerNode::honest("ref", *s).train()).collect();
        let mut cfg = ServiceConfig::new(2);
        cfg.dispatch_deadline = Duration::from_millis(800);
        let report = run_service_with(js, &pool, cfg);

        assert_eq!(report.outcomes.len(), 3);
        for o in &report.outcomes {
            assert_eq!(o.accepted, Some(expected[o.job_id as usize]), "job {}", o.job_id);
        }
        assert_eq!(report.revoked, vec!["w2".to_string()]);
        assert_eq!(pool.size(), 2, "pool shrank by the revoked worker");
        assert_eq!(pool.idle(), 2, "surviving leases all returned");
        assert_eq!(report.total_requeued(), 1, "exactly one job paid a re-queue");
        let victim: Vec<&JobOutcome> =
            report.outcomes.iter().filter(|o| o.requeues > 0).collect();
        assert_eq!(victim.len(), 1);
        assert_eq!(victim[0].revoked, 1);
        let json = report.to_json();
        assert!(json.contains("\"requeued\":1"), "{json}");
        assert!(json.contains("\"revoked\":1"), "{json}");
    }

    #[test]
    fn health_check_ping_revokes_stalled_idle_worker() {
        // w1 never answers anything. A long dispatch deadline keeps the
        // dispatch path from catching it; the health-check ping must. The
        // single job runs on w0 while w1 idles, gets pinged, misses the
        // ping deadline, and is revoked.
        let pool = in_process_pool(&[
            FaultPlan::Honest,
            FaultPlan::Stall { at_request: 1 },
        ]);
        let mut cfg = ServiceConfig::new(1);
        cfg.dispatch_deadline = Duration::from_secs(60);
        cfg.health_check = Some(Duration::from_millis(1));
        cfg.ping_deadline = Duration::from_millis(120);
        let report = run_service_with(jobs(1, 8), &pool, cfg);

        assert_eq!(report.outcomes.len(), 1);
        assert!(report.outcomes[0].accepted.is_some());
        assert_eq!(report.revoked, vec!["w1".to_string()]);
        assert_eq!(pool.size(), 1);
        assert_eq!(pool.idle(), 1);
    }

    #[test]
    fn exhausted_requeues_report_unresolved_not_hang() {
        // Every worker stalls: each attempt revokes the whole lease, and
        // once the pool is empty the job must be reported unresolved
        // rather than hanging the coordinator.
        let pool = in_process_pool(&[
            FaultPlan::Stall { at_request: 1 },
            FaultPlan::Stall { at_request: 1 },
        ]);
        let mut cfg = ServiceConfig::new(2);
        cfg.dispatch_deadline = Duration::from_millis(200);
        cfg.max_requeues = 4;
        let report = run_service_with(jobs(1, 3), &pool, cfg);
        assert_eq!(report.outcomes.len(), 1);
        assert!(report.outcomes[0].accepted.is_none());
        assert_eq!(report.outcomes[0].revoked, 2, "both stallers revoked");
        assert_eq!(pool.size(), 0, "nobody left");
        assert_eq!(report.revoked.len(), 2);
    }

    #[test]
    fn empty_report_stats_are_zero_not_nan() {
        // A just-started (or immediately finished) delegation has no
        // outcomes; every derived statistic must be finite.
        let report = ServiceReport {
            outcomes: Vec::new(),
            wall: Duration::ZERO,
            k: 2,
            workers: 4,
            revoked: Vec::new(),
            threads: 5,
            stakes: Vec::new(),
            overloads: 0,
            ckpt_cache_hits: 0,
            ckpt_cache_misses: 0,
        };
        assert_eq!(report.jobs_per_sec(), 0.0);
        assert_eq!(report.bytes_per_job(), 0.0);
        assert_eq!(report.mean_latency(), Duration::ZERO);
        assert!(report.jobs_per_sec().is_finite());
        assert!(report.bytes_per_job().is_finite());
        let json = report.to_json();
        assert!(json.contains("\"jobs\":0"), "{json}");
        assert!(!json.contains("NaN"), "{json}");

        // The same holds for a live delegation that is finished with no
        // jobs ever submitted.
        let pool = in_process_pool(&[FaultPlan::Honest]);
        let d = Delegation::start(&pool, ServiceConfig::new(1));
        let report = d.finish();
        assert_eq!(report.outcomes.len(), 0);
        assert_eq!(report.jobs_per_sec(), 0.0);
        assert_eq!(report.bytes_per_job(), 0.0);
        assert_eq!(report.mean_latency(), Duration::ZERO);
    }
}
