//! The delegation **service**: an event-driven coordinator that accepts
//! many training jobs, schedules each onto `k` workers drawn from a shared
//! pool, collects final commitments off a completion queue, and resolves
//! disagreements with concurrent dispute tournaments — the deployment shape
//! of the paper's client/trainers/referee topology at many-jobs scale, with
//! the untrusted-provider failure modes (hangs, dead sockets) handled by
//! per-request deadlines and lease revocation.
//!
//! * [`pool`] — the leasable worker free-list. Jobs acquire `k` workers
//!   atomically; a worker that misses a dispatch deadline or health-check
//!   ping is **revoked** (never returns, pool shrinks). Each
//!   [`pool::PooledWorker`] fronts a blocking endpoint, an actor thread, or
//!   a multiplexed TCP connection behind one non-blocking dispatch surface.
//! * [`worker`] — [`worker::WorkerHost`]: the worker-process brain. It
//!   accepts [`Request::Train`](crate::verde::protocol::Request) job
//!   assignments, runs them through a
//!   [`TrainerNode`](crate::verde::trainer::TrainerNode) (honestly or under
//!   a configured [`worker::FaultPlan`], including
//!   [`worker::FaultPlan::Stall`] — hanging mid-protocol), answers
//!   health-check pings, and serves dispute queries for the active job.
//! * [`coordinator`] — [`coordinator::run_service`]: per-job state machines
//!   driven off one completion queue by a single event-loop thread plus a
//!   small tournament-resolver pool; deadline expiry → lease revocation →
//!   job re-queue. The thread-per-dispatch baseline survives as
//!   [`coordinator::run_service_blocking`].
//!
//! Workers can live anywhere an [`Endpoint`](crate::net::Endpoint) can:
//! in-process, on threads ([`crate::net::threaded`]), or in separate
//! processes over TCP — blocking ([`crate::net::tcp`]) or multiplexed
//! ([`crate::net::mux`], thousands of workers per coordinator thread).

pub mod coordinator;
pub mod pool;
pub mod worker;

pub use coordinator::{
    run_service, run_service_blocking, run_service_with, JobOutcome, ServiceConfig, ServiceReport,
};
pub use pool::{PooledWorker, WorkerPool};
pub use worker::{FaultPlan, WorkerHost};
