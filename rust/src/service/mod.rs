//! The delegation **service**: a long-lived, handle-based client API over
//! an event-driven coordinator. A [`client::Delegation`] accepts jobs one
//! at a time from persistent [`client::Client`]s, shards each into
//! checkpoint-delimited segments, schedules segments onto `k`-worker
//! subsets drawn from a shared pool, collects final commitments off a
//! completion queue, and resolves disagreements with concurrent dispute
//! tournaments — the deployment shape of the paper's
//! client/trainers/referee topology at many-jobs scale, with the
//! untrusted-provider failure modes (hangs, dead sockets, transient
//! slowness) handled by per-request deadlines, lease suspension with
//! exponential-backoff re-admission, and permanent revocation.
//!
//! ## Client & handle lifecycle
//!
//! ```text
//!   Delegation::start(&pool, cfg)
//!       │                             per job:
//!       ├── client() ─▶ Client ──submit(JobRequest{spec, policy})──▶ JobHandle
//!       │                                                            │  │  │
//!       │        ┌───────────────────────────────────────────────────┘  │  │
//!       │     wait() ─▶ JobOutcome         try_status() ─▶ Queued ◀─────┘  │
//!       │     (terminal, per-segment          │ Running{done,total}        │
//!       │      verdicts rolled up)            │ Done(outcome)              │
//!       │                                                                  │
//!       │     cancel() ─▶ queued segments dropped; in-flight leases ◀──────┘
//!       │                 drain back to the pool as their dispatches
//!       │                 settle; handle resolves Done immediately
//!       │                 (outcome.cancelled == true)
//!       └── finish() ─▶ ServiceReport (drain, join, aggregate)
//! ```
//!
//! ## Segment sharding
//!
//! A job with `policy.segments = m` is split at the Phase-1
//! [`split_points`](crate::train::checkpoint::split_points) boundaries
//! `b_1 < … < b_m = steps`; segment `i` is the prefix job
//! `spec.prefix(b_i)`. Determinism makes a prefix job's final commitment
//! equal the full job's checkpoint commitment at that boundary, so
//! per-segment tournaments certify the job's checkpoint chain and the
//! final segment's verdict **is** the unsharded job's verdict. Segments
//! schedule independently — different worker subsets, concurrently when
//! the pool has capacity, re-queued individually on worker failure — and
//! roll up into one [`coordinator::JobOutcome`] (`segments` holds the
//! per-boundary verdicts).
//!
//! ## Verified checkpoint state-transfer (`policy.transfer`)
//!
//! By default segment `i` **re-trains the whole prefix** `[0, b_i]`, so a
//! sharded job pays `Σ b_i` training steps per worker instead of `steps`.
//! With `JobRequest::with_state_transfer()` the coordinator moves the
//! verified boundary checkpoint between segments instead, so segment `i`
//! trains only `b_i − b_{i−1}` steps and the whole job costs exactly
//! `k × steps` worker-steps:
//!
//! ```text
//!  segment i−1                    coordinator                    segment i
//!  ┌─────────┐   verdict          ┌─────────────────────┐
//!  │ k leases│──(tournament)────▶ │ FETCH  chunked       │
//!  │  (done) │◀──FetchCheckpoint──│  checkpoint from the │
//!  │         │───Checkpoint{root}▶│  winning group       │
//!  └─────────┘                    │ VERIFY Merkle root   │
//!                                 │  over state leaves,  │
//!                                 │  unanimous across    │
//!                                 │  co-winners          │   ┌─────────┐
//!                                 │ SEED   chunked      ─┼──▶│ k fresh │
//!                                 │  SeedCheckpoint      │   │ leases  │
//!                                 │ SCHEDULE train       │   │ train   │
//!                                 │  b_i − b_{i−1} steps │   │ delta   │
//!                                 └─────────────────────┘   └─────────┘
//! ```
//!
//! *Verification.* The serialized state
//! ([`encode_state`](crate::train::checkpoint::encode_state)) is checked
//! against the Merkle root over its state leaves
//! ([`State::state_root`](crate::graph::executor::State::state_root)).
//! The root is certified by **unanimity across the winning group** (every
//! worker whose final claim equals the accepted hash): under the
//! protocol's standing assumption — at least one honest worker per lease —
//! an accepted-honest claim puts every honest worker in that group, so a
//! unanimous root is the honest root. A bit-flipped upload fails
//! verification, costs the uploader its lease, and the fetch moves to a
//! surviving co-winner. Seeded workers re-verify the root before training.
//!
//! *Fallback semantics* (every failure degrades to the safe path, never a
//! wedged job):
//!
//! | failure                                   | consequence                         |
//! |-------------------------------------------|-------------------------------------|
//! | upload fails Merkle verification          | uploader revoked; next co-winner    |
//! | every group upload fails                  | next segment re-trains its prefix   |
//! | winning group splits on the state root    | next segment re-trains its prefix   |
//! | seeded lease disagrees on the commitment  | segment re-queued **as prefix** (the dispute protocol needs the full trajectory, which seeded trainers don't hold) |
//! | seeded worker misses its deadline         | lease disciplined, segment re-queued with the same verified seed |
//!
//! Segments pipeline under transfer (each needs its predecessor's state),
//! so the trade is concurrency-across-segments for `Σ b_i → steps` total
//! work; per-segment accounting
//! ([`SegmentOutcome`](coordinator::SegmentOutcome)`::steps_trained`,
//! `seeded_from`, `transfer_bytes`, `uploads_rejected`) makes the saving
//! observable in every report.
//!
//! *Streaming pipeline.* Replicated (non-commitment) transfers never
//! materialize the whole checkpoint at the coordinator. The resolver
//! first certifies a **chunk manifest** — `(root, total_len, per-chunk
//! hashes)`, unanimous across the winning group, clamped by
//! `ServiceConfig::max_checkpoint_bytes` — and then fetches 1 MiB chunks
//! one at a time, verifying each against the manifest and handing it
//! through a bounded chunk stream (`transfer::ChunkStream`) to the
//! successor's already-leased workers (`ServiceConfig::stream_window`
//! chunks in flight, gated on per-slot acks). Successor lease acquisition
//! overlaps the fetch, and resident bytes stay `O(window × chunk)`
//! instead of `O(checkpoint)`. A content-addressed checkpoint cache
//! (budget `ServiceConfig::ckpt_cache_bytes`, keyed by certified state
//! root + boundary) short-circuits repeat fetches of a root that was
//! already certified — a cache hit seeds the successor with **zero**
//! transfer traffic. Chunks failing verification reject their source
//! (revoked, fetch rotates to a co-winner); a stream that dies mid-seed
//! falls back to prefix re-training like any other transfer failure.
//!
//! | key                       | kind    | meaning                                      |
//! |---------------------------|---------|----------------------------------------------|
//! | `coord_ckpt_cache_hits`   | counter | seeds served from the checkpoint cache       |
//! | `coord_ckpt_cache_misses` | counter | certified roots not found in the cache       |
//! | `coord_ckpt_cache_bytes`  | gauge   | bytes currently held by the cache            |
//! | `coord_stream_peak_bytes` | gauge   | high-water mark of in-flight stream buffers  |
//! | `coord_overloads`         | counter | dispatches refused by a full mux write buffer |
//!
//! ## Staked spot-check audit tier (`policy.audit_rate`)
//!
//! Replication pays `k × steps` worker-steps on every job, honest or
//! not. With `JobRequest::with_audit(rate)` a job instead runs
//! **optimistically**: the coordinator pins the whole job to **one**
//! staked worker (enrolled in the [`audit::StakeLedger`] at
//! `ServiceConfig::worker_stake`), which trains every segment and
//! commits each boundary checkpoint root
//! (`Request::CommitRoot`). A seeded deterministic sampler
//! ([`audit::AuditSampler`], keyed by `ServiceConfig::audit_seed`) then
//! flips a coin per committed segment at `audit_rate`; sampled segments
//! are **replayed once** on an independent worker seeded from the same
//! verified predecessor checkpoint (single-segment replay — no prefix
//! re-training), and the replayed root is compared against the
//! commitment.
//!
//! *Cost model.* Expected worker-steps per job ≈ `(1 + audit_rate) ×
//! steps`, versus `k × steps` replicated — at `audit_rate = 0.1` an
//! honest fleet does ~55% of the `k = 2` work. The audit replay is a
//! single segment, so even a sampled segment costs `steps + seg_len`,
//! never `2 × prefix`.
//!
//! *Escalation lifecycle* (every arrow is crash-safe; a wedged audit
//! degrades to replication, never a stuck job):
//!
//! ```text
//!   commit ──sampler──▶ unsampled ───────────────────────▶ settle
//!     │                                                      ▲
//!     └─▶ sampled: lock stake, replay on another worker      │
//!              │                                             │
//!              ├── replay root == commitment ── release ─────┘
//!              │
//!              └── divergence (or replay impossible)
//!                       │
//!                       ▼
//!              ESCALATE: re-queue segment as a k-replicated
//!              prefix job, accused preferentially re-leased
//!              (k ≥ 2) so the dispute tournament can bisect it
//!                       │
//!                       ├── certified verdict ≠ commitment:
//!                       │     StakeLedger::slash (confiscate the
//!                       │     locked stake); job continues
//!                       │     k-replicated (`escalated`)
//!                       └── commitment upheld / accused gone:
//!                             stake released, honest verdict stands
//! ```
//!
//! Safety is inherited, not assumed: a divergent audit never settles on
//! the auditor's word — it hands the segment to the existing
//! bisection-tournament machinery, which certifies the honest root under
//! the same one-honest-worker-per-lease assumption as replicated jobs.
//! The sampler is deterministic in `(audit_seed, job_id, seg_idx)`, so a
//! worker cannot learn whether a segment will be audited before
//! committing to it (the seed is coordinator-private), while operators
//! can replay sampling decisions exactly.
//!
//! Per-segment accounting lands in
//! [`SegmentOutcome`](coordinator::SegmentOutcome) (`audit_sampled`,
//! `audit_passed`, `audit_escalated`, `audit_steps`, `slashed`) and
//! rolls up through [`coordinator::ServiceReport`] (`total_audit_*`,
//! `total_slashed`, plus the closing [`audit::StakeEntry`] snapshot in
//! `report.stakes`). The obs registry mirrors the same settling
//! outcomes:
//!
//! | key                     | kind    | meaning                                      |
//! |-------------------------|---------|----------------------------------------------|
//! | `coord_audit_sampled`   | counter | segments picked for replay by the sampler    |
//! | `coord_audit_passed`    | counter | replays whose root matched the commitment    |
//! | `coord_audit_escalated` | counter | divergent/failed audits sent to a tournament |
//! | `coord_audit_steps`     | counter | extra worker-steps spent on audit replays    |
//! | `coord_stake_slashed`   | counter | total stake confiscated by convictions       |
//! | `coord_stake_locked`    | gauge   | stake currently locked pending audits        |
//!
//! ## Durability: the write-ahead journal (`--journal PATH`)
//!
//! The coordinator is the protocol's referee; [`journal`] makes its memory
//! survive the process. A delegation started with
//! [`client::Delegation::start_durable`] appends one
//! [`journal::JournalEntry`] per state transition — job submission (full
//! spec + policy), lease grants, worker revocations, per-segment settled
//! verdicts (the certified roots), audit commitments and outcomes, stake
//! lock/release/slash, and final job settlement — to an append-only file,
//! each entry framed by the canonical wire codec (`u32`-LE length prefix +
//! canonical payload; `wire_size() == encode().len()`; total decoding on
//! hostile bytes).
//!
//! *Fsync policy.* Write-ahead, group-committed: entries buffer in process
//! and the file is fsync'd at **settlement boundaries** — job submission
//! acknowledged, segment settled, job settled, job cancelled. Cheap
//! high-frequency records (leases, audit commits, stake locks) ride the
//! next boundary sync: losing them in a crash is safe because recovery
//! re-queues the affected segment anyway. What is never lost is an
//! acknowledged verdict.
//!
//! *Recovery lifecycle.* [`client::Delegation::recover`] replays the file
//! (tolerating a torn final entry — the partial frame is truncated away),
//! folds it keyed by job/segment/worker (last write wins, so recovery is
//! idempotent across repeated crashes), and rebuilds the delegation:
//! settled jobs come back as already-`Done` handles serving the logged,
//! bit-identical outcome; in-flight jobs re-queue **only their unsettled
//! segments** (settled verdicts and certified roots are trusted from the
//! log — recovery cost is proportional to work lost, not work done);
//! stakes locked behind audits that died with the process are released
//! (and the release journaled); permanently revoked workers stay revoked;
//! the job-id counter resumes past every journaled id.
//!
//! *Handle re-attach.* Remote clients hold job ids, not sockets: feed the
//! recovered handles to [`client::DelegationFrontend::adopt`] and a
//! pre-crash `Status { job_id }` answers with the job's live (or settled)
//! state on the recovered coordinator. Ids evicted past the frontend's
//! retention cap answer `Unknown`, never hang.
//!
//! | key                                | kind    | meaning                                   |
//! |------------------------------------|---------|-------------------------------------------|
//! | `coord_journal_entries`            | counter | entries appended this process             |
//! | `coord_journal_bytes`              | counter | bytes appended this process               |
//! | `coord_journal_syncs`              | counter | fsync batches (settlement boundaries)     |
//! | `coord_journal_replayed_entries`   | counter | whole entries replayed at recovery        |
//! | `coord_journal_replayed_segments`  | counter | settled segments trusted from the log     |
//! | `coord_journal_recovered_jobs`     | counter | in-flight jobs re-queued at recovery      |
//!
//! ## Observability (the stats plane)
//!
//! Every delegation owns a private [`crate::obs::Registry`]
//! ([`client::Delegation::registry`]): the event loop records `coord_*`
//! counters, queue/pool gauges, and a tick-duration histogram, and — when
//! span tracing is enabled via `registry().spans().enable()` — the full
//! per-job lifecycle timeline (submit → queue → lease → dispatch →
//! fetch/verify/seed → verdict → settle). Registry totals are folded from
//! the same settling [`coordinator::SegmentOutcome`]s the report
//! aggregates, so they reconcile **exactly** with
//! [`coordinator::ServiceReport`]; `tests/obs_stats.rs` asserts the
//! equality. Live access: [`client::Delegation::stats`] in-process,
//! `Request::Stats` over the wire against a
//! [`client::DelegationFrontend::with_stats`] frontend or any
//! [`worker::WorkerHost`] (which serves its own `worker_*` registry), and
//! `verde stats --from host:port` on the command line. The key catalog
//! lives in `rust/README.md`.
//!
//! ## Migration from `run_service`
//!
//! `run_service(jobs, &pool, k)` and `run_service_with(jobs, &pool, cfg)`
//! survive as wrappers (submit everything, wait, [`Delegation::finish`])
//! so existing callers compile unchanged. New code should hold a
//! [`client::Delegation`] and submit through handles; remote callers use
//! the wire API (`Submit` / `Status` / `Cancel` requests in
//! [`crate::verde::protocol`]) against a [`client::DelegationFrontend`]
//! served over TCP.
//!
//! * [`pool`] — the leasable worker free-list. Segments acquire `k`
//!   workers atomically, filtered by the job's backend requirement; a
//!   worker that misses a deadline is **suspended** (with parole +
//!   re-admission) or **revoked** (permanent, pool shrinks). Each
//!   [`pool::PooledWorker`] fronts a blocking endpoint, an actor thread,
//!   or a multiplexed TCP connection behind one non-blocking dispatch
//!   surface, and advertises the [`Backend`](crate::graph::kernels::Backend)
//!   it runs on.
//! * [`worker`] — [`worker::WorkerHost`]: the worker-process brain. It
//!   accepts [`Request::Train`](crate::verde::protocol::Request) job
//!   assignments, runs them through a
//!   [`TrainerNode`](crate::verde::trainer::TrainerNode) (honestly or under
//!   a configured [`worker::FaultPlan`], including
//!   [`worker::FaultPlan::Stall`] — hanging mid-protocol — and
//!   [`worker::FaultPlan::Nap`] — transiently slow), answers health-check
//!   pings, and serves dispute queries for the active job.
//! * [`coordinator`] — the persistent event loop: per-segment state
//!   machines driven off one completion queue by a single event-loop
//!   thread plus a small tournament-resolver pool; deadline expiry →
//!   suspension/revocation → segment re-queue. The thread-per-dispatch
//!   baseline survives as [`coordinator::run_service_blocking`].
//! * [`client`] — [`client::Delegation`], [`client::Client`],
//!   [`client::JobHandle`], and the wire-facing
//!   [`client::DelegationFrontend`].
//! * [`journal`] — the append-only write-ahead journal and the recovery
//!   fold ([`journal::replay`] / [`journal::recover`]) behind
//!   [`client::Delegation::recover`].
//!
//! Workers can live anywhere an [`Endpoint`](crate::net::Endpoint) can:
//! in-process, on threads ([`crate::net::threaded`]), or in separate
//! processes over TCP — blocking ([`crate::net::tcp`]) or multiplexed
//! ([`crate::net::mux`], thousands of workers per coordinator thread).

pub mod audit;
pub mod client;
pub mod coordinator;
pub mod journal;
pub mod pool;
pub(crate) mod transfer;
pub mod worker;

pub use audit::{AuditSampler, StakeEntry, StakeLedger};
pub use journal::{Journal, JournalEntry, Recovery, Replay};
pub use client::{Client, Delegation, DelegationFrontend, JobHandle, JobRequest, JobStatus};
pub use coordinator::{
    run_service, run_service_blocking, run_service_with, JobOutcome, SegmentOutcome,
    ServiceConfig, ServiceReport,
};
pub use pool::{PooledWorker, WorkerPool};
pub use worker::{FaultPlan, WorkerHost};

pub use crate::verde::protocol::{BackendRequirement, JobPolicy, RemoteStatus};
