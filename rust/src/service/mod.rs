//! The delegation **service**: a coordinator that accepts many training
//! jobs, schedules each onto `k` workers drawn from a shared pool, collects
//! final commitments, and resolves disagreements with concurrent dispute
//! tournaments — the deployment shape of the paper's client/trainers/referee
//! topology at many-jobs scale.
//!
//! * [`pool`] — a blocking free-list of worker endpoints; jobs acquire `k`
//!   workers atomically and return them when resolved.
//! * [`worker`] — [`worker::WorkerHost`]: the worker-process brain. It
//!   accepts [`Request::Train`](crate::verde::protocol::Request) job
//!   assignments, runs them through a
//!   [`TrainerNode`](crate::verde::trainer::TrainerNode) (honestly or under
//!   a configured [`worker::FaultPlan`]), and then answers dispute queries
//!   for the active job.
//! * [`coordinator`] — [`coordinator::run_service`]: the job queue,
//!   scheduler lanes, per-job tournaments, and aggregate
//!   throughput/latency/byte metrics.
//!
//! Workers can live anywhere an [`Endpoint`](crate::net::Endpoint) can:
//! in-process, on threads ([`crate::net::threaded`]), or in separate
//! processes over TCP ([`crate::net::tcp`], `verde worker --listen`).

pub mod coordinator;
pub mod pool;
pub mod worker;

pub use coordinator::{run_service, JobOutcome, ServiceReport};
pub use pool::{PooledWorker, WorkerPool};
pub use worker::{FaultPlan, WorkerHost};
