//! The worker-process brain: accepts job assignments over the protocol and
//! answers dispute queries for the active job.
//!
//! A [`WorkerHost`] is configured once (at process/actor start) with a
//! [`FaultPlan`] — honest, or one of the trainer faults with per-job
//! placement resolved lazily against each delegated [`JobSpec`]. This
//! mirrors deployment reality: whether a provider cheats is a property of
//! the provider, not of any single job.

use std::fmt;

use std::time::Instant;

use crate::graph::kernels::Backend;
use crate::net::Endpoint;
use crate::obs::{Counter, Histogram, Registry, LATENCY_US_BOUNDS};
use crate::train::session::Session;
use crate::train::JobSpec;
use crate::util::metrics::Counters;
use crate::verde::faults::{first_mutable_node, first_update_node, Fault};
use crate::verde::protocol::{Request, Response};
use crate::verde::trainer::TrainerNode;
use crate::verde::wire;

/// A job-independent fault recipe; concrete node/step targets are resolved
/// against each delegated job's spec.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultPlan {
    Honest,
    /// Perturb the first parameter-update output at `step`.
    Tamper { step: Option<u64>, delta: f32 },
    /// Run an impostor operator at the first mutable node at `step`.
    WrongOperator { step: Option<u64> },
    /// Substitute the data batch at `step`.
    WrongData { step: Option<u64> },
    /// Skip the optimizer update at `step`.
    SkipOptimizer { step: Option<u64> },
    /// Stop computing after `after` steps.
    SkipSteps { after: Option<u64> },
    /// Forge one input's lineage at the first MatMul at `step`.
    ForgedLineage { step: Option<u64> },
    /// Commit inconsistently between Phase 1 and Phase 2 at `step`.
    InconsistentCommit { step: Option<u64> },
    /// Stop responding from protocol request number `at_request` on (1 =
    /// the first request, typically the `Train` dispatch itself). Models a
    /// worker that hangs mid-protocol: the request never returns, so only
    /// deadline expiry and lease revocation can unblock the job.
    Stall { at_request: u64 },
    /// Sleep `nap_ms` before answering protocol request number
    /// `at_request` (later requests answer normally) — a worker that is
    /// *transiently* unresponsive (GC pause, checkpoint flush, noisy
    /// neighbor) rather than dead. The dispatch deadline still fires and
    /// the lease is suspended, but a later parole ping finds the worker
    /// healthy and re-admits it.
    Nap { at_request: u64, nap_ms: u64 },
    /// Train honestly but serve bit-flipped checkpoint uploads
    /// (`FetchCheckpoint` payloads): models a worker whose stored state is
    /// corrupt — or who tries to poison the next segment's seed while
    /// keeping an honest tournament record. Caught by the coordinator's
    /// Merkle verification of the reassembled state.
    TamperUpload,
}

impl FaultPlan {
    /// Parse CLI syntax: `none` | `kind` | `kind@step`, with kinds
    /// `tamper`, `wrong-op`, `wrong-data`, `skip-opt`, `skip-steps`,
    /// `forged-lineage`, `inconsistent`, `stall` (`stall@N` = stop
    /// responding from protocol request `N` on), `nap` (`nap@N` = sleep
    /// 1500 ms before answering request `N`, then recover),
    /// `tamper-upload` (honest training, bit-flipped checkpoint uploads).
    pub fn parse(s: &str) -> Option<FaultPlan> {
        let (kind, step) = match s.split_once('@') {
            Some((k, v)) => (k, Some(v.parse::<u64>().ok()?)),
            None => (s, None),
        };
        Some(match kind {
            "none" | "honest" => FaultPlan::Honest,
            "tamper" => FaultPlan::Tamper { step, delta: 0.05 },
            "wrong-op" => FaultPlan::WrongOperator { step },
            "wrong-data" => FaultPlan::WrongData { step },
            "skip-opt" => FaultPlan::SkipOptimizer { step },
            "skip-steps" => FaultPlan::SkipSteps { after: step },
            "forged-lineage" => FaultPlan::ForgedLineage { step },
            "inconsistent" => FaultPlan::InconsistentCommit { step },
            "stall" => FaultPlan::Stall { at_request: step.unwrap_or(1).max(1) },
            "nap" => FaultPlan::Nap { at_request: step.unwrap_or(1).max(1), nap_ms: 1500 },
            "tamper-upload" => FaultPlan::TamperUpload,
            _ => return None,
        })
    }

    fn step_for(step: Option<u64>, spec: &JobSpec) -> u64 {
        step.unwrap_or(spec.steps / 2 + 1).clamp(1, spec.steps.max(1))
    }

    /// Materialize the plan against a delegated job. Takes the session the
    /// trainer will run with, so node targets are looked up without a
    /// second graph/state build.
    pub fn resolve(&self, session: &Session) -> Fault {
        let spec = &session.spec;
        match *self {
            FaultPlan::Honest => Fault::None,
            FaultPlan::Tamper { step, delta } => {
                let node = first_update_node(&session.program)
                    .expect("preset has no trainable parameters");
                Fault::TamperOutput { step: Self::step_for(step, spec), node, delta }
            }
            FaultPlan::WrongOperator { step } => {
                let node = first_mutable_node(&session.program.graph)
                    .expect("preset has no mutable operator");
                Fault::WrongOperator { step: Self::step_for(step, spec), node }
            }
            FaultPlan::WrongData { step } => {
                Fault::WrongData { step: Self::step_for(step, spec) }
            }
            FaultPlan::SkipOptimizer { step } => {
                Fault::SkipOptimizer { step: Self::step_for(step, spec) }
            }
            FaultPlan::SkipSteps { after } => Fault::SkipSteps {
                after: after.unwrap_or(spec.steps / 2).clamp(1, spec.steps.saturating_sub(1).max(1)),
            },
            FaultPlan::ForgedLineage { step } => {
                let node = session
                    .program
                    .graph
                    .nodes
                    .iter()
                    .position(|n| matches!(n.op, crate::graph::Op::MatMul))
                    .expect("preset has no MatMul");
                Fault::ForgedLineage { step: Self::step_for(step, spec), node }
            }
            FaultPlan::InconsistentCommit { step } => {
                Fault::InconsistentCommit { step: Self::step_for(step, spec) }
            }
            // Stalls, naps, and upload tampering live at the request layer
            // (the host delays, withholds, or corrupts answers), not in
            // the training computation.
            FaultPlan::Stall { .. } | FaultPlan::Nap { .. } | FaultPlan::TamperUpload => {
                Fault::None
            }
        }
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultPlan::Honest => write!(f, "honest"),
            FaultPlan::Tamper { step, delta } => write!(f, "tamper@{step:?} delta={delta}"),
            FaultPlan::WrongOperator { step } => write!(f, "wrong-op@{step:?}"),
            FaultPlan::WrongData { step } => write!(f, "wrong-data@{step:?}"),
            FaultPlan::SkipOptimizer { step } => write!(f, "skip-opt@{step:?}"),
            FaultPlan::SkipSteps { after } => write!(f, "skip-steps@{after:?}"),
            FaultPlan::ForgedLineage { step } => write!(f, "forged-lineage@{step:?}"),
            FaultPlan::InconsistentCommit { step } => write!(f, "inconsistent@{step:?}"),
            FaultPlan::Stall { at_request } => write!(f, "stall@{at_request}"),
            FaultPlan::Nap { at_request, nap_ms } => write!(f, "nap@{at_request} ({nap_ms}ms)"),
            FaultPlan::TamperUpload => write!(f, "tamper-upload"),
        }
    }
}

/// An in-progress chunked checkpoint upload ([`Request::SeedCheckpoint`]):
/// the host buffers chunks until the last one arrives, then verifies and
/// trains.
struct SeedBuf {
    spec: JobSpec,
    start: u64,
    root: crate::hash::Hash,
    total_chunks: u64,
    next_chunk: u64,
    buf: Vec<u8>,
}

/// Cached `worker_*` instrument handles over the host's private
/// [`Registry`] — the snapshot a worker answers [`Request::Stats`] with.
struct WorkerMetrics {
    registry: Registry,
    requests: Counter,
    jobs_trained: Counter,
    jobs_cached: Counter,
    jobs_seeded: Counter,
    steps_trained: Counter,
    seed_bytes: Counter,
    chunks_served: Counter,
    train_us: Histogram,
    seed_verify_us: Histogram,
}

impl WorkerMetrics {
    fn new() -> WorkerMetrics {
        let registry = Registry::new();
        WorkerMetrics {
            requests: registry.counter("worker_requests"),
            jobs_trained: registry.counter("worker_jobs_trained"),
            jobs_cached: registry.counter("worker_jobs_cached"),
            jobs_seeded: registry.counter("worker_jobs_seeded"),
            steps_trained: registry.counter("worker_steps_trained"),
            seed_bytes: registry.counter("worker_seed_bytes"),
            chunks_served: registry.counter("worker_chunks_served"),
            train_us: registry.histogram("worker_train_us", &LATENCY_US_BOUNDS),
            seed_verify_us: registry.histogram("worker_seed_verify_us", &LATENCY_US_BOUNDS),
            registry,
        }
    }
}

/// Endpoint served by a worker process/actor: `Train` assigns a job, every
/// other request addresses the active job's trainer.
pub struct WorkerHost {
    name: String,
    plan: FaultPlan,
    backend: Backend,
    active: Option<TrainerNode>,
    /// Chunked seed upload in flight (cleared on completion or mismatch).
    seed_buf: Option<SeedBuf>,
    /// Most bytes a seed upload may declare before it is refused
    /// ([`WorkerHost::with_max_seed_bytes`]). This — not the wire codec's
    /// anti-DoS chunk ceiling — is the operational size limit; an
    /// oversize transfer gets a reported `Refuse`, never a wire tear.
    max_seed_bytes: usize,
    /// Protocol requests seen so far (drives [`FaultPlan::Stall`]).
    requests_seen: u64,
    pub counters: Counters,
    metrics: WorkerMetrics,
}

/// Default seed-upload budget: the 1 GiB the wire codec's old hard clamp
/// allowed, now a per-host policy knob instead of a decode error.
pub const DEFAULT_MAX_SEED_BYTES: usize = 1 << 30;

impl WorkerHost {
    pub fn new(name: &str, plan: FaultPlan) -> WorkerHost {
        WorkerHost {
            name: name.to_string(),
            plan,
            backend: Backend::Rep,
            active: None,
            seed_buf: None,
            max_seed_bytes: DEFAULT_MAX_SEED_BYTES,
            requests_seen: 0,
            counters: Counters::new(),
            metrics: WorkerMetrics::new(),
        }
    }

    /// Bound the reassembly buffer a seed upload may grow; a transfer
    /// declaring more is refused on its first chunk.
    pub fn with_max_seed_bytes(mut self, bytes: usize) -> WorkerHost {
        self.max_seed_bytes = bytes;
        self
    }

    /// The host's private stats registry (`worker_*` keys) — the snapshot
    /// it answers [`Request::Stats`] with.
    pub fn registry(&self) -> &Registry {
        &self.metrics.registry
    }

    pub fn with_backend(mut self, backend: Backend) -> WorkerHost {
        self.backend = backend;
        self
    }

    pub fn plan(&self) -> FaultPlan {
        self.plan
    }

    /// Accept one chunk of a verified-checkpoint seed. Intermediate chunks
    /// answer `Pong`; the final chunk reassembles the state, verifies it
    /// against the declared Merkle root, trains the remaining
    /// `spec.steps − start` steps, and answers the final commitment
    /// exactly as a full `Train` would (training is deterministic, so a
    /// seeded run's commitment equals the prefix-trained one).
    fn accept_seed_chunk(
        &mut self,
        spec: JobSpec,
        start: u64,
        root: crate::hash::Hash,
        total_chunks: u64,
        chunk: u64,
        payload: Vec<u8>,
    ) -> Response {
        use crate::train::checkpoint::decode_state;

        if chunk == 0 {
            // Policy-level size limit, checked against the declared shape
            // before any buffering: a worker never grows a reassembly
            // buffer past its configured budget, and the refusal is a
            // normal reported answer rather than a wire error.
            let declared = total_chunks.saturating_mul(wire::CHECKPOINT_CHUNK as u64);
            if declared > self.max_seed_bytes as u64 {
                return Response::Refuse(format!(
                    "{}: seed of {total_chunks} chunks exceeds the {} byte budget",
                    self.name, self.max_seed_bytes
                ));
            }
            self.seed_buf = Some(SeedBuf { spec, start, root, total_chunks, next_chunk: 0, buf: Vec::new() });
        }
        let Some(sb) = self.seed_buf.as_mut() else {
            return Response::Refuse(format!("{}: seed chunk {chunk} without a chunk 0", self.name));
        };
        if sb.spec != spec
            || sb.start != start
            || sb.root != root
            || sb.total_chunks != total_chunks
            || sb.next_chunk != chunk
        {
            self.seed_buf = None;
            return Response::Refuse(format!("{}: out-of-order or mismatched seed chunk", self.name));
        }
        sb.buf.extend_from_slice(&payload);
        sb.next_chunk += 1;
        if sb.next_chunk < sb.total_chunks {
            return Response::Pong;
        }

        // Final chunk: verify, then train the delta.
        let sb = self.seed_buf.take().expect("checked above");
        let t_verify = Instant::now();
        let state = match decode_state(&sb.buf) {
            Ok(s) => s,
            Err(e) => {
                return Response::Refuse(format!("{}: undecodable checkpoint seed: {e}", self.name))
            }
        };
        if state.step != sb.start {
            return Response::Refuse(format!(
                "{}: seed claims step {} but was sent for boundary {}",
                self.name, state.step, sb.start
            ));
        }
        if state.state_root() != sb.root {
            // The untrusted transfer path corrupted (or forged) the state:
            // refuse rather than train garbage.
            return Response::Refuse(format!(
                "{}: checkpoint seed does not match its committed root",
                self.name
            ));
        }
        // Decode + Merkle verification of the reassembled state is the
        // security-critical cost of accepting a seed — timed always.
        self.metrics.seed_verify_us.observe_micros(t_verify.elapsed());
        if sb.start == 0 || sb.start >= sb.spec.steps {
            return Response::Refuse(format!(
                "{}: seed boundary {} outside job of {} steps",
                self.name, sb.start, sb.spec.steps
            ));
        }
        let session = Session::new(sb.spec);
        if !state.params.keys().eq(session.genesis.params.keys())
            || !state.opt.keys().eq(session.genesis.opt.keys())
        {
            return Response::Refuse(format!(
                "{}: seed state tensors do not match the job's program",
                self.name
            ));
        }
        let fault = match self.plan.resolve(&session) {
            // A skip-steps cheater whose cutoff predates the seed boundary
            // degenerates to "skip everything after the seed" — it must
            // never be asked for state below the boundary it was seeded at.
            crate::verde::faults::Fault::SkipSteps { after } if after < sb.start => {
                crate::verde::faults::Fault::SkipSteps { after: sb.start }
            }
            f => f,
        };
        self.active = None;
        let mut trainer =
            TrainerNode::with_seed(&self.name, session, self.backend, fault, state, sb.root);
        let commit = trainer.train();
        self.counters.incr("jobs_seeded");
        self.counters.add("steps_trained", sb.spec.steps - sb.start);
        self.counters.add("seed_bytes_received", sb.buf.len() as u64);
        self.metrics.jobs_seeded.inc();
        self.metrics.steps_trained.add(sb.spec.steps - sb.start);
        self.metrics.seed_bytes.add(sb.buf.len() as u64);
        self.active = Some(trainer);
        Response::Commit(commit)
    }
}

impl Endpoint for WorkerHost {
    fn name(&self) -> &str {
        &self.name
    }

    fn call(&mut self, req: Request) -> Response {
        self.requests_seen += 1;
        self.metrics.requests.inc();
        if let FaultPlan::Stall { at_request } = self.plan {
            if self.requests_seen >= at_request {
                // Hang mid-protocol, never answering: the caller's only
                // way out is its deadline. (The thread serving this host
                // is deliberately stranded — exactly what a hung worker
                // process does to its connection.)
                loop {
                    std::thread::sleep(std::time::Duration::from_secs(3600));
                }
            }
        }
        if let FaultPlan::Nap { at_request, nap_ms } = self.plan {
            if self.requests_seen == at_request {
                // Transient unresponsiveness: miss one deadline, recover.
                std::thread::sleep(std::time::Duration::from_millis(nap_ms));
            }
        }
        match req {
            Request::Train { spec } => {
                // Re-delegation of the active job (a re-queued assignment
                // after a peer's lease was revoked): determinism makes the
                // cached commitment exact, so skip the retrain. A *seeded*
                // active job never serves this cache: a full `Train` after
                // a seeded run is the coordinator falling back to prefix
                // re-training, which exists precisely so the whole
                // trajectory (and its dispute queries) is available.
                if let Some(active) = &mut self.active {
                    if active.session.spec == spec && active.seed_base() == 0 {
                        self.counters.incr("jobs_cached");
                        self.metrics.jobs_cached.inc();
                        return Response::Commit(active.final_commit());
                    }
                }
                // Drop the previous job before training so a failure can
                // never leave a stale job answering dispute queries.
                self.active = None;
                let t_train = Instant::now();
                let session = Session::new(spec);
                let fault = self.plan.resolve(&session);
                let mut trainer =
                    TrainerNode::with_session(&self.name, session, self.backend, fault);
                let commit = trainer.train();
                self.metrics.train_us.observe_micros(t_train.elapsed());
                self.counters.incr("jobs_trained");
                self.counters.add("steps_trained", spec.steps);
                self.metrics.jobs_trained.inc();
                self.metrics.steps_trained.add(spec.steps);
                self.active = Some(trainer);
                Response::Commit(commit)
            }
            Request::SeedCheckpoint { spec, start, root, total_chunks, chunk, payload } => {
                self.accept_seed_chunk(spec, start, root, total_chunks, chunk, payload)
            }
            Request::FetchCheckpoint { .. } => {
                let mut resp = match &mut self.active {
                    Some(trainer) => trainer.call(req),
                    None => Response::Refuse(format!("{}: no active job", self.name)),
                };
                if matches!(self.plan, FaultPlan::TamperUpload) {
                    if let Response::Checkpoint { payload, .. } = &mut resp {
                        if let Some(b) = payload.first_mut() {
                            *b ^= 0x01;
                        }
                    }
                }
                if matches!(resp, Response::Checkpoint { .. }) {
                    self.metrics.chunks_served.inc();
                }
                resp
            }
            Request::FetchManifest { .. } => {
                // Manifests are always computed honestly, even under
                // `TamperUpload`: that fault corrupts chunk *payloads*, and
                // the honest manifest is exactly the binding the
                // coordinator's per-chunk verification catches it against.
                match &mut self.active {
                    Some(trainer) => trainer.call(req),
                    None => Response::Refuse(format!("{}: no active job", self.name)),
                }
            }
            Request::Stats => Response::Stats(self.metrics.registry.snapshot()),
            Request::Ping => Response::Pong,
            Request::Shutdown => Response::Bye,
            other => match &mut self.active {
                Some(trainer) => trainer.call(other),
                None => Response::Refuse(format!("{}: no active job", self.name)),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Preset;

    #[test]
    fn parse_roundtrip() {
        assert_eq!(FaultPlan::parse("none"), Some(FaultPlan::Honest));
        assert_eq!(
            FaultPlan::parse("tamper@3"),
            Some(FaultPlan::Tamper { step: Some(3), delta: 0.05 })
        );
        assert_eq!(
            FaultPlan::parse("skip-steps@2"),
            Some(FaultPlan::SkipSteps { after: Some(2) })
        );
        assert_eq!(FaultPlan::parse("wrong-data"), Some(FaultPlan::WrongData { step: None }));
        assert_eq!(
            FaultPlan::parse("stall@3"),
            Some(FaultPlan::Stall { at_request: 3 })
        );
        assert_eq!(FaultPlan::parse("stall"), Some(FaultPlan::Stall { at_request: 1 }));
        assert_eq!(
            FaultPlan::parse("nap@2"),
            Some(FaultPlan::Nap { at_request: 2, nap_ms: 1500 })
        );
        assert_eq!(FaultPlan::parse("nonsense"), None);
        assert_eq!(FaultPlan::parse("tamper@x"), None);
        assert_eq!(FaultPlan::parse("tamper-upload"), Some(FaultPlan::TamperUpload));
    }

    /// Drive a full fetch → seed handoff between two hosts and check the
    /// seeded host trains only the delta yet commits identically.
    #[test]
    fn fetch_then_seed_roundtrip_trains_only_the_delta() {
        let full_spec = JobSpec::quick(Preset::Mlp, 8);
        let prefix = full_spec.prefix(4);

        // Host A trains the first segment and serves its checkpoint.
        let mut a = WorkerHost::new("a", FaultPlan::Honest);
        assert!(matches!(a.call(Request::Train { spec: prefix }), Response::Commit(_)));
        let (root, payload) = match a.call(Request::FetchCheckpoint { step: 4, chunk: 0 }) {
            Response::Checkpoint { step, root, total_chunks, chunk, payload } => {
                assert_eq!((step, total_chunks, chunk), (4, 1, 0));
                (root, payload)
            }
            other => panic!("{other:?}"),
        };

        // Host B is seeded with it and trains steps 5..=8 only.
        let mut b = WorkerHost::new("b", FaultPlan::Honest);
        let commit = match b.call(Request::SeedCheckpoint {
            spec: full_spec,
            start: 4,
            root,
            total_chunks: 1,
            chunk: 0,
            payload,
        }) {
            Response::Commit(h) => h,
            other => panic!("{other:?}"),
        };
        let honest = TrainerNode::honest("ref", full_spec).train();
        assert_eq!(commit, honest, "seeded commitment equals the full-training one");
        assert_eq!(b.counters.get("steps_trained"), 4, "only the delta was trained");
        assert_eq!(b.counters.get("jobs_seeded"), 1);
    }

    #[test]
    fn corrupt_or_out_of_order_seed_chunks_are_refused() {
        let full_spec = JobSpec::quick(Preset::Mlp, 6);
        let prefix = full_spec.prefix(3);
        let mut a = WorkerHost::new("a", FaultPlan::Honest);
        a.call(Request::Train { spec: prefix });
        let (root, payload) = match a.call(Request::FetchCheckpoint { step: 3, chunk: 0 }) {
            Response::Checkpoint { root, payload, .. } => (root, payload),
            other => panic!("{other:?}"),
        };

        // Bit-flipped payload fails Merkle verification.
        let mut b = WorkerHost::new("b", FaultPlan::Honest);
        let mut bad = payload.clone();
        bad[0] ^= 0x01;
        assert!(matches!(
            b.call(Request::SeedCheckpoint {
                spec: full_spec,
                start: 3,
                root,
                total_chunks: 1,
                chunk: 0,
                payload: bad,
            }),
            Response::Refuse(_)
        ));
        assert_eq!(b.counters.get("jobs_seeded"), 0);

        // A chunk without its chunk 0 is refused.
        assert!(matches!(
            b.call(Request::SeedCheckpoint {
                spec: full_spec,
                start: 3,
                root,
                total_chunks: 2,
                chunk: 1,
                payload: payload.clone(),
            }),
            Response::Refuse(_)
        ));

        // A wrong boundary (state.step mismatch) is refused.
        assert!(matches!(
            b.call(Request::SeedCheckpoint {
                spec: full_spec,
                start: 4,
                root,
                total_chunks: 1,
                chunk: 0,
                payload: payload.clone(),
            }),
            Response::Refuse(_)
        ));

        // The clean upload still works afterwards.
        assert!(matches!(
            b.call(Request::SeedCheckpoint {
                spec: full_spec,
                start: 3,
                root,
                total_chunks: 1,
                chunk: 0,
                payload,
            }),
            Response::Commit(_)
        ));
    }

    #[test]
    fn tamper_upload_plan_flips_served_payload_bits() {
        let spec = JobSpec::quick(Preset::Mlp, 4);
        let mut honest = WorkerHost::new("h", FaultPlan::Honest);
        let mut evil = WorkerHost::new("e", FaultPlan::TamperUpload);
        // Both train honestly and commit identically…
        let ch = match honest.call(Request::Train { spec }) {
            Response::Commit(h) => h,
            other => panic!("{other:?}"),
        };
        let ce = match evil.call(Request::Train { spec }) {
            Response::Commit(h) => h,
            other => panic!("{other:?}"),
        };
        assert_eq!(ch, ce, "upload tamperer keeps an honest tournament record");
        // …but the tamperer's upload contradicts its committed root.
        let (hr, hp) = match honest.call(Request::FetchCheckpoint { step: 4, chunk: 0 }) {
            Response::Checkpoint { root, payload, .. } => (root, payload),
            other => panic!("{other:?}"),
        };
        let (er, ep) = match evil.call(Request::FetchCheckpoint { step: 4, chunk: 0 }) {
            Response::Checkpoint { root, payload, .. } => (root, payload),
            other => panic!("{other:?}"),
        };
        assert_eq!(hr, er, "the claimed root is the honest one");
        assert_ne!(hp, ep, "the payload is not");
        use crate::train::checkpoint::decode_state;
        let bad = decode_state(&ep);
        assert!(
            bad.is_err() || bad.unwrap().state_root() != er,
            "tampered upload must fail Merkle verification"
        );
    }

    #[test]
    fn oversize_seed_declaration_is_refused_within_budget_policy() {
        let full_spec = JobSpec::quick(Preset::Mlp, 6);
        let prefix = full_spec.prefix(3);
        let mut a = WorkerHost::new("a", FaultPlan::Honest);
        a.call(Request::Train { spec: prefix });
        let (root, payload) = match a.call(Request::FetchCheckpoint { step: 3, chunk: 0 }) {
            Response::Checkpoint { root, payload, .. } => (root, payload),
            other => panic!("{other:?}"),
        };

        // A host with a 2-chunk budget refuses a transfer declaring 3
        // chunks on its very first chunk — reported, not a wire tear, and
        // nothing was buffered.
        let mut b = WorkerHost::new("b", FaultPlan::Honest)
            .with_max_seed_bytes(2 * wire::CHECKPOINT_CHUNK);
        match b.call(Request::SeedCheckpoint {
            spec: full_spec,
            start: 3,
            root,
            total_chunks: 3,
            chunk: 0,
            payload: payload.clone(),
        }) {
            Response::Refuse(why) => assert!(why.contains("budget"), "{why}"),
            other => panic!("{other:?}"),
        }
        assert_eq!(b.counters.get("jobs_seeded"), 0);

        // A transfer within budget on the same host still succeeds.
        assert!(matches!(
            b.call(Request::SeedCheckpoint {
                spec: full_spec,
                start: 3,
                root,
                total_chunks: 1,
                chunk: 0,
                payload,
            }),
            Response::Commit(_)
        ));
    }

    #[test]
    fn manifest_is_honest_even_under_tamper_upload() {
        let spec = JobSpec::quick(Preset::Mlp, 4);
        let mut evil = WorkerHost::new("e", FaultPlan::TamperUpload);
        assert!(matches!(evil.call(Request::Train { spec }), Response::Commit(_)));
        // No active job: manifests refuse like every other job query.
        let mut idle = WorkerHost::new("i", FaultPlan::TamperUpload);
        assert!(matches!(idle.call(Request::FetchManifest { step: 4 }), Response::Refuse(_)));

        let (m_root, chunks, total_len) = match evil.call(Request::FetchManifest { step: 4 }) {
            Response::Manifest { step, root, total_len, chunks } => {
                assert_eq!(step, 4);
                (root, chunks, total_len)
            }
            other => panic!("{other:?}"),
        };
        // The manifest is the honest shape of the state…
        let mut honest = WorkerHost::new("h", FaultPlan::Honest);
        honest.call(Request::Train { spec });
        match honest.call(Request::FetchManifest { step: 4 }) {
            Response::Manifest { root, total_len: tl, chunks: hc, .. } => {
                assert_eq!(root, m_root);
                assert_eq!(tl, total_len);
                assert_eq!(hc, chunks);
            }
            other => panic!("{other:?}"),
        }
        // …so the tamperer's corrupted chunk payload contradicts its own
        // manifest entry — exactly what streaming verification checks.
        match evil.call(Request::FetchCheckpoint { step: 4, chunk: 0 }) {
            Response::Checkpoint { payload, .. } => {
                assert_ne!(crate::hash::Hash::of_bytes(&payload), chunks[0]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stats_request_answers_the_host_registry_snapshot() {
        let mut host = WorkerHost::new("w0", FaultPlan::Honest);
        let spec = JobSpec::quick(Preset::Mlp, 4);
        assert!(matches!(host.call(Request::Train { spec }), Response::Commit(_)));
        match host.call(Request::Stats) {
            Response::Stats(s) => {
                assert_eq!(s.counter("worker_jobs_trained"), 1);
                assert_eq!(s.counter("worker_steps_trained"), 4);
                assert!(s.counter("worker_requests") >= 2, "Train + Stats seen");
                let h = s.histogram("worker_train_us").expect("train was timed");
                assert_eq!(h.count, 1);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn ping_answers_pong_without_touching_job_state() {
        let mut host = WorkerHost::new("w0", FaultPlan::Honest);
        assert!(matches!(host.call(Request::Ping), Response::Pong));
        let spec = JobSpec::quick(Preset::Mlp, 4);
        let commit = match host.call(Request::Train { spec }) {
            Response::Commit(h) => h,
            other => panic!("{other:?}"),
        };
        assert!(matches!(host.call(Request::Ping), Response::Pong));
        match host.call(Request::FinalCommit) {
            Response::Commit(h) => assert_eq!(h, commit, "ping left the job intact"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn commit_root_binds_the_served_checkpoint() {
        let spec = JobSpec::quick(Preset::Mlp, 4);
        let mut host = WorkerHost::new("w0", FaultPlan::Honest);
        // No active job: nothing to commit to.
        assert!(matches!(host.call(Request::CommitRoot { step: 4 }), Response::Refuse(_)));
        assert!(matches!(host.call(Request::Train { spec }), Response::Commit(_)));
        let root = match host.call(Request::CommitRoot { step: 4 }) {
            Response::Commit(r) => r,
            other => panic!("{other:?}"),
        };
        // The committed root is exactly the root the checkpoint upload
        // serves — an audit can bind the commitment to the bytes shipped.
        match host.call(Request::FetchCheckpoint { step: 4, chunk: 0 }) {
            Response::Checkpoint { root: served, .. } => assert_eq!(served, root),
            other => panic!("{other:?}"),
        }
        // Hostile or stale steps refuse instead of panicking.
        assert!(matches!(host.call(Request::CommitRoot { step: 0 }), Response::Refuse(_)));
        assert!(matches!(host.call(Request::CommitRoot { step: 99 }), Response::Refuse(_)));
    }

    #[test]
    fn redelegated_identical_job_answers_from_cache() {
        let spec = JobSpec::quick(Preset::Mlp, 4);
        let mut host = WorkerHost::new("w0", FaultPlan::Honest);
        let first = match host.call(Request::Train { spec }) {
            Response::Commit(h) => h,
            other => panic!("{other:?}"),
        };
        let second = match host.call(Request::Train { spec }) {
            Response::Commit(h) => h,
            other => panic!("{other:?}"),
        };
        assert_eq!(first, second);
        assert_eq!(host.counters.get("jobs_trained"), 1, "no retrain");
        assert_eq!(host.counters.get("jobs_cached"), 1);
    }

    #[test]
    fn host_trains_and_answers_dispute_queries() {
        let spec = JobSpec::quick(Preset::Mlp, 5);
        let mut host = WorkerHost::new("w0", FaultPlan::Honest);
        // no job yet: dispute queries are refused
        assert!(matches!(
            host.call(Request::NodeHashSeq { step: 1 }),
            Response::Refuse(_)
        ));
        let commit = match host.call(Request::Train { spec }) {
            Response::Commit(h) => h,
            other => panic!("{other:?}"),
        };
        let honest = TrainerNode::honest("ref", spec).train();
        assert_eq!(commit, honest);
        // dispute queries now hit the active job
        match host.call(Request::FinalCommit) {
            Response::Commit(h) => assert_eq!(h, commit),
            other => panic!("{other:?}"),
        }
        match host.call(Request::NodeHashSeq { step: 2 }) {
            Response::NodeSeq(seq) => assert!(!seq.is_empty()),
            other => panic!("{other:?}"),
        }
        assert_eq!(host.counters.get("jobs_trained"), 1);
    }

    #[test]
    fn faulty_plan_diverges_from_honest() {
        let spec = JobSpec::quick(Preset::Mlp, 6);
        let honest = TrainerNode::honest("ref", spec).train();
        for plan in [
            FaultPlan::Tamper { step: Some(2), delta: 0.05 },
            FaultPlan::WrongData { step: Some(3) },
            FaultPlan::SkipSteps { after: Some(2) },
        ] {
            let mut host = WorkerHost::new("w", plan);
            match host.call(Request::Train { spec }) {
                Response::Commit(h) => assert_ne!(h, honest, "{plan}"),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn new_job_replaces_old_one() {
        let a = JobSpec::quick(Preset::Mlp, 4);
        let mut b = a;
        b.data_seed ^= 0x5555;
        let mut host = WorkerHost::new("w", FaultPlan::Honest);
        let ca = match host.call(Request::Train { spec: a }) {
            Response::Commit(h) => h,
            other => panic!("{other:?}"),
        };
        let cb = match host.call(Request::Train { spec: b }) {
            Response::Commit(h) => h,
            other => panic!("{other:?}"),
        };
        assert_ne!(ca, cb);
        match host.call(Request::FinalCommit) {
            Response::Commit(h) => assert_eq!(h, cb, "active job is the newest"),
            other => panic!("{other:?}"),
        }
        assert_eq!(host.counters.get("jobs_trained"), 2);
    }
}
