//! Streaming state-transfer plumbing: the bounded chunk pipe between a
//! resolver-side checkpoint *producer* and the event loop's seed
//! *consumer*, plus the content-addressed checkpoint cache.
//!
//! The legacy transfer path buffered the whole serialized [`State`] at the
//! coordinator (`fetch → verify → hold → re-dispatch`), so coordinator
//! memory scaled with checkpoint size even though both ends of the
//! transfer only ever need one chunk at a time. The streaming pipeline
//! keeps at most a small window of chunks in flight:
//!
//! ```text
//!   winner workers ──FetchCheckpoint──▶ producer (resolver thread)
//!        verify chunk i against the certified manifest
//!   producer ──ChunkStream (bounded window)──▶ event loop pump
//!   pump ──SeedCheckpoint chunk i──▶ next segment's k workers
//! ```
//!
//! The manifest (per-chunk hashes, certified by unanimity over the winning
//! group) is what makes per-chunk verification sound: a tampered chunk is
//! rejected the moment it arrives and the producer re-fetches it from a
//! co-winner, so bad bytes never reach the stream, let alone a worker.
//!
//! [`State`]: crate::train::State

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::hash::Hash;
use crate::obs::{Counter, Gauge, Registry};

/// A whole checkpoint fetched and verified against its certified state
/// root — ready to seed a segment's workers (shared via `Arc` so re-queues
/// and multi-worker dispatches don't copy the state). Produced by the
/// buffered (optimistic-tier) fetch path and by cache hits; the streaming
/// path only materializes one when assembling a cache entry on the side.
pub(crate) struct SeedPayload {
    /// Boundary the state sits at (the previous segment's end).
    pub(crate) start: u64,
    /// Merkle root over the state's leaves, verified before queueing.
    pub(crate) root: Hash,
    /// Canonical serialization ([`crate::train::checkpoint::encode_state`]).
    pub(crate) bytes: Vec<u8>,
}

/// The certified shape of one checkpoint: what a `Response::Manifest`
/// carries, agreed unanimously by the winning group before any chunk
/// moves. Every arriving chunk payload is checked against `chunks[i]`.
#[derive(Clone)]
pub(crate) struct ChunkManifest {
    /// Boundary step the checkpoint certifies.
    pub(crate) step: u64,
    /// Merkle state root the assembled bytes must verify against.
    pub(crate) root: Hash,
    /// Exact encoded length; chunk count must equal `chunks.len()`.
    pub(crate) total_len: u64,
    /// Per-chunk content hashes, in chunk order.
    pub(crate) chunks: Vec<Hash>,
}

/// What [`ChunkStream::try_pop`] found.
pub(crate) enum Pop {
    /// The next chunk's verified payload, in order.
    Chunk(Vec<u8>),
    /// Nothing buffered yet; the producer is still fetching.
    Pending,
    /// The producer gave up (every source served bad bytes or refused):
    /// the consumer unwinds and falls back to prefix re-training.
    Failed,
}

struct StreamState {
    window: VecDeque<Vec<u8>>,
    buffered: u64,
    peak: u64,
    /// A consumer dispatch is pumping: the window cap is enforced by
    /// blocking the producer. Until then pushes spill unbounded-by-cap
    /// (bounded by the manifest's `total_len`, which the coordinator
    /// already capped at `ServiceConfig::max_checkpoint_bytes`) so a
    /// producer can never deadlock against a lease it is itself holding
    /// the workers for.
    attached: bool,
    closed: bool,
    failed: bool,
    aborted: bool,
}

/// A bounded, ordered, single-producer single-consumer chunk pipe.
///
/// The producer (a resolver thread) `push`es verified chunks in order and
/// blocks once `cap` chunks are buffered *and* a consumer is attached; the
/// consumer (the event loop's pump) `try_pop`s without ever blocking.
/// `abort` from either side unblocks the producer immediately — every
/// discard path in the coordinator must call it, or the producer would
/// wedge its resolver thread forever.
pub(crate) struct ChunkStream {
    manifest: ChunkManifest,
    cap: usize,
    state: Mutex<StreamState>,
    cv: Condvar,
}

impl ChunkStream {
    pub(crate) fn new(manifest: ChunkManifest, cap_chunks: usize) -> ChunkStream {
        ChunkStream {
            manifest,
            cap: cap_chunks.max(1),
            state: Mutex::new(StreamState {
                window: VecDeque::new(),
                buffered: 0,
                peak: 0,
                attached: false,
                closed: false,
                failed: false,
                aborted: false,
            }),
            cv: Condvar::new(),
        }
    }

    pub(crate) fn manifest(&self) -> &ChunkManifest {
        &self.manifest
    }

    pub(crate) fn total_chunks(&self) -> u64 {
        self.manifest.chunks.len() as u64
    }

    /// A consumer dispatch is live: enforce the window cap from now on.
    pub(crate) fn attach(&self) {
        let mut st = self.state.lock().unwrap();
        st.attached = true;
        self.cv.notify_all();
    }

    /// Producer: append the next chunk in order. Blocks while the window
    /// is full and a consumer is attached. Returns `false` when the
    /// consumer aborted — the producer stops fetching.
    pub(crate) fn push(&self, payload: Vec<u8>) -> bool {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.aborted {
                return false;
            }
            if st.attached && st.window.len() >= self.cap {
                st = self.cv.wait(st).unwrap();
                continue;
            }
            break;
        }
        st.buffered += payload.len() as u64;
        st.peak = st.peak.max(st.buffered);
        st.window.push_back(payload);
        self.cv.notify_all();
        true
    }

    /// Consumer: take the next chunk if one is buffered. Never blocks.
    pub(crate) fn try_pop(&self) -> Pop {
        let mut st = self.state.lock().unwrap();
        if let Some(payload) = st.window.pop_front() {
            st.buffered -= payload.len() as u64;
            self.cv.notify_all();
            return Pop::Chunk(payload);
        }
        if st.failed || st.aborted {
            Pop::Failed
        } else {
            Pop::Pending
        }
    }

    /// Producer: every chunk was pushed.
    pub(crate) fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.cv.notify_all();
    }

    /// Producer: no source could serve some chunk honestly — the consumer
    /// sees [`Pop::Failed`] once the window drains.
    pub(crate) fn fail(&self) {
        let mut st = self.state.lock().unwrap();
        st.failed = true;
        self.cv.notify_all();
    }

    /// Consumer (or any discard path): stop the producer. Idempotent.
    pub(crate) fn abort(&self) {
        let mut st = self.state.lock().unwrap();
        st.aborted = true;
        self.cv.notify_all();
    }

    /// High-water mark of bytes buffered in the window.
    pub(crate) fn peak_buffered(&self) -> u64 {
        self.state.lock().unwrap().peak
    }
}

struct CacheInner {
    /// LRU order: front is coldest. Linear scans are fine — the cache
    /// holds a handful of whole checkpoints, not thousands of keys.
    entries: Vec<(Hash, Arc<SeedPayload>)>,
    bytes: u64,
}

/// Content-addressed checkpoint cache, keyed by certified state root.
///
/// A resolver that certifies a root it has seen before seeds the successor
/// from the cache and skips the transfer entirely — re-submitted jobs and
/// repeated prefixes pay the network cost once. Evicts least-recently-used
/// whole entries to stay under a byte budget. Instruments
/// `coord_ckpt_cache_{hits,misses,bytes}` on the delegation's registry;
/// the hit/miss totals are also mirrored into the final `ServiceReport`.
pub(crate) struct CheckpointCache {
    budget: u64,
    inner: Mutex<CacheInner>,
    hits: AtomicU64,
    misses: AtomicU64,
    c_hits: Counter,
    c_misses: Counter,
    g_bytes: Gauge,
}

impl CheckpointCache {
    pub(crate) fn new(registry: &Registry, budget_bytes: u64) -> CheckpointCache {
        CheckpointCache {
            budget: budget_bytes,
            inner: Mutex::new(CacheInner { entries: Vec::new(), bytes: 0 }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            c_hits: registry.counter("coord_ckpt_cache_hits"),
            c_misses: registry.counter("coord_ckpt_cache_misses"),
            g_bytes: registry.gauge("coord_ckpt_cache_bytes"),
        }
    }

    /// Byte budget this cache was built with (an insert larger than the
    /// whole budget is never attempted, so producers can skip assembling
    /// a state that could not be cached anyway).
    pub(crate) fn budget(&self) -> u64 {
        self.budget
    }

    /// Look up the checkpoint with state root `root` at boundary `start`.
    /// A root match at a different boundary is a miss (roots bind state
    /// content, and content at the wrong step must not seed anything).
    pub(crate) fn get(&self, root: &Hash, start: u64) -> Option<Arc<SeedPayload>> {
        let mut inner = self.inner.lock().unwrap();
        let pos = inner
            .entries
            .iter()
            .position(|(r, p)| r == root && p.start == start);
        match pos {
            Some(i) => {
                // Touch: move to the hot end.
                let entry = inner.entries.remove(i);
                let payload = Arc::clone(&entry.1);
                inner.entries.push(entry);
                self.hits.fetch_add(1, Ordering::Relaxed);
                self.c_hits.inc();
                Some(payload)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                self.c_misses.inc();
                None
            }
        }
    }

    /// Insert a verified checkpoint, evicting cold entries to fit. An
    /// entry bigger than the whole budget (or already present) is a no-op.
    pub(crate) fn insert(&self, payload: Arc<SeedPayload>) {
        let size = payload.bytes.len() as u64;
        if size > self.budget {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        if inner.entries.iter().any(|(r, p)| *r == payload.root && p.start == payload.start)
        {
            return;
        }
        while inner.bytes + size > self.budget && !inner.entries.is_empty() {
            let (_, cold) = inner.entries.remove(0);
            inner.bytes -= cold.bytes.len() as u64;
        }
        inner.bytes += size;
        let key = payload.root;
        inner.entries.push((key, payload));
        self.g_bytes.set(inner.bytes);
    }

    pub(crate) fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub(crate) fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn manifest(n_chunks: usize) -> ChunkManifest {
        ChunkManifest {
            step: 8,
            root: Hash::of_bytes(b"root"),
            total_len: (n_chunks * 4) as u64,
            chunks: (0..n_chunks)
                .map(|i| Hash::of_bytes(&(i as u64).to_le_bytes()))
                .collect(),
        }
    }

    #[test]
    fn attached_stream_bounds_the_window_and_tracks_peak() {
        // Producer thread pushes 16 four-byte chunks through a 3-chunk
        // window; the consumer drains slowly. The peak buffered bytes must
        // never exceed the window cap — the bounded-memory property of
        // the streaming pipeline.
        let stream = Arc::new(ChunkStream::new(manifest(16), 3));
        stream.attach();
        let producer = {
            let stream = Arc::clone(&stream);
            std::thread::spawn(move || {
                for i in 0..16u32 {
                    assert!(stream.push(i.to_le_bytes().to_vec()));
                }
                stream.close();
            })
        };
        let mut got = Vec::new();
        while got.len() < 16 {
            match stream.try_pop() {
                Pop::Chunk(c) => got.push(c),
                Pop::Pending => std::thread::sleep(Duration::from_millis(1)),
                Pop::Failed => panic!("stream failed"),
            }
        }
        producer.join().unwrap();
        for (i, c) in got.iter().enumerate() {
            assert_eq!(c, &(i as u32).to_le_bytes().to_vec(), "in-order delivery");
        }
        assert!(
            stream.peak_buffered() <= 3 * 4,
            "peak {} exceeds the 3-chunk window",
            stream.peak_buffered()
        );
    }

    #[test]
    fn unattached_pushes_spill_instead_of_blocking() {
        // Until a consumer attaches, the producer must never block: a
        // blocked producer holds leased workers, and with a tight pool the
        // consumer lease it is waiting for could need exactly those
        // workers. 8 chunks through a 2-chunk window, no consumer.
        let stream = ChunkStream::new(manifest(8), 2);
        for i in 0..8u32 {
            assert!(stream.push(i.to_le_bytes().to_vec()), "unattached push must not block");
        }
        stream.close();
        let mut n = 0;
        while let Pop::Chunk(_) = stream.try_pop() {
            n += 1;
        }
        assert_eq!(n, 8);
    }

    #[test]
    fn abort_unblocks_a_producer_stuck_on_a_full_window() {
        let stream = Arc::new(ChunkStream::new(manifest(8), 1));
        stream.attach();
        assert!(stream.push(vec![0; 4]));
        let producer = {
            let stream = Arc::clone(&stream);
            std::thread::spawn(move || stream.push(vec![1; 4]))
        };
        // Give the producer a moment to block on the full window, then
        // abort from the consumer side.
        std::thread::sleep(Duration::from_millis(20));
        stream.abort();
        assert!(!producer.join().unwrap(), "aborted push reports the abort");
        assert!(matches!(stream.try_pop(), Pop::Chunk(_)), "already-pushed chunk survives");
        assert!(matches!(stream.try_pop(), Pop::Failed), "then the abort surfaces");
    }

    #[test]
    fn failed_stream_surfaces_after_the_window_drains() {
        let stream = ChunkStream::new(manifest(4), 4);
        assert!(stream.push(vec![7; 4]));
        stream.fail();
        assert!(matches!(stream.try_pop(), Pop::Chunk(_)), "buffered chunk still delivered");
        assert!(matches!(stream.try_pop(), Pop::Failed));
    }

    #[test]
    fn empty_open_stream_is_pending() {
        let stream = ChunkStream::new(manifest(4), 4);
        assert!(matches!(stream.try_pop(), Pop::Pending));
    }

    fn payload(tag: u8, start: u64, len: usize) -> Arc<SeedPayload> {
        let bytes = vec![tag; len];
        Arc::new(SeedPayload { start, root: Hash::of_bytes(&[tag]), bytes })
    }

    #[test]
    fn cache_hits_misses_and_boundary_binding() {
        let registry = Registry::new();
        let cache = CheckpointCache::new(&registry, 1024);
        let p = payload(1, 8, 100);
        assert!(cache.get(&p.root, 8).is_none(), "cold cache misses");
        cache.insert(Arc::clone(&p));
        let hit = cache.get(&p.root, 8).expect("hit after insert");
        assert_eq!(hit.bytes, p.bytes);
        // Same root asked for at a different boundary must miss: content
        // at the wrong step never seeds a lease.
        assert!(cache.get(&p.root, 16).is_none());
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 2);
        assert_eq!(registry.counter("coord_ckpt_cache_hits").get(), 1);
        assert_eq!(registry.counter("coord_ckpt_cache_misses").get(), 2);
    }

    #[test]
    fn cache_evicts_least_recently_used_to_fit_budget() {
        let registry = Registry::new();
        let cache = CheckpointCache::new(&registry, 250);
        let a = payload(1, 8, 100);
        let b = payload(2, 8, 100);
        let c = payload(3, 8, 100);
        cache.insert(Arc::clone(&a));
        cache.insert(Arc::clone(&b));
        // Touch `a` so `b` is the cold entry when `c` forces an eviction.
        assert!(cache.get(&a.root, 8).is_some());
        cache.insert(Arc::clone(&c));
        assert!(cache.get(&b.root, 8).is_none(), "cold entry evicted");
        assert!(cache.get(&a.root, 8).is_some(), "touched entry survives");
        assert!(cache.get(&c.root, 8).is_some());
        assert_eq!(registry.gauge("coord_ckpt_cache_bytes").get(), 200);
        // An entry bigger than the whole budget is refused outright.
        cache.insert(payload(4, 8, 1000));
        assert!(cache.get(&Hash::of_bytes(&[4]), 8).is_none());
    }
}
