//! The handle-based client API: a long-lived [`Delegation`] service
//! object wrapping the event-driven core, [`Client`] handles that
//! [`submit`](Client::submit) jobs with per-job [`JobPolicy`], and
//! [`JobHandle`]s that [`wait`](JobHandle::wait),
//! [`try_status`](JobHandle::try_status), and [`cancel`](JobHandle::cancel)
//! — the deployment shape of a client continuously delegating ML programs
//! to an untrusted provider fleet, rather than a one-shot batch call.
//!
//! ```text
//!   Delegation::start(&pool, cfg)          (event loop + resolver pool spawn)
//!        │
//!        ├─ client() ──▶ Client ──submit(JobRequest)──▶ JobHandle
//!        │                                   │   │   │
//!        │                  wait() ◀─────────┘   │   └─▶ cancel()
//!        │                  (blocks → JobOutcome)└─▶ try_status()
//!        │                                           (Queued / Running / Done)
//!        └─ finish() ──▶ ServiceReport      (drains, joins, aggregates)
//! ```
//!
//! A [`JobRequest`] carries the [`JobSpec`] plus [`JobPolicy`]:
//! replication factor `k`, dispatch deadline, scheduling priority, a
//! [`BackendRequirement`] (reproducible-only vs. any hardware profile),
//! and the checkpoint-segment count for sharding. Cancelling a handle
//! releases its leases back to the pool mid-flight, so a queued job takes
//! them immediately.
//!
//! [`DelegationFrontend`] exposes the same API over the wire: it is an
//! [`Endpoint`] that answers [`Request::Submit`] / [`Request::Status`] /
//! [`Request::Cancel`], so a remote client drives a coordinator over TCP
//! (`verde coordinator --serve`, `verde client`) with the exact semantics
//! of the in-process handles.
//!
//! ## Migrating from `run_service`
//!
//! ```ignore
//! // before (one-shot batch):
//! let report = run_service(jobs, &pool, k);
//! // after (persistent client):
//! let delegation = Delegation::start(&pool, ServiceConfig::new(k));
//! let handles: Vec<_> =
//!     jobs.into_iter().map(|spec| delegation.submit(JobRequest::new(spec))).collect();
//! for h in &handles { h.wait(); }
//! let report = delegation.finish();
//! ```
//!
//! `run_service` / `run_service_with` still exist and do exactly the
//! above, so existing callers compile unchanged.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::net::mux::Completion;
use crate::net::Endpoint;
use crate::obs::{Registry, Snapshot};
use crate::train::JobSpec;
use crate::verde::protocol::{
    BackendRequirement, JobPolicy, RemoteStatus, Request, Response,
};

use super::coordinator::{
    wake, Cmd, CmdGate, CoreRestore, JobOutcome, LoopReport, ServiceConfig, ServiceReport,
};
use super::journal::{self, Journal, JournalEntry};
use super::pool::WorkerPool;

/// A job submission: the program spec plus its delegation policy.
#[derive(Debug, Clone, Copy)]
pub struct JobRequest {
    pub spec: JobSpec,
    pub policy: JobPolicy,
}

impl JobRequest {
    /// Submit `spec` under the default policy (service-default `k` and
    /// deadline, priority 0, any backend, unsharded).
    pub fn new(spec: JobSpec) -> JobRequest {
        JobRequest { spec, policy: JobPolicy::default() }
    }

    /// Override the replication factor for this job.
    pub fn with_k(mut self, k: usize) -> JobRequest {
        self.policy.k = k;
        self
    }

    /// Override the dispatch deadline for this job.
    pub fn with_deadline(mut self, deadline: std::time::Duration) -> JobRequest {
        self.policy.deadline = Some(deadline);
        self
    }

    /// Scheduling priority: higher schedules first, ties FIFO.
    pub fn with_priority(mut self, priority: i64) -> JobRequest {
        self.policy.priority = priority;
        self
    }

    /// Restrict which hardware may serve this job.
    pub fn with_backend(mut self, backend: BackendRequirement) -> JobRequest {
        self.policy.backend = backend;
        self
    }

    /// Shard the job into `segments` checkpoint-delimited segments that
    /// schedule independently (shard edges from the Phase-1 `split_points`
    /// schedule).
    pub fn with_segments(mut self, segments: u64) -> JobRequest {
        self.policy.segments = segments.max(1);
        self
    }

    /// Verified checkpoint state-transfer between segments: each segment
    /// is seeded with its predecessor's Merkle-verified checkpoint and
    /// trains only `b_i − b_{i−1}` steps (instead of re-training the
    /// prefix `[0, b_i]`). Segments then pipeline instead of running
    /// concurrently; transfer failures fall back to prefix re-training.
    pub fn with_state_transfer(mut self) -> JobRequest {
        self.policy.transfer = true;
        self
    }

    /// Override the per-segment re-queue budget.
    pub fn with_max_requeues(mut self, max_requeues: u32) -> JobRequest {
        self.policy.max_requeues = Some(max_requeues);
        self
    }

    /// Run this job on the optimistic staked audit tier: **one** staked
    /// worker trains every segment and commits per-segment checkpoint
    /// roots; the coordinator replay-audits each committed segment with
    /// probability `rate` (clamped to `[0, 1]`) on an independent worker.
    /// A divergent audit escalates the segment into the full dispute
    /// tournament, slashes the committer's stake on conviction, and
    /// reverts the rest of the job to k-replication. Expected honest cost
    /// is `(1 + rate) × steps` worker-steps instead of `k × steps`.
    pub fn with_audit(mut self, rate: f32) -> JobRequest {
        self.policy.audit_rate = if rate.is_nan() { 0.0 } else { rate.clamp(0.0, 1.0) };
        self
    }
}

/// A snapshot of a submitted job's progress ([`JobHandle::try_status`]).
#[derive(Debug, Clone)]
pub enum JobStatus {
    /// Submitted, no segment leased yet.
    Queued,
    /// At least one segment leased.
    Running { segments_done: usize, segments_total: usize },
    /// Terminal: every segment settled, or the job was cancelled
    /// (`outcome.cancelled`).
    Done(JobOutcome),
}

impl JobStatus {
    /// The wire-level mirror of this status ([`Response::Status`]).
    pub fn remote(&self) -> RemoteStatus {
        match self {
            JobStatus::Queued => RemoteStatus::Queued,
            JobStatus::Running { segments_done, segments_total } => RemoteStatus::Running {
                segments_done: *segments_done as u64,
                segments_total: *segments_total as u64,
            },
            JobStatus::Done(o) => RemoteStatus::Done {
                accepted: o.accepted,
                cancelled: o.cancelled,
                disputes: o.disputes as u64,
                eliminated: o.eliminated as u64,
            },
        }
    }
}

/// Shared per-job state: the event loop writes, handles read/wait.
pub(crate) struct JobCell {
    state: Mutex<JobStatus>,
    done: Condvar,
}

impl JobCell {
    fn new() -> JobCell {
        JobCell { state: Mutex::new(JobStatus::Queued), done: Condvar::new() }
    }

    pub(crate) fn set_running(&self, segments_done: usize, segments_total: usize) {
        let mut st = self.state.lock().unwrap();
        if !matches!(*st, JobStatus::Done(_)) {
            *st = JobStatus::Running { segments_done, segments_total };
        }
    }

    pub(crate) fn finish(&self, outcome: JobOutcome) {
        let mut st = self.state.lock().unwrap();
        *st = JobStatus::Done(outcome);
        drop(st);
        self.done.notify_all();
    }

    fn snapshot(&self) -> JobStatus {
        self.state.lock().unwrap().clone()
    }

    fn wait(&self) -> JobOutcome {
        let mut st = self.state.lock().unwrap();
        loop {
            if let JobStatus::Done(o) = &*st {
                return o.clone();
            }
            st = self.done.wait(st).unwrap();
        }
    }
}

/// Shared plumbing every client/handle talks to the event loop through.
struct ClientCore {
    gate: Arc<Mutex<CmdGate>>,
    comp_tx: Mutex<Sender<Completion>>,
    next_job: AtomicU64,
}

impl ClientCore {
    /// Send a command and nudge the event loop awake. `Err` once the
    /// event loop has closed the gate (or exited) — the gate's mutex makes
    /// this exact: a send that returns `Ok` is guaranteed to be processed
    /// (by the loop or its final straggler drain), and a send after
    /// shutdown always errors so the caller can settle its own handle.
    fn send(&self, cmd: Cmd) -> Result<(), ()> {
        {
            let gate = self.gate.lock().unwrap();
            if gate.closed {
                return Err(());
            }
            gate.tx.send(cmd).map_err(|_| ())?;
        }
        let _ = self.comp_tx.lock().unwrap().send(wake());
        Ok(())
    }
}

/// A cheap handle for submitting jobs to a [`Delegation`]. Cloneable and
/// `Send`: many threads (or a TCP frontend) can submit concurrently.
#[derive(Clone)]
pub struct Client {
    core: Arc<ClientCore>,
}

impl Client {
    /// Register a job and get its handle back immediately; scheduling,
    /// sharding, dispatch, and verification proceed in the background. If
    /// the delegation has already shut down, the handle comes back
    /// already `Done` with a cancelled outcome.
    pub fn submit(&self, req: JobRequest) -> JobHandle {
        let job_id = self.core.next_job.fetch_add(1, Ordering::Relaxed);
        let cell = Arc::new(JobCell::new());
        let cmd = Cmd::Submit {
            job_id,
            spec: req.spec,
            policy: req.policy,
            cell: Arc::clone(&cell),
        };
        if self.core.send(cmd).is_err() {
            cell.finish(JobOutcome::cancelled_stub(job_id));
        }
        JobHandle { job_id, cell, core: Arc::clone(&self.core) }
    }
}

/// One submitted job. Dropping the handle does **not** cancel the job —
/// it keeps running and its outcome lands in the final [`ServiceReport`].
/// Cloning yields another handle to the same job (a shared frontend keeps
/// clones across connections).
#[derive(Clone)]
pub struct JobHandle {
    job_id: u64,
    cell: Arc<JobCell>,
    core: Arc<ClientCore>,
}

impl JobHandle {
    /// The delegation-wide job id (also the id `Status`/`Cancel` wire
    /// messages address).
    pub fn id(&self) -> u64 {
        self.job_id
    }

    /// Block until the job reaches a terminal state and return its
    /// outcome (cancelled jobs return `outcome.cancelled == true`).
    pub fn wait(&self) -> JobOutcome {
        self.cell.wait()
    }

    /// Non-blocking progress snapshot.
    pub fn try_status(&self) -> JobStatus {
        self.cell.snapshot()
    }

    /// Cancel the job: queued segments are dropped and in-flight leases
    /// drain back to the pool — each worker re-enters as soon as its
    /// current dispatch settles (its deadline still bounds a stalled
    /// one), so waiting jobs take the freed leases without ever landing
    /// on a link still crunching the cancelled work. Returns `true` when
    /// the cancel landed before the job finished; `false` when the job
    /// was already terminal. After a successful cancel,
    /// [`wait`](JobHandle::wait) returns promptly regardless of the
    /// drain.
    pub fn cancel(&self) -> bool {
        if matches!(self.try_status(), JobStatus::Done(_)) {
            return false;
        }
        let (reply_tx, reply_rx) = channel();
        if self.core.send(Cmd::Cancel { job_id: self.job_id, reply: reply_tx }).is_err() {
            return false;
        }
        reply_rx.recv().unwrap_or(false)
    }
}

/// The long-lived delegation service: owns the event loop and resolver
/// threads over a [`WorkerPool`]. Create with [`Delegation::start`], hand
/// out [`Client`]s, and close with [`Delegation::finish`] to get the
/// aggregate [`ServiceReport`].
pub struct Delegation {
    core: Arc<ClientCore>,
    pool: WorkerPool,
    cfg: ServiceConfig,
    start_size: usize,
    t_start: Instant,
    event_join: Option<JoinHandle<LoopReport>>,
    resolver_joins: Vec<JoinHandle<()>>,
    registry: Registry,
}

impl Delegation {
    /// Spawn the event core over a clone of the pool handle.
    ///
    /// # Panics
    /// If `cfg.k == 0` (per-job policies may still lower/raise `k`; it is
    /// clamped to the live pool size at lease time).
    pub fn start(pool: &WorkerPool, cfg: ServiceConfig) -> Delegation {
        assert!(cfg.k >= 1, "a delegation needs k >= 1");
        Delegation::boot(pool, cfg, None, None, 0)
    }

    /// [`start`](Delegation::start), journaling every state transition to
    /// the write-ahead journal at `path` so a crashed coordinator can be
    /// rebuilt with [`recover`](Delegation::recover). Truncates any
    /// existing file — use `recover` to resume one.
    pub fn start_durable(
        pool: &WorkerPool,
        cfg: ServiceConfig,
        path: impl AsRef<std::path::Path>,
    ) -> std::io::Result<Delegation> {
        assert!(cfg.k >= 1, "a delegation needs k >= 1");
        let journal = Journal::create(path.as_ref())?;
        Ok(Delegation::boot(pool, cfg, Some(journal), None, 0))
    }

    /// Rebuild a delegation from the write-ahead journal at `path`.
    ///
    /// Replays the journal (tolerating a torn final entry — the tail is
    /// truncated and overwritten), folds it into recovered state, and
    /// returns the delegation plus one [`JobHandle`] per journaled job:
    /// settled jobs come back already `Done` with their logged outcome
    /// (bit-identical to what the crashed coordinator certified), and
    /// in-flight jobs are re-queued to train **only their unsettled
    /// segments** — settled verdicts are trusted from the log, so recovery
    /// cost is proportional to work lost, not work done. Stakes locked
    /// behind audits that died with the old process are released (and the
    /// release journaled) rather than leaked; permanently revoked workers
    /// stay revoked. A missing or empty journal file recovers to a fresh
    /// delegation with zero handles.
    ///
    /// Feed the handles to [`DelegationFrontend::adopt`] to re-serve them
    /// over the wire: remote clients re-attach by polling `Status` with
    /// their pre-crash job ids.
    pub fn recover(
        pool: &WorkerPool,
        cfg: ServiceConfig,
        path: impl AsRef<std::path::Path>,
    ) -> std::io::Result<(Delegation, Vec<JobHandle>)> {
        assert!(cfg.k >= 1, "a delegation needs k >= 1");
        let path = path.as_ref();
        let bytes = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(e),
        };
        let replay = journal::replay(&bytes).map_err(|e| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("corrupt journal {}: {e}", path.display()),
            )
        })?;
        let rec = journal::recover(replay);

        // Re-open at the last whole entry: the torn tail (if any) is
        // truncated away so new entries append at a frame boundary.
        let whole = (bytes.len() - rec.torn_bytes) as u64;
        let mut journal = Journal::resume(path, whole)?;
        // Stakes locked behind audits that died with the old process go
        // back to available; journal the releases so a second crash during
        // recovery folds to the same ledger.
        for s in rec.stakes.iter().filter(|s| s.locked_at_crash > 0) {
            journal.append(&JournalEntry::StakeRelease { worker: s.worker.clone() });
        }
        journal.sync();

        let restore = CoreRestore { stakes: rec.stakes, revoked: rec.revoked };
        let delegation =
            Delegation::boot(pool, cfg, Some(journal), Some(restore), rec.next_job_id);
        delegation.registry.counter("coord_journal_replayed_entries").add(rec.entries);

        let mut handles = Vec::with_capacity(rec.finished.len() + rec.jobs.len());
        // Settled jobs: pre-finished handles serving the logged outcome.
        for outcome in rec.finished {
            let job_id = outcome.job_id;
            let cell = Arc::new(JobCell::new());
            cell.finish(outcome);
            handles.push(JobHandle { job_id, cell, core: Arc::clone(&delegation.core) });
        }
        // In-flight jobs: re-queue the unsettled remainder. `Recover` (not
        // `Submit`) so the event loop trusts the settled verdicts and does
        // not re-journal the submission.
        for job in rec.jobs {
            let cell = Arc::new(JobCell::new());
            let cmd = Cmd::Recover {
                job_id: job.job_id,
                spec: job.spec,
                policy: job.policy,
                cell: Arc::clone(&cell),
                settled: job.settled,
            };
            if delegation.core.send(cmd).is_err() {
                cell.finish(JobOutcome::cancelled_stub(job.job_id));
            }
            handles.push(JobHandle {
                job_id: job.job_id,
                cell,
                core: Arc::clone(&delegation.core),
            });
        }
        handles.sort_by_key(|h| h.job_id);
        Ok((delegation, handles))
    }

    fn boot(
        pool: &WorkerPool,
        cfg: ServiceConfig,
        journal: Option<Journal>,
        restore: Option<CoreRestore>,
        next_job_id: u64,
    ) -> Delegation {
        let core = super::coordinator::start_core(pool, cfg, journal, restore);
        Delegation {
            core: Arc::new(ClientCore {
                gate: core.gate,
                comp_tx: Mutex::new(core.comp_tx),
                next_job: AtomicU64::new(next_job_id),
            }),
            pool: pool.clone(),
            cfg,
            start_size: pool.size(),
            t_start: Instant::now(),
            event_join: Some(core.event_join),
            resolver_joins: core.resolver_joins,
            registry: core.registry,
        }
    }

    /// The delegation's private stats registry (`coord_*` keys). Its
    /// counter totals reconcile exactly with the final [`ServiceReport`];
    /// call `registry().spans().enable()` before submitting to record
    /// per-job lifecycle span events.
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// A live point-in-time stats snapshot (what `Response::Stats`
    /// carries and `verde stats` renders). Safe to call any time; an
    /// idle delegation reports zeros, never NaN.
    pub fn stats(&self) -> Snapshot {
        self.registry.snapshot()
    }

    /// A cheap submission handle (cloneable, shareable across threads).
    pub fn client(&self) -> Client {
        Client { core: Arc::clone(&self.core) }
    }

    /// Convenience: submit directly on the delegation.
    pub fn submit(&self, req: JobRequest) -> JobHandle {
        self.client().submit(req)
    }

    fn shutdown(&mut self) -> Option<LoopReport> {
        let join = self.event_join.take()?;
        let _ = self.core.send(Cmd::Shutdown);
        let report = join.join().expect("event loop thread");
        for j in self.resolver_joins.drain(..) {
            let _ = j.join();
        }
        // Hand actors their endpoints back so the pool can be torn down
        // with plain blocking calls (`into_workers` + `Shutdown`).
        let mut idle = self.pool.drain_idle();
        for w in &mut idle {
            w.deactivate();
        }
        if !idle.is_empty() {
            self.pool.release(idle);
        }
        Some(report)
    }

    /// Drain all outstanding work (every submitted job still completes or
    /// reports unresolved — deadlines bound the wait), stop the event
    /// core, and aggregate the run.
    pub fn finish(mut self) -> ServiceReport {
        let lr = self.shutdown().expect("finish() runs once");
        let mut outcomes = lr.outcomes;
        outcomes.sort_by_key(|o| o.job_id);
        ServiceReport {
            outcomes,
            wall: self.t_start.elapsed(),
            k: self.cfg.k,
            workers: self.start_size,
            revoked: self.pool.revoked(),
            stakes: lr.stakes,
            threads: 1 + self.cfg.resolvers.max(1) + lr.actor_threads,
            overloads: lr.overloads,
            ckpt_cache_hits: lr.ckpt_cache_hits,
            ckpt_cache_misses: lr.ckpt_cache_misses,
        }
    }
}

impl Drop for Delegation {
    fn drop(&mut self) {
        let _ = self.shutdown();
    }
}

/// Terminal handles a frontend keeps for late `Status` queries before
/// evicting the oldest — bounds memory on a long-lived serving
/// coordinator (each retained handle pins its full `JobOutcome`).
const MAX_FINISHED_RETAINED: usize = 1024;

/// The handle registry every clone of one [`DelegationFrontend`] shares:
/// jobs submitted on one connection are visible to `Status`/`Cancel` from
/// any other.
struct FrontendState {
    /// Jobs not yet observed terminal.
    jobs: HashMap<u64, JobHandle>,
    /// Terminal jobs, evicted FIFO beyond [`MAX_FINISHED_RETAINED`] (a
    /// `Status` for an evicted id answers `Unknown`).
    finished: HashMap<u64, JobHandle>,
    finished_order: VecDeque<u64>,
}

/// Serves the client API over the wire: an [`Endpoint`] answering
/// [`Request::Submit`] / [`Request::Status`] / [`Request::Cancel`] by
/// driving an in-process [`Client`]. Plug it into
/// [`serve_connection`](crate::net::tcp::serve_connection) (or
/// [`spawn_server`](crate::net::tcp::spawn_server)) and any
/// [`TcpEndpoint`](crate::net::tcp::TcpEndpoint) becomes a remote job
/// submitter — the `verde coordinator --serve` / `verde client` pair.
///
/// Cloning is cheap and shares the handle registry, so a **threaded accept
/// loop** ([`spawn_server_threaded`](crate::net::tcp::spawn_server_threaded))
/// can serve many concurrent remote clients against one delegation: each
/// connection gets a clone, and every connection sees every job.
#[derive(Clone)]
pub struct DelegationFrontend {
    name: String,
    client: Client,
    state: Arc<Mutex<FrontendState>>,
    /// The delegation's registry, when the frontend serves the stats
    /// plane ([`Request::Stats`]); `None` refuses stats queries.
    registry: Option<Registry>,
}

impl DelegationFrontend {
    pub fn new(name: &str, client: Client) -> DelegationFrontend {
        DelegationFrontend {
            name: name.to_string(),
            client,
            state: Arc::new(Mutex::new(FrontendState {
                jobs: HashMap::new(),
                finished: HashMap::new(),
                finished_order: VecDeque::new(),
            })),
            registry: None,
        }
    }

    /// Serve [`Request::Stats`] from this registry (pass a clone of
    /// [`Delegation::registry`]); without it stats queries are refused.
    pub fn with_stats(mut self, registry: Registry) -> DelegationFrontend {
        self.registry = Some(registry);
        self
    }

    /// Handles registered by remote submissions (on any connection sharing
    /// this frontend) and not yet evicted — waiting on all of them is how
    /// a serving CLI drains before shutdown.
    pub fn handles(&self) -> Vec<JobHandle> {
        let st = self.state.lock().unwrap();
        st.jobs.values().chain(st.finished.values()).cloned().collect()
    }

    /// Register handles recovered by [`Delegation::recover`] so remote
    /// clients re-attach to their pre-crash job ids via `Status`/`Cancel`.
    /// Already-terminal handles land directly in the bounded finished set
    /// (lowest id retired first under the cap); live ones are tracked like
    /// fresh submissions.
    pub fn adopt(&self, handles: Vec<JobHandle>) {
        let mut st = self.state.lock().unwrap();
        for h in handles {
            st.jobs.insert(h.id(), h);
        }
        st.retire_done();
    }

    /// `(live, finished)` handle counts — observability for retirement
    /// behaviour (a frontend that stops receiving submissions must still
    /// drain `live` as jobs settle).
    pub fn tracked(&self) -> (usize, usize) {
        let st = self.state.lock().unwrap();
        (st.jobs.len(), st.finished.len())
    }
}

impl FrontendState {
    /// Migrate every job observed terminal into the bounded finished set,
    /// evicting the oldest beyond the cap. Runs on every Submit, Status,
    /// and Cancel, so even a frontend that stops receiving submissions
    /// retires terminal outcomes instead of pinning them forever.
    fn retire_done(&mut self) {
        let mut done: Vec<u64> = self
            .jobs
            .iter()
            .filter(|(_, h)| matches!(h.try_status(), JobStatus::Done(_)))
            .map(|(&id, _)| id)
            .collect();
        // Deterministic retention order: jobs observed terminal in the same
        // sweep retire lowest-id first, regardless of map iteration order.
        done.sort_unstable();
        for id in done {
            let handle = self.jobs.remove(&id).expect("listed");
            self.finished.insert(id, handle);
            self.finished_order.push_back(id);
            while self.finished_order.len() > MAX_FINISHED_RETAINED {
                let evict = self.finished_order.pop_front().expect("nonempty");
                self.finished.remove(&evict);
            }
        }
    }

    fn lookup(&self, job_id: u64) -> Option<&JobHandle> {
        self.jobs.get(&job_id).or_else(|| self.finished.get(&job_id))
    }
}

impl Endpoint for DelegationFrontend {
    fn name(&self) -> &str {
        &self.name
    }

    fn call(&mut self, req: Request) -> Response {
        match req {
            Request::Submit { spec, policy } => {
                // Submit outside the lock (it only touches the client
                // core), then register under it.
                let handle = self.client.submit(JobRequest { spec, policy });
                let job_id = handle.id();
                let mut st = self.state.lock().unwrap();
                st.retire_done();
                st.jobs.insert(job_id, handle);
                Response::Submitted { job_id }
            }
            Request::Status { job_id } => {
                let mut st = self.state.lock().unwrap();
                st.retire_done();
                // An id evicted past the retention cap answers `Unknown`
                // deterministically — the handle is gone, never a hang.
                Response::Status(match st.lookup(job_id) {
                    None => RemoteStatus::Unknown,
                    Some(h) => h.try_status().remote(),
                })
            }
            Request::Cancel { job_id } => {
                // Clone the handle out so the (blocking) cancel round-trip
                // to the event loop runs without holding the registry lock
                // against other connections. An evicted or unknown id
                // answers `Cancelled(false)`.
                let handle = {
                    let mut st = self.state.lock().unwrap();
                    st.retire_done();
                    st.lookup(job_id).cloned()
                };
                Response::Cancelled(handle.is_some_and(|h| h.cancel()))
            }
            Request::Stats => match &self.registry {
                Some(reg) => Response::Stats(reg.snapshot()),
                None => Response::Refuse(format!("{}: stats plane not enabled", self.name)),
            },
            Request::Ping => Response::Pong,
            Request::Shutdown => Response::Bye,
            other => Response::Refuse(format!(
                "{}: coordinator frontend serves Submit/Status/Cancel, not {other:?}",
                self.name
            )),
        }
    }
}
