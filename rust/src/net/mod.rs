//! Party communication layer: a synchronous request/response endpoint
//! abstraction with byte-level accounting, a thread-backed transport so
//! trainers can run as independent actors, and a non-blocking connection
//! multiplexer ([`mux`]) — the event-driven core the service layer
//! dispatches through.
//!
//! The dispute protocol is referee-driven and strictly turn-based, so the
//! synchronous [`Endpoint::call`] interface remains the faithful model for
//! disputes; the multiplexer exists so a coordinator can keep thousands of
//! workers in flight from a handful of threads, with the blocking interface
//! kept as a thin adapter ([`mux::MuxConn`] implements [`Endpoint`]) so
//! tournaments and disputes run over it unchanged.

pub mod mux;
pub mod readiness;
pub mod tcp;
pub mod threaded;

use crate::util::metrics::Counters;
use crate::verde::protocol::{Request, Response};

/// Anything the referee/client can issue protocol requests to.
pub trait Endpoint {
    fn name(&self) -> &str;
    fn call(&mut self, req: Request) -> Response;
}

impl<E: Endpoint + ?Sized> Endpoint for &mut E {
    fn name(&self) -> &str {
        (**self).name()
    }
    fn call(&mut self, req: Request) -> Response {
        (**self).call(req)
    }
}

/// Wraps an endpoint and meters traffic in both directions — the
/// communication-cost numbers of EXPERIMENTS.md come from these counters.
pub struct Metered<E: Endpoint> {
    pub inner: E,
    pub counters: Counters,
}

impl<E: Endpoint> Metered<E> {
    pub fn new(inner: E) -> Self {
        Metered { inner, counters: Counters::new() }
    }

    pub fn bytes_sent(&self) -> u64 {
        self.counters.get("bytes_to")
    }

    pub fn bytes_received(&self) -> u64 {
        self.counters.get("bytes_from")
    }
}

impl<E: Endpoint> Endpoint for Metered<E> {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn call(&mut self, req: Request) -> Response {
        self.counters.add("bytes_to", req.wire_size() as u64);
        self.counters.incr("requests");
        let resp = self.inner.call(req);
        self.counters.add("bytes_from", resp.wire_size() as u64);
        resp
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Echo;

    impl Endpoint for Echo {
        fn name(&self) -> &str {
            "echo"
        }
        fn call(&mut self, _req: Request) -> Response {
            Response::Refuse("echo".into())
        }
    }

    #[test]
    fn meter_counts_traffic() {
        let mut m = Metered::new(Echo);
        let r = m.call(Request::FinalCommit);
        assert!(matches!(r, Response::Refuse(_)));
        assert!(m.bytes_sent() > 0);
        assert!(m.bytes_received() > 0);
        assert_eq!(m.counters.get("requests"), 1);
    }
}
