//! Thread-backed transport: run any [`Endpoint`] as an independent actor
//! with an mpsc mailbox, mirroring a trainer process on a remote machine.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use super::Endpoint;
use crate::verde::protocol::{Request, Response};

/// Client-side handle to an endpoint running on its own thread.
pub struct Remote {
    name: String,
    tx: Sender<Request>,
    rx: Receiver<Response>,
    join: Option<JoinHandle<()>>,
}

/// Spawn `endpoint` onto a dedicated thread; the returned [`Remote`] is
/// itself an [`Endpoint`].
pub fn spawn<E: Endpoint + Send + 'static>(mut endpoint: E) -> Remote {
    let name = endpoint.name().to_string();
    let (req_tx, req_rx) = channel::<Request>();
    let (resp_tx, resp_rx) = channel::<Response>();
    let join = std::thread::Builder::new()
        .name(format!("verde-{name}"))
        .spawn(move || {
            while let Ok(req) = req_rx.recv() {
                let stop = matches!(req, Request::Shutdown);
                let resp = endpoint.call(req);
                if resp_tx.send(resp).is_err() || stop {
                    break;
                }
            }
        })
        .expect("spawn endpoint thread");
    Remote { name, tx: req_tx, rx: resp_rx, join: Some(join) }
}

impl Endpoint for Remote {
    fn name(&self) -> &str {
        &self.name
    }

    fn call(&mut self, req: Request) -> Response {
        if self.tx.send(req).is_err() {
            return Response::Refuse("endpoint thread gone".into());
        }
        self.rx
            .recv()
            .unwrap_or_else(|_| Response::Refuse("endpoint thread gone".into()))
    }
}

impl Drop for Remote {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        let _ = self.rx.recv();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Const(u8);

    impl Endpoint for Const {
        fn name(&self) -> &str {
            "const"
        }
        fn call(&mut self, req: Request) -> Response {
            match req {
                Request::Shutdown => Response::Bye,
                _ => Response::Refuse(format!("const-{}", self.0)),
            }
        }
    }

    #[test]
    fn remote_roundtrip() {
        let mut r = spawn(Const(7));
        for _ in 0..3 {
            match r.call(Request::FinalCommit) {
                Response::Refuse(s) => assert_eq!(s, "const-7"),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn two_remotes_run_concurrently() {
        let mut a = spawn(Const(1));
        let mut b = spawn(Const(2));
        match (a.call(Request::FinalCommit), b.call(Request::FinalCommit)) {
            (Response::Refuse(x), Response::Refuse(y)) => {
                assert_eq!(x, "const-1");
                assert_eq!(y, "const-2");
            }
            other => panic!("{other:?}"),
        }
    }
}
