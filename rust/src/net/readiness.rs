//! Readiness backends for the connection multiplexer: a raw-syscall
//! `epoll` wrapper plus the portable scan fallback selector.
//!
//! The mux driver needs one question answered cheaply: *which connections
//! can make progress right now?* The in-tree answer since PR 2 was a full
//! scan — try every socket each pass, O(conns) per tick, fine at 64
//! sockets and ruinous at thousands. This module wraps the Linux
//! `epoll_create1`/`epoll_ctl`/`epoll_wait` syscalls behind a minimal
//! [`Readiness`] handle (declared `extern "C"` against the libc the Rust
//! standard library already links, so the crate stays dependency-free
//! offline) and a [`BackendKind`] selector that falls back to the scan
//! loop on platforms or kernels where epoll is unavailable.
//!
//! Design points:
//!
//! - **Level-triggered.** Edge-triggered epoll demands
//!   drain-until-`EAGAIN` discipline on every wakeup; level-triggered
//!   keeps the driver loop identical in shape to the scan loop (pump the
//!   ready set, sleep) and cannot lose a readiness edge to a partial
//!   read. The mux pumps each ready connection once per pass, exactly as
//!   the scan path does.
//! - **Write interest is armed only while a send buffer is non-empty.**
//!   An idle connection costs one registered fd and nothing per tick —
//!   that is the whole point over the scan loop.
//! - **Self-pipe wakeup.** Submitting threads must interrupt a blocked
//!   `epoll_wait` (the scan backend uses a condvar for this). A
//!   non-blocking pipe registered under [`WAKE_TOKEN`] does the same for
//!   epoll: writers poke one byte, the driver drains the pipe and
//!   re-reads its queues.
//!
//! Backend selection (`BackendKind::detect`): the `VERDE_NET_BACKEND`
//! environment variable (`epoll` | `scan`) wins; otherwise epoll is
//! probed at startup and the scan loop is the fallback. The selected
//! backend is exported on the `net_readiness_backend` gauge (1 = epoll,
//! 0 = scan) so a fleet operator can see which spine a coordinator runs.

use std::io;
use std::time::Duration;

/// Token reserved for the self-pipe wakeup; never a connection id.
pub const WAKE_TOKEN: u64 = u64::MAX;

/// Which readiness spine the mux driver runs.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BackendKind {
    /// Kernel readiness queue: poll cost proportional to *ready*
    /// connections. Linux only.
    Epoll,
    /// Portable fallback: scan every connection each pass, condvar sleep.
    Scan,
}

impl BackendKind {
    /// Pick the backend: explicit `VERDE_NET_BACKEND` env override first,
    /// then probe epoll, then the scan fallback.
    pub fn detect() -> BackendKind {
        match std::env::var("VERDE_NET_BACKEND").as_deref() {
            Ok("epoll") => BackendKind::Epoll,
            Ok("scan") => BackendKind::Scan,
            _ => {
                if Readiness::available() {
                    BackendKind::Epoll
                } else {
                    BackendKind::Scan
                }
            }
        }
    }

    /// Value exported on the `net_readiness_backend` gauge.
    pub fn gauge_value(&self) -> u64 {
        match self {
            BackendKind::Epoll => 1,
            BackendKind::Scan => 0,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Epoll => "epoll",
            BackendKind::Scan => "scan",
        }
    }
}

/// One readiness report from [`Readiness::wait`].
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// The token the fd was registered under ([`WAKE_TOKEN`] for the
    /// self-pipe; the mux uses connection ids).
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    /// `EPOLLERR`/`EPOLLHUP`: the peer is gone or the socket errored; a
    /// read on the connection will surface the exact failure.
    pub hangup: bool,
}

#[cfg(unix)]
pub use sys::Readiness;

#[cfg(unix)]
mod sys {
    use super::{Event, WAKE_TOKEN};
    use std::io;
    use std::os::raw::{c_int, c_void};
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    // Linux ABI constants (asm-generic; identical on x86_64 and aarch64).
    const EPOLL_CLOEXEC: c_int = 0o2000000;
    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const O_NONBLOCK: c_int = 0o4000;
    const O_CLOEXEC: c_int = 0o2000000;

    /// `struct epoll_event`: packed on x86_64 (kernel ABI quirk), natural
    /// layout elsewhere.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    // The std library links libc; these are its exported syscall wrappers,
    // declared here directly so no crate dependency is added.
    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        fn pipe2(fds: *mut c_int, flags: c_int) -> c_int;
        fn close(fd: c_int) -> c_int;
        fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    }

    /// An epoll instance plus its self-pipe. All methods take `&self`:
    /// `epoll_ctl` and `epoll_wait` are kernel-side thread-safe, so
    /// submitters register interest and poke the wake pipe concurrently
    /// with a driver blocked in [`Readiness::wait`].
    pub struct Readiness {
        epfd: RawFd,
        wake_rd: RawFd,
        wake_wr: RawFd,
    }

    impl Readiness {
        /// Probe whether epoll can be created at all (used by backend
        /// detection; non-Linux unix kernels lacking the syscall fail
        /// here and fall back to the scan loop).
        pub fn available() -> bool {
            let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if fd >= 0 {
                unsafe { close(fd) };
                true
            } else {
                false
            }
        }

        pub fn new() -> io::Result<Readiness> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            let mut fds: [c_int; 2] = [-1, -1];
            if unsafe { pipe2(fds.as_mut_ptr(), O_NONBLOCK | O_CLOEXEC) } < 0 {
                let e = io::Error::last_os_error();
                unsafe { close(epfd) };
                return Err(e);
            }
            let r = Readiness { epfd, wake_rd: fds[0], wake_wr: fds[1] };
            r.ctl(EPOLL_CTL_ADD, r.wake_rd, EPOLLIN, WAKE_TOKEN)?;
            Ok(r)
        }

        fn ctl(&self, op: c_int, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
            let mut ev = EpollEvent { events, data: token };
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
            if rc < 0 {
                Err(io::Error::last_os_error())
            } else {
                Ok(())
            }
        }

        /// Register a connection fd under `token` with read interest.
        pub fn register(&self, fd: RawFd, token: u64) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, EPOLLIN, token)
        }

        /// Arm or disarm write interest (read interest stays on).
        pub fn set_write_interest(&self, fd: RawFd, token: u64, want: bool) -> io::Result<()> {
            let events = if want { EPOLLIN | EPOLLOUT } else { EPOLLIN };
            self.ctl(EPOLL_CTL_MOD, fd, events, token)
        }

        /// Drop a connection fd from the interest set. Failure is ignored:
        /// a concurrently closed fd removes itself from every epoll set.
        pub fn deregister(&self, fd: RawFd) {
            let _ = self.ctl(EPOLL_CTL_DEL, fd, 0, 0);
        }

        /// Interrupt a blocked [`wait`](Readiness::wait). One byte on the
        /// self-pipe; a full pipe means a wakeup is already pending, which
        /// is all a waker needs.
        pub fn wake(&self) {
            let byte = 1u8;
            unsafe { write(self.wake_wr, (&byte as *const u8).cast::<c_void>(), 1) };
        }

        /// Block until something is ready (or `timeout` elapses), then
        /// fill `out` with the ready set. Self-pipe readiness is drained
        /// and reported as a [`WAKE_TOKEN`] event. `None` blocks
        /// indefinitely. `EINTR` returns an empty set.
        pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> usize {
            out.clear();
            let mut buf = [EpollEvent { events: 0, data: 0 }; 128];
            let timeout_ms: c_int = match timeout {
                None => -1,
                Some(d) => d.as_millis().min(i32::MAX as u128) as c_int,
            };
            let n = unsafe {
                epoll_wait(self.epfd, buf.as_mut_ptr(), buf.len() as c_int, timeout_ms)
            };
            if n <= 0 {
                // 0 = timeout; -1 = EINTR or a real error — either way the
                // driver re-reads its queues and comes back.
                return 0;
            }
            for ev in buf.iter().take(n as usize) {
                let (events, token) = (ev.events, ev.data);
                if token == WAKE_TOKEN {
                    // Coalesce any number of pokes into one wakeup.
                    let mut sink = [0u8; 64];
                    while unsafe {
                        read(self.wake_rd, sink.as_mut_ptr().cast::<c_void>(), sink.len())
                    } > 0
                    {}
                    out.push(Event { token, readable: false, writable: false, hangup: false });
                    continue;
                }
                out.push(Event {
                    token,
                    readable: events & EPOLLIN != 0,
                    writable: events & EPOLLOUT != 0,
                    hangup: events & (EPOLLERR | EPOLLHUP) != 0,
                });
            }
            out.len()
        }
    }

    impl Drop for Readiness {
        fn drop(&mut self) {
            unsafe {
                close(self.wake_rd);
                close(self.wake_wr);
                close(self.epfd);
            }
        }
    }
}

#[cfg(not(unix))]
mod sys_stub {
    use super::Event;
    use std::io;
    use std::time::Duration;

    /// Stub for non-unix targets: construction fails, so backend
    /// detection always selects the scan loop.
    pub struct Readiness;

    impl Readiness {
        pub fn available() -> bool {
            false
        }
        pub fn new() -> io::Result<Readiness> {
            Err(io::Error::new(io::ErrorKind::Unsupported, "epoll requires unix"))
        }
        pub fn register(&self, _fd: i32, _token: u64) -> io::Result<()> {
            unreachable!("stub readiness is never constructed")
        }
        pub fn set_write_interest(&self, _fd: i32, _token: u64, _want: bool) -> io::Result<()> {
            unreachable!("stub readiness is never constructed")
        }
        pub fn deregister(&self, _fd: i32) {}
        pub fn wake(&self) {}
        pub fn wait(&self, _out: &mut Vec<Event>, _timeout: Option<Duration>) -> usize {
            0
        }
    }
}

#[cfg(not(unix))]
pub use sys_stub::Readiness;

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;
    use std::time::Duration;

    #[test]
    fn wake_interrupts_a_blocked_wait() {
        let r = Readiness::new().expect("epoll available on linux CI");
        let mut events = Vec::new();
        // Nothing ready: a short wait times out empty.
        assert_eq!(r.wait(&mut events, Some(Duration::from_millis(10))), 0);
        // A poke from another thread lands as a WAKE_TOKEN event.
        std::thread::scope(|s| {
            s.spawn(|| r.wake());
            let n = r.wait(&mut events, Some(Duration::from_secs(5)));
            assert_eq!(n, 1);
            assert_eq!(events[0].token, WAKE_TOKEN);
        });
        // The pipe was drained: the next wait is quiet again.
        assert_eq!(r.wait(&mut events, Some(Duration::from_millis(10))), 0);
    }

    #[test]
    fn socket_readability_and_write_interest_roundtrip() {
        let r = Readiness::new().expect("epoll available");
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (mut server, _) = listener.accept().unwrap();
        client.set_nonblocking(true).unwrap();
        let fd = client.as_raw_fd();
        r.register(fd, 7).unwrap();

        let mut events = Vec::new();
        // Idle socket: nothing ready.
        assert_eq!(r.wait(&mut events, Some(Duration::from_millis(10))), 0);
        // Bytes from the peer make it readable.
        server.write_all(b"ping").unwrap();
        let n = r.wait(&mut events, Some(Duration::from_secs(5)));
        assert!(n >= 1);
        let ev = events.iter().find(|e| e.token == 7).expect("socket event");
        assert!(ev.readable);
        assert!(!ev.writable, "write interest not armed yet");

        // Arming write interest on an idle socket reports writable
        // immediately (level-triggered, buffer empty).
        r.set_write_interest(fd, 7, true).unwrap();
        let n = r.wait(&mut events, Some(Duration::from_secs(5)));
        assert!(n >= 1);
        assert!(events.iter().any(|e| e.token == 7 && e.writable));

        // Disarm: back to readable-only (the unread "ping" keeps it hot).
        r.set_write_interest(fd, 7, false).unwrap();
        let n = r.wait(&mut events, Some(Duration::from_secs(5)));
        assert!(n >= 1);
        let ev = events.iter().find(|e| e.token == 7).expect("socket event");
        assert!(ev.readable && !ev.writable);

        r.deregister(fd);
        assert_eq!(r.wait(&mut events, Some(Duration::from_millis(10))), 0);
    }

    #[test]
    fn detection_honors_env_override() {
        // Do not mutate the process environment (tests run threaded);
        // just pin the default detection on a kernel with epoll.
        if std::env::var("VERDE_NET_BACKEND").is_err() {
            assert_eq!(BackendKind::detect(), BackendKind::Epoll);
        }
        assert_eq!(BackendKind::Epoll.gauge_value(), 1);
        assert_eq!(BackendKind::Scan.gauge_value(), 0);
        assert_eq!(BackendKind::Epoll.name(), "epoll");
        assert_eq!(BackendKind::Scan.name(), "scan");
    }
}
