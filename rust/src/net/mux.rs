//! Non-blocking connection multiplexer: many in-flight requests over many
//! worker sockets, driven by one readiness-loop thread.
//!
//! The blocking [`TcpEndpoint`](crate::net::tcp::TcpEndpoint) burns one
//! socket round-trip per `call` and one OS thread per concurrent dispatch.
//! The [`Mux`] replaces that with the event-driven core the service layer
//! runs on:
//!
//! * every connection is `set_nonblocking(true)`; a single driver thread
//!   waits on a [readiness backend](crate::net::readiness) — kernel
//!   `epoll` where available (poll cost proportional to *ready*
//!   connections, the 1000-fleet spine), or the portable in-tree scan
//!   loop as the runtime-selected fallback;
//! * request frames carry a caller-chosen **correlation tag**
//!   ([`crate::verde::wire`]); the peer echoes it, and the driver routes
//!   each answer to the completion sink registered under that tag, so any
//!   number of requests can be outstanding per connection;
//! * every submission may carry a **deadline**, tracked in one global
//!   min-heap (lazy deletion against the pending maps) so firing expiries
//!   costs O(log n) per due entry rather than a scan of every in-flight
//!   request. When a deadline passes unanswered the driver synthesizes a
//!   [`Response::Refuse`] completion with
//!   [`CompletionKind::DeadlineExpired`] — the connection itself stays up,
//!   and a late answer to an expired tag is discarded as stale;
//! * a transport failure (reset, EOF with requests outstanding, bad frame)
//!   fails **all** pending requests with [`CompletionKind::Transport`] and
//!   marks the connection dead;
//! * each connection's write buffer is **bounded**
//!   ([`Mux::set_write_cap`], default 32 MiB). A submit that would
//!   overflow it completes immediately with
//!   [`CompletionKind::Overloaded`] instead of growing coordinator
//!   memory without limit behind a slow worker — backpressure the
//!   coordinator surfaces in `ServiceReport::overloads`.
//!
//! [`MuxConn`] is the per-connection handle: non-blocking [`MuxConn::submit`]
//! for the coordinator's completion-queue state machines, plus a blocking
//! [`Endpoint`] adapter (submit + wait with the connection's default
//! deadline) so `run_dispute`/`run_tournament` work over multiplexed
//! connections unchanged.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::io::{self, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::obs::{Counter, Histogram, LATENCY_US_BOUNDS};
use crate::verde::protocol::{Request, Response};
use crate::verde::wire::{frame_bytes, split_frame};

use super::readiness::{BackendKind, Event, Readiness, WAKE_TOKEN};
use super::Endpoint;

/// Cached handles over the process-global registry (`net_mux_*` keys).
/// The driver thread builds one at start; `MuxConn` holds frames-out and
/// overload handles for its submit path. These are process-lifetime
/// totals — parallel delegations share them.
struct MuxMetrics {
    bytes_out: Counter,
    bytes_in: Counter,
    frames_in: Counter,
    deadline_expiries: Counter,
    poll_us: Histogram,
    /// Time spent blocked in `epoll_wait` (epoll backend only).
    epoll_wait_us: Histogram,
}

impl MuxMetrics {
    fn new() -> MuxMetrics {
        let g = crate::obs::global();
        MuxMetrics {
            bytes_out: g.counter("net_mux_bytes_out"),
            bytes_in: g.counter("net_mux_bytes_in"),
            frames_in: g.counter("net_mux_frames_in"),
            deadline_expiries: g.counter("net_mux_deadline_expiries"),
            poll_us: g.histogram("net_mux_poll_us", &LATENCY_US_BOUNDS),
            epoll_wait_us: g.histogram("net_mux_epoll_wait_us", &LATENCY_US_BOUNDS),
        }
    }
}

/// Identifies one multiplexed connection for the lifetime of its [`Mux`].
pub type ConnId = u64;

/// Poll cadence of the scan backend when no socket made progress — the
/// latency floor of the in-tree readiness loop.
const IDLE_POLL: Duration = Duration::from_millis(1);

/// Extra slack a blocking [`MuxConn::call`] waits beyond its deadline for
/// the driver to deliver the synthesized refusal (covers a torn-down mux).
const CALL_GRACE: Duration = Duration::from_millis(500);

/// Default per-connection write-buffer bound. Large enough for a full
/// streaming-seed window plus control traffic; a submit that would push a
/// connection past it completes as [`CompletionKind::Overloaded`].
const DEFAULT_WRITE_CAP: usize = 32 << 20;

/// How a completion was produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompletionKind {
    /// The peer answered within the deadline.
    Answered,
    /// The deadline passed first; `resp` is a synthesized `Refuse`. The
    /// connection is still up — the caller decides whether to revoke.
    DeadlineExpired,
    /// The connection died (reset, EOF mid-conversation, hostile frame);
    /// `resp` is a synthesized `Refuse` and later submits fail instantly.
    Transport,
    /// The connection's bounded write buffer was full: the request was
    /// never enqueued. The connection is healthy but the peer is not
    /// draining — backpressure, not failure.
    Overloaded,
}

impl CompletionKind {
    /// True when the worker failed to take/answer the request (deadline,
    /// dead transport, or a write buffer it is not draining) — the
    /// lease-revocation trigger.
    pub fn unresponsive(self) -> bool {
        !matches!(self, CompletionKind::Answered)
    }
}

/// One resolved request, delivered to the sink registered at submit time.
#[derive(Debug)]
pub struct Completion {
    /// The correlation tag the caller chose at submit time.
    pub token: u64,
    pub kind: CompletionKind,
    pub resp: Response,
}

struct Pending {
    reply: Sender<Completion>,
}

struct Conn {
    name: String,
    stream: TcpStream,
    /// `Some(reason)` once the transport failed; pending requests were
    /// refused and later submits refuse immediately.
    dead: Option<String>,
    /// Outgoing bytes not yet accepted by the socket (`send_pos` consumed).
    send_buf: Vec<u8>,
    send_pos: usize,
    /// Incoming bytes not yet forming a complete frame.
    recv_buf: Vec<u8>,
    /// In-flight requests keyed by correlation tag.
    pending: HashMap<u64, Pending>,
    /// Whether `EPOLLOUT` is currently armed (epoll backend only).
    write_armed: bool,
    /// Whether the fd is in the epoll interest set (epoll backend only).
    registered: bool,
    raw_sent: u64,
    raw_received: u64,
    frames_sent: u64,
    frames_received: u64,
}

impl Conn {
    /// Bytes queued but not yet accepted by the socket.
    fn unflushed(&self) -> usize {
        self.send_buf.len() - self.send_pos
    }
}

#[cfg(unix)]
fn conn_fd(stream: &TcpStream) -> i32 {
    use std::os::unix::io::AsRawFd;
    stream.as_raw_fd()
}

#[cfg(not(unix))]
fn conn_fd(_stream: &TcpStream) -> i32 {
    -1 // the readiness backend is never constructed off-unix
}

/// Raw traffic counters for one connection (frame headers included in the
/// `raw_*` figures, exactly as they crossed the socket).
#[derive(Debug, Default, Clone, Copy)]
pub struct ConnStats {
    pub raw_sent: u64,
    pub raw_received: u64,
    pub frames_sent: u64,
    pub frames_received: u64,
    pub pending: usize,
}

struct State {
    conns: HashMap<ConnId, Conn>,
    next_conn: ConnId,
    /// Global deadline min-heap: `(deadline, conn, tag)`, lazily deleted —
    /// an entry whose tag is no longer pending is skipped when it pops.
    deadlines: BinaryHeap<Reverse<(Instant, ConnId, u64)>>,
    /// Connections with freshly queued outbound bytes (epoll backend:
    /// the driver pumps exactly these plus the kernel-ready set).
    dirty: Vec<ConnId>,
    /// Per-connection write-buffer bound in bytes.
    write_cap: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    wake: Condvar,
    /// `Some` when the epoll backend drives this mux.
    readiness: Option<Readiness>,
    backend: BackendKind,
}

impl Shared {
    /// Wake the driver whichever backend it runs: condvar for the scan
    /// loop, self-pipe for a driver blocked in `epoll_wait`.
    fn poke(&self) {
        self.wake.notify_all();
        if let Some(r) = &self.readiness {
            r.wake();
        }
    }
}

/// The multiplexer: owns the driver thread and all registered connections.
pub struct Mux {
    shared: Arc<Shared>,
    driver: Option<JoinHandle<()>>,
}

impl Mux {
    /// Start a multiplexer on the auto-detected readiness backend
    /// (`VERDE_NET_BACKEND` env override, else epoll where available,
    /// else the scan loop).
    pub fn new() -> Mux {
        Mux::with_backend(BackendKind::detect())
    }

    /// Start a multiplexer on an explicit readiness backend (tests and
    /// benches pin this for backend-equivalence runs). Requesting
    /// [`BackendKind::Epoll`] where the kernel lacks it falls back to the
    /// scan loop.
    pub fn with_backend(kind: BackendKind) -> Mux {
        let readiness = match kind {
            BackendKind::Epoll => Readiness::new().ok(),
            BackendKind::Scan => None,
        };
        let backend = if readiness.is_some() { BackendKind::Epoll } else { BackendKind::Scan };
        crate::obs::global().gauge("net_readiness_backend").set(backend.gauge_value());
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                conns: HashMap::new(),
                next_conn: 1,
                deadlines: BinaryHeap::new(),
                dirty: Vec::new(),
                write_cap: DEFAULT_WRITE_CAP,
                shutdown: false,
            }),
            wake: Condvar::new(),
            readiness,
            backend,
        });
        let driver_shared = Arc::clone(&shared);
        let driver = std::thread::Builder::new()
            .name("verde-mux".into())
            .spawn(move || drive(&driver_shared))
            .expect("spawn mux driver");
        Mux { shared, driver: Some(driver) }
    }

    /// The readiness backend actually driving this mux.
    pub fn backend(&self) -> BackendKind {
        self.shared.backend
    }

    /// Bound every connection's write buffer to `bytes` (default 32 MiB).
    /// A submit that would overflow the bound completes immediately as
    /// [`CompletionKind::Overloaded`]; a single frame is always accepted
    /// into an empty buffer so progress is never wedged by a small cap.
    pub fn set_write_cap(&self, bytes: usize) {
        self.shared.state.lock().unwrap().write_cap = bytes.max(1);
    }

    /// Connect to a listening worker and register the socket with the
    /// driver. The returned handle submits work and reads completions.
    pub fn connect(&self, name: &str, addr: impl ToSocketAddrs) -> io::Result<MuxConn> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        stream.set_nonblocking(true)?;
        let mut st = self.shared.state.lock().unwrap();
        let id = st.next_conn;
        st.next_conn += 1;
        let mut registered = false;
        if let Some(r) = &self.shared.readiness {
            registered = r.register(conn_fd(&stream), id).is_ok();
            if !registered {
                // Registration failure (fd exhaustion in the interest set)
                // degrades this connection to unusable rather than killing
                // the mux; the first submit will fail it.
                return Err(io::Error::other("epoll registration failed"));
            }
        }
        st.conns.insert(
            id,
            Conn {
                name: name.to_string(),
                stream,
                dead: None,
                send_buf: Vec::new(),
                send_pos: 0,
                recv_buf: Vec::new(),
                pending: HashMap::new(),
                write_armed: false,
                registered,
                raw_sent: 0,
                raw_received: 0,
                frames_sent: 0,
                frames_received: 0,
            },
        );
        crate::obs::global().gauge("net_mux_conns").set(st.conns.len() as u64);
        drop(st);
        self.shared.poke();
        let (reply_tx, reply_rx) = channel();
        Ok(MuxConn {
            shared: Arc::clone(&self.shared),
            id,
            name: name.to_string(),
            call_deadline: Duration::from_secs(60),
            // Blocking calls tag from the top half of the space so they can
            // never collide with coordinator dispatch tokens (< 2^63).
            next_call_tag: 1 << 63,
            reply_tx,
            reply_rx,
            frames_out: crate::obs::global().counter("net_mux_frames_out"),
            overloads: crate::obs::global().counter("net_mux_overloads"),
            faulted: false,
        })
    }
}

impl Default for Mux {
    fn default() -> Self {
        Mux::new()
    }
}

impl Drop for Mux {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.poke();
        if let Some(j) = self.driver.take() {
            let _ = j.join();
        }
    }
}

/// Handle to one multiplexed connection. Submit is non-blocking; the
/// [`Endpoint`] impl is the thin blocking adapter (used by disputes and
/// tournaments) over the same completion machinery.
pub struct MuxConn {
    shared: Arc<Shared>,
    id: ConnId,
    name: String,
    /// Deadline applied to blocking [`Endpoint::call`]s.
    call_deadline: Duration,
    next_call_tag: u64,
    reply_tx: Sender<Completion>,
    reply_rx: Receiver<Completion>,
    /// Cached global-registry handle: frames enqueued by this handle
    /// (`net_mux_frames_out`). Submit runs on caller threads, so the
    /// handle lives here rather than in the driver's [`MuxMetrics`].
    frames_out: Counter,
    /// Cached global-registry handle: submits refused by the
    /// write-buffer bound (`net_mux_overloads`).
    overloads: Counter,
    /// Latched when any request on this handle went unanswered — the
    /// coordinator reads this after a job to decide on revocation.
    faulted: bool,
}

impl MuxConn {
    /// Override the deadline blocking calls use (default 60 s).
    pub fn with_call_deadline(mut self, d: Duration) -> MuxConn {
        self.call_deadline = d;
        self
    }

    /// Enqueue `req` under correlation tag `token`; the answer (or a
    /// synthesized refusal on deadline/transport failure) arrives on
    /// `reply` as a [`Completion`]. Never blocks on the socket.
    ///
    /// `token` must be unique among this connection's in-flight requests
    /// and below `2^63` (the upper half is reserved for blocking calls).
    pub fn submit(
        &self,
        token: u64,
        req: &Request,
        deadline: Option<Instant>,
        reply: &Sender<Completion>,
    ) {
        let payload = req.encode();
        let mut st = self.shared.state.lock().unwrap();
        let dead = CompletionKind::Transport;
        if st.shutdown {
            let _ = reply.send(refused(token, dead, &self.name, "multiplexer shut down"));
            return;
        }
        let write_cap = st.write_cap;
        let Some(conn) = st.conns.get_mut(&self.id) else {
            let _ = reply.send(refused(token, dead, &self.name, "connection unregistered"));
            return;
        };
        if let Some(why) = conn.dead.clone() {
            let _ = reply.send(refused(token, dead, &self.name, &why));
            return;
        }
        if conn.pending.contains_key(&token) {
            let _ = reply.send(refused(token, dead, &self.name, "duplicate correlation tag"));
            return;
        }
        let frame = frame_bytes(token, &payload);
        // Bounded write buffer: a peer not draining its socket may not
        // grow coordinator memory without limit. An empty buffer accepts
        // any single frame so a small cap can never wedge progress.
        if conn.unflushed() > 0 && conn.unflushed() + frame.len() > write_cap {
            self.overloads.inc();
            let _ = reply.send(refused(
                token,
                CompletionKind::Overloaded,
                &self.name,
                "connection write buffer full",
            ));
            return;
        }
        conn.send_buf.extend_from_slice(&frame);
        conn.frames_sent += 1;
        self.frames_out.inc();
        conn.pending.insert(token, Pending { reply: reply.clone() });
        if let Some(d) = deadline {
            st.deadlines.push(Reverse((d, self.id, token)));
        }
        if self.shared.readiness.is_some() {
            st.dirty.push(self.id);
        }
        drop(st);
        self.shared.poke();
    }

    /// Traffic counters for this connection.
    pub fn stats(&self) -> ConnStats {
        let st = self.shared.state.lock().unwrap();
        match st.conns.get(&self.id) {
            Some(c) => ConnStats {
                raw_sent: c.raw_sent,
                raw_received: c.raw_received,
                frames_sent: c.frames_sent,
                frames_received: c.frames_received,
                pending: c.pending.len(),
            },
            None => ConnStats::default(),
        }
    }

    /// True once any request on this handle went unanswered (deadline or
    /// transport failure).
    pub fn faulted(&self) -> bool {
        self.faulted
    }

    /// Clear the fault latch (called when a fresh lease begins).
    pub fn reset_fault(&mut self) {
        self.faulted = false;
    }
}

impl Drop for MuxConn {
    /// Deregister the connection: the handle is the only way to use it, so
    /// dropping it (lease revocation, pool teardown) must close the socket
    /// and stop the driver polling it — a revoked worker may not leak an
    /// fd and driver work for the mux's lifetime.
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        if let Some(mut conn) = st.conns.remove(&self.id) {
            if conn.registered {
                if let Some(r) = &self.shared.readiness {
                    r.deregister(conn_fd(&conn.stream));
                }
            }
            fail_conn(&mut conn, "connection handle dropped");
        }
        crate::obs::global().gauge("net_mux_conns").set(st.conns.len() as u64);
        drop(st);
        self.shared.poke();
    }
}

impl Endpoint for MuxConn {
    fn name(&self) -> &str {
        &self.name
    }

    /// Blocking adapter: submit with the connection's default deadline and
    /// wait for the completion. A deadline or transport failure returns the
    /// synthesized `Refuse` and latches [`MuxConn::faulted`].
    fn call(&mut self, req: Request) -> Response {
        let tag = self.next_call_tag;
        self.next_call_tag += 1;
        let deadline = Instant::now() + self.call_deadline;
        let reply = self.reply_tx.clone();
        self.submit(tag, &req, Some(deadline), &reply);
        loop {
            match self.reply_rx.recv_timeout(self.call_deadline + CALL_GRACE) {
                Ok(c) if c.token == tag => {
                    if c.kind.unresponsive() {
                        self.faulted = true;
                    }
                    return c.resp;
                }
                // Stale completion from an earlier abandoned call: skip.
                Ok(_) => continue,
                Err(_) => {
                    self.faulted = true;
                    return Response::Refuse(format!("{}: multiplexer unresponsive", self.name));
                }
            }
        }
    }
}

fn refused(token: u64, kind: CompletionKind, name: &str, why: &str) -> Completion {
    Completion {
        token,
        kind,
        resp: Response::Refuse(format!("{name}: {why}")),
    }
}

/// Fail every pending request on `conn` and mark it dead.
fn fail_conn(conn: &mut Conn, why: &str) {
    if conn.dead.is_some() {
        return;
    }
    conn.dead = Some(why.to_string());
    for (tag, p) in conn.pending.drain() {
        let _ = p.reply.send(refused(tag, CompletionKind::Transport, &conn.name, why));
    }
}

/// Flush queued outgoing bytes; returns true if any byte moved.
fn pump_writes(conn: &mut Conn, m: &MuxMetrics) -> bool {
    let mut progress = false;
    while conn.send_pos < conn.send_buf.len() {
        match conn.stream.write(&conn.send_buf[conn.send_pos..]) {
            Ok(0) => {
                fail_conn(conn, "socket write returned 0");
                break;
            }
            Ok(n) => {
                conn.send_pos += n;
                conn.raw_sent += n as u64;
                m.bytes_out.add(n as u64);
                progress = true;
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => {
                fail_conn(conn, &format!("socket write failed: {e}"));
                break;
            }
        }
    }
    if conn.send_pos == conn.send_buf.len() && !conn.send_buf.is_empty() {
        conn.send_buf.clear();
        conn.send_pos = 0;
    }
    progress
}

/// Drain readable bytes into the reassembly buffer. Returns `(progress,
/// failure)`; a failure (EOF or read error) is NOT applied here — the
/// caller must deliver already-buffered frames first, so a peer that
/// answers and immediately closes does not lose its final response.
fn pump_reads(conn: &mut Conn, scratch: &mut [u8], m: &MuxMetrics) -> (bool, Option<String>) {
    let mut progress = false;
    let mut failure = None;
    loop {
        match conn.stream.read(scratch) {
            Ok(0) => {
                failure = Some("peer closed the connection".to_string());
                break;
            }
            Ok(n) => {
                conn.recv_buf.extend_from_slice(&scratch[..n]);
                conn.raw_received += n as u64;
                m.bytes_in.add(n as u64);
                progress = true;
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => {
                failure = Some(format!("socket read failed: {e}"));
                break;
            }
        }
    }
    (progress, failure)
}

/// Carve complete frames out of the reassembly buffer and complete their
/// pending requests. Frames for expired/unknown tags are stale — dropped.
fn deliver_frames(conn: &mut Conn, m: &MuxMetrics) {
    loop {
        match split_frame(&conn.recv_buf) {
            Ok(Some((tag, payload, consumed))) => {
                conn.recv_buf.drain(..consumed);
                conn.frames_received += 1;
                m.frames_in.inc();
                if let Some(p) = conn.pending.remove(&tag) {
                    let resp = Response::decode(&payload).unwrap_or_else(|e| {
                        Response::Refuse(format!("bad frame from {}: {e}", conn.name))
                    });
                    let _ = p.reply.send(Completion {
                        token: tag,
                        kind: CompletionKind::Answered,
                        resp,
                    });
                }
            }
            Ok(None) => break,
            Err(e) => {
                fail_conn(conn, &format!("bad frame from {}: {e}", conn.name));
                break;
            }
        }
    }
}

/// Pump one connection end to end: flush writes, drain reads, deliver
/// complete frames, and apply a read failure only after delivery. Returns
/// true if any byte moved.
fn pump_conn(conn: &mut Conn, scratch: &mut [u8], m: &MuxMetrics) -> bool {
    if conn.dead.is_some() {
        return false;
    }
    let mut progress = pump_writes(conn, m);
    if conn.dead.is_none() {
        let (read_progress, failure) = pump_reads(conn, scratch, m);
        progress |= read_progress;
        // Complete frames first: an answer that arrived in the same
        // pass as the EOF must reach its caller, not a refusal.
        deliver_frames(conn, m);
        if let Some(why) = failure {
            if conn.dead.is_none() {
                if conn.pending.is_empty() {
                    conn.dead = Some(why);
                } else {
                    fail_conn(conn, &why);
                }
            }
        }
    }
    progress
}

/// Pop every due entry off the global deadline heap and refuse the
/// requests still pending under them. Entries whose tag already completed
/// (or whose connection died/closed) are stale — skipped. Connections stay
/// registered; policy (revocation) belongs to the coordinator.
fn fire_deadlines(st: &mut State, now: Instant, m: &MuxMetrics) {
    while let Some(Reverse((d, _, _))) = st.deadlines.peek() {
        if *d > now {
            break;
        }
        let Reverse((_, conn_id, tag)) = st.deadlines.pop().expect("peeked");
        let Some(conn) = st.conns.get_mut(&conn_id) else { continue };
        if conn.dead.is_some() {
            continue;
        }
        if let Some(p) = conn.pending.remove(&tag) {
            m.deadline_expiries.inc();
            let _ = p.reply.send(refused(
                tag,
                CompletionKind::DeadlineExpired,
                &conn.name,
                "deadline expired before the worker answered",
            ));
        }
    }
}

/// Next due instant on the heap (may be stale — waking early is harmless).
fn next_deadline(st: &State) -> Option<Instant> {
    st.deadlines.peek().map(|Reverse((d, _, _))| *d)
}

fn drive(shared: &Shared) {
    match &shared.readiness {
        Some(r) => drive_epoll(shared, r),
        None => drive_scan(shared),
    }
}

/// The epoll driver: pump exactly the connections the kernel reports
/// ready plus those with freshly queued submits, then block in
/// `epoll_wait` until the next readiness event, wakeup, or deadline.
/// Poll cost per pass is O(ready + dirty), not O(conns) — the property
/// that lets one loop drive a 1024-connection fleet.
fn drive_epoll(shared: &Shared, readiness: &Readiness) {
    let mut scratch = vec![0u8; 64 * 1024];
    let metrics = MuxMetrics::new();
    let mut events: Vec<Event> = Vec::new();
    let mut ready: Vec<ConnId> = Vec::new();
    loop {
        let mut st = shared.state.lock().unwrap();
        if st.shutdown {
            for conn in st.conns.values_mut() {
                fail_conn(conn, "multiplexer shut down");
            }
            return;
        }
        let now = Instant::now();
        let mut work = std::mem::take(&mut st.dirty);
        work.extend(ready.drain(..));
        work.sort_unstable();
        work.dedup();
        let mut progress = false;
        for id in work {
            let Some(conn) = st.conns.get_mut(&id) else { continue };
            progress |= pump_conn(conn, &mut scratch, &metrics);
            // Arm EPOLLOUT only while bytes survive a write attempt, so an
            // idle-but-writable socket does not wake the loop forever.
            let want = conn.dead.is_none() && conn.unflushed() > 0;
            if conn.registered && want != conn.write_armed {
                let fd = conn_fd(&conn.stream);
                if readiness.set_write_interest(fd, id, want).is_ok() {
                    conn.write_armed = want;
                }
            }
            if conn.dead.is_some() && conn.registered {
                readiness.deregister(conn_fd(&conn.stream));
                conn.registered = false;
            }
        }
        fire_deadlines(&mut st, now, &metrics);
        if progress {
            metrics.poll_us.observe_micros(now.elapsed());
        }
        let timeout = next_deadline(&st)
            .map(|d| d.saturating_duration_since(now).max(Duration::from_millis(1)));
        // Release the lock before blocking: submitters must never queue
        // behind a driver that is merely waiting for readiness.
        drop(st);
        let t_wait = Instant::now();
        readiness.wait(&mut events, timeout);
        metrics.epoll_wait_us.observe_micros(t_wait.elapsed());
        ready.extend(events.iter().filter(|e| e.token != WAKE_TOKEN).map(|e| e.token));
    }
}

/// The portable scan driver: pump every live connection each pass,
/// condvar-sleep when nothing moved. O(conns) per tick — the fallback
/// spine, and the reference the epoll backend is equivalence-tested
/// against.
fn drive_scan(shared: &Shared) {
    let mut scratch = vec![0u8; 64 * 1024];
    let metrics = MuxMetrics::new();
    loop {
        let mut st = shared.state.lock().unwrap();
        if st.shutdown {
            for conn in st.conns.values_mut() {
                fail_conn(conn, "multiplexer shut down");
            }
            return;
        }
        let now = Instant::now();
        let mut progress = false;
        let mut outstanding = false;
        for conn in st.conns.values_mut() {
            progress |= pump_conn(conn, &mut scratch, &metrics);
            if conn.dead.is_none() {
                outstanding |= !conn.pending.is_empty() || conn.unflushed() > 0;
            }
        }
        fire_deadlines(&mut st, now, &metrics);
        if progress {
            // Time only productive passes: idle polls at the readiness
            // cadence would swamp the histogram with near-zero samples.
            metrics.poll_us.observe_micros(now.elapsed());
        }
        if !progress {
            if outstanding {
                // Answers or deadlines are due: poll at the readiness cadence.
                let mut timeout = IDLE_POLL;
                if let Some(d) = next_deadline(&st) {
                    timeout = timeout
                        .min(d.saturating_duration_since(now))
                        .max(Duration::from_micros(100));
                }
                let _ = shared.wake.wait_timeout(st, timeout);
            } else {
                // Fully idle: sleep until a submit/connect/shutdown notifies.
                let _ = shared.wake.wait(st);
            }
        }
    }
}

/// Payload-byte and frame accounting identity for a flushed connection:
/// `raw = Σ payload + FRAME_HEADER_LEN × frames` in each direction. Tests
/// assert it; exported for reuse by integration tests and benches.
pub fn accounting_identity(stats: &ConnStats, payload_sent: u64, payload_received: u64) -> bool {
    use crate::verde::wire::FRAME_HEADER_LEN;
    stats.raw_sent == payload_sent + FRAME_HEADER_LEN as u64 * stats.frames_sent
        && stats.raw_received == payload_received + FRAME_HEADER_LEN as u64 * stats.frames_received
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::Hash;
    use crate::net::tcp::spawn_server;
    use std::net::TcpListener;

    /// Both readiness backends, so every scenario is equivalence-checked
    /// (epoll is skipped only where the kernel lacks it).
    fn backends() -> Vec<BackendKind> {
        if Readiness::available() {
            vec![BackendKind::Scan, BackendKind::Epoll]
        } else {
            vec![BackendKind::Scan]
        }
    }

    /// Answers every request with a fixed commit (Shutdown with Bye).
    struct Fixed(Hash);

    impl Endpoint for Fixed {
        fn name(&self) -> &str {
            "fixed"
        }
        fn call(&mut self, req: Request) -> Response {
            match req {
                Request::Shutdown => Response::Bye,
                Request::Ping => Response::Pong,
                _ => Response::Commit(self.0),
            }
        }
    }

    fn ephemeral() -> TcpListener {
        TcpListener::bind("127.0.0.1:0").expect("bind ephemeral")
    }

    #[test]
    fn many_requests_in_flight_complete_by_tag() {
        for kind in backends() {
            let listener = ephemeral();
            let addr = listener.local_addr().unwrap();
            let h = Hash::of_bytes(b"muxed");
            let server = spawn_server(listener, Fixed(h), Some(1));

            let mux = Mux::with_backend(kind);
            assert_eq!(mux.backend(), kind);
            let conn = mux.connect("fixed", addr).unwrap();
            let (tx, rx) = channel();
            // Submit a burst before reading any completion: all in flight at
            // once on one connection, matched back by tag.
            for token in 0..8u64 {
                conn.submit(token, &Request::FinalCommit, None, &tx);
            }
            let mut seen = Vec::new();
            for _ in 0..8 {
                let c = rx.recv_timeout(Duration::from_secs(10)).expect("completion");
                assert_eq!(c.kind, CompletionKind::Answered);
                match c.resp {
                    Response::Commit(got) => assert_eq!(got, h),
                    other => panic!("{other:?}"),
                }
                seen.push(c.token);
            }
            seen.sort();
            assert_eq!(seen, (0..8).collect::<Vec<u64>>());

            // Raw traffic identity: payloads + 12-byte header per frame.
            let stats = conn.stats();
            assert_eq!(stats.frames_sent, 8);
            assert_eq!(stats.frames_received, 8);
            let req_payload = 8 * Request::FinalCommit.wire_size() as u64;
            let resp_payload = 8 * Response::Commit(h).wire_size() as u64;
            assert!(accounting_identity(&stats, req_payload, resp_payload));

            // Clean shutdown via the blocking adapter.
            let mut conn = conn;
            assert!(matches!(conn.call(Request::Shutdown), Response::Bye));
            server.join().expect("server thread");
        }
    }

    #[test]
    fn deadline_expires_to_refuse_without_blocking_any_thread() {
        for kind in backends() {
            // A listener that accepts and then never answers.
            let listener = ephemeral();
            let addr = listener.local_addr().unwrap();
            let hold = std::thread::spawn(move || {
                let (stream, _) = listener.accept().expect("accept");
                // Hold the socket open past the deadline under test.
                std::thread::sleep(Duration::from_secs(2));
                drop(stream);
            });

            let mux = Mux::with_backend(kind);
            let conn = mux.connect("silent", addr).unwrap();
            let (tx, rx) = channel();
            let t0 = Instant::now();
            conn.submit(
                1,
                &Request::FinalCommit,
                Some(Instant::now() + Duration::from_millis(100)),
                &tx,
            );
            let c = rx.recv_timeout(Duration::from_secs(5)).expect("deadline completion");
            assert_eq!(c.kind, CompletionKind::DeadlineExpired);
            assert!(matches!(c.resp, Response::Refuse(_)));
            assert!(c.kind.unresponsive());
            assert!(
                t0.elapsed() < Duration::from_secs(3),
                "deadline must fire promptly, took {:?}",
                t0.elapsed()
            );
            drop(conn);
            drop(mux); // must not hang on the silent peer
            let _ = hold.join();
        }
    }

    #[test]
    fn transport_death_fails_all_pending_and_later_submits() {
        for kind in backends() {
            // Peer accepts, reads nothing, and closes immediately.
            let listener = ephemeral();
            let addr = listener.local_addr().unwrap();
            let closer = std::thread::spawn(move || {
                let (stream, _) = listener.accept().expect("accept");
                drop(stream);
            });

            let mux = Mux::with_backend(kind);
            let conn = mux.connect("flaky", addr).unwrap();
            closer.join().unwrap();
            let (tx, rx) = channel();
            conn.submit(1, &Request::FinalCommit, None, &tx);
            conn.submit(2, &Request::FinalCommit, None, &tx);
            let mut kinds = Vec::new();
            for _ in 0..2 {
                let c = rx.recv_timeout(Duration::from_secs(10)).expect("failure completion");
                assert!(matches!(c.resp, Response::Refuse(_)));
                kinds.push(c.kind);
            }
            assert!(kinds.iter().all(|k| k.unresponsive()));
            // The connection is now dead: new submits refuse instantly.
            conn.submit(3, &Request::FinalCommit, None, &tx);
            let c = rx.recv_timeout(Duration::from_secs(2)).expect("instant refuse");
            assert_eq!(c.kind, CompletionKind::Transport);
        }
    }

    #[test]
    fn blocking_endpoint_adapter_latches_fault_on_deadline() {
        for kind in backends() {
            let listener = ephemeral();
            let addr = listener.local_addr().unwrap();
            let hold = std::thread::spawn(move || {
                let (stream, _) = listener.accept().expect("accept");
                std::thread::sleep(Duration::from_secs(2));
                drop(stream);
            });

            let mux = Mux::with_backend(kind);
            let mut conn = mux
                .connect("silent", addr)
                .unwrap()
                .with_call_deadline(Duration::from_millis(100));
            assert!(!conn.faulted());
            let resp = conn.call(Request::FinalCommit);
            assert!(matches!(resp, Response::Refuse(_)));
            assert!(conn.faulted(), "unanswered call latches the fault flag");
            conn.reset_fault();
            assert!(!conn.faulted());
            drop(conn);
            drop(mux);
            let _ = hold.join();
        }
    }

    #[test]
    fn two_connections_multiplex_through_one_driver() {
        for kind in backends() {
            let la = ephemeral();
            let lb = ephemeral();
            let (aa, ab) = (la.local_addr().unwrap(), lb.local_addr().unwrap());
            let ha = Hash::of_bytes(b"a");
            let hb = Hash::of_bytes(b"b");
            let sa = spawn_server(la, Fixed(ha), Some(1));
            let sb = spawn_server(lb, Fixed(hb), Some(1));

            let mux = Mux::with_backend(kind);
            let ca = mux.connect("a", aa).unwrap();
            let cb = mux.connect("b", ab).unwrap();
            let (tx, rx) = channel();
            for token in 0..4u64 {
                ca.submit(token, &Request::FinalCommit, None, &tx);
                cb.submit(token, &Request::FinalCommit, None, &tx);
            }
            let mut got_a = 0;
            let mut got_b = 0;
            for _ in 0..8 {
                let c = rx.recv_timeout(Duration::from_secs(10)).expect("completion");
                match c.resp {
                    Response::Commit(h) if h == ha => got_a += 1,
                    Response::Commit(h) if h == hb => got_b += 1,
                    other => panic!("{other:?}"),
                }
            }
            assert_eq!((got_a, got_b), (4, 4));
            let (mut ca, mut cb) = (ca, cb);
            assert!(matches!(ca.call(Request::Shutdown), Response::Bye));
            assert!(matches!(cb.call(Request::Shutdown), Response::Bye));
            sa.join().unwrap();
            sb.join().unwrap();
        }
    }

    #[test]
    fn write_cap_overflow_completes_as_overloaded_not_transport() {
        for kind in backends() {
            // Peer accepts and never reads: the kernel buffer fills, then
            // the mux write buffer fills, then submits must bounce as
            // Overloaded while the connection itself stays alive.
            let listener = ephemeral();
            let addr = listener.local_addr().unwrap();
            let (done_tx, done_rx) = channel::<()>();
            let hold = std::thread::spawn(move || {
                let (stream, _) = listener.accept().expect("accept");
                let _ = done_rx.recv_timeout(Duration::from_secs(30));
                drop(stream);
            });

            let mux = Mux::with_backend(kind);
            mux.set_write_cap(256 * 1024);
            let conn = mux.connect("slow", addr).unwrap();
            let (tx, rx) = channel();
            // ~8 MiB of checkpoint-chunk frames: far beyond cap + any
            // kernel socket buffer, so overflow must occur.
            let spec = crate::train::JobSpec::quick(crate::model::Preset::Mlp, 4);
            let req = Request::SeedCheckpoint {
                spec,
                start: 2,
                root: Hash::of_bytes(b"cap"),
                total_chunks: 64,
                chunk: 0,
                payload: vec![7u8; 128 * 1024],
            };
            for token in 0..64u64 {
                conn.submit(token, &req, None, &tx);
            }
            let mut overloaded = 0;
            let mut transport = 0;
            while let Ok(c) = rx.recv_timeout(Duration::from_millis(500)) {
                match c.kind {
                    CompletionKind::Overloaded => overloaded += 1,
                    CompletionKind::Transport => transport += 1,
                    k => panic!("unexpected completion kind {k:?}"),
                }
            }
            assert!(overloaded > 0, "cap overflow must surface as Overloaded");
            assert_eq!(transport, 0, "backpressure must not kill the connection");
            assert!(CompletionKind::Overloaded.unresponsive());
            let _ = done_tx.send(());
            drop(conn);
            drop(mux);
            let _ = hold.join();
        }
    }
}
