//! Non-blocking connection multiplexer: many in-flight requests over many
//! worker sockets, driven by one readiness-loop thread.
//!
//! The blocking [`TcpEndpoint`](crate::net::tcp::TcpEndpoint) burns one
//! socket round-trip per `call` and one OS thread per concurrent dispatch.
//! The [`Mux`] replaces that with the event-driven core the service layer
//! runs on:
//!
//! * every connection is `set_nonblocking(true)`; a single driver thread
//!   polls readiness in-tree (no epoll dependency — the loop attempts
//!   writes/reads and backs off on `WouldBlock`);
//! * request frames carry a caller-chosen **correlation tag**
//!   ([`crate::verde::wire`]); the peer echoes it, and the driver routes
//!   each answer to the completion sink registered under that tag, so any
//!   number of requests can be outstanding per connection;
//! * every submission may carry a **deadline**. When it passes without an
//!   answer the driver synthesizes a [`Response::Refuse`] completion with
//!   [`CompletionKind::DeadlineExpired`] — the connection itself stays up,
//!   and a late answer to an expired tag is discarded as stale;
//! * a transport failure (reset, EOF with requests outstanding, bad frame)
//!   fails **all** pending requests with [`CompletionKind::Transport`] and
//!   marks the connection dead.
//!
//! [`MuxConn`] is the per-connection handle: non-blocking [`MuxConn::submit`]
//! for the coordinator's completion-queue state machines, plus a blocking
//! [`Endpoint`] adapter (submit + wait with the connection's default
//! deadline) so `run_dispute`/`run_tournament` work over multiplexed
//! connections unchanged.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::obs::{Counter, Histogram, LATENCY_US_BOUNDS};
use crate::verde::protocol::{Request, Response};
use crate::verde::wire::{frame_bytes, split_frame};

use super::Endpoint;

/// Cached handles over the process-global registry (`net_mux_*` keys).
/// The driver thread builds one at start; `MuxConn` holds a frames-out
/// handle for its submit path. These are process-lifetime totals —
/// parallel delegations share them.
struct MuxMetrics {
    bytes_out: Counter,
    bytes_in: Counter,
    frames_in: Counter,
    deadline_expiries: Counter,
    poll_us: Histogram,
}

impl MuxMetrics {
    fn new() -> MuxMetrics {
        let g = crate::obs::global();
        MuxMetrics {
            bytes_out: g.counter("net_mux_bytes_out"),
            bytes_in: g.counter("net_mux_bytes_in"),
            frames_in: g.counter("net_mux_frames_in"),
            deadline_expiries: g.counter("net_mux_deadline_expiries"),
            poll_us: g.histogram("net_mux_poll_us", &LATENCY_US_BOUNDS),
        }
    }
}

/// Identifies one multiplexed connection for the lifetime of its [`Mux`].
pub type ConnId = u64;

/// Poll cadence when no socket made progress — the latency floor of the
/// in-tree readiness loop.
const IDLE_POLL: Duration = Duration::from_millis(1);

/// Extra slack a blocking [`MuxConn::call`] waits beyond its deadline for
/// the driver to deliver the synthesized refusal (covers a torn-down mux).
const CALL_GRACE: Duration = Duration::from_millis(500);

/// How a completion was produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompletionKind {
    /// The peer answered within the deadline.
    Answered,
    /// The deadline passed first; `resp` is a synthesized `Refuse`. The
    /// connection is still up — the caller decides whether to revoke.
    DeadlineExpired,
    /// The connection died (reset, EOF mid-conversation, hostile frame);
    /// `resp` is a synthesized `Refuse` and later submits fail instantly.
    Transport,
}

impl CompletionKind {
    /// True when the worker failed to answer (deadline or dead transport) —
    /// the lease-revocation trigger.
    pub fn unresponsive(self) -> bool {
        !matches!(self, CompletionKind::Answered)
    }
}

/// One resolved request, delivered to the sink registered at submit time.
#[derive(Debug)]
pub struct Completion {
    /// The correlation tag the caller chose at submit time.
    pub token: u64,
    pub kind: CompletionKind,
    pub resp: Response,
}

struct Pending {
    deadline: Option<Instant>,
    reply: Sender<Completion>,
}

struct Conn {
    name: String,
    stream: TcpStream,
    /// `Some(reason)` once the transport failed; pending requests were
    /// refused and later submits refuse immediately.
    dead: Option<String>,
    /// Outgoing bytes not yet accepted by the socket (`send_pos` consumed).
    send_buf: Vec<u8>,
    send_pos: usize,
    /// Incoming bytes not yet forming a complete frame.
    recv_buf: Vec<u8>,
    /// In-flight requests keyed by correlation tag.
    pending: HashMap<u64, Pending>,
    raw_sent: u64,
    raw_received: u64,
    frames_sent: u64,
    frames_received: u64,
}

/// Raw traffic counters for one connection (frame headers included in the
/// `raw_*` figures, exactly as they crossed the socket).
#[derive(Debug, Default, Clone, Copy)]
pub struct ConnStats {
    pub raw_sent: u64,
    pub raw_received: u64,
    pub frames_sent: u64,
    pub frames_received: u64,
    pub pending: usize,
}

struct State {
    conns: HashMap<ConnId, Conn>,
    next_conn: ConnId,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    wake: Condvar,
}

/// The multiplexer: owns the driver thread and all registered connections.
pub struct Mux {
    shared: Arc<Shared>,
    driver: Option<JoinHandle<()>>,
}

impl Mux {
    /// Start a multiplexer with its driver thread.
    pub fn new() -> Mux {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                conns: HashMap::new(),
                next_conn: 1,
                shutdown: false,
            }),
            wake: Condvar::new(),
        });
        let driver_shared = Arc::clone(&shared);
        let driver = std::thread::Builder::new()
            .name("verde-mux".into())
            .spawn(move || drive(&driver_shared))
            .expect("spawn mux driver");
        Mux { shared, driver: Some(driver) }
    }

    /// Connect to a listening worker and register the socket with the
    /// driver. The returned handle submits work and reads completions.
    pub fn connect(&self, name: &str, addr: impl ToSocketAddrs) -> io::Result<MuxConn> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        stream.set_nonblocking(true)?;
        let mut st = self.shared.state.lock().unwrap();
        let id = st.next_conn;
        st.next_conn += 1;
        st.conns.insert(
            id,
            Conn {
                name: name.to_string(),
                stream,
                dead: None,
                send_buf: Vec::new(),
                send_pos: 0,
                recv_buf: Vec::new(),
                pending: HashMap::new(),
                raw_sent: 0,
                raw_received: 0,
                frames_sent: 0,
                frames_received: 0,
            },
        );
        drop(st);
        self.shared.wake.notify_all();
        let (reply_tx, reply_rx) = channel();
        Ok(MuxConn {
            shared: Arc::clone(&self.shared),
            id,
            name: name.to_string(),
            call_deadline: Duration::from_secs(60),
            // Blocking calls tag from the top half of the space so they can
            // never collide with coordinator dispatch tokens (< 2^63).
            next_call_tag: 1 << 63,
            reply_tx,
            reply_rx,
            frames_out: crate::obs::global().counter("net_mux_frames_out"),
            faulted: false,
        })
    }
}

impl Default for Mux {
    fn default() -> Self {
        Mux::new()
    }
}

impl Drop for Mux {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.wake.notify_all();
        if let Some(j) = self.driver.take() {
            let _ = j.join();
        }
    }
}

/// Handle to one multiplexed connection. Submit is non-blocking; the
/// [`Endpoint`] impl is the thin blocking adapter (used by disputes and
/// tournaments) over the same completion machinery.
pub struct MuxConn {
    shared: Arc<Shared>,
    id: ConnId,
    name: String,
    /// Deadline applied to blocking [`Endpoint::call`]s.
    call_deadline: Duration,
    next_call_tag: u64,
    reply_tx: Sender<Completion>,
    reply_rx: Receiver<Completion>,
    /// Cached global-registry handle: frames enqueued by this handle
    /// (`net_mux_frames_out`). Submit runs on caller threads, so the
    /// handle lives here rather than in the driver's [`MuxMetrics`].
    frames_out: Counter,
    /// Latched when any request on this handle went unanswered — the
    /// coordinator reads this after a job to decide on revocation.
    faulted: bool,
}

impl MuxConn {
    /// Override the deadline blocking calls use (default 60 s).
    pub fn with_call_deadline(mut self, d: Duration) -> MuxConn {
        self.call_deadline = d;
        self
    }

    /// Enqueue `req` under correlation tag `token`; the answer (or a
    /// synthesized refusal on deadline/transport failure) arrives on
    /// `reply` as a [`Completion`]. Never blocks on the socket.
    ///
    /// `token` must be unique among this connection's in-flight requests
    /// and below `2^63` (the upper half is reserved for blocking calls).
    pub fn submit(
        &self,
        token: u64,
        req: &Request,
        deadline: Option<Instant>,
        reply: &Sender<Completion>,
    ) {
        let payload = req.encode();
        let mut st = self.shared.state.lock().unwrap();
        let dead = CompletionKind::Transport;
        if st.shutdown {
            let _ = reply.send(refused(token, dead, &self.name, "multiplexer shut down"));
            return;
        }
        let Some(conn) = st.conns.get_mut(&self.id) else {
            let _ = reply.send(refused(token, dead, &self.name, "connection unregistered"));
            return;
        };
        if let Some(why) = conn.dead.clone() {
            let _ = reply.send(refused(token, dead, &self.name, &why));
            return;
        }
        if conn.pending.contains_key(&token) {
            let _ = reply.send(refused(token, dead, &self.name, "duplicate correlation tag"));
            return;
        }
        conn.send_buf.extend_from_slice(&frame_bytes(token, &payload));
        conn.frames_sent += 1;
        self.frames_out.inc();
        conn.pending.insert(token, Pending { deadline, reply: reply.clone() });
        drop(st);
        self.shared.wake.notify_all();
    }

    /// Traffic counters for this connection.
    pub fn stats(&self) -> ConnStats {
        let st = self.shared.state.lock().unwrap();
        match st.conns.get(&self.id) {
            Some(c) => ConnStats {
                raw_sent: c.raw_sent,
                raw_received: c.raw_received,
                frames_sent: c.frames_sent,
                frames_received: c.frames_received,
                pending: c.pending.len(),
            },
            None => ConnStats::default(),
        }
    }

    /// True once any request on this handle went unanswered (deadline or
    /// transport failure).
    pub fn faulted(&self) -> bool {
        self.faulted
    }

    /// Clear the fault latch (called when a fresh lease begins).
    pub fn reset_fault(&mut self) {
        self.faulted = false;
    }
}

impl Drop for MuxConn {
    /// Deregister the connection: the handle is the only way to use it, so
    /// dropping it (lease revocation, pool teardown) must close the socket
    /// and stop the driver polling it — a revoked worker may not leak an
    /// fd and driver work for the mux's lifetime.
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        if let Some(mut conn) = st.conns.remove(&self.id) {
            fail_conn(&mut conn, "connection handle dropped");
        }
        drop(st);
        self.shared.wake.notify_all();
    }
}

impl Endpoint for MuxConn {
    fn name(&self) -> &str {
        &self.name
    }

    /// Blocking adapter: submit with the connection's default deadline and
    /// wait for the completion. A deadline or transport failure returns the
    /// synthesized `Refuse` and latches [`MuxConn::faulted`].
    fn call(&mut self, req: Request) -> Response {
        let tag = self.next_call_tag;
        self.next_call_tag += 1;
        let deadline = Instant::now() + self.call_deadline;
        let reply = self.reply_tx.clone();
        self.submit(tag, &req, Some(deadline), &reply);
        loop {
            match self.reply_rx.recv_timeout(self.call_deadline + CALL_GRACE) {
                Ok(c) if c.token == tag => {
                    if c.kind.unresponsive() {
                        self.faulted = true;
                    }
                    return c.resp;
                }
                // Stale completion from an earlier abandoned call: skip.
                Ok(_) => continue,
                Err(_) => {
                    self.faulted = true;
                    return Response::Refuse(format!("{}: multiplexer unresponsive", self.name));
                }
            }
        }
    }
}

fn refused(token: u64, kind: CompletionKind, name: &str, why: &str) -> Completion {
    Completion {
        token,
        kind,
        resp: Response::Refuse(format!("{name}: {why}")),
    }
}

/// Fail every pending request on `conn` and mark it dead.
fn fail_conn(conn: &mut Conn, why: &str) {
    if conn.dead.is_some() {
        return;
    }
    conn.dead = Some(why.to_string());
    for (tag, p) in conn.pending.drain() {
        let _ = p.reply.send(refused(tag, CompletionKind::Transport, &conn.name, why));
    }
}

/// Flush queued outgoing bytes; returns true if any byte moved.
fn pump_writes(conn: &mut Conn, m: &MuxMetrics) -> bool {
    let mut progress = false;
    while conn.send_pos < conn.send_buf.len() {
        match conn.stream.write(&conn.send_buf[conn.send_pos..]) {
            Ok(0) => {
                fail_conn(conn, "socket write returned 0");
                break;
            }
            Ok(n) => {
                conn.send_pos += n;
                conn.raw_sent += n as u64;
                m.bytes_out.add(n as u64);
                progress = true;
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => {
                fail_conn(conn, &format!("socket write failed: {e}"));
                break;
            }
        }
    }
    if conn.send_pos == conn.send_buf.len() && !conn.send_buf.is_empty() {
        conn.send_buf.clear();
        conn.send_pos = 0;
    }
    progress
}

/// Drain readable bytes into the reassembly buffer. Returns `(progress,
/// failure)`; a failure (EOF or read error) is NOT applied here — the
/// caller must deliver already-buffered frames first, so a peer that
/// answers and immediately closes does not lose its final response.
fn pump_reads(conn: &mut Conn, scratch: &mut [u8], m: &MuxMetrics) -> (bool, Option<String>) {
    let mut progress = false;
    let mut failure = None;
    loop {
        match conn.stream.read(scratch) {
            Ok(0) => {
                failure = Some("peer closed the connection".to_string());
                break;
            }
            Ok(n) => {
                conn.recv_buf.extend_from_slice(&scratch[..n]);
                conn.raw_received += n as u64;
                m.bytes_in.add(n as u64);
                progress = true;
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => {
                failure = Some(format!("socket read failed: {e}"));
                break;
            }
        }
    }
    (progress, failure)
}

/// Carve complete frames out of the reassembly buffer and complete their
/// pending requests. Frames for expired/unknown tags are stale — dropped.
fn deliver_frames(conn: &mut Conn, m: &MuxMetrics) {
    loop {
        match split_frame(&conn.recv_buf) {
            Ok(Some((tag, payload, consumed))) => {
                conn.recv_buf.drain(..consumed);
                conn.frames_received += 1;
                m.frames_in.inc();
                if let Some(p) = conn.pending.remove(&tag) {
                    let resp = Response::decode(&payload).unwrap_or_else(|e| {
                        Response::Refuse(format!("bad frame from {}: {e}", conn.name))
                    });
                    let _ = p.reply.send(Completion {
                        token: tag,
                        kind: CompletionKind::Answered,
                        resp,
                    });
                }
            }
            Ok(None) => break,
            Err(e) => {
                fail_conn(conn, &format!("bad frame from {}: {e}", conn.name));
                break;
            }
        }
    }
}

/// Refuse every pending request whose deadline has passed. The connection
/// stays registered — the peer may still be healthy for later work; policy
/// (revocation) belongs to the coordinator.
fn expire_deadlines(conn: &mut Conn, now: Instant, m: &MuxMetrics) {
    let expired: Vec<u64> = conn
        .pending
        .iter()
        .filter(|(_, p)| p.deadline.is_some_and(|d| d <= now))
        .map(|(&t, _)| t)
        .collect();
    for tag in expired {
        if let Some(p) = conn.pending.remove(&tag) {
            m.deadline_expiries.inc();
            let _ = p.reply.send(refused(
                tag,
                CompletionKind::DeadlineExpired,
                &conn.name,
                "deadline expired before the worker answered",
            ));
        }
    }
}

/// The readiness loop: pump every live connection, deliver completions,
/// fire deadlines, and sleep only when nothing moved.
fn drive(shared: &Shared) {
    let mut scratch = vec![0u8; 64 * 1024];
    let metrics = MuxMetrics::new();
    loop {
        let mut st = shared.state.lock().unwrap();
        if st.shutdown {
            for conn in st.conns.values_mut() {
                fail_conn(conn, "multiplexer shut down");
            }
            return;
        }
        let now = Instant::now();
        let mut progress = false;
        let mut outstanding = false;
        let mut next_deadline: Option<Instant> = None;
        for conn in st.conns.values_mut() {
            if conn.dead.is_some() {
                continue;
            }
            progress |= pump_writes(conn, &metrics);
            if conn.dead.is_none() {
                let (read_progress, failure) = pump_reads(conn, &mut scratch, &metrics);
                progress |= read_progress;
                // Complete frames first: an answer that arrived in the same
                // pass as the EOF must reach its caller, not a refusal.
                deliver_frames(conn, &metrics);
                if let Some(why) = failure {
                    if conn.dead.is_none() {
                        if conn.pending.is_empty() {
                            conn.dead = Some(why);
                        } else {
                            fail_conn(conn, &why);
                        }
                    }
                }
            }
            if conn.dead.is_none() {
                expire_deadlines(conn, now, &metrics);
                outstanding |= !conn.pending.is_empty() || conn.send_pos < conn.send_buf.len();
                for p in conn.pending.values() {
                    if let Some(d) = p.deadline {
                        next_deadline = Some(next_deadline.map_or(d, |nd: Instant| nd.min(d)));
                    }
                }
            }
        }
        if progress {
            // Time only productive passes: idle polls at the readiness
            // cadence would swamp the histogram with near-zero samples.
            metrics.poll_us.observe_micros(now.elapsed());
        }
        if !progress {
            if outstanding {
                // Answers or deadlines are due: poll at the readiness cadence.
                let mut timeout = IDLE_POLL;
                if let Some(d) = next_deadline {
                    timeout = timeout
                        .min(d.saturating_duration_since(now))
                        .max(Duration::from_micros(100));
                }
                let _ = shared.wake.wait_timeout(st, timeout);
            } else {
                // Fully idle: sleep until a submit/connect/shutdown notifies.
                let _ = shared.wake.wait(st);
            }
        }
    }
}

/// Payload-byte and frame accounting identity for a flushed connection:
/// `raw = Σ payload + FRAME_HEADER_LEN × frames` in each direction. Tests
/// assert it; exported for reuse by integration tests and benches.
pub fn accounting_identity(stats: &ConnStats, payload_sent: u64, payload_received: u64) -> bool {
    use crate::verde::wire::FRAME_HEADER_LEN;
    stats.raw_sent == payload_sent + FRAME_HEADER_LEN as u64 * stats.frames_sent
        && stats.raw_received == payload_received + FRAME_HEADER_LEN as u64 * stats.frames_received
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::Hash;
    use crate::net::tcp::spawn_server;
    use std::net::TcpListener;

    /// Answers every request with a fixed commit (Shutdown with Bye).
    struct Fixed(Hash);

    impl Endpoint for Fixed {
        fn name(&self) -> &str {
            "fixed"
        }
        fn call(&mut self, req: Request) -> Response {
            match req {
                Request::Shutdown => Response::Bye,
                Request::Ping => Response::Pong,
                _ => Response::Commit(self.0),
            }
        }
    }

    fn ephemeral() -> TcpListener {
        TcpListener::bind("127.0.0.1:0").expect("bind ephemeral")
    }

    #[test]
    fn many_requests_in_flight_complete_by_tag() {
        let listener = ephemeral();
        let addr = listener.local_addr().unwrap();
        let h = Hash::of_bytes(b"muxed");
        let server = spawn_server(listener, Fixed(h), Some(1));

        let mux = Mux::new();
        let conn = mux.connect("fixed", addr).unwrap();
        let (tx, rx) = channel();
        // Submit a burst before reading any completion: all in flight at
        // once on one connection, matched back by tag.
        for token in 0..8u64 {
            conn.submit(token, &Request::FinalCommit, None, &tx);
        }
        let mut seen = Vec::new();
        for _ in 0..8 {
            let c = rx.recv_timeout(Duration::from_secs(10)).expect("completion");
            assert_eq!(c.kind, CompletionKind::Answered);
            match c.resp {
                Response::Commit(got) => assert_eq!(got, h),
                other => panic!("{other:?}"),
            }
            seen.push(c.token);
        }
        seen.sort();
        assert_eq!(seen, (0..8).collect::<Vec<u64>>());

        // Raw traffic identity: payloads + 12-byte header per frame.
        let stats = conn.stats();
        assert_eq!(stats.frames_sent, 8);
        assert_eq!(stats.frames_received, 8);
        let req_payload = 8 * Request::FinalCommit.wire_size() as u64;
        let resp_payload = 8 * Response::Commit(h).wire_size() as u64;
        assert!(accounting_identity(&stats, req_payload, resp_payload));

        // Clean shutdown via the blocking adapter.
        let mut conn = conn;
        assert!(matches!(conn.call(Request::Shutdown), Response::Bye));
        server.join().expect("server thread");
    }

    #[test]
    fn deadline_expires_to_refuse_without_blocking_any_thread() {
        // A listener that accepts and then never answers.
        let listener = ephemeral();
        let addr = listener.local_addr().unwrap();
        let hold = std::thread::spawn(move || {
            let (stream, _) = listener.accept().expect("accept");
            // Hold the socket open past the deadline under test.
            std::thread::sleep(Duration::from_secs(2));
            drop(stream);
        });

        let mux = Mux::new();
        let conn = mux.connect("silent", addr).unwrap();
        let (tx, rx) = channel();
        let t0 = Instant::now();
        conn.submit(
            1,
            &Request::FinalCommit,
            Some(Instant::now() + Duration::from_millis(100)),
            &tx,
        );
        let c = rx.recv_timeout(Duration::from_secs(5)).expect("deadline completion");
        assert_eq!(c.kind, CompletionKind::DeadlineExpired);
        assert!(matches!(c.resp, Response::Refuse(_)));
        assert!(c.kind.unresponsive());
        assert!(
            t0.elapsed() < Duration::from_secs(3),
            "deadline must fire promptly, took {:?}",
            t0.elapsed()
        );
        drop(conn);
        drop(mux); // must not hang on the silent peer
        let _ = hold.join();
    }

    #[test]
    fn transport_death_fails_all_pending_and_later_submits() {
        // Peer accepts, reads nothing, and closes immediately.
        let listener = ephemeral();
        let addr = listener.local_addr().unwrap();
        let closer = std::thread::spawn(move || {
            let (stream, _) = listener.accept().expect("accept");
            drop(stream);
        });

        let mux = Mux::new();
        let conn = mux.connect("flaky", addr).unwrap();
        closer.join().unwrap();
        let (tx, rx) = channel();
        conn.submit(1, &Request::FinalCommit, None, &tx);
        conn.submit(2, &Request::FinalCommit, None, &tx);
        let mut kinds = Vec::new();
        for _ in 0..2 {
            let c = rx.recv_timeout(Duration::from_secs(10)).expect("failure completion");
            assert!(matches!(c.resp, Response::Refuse(_)));
            kinds.push(c.kind);
        }
        assert!(kinds.iter().all(|k| k.unresponsive()));
        // The connection is now dead: new submits refuse instantly.
        conn.submit(3, &Request::FinalCommit, None, &tx);
        let c = rx.recv_timeout(Duration::from_secs(2)).expect("instant refuse");
        assert_eq!(c.kind, CompletionKind::Transport);
    }

    #[test]
    fn blocking_endpoint_adapter_latches_fault_on_deadline() {
        let listener = ephemeral();
        let addr = listener.local_addr().unwrap();
        let hold = std::thread::spawn(move || {
            let (stream, _) = listener.accept().expect("accept");
            std::thread::sleep(Duration::from_secs(2));
            drop(stream);
        });

        let mux = Mux::new();
        let mut conn = mux
            .connect("silent", addr)
            .unwrap()
            .with_call_deadline(Duration::from_millis(100));
        assert!(!conn.faulted());
        let resp = conn.call(Request::FinalCommit);
        assert!(matches!(resp, Response::Refuse(_)));
        assert!(conn.faulted(), "unanswered call latches the fault flag");
        conn.reset_fault();
        assert!(!conn.faulted());
        drop(conn);
        drop(mux);
        let _ = hold.join();
    }

    #[test]
    fn two_connections_multiplex_through_one_driver() {
        let la = ephemeral();
        let lb = ephemeral();
        let (aa, ab) = (la.local_addr().unwrap(), lb.local_addr().unwrap());
        let ha = Hash::of_bytes(b"a");
        let hb = Hash::of_bytes(b"b");
        let sa = spawn_server(la, Fixed(ha), Some(1));
        let sb = spawn_server(lb, Fixed(hb), Some(1));

        let mux = Mux::new();
        let ca = mux.connect("a", aa).unwrap();
        let cb = mux.connect("b", ab).unwrap();
        let (tx, rx) = channel();
        for token in 0..4u64 {
            ca.submit(token, &Request::FinalCommit, None, &tx);
            cb.submit(token, &Request::FinalCommit, None, &tx);
        }
        let mut got_a = 0;
        let mut got_b = 0;
        for _ in 0..8 {
            let c = rx.recv_timeout(Duration::from_secs(10)).expect("completion");
            match c.resp {
                Response::Commit(h) if h == ha => got_a += 1,
                Response::Commit(h) if h == hb => got_b += 1,
                other => panic!("{other:?}"),
            }
        }
        assert_eq!((got_a, got_b), (4, 4));
        let (mut ca, mut cb) = (ca, cb);
        assert!(matches!(ca.call(Request::Shutdown), Response::Bye));
        assert!(matches!(cb.call(Request::Shutdown), Response::Bye));
        sa.join().unwrap();
        sb.join().unwrap();
    }
}
