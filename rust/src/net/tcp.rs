//! Socket transport: run the dispute/delegation protocol between genuinely
//! separate processes over `std::net::TcpStream`, using the canonical frame
//! codec of [`crate::verde::wire`].
//!
//! Both halves count **raw socket bytes** (every byte that actually crosses
//! the transport, frame prefixes included) independently of the protocol's
//! `wire_size()` accounting, so tests can prove the two agree exactly:
//! `raw = Σ wire_size(msg) + 4 × frames`.

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::obs::Counter;
use crate::util::metrics::Counters;
use crate::verde::protocol::{Request, Response};
use crate::verde::wire::{read_frame, write_frame, WireError};

use super::Endpoint;

/// How long [`Drop`] waits for the goodbye handshake before abandoning the
/// stream — a dead worker must never be able to hang an endpoint drop.
const GOODBYE_TIMEOUT: Duration = Duration::from_millis(250);

/// A stream wrapper counting the bytes that actually pass through the
/// socket in each direction.
struct CountingStream {
    inner: TcpStream,
    sent: u64,
    received: u64,
    /// Cached process-global totals (`net_tcp_bytes_out` /
    /// `net_tcp_bytes_in`) — registered once per stream, bumped alongside
    /// the per-stream counters.
    g_sent: Counter,
    g_received: Counter,
}

impl CountingStream {
    fn new(inner: TcpStream) -> CountingStream {
        let g = crate::obs::global();
        CountingStream {
            inner,
            sent: 0,
            received: 0,
            g_sent: g.counter("net_tcp_bytes_out"),
            g_received: g.counter("net_tcp_bytes_in"),
        }
    }
}

impl Read for CountingStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.received += n as u64;
        self.g_received.add(n as u64);
        Ok(n)
    }
}

impl Write for CountingStream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.sent += n as u64;
        self.g_sent.add(n as u64);
        Ok(n)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

/// Client-side handle to a worker across a TCP connection; implements
/// [`Endpoint`], so disputes and tournaments run over it unchanged.
pub struct TcpEndpoint {
    name: String,
    stream: CountingStream,
    /// Correlation tag for the next request frame; responses are matched
    /// by echoed tag, so a stale answer to an abandoned request can never
    /// be mistaken for the current one.
    next_tag: u64,
    /// Protocol-level accounting: payload bytes (`bytes_to`/`bytes_from`)
    /// and frame counts (`frames_to`/`frames_from`).
    pub counters: Counters,
}

impl TcpEndpoint {
    /// Connect to a listening worker.
    pub fn connect(name: &str, addr: impl ToSocketAddrs) -> io::Result<TcpEndpoint> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(TcpEndpoint {
            name: name.to_string(),
            stream: CountingStream::new(stream),
            next_tag: 1,
            counters: Counters::new(),
        })
    }

    /// Raw bytes written to the socket (frame prefixes included).
    pub fn raw_sent(&self) -> u64 {
        self.stream.sent
    }

    /// Raw bytes read from the socket (frame prefixes included).
    pub fn raw_received(&self) -> u64 {
        self.stream.received
    }
}

impl Endpoint for TcpEndpoint {
    fn name(&self) -> &str {
        &self.name
    }

    fn call(&mut self, req: Request) -> Response {
        let tag = self.next_tag;
        self.next_tag += 1;
        let payload = req.encode();
        self.counters.add("bytes_to", payload.len() as u64);
        self.counters.incr("frames_to");
        if let Err(e) = write_frame(&mut self.stream, tag, &payload) {
            return Response::Refuse(format!("send to {} failed: {e}", self.name));
        }
        // One request is in flight at a time on the blocking path, but a
        // peer may still replay stale tags; skip them rather than
        // desynchronize.
        loop {
            match read_frame(&mut self.stream) {
                Ok(Some((got_tag, frame))) => {
                    self.counters.add("bytes_from", frame.len() as u64);
                    self.counters.incr("frames_from");
                    if got_tag != tag {
                        continue;
                    }
                    return match Response::decode(&frame) {
                        Ok(resp) => resp,
                        Err(e) => Response::Refuse(format!("bad frame from {}: {e}", self.name)),
                    };
                }
                Ok(None) => {
                    return Response::Refuse(format!("{} closed the connection", self.name))
                }
                Err(e) => return Response::Refuse(format!("recv from {} failed: {e}", self.name)),
            }
        }
    }
}

impl Drop for TcpEndpoint {
    fn drop(&mut self) {
        // Best-effort goodbye so the server's serve loop ends promptly.
        // Both directions are bounded: a dead worker with a full kernel
        // send buffer could otherwise block the write, and one that never
        // answers could block the read — dropping an endpoint must not
        // hang on a socket that will never cooperate.
        let _ = self.stream.inner.set_write_timeout(Some(GOODBYE_TIMEOUT));
        let _ = self.stream.inner.set_read_timeout(Some(GOODBYE_TIMEOUT));
        let tag = self.next_tag;
        let _ = write_frame(&mut self.stream, tag, &Request::Shutdown.encode());
        let _ = read_frame(&mut self.stream);
    }
}

/// Traffic served over one connection.
#[derive(Debug, Default, Clone, Copy)]
pub struct ServeStats {
    pub requests: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

/// Serve one client connection: decode request frames, route them through
/// `endpoint`, write response frames. Returns when the client sends
/// [`Request::Shutdown`] or closes the stream.
pub fn serve_connection<E: Endpoint>(
    stream: TcpStream,
    endpoint: &mut E,
) -> Result<ServeStats, WireError> {
    stream.set_nodelay(true).ok();
    let mut stream = CountingStream::new(stream);
    let mut stats = ServeStats::default();
    let served = crate::obs::global().counter("net_tcp_requests_served");
    // Live-connection gauge, balanced on every exit path (error or EOF).
    struct ConnGuard(crate::obs::Gauge);
    impl Drop for ConnGuard {
        fn drop(&mut self) {
            self.0.sub(1);
        }
    }
    let conns = crate::obs::global().gauge("net_tcp_conns");
    conns.add(1);
    let _guard = ConnGuard(conns);
    loop {
        let (tag, frame) = match read_frame(&mut stream)? {
            Some(f) => f,
            None => break,
        };
        stats.bytes_in += frame.len() as u64;
        let req = match Request::decode(&frame) {
            Ok(r) => r,
            Err(e) => {
                // Tell the peer why, then drop the desynchronized stream.
                let refuse = Response::Refuse(format!("bad request: {e}")).encode();
                let _ = write_frame(&mut stream, tag, &refuse);
                return Err(e);
            }
        };
        let stop = matches!(req, Request::Shutdown);
        let resp = endpoint.call(req);
        let payload = resp.encode();
        stats.bytes_out += payload.len() as u64;
        stats.requests += 1;
        served.inc();
        // Echo the request's correlation tag so multiplexing clients can
        // match this answer to the frame that asked for it.
        write_frame(&mut stream, tag, &payload).map_err(|e| WireError::Io(e.to_string()))?;
        if stop {
            break;
        }
    }
    Ok(stats)
}

/// Spawn a **threaded** accept loop: every connection is served
/// concurrently on its own thread through a clone of `endpoint`. Built for
/// endpoints whose clones share state — a
/// [`DelegationFrontend`](crate::service::client::DelegationFrontend)
/// clone shares its handle registry, so many remote clients can submit,
/// poll, and cancel simultaneously against one delegation. With
/// `max_conns = Some(n)` the acceptor stops after `n` connections, joins
/// every connection thread, and hands the endpoint back.
pub fn spawn_server_threaded<E: Endpoint + Clone + Send + 'static>(
    listener: TcpListener,
    endpoint: E,
    max_conns: Option<usize>,
) -> JoinHandle<E> {
    std::thread::Builder::new()
        .name(format!("verde-accept-{}", endpoint.name()))
        .spawn(move || {
            let mut served = 0usize;
            let mut conns: Vec<JoinHandle<()>> = Vec::new();
            for conn in listener.incoming() {
                // Reap finished connection threads so a long-lived server
                // (max_conns: None) doesn't accumulate join handles.
                conns.retain(|c| !c.is_finished());
                match conn {
                    Ok(stream) => {
                        let mut ep = endpoint.clone();
                        let handle = std::thread::Builder::new()
                            .name(format!("verde-conn-{}", ep.name()))
                            .spawn(move || {
                                let _ = serve_connection(stream, &mut ep);
                            })
                            .expect("spawn connection thread");
                        conns.push(handle);
                        served += 1;
                    }
                    Err(e) => {
                        eprintln!("accept failed: {e}");
                        continue;
                    }
                }
                if max_conns.is_some_and(|m| served >= m) {
                    break;
                }
            }
            for c in conns {
                let _ = c.join();
            }
            endpoint
        })
        .expect("spawn threaded server")
}

/// Spawn a worker server on its own thread: accept connections from
/// `listener` and serve each sequentially through `endpoint` (workers hold
/// per-job state, so one conversation at a time is the consistent model).
/// With `max_conns = Some(n)` the thread exits after `n` connections and
/// hands the endpoint back for inspection.
pub fn spawn_server<E: Endpoint + Send + 'static>(
    listener: TcpListener,
    mut endpoint: E,
    max_conns: Option<usize>,
) -> JoinHandle<E> {
    std::thread::Builder::new()
        .name(format!("verde-serve-{}", endpoint.name()))
        .spawn(move || {
            let mut served = 0usize;
            for conn in listener.incoming() {
                match conn {
                    Ok(stream) => {
                        let _ = serve_connection(stream, &mut endpoint);
                        served += 1;
                    }
                    Err(_) => continue,
                }
                if max_conns.is_some_and(|m| served >= m) {
                    break;
                }
            }
            endpoint
        })
        .expect("spawn server thread")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::Hash;

    /// Echo-style endpoint: answers every request with a fixed commit.
    struct Fixed(Hash);

    impl Endpoint for Fixed {
        fn name(&self) -> &str {
            "fixed"
        }
        fn call(&mut self, req: Request) -> Response {
            match req {
                Request::Shutdown => Response::Bye,
                _ => Response::Commit(self.0),
            }
        }
    }

    fn ephemeral() -> TcpListener {
        TcpListener::bind("127.0.0.1:0").expect("bind ephemeral")
    }

    #[test]
    fn tcp_roundtrip_and_raw_byte_accounting() {
        let listener = ephemeral();
        let addr = listener.local_addr().unwrap();
        let h = Hash::of_bytes(b"fixed-commit");
        let server = spawn_server(listener, Fixed(h), Some(1));

        let mut ep = TcpEndpoint::connect("fixed", addr).unwrap();
        for _ in 0..3 {
            match ep.call(Request::FinalCommit) {
                Response::Commit(got) => assert_eq!(got, h),
                other => panic!("{other:?}"),
            }
        }
        // Raw socket traffic == protocol payloads + one 12-byte header
        // (u32 length + u64 correlation tag) per frame.
        let header = crate::verde::wire::FRAME_HEADER_LEN as u64;
        assert_eq!(
            ep.raw_sent(),
            ep.counters.get("bytes_to") + header * ep.counters.get("frames_to")
        );
        assert_eq!(
            ep.raw_received(),
            ep.counters.get("bytes_from") + header * ep.counters.get("frames_from")
        );
        assert_eq!(ep.counters.get("frames_to"), 3);
        drop(ep); // sends Shutdown, unblocking the serve loop
        server.join().expect("server thread");
    }

    #[test]
    fn server_survives_reconnects() {
        let listener = ephemeral();
        let addr = listener.local_addr().unwrap();
        let h = Hash::of_bytes(b"again");
        let server = spawn_server(listener, Fixed(h), Some(2));
        for _ in 0..2 {
            let mut ep = TcpEndpoint::connect("fixed", addr).unwrap();
            match ep.call(Request::NodeHashSeq { step: 1 }) {
                Response::Commit(got) => assert_eq!(got, h),
                other => panic!("{other:?}"),
            }
        }
        server.join().expect("server thread");
    }
}
