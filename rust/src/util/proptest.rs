//! Minimal property-testing harness (stand-in for the `proptest` crate,
//! unavailable offline — DESIGN.md §4.5).
//!
//! Usage:
//! ```
//! use verde::util::proptest::{forall, Gen};
//! forall("matmul associativity of shapes", 64, |g: &mut Gen| {
//!     let m = g.usize_in(1, 8);
//!     assert!(m >= 1);
//! });
//! ```
//!
//! On failure the panic message carries the case index and the seed, so a
//! failing case replays with `Gen::replay(seed)`.

use super::prng::SplitMix64;

/// A generator handle passed to each property invocation.
pub struct Gen {
    rng: SplitMix64,
    seed: u64,
}

impl Gen {
    pub fn replay(seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed), seed }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.next_bounded((hi - lo + 1) as u64) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// f32 with a wide exponent range — the adversarial distribution for
    /// reduction-order sensitivity tests.
    pub fn f32_wide(&mut self) -> f32 {
        let mag = self.usize_in(0, 24) as i32 - 12;
        (self.rng.next_f32() * 2.0 - 1.0) * (2.0f32).powi(mag)
    }

    pub fn vec_f32_wide(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.f32_wide()).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }
}

/// Run `prop` against `cases` generated cases. Panics (with replay seed) on
/// the first failing case.
pub fn forall(name: &str, cases: u32, mut prop: impl FnMut(&mut Gen)) {
    // Root seed fixed for CI reproducibility; vary locally by setting
    // VERDE_PROPTEST_SEED.
    let root = std::env::var("VERDE_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_u64);
    let mut seeder = SplitMix64::new(root);
    for case in 0..cases {
        let seed = seeder.next_u64();
        let mut g = Gen::replay(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| err.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {case}/{cases} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("sum is commutative", 32, |g| {
            let a = g.f32_in(-10.0, 10.0);
            let b = g.f32_in(-10.0, 10.0);
            assert_eq!((a + b).to_bits(), (b + a).to_bits());
        });
    }

    #[test]
    fn forall_reports_failures_with_seed() {
        let r = std::panic::catch_unwind(|| {
            forall("always fails", 4, |_| panic!("boom"));
        });
        let err = r.expect_err("property should fail");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("replay seed"), "got: {msg}");
        assert!(msg.contains("boom"), "got: {msg}");
    }

    #[test]
    fn gen_replay_reproduces() {
        let mut a = Gen::replay(123);
        let mut b = Gen::replay(123);
        for _ in 0..10 {
            assert_eq!(a.u64(), b.u64());
        }
    }
}
