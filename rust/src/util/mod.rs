//! Small self-contained utilities: deterministic PRNGs, a property-testing
//! harness, a benchmarking harness, CLI parsing, metrics emission, and the
//! deterministic worker pool behind the RepOps data parallelism.
//!
//! These replace crates (proptest, criterion, clap, rayon) that are
//! unavailable in the offline build environment — see DESIGN.md §4
//! substitution 5.

pub mod bench;
pub mod cli;
pub mod metrics;
pub mod parallel;
pub mod prng;
pub mod proptest;
