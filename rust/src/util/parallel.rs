//! Deterministic data-parallel execution for the RepOps kernel path.
//!
//! RepOps' reproducibility contract (paper §3.2) pins the evaluation order
//! of the **order-critical** dimension of every reduction — the K loop of a
//! matmul, the column scan of a row sum — and nothing else. The remaining
//! dimensions (M rows, N panels, batch, independent output elements) are
//! order-*insensitive*: each output element is produced by exactly one
//! fixed-order scalar computation regardless of which thread runs it or
//! when. This module farms those dimensions out to a persistent worker
//! pool, so every worker step and every dispute recomputation uses all
//! cores while producing **bitwise identical** results at any thread count
//! (`tests/par_invariance.rs` pins this from kernel level up to trainer
//! checkpoint roots).
//!
//! Design rules that keep the bits honest:
//!
//! * **Partitioning is a pure function of shape** (`chunk_range`): chunk
//!   boundaries depend only on the item count and the configured thread
//!   count — never on timing, queue depth, or work stealing. Which thread
//!   executes which chunk *is* timing-dependent, but that is invisible:
//!   chunks write disjoint outputs and share only read-only inputs.
//! * **Every chunk body is a complete, fixed-order computation** of its
//!   output elements. The pool never splits an order-critical loop.
//! * **Single-thread fallback is the identity schedule**: with 1 thread
//!   (or a busy/nested pool) the chunks run inline on the caller, in
//!   ascending order, through the same code path.
//!
//! The pool is spawn-once (threads persist across jobs; submission is a
//! mutex + condvar handoff, not a thread spawn) and dependency-free. The
//! thread count comes from, in priority order: [`set_threads`] (the
//! `--threads` CLI knob), the `VERDE_THREADS` environment variable, then
//! `std::thread::available_parallelism()`.
//!
//! Observability: regions, tasks, and inline fallbacks are counted in the
//! process-global registry (`repops_par_regions` / `repops_par_tasks` /
//! `repops_par_inline`, gauge `repops_par_threads`) — see the metric
//! catalog in `rust/README.md`.

use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

// ---------------------------------------------------------------------------
// thread-count knob
// ---------------------------------------------------------------------------

/// Desired worker count; 0 = not yet resolved (resolve lazily from
/// `VERDE_THREADS` / available parallelism on first use).
static DESIRED: AtomicUsize = AtomicUsize::new(0);

/// Set the global RepOps thread count (the `--threads` CLI knob). Takes
/// effect at the next parallel region; the persistent pool is re-sized
/// lazily. `n` is clamped to at least 1.
pub fn set_threads(n: usize) {
    DESIRED.store(n.max(1), Ordering::SeqCst);
}

/// The effective thread count parallel regions will use. Resolves and
/// caches `VERDE_THREADS` (else `available_parallelism`) on first call
/// unless [`set_threads`] already pinned a value.
pub fn threads() -> usize {
    let d = DESIRED.load(Ordering::SeqCst);
    if d != 0 {
        return d;
    }
    let n = std::env::var("VERDE_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1));
    DESIRED.store(n, Ordering::SeqCst);
    n
}

/// Deterministic contiguous split of `0..n` into `chunks` ranges: a pure
/// function of `(n, chunks, c)`. The first `n % chunks` chunks get one
/// extra item; ranges are disjoint, ascending, and cover `0..n` exactly.
pub fn chunk_range(n: usize, chunks: usize, c: usize) -> Range<usize> {
    debug_assert!(c < chunks);
    let base = n / chunks;
    let rem = n % chunks;
    let start = c * base + c.min(rem);
    let len = base + usize::from(c < rem);
    start..start + len
}

// ---------------------------------------------------------------------------
// the persistent pool
// ---------------------------------------------------------------------------

/// Lifetime-erased pointer to a job body. Only dereferenced by a thread
/// that has *won a chunk* (`next.fetch_add() < n_chunks`), which the
/// submitting thread's completion barrier guarantees happens strictly
/// before `Pool::run` returns — i.e. while the borrow is live.
#[derive(Clone, Copy)]
struct BodyPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared `&`-calls from many threads are
// fine) and the pointer is only dereferenced while the submitter keeps the
// closure alive (see `BodyPtr` docs / the safety argument in `Pool::run`).
unsafe impl Send for BodyPtr {}
unsafe impl Sync for BodyPtr {}

/// One submitted parallel region: a body and the chunk-claim/completion
/// counters. `next` hands out chunk indices (claim order is timing-
/// dependent; outputs are not), `done` counts finished chunk bodies.
/// A panicking body is caught so the completion barrier still trips
/// (no deadlocked submitter, no dead worker); the first panic payload is
/// kept and re-raised on the submitting thread.
struct Job {
    body: BodyPtr,
    n_chunks: usize,
    next: AtomicUsize,
    done: AtomicUsize,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

struct SlotState {
    job: Option<Arc<Job>>,
    generation: u64,
}

struct Shared {
    slot: Mutex<SlotState>,
    wake: Condvar,
    done_mx: Mutex<()>,
    done_cv: Condvar,
    shutdown: AtomicBool,
}

/// Claim and run chunks of `job` until none remain; the last finisher
/// signals the submitter's completion barrier.
fn run_chunks(shared: &Shared, job: &Job) {
    loop {
        let c = job.next.fetch_add(1, Ordering::Relaxed);
        if c >= job.n_chunks {
            return;
        }
        // SAFETY: `c < n_chunks` means this chunk has not been completed,
        // so the submitter is still blocked in `Pool::run` and the closure
        // behind `body` is alive.
        let body = unsafe { &*job.body.0 };
        if let Err(payload) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(c))) {
            let mut p = job.panic.lock().unwrap();
            p.get_or_insert(payload);
        }
        if job.done.fetch_add(1, Ordering::AcqRel) + 1 == job.n_chunks {
            let _g = shared.done_mx.lock().unwrap();
            shared.done_cv.notify_all();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut g = shared.slot.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if g.generation != seen {
                    seen = g.generation;
                    if let Some(j) = g.job.clone() {
                        break j;
                    }
                }
                g = shared.wake.wait(g).unwrap();
            }
        };
        run_chunks(&shared, &job);
    }
}

/// A spawn-once worker pool: `threads - 1` persistent workers plus the
/// submitting caller. One region runs at a time; concurrent or nested
/// submissions fall back to inline serial execution (same bits — the
/// schedule never changes results, only wall-clock).
pub struct Pool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    run_mx: Mutex<()>,
    threads: usize,
}

impl Pool {
    /// Spawn a pool of `threads` participants (`threads - 1` OS threads;
    /// the caller of [`Pool::run`] is the last participant).
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            slot: Mutex::new(SlotState { job: None, generation: 0 }),
            wake: Condvar::new(),
            done_mx: Mutex::new(()),
            done_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (1..threads)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("verde-par-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn parallel worker")
            })
            .collect();
        Pool { shared, handles, run_mx: Mutex::new(()), threads }
    }

    /// Number of participants (workers + caller) this pool was sized for.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute `body(c)` exactly once for every chunk `c in 0..n_chunks`,
    /// fanned out across the pool with the caller participating. Blocks
    /// until every chunk body has returned.
    ///
    /// Falls back to inline ascending-order execution when the pool is
    /// sized 1, the region is trivial, or another region is in flight
    /// (nested parallelism) — all of which are bitwise-invisible because
    /// chunk bodies are independent.
    pub fn run(&self, n_chunks: usize, body: &(dyn Fn(usize) + Sync)) {
        if n_chunks == 0 {
            return;
        }
        let guard =
            if n_chunks > 1 && self.threads > 1 { self.run_mx.try_lock().ok() } else { None };
        let _guard = match guard {
            Some(g) => g,
            None => {
                for c in 0..n_chunks {
                    body(c);
                }
                return;
            }
        };
        // SAFETY: erase the borrow's lifetime so worker threads can hold a
        // copy. Sound because this function does not return until `done ==
        // n_chunks`, i.e. until every dereference of the pointer has
        // completed; late-waking workers that lose the claim race never
        // dereference it (see `run_chunks`).
        let body_static: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(body) };
        let job = Arc::new(Job {
            body: BodyPtr(body_static),
            n_chunks,
            next: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            panic: Mutex::new(None),
        });
        {
            let mut g = self.shared.slot.lock().unwrap();
            g.generation = g.generation.wrapping_add(1);
            g.job = Some(Arc::clone(&job));
            self.shared.wake.notify_all();
        }
        run_chunks(&self.shared, &job);
        {
            let mut g = self.shared.done_mx.lock().unwrap();
            while job.done.load(Ordering::Acquire) < n_chunks {
                g = self.shared.done_cv.wait(g).unwrap();
            }
        }
        // Drop the slot's copy so no lifetime-erased pointer outlives the
        // region (workers' own clones die as they re-enter the wait loop
        // without touching the body).
        self.shared.slot.lock().unwrap().job = None;
        // Surface a chunk panic on the submitting thread with its original
        // payload (assert messages survive; `#[should_panic]` tests work).
        if let Some(p) = job.panic.lock().unwrap().take() {
            std::panic::resume_unwind(p);
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        {
            let _g = self.shared.slot.lock().unwrap();
            self.shared.wake.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The process-global pool, lazily created and lazily re-sized when the
/// knob changes. Regions hold an `Arc` for their duration, so a re-size
/// never tears down a pool mid-region.
fn pool() -> Arc<Pool> {
    static POOL: Mutex<Option<Arc<Pool>>> = Mutex::new(None);
    let want = threads();
    let mut g = POOL.lock().unwrap();
    match g.as_ref() {
        Some(p) if p.threads() == want => Arc::clone(p),
        _ => {
            let p = Arc::new(Pool::new(want));
            *g = Some(Arc::clone(&p));
            p
        }
    }
}

// ---------------------------------------------------------------------------
// high-level entry points
// ---------------------------------------------------------------------------

/// Minimum scalar work per chunk before an elementwise/movement region
/// fans out; below it the pool overhead dwarfs the arithmetic.
pub const EW_GRAIN: usize = 16 * 1024;

/// Minimum multiply-add work per chunk for matmul-family fan-out.
pub const MM_GRAIN: usize = 128 * 1024;

struct ParObs {
    regions: crate::obs::Counter,
    tasks: crate::obs::Counter,
    inline: crate::obs::Counter,
    threads: crate::obs::Gauge,
}

fn par_obs() -> &'static ParObs {
    static OBS: OnceLock<ParObs> = OnceLock::new();
    OBS.get_or_init(|| {
        let g = crate::obs::global();
        ParObs {
            regions: g.counter("repops_par_regions"),
            tasks: g.counter("repops_par_tasks"),
            inline: g.counter("repops_par_inline"),
            threads: g.gauge("repops_par_threads"),
        }
    })
}

/// Run `body` over `0..n` split into contiguous chunks of at least
/// `min_items` items each, at most one chunk per configured thread. Chunk
/// boundaries are a pure function of `(n, min_items, threads())`.
///
/// `body` must be safe to call concurrently on disjoint ranges; together
/// the calls cover `0..n` exactly once.
pub fn for_each_chunk(n: usize, min_items: usize, body: impl Fn(Range<usize>) + Sync) {
    if n == 0 {
        return;
    }
    let chunks = threads().min(n.div_ceil(min_items.max(1)));
    if chunks <= 1 {
        par_obs().inline.inc();
        body(0..n);
        return;
    }
    let obs = par_obs();
    obs.regions.inc();
    obs.tasks.add(chunks as u64);
    obs.threads.set(threads() as u64);
    pool().run(chunks, &|c| body(chunk_range(n, chunks, c)));
}

/// A `Send + Sync` raw `*mut f32`, for fanning disjoint writes of one
/// output buffer across chunk bodies. The caller is responsible for the
/// disjointness; every use in this crate derives the written region from
/// the chunk's own (disjoint-by-construction) range.
#[derive(Clone, Copy)]
pub struct SendPtr(*mut f32);

// SAFETY: raw pointers carry no aliasing claim; all dereferences in this
// crate write chunk-disjoint regions (see `SendPtr` docs).
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

impl SendPtr {
    pub fn new(p: *mut f32) -> SendPtr {
        SendPtr(p)
    }

    pub fn get(self) -> *mut f32 {
        self.0
    }
}

/// Split `out` at multiples of `stride` floats into per-chunk sub-slices
/// (at least `min_rows` rows each) and run `body(first_row, sub_slice)`
/// over them in parallel. Sub-slices are disjoint, so each body owns its
/// rows exclusively; `out.len()` must be a multiple of `stride`.
pub fn for_each_row_chunk(
    out: &mut [f32],
    stride: usize,
    min_rows: usize,
    body: impl Fn(usize, &mut [f32]) + Sync,
) {
    assert!(stride > 0, "row stride must be positive");
    assert_eq!(out.len() % stride, 0, "output length must be a multiple of the row stride");
    let rows = out.len() / stride;
    let base = SendPtr::new(out.as_mut_ptr());
    for_each_chunk(rows, min_rows, move |r| {
        // SAFETY: chunk ranges are disjoint and in-bounds, so the derived
        // sub-slices never alias each other or escape `out`.
        let chunk = unsafe {
            std::slice::from_raw_parts_mut(
                base.get().add(r.start * stride),
                (r.end - r.start) * stride,
            )
        };
        body(r.start, chunk);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_ranges_cover_exactly_and_are_balanced() {
        for n in [0usize, 1, 2, 7, 32, 33, 100, 1023] {
            for chunks in 1..=9usize {
                if n == 0 {
                    continue;
                }
                let mut seen = vec![false; n];
                let mut sizes = Vec::new();
                let mut prev_end = 0;
                for c in 0..chunks {
                    let r = chunk_range(n, chunks, c);
                    assert_eq!(r.start, prev_end, "contiguous ascending ({n},{chunks},{c})");
                    prev_end = r.end;
                    sizes.push(r.len());
                    for i in r {
                        assert!(!seen[i], "item {i} covered twice");
                        seen[i] = true;
                    }
                }
                assert_eq!(prev_end, n, "full coverage ({n},{chunks})");
                assert!(seen.iter().all(|&s| s));
                let (mn, mx) =
                    (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(mx - mn <= 1, "sizes within 1 of each other ({n},{chunks})");
            }
        }
    }

    #[test]
    fn pool_runs_every_chunk_exactly_once() {
        let pool = Pool::new(4);
        let n = 64;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.run(n, &|c| {
            hits[c].fetch_add(1, Ordering::SeqCst);
        });
        for (c, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "chunk {c}");
        }
    }

    #[test]
    fn pool_reuses_threads_across_jobs() {
        let pool = Pool::new(3);
        let total = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.run(8, &|_c| {
                total.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(total.load(Ordering::SeqCst), 400);
    }

    #[test]
    fn nested_regions_fall_back_inline() {
        let pool = Pool::new(2);
        let outer = AtomicUsize::new(0);
        let inner = AtomicUsize::new(0);
        pool.run(2, &|_| {
            outer.fetch_add(1, Ordering::SeqCst);
            // the nested submission must not deadlock; it runs inline
            pool.run(3, &|_| {
                inner.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(outer.load(Ordering::SeqCst), 2);
        assert_eq!(inner.load(Ordering::SeqCst), 6);
    }

    #[test]
    fn chunk_panic_propagates_and_pool_survives() {
        let pool = Pool::new(4);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, &|c| {
                if c == 3 {
                    panic!("chunk boom");
                }
            });
        }));
        assert!(res.is_err(), "submitter sees the chunk panic");
        // the barrier tripped and no worker died: the pool still works
        let total = AtomicUsize::new(0);
        pool.run(4, &|_| {
            total.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = Pool::new(1);
        let sum = AtomicUsize::new(0);
        pool.run(5, &|c| {
            sum.fetch_add(c + 1, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 15);
    }

    #[test]
    fn row_chunks_write_disjoint_rows() {
        let mut out = vec![0.0f32; 12 * 7];
        for_each_row_chunk(&mut out, 7, 1, |first, chunk| {
            for (i, row) in chunk.chunks_mut(7).enumerate() {
                for x in row.iter_mut() {
                    *x += (first + i) as f32;
                }
            }
        });
        for (r, row) in out.chunks(7).enumerate() {
            assert!(row.iter().all(|&x| x == r as f32), "row {r} written once");
        }
    }
}
