//! Tiny CLI argument parser (stand-in for clap, unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable) — skips argv[0].
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.options.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn parse() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants an integer, got '{v}'")))
            .unwrap_or(default)
    }

    /// Comma-separated list value (`--workers a:1,b:2`); empty when absent.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        self.get(name)
            .map(|v| v.split(',').filter(|s| !s.is_empty()).map(str::to_string).collect())
            .unwrap_or_default()
    }

    pub fn get_f32(&self, name: &str, default: f32) -> f32 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants a float, got '{v}'")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_mixed_styles() {
        // NOTE: a `--key` followed by a non-dashed token consumes it as the
        // value, so bare flags go last or use `--key=value` style.
        let a = parse("train extra --steps 100 --model=llama-tiny --verbose");
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.get("steps"), Some("100"));
        assert_eq!(a.get("model"), Some("llama-tiny"));
        assert!(a.flag("verbose"));
        assert_eq!(a.get_usize("steps", 0), 100);
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn list_values_split_on_commas() {
        let a = parse("coordinator --workers 127.0.0.1:7000,127.0.0.1:7001");
        assert_eq!(a.get_list("workers"), vec!["127.0.0.1:7000", "127.0.0.1:7001"]);
        assert!(a.get_list("absent").is_empty());
    }

    #[test]
    fn trailing_flag_is_flag() {
        let a = parse("run --fast");
        assert!(a.flag("fast"));
        assert_eq!(a.get("fast"), None);
    }
}
