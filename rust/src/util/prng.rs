//! Deterministic pseudo-random number generation.
//!
//! All randomness in the system (weight init, synthetic data, fault
//! placement, property-test case generation) flows through these seeded
//! generators, which is what lets two independent trainers — and the test
//! suite — reproduce identical bit streams. The paper relies on "built-in
//! support for deterministic pseudorandomness" (§3.1); this module is our
//! equivalent.

/// SplitMix64 — tiny, fast, full-period 2^64 stream; the recommended seeder
/// for other generators and good enough statistically for synthetic data.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)` with 24 bits of mantissa entropy (exact in f32).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` (bound > 0), via 128-bit multiply —
    /// avoids modulo bias.
    #[inline]
    pub fn next_bounded(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller (fixed operation order; uses libm only
    /// in test/data-generation contexts, never inside RepOps kernels).
    pub fn next_normal(&mut self) -> f32 {
        let u1 = self.next_f32().max(1e-7);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_bounded(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Derive a fresh stream for a labelled sub-purpose, so e.g. "weights of
/// layer 3" and "batch 17 of the corpus" never share a stream even under the
/// same root seed.
pub fn derive_seed(root: u64, label: &str, index: u64) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ root;
    for b in label.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h ^= index;
    h = h.wrapping_mul(0x0000_0100_0000_01B3);
    SplitMix64::new(h).next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn bounded_respects_bound() {
        let mut r = SplitMix64::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.next_bounded(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn derive_seed_separates_labels() {
        let a = derive_seed(1, "weights", 0);
        let b = derive_seed(1, "data", 0);
        let c = derive_seed(1, "weights", 1);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, derive_seed(1, "weights", 0));
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = SplitMix64::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.next_normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "astronomically unlikely");
    }
}
