//! Lightweight metrics: counters and a JSON-lines emitter.
//!
//! The protocol accounts for the quantities the paper reasons about —
//! bytes communicated per party, steps re-executed, hashes computed,
//! operators recomputed by the referee — through [`Counters`].

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A named bag of monotonically increasing counters.
#[derive(Debug, Default, Clone)]
pub struct Counters {
    vals: BTreeMap<String, u64>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, key: &str, delta: u64) {
        *self.vals.entry(key.to_string()).or_insert(0) += delta;
    }

    pub fn incr(&mut self, key: &str) {
        self.add(key, 1);
    }

    pub fn get(&self, key: &str) -> u64 {
        self.vals.get(key).copied().unwrap_or(0)
    }

    pub fn merge(&mut self, other: &Counters) {
        for (k, v) in &other.vals {
            self.add(k, *v);
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.vals.iter().map(|(k, &v)| (k.as_str(), v))
    }

    /// Render as a single JSON object (sorted keys, stable output).
    pub fn to_json(&self) -> String {
        let mut s = String::from("{");
        for (i, (k, v)) in self.vals.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "\"{k}\":{v}");
        }
        s.push('}');
        s
    }
}

/// Human-friendly byte formatting for reports.
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_merge() {
        let mut a = Counters::new();
        a.add("bytes", 10);
        a.incr("msgs");
        let mut b = Counters::new();
        b.add("bytes", 5);
        a.merge(&b);
        assert_eq!(a.get("bytes"), 15);
        assert_eq!(a.get("msgs"), 1);
        assert_eq!(a.get("absent"), 0);
    }

    #[test]
    fn json_stable_sorted() {
        let mut c = Counters::new();
        c.add("z", 1);
        c.add("a", 2);
        assert_eq!(c.to_json(), "{\"a\":2,\"z\":1}");
    }

    #[test]
    fn human_bytes_formats() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 << 30), "3.00 GiB");
    }
}
