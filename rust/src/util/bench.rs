//! Benchmark harness (stand-in for criterion, unavailable offline).
//!
//! Provides warmup + repeated timed runs with median/min/mean reporting and
//! a machine-readable JSON line per measurement, which the bench binaries
//! use to regenerate the paper's tables and figures (EXPERIMENTS.md).

use std::time::{Duration, Instant};

/// One measured quantity.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
    pub samples: usize,
}

impl Measurement {
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

/// Time `f` with `warmup` unmeasured runs then `samples` measured runs.
/// The closure's return value is consumed via `std::hint::black_box` so the
/// optimizer cannot elide the work.
pub fn time<T>(name: &str, warmup: usize, samples: usize, mut f: impl FnMut() -> T) -> Measurement {
    assert!(samples > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    times.sort();
    let median = times[times.len() / 2];
    let min = times[0];
    let mean = times.iter().sum::<Duration>() / samples as u32;
    Measurement { name: name.to_string(), median, mean, min, samples }
}

/// Adaptive variant: keeps sampling until `min_total` wall time is spent or
/// `max_samples` reached — good for very fast ops.
pub fn time_adaptive<T>(name: &str, min_total: Duration, max_samples: usize, mut f: impl FnMut() -> T) -> Measurement {
    // warmup once
    std::hint::black_box(f());
    let mut times = Vec::new();
    let start = Instant::now();
    while start.elapsed() < min_total && times.len() < max_samples {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    if times.is_empty() {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    times.sort();
    let median = times[times.len() / 2];
    let min = times[0];
    let mean = times.iter().sum::<Duration>() / times.len() as u32;
    Measurement { name: name.to_string(), median, mean, min, samples: times.len() }
}

/// Overhead of `slow` relative to `fast`, in percent (the paper's metric:
/// "RepOps incurs X% extra time").
pub fn overhead_pct(slow: &Measurement, fast: &Measurement) -> f64 {
    (slow.median_secs() / fast.median_secs() - 1.0) * 100.0
}

/// Pretty-print a table row and emit a JSON line for downstream tooling.
pub fn report(m: &Measurement, extra: &[(&str, String)]) {
    let mut json = format!(
        "{{\"name\":\"{}\",\"median_s\":{:.9},\"mean_s\":{:.9},\"min_s\":{:.9},\"samples\":{}",
        m.name,
        m.median.as_secs_f64(),
        m.mean.as_secs_f64(),
        m.min.as_secs_f64(),
        m.samples
    );
    for (k, v) in extra {
        json.push_str(&format!(",\"{k}\":{v}"));
    }
    json.push('}');
    println!("  {:<48} median {:>12?}  (n={})", m.name, m.median, m.samples);
    println!("JSON {json}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_measures_something() {
        let m = time("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(m.median > Duration::ZERO);
        assert_eq!(m.samples, 5);
        assert!(m.min <= m.median);
    }

    #[test]
    fn overhead_pct_sane() {
        let fast = Measurement {
            name: "f".into(),
            median: Duration::from_millis(10),
            mean: Duration::from_millis(10),
            min: Duration::from_millis(10),
            samples: 1,
        };
        let slow = Measurement {
            name: "s".into(),
            median: Duration::from_millis(15),
            mean: Duration::from_millis(15),
            min: Duration::from_millis(15),
            samples: 1,
        };
        let o = overhead_pct(&slow, &fast);
        assert!((o - 50.0).abs() < 1e-9);
    }
}
