//! Dense FP32 tensors and the two operator families Verde arbitrates over:
//!
//! * [`repops`] — **RepOps**: bitwise-reproducible operators with a fixed
//!   floating-point evaluation order (paper §3).
//! * [`baseline`] — hardware-tuned, *free-order* operators whose reduction
//!   order depends on a [`HardwareProfile`](profile::HardwareProfile),
//!   standing in for cuDNN/torch on the paper's four GPUs (DESIGN.md §4.1).
//!
//! All tensors are contiguous, row-major, `f32`. FP32 is the only dtype the
//! paper's RepOps supports (IEEE-754 compliance, §4), so it is the only
//! arithmetic dtype here; integer tensors (token ids) are carried as `f32`
//! bit-exact integers which is lossless below 2^24.

pub mod baseline;
pub mod math;
pub mod profile;
pub mod repops;

use std::fmt;

/// A dense, contiguous, row-major FP32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Build a tensor from a shape and a flat row-major buffer.
    ///
    /// # Panics
    /// If `data.len()` does not equal the product of `shape`.
    pub fn new(shape: impl Into<Vec<usize>>, data: Vec<f32>) -> Self {
        let shape = shape.into();
        let numel: usize = shape.iter().product();
        assert_eq!(
            data.len(),
            numel,
            "shape {:?} wants {} elements, got {}",
            shape,
            numel,
            data.len()
        );
        Self { shape, data }
    }

    /// All-zeros tensor.
    pub fn zeros(shape: impl Into<Vec<usize>>) -> Self {
        let shape = shape.into();
        let numel = shape.iter().product();
        Self { shape, data: vec![0.0; numel] }
    }

    /// Tensor filled with `value`.
    pub fn full(shape: impl Into<Vec<usize>>, value: f32) -> Self {
        let shape = shape.into();
        let numel = shape.iter().product();
        Self { shape, data: vec![value; numel] }
    }

    /// Scalar (rank-0) tensor.
    pub fn scalar(value: f32) -> Self {
        Self { shape: vec![], data: vec![value] }
    }

    /// Deterministically pseudo-random tensor in `[-scale, scale)`,
    /// generated from a [`SplitMix64`](crate::util::prng::SplitMix64) stream.
    /// Used for synthetic weights and data; the same seed always produces the
    /// same bits, which the whole protocol relies on.
    pub fn rand(shape: impl Into<Vec<usize>>, seed: u64, scale: f32) -> Self {
        let shape = shape.into();
        let numel: usize = shape.iter().product();
        let mut rng = crate::util::prng::SplitMix64::new(seed);
        let data = (0..numel)
            .map(|_| (rng.next_f32() * 2.0 - 1.0) * scale)
            .collect();
        Self { shape, data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Size in bytes of the raw FP32 payload.
    pub fn byte_len(&self) -> usize {
        self.data.len() * 4
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(&self, shape: impl Into<Vec<usize>>) -> Tensor {
        let shape = shape.into();
        assert_eq!(
            shape.iter().product::<usize>(),
            self.numel(),
            "reshape {:?} -> {:?} changes element count",
            self.shape,
            shape
        );
        Tensor { shape, data: self.data.clone() }
    }

    /// 2-D strict accessor.
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Bitwise equality — the equality Verde cares about. `PartialEq` on
    /// floats treats `-0.0 == 0.0` and `NaN != NaN`; commitments hash raw
    /// bits, so tests should use this.
    pub fn bit_eq(&self, other: &Tensor) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| a.to_bits() == b.to_bits())
    }

    /// Max absolute elementwise difference (for *approximate* comparisons
    /// against oracles only — never for protocol decisions).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Raw little-endian bytes of the payload (hashing, wire format).
    pub fn to_le_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.data.len() * 4);
        for v in &self.data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// Inverse of [`Tensor::to_le_bytes`].
    pub fn from_le_bytes(shape: impl Into<Vec<usize>>, bytes: &[u8]) -> Self {
        assert_eq!(bytes.len() % 4, 0);
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Self::new(shape, data)
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.numel() <= 8 {
            write!(f, " {:?}", self.data)
        } else {
            write!(f, " [{:?}, {:?}, ..]", self.data[0], self.data[1])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_numel() {
        let t = Tensor::new([2, 3], vec![0.0; 6]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.shape(), &[2, 3]);
    }

    #[test]
    #[should_panic]
    fn new_rejects_bad_numel() {
        Tensor::new([2, 3], vec![0.0; 5]);
    }

    #[test]
    fn rand_is_seed_deterministic() {
        let a = Tensor::rand([4, 4], 7, 1.0);
        let b = Tensor::rand([4, 4], 7, 1.0);
        let c = Tensor::rand([4, 4], 8, 1.0);
        assert!(a.bit_eq(&b));
        assert!(!a.bit_eq(&c));
    }

    #[test]
    fn bytes_roundtrip() {
        let a = Tensor::rand([3, 5], 42, 2.0);
        let b = Tensor::from_le_bytes([3, 5], &a.to_le_bytes());
        assert!(a.bit_eq(&b));
    }

    #[test]
    fn bit_eq_distinguishes_signed_zero() {
        let a = Tensor::new([1], vec![0.0]);
        let b = Tensor::new([1], vec![-0.0]);
        assert_eq!(a, b); // PartialEq: equal
        assert!(!a.bit_eq(&b)); // bitwise: different
    }

    #[test]
    fn reshape_preserves_bits() {
        let a = Tensor::rand([2, 6], 1, 1.0);
        let b = a.reshape([3, 4]);
        assert_eq!(b.shape(), &[3, 4]);
        assert_eq!(a.data(), b.data());
    }
}
